"""Hypothesis import guard for minimal environments.

The tier-1 CI image may lack ``hypothesis``; the property tests must *skip*
there rather than break collection of their whole module (the seed's
top-level ``from hypothesis import ...`` errored out four test files, taking
every plain unit test in them down too).  A module-level
``pytest.importorskip("hypothesis")`` would likewise skip the unit tests, so
guarded modules instead do

    from hypothesis_compat import given, settings, st

which resolves to the real hypothesis when installed (the ``dev`` extra in
pyproject.toml) and to skip-marking stand-ins otherwise.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # minimal env: property tests skip, unit tests still run
    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stand-in for hypothesis.strategies: every call yields a dummy."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
