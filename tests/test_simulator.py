"""End-to-end simulator behaviour: paper-property reproduction at test scale,
fault tolerance, elastic scaling, straggler mitigation, oracle staleness."""

import numpy as np
import pytest

from repro.sim import FaultEvent, SimConfig, Simulation, run_sim
from repro.sim.instances import RequestState
from repro.sim.kvcache import BlockCache
from repro.traces import generate_trace, profile_capacity
from repro.traces.mooncake import Request


def _trace(profile="rag", dur=12.0, frac=1.0, seed=0, **kw):
    cap = profile_capacity(profile)
    return generate_trace(profile, duration=dur, target_rps=cap * frac, seed=seed, **kw)


def _cfg(sched, seed=0, **kw):
    kw.setdefault("warmup", 2.0)
    kw.setdefault("measure", 8.0)
    kw.setdefault("background", 0.2)
    return SimConfig(scheduler=sched, seed=seed, **kw)


TRACE = _trace()


class TestSchedulerOrdering:
    """The paper's headline ordering at 100% RAG load."""

    def test_netkv_beats_rr_and_cla(self):
        ms = {s: run_sim(_cfg(s), TRACE) for s in ("rr", "cla", "netkv-full")}
        assert ms["netkv-full"].ttft_mean < ms["cla"].ttft_mean
        assert ms["netkv-full"].ttft_mean < ms["rr"].ttft_mean
        assert ms["netkv-full"].xfer_mean < ms["rr"].xfer_mean

    def test_tbt_overhead_below_half_ms(self):
        """§VI-J: NetKV's TBT cost vs CLA* stays under 0.5 ms."""
        cla = run_sim(_cfg("cla"), TRACE)
        nk = run_sim(_cfg("netkv-full"), TRACE)
        assert abs(nk.tbt_mean - cla.tbt_mean) < 0.5e-3

    def test_tier_shifting(self):
        """Table VI: NetKV shifts transfers toward tier 2."""
        rr = run_sim(_cfg("rr"), TRACE)
        nk = run_sim(_cfg("netkv-full"), TRACE)
        assert nk.tier_fraction[2] > rr.tier_fraction[2]
        assert nk.tier_fraction[3] < rr.tier_fraction[3]
        # pack placement: tiers 0/1 unreached
        assert rr.tier_fraction[0] == 0 and rr.tier_fraction[1] == 0

    def test_ablation_ladder_order(self):
        """Table IV: every rung is at least as good as the previous (with
        tolerance — dynamic congestion may add a small residual either way)."""
        cla = run_sim(_cfg("cla"), TRACE)
        topo = run_sim(_cfg("netkv-topo"), TRACE)
        static = run_sim(_cfg("netkv-static"), TRACE)
        assert topo.ttft_mean < cla.ttft_mean  # static tier signal dominates
        assert static.ttft_mean < cla.ttft_mean


class TestOracleStaleness:
    def test_minute_refresh_harmless(self):
        """Exp 4: 100 ms vs 60 s refresh changes TTFT by < 10%."""
        fast = run_sim(_cfg("netkv-full", oracle_refresh=0.1), TRACE)
        slow = run_sim(_cfg("netkv-full", oracle_refresh=60.0), TRACE)
        assert abs(fast.ttft_mean - slow.ttft_mean) / fast.ttft_mean < 0.10


class TestFaultTolerance:
    def test_decode_failure_requeues_and_completes(self):
        faults = [FaultEvent(time=4.0, kind="kill_decode", instance_id=5)]
        m = run_sim(_cfg("netkv-full", faults=faults), TRACE)
        assert m.requeues > 0                    # victims re-ran
        assert m.n_unfinished == 0               # and completed
        assert m.slo_attainment > 0.3            # cluster survived

    def test_elastic_scale_up(self):
        faults = [FaultEvent(time=3.0, kind="add_decode", instance_id=0)]
        m = run_sim(_cfg("netkv-full", faults=faults), TRACE)
        assert m.n_unfinished == 0

    def test_elastic_join_lands_on_least_populated_server(self):
        """add_decode places the new instance on the decode-hosting server
        with the fewest healthy resident decode instances — after a kill,
        that is the dead instance's server — and it becomes schedulable."""
        faults = [
            FaultEvent(time=1.0, kind="kill_decode", instance_id=5),
            FaultEvent(time=3.0, kind="add_decode"),
        ]
        cfg = _cfg("netkv-full", faults=faults)
        sim = Simulation(cfg)
        dead_server = sim._decode_by_id(5).server
        sim.run(TRACE)
        new = sim.decode[-1]
        assert new.instance_id == max(sim._server_of)
        assert new.server == dead_server          # thinnest decode population
        assert bool(sim.view.healthy[new.slot])   # scheduler-visible
        assert new.iterations > 0                 # actually received work

    def test_elastic_join_spreads_across_servers(self):
        """With all servers equally populated, consecutive joins never stack
        on the server a previous join already thickened."""
        faults = [FaultEvent(time=2.0 + i, kind="add_decode") for i in range(2)]
        cfg = _cfg("netkv-full", faults=faults)
        sim = Simulation(cfg)
        sim.run(TRACE)
        joined = sim.decode[-2:]
        assert joined[0].server != joined[1].server

    def test_straggler_detected_and_avoided(self):
        """A 4x-slowed instance should receive fewer requests under LA-aware
        policies once the EWMA detector converges."""
        faults = [FaultEvent(time=0.0, kind="slowdown", instance_id=5, factor=4.0)]
        cfg = _cfg("netkv-full", faults=faults)
        sim = Simulation(cfg)
        m = sim.run(_trace(dur=10.0))
        slow = next(d for d in sim.decode if d.instance_id == 5)
        others = [d for d in sim.decode if d.instance_id != 5]
        assert slow.iter_scale_est > 2.0         # detector converged
        mean_iters = np.mean([d.iterations for d in others])
        # the slow instance ran fewer iterations per unit time by construction;
        # scheduling kept its queue from exploding
        assert slow.queued <= max(d.queued for d in others) + 2

    def test_dead_prefill_rejects_cleanly(self):
        cfg = _cfg("netkv-full")
        sim = Simulation(cfg)
        for p in sim.prefill:
            p.healthy = False
        m = sim.run(TRACE)
        assert m.n_rejected == len(TRACE)


class TestDetectionDelay:
    """Health flips scheduler-visible only after the detection delay; in the
    window, dispatches to the dead instance bounce and requeue."""

    def test_visibility_lags_by_detection_delay(self):
        faults = [FaultEvent(time=0.5, kind="kill_decode", instance_id=5,
                             detection_delay=0.25)]
        sim = Simulation(_cfg("netkv-full", faults=faults))
        sim.load_trace([])
        dec = sim._decode_by_id(5)
        sim.loop.run(until=0.6)
        assert dec.healthy is False                       # engine truth: dead
        assert bool(sim.view.healthy[dec.slot]) is True   # not yet detected
        sim.loop.run(until=0.8)
        assert bool(sim.view.healthy[dec.slot]) is False  # visible after delay

    def test_window_dispatch_bounces_and_requeues(self):
        """Single-decode cluster: a request scheduled inside the detection
        window is dispatched to the dead instance, bounces at transfer-landing
        time, and requeues — it is NOT rejected up front."""
        cfg = SimConfig(scheduler="netkv-full", n_pods=1, racks_per_pod=1,
                        servers_per_rack=1, gpus_per_server=8, tp=4,
                        n_prefill=1, warmup=0.0, measure=5.0, background=0.0,
                        faults=[FaultEvent(time=0.5, kind="kill_decode",
                                           instance_id=-1,
                                           detection_delay=1.0)])
        sim = Simulation(cfg)
        assert len(sim.decode) == 1
        cfg.faults[0].instance_id = sim.decode[0].instance_id
        # Short prompt: prefill lands well inside the (0.5, 1.5) window.
        req = Request(request_id=0, arrival=0.55, input_len=128, output_len=4,
                      block_hashes=tuple(("t", i) for i in range(8)),
                      share_group=-1, slo=2.0)
        sim.load_trace([req])
        sim.loop.run(until=5.0)
        rs = sim.records[0]
        assert rs.requeues > 0      # dispatched to the dead instance, bounced
        assert rs.rejected          # only decode instance never recovers


class TestRequeueReset:
    def test_requeue_clears_per_attempt_fields(self):
        """Regression: a requeued request must not keep sched_time /
        first_token / admit_time / tier / s_eff / hit_tokens from the failed
        attempt — a stale first_token reports a phantom TTFT for a request
        that never decoded on the new attempt."""
        sim = Simulation(_cfg("netkv-full"))
        sim.load_trace([])
        rs = RequestState(req=TRACE[0], kv_bytes=1e6)
        rs.sched_time = 1.0
        rs.first_token = 2.0
        rs.admit_time = 1.5
        rs.tier = 3
        rs.s_eff = 5e5
        rs.hit_tokens = 128.0
        rs.decode_instance = 5
        rs.tokens_out = 7
        rs.transfer_end = 1.2
        sim._requeue(rs, 2.5)
        assert rs.sched_time == -1.0
        assert rs.first_token == -1.0
        assert rs.admit_time == -1.0
        assert rs.tier == -1
        assert rs.s_eff == 0.0
        assert rs.hit_tokens == 0.0
        assert rs.decode_instance == -1
        assert rs.tokens_out == 0
        assert rs.transfer_end == -1.0
        assert rs.requeues == 1 and not rs.rejected

    def test_no_phantom_ttft_after_fault(self):
        """Every record that reports a finite TTFT actually produced a first
        token after its last (re)scheduling."""
        faults = [FaultEvent(time=4.0, kind="kill_decode", instance_id=5)]
        cfg = _cfg("netkv-full", faults=faults)
        sim = Simulation(cfg)
        m = sim.run(TRACE)
        assert m.requeues > 0
        for rs in sim.records:
            if rs.first_token >= 0:
                assert rs.first_token >= rs.sched_time >= 0


class TestDeterminism:
    def test_same_seed_same_result(self):
        a = run_sim(_cfg("netkv-full", seed=7), TRACE)
        b = run_sim(_cfg("netkv-full", seed=7), TRACE)
        assert a.ttft_mean == b.ttft_mean
        assert a.tier_fraction == b.tier_fraction


class TestBlockCache:
    def test_lcp_semantics(self):
        c = BlockCache(budget_bytes=1e9, bytes_per_block=1e3)
        c.insert([("a", 0), ("a", 1), ("a", 3)])
        # LCP requires consecutiveness: block 2 missing stops the prefix at 2
        assert c.lcp_blocks([("a", 0), ("a", 1), ("a", 2), ("a", 3)]) == 2

    def test_lru_eviction(self):
        c = BlockCache(budget_bytes=3e3, bytes_per_block=1e3)
        c.insert([1, 2, 3])
        c.touch([1])          # 2 becomes LRU
        c.insert([4])
        assert 2 not in c and 1 in c and 4 in c

    def test_hit_clamped_to_input(self):
        c = BlockCache(budget_bytes=1e9, bytes_per_block=1e3)
        c.insert([("a", i) for i in range(10)])
        assert c.hit_tokens([("a", i) for i in range(10)], input_len=50) == 50


class TestBatchScheduler:
    def test_batch_mode_runs(self):
        m = run_sim(_cfg("netkv-batch"), TRACE)
        assert m.n_unfinished == 0
        assert np.isfinite(m.ttft_mean)


class TestKVGrowthAccounting:
    """Regression: decode-side KV growth (one token per iteration) can push
    pinned bytes past the budget.  The scheduler-visible free_memory must
    clamp at zero (no phantom negative capacity) and growth must keep
    evicting the LRU cache — on both instance engines."""

    def _grow_past_budget(self, engine):
        from repro.core.cost import B_TOK, H100_TP4_ITER, H100_TP4_PREFILL, \
            LLAMA3_70B_KV
        from repro.core.view import ClusterView
        from repro.sim import EventLoop, InstancePlane, \
            ReferenceInstanceEngine, RequestState

        class Meta:
            def __init__(self, iid, srv):
                self.instance_id, self.server = iid, srv

        kpt = LLAMA3_70B_KV.kv_bytes_per_token
        req = Request(request_id=0, arrival=0.0, input_len=128, output_len=64,
                      block_hashes=tuple(("k", i) for i in range(8)),
                      share_group=-1, slo=5.0)
        rs = RequestState(req=req, kv_bytes=float(LLAMA3_70B_KV.kv_bytes(128)))
        # Budget: the pinned prefix plus 3 cache blocks of headroom.  The 8
        # inserted prefix blocks don't all fit (insert evicts 5), and the 64
        # output tokens of decode growth (= 4 blocks of bytes) evict the
        # rest mid-decode and then overcommit the budget outright.
        budget = rs.kv_bytes + 3 * (kpt * B_TOK)
        loop = EventLoop()
        view = ClusterView(capacity=1)
        cls = InstancePlane if engine == "plane" else ReferenceInstanceEngine
        eng = cls([], [Meta(0, (0, 0, 0))], view=view, loop=loop,
                  iter_model=H100_TP4_ITER, prefill_model=H100_TP4_PREFILL,
                  beta_max=4, kv_spec=LLAMA3_70B_KV, kv_budget=budget)
        eng.set_decode_callbacks(None, None)
        eng.reserve(0, rs, 0.0)
        eng.enqueue(0, rs, 0.0)
        eng.kick([0], 0.0)
        min_free = float("inf")
        while not loop.empty():
            nt = loop.next_time()
            loop.run(until=nt)
            min_free = min(min_free, float(view.free_memory[0]))
        assert rs.finish > 0
        stats = eng.cache_stats()[0]
        return min_free, stats

    @pytest.mark.parametrize("engine", ["plane", "reference"])
    def test_free_memory_clamped_and_cache_evicted(self, engine):
        min_free, stats = self._grow_past_budget(engine)
        assert min_free == 0.0          # overcommitted, but never negative
        assert stats["evictions"] > 0   # growth evicted the resident blocks
        assert stats["bytes_used"] == 0.0

    def test_no_negative_free_memory_in_full_run(self):
        sim = Simulation(_cfg("netkv-full"))
        sim.run(TRACE)
        assert (sim.view.free_memory[: sim.view.n] >= 0.0).all()


class TestMeasuredTelemetry:
    """Satellite: oracle source='measured' aggregates FlowPlane link
    counters instead of reading the background model's ground truth."""

    def test_measured_matches_static_background_when_idle(self):
        from repro.cluster.network import BackgroundTraffic, FlowPlane
        from repro.cluster.topology import FatTree

        net = FlowPlane(FatTree(), BackgroundTraffic(0.3), seed=0)
        m = net.measured_tier_congestion(0.0)
        truth = net.tier_congestion(0.0)
        assert m[0] == 0.0  # tier 0 (NVLink) has no fabric links
        for t in (1, 2, 3):
            assert m[t] == pytest.approx(truth[t], abs=1e-9)

    def test_measured_sees_own_kv_traffic(self):
        from repro.cluster.network import BackgroundTraffic, FlowPlane
        from repro.cluster.topology import FatTree

        net = FlowPlane(FatTree(), BackgroundTraffic(0.2), seed=0)
        net.start_transfer((0, 0, 0), (1, 1, 1), 1e12, 0.0, lambda t, n: None)
        with_kv = net.measured_tier_congestion(0.0)
        without = net.measured_tier_congestion(0.0, include_kv=False)
        assert with_kv[3] > without[3]  # cross-pod flow shows in the counters
        for t in (1, 2, 3):
            assert without[t] == pytest.approx(0.2, abs=1e-9)

    def test_sim_runs_with_measured_source(self):
        m = run_sim(_cfg("netkv-full", telemetry_source="measured"),
                    TRACE[: len(TRACE) // 2])
        assert np.isfinite(m.ttft_mean)

    def test_unknown_source_rejected(self):
        with pytest.raises(ValueError):
            Simulation(_cfg("netkv-full", telemetry_source="sflow"))
