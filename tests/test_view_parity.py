"""Scorer parity: vectorised ClusterView ladder vs the retired Python loop.

For every ladder policy the vectorised path must pick the same instance with
the same ``Decision`` cost/tier/s_eff/est_transfer_time as the per-candidate
reference loop (``repro.core.reference``), including deterministic
tie-breaking under fixed seeds, rejection behaviour, and the all-infeasible
-> ``None`` case.  The Pallas ``netkv_score`` backend (f32, interpret mode
on CPU) is parity-checked on the winner with a cost tolerance.
"""

import numpy as np
import pytest

from repro.core import (
    CandidateState,
    ClusterView,
    H100_TP4_ITER,
    RequestInfo,
    SelfContentionTracker,
    make_reference_scheduler,
    make_scheduler,
)
from repro.core.cost import IterTimeModel
from repro.core.oracle import OracleView, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY

LADDER = ["rr", "la", "ca", "cla", "netkv-topo", "netkv-static", "netkv-full",
          "netkv-pred"]
REQ = RequestInfo(0, 8192, 8192 * 320 * 1024)
# A piecewise iter model exercises the v_iter_time segments too.
PIECEWISE_ITER = IterTimeModel(a=0.0124, b=1.6e-5, breaks=(32.0,), slopes=(4e-5,))


def _pool(rng, n, all_infeasible=False):
    return [
        CandidateState(
            instance_id=i + 1,
            free_memory=1e5 if all_infeasible else float(rng.uniform(1e9, 4e11)),
            queued=int(rng.integers(0, 10)),
            batch_size=int(rng.integers(0, 64)),
            hit_tokens=float(rng.integers(0, REQ.input_len)),
            healthy=bool(rng.random() > 0.15),
            iter_scale=float(rng.uniform(1.0, 2.0)),
        )
        for i in range(n)
    ]


def _oracle(rng, n):
    tiers = rng.integers(0, 4, n + 1)
    return OracleView(
        tier_of=lambda p, d: int(tiers[d % len(tiers)]),
        tier_bandwidth=PAPER_TIER_BANDWIDTH,
        tier_latency=PAPER_TIER_LATENCY,
        congestion={t: float(rng.uniform(0, 0.8)) for t in range(4)},
    )


def _assert_same(d_new, d_ref):
    if d_ref is None:
        assert d_new is None
        return
    assert d_new is not None
    assert d_new.instance_id == d_ref.instance_id
    assert d_new.cost == d_ref.cost
    assert d_new.tier == d_ref.tier
    assert d_new.s_eff == d_ref.s_eff
    assert d_new.est_transfer_time == d_ref.est_transfer_time


class TestLadderParity:
    @pytest.mark.parametrize("name", LADDER)
    @pytest.mark.parametrize("seed", range(8))
    def test_seed_sweep_bit_identical(self, name, seed):
        """Sequential decisions (shared contention state) match bit-for-bit."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 40))
        cands = _pool(rng, n)
        view = _oracle(rng, n)
        s_new = make_scheduler(name, H100_TP4_ITER, 64, m_min=1e9, seed=seed)
        s_ref = make_reference_scheduler(name, H100_TP4_ITER, 64, m_min=1e9, seed=seed)
        infl_new, infl_ref = SelfContentionTracker(), SelfContentionTracker()
        for _ in range(4):
            _assert_same(
                s_new.select(REQ, 0, cands, view, infl_new),
                s_ref.select(REQ, 0, cands, view, infl_ref),
            )
        assert infl_new._counts == infl_ref._counts

    @pytest.mark.parametrize("name", LADDER)
    def test_piecewise_iter_model(self, name):
        rng = np.random.default_rng(99)
        cands = _pool(rng, 24)
        view = _oracle(rng, 24)
        s_new = make_scheduler(name, PIECEWISE_ITER, 64, m_min=1e9)
        s_ref = make_reference_scheduler(name, PIECEWISE_ITER, 64, m_min=1e9)
        _assert_same(s_new.select(REQ, 0, cands, view, None),
                     s_ref.select(REQ, 0, cands, view, None))

    @pytest.mark.parametrize("name", LADDER)
    def test_all_infeasible_rejects(self, name):
        rng = np.random.default_rng(3)
        cands = _pool(rng, 12, all_infeasible=True)
        view = _oracle(rng, 12)
        assert make_scheduler(name, H100_TP4_ITER, 64, m_min=1e9).select(
            REQ, 0, cands, view, None) is None
        assert make_reference_scheduler(name, H100_TP4_ITER, 64, m_min=1e9).select(
            REQ, 0, cands, view, None) is None

    @pytest.mark.parametrize("name", LADDER)
    def test_exact_tie_breaking_deterministic(self, name):
        """Identical candidates: ties resolved by the shared RNG stream —
        same seed picks the same winner as the reference, twice over."""
        view = _oracle(np.random.default_rng(0), 8)
        for seed in range(5):
            cands = [CandidateState(i + 1, 2e11, 0, 4, 0.0) for i in range(8)]
            picks = []
            for mk in (make_scheduler, make_reference_scheduler,
                       make_scheduler, make_reference_scheduler):
                s = mk(name, H100_TP4_ITER, 64, m_min=1e9, seed=seed)
                picks.append(s.select(REQ, 0, cands, view, None).instance_id)
            assert len(set(picks)) == 1

    def test_view_and_candidate_list_agree(self):
        """select() over a maintained ClusterView == select() over the
        equivalent CandidateState list."""
        rng = np.random.default_rng(11)
        cands = _pool(rng, 16)
        view = _oracle(rng, 16)
        cv = ClusterView.from_candidates(cands, tier_fn=view.tier_of)
        a = make_scheduler("netkv-full", H100_TP4_ITER, 64, m_min=1e9)
        b = make_scheduler("netkv-full", H100_TP4_ITER, 64, m_min=1e9)
        _assert_same(a.select(REQ, 0, cv, view, None),
                     b.select(REQ, 0, cands, view, None))


class TestPallasBackendParity:
    @pytest.mark.parametrize("seed", range(4))
    def test_winner_matches_numpy(self, seed):
        rng = np.random.default_rng(seed + 100)
        n = int(rng.integers(4, 48))
        cands = _pool(rng, n)
        view = _oracle(rng, n)
        d_np = make_scheduler("netkv-full", H100_TP4_ITER, 64, m_min=1e9).select(
            REQ, 0, cands, view, None)
        d_pl = make_scheduler("netkv-full", H100_TP4_ITER, 64, m_min=1e9,
                              backend="pallas").select(REQ, 0, cands, view, None)
        if d_np is None:
            assert d_pl is None
            return
        # f32 scoring: same winner (or an equal-cost winner within f32 eps).
        assert d_pl.instance_id == d_np.instance_id or \
            abs(d_pl.cost - d_np.cost) < 1e-5 * max(abs(d_np.cost), 1e-9)
        assert d_pl.tier == d_np.tier or d_pl.instance_id != d_np.instance_id
        assert d_pl.s_eff == d_np.s_eff or d_pl.instance_id != d_np.instance_id

    def test_all_infeasible_rejects(self):
        rng = np.random.default_rng(0)
        cands = _pool(rng, 8, all_infeasible=True)
        view = _oracle(rng, 8)
        s = make_scheduler("netkv-full", H100_TP4_ITER, 64, m_min=1e9,
                           backend="pallas")
        assert s.select(REQ, 0, cands, view, None) is None

    def test_piecewise_model_rejected_at_construction(self):
        with pytest.raises(ValueError):
            make_scheduler("netkv-full", PIECEWISE_ITER, 64, backend="pallas")


class TestClusterViewMaintenance:
    def test_slot_map_and_growth(self):
        cv = ClusterView(capacity=2)
        slots = [cv.add_instance(10 * i, free_memory=float(i)) for i in range(9)]
        assert slots == list(range(9))
        assert cv.n == 9
        for i in range(9):
            assert cv.slot_of(10 * i) == i
            assert cv.free_memory[i] == float(i)
        with pytest.raises(ValueError):
            cv.add_instance(0)

    def test_tier_rows_cached_and_invalidated(self):
        calls = []

        def tier_fn(a, b):
            calls.append((a, b))
            return (a + b) % 4

        cv = ClusterView(tier_fn=tier_fn)
        cv.add_instance(1)
        cv.add_instance(2)
        row = cv.tier_row(0)
        assert list(row) == [1, 2]
        cv.tier_row(0)
        assert len(calls) == 2          # second lookup served from cache
        cv.add_instance(3)              # membership change invalidates rows
        assert list(cv.tier_row(0)) == [1, 2, 3]
