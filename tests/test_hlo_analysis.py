"""Loop-aware HLO parser validation against hand-built scans."""

import os
import subprocess
import sys

import pytest


@pytest.fixture(scope="module")
def hlo_with_scan():
    """Compile a scanned collective program on a 4-device host mesh in a
    subprocess (keeps this test process at 1 device)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np
mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("d",))
def step(x):
    def body(c, _):
        y = jax.lax.with_sharding_constraint(c @ c, P("d", None))
        return y, None
    out, _ = jax.lax.scan(body, x, None, length=7)
    return out
fn = jax.jit(step, in_shardings=NamedSharding(mesh, P("d", None)))
with mesh:
    print(fn.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text())
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env={**os.environ})
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_trip_count_extraction(hlo_with_scan):
    from repro.launch.hlo_analysis import computation_multipliers, split_computations

    comps, entry = split_computations(hlo_with_scan)
    assert entry is not None
    mult = computation_multipliers(hlo_with_scan)
    # some computation (the while body) must carry multiplier 7
    assert any(abs(m - 7.0) < 1e-9 for m in mult.values()), mult


def test_loop_aware_at_least_raw(hlo_with_scan):
    from repro.launch.hlo_analysis import collective_bytes_loop_aware

    out = collective_bytes_loop_aware(hlo_with_scan)
    assert out["total_bytes"] >= out["raw_total_bytes"]
    # if the scanned matmul produced an in-loop collective, the multiplier
    # must scale it ~7x
    if out["raw_total_bytes"] > 0:
        assert out["total_bytes"] >= 6 * out["raw_total_bytes"] or \
            out["total_bytes"] == out["raw_total_bytes"]  # collective hoisted


def test_no_loops_identity():
    from repro.launch.hlo_analysis import collective_bytes_loop_aware

    hlo = """HloModule m

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(f32[8]{0} %a), replica_groups={}
}
"""
    out = collective_bytes_loop_aware(hlo)
    assert out["total_bytes"] == out["raw_total_bytes"] == 32
