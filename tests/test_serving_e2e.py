"""End-to-end disaggregated serving: token exactness + transfer kernels +
checkpoint/restart of training."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.models import decode_step, forward_logits, init_params, prefill
from repro.serving import (
    DisaggregatedCluster,
    ServeRequest,
    merge_chunk_buffers,
    pack_transfer,
    pack_transfer_chunk,
    unpack_transfer,
)
from repro.train import (
    make_optimizer,
    make_train_step,
    restore_latest,
    save_checkpoint,
    synth_batch,
)


@pytest.fixture(scope="module")
def smoke_cfg():
    return dataclasses.replace(get_spec("qwen3-14b").smoke, compute_dtype=jnp.float32)


class TestTransferPath:
    def test_pack_unpack_cache_roundtrip(self, smoke_cfg):
        cfg = smoke_cfg
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, cfg.vocab_size)
        _, cache = prefill(cfg, params, toks, cache_len=64)
        buffers, nbytes = pack_transfer(cache, hit_pages=0)
        assert nbytes > 0
        rebuilt = unpack_transfer(buffers, cache)
        rebuilt["pos"] = cache["pos"]
        # decode from the rebuilt cache must equal decode from the original
        lg1, _ = decode_step(cfg, params, toks[:, -1:], dict(cache))
        lg2, _ = decode_step(cfg, params, toks[:, -1:], rebuilt)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-6)

    def test_prefix_hit_reduces_bytes(self, smoke_cfg):
        cfg = smoke_cfg
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, cfg.vocab_size)
        _, cache = prefill(cfg, params, toks, cache_len=64)
        _, full = pack_transfer(cache, hit_pages=0)
        _, hit2 = pack_transfer(cache, hit_pages=2)
        assert hit2 < full  # Eq. (2) materialised

    def test_chunked_pack_conserves_bytes_and_roundtrips(self, smoke_cfg):
        """The executable twin of kv_streaming: packing the cache chunk by
        chunk (fixed state riding with the final chunk) moves exactly the
        bytes of the one-shot pack, and the merged chunks rebuild a cache
        that decodes identically."""
        cfg = smoke_cfg
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 48), 0, cfg.vocab_size)
        _, cache = prefill(cfg, params, toks, cache_len=64)
        full_buffers, full_bytes = pack_transfer(cache, hit_pages=1)
        chunks, total = [], 0
        for start, end, final in ((0, 2, False), (2, 3, False), (3, None, True)):
            b, n = pack_transfer_chunk(cache, hit_pages=1, start_page=start,
                                       end_page=end, final=final)
            chunks.append(b)
            total += n
        assert total == full_bytes  # byte conservation on the real path
        merged = merge_chunk_buffers(chunks)
        for name, (buf, table) in full_buffers.items():
            mbuf, mtable = merged[name]
            # Same page set (chunk tables are page-major, the one-shot pack
            # period-major — unpack scatters by table, so order is free).
            assert sorted(table) == sorted(mtable)
            assert np.asarray(buf).shape == np.asarray(mbuf).shape
        rebuilt = unpack_transfer(merged, cache)
        rebuilt["pos"] = cache["pos"]
        want = unpack_transfer(full_buffers, cache)
        want["pos"] = cache["pos"]
        lg1, _ = decode_step(cfg, params, toks[:, -1:], want)
        lg2, _ = decode_step(cfg, params, toks[:, -1:], rebuilt)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-6)


class TestEndToEndServing:
    def test_token_exact_vs_monolithic(self, smoke_cfg):
        cfg = smoke_cfg
        cluster = DisaggregatedCluster(cfg, scheduler="netkv-full", cache_len=64)
        rng = np.random.default_rng(0)
        reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size, size=20),
                             max_new=6, arrival=i * 0.01) for i in range(4)]
        res = cluster.serve(reqs)
        params = init_params(cfg, jax.random.PRNGKey(0))
        for r, req in zip(res, reqs):
            toks = list(req.prompt)
            for _ in range(req.max_new):
                lg, _ = forward_logits(cfg, params, jnp.asarray(toks, jnp.int32)[None])
                toks.append(int(jnp.argmax(lg[0, -1])))
            assert r.tokens[:req.max_new] == toks[len(req.prompt):], r.request_id

    def test_prefix_sharing_cuts_transfer(self, smoke_cfg):
        cfg = smoke_cfg
        cluster = DisaggregatedCluster(cfg, scheduler="netkv-full", cache_len=64)
        rng = np.random.default_rng(1)
        shared = rng.integers(0, cfg.vocab_size, size=48)
        reqs = [ServeRequest(i, shared.copy(), max_new=2, arrival=i * 0.5)
                for i in range(3)]
        res = cluster.serve(reqs)
        by_inst = {}
        for r in res:
            by_inst.setdefault(r.decode_instance, []).append(r)
        for rs in by_inst.values():
            if len(rs) > 1:
                assert rs[1].transfer_bytes < rs[0].transfer_bytes
                return
        pytest.skip("scheduler spread all requests (no repeat instance)")

    def test_scheduler_ladder_runs_e2e(self, smoke_cfg):
        cfg = smoke_cfg
        rng = np.random.default_rng(2)
        for sched in ["rr", "cla", "netkv-static", "netkv-full"]:
            cluster = DisaggregatedCluster(cfg, scheduler=sched, cache_len=64)
            reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size, size=16),
                                 max_new=3) for i in range(3)]
            res = cluster.serve(reqs)
            assert all(len(r.tokens) >= 3 for r in res), sched


class TestCheckpointRestart:
    def test_restart_is_bitwise_reproducible(self, tmp_path, smoke_cfg):
        """Preemption drill: train 6 steps; kill; resume from step 3; the
        final params must equal an uninterrupted run (seeded data pipeline)."""
        cfg = smoke_cfg
        opt = make_optimizer("adamw", lr=1e-3)
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, opt, microbatches=1, batch_shards=1))

        def run(params, state, start, end, ckpt_at=None):
            for i in range(start, end):
                batch = synth_batch(cfg, global_batch=4, seq_len=32, seed=11, step=i)
                params, state, _ = step_fn(params, state, batch)
                if ckpt_at is not None and i == ckpt_at:
                    save_checkpoint(str(tmp_path), i + 1, {"p": params, "o": state})
            return params, state

        # uninterrupted
        p_full, _ = run(params, state, 0, 6)
        # interrupted at step 3 + restart
        p_half, s_half = run(params, state, 0, 3, ckpt_at=2)
        restored = restore_latest(str(tmp_path), {"p": params, "o": state})
        assert restored is not None
        step0, tree = restored
        assert step0 == 3
        p_res, _ = run(tree["p"], tree["o"], step0, 6)
        for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial_checkpoints(self, tmp_path, smoke_cfg):
        from repro.train.checkpoint import list_checkpoints

        params = init_params(smoke_cfg, jax.random.PRNGKey(0))
        save_checkpoint(str(tmp_path), 1, {"p": params})
        save_checkpoint(str(tmp_path), 2, {"p": params})
        # a stale tmp dir must never be listed
        os.makedirs(os.path.join(str(tmp_path), ".tmp_dead"), exist_ok=True)
        assert list_checkpoints(str(tmp_path)) == [1, 2]

    def test_retention(self, tmp_path, smoke_cfg):
        from repro.train.checkpoint import list_checkpoints

        params = init_params(smoke_cfg, jax.random.PRNGKey(0))
        for i in range(1, 6):
            save_checkpoint(str(tmp_path), i, {"p": params})
        assert list_checkpoints(str(tmp_path)) == [3, 4, 5]
