"""Event-engine behaviour: the reference EventLoop and the typed-lane
EventPlane — shared API semantics, heap-compaction hygiene, and the
property test pinning identical pop order across the two engines."""

import itertools

import pytest

from hypothesis_compat import given, settings, st
from repro.sim.engine import (
    LANE_ARRIVAL,
    LANE_CLOCK,
    LANE_NET,
    LANE_PREFILL,
    EventLoop,
    EventPlane,
    make_event_loop,
)

ENGINES = [EventLoop, EventPlane]


def _noop(now):
    pass


class TestEmptyCounter:
    def test_empty_initially_and_after_run(self):
        loop = EventLoop()
        assert loop.empty()
        loop.at(1.0, _noop)
        loop.at(2.0, _noop)
        assert not loop.empty()
        loop.run()
        assert loop.empty()

    def test_cancel_decrements_once(self):
        loop = EventLoop()
        ev = loop.at(1.0, _noop)
        loop.cancel(ev)
        assert loop.empty()
        loop.cancel(ev)          # double-cancel must not go negative
        assert loop._live == 0
        loop.at(1.0, _noop)
        assert not loop.empty()  # a later event is still visible

    def test_putback_event_stays_live(self):
        """run(until=...) re-pushes the future event: still pending."""
        loop = EventLoop()
        loop.at(5.0, _noop)
        loop.run(until=1.0)
        assert not loop.empty()
        loop.run(until=10.0)
        assert loop.empty()

    def test_counter_matches_heap_scan(self):
        """The counter equals the old O(n) definition under churn."""
        loop = EventLoop()
        evs = [loop.at(float(i), _noop) for i in range(20)]
        for ev in evs[::3]:
            loop.cancel(ev)
        scan = sum(1 for e in loop._heap if not e.cancelled)
        assert loop._live == scan
        loop.run(until=7.5)
        scan = sum(1 for e in loop._heap if not e.cancelled)
        assert loop._live == scan

    def test_cancel_after_execution_is_noop(self):
        """A stale reference cancelled after its event fired must not
        corrupt the live counter (empty() would report true with work
        still pending, silently stopping the simulator's net ticks)."""
        loop = EventLoop()
        ev = loop.at(1.0, _noop)
        loop.run(until=2.0)
        loop.at(5.0, _noop)       # one genuinely pending event
        loop.cancel(ev)           # stale: ev already executed
        assert loop._live == 1
        assert not loop.empty()

    def test_callbacks_scheduling_more_events(self):
        loop = EventLoop()
        fired = []

        def chain(now):
            fired.append(now)
            if len(fired) < 3:
                loop.after(1.0, chain)

        loop.after(1.0, chain)
        loop.run()
        assert len(fired) == 3
        assert loop.empty()


class TestNextTime:
    def test_peek_earliest_live_event(self):
        loop = EventLoop()
        assert loop.next_time() is None
        a = loop.at(3.0, _noop)
        loop.at(5.0, _noop)
        assert loop.next_time() == 3.0
        loop.cancel(a)
        assert loop.next_time() == 5.0   # cancelled head lazily skipped
        loop.run()
        assert loop.next_time() is None

    def test_peek_does_not_consume(self):
        loop = EventLoop()
        loop.at(1.0, _noop)
        assert loop.next_time() == 1.0
        assert loop.next_time() == 1.0
        loop.run(until=0.5)
        assert loop.next_time() == 1.0


@pytest.mark.parametrize("cls", ENGINES)
class TestSharedLaneAPI:
    """Both engines expose one lane API with identical observable behaviour."""

    def test_make_event_loop(self, cls):
        kind = "reference" if cls is EventLoop else "plane"
        assert type(make_event_loop(kind)) is cls
        with pytest.raises(ValueError):
            make_event_loop("nope")

    def test_generic_dispatch_order_and_until(self, cls):
        loop = cls()
        fired = []
        loop.at(2.0, lambda t: fired.append(("b", t)))
        loop.at(1.0, lambda t: fired.append(("a", t)))
        loop.at(2.0, lambda t: fired.append(("c", t)))  # same-time: seq order
        loop.run(until=1.5)
        assert fired == [("a", 1.0)] and loop.now == 1.5 and not loop.empty()
        loop.run()
        assert fired == [("a", 1.0), ("b", 2.0), ("c", 2.0)] and loop.empty()

    def test_cursor_fires_in_time_then_load_order(self, cls):
        loop = cls()
        fired = []
        loop.load_cursor(LANE_ARRIVAL, [1.0, 0.5, 1.0], ["a", "b", "c"],
                         lambda p, t: fired.append((p, t)))
        assert not loop.empty()
        assert loop.next_time() == 0.5
        loop.run()
        assert fired == [("b", 0.5), ("a", 1.0), ("c", 1.0)]
        assert loop.empty()

    def test_cursor_interleaves_with_generic_events(self, cls):
        loop = cls()
        fired = []
        loop.at(0.75, lambda t: fired.append(("g", t)))
        loop.load_cursor(LANE_ARRIVAL, [0.5, 1.0], ["a", "b"],
                         lambda p, t: fired.append((p, t)))
        loop.run()
        assert fired == [("a", 0.5), ("g", 0.75), ("b", 1.0)]

    def test_second_cursor_load_merges_pending(self, cls):
        loop = cls()
        fired = []
        h = lambda p, t: fired.append(p)
        loop.load_cursor(LANE_ARRIVAL, [1.0, 3.0], ["a", "b"], h)
        loop.run(until=1.5)
        loop.load_cursor(LANE_ARRIVAL, [2.0], ["c"], h)
        loop.run()
        assert fired == ["a", "c", "b"]

    def test_arm_single_slot_replaces(self, cls):
        loop = cls()
        fired = []
        loop.arm(LANE_NET, 2.0, lambda t: fired.append(("x", t)))
        loop.arm(LANE_NET, 1.0, lambda t: fired.append(("y", t)))  # replaces
        loop.run()
        assert fired == [("y", 1.0)]

    def test_arm_dedupe_keeps_original(self, cls):
        loop = cls()
        fired = []
        loop.arm(LANE_NET, 1.0, lambda t: fired.append("x"), dedupe=True)
        loop.arm(LANE_NET, 1.0, lambda t: fired.append("y"), dedupe=True)
        loop.run()
        assert fired == ["x"]        # unchanged deadline: no replacement

    def test_arm_after_fire_rearms(self, cls):
        loop = cls()
        fired = []

        def fn(t):
            fired.append(t)
            if len(fired) < 3:
                loop.arm(LANE_NET, t + 1.0, fn, dedupe=True)

        loop.arm(LANE_NET, 1.0, fn, dedupe=True)
        loop.run()
        assert fired == [1.0, 2.0, 3.0] and loop.empty()

    def test_disarm(self, cls):
        loop = cls()
        loop.arm(LANE_TICK_ := LANE_NET, 1.0, _noop)
        assert not loop.empty()
        loop.disarm(LANE_TICK_)
        assert loop.empty()
        loop.disarm(LANE_TICK_)      # idempotent
        assert loop.empty()
        loop.run()
        assert loop.now == 0.0

    def test_arm_slot_per_index_timers(self, cls):
        loop = cls()
        fired = []
        loop.arm_slot(LANE_PREFILL, 3, 2.0, lambda i, t: fired.append((i, t)))
        loop.arm_slot(LANE_PREFILL, 1, 1.0, lambda i, t: fired.append((i, t)))
        loop.arm_slot(LANE_PREFILL, 2, 1.0, lambda i, t: fired.append((i, t)))
        loop.run()
        assert fired == [(1, 1.0), (2, 1.0), (3, 2.0)]

    def test_backwards_rounding_clamps_to_now(self, cls):
        loop = cls()
        fired = []
        loop.at(1.0, lambda t: loop.at(t - 1e-13, lambda u: fired.append(u)))
        loop.at(1.0, lambda t: loop.at(t - 5.0, lambda u: fired.append(u)))
        loop.run()
        assert fired == [1.0, 1.0] and loop.now == 1.0

    def test_trace_log_records_lanes(self, cls):
        loop = cls()
        loop.trace_log = []
        loop.at(1.0, _noop)
        loop.load_cursor(LANE_ARRIVAL, [0.5], ["a"], lambda p, t: None)
        loop.arm(LANE_NET, 2.0, _noop)
        loop.run()
        assert loop.trace_log == [(0.5, LANE_ARRIVAL), (1.0, 0), (2.0, LANE_NET)]


class TestHeapCompaction:
    """Satellite bugfix: cancelled corpses must not balloon the heap."""

    @pytest.mark.parametrize("cls", ENGINES)
    def test_cancel_heavy_rearm_drive_keeps_heap_bounded(self, cls):
        # The fault/rewire pattern: every network event replaces the pending
        # completion timer via cancel + at.  Before compaction the heap held
        # every corpse until its pop time came around (10k entries here).
        loop = cls()
        heap = lambda: loop._heap if cls is EventLoop else loop._gen
        ev = None
        for i in range(10_000):
            if ev is not None:
                loop.cancel(ev)
            ev = loop.at(1e6 + i, _noop)
        assert loop._live == 1
        assert len(heap()) <= 66   # live + a sub-threshold corpse tail
        loop.run()
        assert loop.empty()

    def test_compaction_preserves_pop_order(self):
        loop = EventLoop()
        fired = []
        evs = [loop.at(float(i), lambda t, i=i: fired.append(i))
               for i in range(300)]
        for i, ev in enumerate(evs):
            if i % 3:
                loop.cancel(ev)  # 2/3 cancelled: corpses outnumber live
        assert len(loop._heap) <= 2 * loop._live
        loop.run()
        assert fired == list(range(0, 300, 3))


class TestEventPlaneHorizon:
    """The batching hooks a cohort handler drives (InstancePlane._step)."""

    def test_lane_horizon_scans_other_lanes_and_until(self):
        loop = EventPlane()
        assert loop.lane_horizon(LANE_CLOCK) == float("inf")
        loop.arm(LANE_NET, 4.0, _noop)
        loop.load_cursor(LANE_ARRIVAL, [3.0], ["a"], lambda p, t: None)
        loop.arm(LANE_CLOCK, 1.0, _noop)
        assert loop.lane_horizon(LANE_CLOCK) == 3.0   # own lane excluded
        loop.at(2.5, _noop)
        assert loop.lane_horizon(LANE_CLOCK) == 2.5

    def test_lane_tick_advances_now_and_processed(self):
        loop = EventPlane()
        loop.lane_tick(LANE_CLOCK, 1.5)
        loop.lane_ticks(LANE_CLOCK, 7)
        assert loop.now == 1.5 and loop.processed == 8

    def test_batched_log_entries_merge_and_sort(self):
        # A horizon-batched handler reports in-window work out of time
        # order (fused per-instance runs); the flush must restore global
        # order and merge same-time entries into one pop, matching the
        # reference engine's one-heap-event-per-cohort log.
        loop = EventPlane()
        loop.trace_log = []

        def handler(t):
            loop.lane_ticks(LANE_CLOCK, 3, times=[1.4, 1.8, 1.6])
            loop.lane_tick(LANE_CLOCK, 1.6)

        loop.arm(LANE_CLOCK, 1.2, handler)
        loop.at(2.0, _noop)
        loop.run()
        assert loop.trace_log == [
            (1.2, LANE_CLOCK), (1.4, LANE_CLOCK), (1.6, LANE_CLOCK),
            (1.8, LANE_CLOCK), (2.0, 0),
        ]


# ---------------------------------------------------------------- property
# Random API scripts: same-timestamp cohorts (grid times with duplicates),
# cancellations (incl. of already-fired events), slot re-arms and
# backwards-rounding at() clamps must dispatch in the identical order on
# both engines.
_GRID = [0.0, 0.5, 1.0, 1.0, 1.5, 2.0, 2.0, 2.0, 3.0]

_op = st.one_of(
    st.tuples(st.just("at"), st.sampled_from(_GRID)),
    st.tuples(st.just("cancel"), st.integers(min_value=0, max_value=40)),
    st.tuples(st.just("arm"), st.sampled_from(_GRID), st.booleans()),
    st.tuples(st.just("slot"), st.integers(min_value=0, max_value=3),
              st.sampled_from(_GRID)),
    st.tuples(st.just("cursor"),
              st.lists(st.sampled_from(_GRID), max_size=5)),
)


def _run_script(cls, ops):
    loop = cls()
    fired = []
    events = []
    counter = itertools.count()

    def mk(tag):
        def fn(now):
            fired.append((now, tag))
            k = next(counter)
            if k % 3 == 0:
                # rounds slightly backwards: must clamp to now, not jump
                # the queue
                loop.at(now - 1e-13, mk(f"{tag}/clamp"))
            if k % 5 == 0:
                loop.arm(LANE_NET, now + 0.25, mk(f"{tag}/net"), dedupe=True)
        return fn

    ncur = 0
    for i, op in enumerate(ops):
        kind = op[0]
        if kind == "at":
            events.append(loop.at(op[1], mk(f"at{i}")))
        elif kind == "cancel":
            if events:
                loop.cancel(events[op[1] % len(events)])
        elif kind == "arm":
            loop.arm(LANE_NET, op[1], mk(f"arm{i}"), dedupe=op[2])
        elif kind == "slot":
            loop.arm_slot(LANE_PREFILL, op[1], op[2],
                          lambda idx, now, i=i: fired.append((now, f"s{i}-{idx}")))
        elif kind == "cursor":
            tags = [f"c{ncur + j}" for j in range(len(op[1]))]
            ncur += len(op[1])
            loop.load_cursor(LANE_ARRIVAL, op[1], tags,
                             lambda p, now: fired.append((now, p)))
    loop.run(max_events=100_000)
    return fired


@settings(max_examples=80, deadline=None)
@given(st.lists(_op, max_size=40))
def test_eventplane_matches_eventloop_pop_order(ops):
    assert _run_script(EventPlane, ops) == _run_script(EventLoop, ops)
