"""EventLoop behaviour: O(1) live-event accounting for empty()."""

from repro.sim.engine import EventLoop


def _noop(now):
    pass


class TestEmptyCounter:
    def test_empty_initially_and_after_run(self):
        loop = EventLoop()
        assert loop.empty()
        loop.at(1.0, _noop)
        loop.at(2.0, _noop)
        assert not loop.empty()
        loop.run()
        assert loop.empty()

    def test_cancel_decrements_once(self):
        loop = EventLoop()
        ev = loop.at(1.0, _noop)
        loop.cancel(ev)
        assert loop.empty()
        loop.cancel(ev)          # double-cancel must not go negative
        assert loop._live == 0
        loop.at(1.0, _noop)
        assert not loop.empty()  # a later event is still visible

    def test_putback_event_stays_live(self):
        """run(until=...) re-pushes the future event: still pending."""
        loop = EventLoop()
        loop.at(5.0, _noop)
        loop.run(until=1.0)
        assert not loop.empty()
        loop.run(until=10.0)
        assert loop.empty()

    def test_counter_matches_heap_scan(self):
        """The counter equals the old O(n) definition under churn."""
        loop = EventLoop()
        evs = [loop.at(float(i), _noop) for i in range(20)]
        for ev in evs[::3]:
            loop.cancel(ev)
        scan = sum(1 for e in loop._heap if not e.cancelled)
        assert loop._live == scan
        loop.run(until=7.5)
        scan = sum(1 for e in loop._heap if not e.cancelled)
        assert loop._live == scan

    def test_cancel_after_execution_is_noop(self):
        """A stale reference cancelled after its event fired must not
        corrupt the live counter (empty() would report true with work
        still pending, silently stopping the simulator's net ticks)."""
        loop = EventLoop()
        ev = loop.at(1.0, _noop)
        loop.run(until=2.0)
        loop.at(5.0, _noop)       # one genuinely pending event
        loop.cancel(ev)           # stale: ev already executed
        assert loop._live == 1
        assert not loop.empty()

    def test_callbacks_scheduling_more_events(self):
        loop = EventLoop()
        fired = []

        def chain(now):
            fired.append(now)
            if len(fired) < 3:
                loop.after(1.0, chain)

        loop.after(1.0, chain)
        loop.run()
        assert len(fired) == 3
        assert loop.empty()


class TestNextTime:
    def test_peek_earliest_live_event(self):
        loop = EventLoop()
        assert loop.next_time() is None
        a = loop.at(3.0, _noop)
        loop.at(5.0, _noop)
        assert loop.next_time() == 3.0
        loop.cancel(a)
        assert loop.next_time() == 5.0   # cancelled head lazily skipped
        loop.run()
        assert loop.next_time() is None

    def test_peek_does_not_consume(self):
        loop = EventLoop()
        loop.at(1.0, _noop)
        assert loop.next_time() == 1.0
        assert loop.next_time() == 1.0
        loop.run(until=0.5)
        assert loop.next_time() == 1.0
