"""Flow-level network model validation (§VI-B's three analytical checks)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.cluster import BackgroundTraffic, FatTree, FlowNetwork, make_instances


def _drain(net, until=1e9):
    now = 0.0
    while True:
        nxt = net.next_completion_time(now)
        if nxt is None or nxt > until:
            return now
        now = nxt
        net.advance(now)


def _mono_tree():
    # deterministic single-uplink fabric: no ECMP randomness
    return FatTree(n_tor_uplinks=1, n_agg_uplinks=1)


class TestAnalyticalValidation:
    def test_single_transfer_matches_tier_bandwidth(self):
        """One 4-flow transfer on an idle fabric attains B_tau within 0.1%."""
        for (src, dst, bw) in [
            ((0, 0, 0), (0, 0, 1), 100e9 / 8),   # tier 1
            ((0, 0, 0), (0, 1, 0), 50e9 / 8),    # tier 2
            ((0, 0, 0), (1, 0, 0), 25e9 / 8),    # tier 3
        ]:
            net = FlowNetwork(_mono_tree(), BackgroundTraffic(0.0), seed=0)
            done = []
            net.start_transfer(src, dst, 1e9, 0.0, lambda t, n: done.append(n))
            _drain(net)
            assert done, (src, dst)
            assert abs(done[0] - 1e9 / bw) / (1e9 / bw) < 1e-3

    def test_n_flows_each_get_capacity_over_n(self):
        """N coexisting transfers on one bottleneck each get 1/N."""
        net = FlowNetwork(_mono_tree(), BackgroundTraffic(0.0), seed=0)
        n = 4
        for i in range(n):
            net.start_transfer((0, 0, i % 2), (1, i % 2, i % 2), 1e9, 0.0,
                               lambda t, now: None, n_flows=1)
        rates = [f.rate for f in net.flows.values()]
        agg_cap = 25e9 / 8  # tier-3 agg uplink is the shared bottleneck
        assert all(abs(r - agg_cap / n) / (agg_cap / n) < 1e-6 for r in rates)

    def test_fair_share_reconverges_after_completion(self):
        """Rates re-fill within one event of a flow finishing."""
        net = FlowNetwork(_mono_tree(), BackgroundTraffic(0.0), seed=0)
        net.start_transfer((0, 0, 0), (1, 0, 0), 1e8, 0.0, lambda t, n: None, n_flows=1)
        net.start_transfer((0, 0, 1), (1, 0, 1), 1e9, 0.0, lambda t, n: None, n_flows=1)
        first = net.next_completion_time(0.0)
        net.advance(first)
        # survivor takes the whole agg uplink
        (f,) = net.flows.values()
        assert abs(f.rate - 25e9 / 8) / (25e9 / 8) < 1e-6

    def test_background_scales_residual(self):
        net = FlowNetwork(_mono_tree(), BackgroundTraffic(0.4, wander=0.0), seed=0)
        net.start_transfer((0, 0, 0), (1, 0, 0), 1e9, 0.0, lambda t, n: None)
        agg = sum(f.rate for f in net.flows.values())
        assert abs(agg - 25e9 / 8 * 0.6) / (25e9 / 8 * 0.6) < 1e-6


class TestECMP:
    def test_collisions_happen_below_capacity(self):
        """Per §VI-B: correlated transfers can collide even below capacity."""
        tree = FatTree(n_tor_uplinks=2, n_agg_uplinks=2)
        saw_collision = saw_clean = False
        for seed in range(40):
            net = FlowNetwork(tree, BackgroundTraffic(0.0), seed=seed)
            net.start_transfer((0, 0, 0), (1, 0, 0), 1e9, 0.0, lambda t, n: None)
            net.start_transfer((0, 0, 1), (1, 0, 1), 1e9, 0.0, lambda t, n: None)
            rates = sorted(round(f.rate) for f in net.flows.values())
            total = sum(rates)
            if total < 2 * 25e9 / 8 * 0.99:
                saw_collision = True
            else:
                saw_clean = True
        assert saw_collision and saw_clean


class TestAbort:
    def test_abort_releases_capacity(self):
        net = FlowNetwork(_mono_tree(), BackgroundTraffic(0.0), seed=0)
        t1 = net.start_transfer((0, 0, 0), (1, 0, 0), 1e9, 0.0, lambda t, n: None)
        t2 = net.start_transfer((0, 0, 1), (1, 0, 1), 1e9, 0.0, lambda t, n: None)
        net.abort_transfer(t1, 0.001)
        (f,) = [f for f in net.flows.values()][:1]
        assert abs(sum(f.rate for f in net.flows.values()) - 25e9 / 8) < 1


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_work_conservation(data):
    """Property: total delivered bytes == sum of transfer sizes (no loss/dup)."""
    tree = FatTree()
    net = FlowNetwork(tree, BackgroundTraffic(0.0), seed=data.draw(st.integers(0, 999)))
    total = 0.0
    servers = [(p, r, s) for p in range(2) for r in range(2) for s in range(2)]
    for i in range(data.draw(st.integers(1, 6))):
        src = servers[data.draw(st.integers(0, 7))]
        dst = servers[data.draw(st.integers(0, 7))]
        if src == dst:
            continue
        b = data.draw(st.floats(1e6, 1e9))
        total += b
        net.start_transfer(src, dst, b, 0.0, lambda t, n: None)
    _drain(net)
    assert abs(net.bytes_delivered - total) < max(1e-6 * total, 64.0)


def _random_plane(seed, n_transfers, bg=0.0):
    tree = FatTree()
    net = FlowNetwork(tree, BackgroundTraffic(bg), seed=seed)
    wl = np.random.default_rng(seed)
    servers = [(p, r, s) for p in range(2) for r in range(2) for s in range(2)]
    for _ in range(n_transfers):
        i, j = wl.choice(8, 2, replace=False)
        net.start_transfer(servers[i], servers[j], float(wl.uniform(1e6, 1e9)),
                           0.0, lambda t, n: None)
    return net


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_tier_bytes_sum_to_bytes_delivered(data):
    """Property: per-tier byte counters partition the delivered total."""
    net = _random_plane(data.draw(st.integers(0, 999)),
                        data.draw(st.integers(1, 8)),
                        bg=data.draw(st.floats(0.0, 0.5)))
    # Partially drain (a few completion epochs), then check mid-flight too.
    now = 0.0
    for _ in range(data.draw(st.integers(0, 4))):
        nxt = net.next_completion_time(now)
        if nxt is None:
            break
        now = nxt
        net.advance(now)
    tier_sum = sum(net.tier_utilization_observed(now).values())
    assert abs(tier_sum - net.bytes_delivered) <= max(1e-9 * net.bytes_delivered, 1.0)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_max_min_invariants(data):
    """Property: no link over residual capacity; every flow is bottlenecked
    on at least one saturated link of its path (max-min optimality)."""
    net = _random_plane(data.draw(st.integers(0, 999)),
                        data.draw(st.integers(1, 10)),
                        bg=data.draw(st.floats(0.0, 0.5)))
    load, resid = net.link_utilization()
    assert np.all(load <= resid * (1 + 1e-9) + 1e-6)
    for f in net.flows.values():
        assert f.rate > 0
        saturated = any(load[l] >= resid[l] * (1 - 1e-9) - 1e-6 for l in f.path)
        assert saturated, f"flow {f.flow_id} not bottlenecked on its path"


class TestTopology:
    def test_tiers(self):
        t = FatTree()
        assert t.tier((0, 0, 0), (0, 0, 0)) == 0
        assert t.tier((0, 0, 0), (0, 0, 1)) == 1
        assert t.tier((0, 0, 0), (0, 1, 0)) == 2
        assert t.tier((0, 0, 0), (1, 1, 1)) == 3

    def test_tier_vec_matches_scalar_tier(self):
        """Vectorised tau over flat server indices == the scalar tier()."""
        t = FatTree(n_pods=2, racks_per_pod=3, servers_per_rack=2)
        servers = [(p, r, s) for p in range(2) for r in range(3) for s in range(2)]
        idx = np.array([t.server_index(srv) for srv in servers])
        assert list(idx) == list(range(t.n_servers))
        mat = t.tier_vec(idx[:, None], idx[None, :])
        for i, a in enumerate(servers):
            for j, b in enumerate(servers):
                assert mat[i, j] == t.tier(a, b), (a, b)

    def test_path_row_matches_flow_path(self):
        """path_row consumes the same RNG draws and yields the same links."""
        t = FatTree()
        r1 = np.random.default_rng(7)
        r2 = np.random.default_rng(7)
        for src, dst in [((0, 0, 0), (0, 0, 0)), ((0, 0, 0), (0, 0, 1)),
                         ((0, 0, 0), (0, 1, 0)), ((0, 1, 1), (1, 0, 1))]:
            row, k = t.path_row(src, dst, r1)
            assert [int(x) for x in row[:k]] == t.flow_path(src, dst, r2)

    def test_pack_placement_never_colocates(self):
        """Table VI footnote: tier 0/1 unreached under pack placement."""
        tree = FatTree()
        pre, dec = make_instances(tree, tp=4, n_prefill=4, placement="pack")
        for p in pre:
            for d in dec:
                assert tree.tier(p.server, d.server) >= 2

    def test_spread_placement_reaches_low_tiers(self):
        tree = FatTree()
        pre, dec = make_instances(tree, tp=4, n_prefill=4, placement="spread")
        tiers = {tree.tier(p.server, d.server) for p in pre for d in dec}
        assert 0 in tiers or 1 in tiers


class TestArrivalEpochs:
    """begin_epoch/end_epoch: a burst of same-instant transfer arrivals
    admitted with one union dirty-component recompute must end up with
    bit-identical rates and completion behaviour to per-arrival recomputes
    (rates depend only on the final flow set; no time passes mid-burst)."""

    def _burst(self, epoch: bool, n=12, seed=3):
        rng = np.random.default_rng(seed)
        tree = FatTree()
        net = FlowNetwork(tree, BackgroundTraffic(0.2), seed=seed)
        servers = [(p, r, s) for p in range(2) for r in range(2) for s in range(2)]
        done = []
        if epoch:
            net.begin_epoch()
        for k in range(n):
            i, j = rng.choice(len(servers), 2, replace=False)
            net.start_transfer(servers[i], servers[j],
                               float(rng.uniform(1e7, 5e8)), 0.0,
                               lambda t, now: done.append((t.transfer_id, now)))
        if epoch:
            net.end_epoch()
        return net, done

    def test_epoch_rates_match_sequential(self):
        a, _ = self._burst(epoch=True)
        b, _ = self._burst(epoch=False)
        fa = {f: (v.rate, v.bytes_remaining, v.path) for f, v in a.flows.items()}
        fb = {f: (v.rate, v.bytes_remaining, v.path) for f, v in b.flows.items()}
        assert fa == fb

    def test_epoch_completions_match_sequential(self):
        a, da = self._burst(epoch=True)
        b, db = self._burst(epoch=False)
        now = 0.0
        for _ in range(10_000):
            na, nb = a.next_completion_time(now), b.next_completion_time(now)
            assert na == nb
            if na is None:
                break
            now = na
            a.advance(now)
            b.advance(now)
        assert da == db and len(da) == 12

    def test_nested_epoch_rejected(self):
        net = FlowNetwork(FatTree(), BackgroundTraffic(0.0), seed=0)
        net.begin_epoch()
        with pytest.raises(RuntimeError):
            net.begin_epoch()
        net.end_epoch()
        assert not net.in_epoch
