"""ChunkPlane: chunk-interleaved prefill + streamed KV transfer.

Covers the PR-5 tentpole and its satellites:

* plane vs reference bit-exact parity in chunked mode (streaming off/on,
  with faults and mid-stream OCS rewires),
* chunk-duration conservation (the per-request compute telescopes to the
  monolithic ``c*l + d``) and byte conservation of streamed transfers,
* the serial ETA-fold shortcut audited at the queue-drain boundary,
* open-flow-counter parity after fault-driven aborts (the least-loaded
  NIC policy's signal),
* NaN-safe metrics rows for degenerate measurement windows,
* the streamed-overlap transfer-time column vs its scalar oracle twin.
"""

import math

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.cost import (
    H100_TP4_ITER,
    H100_TP4_PREFILL,
    LLAMA3_70B_KV,
    PrefillTimeModel,
    streamed_transfer_time,
)
from repro.core.oracle import OracleView, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY
from repro.core.schedulers import v_transfer_time
from repro.core.view import ClusterView
from repro.sim import (
    EventLoop,
    FaultEvent,
    InstancePlane,
    ReferenceInstanceEngine,
    RequestState,
    RewireEvent,
    SimConfig,
    Simulation,
)
from repro.sim.metrics import summarize
from repro.traces import generate_trace, profile_capacity
from repro.traces.mooncake import Request

TREE_64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2, n_prefill=4)


def _trace(seed, duration=5.0):
    cap = profile_capacity("rag", n_prefill=4, n_decode=12)
    return generate_trace("rag", duration=duration, target_rps=cap, seed=seed)


def _run(engine, seed=0, duration=5.0, faults=(), rewires=(), **kw):
    kw.setdefault("background", 0.2)
    cfg = SimConfig(scheduler="netkv-full", seed=seed,
                    warmup=1.0, measure=3.0, instance_engine=engine,
                    faults=faults, rewires=rewires, **TREE_64, **kw)
    sim = Simulation(cfg)
    sim.run(_trace(seed, duration), drain=40.0)
    return sim


def _outcomes(sim):
    return [
        (r.req.request_id, r.prefill_instance, r.prefill_start, r.prefill_end,
         r.sched_time, r.decode_instance, r.tier, r.s_eff, r.hit_tokens,
         r.transfer_end, r.admit_time, r.first_token, r.finish, r.tbt,
         r.tokens_out, r.rejected, r.requeues, r.tokens_ready,
         r.streamed_bytes)
        for r in sim.records
    ]


class TestChunkedParity:
    """InstancePlane's ChunkPlane vs the scalar ChunkedPrefillSim oracle."""

    @pytest.mark.parametrize("chunk,budget", [(512, None), (768, 3072)])
    def test_chunked_bit_exact(self, chunk, budget):
        a = _run("plane", chunk_tokens=chunk, prefill_token_budget=budget)
        b = _run("reference", chunk_tokens=chunk, prefill_token_budget=budget)
        assert _outcomes(a) == _outcomes(b)
        assert a.engine.chunks.iterations > len(a.records)  # interleaved

    def test_streaming_bit_exact(self):
        a = _run("plane", chunk_tokens=512, kv_streaming=True)
        b = _run("reference", chunk_tokens=512, kv_streaming=True)
        assert _outcomes(a) == _outcomes(b)
        streamed = [r for r in a.records if r.streamed_bytes > 0]
        assert streamed  # the streaming path actually ran

    def test_streaming_with_faults_bit_exact(self):
        faults = (FaultEvent(time=1.6, kind="kill_decode", instance_id=5,
                             detection_delay=0.3),
                  FaultEvent(time=2.2, kind="slowdown", instance_id=7,
                             factor=3.0))
        a = _run("plane", seed=1, chunk_tokens=512, kv_streaming=True,
                 faults=faults)
        b = _run("reference", seed=1, chunk_tokens=512, kv_streaming=True,
                 faults=faults)
        assert _outcomes(a) == _outcomes(b)
        assert sum(r.requeues for r in a.records) > 0  # fault path exercised

    def test_serial_mode_untouched(self):
        """chunk_tokens=None reproduces the serial model bit-for-bit (the
        full 64/256-GPU suites live in test_instanceplane_parity.py)."""
        a = _run("plane", duration=3.0)
        b = _run("reference", duration=3.0)
        assert _outcomes(a) == _outcomes(b)
        assert a.engine.chunks is None


class TestStreamedBytes:
    """Byte conservation of the streamed transfer path."""

    def test_streamed_bytes_telescope_to_s_eff(self):
        sim = _run("plane", chunk_tokens=512, kv_streaming=True)
        done = [r for r in sim.records if r.stream_last]
        assert done
        for r in done:
            assert r.streamed_bytes == pytest.approx(r.s_eff, rel=1e-12)

    def test_conservation_across_midstream_rewires(self):
        """An OCS rewire mid-stream re-water-fills in-flight chunk flows;
        the per-request streamed byte total must still telescope to s_eff
        and both engines must agree bit-for-bit."""
        rewires = (RewireEvent(time=1.8, scale={2: 0.25, 3: 0.25}),
                   RewireEvent(time=2.8, scale={2: 4.0, 3: 4.0}))
        a = _run("plane", chunk_tokens=512, kv_streaming=True, rewires=rewires)
        b = _run("reference", chunk_tokens=512, kv_streaming=True,
                 rewires=rewires)
        assert _outcomes(a) == _outcomes(b)
        for r in a.records:
            if r.stream_last:
                assert r.streamed_bytes == pytest.approx(r.s_eff, rel=1e-12)

    def test_streaming_overlaps_and_cuts_ttft(self):
        """The whole point: transfer overlaps prefill, so mean TTFT drops
        vs the same chunked run without streaming."""
        base = _run("plane", chunk_tokens=1024, background=0.4)
        stream = _run("plane", chunk_tokens=1024, kv_streaming=True,
                      background=0.4)
        mb = summarize(base.records, window=(1.0, 4.0), scheduler="x")
        ms = summarize(stream.records, window=(1.0, 4.0), scheduler="x")
        assert ms.xfer_mean < mb.xfer_mean


class TestStreamingFaultEdges:
    """Regressions for the streamed-dispatch fault/rejection edges."""

    def test_requeue_cancels_stream_despite_stale_prefill_end(self):
        """A requeued request may carry a *stale* prefill_end from an
        earlier completed attempt while its current attempt is still
        mid-prefill; _requeue must cancel the live chunk stream anyway
        (and reset prefill_end), or the orphaned stream keeps firing
        chunk callbacks for a request being re-scheduled elsewhere."""
        cfg = SimConfig(scheduler="netkv-full", seed=0, warmup=1.0,
                        measure=3.0, chunk_tokens=512, kv_streaming=True,
                        **TREE_64)
        sim = Simulation(cfg)
        sim.load_trace([])
        rs = _req(0, 4096)
        sim.engine.pick_prefill(0.0).submit(rs, 0.0)
        assert int(sim.engine.chunks.backlog.sum()) > 0
        rs.prefill_end = 0.5          # stale value from a previous attempt
        sim._requeue(rs, 0.0)         # resubmits via _on_arrival
        # Old stream cancelled, exactly one fresh stream: the total chunk
        # backlog is one request's worth, not two.
        claimed = sum(
            take for infl in sim.engine.chunks.inflight if infl
            for st, take in infl if not st.cancelled
        )
        assert int(sim.engine.chunks.backlog.sum()) + claimed == 4096
        assert rs.prefill_end == -1.0

    def test_first_chunk_rejection_counted_once(self):
        """A request rejected at first-chunk scheduling must not be
        re-scheduled (or re-counted) when its prefill later completes."""
        cfg = SimConfig(scheduler="netkv-full", seed=0, warmup=1.0,
                        measure=3.0, background=0.2, chunk_tokens=512,
                        kv_streaming=True, m_min=1e18, **TREE_64)
        sim = Simulation(cfg)
        sim.run(_trace(0, duration=3.0), drain=30.0)
        n_arrived = sum(1 for r in sim.records if r.prefill_instance >= 0)
        assert n_arrived > 0
        assert all(r.rejected for r in sim.records)
        assert sim.rejected == len(sim.records)  # one count per request

    def test_streaming_refuses_batch_window(self):
        with pytest.raises(ValueError, match="netkv-batch"):
            Simulation(SimConfig(scheduler="netkv-batch", chunk_tokens=512,
                                 kv_streaming=True, **TREE_64))

    def test_kill_between_chunk_transfers_requeues_at_fault_time(self):
        """A streamed victim caught *between* chunk transfers (stream_open
        == 0, next chunk still prefilling) must be cancelled and requeued
        at fault time — not keep streaming KV to the dead instance until
        the last byte bounces."""
        # Fat pipes everywhere: each chunk's transfer drains well inside
        # the next chunk's prefill time, so stream_open dwells at 0.
        cfg = SimConfig(scheduler="netkv-full", seed=0, warmup=0.0,
                        measure=3.0, background=0.0, chunk_tokens=512,
                        kv_streaming=True,
                        tier_bandwidth={t: 1e12 for t in range(4)},
                        **TREE_64)
        sim = Simulation(cfg)
        req = Request(request_id=0, arrival=0.0, input_len=8192, output_len=4,
                      block_hashes=tuple((0, j) for j in range(8192 // 16)),
                      share_group=-1, slo=5.0)
        rs = RequestState(req=req, kv_bytes=float(cfg.kv_spec.kv_bytes(8192)))
        sim.records.append(rs)
        sim.loop.at(0.0, lambda now: sim._on_arrival(rs, now))
        # Run until the first chunk committed a decode target.
        while not rs.stream_scheduled and sim.loop.next_time() is not None:
            sim.loop.run(until=sim.loop.next_time())
        assert rs.stream_scheduled and rs.prefill_end < 0
        victim = rs.decode_instance
        # Step to an instant with no chunk transfer in flight (tier
        # transfers drain far faster than the next 512-token chunk
        # prefills), then kill the chosen decode instance.
        while rs.stream_open > 0:
            sim.loop.run(until=sim.loop.next_time())
        assert rs.stream_open == 0 and rs.prefill_end < 0
        t_fault = sim.loop.now + 1e-4
        sim.loop.at(t_fault, lambda now: sim._on_fault(
            FaultEvent(time=now, kind="kill_decode", instance_id=victim), now))
        sim.loop.run(until=t_fault)
        assert rs.requeues == 1          # requeued AT the fault instant
        assert not rs.stream_scheduled   # streaming state reset
        sim.loop.run()
        assert rs.finish >= 0 and rs.decode_instance != victim


class _Meta:
    def __init__(self, iid, srv):
        self.instance_id, self.server = iid, srv


def _mk_engines(chunk, budget, n_pre=2, model=H100_TP4_PREFILL):
    out = []
    for cls in (InstancePlane, ReferenceInstanceEngine):
        loop = EventLoop()
        view = ClusterView(capacity=1)
        pre = [_Meta(i, (0, 0, i)) for i in range(n_pre)]
        eng = cls(pre, [], view=view, loop=loop, iter_model=H100_TP4_ITER,
                  prefill_model=model, beta_max=64, kv_spec=LLAMA3_70B_KV,
                  kv_budget=1e18, chunk_tokens=chunk,
                  prefill_token_budget=budget)
        out.append((loop, eng))
    return out


def _req(rid, l):
    return RequestState(
        req=Request(request_id=rid, arrival=0.0, input_len=l, output_len=4,
                    block_hashes=((rid, 0),), share_group=-1, slo=5.0),
        kv_bytes=1.0,
    )


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_chunk_duration_conservation(data):
    """Per-request prefill compute telescopes to the monolithic c*l + d,
    and the instance makespan to c*suml + d*n (the fixed overhead rides
    with each request's first chunk)."""
    chunk = data.draw(st.integers(16, 2048), label="chunk")
    budget = data.draw(st.one_of(st.none(), st.integers(16, 8192)),
                       label="budget")
    lens = data.draw(st.lists(st.integers(1, 6000), min_size=1, max_size=6),
                     label="lens")
    model = H100_TP4_PREFILL
    (loop, eng), _ = _mk_engines(chunk, budget, n_pre=1)
    rss = [_req(i, l) for i, l in enumerate(lens)]
    got = []
    eng.on_prefill_done = lambda rs, now: got.append(rs)
    for rs in rss:
        eng.prefill[0].submit(rs, 0.0)
    loop.run()
    assert len(got) == len(rss)
    solo = len(rss) == 1
    for rs, l in zip(rss, lens):
        assert rs.prefill_end >= rs.prefill_start
        if solo:  # alone on the instance: end - start is exactly T_prefill(l)
            assert rs.prefill_end - rs.prefill_start == pytest.approx(
                model.c * l + model.d, rel=1e-9)
    makespan = max(rs.prefill_end for rs in rss)
    assert makespan == pytest.approx(
        model.c * sum(lens) + model.d * len(lens), rel=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_chunk_engine_event_parity(data):
    """Random chunk/budget/length mixes: the plane and the scalar oracle
    emit identical (request, tokens_ready, time) chunk-completion streams
    and identical prefill_start/end fields — bit-for-bit."""
    chunk = data.draw(st.integers(16, 1024), label="chunk")
    budget = data.draw(st.one_of(st.none(), st.integers(16, 4096)),
                       label="budget")
    lens = data.draw(st.lists(st.integers(1, 4000), min_size=1, max_size=8),
                     label="lens")
    n_pre = data.draw(st.integers(1, 3), label="n_pre")
    seqs = []
    for loop, eng in _mk_engines(chunk, budget, n_pre=n_pre):
        events = []
        eng.on_chunk_done = lambda rs, tok, now: events.append(
            ("chunk", rs.req.request_id, tok, now))
        eng.on_prefill_done = lambda rs, now: events.append(
            ("done", rs.req.request_id, now))
        rss = [_req(i, l) for i, l in enumerate(lens)]
        for rs in rss:
            eng.pick_prefill(0.0).submit(rs, 0.0)
        loop.run()
        seqs.append((events,
                     [(rs.prefill_instance, rs.prefill_start, rs.prefill_end)
                      for rs in rss]))
    assert seqs[0] == seqs[1]


class TestSerialEtaBoundary:
    """Satellite: the ``base = p_eta if len(q) > 1 else p_busy`` shortcut
    (sim/instances.py submit_prefill) vs the reference exact-fold walk at
    the queue-drain boundary."""

    def test_drain_and_resubmit_parity(self):
        model = H100_TP4_PREFILL
        engines = _mk_engines(None, None, n_pre=2)
        results = []
        for loop, eng in engines:
            done = []
            eng.on_prefill_done = lambda rs, now: done.append(rs)
            first = [_req(i, 1000 + 500 * i) for i in range(4)]
            for rs in first:
                eng.pick_prefill(0.0).submit(rs, 0.0)
            drain = max(model(1000), model(1500)) + model(2000) + model(2500)
            # Resubmit at the exact drain instant of the busier queue and
            # once more mid-event later; both must reproduce the reference
            # fold (max(busy, now) + sum T) bit-for-bit.
            second = [_req(10 + i, 3000 + i) for i in range(3)]

            def resub(now, eng=eng, rss=second):
                for rs in rss:
                    eng.pick_prefill(now).submit(rs, now)

            loop.at(drain, resub)
            loop.run()
            etas = [eng.prefill[s].eta(loop.now) for s in range(2)]
            results.append((
                [(rs.prefill_instance, rs.prefill_start, rs.prefill_end)
                 for rs in first + second],
                [rs.req.request_id for rs in done], etas,
            ))
        assert results[0] == results[1]

    def test_idle_resubmit_rebuilds_fold(self):
        """Queue fully drained, instance idle past busy_until: a fresh
        submit must base the fold on ``now``, not the stale busy column."""
        (loop, eng), (rloop, reng) = _mk_engines(None, None, n_pre=1)
        for l_, e in ((loop, eng), (rloop, reng)):
            rs = _req(0, 800)
            e.prefill[0].submit(rs, 0.0)
            l_.run()
            late = l_.now + 5.0
            l_.at(late, lambda now, e=e: e.prefill[0].submit(_req(1, 600), now))
            l_.run()
        assert eng.prefill[0].eta(loop.now) == reng.prefill[0].eta(rloop.now)
        assert eng.prefill[0].busy_until == reng.prefill[0].busy_until


class TestAbortCounterParity:
    """Satellite: per-link open-flow counters stay reconciled through
    fault-driven aborts in both network engines (the least-loaded NIC
    policy's signal)."""

    def _recount(self, fp):
        cnt = np.zeros(fp.tree.n_links, np.int64)
        for fv in fp.flows.values():
            for l in fv.path:
                cnt[l] += 1
        return cnt

    def test_direct_abort_counter_parity(self):
        from repro.cluster.network import BackgroundTraffic, FlowPlane
        from repro.cluster.reference import ReferenceFlowNetwork
        from repro.cluster.topology import FatTree

        bg = BackgroundTraffic(0.2)
        fp = FlowPlane(FatTree(2, 2, 2, 8, nics_per_server=4), bg, seed=0,
                       nic_policy="least-loaded")
        rf = ReferenceFlowNetwork(FatTree(2, 2, 2, 8, nics_per_server=4), bg,
                                  seed=0, nic_policy="least-loaded")
        srv = [(p, r, s) for p in range(2) for r in range(2) for s in range(2)]
        tps, trs = [], []
        for i in range(8):
            a, b = srv[i % 8], srv[(i + 3) % 8]
            tps.append(fp.start_transfer(a, b, 1e9, 0.0, lambda t, n: None))
            trs.append(rf.start_transfer(a, b, 1e9, 0.0, lambda t, n: None))
        for i in (1, 4, 6):
            fp.abort_transfer(tps[i], 0.01)
            rf.abort_transfer(trs[i], 0.01)
            assert tps[i].flows_open == 0 == trs[i].flows_open
        np.testing.assert_array_equal(fp.open_flow_counts(),
                                      rf.open_flow_counts())
        # The incremental counters also match a from-scratch recount of the
        # plane's own live flows (no leaked abort residue).
        np.testing.assert_array_equal(fp.open_flow_counts(), self._recount(fp))

    def test_fault_driven_abort_keeps_counters_consistent(self):
        """Full simulation with kills under the least-loaded policy at 4
        NICs + streaming (many in-flight chunk flows to abort): the
        FlowPlane's incremental counters must equal a live recount after
        the run, and both engines replay identically."""
        faults = (FaultEvent(time=1.5, kind="kill_decode", instance_id=4,
                             detection_delay=0.3),
                  FaultEvent(time=2.0, kind="kill_decode", instance_id=9,
                             detection_delay=0.3))
        kw = dict(chunk_tokens=512, kv_streaming=True, nics_per_server=4,
                  nic_policy="least-loaded", faults=faults)
        a = _run("plane", seed=2, **kw)
        b = _run("reference", seed=2, **kw)
        assert _outcomes(a) == _outcomes(b)
        np.testing.assert_array_equal(a.net.open_flow_counts(),
                                      self._recount(a.net))


class TestEmptyWindowMetrics:
    """Satellite: summarize must yield NaN-safe rows, never crash."""

    def test_empty_records(self):
        m = summarize([], window=(5.0, 5.0), scheduler="x")
        assert m.n_measured == 0
        assert math.isnan(m.ttft_mean) and math.isnan(m.ttft_p99)
        assert math.isnan(m.tbt_mean) and math.isnan(m.xfer_p95)
        assert math.isnan(m.slo_attainment) and math.isnan(m.hit_frac_mean)
        assert m.goodput_rps == 0.0
        m.row()  # the CSV path digests the NaNs too

    def test_window_with_no_completions(self):
        rs = _req(0, 1000)
        rs.req = Request(request_id=0, arrival=6.0, input_len=1000,
                         output_len=4, block_hashes=((0, 0),),
                         share_group=-1, slo=5.0)
        m = summarize([rs], window=(5.0, 10.0), scheduler="x")
        assert m.n_measured == 1 and m.n_unfinished == 1
        assert math.isnan(m.ttft_p50)
        assert m.slo_attainment == 0.0

    def test_done_without_valid_tbt(self):
        """A record with a first token but no valid TBT used to feed
        np.percentile an empty array and crash mid-sweep."""
        rs = _req(0, 1000)
        rs.first_token = 1.0
        rs.tbt = -1.0
        m = summarize([rs], window=(0.0, 10.0), scheduler="x")
        assert math.isnan(m.tbt_mean) and math.isnan(m.tbt_p95)
        assert np.isfinite(m.ttft_mean)

    def test_degenerate_window_in_full_sweep(self):
        """measure window entirely before any arrival: the whole summarize
        path (incl. aggregate_seeds) survives."""
        from repro.sim.metrics import aggregate_seeds

        cfg = SimConfig(scheduler="cla", seed=0, warmup=30.0, measure=1e-9,
                        background=0.2, **TREE_64)
        sim = Simulation(cfg)
        m = sim.run(_trace(0, duration=2.0), drain=10.0)
        agg = aggregate_seeds([m])
        assert math.isnan(agg["ttft_mean"])


class TestStreamedTransferTerm:
    """The ladder's overlap-aware T_xfer column vs its scalar oracle."""

    def _oracle(self):
        return OracleView(tier_of=lambda a, b: 2,
                          tier_bandwidth=dict(PAPER_TIER_BANDWIDTH),
                          tier_latency=dict(PAPER_TIER_LATENCY),
                          congestion={0: 0.0, 1: 0.2, 2: 0.3, 3: 0.5})

    def test_vector_matches_scalar(self):
        ov = self._oracle()
        s_eff = np.array([0.0, 1e9, 5e9, 2e8])
        tier_row = np.array([0, 1, 2, 3])
        nfl = {0: 0, 1: 1, 2: 0, 3: 2}
        for rem, tail in [(0.0, None), (0.4, 1e8), (2.0, 5e8), (0.1, 0.0)]:
            vec = v_transfer_time(s_eff, tier_row, ov.tier_bandwidth,
                                  ov.congestion, nfl, ov.tier_latency,
                                  prefill_remaining=rem, tail_bytes=tail)
            for i in range(len(s_eff)):
                t = int(tier_row[i])
                want = ov.est_transfer_time(
                    float(s_eff[i]), t, nfl[t],
                    prefill_remaining=rem, tail_bytes=tail)
                assert vec[i] == pytest.approx(want, rel=1e-12)

    def test_defaults_reproduce_serial(self):
        ov = self._oracle()
        s_eff = np.array([0.0, 1e9, 5e9])
        tier_row = np.array([1, 2, 3])
        nfl = {t: 0 for t in range(4)}
        a = v_transfer_time(s_eff, tier_row, ov.tier_bandwidth, ov.congestion,
                            nfl, ov.tier_latency)
        b = v_transfer_time(s_eff, tier_row, ov.tier_bandwidth, ov.congestion,
                            nfl, ov.tier_latency, prefill_remaining=0.0,
                            tail_bytes=None)
        np.testing.assert_array_equal(a, b)

    def test_overlap_credit(self):
        """The streamed estimate credits prefill/transfer overlap: it beats
        serial-after-prefill (prefill_remaining + T_xfer), never beats the
        pipe's own drain time, and degenerates to the tail when prefill
        dominates."""
        serial = streamed_transfer_time(1e9, 12.5e9, 0.0, 0, 1e-3)
        over = streamed_transfer_time(1e9, 12.5e9, 0.0, 0, 1e-3,
                                      prefill_remaining=0.05, tail_bytes=1e8)
        floor = streamed_transfer_time(1e9, 12.5e9, 0.0, 0, 1e-3,
                                       prefill_remaining=100.0, tail_bytes=1e8)
        assert over < 0.05 + serial       # beats transfer-after-prefill
        assert over >= serial             # the pipe still has to drain s_eff
        assert floor == pytest.approx(100.0 + 1e8 / 12.5e9 + 1e-3)
