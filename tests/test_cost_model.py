"""Unit + property tests for the cost model (Eqs. 1-7) and Propositions 1-2."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    H100_TP4_ITER,
    LLAMA3_70B_KV,
    ModelKVSpec,
    Prop1Instance,
    effective_bandwidth,
    effective_transfer_bytes,
    first_decode_time,
    post_prefill_latency,
    prop1_condition,
    prop1_latencies,
    prop2_epsilon_bound,
    prop2_ordering_preserved,
    queue_time,
    transfer_time,
)


class TestEq1KVSize:
    def test_llama3_70b_paper_number(self):
        # §III-B: 320 KB/token aggregate for Llama-3-70B.
        assert LLAMA3_70B_KV.kv_bytes_per_token == 320 * 1024

    def test_worked_example_32k(self):
        # §III-D: 32K-token request => ~10 GB aggregate.
        s_r = LLAMA3_70B_KV.kv_bytes(32768)
        assert abs(s_r - 10.74e9) / 10.74e9 < 0.01

    def test_hybrid_fixed_state(self):
        spec = ModelKVSpec("hy", n_layers=32, n_kv_heads=8, d_head=128,
                           n_attn_layers=4, fixed_state_bytes=16_000_000)
        # fixed state present even at zero-length input
        assert spec.kv_bytes(0) == 16_000_000
        # per-token term counts only the attention layers
        assert spec.kv_bytes_per_token == 2 * 4 * 8 * 128 * 2


class TestWorkedExample:
    """§III-D full worked example, both congestion regimes."""

    def test_moderate_congestion(self):
        s_r = LLAMA3_70B_KV.kv_bytes(32768)
        t1 = transfer_time(effective_transfer_bytes(s_r, 16384, 32768),
                           50e9 / 8, 0.2, 1, 8e-6)
        t2 = transfer_time(effective_transfer_bytes(s_r, 0.9 * 32768, 32768),
                           25e9 / 8, 0.2, 0, 15e-6)
        assert abs(t1 - 2.0) < 0.2 and abs(t2 - 0.4) < 0.05
        assert t2 < t1  # warm cross-pod candidate wins

    def test_congestion_flips_gap(self):
        s_r = LLAMA3_70B_KV.kv_bytes(32768)
        t2_low = transfer_time(effective_transfer_bytes(s_r, 0.9 * 32768, 32768),
                               25e9 / 8, 0.2, 0, 15e-6)
        t2_high = transfer_time(effective_transfer_bytes(s_r, 0.9 * 32768, 32768),
                                25e9 / 8, 0.5, 0, 15e-6)
        assert t2_high > t2_low * 1.5  # the gap collapses from 5x to ~3x


@given(
    s_r=st.floats(1e6, 1e11),
    hit=st.floats(0, 1e6),
    l=st.integers(1, 10 ** 6),
)
def test_eq2_bounds(s_r, hit, l):
    s_eff = effective_transfer_bytes(s_r, hit, l)
    assert 0.0 <= s_eff <= s_r
    # full hit -> zero transfer
    assert effective_transfer_bytes(s_r, l, l) == 0.0
    # zero hit -> full transfer
    assert effective_transfer_bytes(s_r, 0, l) == s_r


@given(
    bw=st.floats(1e6, 1e12),
    c=st.floats(0, 0.99),
    n=st.integers(0, 64),
)
def test_eq4_monotonicity(bw, c, n):
    b = effective_bandwidth(bw, c, n)
    assert 0 < b <= bw
    # more congestion or contention never increases bandwidth
    assert effective_bandwidth(bw, min(c + 0.1, 0.99), n) <= b + 1e-9
    assert effective_bandwidth(bw, c, n + 1) < b + 1e-9


@given(
    q=st.integers(0, 200), beta=st.integers(0, 64),
)
def test_eq6_queue(q, beta):
    t = queue_time(q, beta, 64, H100_TP4_ITER)
    assert t >= 0
    # no wait while slots are free
    if q <= 64 - beta:
        assert t == 0


@given(
    s_r=st.floats(1e8, 1e11),
    rho1=st.floats(0, 0.99),
    rho2=st.floats(0, 0.99),
    k=st.floats(1, 16),
    c1=st.floats(0, 0.9),
    c3=st.floats(0, 0.9),
    q1=st.floats(0, 5),
    q2=st.floats(0, 5),
)
@settings(max_examples=300)
def test_prop1_condition_matches_latencies(s_r, rho1, rho2, k, c1, c3, q1, q2):
    """Eq. (8) must EXACTLY characterise when d1 beats d2."""
    inst = Prop1Instance(s_r=s_r, B1=12.5e9, k=k, c1=c1, c3=c3,
                         rho1=rho1, rho2=max(rho1, rho2),
                         t_queue_d1=q1, t_queue_d2=q2)
    t1, t2 = prop1_latencies(inst)
    if abs(t1 - t2) / max(t1, t2, 1e-12) < 1e-9:
        return  # boundary: numerically ambiguous
    assert prop1_condition(inst) == (t1 < t2)


def test_prop1_paper_example():
    inst = Prop1Instance(s_r=1e9, B1=4e9, k=4, c1=0, c3=0, rho1=0.0, rho2=0.5)
    assert prop1_condition(inst)  # 1 < 2: network-oblivious pick is 2x worse
    t1, t2 = prop1_latencies(inst)
    assert abs(t2 / t1 - 2.0) < 1e-9


def test_prop1_gap_widens_with_context():
    """The suboptimality factor grows with s_r (context length)."""
    gaps = []
    for s_r in [1e8, 1e9, 1e10]:
        inst = Prop1Instance(s_r=s_r, B1=4e9, k=4, c1=0, c3=0, rho1=0.0,
                             rho2=0.5, t_queue_d1=0.05, t_queue_d2=0.05)
        t1, t2 = prop1_latencies(inst)
        gaps.append(t2 - t1)
    assert gaps[0] < gaps[1] < gaps[2]


@given(
    b_hi=st.floats(1e8, 1e12), ratio=st.floats(0.01, 1.0),
    c_hi=st.floats(0, 0.95), c_lo=st.floats(0, 0.95),
    eps=st.floats(0, 0.5),
)
@settings(max_examples=300)
def test_prop2_bound_is_sufficient(b_hi, ratio, c_hi, c_lo, eps):
    """Any eps strictly below the Eq. (9) bound preserves the ordering."""
    b_lo = b_hi * ratio
    if b_hi * (1 - c_hi) <= b_lo * (1 - c_lo):
        return  # premise requires true ordering
    bound = prop2_epsilon_bound(b_hi, c_hi, b_lo, c_lo)
    if eps < bound:
        assert prop2_ordering_preserved(b_hi, c_hi, b_lo, c_lo, eps)


def test_prop2_paper_numbers():
    # 4:1 oversub, c*=0.3 both: bound = 0.42
    assert abs(prop2_epsilon_bound(4.0, 0.3, 1.0, 0.3) - 0.42) < 1e-9
    # near saturation the tolerance vanishes
    assert prop2_epsilon_bound(4.0, 0.999, 1.0, 0.0) < 0


def test_eq5_additive():
    total = post_prefill_latency(
        s_r=1e9, hit_tokens=0, input_len=1000, tier_bw=1e9, congestion=0.0,
        n_inflight=0, tier_latency=1e-5, q_d=0, beta_d=3, beta_max=64,
        iter_model=H100_TP4_ITER,
    )
    expect = 1e9 / 1e9 + 1e-5 + first_decode_time(3, H100_TP4_ITER)
    assert abs(total - expect) < 1e-12
