"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Every kernel is swept over shapes and dtypes; hypothesis drives randomized
block tables and pool states for kv_pack/unpack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import repro.kernels.ops as ops
import repro.kernels.ref as ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


class TestFlashDecode:
    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
    @pytest.mark.parametrize("b,h,kv,dh,s,block_s", [
        (1, 4, 4, 64, 512, 128),     # MHA
        (2, 8, 2, 64, 1024, 256),    # GQA 4:1
        (2, 16, 8, 128, 512, 256),   # GQA 2:1, d_head 128
        (1, 8, 1, 128, 2048, 512),   # MQA
    ])
    def test_allclose(self, dtype, tol, b, h, kv, dh, s, block_s):
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _rand(keys[0], (b, h, dh), dtype)
        k = _rand(keys[1], (b, s, kv, dh), dtype)
        v = _rand(keys[2], (b, s, kv, dh), dtype)
        pos = s - s // 3
        out = ops.flash_decode(q, k, v, pos, block_s=block_s)
        exp = ref.flash_decode_ref(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32), atol=tol, rtol=tol)

    def test_pos_boundaries(self):
        """pos exactly on block boundaries and pos=1."""
        key = jax.random.PRNGKey(1)
        q = _rand(key, (1, 4, 64), jnp.float32)
        k = _rand(key, (1, 512, 2, 64), jnp.float32)
        v = _rand(key, (1, 512, 2, 64), jnp.float32)
        for pos in [1, 128, 256, 512]:
            out = ops.flash_decode(q, k, v, pos, block_s=128)
            exp = ref.flash_decode_ref(q, k, v, pos)
            np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)

    def test_matches_model_decode_attention(self):
        """Kernel == the model's XLA decode path (the serving substitution)."""
        from repro.models.attention import decode_attention

        key = jax.random.PRNGKey(2)
        q = _rand(key, (2, 8, 64), jnp.float32)
        k = _rand(key, (2, 256, 4, 64), jnp.float32)
        v = _rand(key, (2, 256, 4, 64), jnp.float32)
        out = ops.flash_decode(q, k, v, 200, block_s=128)
        exp = decode_attention(q[:, None], k, v, jnp.int32(200))[:, 0]
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)


class TestKVPack:
    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_roundtrip(self, data):
        n_pages = data.draw(st.integers(4, 32))
        n_sel = data.draw(st.integers(1, n_pages))
        table = data.draw(st.permutations(range(n_pages)))[:n_sel]
        pool = jax.random.normal(jax.random.PRNGKey(0), (n_pages, 16, 2, 64))
        buf = ops.kv_pack(pool, jnp.asarray(table, jnp.int32))
        exp = ref.kv_pack_ref(pool, jnp.asarray(table, jnp.int32))
        np.testing.assert_array_equal(np.asarray(buf), np.asarray(exp))
        dst = jnp.zeros_like(pool)
        got = ops.kv_unpack(dst, buf, jnp.asarray(table, jnp.int32))
        exp2 = ref.kv_unpack_ref(jnp.zeros_like(pool), buf, jnp.asarray(table, jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp2))

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_dtypes(self, dtype):
        pool = _rand(jax.random.PRNGKey(0), (8, 16, 4, 128), dtype)
        table = jnp.asarray([7, 0, 3], jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(ops.kv_pack(pool, table), np.float32),
            np.asarray(ref.kv_pack_ref(pool, table), np.float32))


class TestNetKVScoreKernel:
    @given(seed=st.integers(0, 1000), d=st.integers(1, 300))
    @settings(max_examples=25, deadline=None)
    def test_allclose_and_argmin(self, seed, d):
        rng = np.random.default_rng(seed)
        args = dict(
            free_mem=rng.uniform(1e9, 4e11, d),
            queued=rng.integers(0, 20, d).astype(np.float32),
            batch=rng.integers(0, 64, d).astype(np.float32),
            hit_tokens=rng.uniform(0, 9000, d),
            tier=rng.integers(0, 4, d),
            healthy=(rng.random(d) > 0.15).astype(np.float32),
            iter_scale=rng.uniform(1, 2, d),
            tier_bw=[4.5e11, 1.25e10, 6.25e9, 3.125e9],
            tier_lat=[1e-6, 3e-6, 8e-6, 1.5e-5],
            congestion=rng.uniform(0, 0.8, 4),
            n_inflight=rng.integers(0, 8, 4).astype(np.float32),
        )
        kw = dict(s_r=2.6e9, input_len=8192.0, iter_a=0.0124, iter_b=1.6e-5,
                  m_min=2e9, beta_max=64)
        c_k, b_k = ops.netkv_score(**args, **kw)
        c_r, b_r = ref.netkv_score_ref(**args, **kw)
        finite = np.asarray(c_r) < 1e38
        if finite.any():
            np.testing.assert_allclose(np.asarray(c_k)[finite],
                                       np.asarray(c_r)[finite], rtol=1e-5)
        assert int(b_k) == int(b_r)

    def test_matches_core_cost_model(self):
        """Kernel == the scalar cost model (one candidate, exact)."""
        from repro.core.cost import post_prefill_latency, H100_TP4_ITER

        kw = dict(s_r=3.2e9, input_len=8192.0, iter_a=H100_TP4_ITER.a,
                  iter_b=H100_TP4_ITER.b, m_min=1e9, beta_max=64)
        c, _ = ops.netkv_score(
            free_mem=[4e11], queued=[3.0], batch=[62.0], hit_tokens=[4096.0],
            tier=[2], healthy=[1.0], iter_scale=[1.0],
            tier_bw=[4.5e11, 1.25e10, 6.25e9, 3.125e9],
            tier_lat=[1e-6, 3e-6, 8e-6, 1.5e-5],
            congestion=[0, 0, 0.2, 0.3], n_inflight=[0, 0, 1, 0], **kw)
        expect = post_prefill_latency(
            s_r=3.2e9, hit_tokens=4096, input_len=8192, tier_bw=6.25e9,
            congestion=0.2, n_inflight=1, tier_latency=8e-6, q_d=3, beta_d=62,
            beta_max=64, iter_model=H100_TP4_ITER)
        assert abs(float(c[0]) - expect) / expect < 1e-5


class TestRWKVScan:
    @pytest.mark.parametrize("b,t,h,dh,chunk", [
        (1, 128, 2, 64, 64), (2, 256, 3, 64, 128), (1, 512, 1, 128, 128),
    ])
    def test_allclose(self, b, t, h, dh, chunk):
        keys = jax.random.split(jax.random.PRNGKey(0), 5)
        r = _rand(keys[0], (b, t, h, dh), jnp.float32) * 0.3
        k = _rand(keys[1], (b, t, h, dh), jnp.float32) * 0.3
        v = _rand(keys[2], (b, t, h, dh), jnp.float32) * 0.3
        w = jax.nn.sigmoid(_rand(keys[3], (b, t, h, dh), jnp.float32)) * 0.5 + 0.45
        u = _rand(keys[4], (h, dh), jnp.float32) * 0.3
        y1, s1 = ops.rwkv_scan(r, k, v, w, u, chunk=chunk)
        y2, s2 = ref.rwkv_scan_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)

    def test_matches_model_rwkv_core(self):
        """Kernel recurrence == the model's WKV inner loop."""
        import repro.models.rwkv as m

        b, t, d = 1, 64, 128
        cfg_h = d // m.HEAD_DIM
        key = jax.random.PRNGKey(3)
        params = {
            k: v for k, v in zip(
                ["r", "k", "v", "w"],
                [jax.random.normal(kk, (b, t, cfg_h, m.HEAD_DIM)) * 0.3
                 for kk in jax.random.split(key, 4)])
        }
        w = jax.nn.sigmoid(params["w"]) * 0.5 + 0.45
        u = jax.random.normal(jax.random.PRNGKey(9), (cfg_h, m.HEAD_DIM)) * 0.3
        y_k, s_k = ops.rwkv_scan(params["r"], params["k"], params["v"], w, u, chunk=32)
        y_r, s_r = ref.rwkv_scan_ref(params["r"], params["k"], params["v"], w, u)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-4)
