"""Scheduler ladder unit/property tests + JAX scorer equivalence."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    CandidateState,
    H100_TP4_ITER,
    NetworkCostOracle,
    RequestInfo,
    SelfContentionTracker,
    make_scheduler,
)
from repro.core.netkv_jax import JaxNetKV, PoolArrays
from repro.core.batch_assign import NetKVBatch
from repro.core.oracle import OracleView, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY


def _view(congestion=None):
    tiers = {(0, 1): 2, (0, 2): 3, (0, 3): 3, (0, 4): 2}
    return OracleView(
        tier_of=lambda p, d: tiers.get((p, d), 3),
        tier_bandwidth=PAPER_TIER_BANDWIDTH,
        tier_latency=PAPER_TIER_LATENCY,
        congestion=congestion or {t: 0.0 for t in range(4)},
    )


def _cands(**over):
    base = [
        CandidateState(1, 2e11, 0, 4, 0.0),
        CandidateState(2, 2e11, 0, 4, 0.0),
        CandidateState(3, 2e11, 0, 4, 0.0),
        CandidateState(4, 2e11, 0, 4, 0.0),
    ]
    for idx, kw in over.items():
        for k, v in kw.items():
            setattr(base[idx], k, v)
    return base


REQ = RequestInfo(0, 8192, 8192 * 320 * 1024)


def _mk(name, **kw):
    return make_scheduler(name, H100_TP4_ITER, 64, m_min=1e9, **kw)


class TestFeasibility:
    def test_memory_filter(self):
        s = _mk("netkv-full")
        cands = _cands()
        for c in cands:
            c.free_memory = 1e6  # below s_eff + m_min
        assert s.select(REQ, 0, cands, _view()) is None

    def test_unhealthy_filtered(self):
        s = _mk("netkv-full")
        cands = _cands()
        for c in cands[1:]:
            c.healthy = False
        d = s.select(REQ, 0, cands, _view())
        assert d.instance_id == 1

    def test_full_hit_always_feasible(self):
        """100% prefix hit -> s_eff = 0 -> only m_min required."""
        s = _mk("netkv-full")
        cands = _cands()
        for c in cands:
            c.free_memory = 2e9
            c.hit_tokens = REQ.input_len
        assert s.select(REQ, 0, cands, _view()) is not None


class TestNetKVDecisions:
    def test_prefers_same_pod_all_else_equal(self):
        s = _mk("netkv-full")
        d = s.select(REQ, 0, _cands(), _view())
        assert d.tier == 2  # candidates 1 and 4 are tier 2

    def test_cache_beats_tier_when_big_enough(self):
        """§III-D: warm cross-pod beats cold same-pod at 90% hit."""
        s = _mk("netkv-full")
        cands = _cands()
        cands[1].hit_tokens = 0.9 * REQ.input_len  # instance 2, tier 3
        d = s.select(REQ, 0, cands, _view())
        assert d.instance_id == 2

    def test_congestion_flips_decision(self):
        """§III-D: perturbing cross-pod congestion flips the verdict."""
        s = _mk("netkv-full")
        cands = _cands()
        cands[1].hit_tokens = 0.75 * REQ.input_len
        assert s.select(REQ, 0, cands, _view()).instance_id == 2
        cands = _cands()
        cands[1].hit_tokens = 0.75 * REQ.input_len
        d = s.select(REQ, 0, cands, _view({0: 0, 1: 0, 2: 0.0, 3: 0.72}))
        assert d.tier == 2

    def test_self_contention_spreads_load(self):
        s = _mk("netkv-static")
        infl = SelfContentionTracker()
        picks = []
        for _ in range(4):
            d = s.select(REQ, 0, _cands(), _view(), infl)
            picks.append(d.tier)
        # once tier 2 carries in-flight transfers, tier 3 gets picked
        assert 3 in picks and 2 in picks

    def test_topo_only_ignores_contention(self):
        s = _mk("netkv-topo")
        infl = SelfContentionTracker()
        for _ in range(4):
            d = s.select(REQ, 0, _cands(), _view(), infl)
            assert d.tier == 2  # never reacts
        assert infl.get(0, 2) == 0  # and never increments

    def test_inflight_cap(self):
        t = SelfContentionTracker(cap=3)
        for _ in range(10):
            t.incr(0, 2)
        assert t.get(0, 2) == 3


class TestLadderInformationOrder:
    def test_rr_cycles(self):
        s = _mk("rr")
        picks = [s.select(REQ, 0, _cands(), _view()).instance_id for _ in range(8)]
        assert picks[:4] == [1, 2, 3, 4] and picks[4:] == [1, 2, 3, 4]

    def test_la_prefers_empty(self):
        s = _mk("la")
        cands = _cands()
        cands[2].batch_size = 0
        for i, c in enumerate(cands):
            if i != 2:
                c.batch_size = 60
                c.queued = 20
        assert s.select(REQ, 0, cands, _view()).instance_id == 3

    def test_ca_prefers_warm(self):
        s = _mk("ca")
        cands = _cands()
        cands[3].hit_tokens = 4096
        assert s.select(REQ, 0, cands, _view()).instance_id == 4

    def test_cla_trades_off(self):
        s = _mk("cla", w_cache=1.0, w_load=1.0)
        cands = _cands()
        cands[0].hit_tokens = REQ.input_len  # warm but overloaded
        cands[0].queued = 500
        cands[0].batch_size = 64
        d = s.select(REQ, 0, cands, _view())
        assert d.instance_id != 1


class TestJaxScorerEquivalence:
    @given(data=st.data())
    @settings(max_examples=30, deadline=None)
    def test_matches_python_netkv(self, data):
        rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
        n = data.draw(st.integers(2, 24))
        cands = [
            CandidateState(
                instance_id=i,
                free_memory=float(rng.uniform(1e9, 4e11)),
                queued=int(rng.integers(0, 10)),
                batch_size=int(rng.integers(0, 64)),
                hit_tokens=float(rng.integers(0, REQ.input_len)),
                healthy=bool(rng.random() > 0.1),
                iter_scale=float(rng.uniform(1.0, 2.0)),
            )
            for i in range(n)
        ]
        tiers = rng.integers(0, 4, n)
        view = OracleView(
            tier_of=lambda p, d: int(tiers[d]),
            tier_bandwidth=PAPER_TIER_BANDWIDTH,
            tier_latency=PAPER_TIER_LATENCY,
            congestion={t: float(rng.uniform(0, 0.8)) for t in range(4)},
        )
        py = _mk("netkv-full")
        d_py = py.select(REQ, 0, cands, view, None)

        jx = JaxNetKV(H100_TP4_ITER, 64, m_min=1e9)
        pool = PoolArrays.from_candidates(cands, tiers)
        idx, costs = jx.select_arrays(pool, REQ.kv_bytes, REQ.input_len, view,
                                      [0, 0, 0, 0])
        if d_py is None:
            assert idx is None
        else:
            # same winner (cost ties broken identically by argmin order)
            assert cands[idx].instance_id == d_py.instance_id or \
                abs(float(costs[idx]) - d_py.cost) < 1e-5


class TestBatchAssignment:
    def test_window_of_one_equals_greedy(self):
        b = NetKVBatch(H100_TP4_ITER, 64, m_min=1e9)
        g = _mk("netkv-full")
        cands = _cands()
        d_b = b.select_batch([(REQ, 0)], [cands], _view(), None)[0]
        d_g = g.select(REQ, 0, _cands(), _view(), None)
        # identical candidates tie; both must pick the same-cost (tier) choice
        assert d_b.tier == d_g.tier
        assert abs(d_b.cost - d_g.cost) < 1e-12

    def test_joint_window_spreads(self):
        """Two same-window requests should not both pile onto one instance
        when the marginal costs say otherwise."""
        b = NetKVBatch(H100_TP4_ITER, 64, m_min=1e9)
        infl = SelfContentionTracker()
        reqs = [(RequestInfo(i, 8192, 8192 * 320 * 1024), 0) for i in range(4)]
        cands = _cands()
        ds = b.select_batch(reqs, [cands] * 4, _view(), infl)
        assert all(d is not None for d in ds)
        tiers = [d.tier for d in ds]
        assert 3 in tiers  # contention pushed someone cross-pod
