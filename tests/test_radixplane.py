"""RadixPlane vs the retired BlockCache on random hash streams.

The array-backed RadixPlane must reproduce the OrderedDict LRU exactly:
LCP hit-token counts, eviction order, byte accounting and the
hits/misses/evictions counters, under interleaved insert/touch/evict_to
with arbitrary ``protected`` levels.  The broadcast ``hit_row`` must agree
with D independent per-instance walks (including slots past the 64th, which
exercises multi-word bit packing).
"""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.sim.kvcache import B_TOK, BlockCache, RadixPlane

BPB = 1e3  # bytes per block


def _mk(n_instances=1, budget=1e9):
    plane = RadixPlane(BPB, block_capacity=64, instance_capacity=2)
    refs = []
    for _ in range(n_instances):
        plane.add_instance(budget)
        refs.append(BlockCache(budget_bytes=budget, bytes_per_block=BPB))
    return plane, refs


def _assert_same(plane, ref, s, probe_hashes):
    assert plane.bytes_used(s) == ref.bytes_used
    assert int(plane.hits[s]) == ref.hits
    assert int(plane.misses[s]) == ref.misses
    assert int(plane.evictions[s]) == ref.evictions
    for h in probe_hashes:
        assert plane.contains(s, h) == (h in ref)
    assert plane.lcp_blocks(s, probe_hashes) == ref.lcp_blocks(probe_hashes)


def _drive(plane, refs, seed, n_ops=200, pool=60):
    """Apply one randomized op stream per instance to both structures."""
    rng = np.random.default_rng(seed)
    universe = [("h", i) for i in range(pool)]
    for _ in range(n_ops):
        s = int(rng.integers(len(refs)))
        ref = refs[s]
        op = rng.random()
        k = int(rng.integers(1, 12))
        start = int(rng.integers(pool))
        chain = [universe[(start + j) % pool] for j in range(k)]
        if op < 0.5:
            protected = float(rng.uniform(0, 8e3))
            plane.insert(s, chain, protected=protected)
            ref.insert(chain, protected=protected)
        elif op < 0.75:
            plane.touch(s, chain)
            ref.touch(chain)
        else:
            protected = float(rng.uniform(0, 1.2e9))
            plane.evict_to(s, protected)
            ref.evict_to(protected)
        _assert_same(plane, ref, s, chain)
        probe = [universe[int(j)] for j in rng.integers(0, pool, 8)]
        assert plane.hit_tokens(s, probe, input_len=1000) == \
            ref.hit_tokens(probe, input_len=1000)


class TestRandomStreamParity:
    @pytest.mark.parametrize("seed", range(4))
    def test_single_instance(self, seed):
        plane, refs = _mk(1, budget=12e3)  # tight budget: constant eviction
        _drive(plane, refs, seed)

    @pytest.mark.parametrize("seed", range(2))
    def test_interleaved_instances(self, seed):
        """Ops interleave across instances sharing the intern table and
        presence bitmask; per-instance state must not cross-talk."""
        plane, refs = _mk(3, budget=9e3)
        _drive(plane, refs, seed + 100, n_ops=300)

    def test_block_ids_recycled_after_last_holder_evicts(self):
        """Memory tracks *resident* distinct blocks: once every instance has
        evicted a block, its dense id (and presence row) is reused, so the
        intern table does not grow with blocks ever seen."""
        plane, refs = _mk(2, budget=4e3)  # 4 blocks per instance
        for i in range(50):
            chain = [("u", i, j) for j in range(4)]
            plane.insert(0, chain)
            plane.insert(1, chain)
        assert len(plane._intern) == plane.count[0] + len(
            set(plane._pos[1]) - set(plane._pos[0]))
        assert len(plane._hash_of) - len(plane._free_bids) == len(plane._intern)
        # Evicted hashes are gone from the intern table entirely.
        assert not plane.contains(0, ("u", 0, 0))
        assert plane.hit_row([("u", 0, 0)], input_len=100).tolist() == [0.0, 0.0]
        # Fresh inserts after recycling still behave (parity spot check).
        ref = BlockCache(4e3, BPB)
        chain = [("v", j) for j in range(4)]
        plane.insert(0, chain)
        ref.insert(chain)
        assert plane.lcp_blocks(0, chain) == ref.lcp_blocks(chain) == 4

    def test_reset_instance_matches_fresh_cache(self):
        plane, refs = _mk(2, budget=20e3)
        _drive(plane, refs, 7, n_ops=60)
        plane.reset_instance(0)
        refs[0] = BlockCache(budget_bytes=20e3, bytes_per_block=BPB)
        _drive(plane, refs, 8, n_ops=120)


class TestBroadcastHitRow:
    def test_matches_per_instance_walks_across_words(self):
        """hit_row against 150 instances (3 uint64 words) == 150 walks."""
        D, budget = 150, 1e9
        plane = RadixPlane(BPB, block_capacity=64, instance_capacity=4)
        refs = [BlockCache(budget, BPB) for _ in range(D)]
        rng = np.random.default_rng(0)
        for s in range(D):
            plane.add_instance(budget)
            k = int(rng.integers(0, 30))
            chain = [("c", int(g), j) for g in rng.integers(0, 5, 1) for j in range(k)]
            plane.insert(s, chain)
            refs[s].insert(chain)
        req = [("c", 2, j) for j in range(25)] + [("miss", 0)]
        row = plane.hit_row(req, input_len=10_000)
        expect = np.array([r.hit_tokens(req, 10_000) for r in refs], float)
        np.testing.assert_array_equal(row, expect)

    def test_unknown_prefix_block_caps_every_instance(self):
        plane, refs = _mk(2)
        plane.insert(0, [("a", 0), ("a", 1)])
        row = plane.hit_row([("never", 9), ("a", 0)], input_len=100)
        assert row.tolist() == [0.0, 0.0]

    def test_out_buffer_reuse(self):
        plane, refs = _mk(2)
        plane.insert(1, [("x", 0)])
        out = np.full(8, -1.0)
        plane.hit_row([("x", 0)], input_len=100, out=out)
        assert out[0] == 0.0 and out[1] == B_TOK
        assert out[2] == -1.0  # untouched past n


class TestPropertyBased:
    """hypothesis property tests (skip cleanly when hypothesis is absent)."""

    @given(st.lists(st.integers(0, 40), min_size=1, max_size=60),
           st.lists(st.integers(0, 40), min_size=1, max_size=20))
    @settings(max_examples=60, deadline=None)
    def test_lcp_hit_tokens_match(self, inserted, query):
        plane, (ref,) = _mk(1)
        chain = [("b", i) for i in inserted]
        plane.insert(0, chain)
        ref.insert(chain)
        q = [("b", i) for i in query]
        assert plane.hit_tokens(0, q, input_len=10_000) == \
            ref.hit_tokens(q, input_len=10_000)
        assert plane.hit_tokens(0, q, input_len=5) == \
            ref.hit_tokens(q, input_len=5)

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 30),
                              st.integers(1, 8)),
                    min_size=1, max_size=80))
    @settings(max_examples=60, deadline=None)
    def test_lru_eviction_order_and_bytes_conservation(self, ops):
        """Interleaved inserts/touches under a tight budget: eviction order,
        membership and byte accounting all match the OrderedDict LRU."""
        budget = 8e3
        plane, (ref,) = _mk(1, budget=budget)
        for kind, start, k in ops:
            chain = [("p", (start + j) % 35) for j in range(k)]
            if kind == 0:
                plane.insert(0, chain)
                ref.insert(chain)
            elif kind == 1:
                plane.touch(0, chain)
                ref.touch(chain)
            else:
                plane.evict_to(0, float(start) * 300.0)
                ref.evict_to(float(start) * 300.0)
            assert plane.bytes_used(0) == ref.bytes_used
            assert plane.bytes_used(0) <= budget
        for i in range(35):
            assert plane.contains(0, ("p", i)) == (("p", i) in ref)
        assert int(plane.evictions[0]) == ref.evictions
