"""InstancePlane parity: columnar engine vs the retired per-object oracle.

Full ``Simulation`` runs (trace -> prefill -> scheduler -> FlowPlane ->
decode) are executed twice — ``instance_engine="plane"`` vs
``instance_engine="reference"`` — on seeded 64- and 256-GPU fat-trees, and
every per-request outcome must match *bit-for-bit*: prefill start/end,
scheduling time, chosen decode instance, tier, effective transfer bytes,
per-instance cache-hit tokens, transfer landing, admission, first token
(TTFT), TBT, finish time, token counts, rejections and requeues.  Finish
*order* (the (finish_time, request_id) sequence) and the per-instance cache
counters (hits/misses/evictions/bytes_used) must also be identical.

This exercises the cohort-stepped iteration clock, the RadixPlane broadcast
LCP + array LRU, epoch-batched admission (both engines share the epoch
path), the vectorised prefill ETA argmin, and the fault/requeue machinery.
Both of the plane's token-accounting paths are pinned explicitly: the
scalar per-row path (small cohorts) and the fused-array path
(``scalar_rows_max = -1`` forces it for every cohort).
"""

import numpy as np
import pytest

from repro.sim import FaultEvent, SimConfig, Simulation
from repro.traces import generate_trace, profile_capacity

TREE_64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2, n_prefill=4)
TREE_256 = dict(n_pods=2, racks_per_pod=8, servers_per_rack=2, n_prefill=16)


def _trace(tree_kw, seed, duration=5.0):
    n_servers = 2 * tree_kw["n_pods"] * tree_kw["racks_per_pod"] * \
        tree_kw["servers_per_rack"]
    n_inst = n_servers * 8 // 4
    n_prefill = tree_kw["n_prefill"]
    cap = profile_capacity(
        "rag", n_prefill=n_prefill, n_decode=n_inst - n_prefill,
        tor_egress_bytes_per_s=8 * 50e9 / 8 * max(n_inst // 16, 1))
    return generate_trace("rag", duration=duration, target_rps=cap, seed=seed)


def _run(engine, tree_kw, sched, seed, faults=(), scalar_rows_max=None):
    cfg = SimConfig(scheduler=sched, seed=seed, background=0.2,
                    warmup=1.0, measure=3.0, instance_engine=engine,
                    faults=faults, **tree_kw)
    sim = Simulation(cfg)
    if scalar_rows_max is not None and engine == "plane":
        sim.engine.scalar_rows_max = scalar_rows_max
    sim.run(_trace(tree_kw, seed), drain=40.0)
    return sim


def _outcomes(sim):
    recs = [
        (r.req.request_id, r.prefill_instance, r.prefill_start, r.prefill_end,
         r.sched_time, r.decode_instance, r.tier, r.s_eff, r.hit_tokens,
         r.transfer_end, r.admit_time, r.first_token, r.finish, r.tbt,
         r.tokens_out, r.rejected, r.requeues)
        for r in sim.records
    ]
    finish_order = sorted(
        (r.finish, r.req.request_id) for r in sim.records if r.finish >= 0
    )
    return recs, finish_order, sim.engine.cache_stats()


def _assert_parity(a, b):
    ra, fa, ca = _outcomes(a)
    rb, fb, cb = _outcomes(b)
    assert ra == rb          # every per-request field, bit-for-bit
    assert fa == fb          # finish order (time, id)
    assert ca == cb          # per-instance cache-hit counters


class TestBitExactParity:
    @pytest.mark.parametrize("seed", range(2))
    def test_netkv_full_64(self, seed):
        _assert_parity(_run("plane", TREE_64, "netkv-full", seed),
                       _run("reference", TREE_64, "netkv-full", seed))

    def test_cla_64(self):
        _assert_parity(_run("plane", TREE_64, "cla", 0),
                       _run("reference", TREE_64, "cla", 0))

    def test_netkv_full_256(self):
        _assert_parity(_run("plane", TREE_256, "netkv-full", 0),
                       _run("reference", TREE_256, "netkv-full", 0))

    def test_vector_row_path_64(self):
        """scalar_rows_max = -1 forces the fused-array accounting path for
        every cohort — it must agree with the reference (and hence with the
        scalar path) exactly."""
        _assert_parity(_run("plane", TREE_64, "netkv-full", 3,
                            scalar_rows_max=-1),
                       _run("reference", TREE_64, "netkv-full", 3))


class TestBatchWindowParity:
    def test_netkv_batch_64(self):
        """Window-batched scheduling: the dispatch burst goes through the
        FlowPlane arrival epoch (one union rate recompute) on both arms."""
        _assert_parity(_run("plane", TREE_64, "netkv-batch", 0),
                       _run("reference", TREE_64, "netkv-batch", 0))


class TestFaultParity:
    FAULTS = (
        FaultEvent(time=1.6, kind="kill_decode", instance_id=5,
                   detection_delay=0.3),
        FaultEvent(time=2.1, kind="slowdown", instance_id=7, factor=3.0),
        FaultEvent(time=2.5, kind="add_decode"),
    )

    def test_kill_slowdown_join_64(self):
        """Failure (victims + bounced dispatches + requeues), straggler
        scaling and elastic join must all replay identically."""
        a = _run("plane", TREE_64, "netkv-full", 0, faults=self.FAULTS)
        b = _run("reference", TREE_64, "netkv-full", 0, faults=self.FAULTS)
        _assert_parity(a, b)
        assert sum(r.requeues for r in a.records) > 0  # fault path exercised
        sa = next(d for d in a.decode if d.instance_id == 7)
        assert sa.iter_scale_est > 1.0                 # straggler EWMA moved


class TestThroughputSanity:
    def test_plane_not_slower_at_scale(self):
        """The cohort clock must step a large synchronized pool much faster
        than per-instance heap events (the decode_throughput benchmark gates
        the full 10x at 1024; this is a fast in-suite canary at 256)."""
        import time

        from repro.core.cost import H100_TP4_ITER, H100_TP4_PREFILL, LLAMA3_70B_KV
        from repro.core.view import ClusterView
        from repro.sim import (
            EventLoop, InstancePlane, ReferenceInstanceEngine, RequestState,
        )
        from repro.traces.mooncake import Request

        class Meta:
            def __init__(self, iid, srv):
                self.instance_id, self.server = iid, srv

        def build(kind, D=256, B=32):
            loop = EventLoop()
            view = ClusterView(capacity=D)
            dec = [Meta(i, (0, 0, i)) for i in range(D)]
            cls = InstancePlane if kind == "plane" else ReferenceInstanceEngine
            eng = cls([], dec, view=view, loop=loop, iter_model=H100_TP4_ITER,
                      prefill_model=H100_TP4_PREFILL, beta_max=64,
                      kv_spec=LLAMA3_70B_KV, kv_budget=1e18)
            eng.set_decode_callbacks(None, None)
            rid = 0
            for i in range(D):
                for _ in range(B):
                    req = Request(request_id=rid, arrival=0.0, input_len=256,
                                  output_len=10**9,
                                  block_hashes=((rid, 0), (rid, 1)),
                                  share_group=-1, slo=5.0)
                    eng.enqueue(i, RequestState(req=req, kv_bytes=1e6), 0.0)
                    rid += 1
            eng.kick(range(D), 0.0)
            return loop, eng

        times = {}
        for kind in ("plane", "reference"):
            loop, eng = build(kind)
            horizon = 10 * H100_TP4_ITER(32) * 1.001
            t0 = time.perf_counter()
            loop.run(until=horizon)
            times[kind] = time.perf_counter() - t0
            assert eng.total_iterations == 256 * 10
        assert times["plane"] < times["reference"]
