"""TopoPlane: multi-NIC hosts, NIC-choice policies, OCS capacity rewiring.

Four concerns, mirroring the other planes' test layout:

* **Topology** — the per-server NIC axis materialises N nic_up/nic_down
  pairs per server at full tier-1 capacity each, and ``nics_per_server=1``
  reproduces the historical single-NIC link table (same ids, same RNG
  stream — the existing parity suites run unmodified on top of this).
* **Policies** — hash spreads, least-loaded avoids occupied rails (with the
  analytic consequence: N disjoint-rail transfers each attain full B_1),
  rail-affine round-robins with src/dst rail alignment.
* **Rewire** — ``FatTree.rewire`` swaps tier capacities atomically in both
  link tables; ``FlowPlane.on_rewire`` re-water-fills in-flight flows so no
  flow is ever left over the new capacity; byte conservation and max-min
  feasibility hold across mid-flight rewires (property tests); and the
  FlowPlane stays bit-exact with ``ReferenceFlowNetwork`` across rewires
  and multi-NIC policies.
* **Oracle** — the static B_tau map snapshots from the *live* topology
  (regression: a non-paper tree must never report paper constants), and a
  rewire reaches the scheduler only at the next refresh (staleness).
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.cluster import (
    BackgroundTraffic,
    FatTree,
    FlowPlane,
    ReferenceFlowNetwork,
    make_nic_policy,
)
from repro.core.oracle import NetworkCostOracle, PAPER_TIER_BANDWIDTH, TIERS

B1 = PAPER_TIER_BANDWIDTH[1]


def _servers(tree):
    return [
        (p, r, s)
        for p in range(tree.n_pods)
        for r in range(tree.racks_per_pod)
        for s in range(tree.servers_per_rack)
    ]


def _drain(net, now=0.0, until=1e9):
    while True:
        nxt = net.next_completion_time(now)
        if nxt is None or nxt > until:
            return now
        now = nxt
        net.advance(now)


# ---------------------------------------------------------------- topology
class TestMultiNicTopology:
    def test_link_counts_and_capacity(self):
        tree = FatTree(nics_per_server=4)
        for srv in _servers(tree):
            assert len(tree._nic_up[srv]) == 4
            assert len(tree._nic_down[srv]) == 4
            for lid in (*tree._nic_up[srv], *tree._nic_down[srv]):
                assert tree.links[lid].tier == 1
                assert tree.links[lid].capacity == B1
        # 1 nvlink + 4 up + 4 down per server, plus ToR/agg uplink groups.
        n_srv = tree.n_servers
        n_racks = tree.n_pods * tree.racks_per_pod
        assert tree.n_links == n_srv * 9 + n_racks * 16 + tree.n_pods * 16

    def test_single_nic_table_is_historical(self):
        """nics_per_server=1 keeps the per-server nvlink/nic_up/nic_down
        link-id triple sequence — ids 3k, 3k+1, 3k+2 within the server
        block — so pre-NIC path rows are reproduced exactly."""
        tree = FatTree(nics_per_server=1)
        for si, srv in enumerate(_servers(tree)):
            assert tree._srv_nic_up[si, 0] == tree._srv_nvlink[si] + 1
            assert tree._srv_nic_down[si, 0] == tree._srv_nvlink[si] + 2

    def test_path_row_uses_chosen_nics(self):
        tree = FatTree(nics_per_server=4)
        rng = np.random.default_rng(0)
        src, dst = (0, 0, 0), (0, 0, 1)
        row, k = tree.path_row(src, dst, rng, nics=(2, 3))
        assert int(row[0]) == tree._nic_up[src][2]
        assert int(row[k - 1]) == tree._nic_down[dst][3]

    def test_path_row_matches_flow_path_multinic(self):
        tree = FatTree(nics_per_server=4)
        r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
        for src, dst, nics in [((0, 0, 0), (0, 0, 1), (1, 2)),
                               ((0, 0, 0), (0, 1, 0), (3, 0)),
                               ((0, 1, 1), (1, 0, 1), (2, 2))]:
            row, k = tree.path_row(src, dst, r1, nics=nics)
            assert [int(x) for x in row[:k]] == tree.flow_path(src, dst, r2, nics=nics)


# ---------------------------------------------------------------- policies
class TestNicPolicies:
    def test_single_nic_consumes_no_rng(self):
        """With one NIC per server every policy must leave the ECMP RNG
        stream untouched (bit-compat with the pre-NIC engines)."""
        tree = FatTree(nics_per_server=1)
        for name in ("hash", "least-loaded", "rail-affine", "adaptive"):
            pol = make_nic_policy(name)
            rng = np.random.default_rng(3)
            probe = np.random.default_rng(3)
            assert pol.pick(tree, 0, 1, rng) == (0, 0)
            assert rng.integers(1 << 30) == probe.integers(1 << 30)

    def test_hash_spreads_across_nics(self):
        tree = FatTree(nics_per_server=4)
        pol = make_nic_policy("hash")
        rng = np.random.default_rng(0)
        picks = {pol.pick(tree, 0, 1, rng) for _ in range(64)}
        assert len({p[0] for p in picks}) == 4
        assert len({p[1] for p in picks}) == 4

    def test_rail_affine_round_robin(self):
        tree = FatTree(nics_per_server=4)
        pol = make_nic_policy("rail-affine")
        rng = np.random.default_rng(0)
        seq = [pol.pick(tree, 0, 1, rng) for _ in range(6)]
        assert seq == [(0, 0), (1, 1), (2, 2), (3, 3), (0, 0), (1, 1)]

    def test_least_loaded_avoids_occupied_rail(self):
        tree = FatTree(n_pods=1, racks_per_pod=1, servers_per_rack=4,
                       nics_per_server=2)
        net = FlowPlane(tree, BackgroundTraffic(0.0), seed=0,
                        nic_policy="least-loaded")
        net.start_transfer((0, 0, 0), (0, 0, 1), 1e9, 0.0, lambda t, n: None)
        net.start_transfer((0, 0, 0), (0, 0, 2), 1e9, 0.0, lambda t, n: None)
        # Each transfer rides its own src NIC: both attain the full B_1.
        per_transfer = {}
        for f in net.flows.values():
            per_transfer.setdefault(f.transfer.transfer_id, 0.0)
            per_transfer[f.transfer.transfer_id] += f.rate
        for agg in per_transfer.values():
            assert abs(agg - B1) / B1 < 1e-9

    def test_single_nic_shares_where_multinic_does_not(self):
        """The same two-transfer pattern on one NIC halves; the analytic
        contrast that makes the NIC sweep (exp9) meaningful."""
        tree = FatTree(n_pods=1, racks_per_pod=1, servers_per_rack=4,
                       nics_per_server=1)
        net = FlowPlane(tree, BackgroundTraffic(0.0), seed=0)
        net.start_transfer((0, 0, 0), (0, 0, 1), 1e9, 0.0, lambda t, n: None)
        net.start_transfer((0, 0, 0), (0, 0, 2), 1e9, 0.0, lambda t, n: None)
        agg = sum(f.rate for f in net.flows.values())
        assert abs(agg - B1) / B1 < 1e-9   # shared nic_up caps the sum

    def test_adaptive_cold_start_matches_hash(self):
        """Before ``warm`` observations the adaptive policy must replay
        the hash baseline bit-for-bit (same RNG draws, same picks)."""
        tree = FatTree(nics_per_server=4)
        ada = make_nic_policy("adaptive")
        ref = make_nic_policy("hash")
        rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
        for _ in range(ada.warm):
            ada.observe(1e9)   # large sizes, but still inside the warm-up
            assert ada.pick(tree, 0, 1, rng_a) == ref.pick(tree, 0, 1, rng_b)
        assert rng_a.integers(1 << 30) == rng_b.integers(1 << 30)

    def test_adaptive_switches_on_observed_size(self):
        tree = FatTree(nics_per_server=4)
        ada = make_nic_policy("adaptive")
        rng = np.random.default_rng(0)
        # Warm up on large transfers: the EWMA settles above the threshold
        # and the policy delegates to rail-affine (round-robin pairs).
        for _ in range(ada.warm + 1):
            ada.observe(1e9)
        assert ada.ewma >= ada.threshold_bytes
        seq = [ada.pick(tree, 0, 1, rng) for _ in range(4)]
        assert seq == [(0, 0), (1, 1), (2, 2), (3, 3)]
        # A long run of small transfers drags the EWMA back under the
        # threshold: picks revert to independent hash draws.
        for _ in range(200):
            ada.observe(1e5)
        assert ada.ewma < ada.threshold_bytes
        picks = {ada.pick(tree, 0, 1, rng) for _ in range(64)}
        assert len({p[0] for p in picks}) == 4   # both endpoints spread
        assert len({p[1] for p in picks}) == 4

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            FlowPlane(FatTree(), BackgroundTraffic(0.0), nic_policy="nope")


# ------------------------------------------------------------------ rewire
class TestRewire:
    def test_rewire_swaps_both_link_tables(self):
        tree = FatTree()
        before = tree.link_capacity.copy()
        epoch = tree.rewire(scale={2: 0.5, 3: 0.25})
        assert epoch == tree.topo_epoch == 1
        t2 = tree.link_tier == 2
        t3 = tree.link_tier == 3
        assert np.all(tree.link_capacity[t2] == before[t2] * 0.5)
        assert np.all(tree.link_capacity[t3] == before[t3] * 0.25)
        assert np.all(tree.link_capacity[~(t2 | t3)] == before[~(t2 | t3)])
        for l in tree.links:   # per-object records swap in the same call
            assert l.capacity == tree.link_capacity[l.link_id]

    def test_rewire_absolute_and_restore(self):
        tree = FatTree()
        base3 = tree.tier_bandwidth[3]
        tree.rewire(tier_bandwidth={3: 1e9})
        assert tree.tier_bandwidth[3] == 1e9
        tree.rewire(scale={3: 0.25})
        tree.rewire(scale={3: 4.0})
        assert tree.tier_bandwidth[3] == 1e9   # power-of-two round trip
        tree.rewire(tier_bandwidth={3: base3})
        assert np.all(
            tree.link_capacity[tree.link_tier == 3] == base3)

    def test_rewire_unknown_tier_rejected(self):
        with pytest.raises(KeyError):
            FatTree().rewire(tier_bandwidth={7: 1e9})

    def test_inflight_flows_rewaterfilled(self):
        """A tier-3 transfer's rate tracks the uplink capacity through a
        degrade/restore cycle — never silently above the live capacity."""
        tree = FatTree(n_tor_uplinks=1, n_agg_uplinks=1)
        net = FlowPlane(tree, BackgroundTraffic(0.0), seed=0)
        net.start_transfer((0, 0, 0), (1, 0, 0), 1e12, 0.0, lambda t, n: None)
        b3 = PAPER_TIER_BANDWIDTH[3]
        assert abs(sum(f.rate for f in net.flows.values()) - b3) / b3 < 1e-9
        tree.rewire(scale={3: 0.5})
        net.on_rewire(0.010)
        agg = sum(f.rate for f in net.flows.values())
        assert abs(agg - b3 / 2) / b3 < 1e-9
        load, resid = net.link_utilization()
        assert np.all(load <= resid * (1 + 1e-9) + 1e-6)
        tree.rewire(scale={3: 2.0})
        net.on_rewire(0.020)
        agg = sum(f.rate for f in net.flows.values())
        assert abs(agg - b3) / b3 < 1e-9

    def test_rewire_inside_epoch_rejected(self):
        net = FlowPlane(FatTree(), BackgroundTraffic(0.0), seed=0)
        net.begin_epoch()
        with pytest.raises(RuntimeError):
            net.on_rewire(0.0)
        net.end_epoch()

    def test_completion_timeline_shifts(self):
        """Halving capacity mid-flight doubles the remaining drain time."""
        tree = FatTree(n_tor_uplinks=1, n_agg_uplinks=1)
        net = FlowPlane(tree, BackgroundTraffic(0.0), seed=0)
        done = []
        b3 = PAPER_TIER_BANDWIDTH[3]
        net.start_transfer((0, 0, 0), (1, 0, 0), b3, 0.0,
                           lambda t, n: done.append(n))   # 1 s uncontested
        half = 0.5
        net.advance(half)
        tree.rewire(scale={3: 0.5})
        net.on_rewire(half)
        _drain(net, now=half)
        assert done and abs(done[0] - 1.5) < 1e-6


# ------------------------------------------------- parity across the fabric
def _drive_pair(tree_kw, seed, *, nic_policy="hash", n_ops=60, bg=0.0,
                rewire_every=None):
    """Randomised op sequence through both engines, rewires interleaved."""
    plane = FlowPlane(FatTree(**tree_kw), BackgroundTraffic(bg), seed=seed,
                      nic_policy=nic_policy)
    ref = ReferenceFlowNetwork(FatTree(**tree_kw), BackgroundTraffic(bg),
                               seed=seed, nic_policy=nic_policy)
    wl = np.random.default_rng(seed + 0x7090)
    servers = _servers(plane.tree)
    done_a, done_b = [], []
    now = 0.0
    scales = [0.25, 0.5, 2.0, 4.0]
    for op_i in range(n_ops):
        now += float(wl.exponential(0.003))
        op = wl.random()
        if rewire_every and op_i and op_i % rewire_every == 0:
            tier = int(wl.integers(1, 4))
            f = scales[int(wl.integers(len(scales)))]
            plane.tree.rewire(scale={tier: f})
            ref.tree.rewire(scale={tier: f})
            plane.on_rewire(now)
            ref.refresh_rates(now)
        elif op < 0.6:
            i, j = wl.choice(len(servers), 2, replace=False)
            nbytes = float(wl.uniform(1e6, 5e8))
            plane.start_transfer(
                servers[i], servers[j], nbytes, now,
                on_complete=lambda t, tt: done_a.append((t.transfer_id, tt)))
            ref.start_transfer(
                servers[i], servers[j], nbytes, now,
                on_complete=lambda t, tt: done_b.append((t.transfer_id, tt)))
        else:
            na, nb = plane.next_completion_time(now), ref.next_completion_time(now)
            assert na == nb
            if na is not None:
                now = na
                plane.advance(now)
                ref.advance(now)
        fa = {f: (v.rate, v.bytes_remaining, v.path) for f, v in plane.flows.items()}
        fb = {f: (v.rate, v.bytes_remaining, v.path) for f, v in ref.flows.items()}
        assert fa == fb
    for _ in range(10_000):
        na, nb = plane.next_completion_time(now), ref.next_completion_time(now)
        assert na == nb
        if na is None:
            break
        now = na
        plane.advance(now)
        ref.advance(now)
    else:  # pragma: no cover
        pytest.fail("drain did not converge")
    return plane, ref, done_a, done_b


TREE_64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2, gpus_per_server=8)


class TestParityAcrossRewire:
    @pytest.mark.parametrize("seed", range(3))
    def test_rewire_completion_order_bit_exact(self, seed):
        plane, ref, da, db = _drive_pair(TREE_64, seed, rewire_every=8)
        assert da == db                       # completion order AND times
        assert plane.bytes_delivered == ref.bytes_delivered
        assert plane.tier_utilization_observed(0.0) == \
            ref.tier_utilization_observed(0.0)

    @pytest.mark.parametrize(
        "policy", ["hash", "least-loaded", "rail-affine", "adaptive"])
    def test_multinic_policy_parity(self, policy):
        kw = dict(TREE_64, nics_per_server=4)
        plane, ref, da, db = _drive_pair(kw, 1, nic_policy=policy)
        assert da == db
        assert plane.bytes_delivered == ref.bytes_delivered

    def test_multinic_rewire_parity(self):
        kw = dict(TREE_64, nics_per_server=2)
        plane, ref, da, db = _drive_pair(kw, 2, nic_policy="least-loaded",
                                         rewire_every=10, bg=0.2)
        assert da == db
        assert plane.bytes_delivered == ref.bytes_delivered


# ------------------------------------------------------------ property tests
@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_byte_conservation_across_rewire(data):
    """Property: a mid-flight capacity swap neither loses nor duplicates
    bytes — total delivered equals the sum of transfer sizes."""
    tree = FatTree(nics_per_server=data.draw(st.integers(1, 4)))
    net = FlowPlane(tree, BackgroundTraffic(0.0),
                    seed=data.draw(st.integers(0, 999)))
    servers = _servers(tree)
    total = 0.0
    for _ in range(data.draw(st.integers(1, 6))):
        i = data.draw(st.integers(0, len(servers) - 1))
        j = data.draw(st.integers(0, len(servers) - 1))
        if i == j:
            continue
        b = data.draw(st.floats(1e6, 1e9))
        total += b
        net.start_transfer(servers[i], servers[j], b, 0.0, lambda t, n: None)
    # Drain a few epochs, swap capacities, drain to empty.
    now = 0.0
    for _ in range(data.draw(st.integers(0, 3))):
        nxt = net.next_completion_time(now)
        if nxt is None:
            break
        now = nxt
        net.advance(now)
    tier = data.draw(st.integers(1, 3))
    tree.rewire(scale={tier: data.draw(st.sampled_from([0.25, 0.5, 2.0]))})
    net.on_rewire(now)
    _drain(net, now=now)
    assert abs(net.bytes_delivered - total) < max(1e-6 * total, 64.0)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_max_min_invariants_after_rewire(data):
    """Property: after a rewire + re-water-fill, no link is over residual
    capacity and every flow is bottlenecked on its path (max-min holds
    against the NEW capacities)."""
    tree = FatTree(nics_per_server=data.draw(st.integers(1, 4)))
    net = FlowPlane(tree, BackgroundTraffic(data.draw(st.floats(0.0, 0.5))),
                    seed=data.draw(st.integers(0, 999)))
    servers = _servers(tree)
    rng = np.random.default_rng(data.draw(st.integers(0, 999)))
    for _ in range(data.draw(st.integers(1, 8))):
        i, j = rng.choice(len(servers), 2, replace=False)
        net.start_transfer(servers[i], servers[j],
                           float(rng.uniform(1e6, 1e9)), 0.0, lambda t, n: None)
    tier = data.draw(st.integers(1, 3))
    tree.rewire(scale={tier: data.draw(st.sampled_from([0.25, 0.5, 2.0, 4.0]))})
    net.on_rewire(0.001)
    load, resid = net.link_utilization()
    assert np.all(load <= resid * (1 + 1e-9) + 1e-6)
    for f in net.flows.values():
        assert f.rate > 0
        saturated = any(load[l] >= resid[l] * (1 - 1e-9) - 1e-6 for l in f.path)
        assert saturated, f"flow {f.flow_id} not bottlenecked after rewire"


# ------------------------------------------------------------------- oracle
class TestOracleRewireAware:
    @staticmethod
    def _tier_of(a, b):
        return 3

    def test_static_source_reflects_topology_not_paper(self):
        """Regression: an oracle built from a halved-uplink tree must report
        the tree's bandwidths, not the PAPER_TIER_BANDWIDTH constants."""
        halved = {t: (b / 2 if t >= 2 else b)
                  for t, b in PAPER_TIER_BANDWIDTH.items()}
        tree = FatTree(tier_bandwidth=halved)
        oracle = NetworkCostOracle(tier_of=self._tier_of, topology=tree)
        bw = oracle.view(0.0).bandwidth_array()
        assert bw[2] == PAPER_TIER_BANDWIDTH[2] / 2
        assert bw[3] == PAPER_TIER_BANDWIDTH[3] / 2
        assert bw[1] == PAPER_TIER_BANDWIDTH[1]

    def test_rewire_reaches_scheduler_at_next_refresh_only(self):
        tree = FatTree()
        oracle = NetworkCostOracle(tier_of=self._tier_of, topology=tree,
                                   refresh_interval=1.0)
        pre = oracle.view(0.0)
        tree.rewire(scale={3: 0.25})
        stale = oracle.view(0.5)               # within the refresh interval
        assert stale is pre
        assert stale.bandwidth_array()[3] == PAPER_TIER_BANDWIDTH[3]
        fresh = oracle.view(1.5)
        assert fresh.bandwidth_array()[3] == PAPER_TIER_BANDWIDTH[3] * 0.25

    def test_snapshot_immutable_between_refreshes(self):
        """The published snapshot must hold pre-rewire values by copy, not
        track the live dict."""
        tree = FatTree()
        oracle = NetworkCostOracle(tier_of=self._tier_of, topology=tree)
        view = oracle.view(0.0)
        tree.rewire(scale={2: 0.5})
        assert view.tier_bandwidth[2] == PAPER_TIER_BANDWIDTH[2]

    def test_default_construction_copies_paper_constants(self):
        oracle = NetworkCostOracle(tier_of=self._tier_of)
        oracle.tier_bandwidth[3] = 1.0
        assert PAPER_TIER_BANDWIDTH[3] != 1.0   # module constant untouched

    def test_measured_source_across_capacity_swap(self):
        tree = FatTree(n_tor_uplinks=1, n_agg_uplinks=1)
        net = FlowPlane(tree, BackgroundTraffic(0.2), seed=0)
        oracle = NetworkCostOracle(
            tier_of=self._tier_of, topology=tree,
            measured_fn=lambda now: net.measured_tier_congestion(now),
            source="measured", refresh_interval=0.5)
        net.start_transfer((0, 0, 0), (1, 0, 0), 1e12, 0.0, lambda t, n: None)
        before = oracle.view(0.0)
        tree.rewire(scale={2: 0.25, 3: 0.25})
        net.on_rewire(0.1)
        after = oracle.view(1.0)
        for t in TIERS:
            assert 0.0 <= after.congestion[t] < 1.0
        # The saturated uplink stays saturated against the NEW capacity.
        assert after.congestion[3] >= before.congestion[3] - 1e-9
        assert after.tier_bandwidth[3] == PAPER_TIER_BANDWIDTH[3] * 0.25


# ------------------------------------------------------------- end-to-end
class TestSimulatorRewire:
    def _run(self, **cfg_kw):
        from repro.sim import SimConfig, run_sim
        from repro.traces import generate_trace, profile_capacity

        cap = profile_capacity("rag")
        trace = generate_trace("rag", duration=5.0, target_rps=cap, seed=0)
        cfg = SimConfig(scheduler="netkv-full", seed=0, warmup=1.0,
                        measure=3.0, background=0.2, **cfg_kw)
        from repro.sim import Simulation

        sim = Simulation(cfg)
        metrics = sim.run(trace, drain=30.0)
        return sim, metrics

    def test_rewire_schedule_applies(self):
        from repro.sim import RewireEvent

        sim, m = self._run(rewires=[
            RewireEvent(time=2.0, scale={2: 0.25, 3: 0.25}),
            RewireEvent(time=3.5, scale={2: 4.0, 3: 4.0}),
        ])
        assert sim.tree.topo_epoch == 2
        assert sim.tree.tier_bandwidth[3] == PAPER_TIER_BANDWIDTH[3]  # restored
        assert m.n_measured > 0 and np.isfinite(m.ttft_mean)

    def test_degrade_hurts_vs_control(self):
        """A deterministic seed: permanently degrading the uplinks must not
        make transfers faster."""
        from repro.sim import RewireEvent

        _, ctrl = self._run()
        _, deg = self._run(rewires=[
            RewireEvent(time=1.5, scale={2: 0.1, 3: 0.1})])
        assert deg.xfer_mean >= ctrl.xfer_mean

    @pytest.mark.parametrize(
        "policy", ["hash", "least-loaded", "rail-affine", "adaptive"])
    def test_multinic_policies_end_to_end(self, policy):
        _, m = self._run(nics_per_server=4, nic_policy=policy)
        assert m.n_measured > 0 and np.isfinite(m.ttft_mean)


# ----------------------------------------------- vectorised admission unit
class TestVectorisedAdmission:
    def test_batch_admission_tbt_matches_scalar_model(self):
        """One kick admitting k queued requests must assign the same
        TBT-at-entry sequence t_iter(beta+1..beta+k) * scale the per-request
        reference loop produces."""
        from repro.core.cost import H100_TP4_ITER, H100_TP4_PREFILL, LLAMA3_70B_KV
        from repro.core.view import ClusterView
        from repro.sim import EventLoop, InstancePlane, RequestState
        from repro.traces.mooncake import Request

        class Meta:
            def __init__(self, iid, srv):
                self.instance_id, self.server = iid, srv

        view = ClusterView(capacity=1)
        plane = InstancePlane([], [Meta(0, (0, 0, 0))], view=view,
                              loop=EventLoop(), iter_model=H100_TP4_ITER,
                              prefill_model=H100_TP4_PREFILL, beta_max=8,
                              kv_spec=LLAMA3_70B_KV, kv_budget=1e18)
        plane.set_decode_callbacks(None, None)
        plane.d_iter_scale[0] = 1.5
        for rid in range(5):
            req = Request(request_id=rid, arrival=0.0, input_len=32,
                          output_len=4, block_hashes=((rid, 0),),
                          share_group=-1, slo=5.0)
            plane.enqueue(0, RequestState(req=req, kv_bytes=1e6), 0.0)
        plane.kick([0], 0.0)
        got = sorted((rs.req.request_id, rs.tbt)
                     for rs in (plane.r_obj[r] for r in plane._inst_rows[0]))
        want = [(i, H100_TP4_ITER(i + 1) * 1.5) for i in range(5)]
        assert got == want
        assert all(rs.admit_time == 0.0
                   for rs in (plane.r_obj[r] for r in plane._inst_rows[0]))


# ------------------------------------------------------- per-link rewiring
class TestRewireLinks:
    def test_per_link_edit_and_p50_summary(self):
        tree = FatTree()
        lid = int(np.flatnonzero(tree.link_tier == 3)[0])
        before = tree.link_capacity.copy()
        bw_dict = tree.tier_bandwidth          # oracle holds this reference
        epoch0 = tree.topo_epoch
        assert tree.rewire_links([lid], 1e9) == epoch0 + 1
        assert tree.link_capacity[lid] == 1e9
        assert tree.links[lid].capacity == 1e9
        other = np.arange(tree.n_links) != lid
        assert np.array_equal(tree.link_capacity[other], before[other])
        # tier_bandwidth becomes the derived p50 of the per-link table,
        # mutated IN PLACE (the oracle's live reference must see it).
        assert tree.tier_bandwidth is bw_dict
        t3 = tree.link_tier == 3
        assert tree.tier_bandwidth[3] == float(
            np.median(tree.link_capacity[t3]))
        # Degrading a *majority* of tier-3 links moves the p50 itself.
        most = np.flatnonzero(t3)[: int(t3.sum()) // 2 + 1]
        tree.rewire_links(most, 2e9)
        assert tree.tier_bandwidth[3] == 2e9

    def test_validation(self):
        tree = FatTree()
        with pytest.raises(IndexError):
            tree.rewire_links([tree.n_links], 1e9)
        with pytest.raises(ValueError):
            tree.rewire_links([0], 0.0)
        with pytest.raises(ValueError):
            tree.rewire_links([0], np.inf)
        epoch = tree.topo_epoch
        assert tree.rewire_links([], 1e9) == epoch   # no-op, no bump

    def test_survives_other_tier_rewire(self):
        """Tier-level rewires only rewrite their own tiers, so a per-link
        edit elsewhere survives; re-asserting the edited tier resets it."""
        tree = FatTree()
        lid = int(np.flatnonzero(tree.link_tier == 3)[0])
        tree.rewire_links([lid], 1e9)
        tree.rewire(scale={2: 0.5})
        assert tree.link_capacity[lid] == 1e9
        tree.rewire(tier_bandwidth={3: PAPER_TIER_BANDWIDTH[3]})
        assert tree.link_capacity[lid] == PAPER_TIER_BANDWIDTH[3]

    def test_single_uplink_degrade_rewaterfills_dirty_component_only(self):
        """The regression the incremental path exists for: degrading one
        uplink must re-water-fill only the flows crossing it — the
        link-disjoint component in the other pod keeps bit-identical
        rates — and the incremental result must equal a full recompute."""
        tree = FatTree()
        net = FlowPlane(tree, BackgroundTraffic(0.0), seed=0)
        # Two link-disjoint transfers: cross-rack inside pod 0 / pod 1.
        ta = net.start_transfer((0, 0, 0), (0, 1, 0), 1e12, 0.0,
                                lambda t, n: None, n_flows=4)
        tb = net.start_transfer((1, 0, 0), (1, 1, 0), 1e12, 0.0,
                                lambda t, n: None, n_flows=4)
        slots_a = list(net._tslots[ta.transfer_id])
        slots_b = list(net._tslots[tb.transfer_id])
        rates_before = net.f_rate.copy()
        # Degrade the first real hop of A's path (its NIC uplink).
        lid = int(net.f_path[slots_a[0], 0])
        tree.rewire_links([lid], tree.link_capacity[lid] * 0.1)
        seen = []
        orig = net._recompute_rates
        net._recompute_rates = lambda dirty_links=None: (
            seen.append(dirty_links), orig(dirty_links))[1]
        try:
            net.on_rewire_links([lid], 0.0)
        finally:
            net._recompute_rates = orig
        assert len(seen) == 1 and np.array_equal(seen[0], [lid])
        # Untouched component: bit-identical; dirty component: re-filled.
        assert np.array_equal(net.f_rate[slots_b], rates_before[slots_b])
        assert not np.array_equal(net.f_rate[slots_a], rates_before[slots_a])
        # Incremental result == full recompute over the same residuals.
        after = net.f_rate.copy()
        net._recompute_rates(dirty_links=None)
        assert np.array_equal(net.f_rate, after)

    def test_inside_epoch_rejected(self):
        net = FlowPlane(FatTree(), BackgroundTraffic(0.0), seed=0)
        net.begin_epoch()
        with pytest.raises(RuntimeError):
            net.on_rewire_links([0], 0.0)
        net.end_epoch()

    def test_oracle_stale_until_forced(self):
        """A per-link rewire reaches the scheduler only via refresh; the
        notify path (``force_refresh``) delivers it immediately."""
        tree = FatTree()
        oracle = NetworkCostOracle(tree.tier, topology=tree,
                                   refresh_interval=100.0)
        v0 = oracle.view(0.0)
        b3_old = v0.tier_bandwidth[3]
        t3 = np.flatnonzero(tree.link_tier == 3)
        tree.rewire_links(t3, 1e9)
        assert oracle.view(1.0).tier_bandwidth[3] == b3_old   # stale
        v1 = oracle.force_refresh(1.0)
        assert v1.tier_bandwidth[3] == 1e9
        assert oracle.view(2.0) is v1                         # new snapshot
        assert oracle.refreshes == 2

    def test_simulation_notify_rewires_wiring(self):
        from repro.sim.simulator import RewireEvent, SimConfig, Simulation

        for notify, extra in ((False, 0), (True, 1)):
            sim = Simulation(SimConfig(notify_rewires=notify))
            sim.oracle.view(0.0)
            n0 = sim.oracle.refreshes
            sim._on_rewire(RewireEvent(time=0.0, scale={3: 0.5}), 0.0)
            assert sim.oracle.refreshes == n0 + extra
