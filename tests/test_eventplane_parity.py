"""EventPlane vs the reference heap engine: bit-exact end-to-end parity.

Seeded 64- and 256-GPU drives — including faults, OCS rewires, chunked
and streamed prefill — must produce identical request outcomes (every
per-request timestamp, placement and counter) AND identical event order
(the engines' ``trace_log``) under ``event_engine="plane"`` vs
``"reference"``.  Same bar as every prior plane's retirement oracle.
"""

from __future__ import annotations

import pytest

from repro.sim import FaultEvent, RewireEvent, SimConfig, Simulation
from repro.traces import generate_trace

GPU64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2)       # 64 GPUs
GPU256 = dict(n_pods=2, racks_per_pod=8, servers_per_rack=2)      # 256 GPUs


def _drive(engine: str, seed: int, cfg_kw: dict, rps: float = 45.0):
    trace = generate_trace("rag", duration=7.0, target_rps=rps, seed=seed)
    cfg = SimConfig(scheduler="netkv-full", seed=seed, warmup=2.0,
                    measure=4.0, event_engine=engine, **cfg_kw)
    sim = Simulation(cfg)
    sim.loop.trace_log = []
    metrics = sim.run(trace, drain=25.0)
    outcomes = [
        (rs.req.request_id, rs.prefill_instance, rs.prefill_start,
         rs.prefill_end, rs.sched_time, rs.decode_instance, rs.tier,
         rs.s_eff, rs.hit_tokens, rs.first_token, rs.finish, rs.tokens_out,
         rs.rejected, rs.requeues)
        for rs in sim.records
    ]
    return metrics, outcomes, sim.loop.trace_log


def _assert_parity(cfg_kw: dict, seed: int = 0, rps: float = 45.0) -> None:
    m_p, o_p, log_p = _drive("plane", seed, cfg_kw, rps)
    m_r, o_r, log_r = _drive("reference", seed, cfg_kw, rps)
    assert o_p == o_r, "request outcomes diverge between event engines"
    assert log_p == log_r, "event (time, lane) dispatch order diverges"
    assert m_p.ttft_mean == m_r.ttft_mean
    assert m_p.tbt_mean == m_r.tbt_mean


class TestEventEngineParity:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_64gpu_baseline(self, seed):
        _assert_parity(dict(**GPU64, background=0.2), seed=seed)

    def test_64gpu_static_background(self):
        # Static background enables net-tick elision: both engines must
        # elide identically (same grid, same wakes) and stay bit-exact.
        _assert_parity(dict(**GPU64, background=0.0))

    def test_256gpu_baseline(self):
        _assert_parity(dict(**GPU256, background=0.15), rps=60.0)

    def test_64gpu_faults(self):
        faults = [
            FaultEvent(time=3.0, kind="kill_decode", instance_id=4),
            FaultEvent(time=3.5, kind="slowdown", instance_id=6, factor=1.5),
            FaultEvent(time=4.5, kind="add_decode"),
        ]
        _assert_parity(dict(**GPU64, background=0.15, faults=faults))

    def test_64gpu_rewires(self):
        rewires = [
            RewireEvent(time=3.0, scale={2: 0.25, 3: 0.25}),
            RewireEvent(time=5.0, scale={2: 4.0, 3: 4.0}),
        ]
        _assert_parity(dict(**GPU64, background=0.25, rewires=rewires))

    def test_64gpu_chunked_prefill(self):
        _assert_parity(dict(**GPU64, background=0.1, chunk_tokens=512,
                            prefill_token_budget=1024))

    def test_64gpu_streamed_kv(self):
        _assert_parity(dict(**GPU64, background=0.1, chunk_tokens=512,
                            kv_streaming=True))

    def test_256gpu_faults_and_rewires(self):
        faults = [FaultEvent(time=3.2, kind="kill_decode", instance_id=20)]
        rewires = [RewireEvent(time=2.8, scale={3: 0.5}),
                   RewireEvent(time=4.8, scale={3: 2.0})]
        _assert_parity(dict(**GPU256, background=0.2, faults=faults,
                            rewires=rewires), rps=60.0)


class TestNetTickElision:
    """net_tick_mode="auto" may only skip ticks that are provably no-ops:
    outcomes must match the keep-every-tick mode exactly."""

    def test_auto_matches_always(self):
        m_a, o_a, _ = _drive("plane", 0, dict(**GPU64, background=0.0,
                                              net_tick_mode="auto"))
        m_b, o_b, _ = _drive("plane", 0, dict(**GPU64, background=0.0,
                                              net_tick_mode="always"))
        assert o_a == o_b
        assert m_a.ttft_mean == m_b.ttft_mean

    def test_auto_elides_idle_ticks(self):
        _, _, log_a = _drive("plane", 0, dict(**GPU64, background=0.0,
                                              net_tick_mode="auto"))
        _, _, log_b = _drive("plane", 0, dict(**GPU64, background=0.0,
                                              net_tick_mode="always"))
        from repro.sim.engine import LANE_TICK
        ticks_a = sum(1 for _, lane in log_a if lane == LANE_TICK)
        ticks_b = sum(1 for _, lane in log_b if lane == LANE_TICK)
        assert ticks_a < ticks_b   # idle grid points actually skipped

    def test_wandering_background_never_elides(self):
        # wander > 0 with nonzero base utilisation: rates drift between
        # ticks, so "auto" must keep every tick.
        kw = dict(**GPU64, background=0.2)   # default bg_wander=0.25
        _, _, log_a = _drive("plane", 0, dict(**kw, net_tick_mode="auto"))
        _, _, log_b = _drive("plane", 0, dict(**kw, net_tick_mode="always"))
        assert log_a == log_b
