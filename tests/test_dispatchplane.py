"""DispatchPlane: cohort-batched selection vs sequential ``select`` calls.

Three layers of the same bit-exactness bar every plane has met:

* unit/property — a ``CohortSelector.select_row`` walk over a fuzzed cohort
  (mixed streamed/serial rows, shared prefill sources, infeasible rows)
  must reproduce the sequential ``select`` stream exactly, *including* the
  RNG tie-break draws, the round-robin cursor and the self-contention
  counters;
* kernel — ``netkv_score_cohort`` rows vs single-row ``netkv_score`` calls
  (the r==1-padded shared program) and the pallas-backend selector;
* end-to-end — ``SimConfig.dispatch_mode="plane"`` vs ``"reference"`` on
  seeded drives where same-timestamp cohorts demonstrably form, for every
  ladder policy, plus chunked/streamed prefill, faults and rewires.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    CandidateState,
    ClusterView,
    CohortItem,
    H100_TP4_ITER,
    RequestInfo,
    SelfContentionTracker,
    make_scheduler,
    supports_cohort,
)
from repro.core.oracle import (
    OracleView,
    PAPER_TIER_BANDWIDTH,
    PAPER_TIER_LATENCY,
)
from repro.sim import FaultEvent, RewireEvent, SimConfig, Simulation
from repro.traces.mooncake import Request

from hypothesis_compat import given, settings, st

LADDER8 = ["rr", "la", "ca", "cla",
           "netkv-topo", "netkv-static", "netkv-full", "netkv-pred"]


# --------------------------------------------------------------------------
# unit / property layer
# --------------------------------------------------------------------------
def _pool(n: int, seed: int, tight: bool = False):
    """Candidates + oracle view; ``tight`` draws free memory low enough
    that some (sometimes all) candidates are infeasible for a multi-GiB
    s_eff, exercising the None-row / no-draw path."""
    rng = np.random.default_rng(seed)
    lo, hi = (0.0, 1.6e10) if tight else (1e10, 4e11)
    cands = [
        CandidateState(i, float(rng.uniform(lo, hi)),
                       int(rng.integers(0, 8)), int(rng.integers(0, 64)),
                       0.0)
        for i in range(n)
    ]
    tiers = rng.integers(0, 4, n)
    view = OracleView(lambda p, d: int(tiers[d % n]), PAPER_TIER_BANDWIDTH,
                      PAPER_TIER_LATENCY, {t: 0.2 for t in range(4)})
    return cands, view


def _cohort(r: int, n: int, seed: int, streamed: bool):
    """R dispatch-ready requests: random prefix hits (including overshoot
    past input_len, which v_s_eff clips), shared prefill sources, and —
    when ``streamed`` — a mix of serial / tail-less / tailed rows."""
    rng = np.random.default_rng(seed ^ 0x5EED)
    items = []
    for k in range(r):
        l = int(rng.integers(1, 16384))
        req = RequestInfo(k, l, float(l) * 320 * 1024)
        if streamed and rng.random() < 0.6:
            req.prefill_remaining = float(rng.uniform(0.0, 0.5))
            if rng.random() < 0.5:
                req.tail_bytes = float(rng.uniform(0, 1.5) * req.kv_bytes)
        items.append(CohortItem(req, int(rng.integers(0, 6))))
    H = rng.uniform(0, 1.25, (r, n)) * np.array(
        [it.req.input_len for it in items], np.float64)[:, None]
    return items, H


def _run_sequential(sched, cv, view, items, H, infl):
    out = []
    for k, it in enumerate(items):
        cv.hit_tokens[: cv.n] = H[k]
        d = sched.select(it.req, it.prefill_id, cv, view, infl)
        out.append(d)
        if d is not None:
            cv.apply_assignment(cv.slot_of(d.instance_id), kv_bytes=d.s_eff)
    return out


def _run_cohort(sched, cv, view, items, H, infl):
    sel = sched.select_cohort(items, cv, view, infl, hit_matrix=H.copy())
    out = []
    for k in range(len(items)):
        d = sel.select_row(k)
        out.append(d)
        if d is not None:
            cv.apply_assignment(cv.slot_of(d.instance_id), kv_bytes=d.s_eff)
    return out


def _assert_walk_parity(name, r, n, seed, *, tight=False, streamed=False,
                        backend=None):
    cands, view = _pool(n, seed, tight)
    items, H = _cohort(r, n, seed, streamed)
    kw = {"backend": backend} if backend else {}
    results, state = [], []
    for runner in (_run_sequential, _run_cohort):
        cv = ClusterView.from_candidates(cands, tier_fn=view.tier_of)
        sched = make_scheduler(name, H100_TP4_ITER, 64, seed=seed, **kw)
        assert supports_cohort(sched)
        infl = SelfContentionTracker()
        results.append(runner(sched, cv, view, items, H, infl))
        state.append((
            sched._rng.bit_generator.state,          # tie-break stream
            getattr(sched, "_next", None),           # rr cursor
            dict(infl._counts),                      # self-contention
            cv.free_memory[: cv.n].tolist(),         # reserved memory
        ))
    seq, coh = results
    assert seq == coh, f"{name}: decisions diverge"
    assert state[0] == state[1], f"{name}: scheduler/view state diverges"


class TestCohortWalkParity:
    @pytest.mark.parametrize("name", LADDER8)
    def test_serial_cohort(self, name):
        _assert_walk_parity(name, r=9, n=48, seed=1)

    @pytest.mark.parametrize("name", ["netkv-full", "netkv-pred"])
    def test_streamed_cohort(self, name):
        _assert_walk_parity(name, r=9, n=48, seed=2, streamed=True)

    @pytest.mark.parametrize("name", LADDER8)
    def test_tight_memory_none_rows(self, name):
        # Infeasible rows return None and must not draw from the RNG.
        _assert_walk_parity(name, r=12, n=16, seed=3, tight=True)

    def test_singleton_cohort(self):
        _assert_walk_parity("netkv-full", r=1, n=48, seed=4)

    def test_rejects_unsupported_scheduler(self):
        from repro.core.batch_assign import NetKVBatch

        sched = NetKVBatch(H100_TP4_ITER, 64)
        assert not supports_cohort(sched)

    @given(data=st.data())
    @settings(max_examples=30, deadline=None)
    def test_fuzz_cohort_composition(self, data):
        name = data.draw(st.sampled_from(LADDER8))
        r = data.draw(st.integers(min_value=1, max_value=10))
        n = data.draw(st.integers(min_value=4, max_value=40))
        seed = data.draw(st.integers(min_value=0, max_value=2**20))
        tight = data.draw(st.booleans())
        streamed = data.draw(st.booleans())
        _assert_walk_parity(name, r, n, seed, tight=tight, streamed=streamed)


# --------------------------------------------------------------------------
# kernel layer
# --------------------------------------------------------------------------
def _kernel_args(r: int, n: int, seed: int):
    rng = np.random.default_rng(seed)
    pool = dict(
        free_mem=rng.uniform(1e9, 4e11, n).astype(np.float32),
        queued=rng.integers(0, 8, n).astype(np.float32),
        batch=rng.integers(0, 64, n).astype(np.float32),
        healthy=(rng.random(n) > 0.1).astype(np.float32),
        iter_scale=rng.uniform(1.0, 2.0, n).astype(np.float32),
    )
    lens = rng.integers(256, 8192, r)
    rows = dict(
        hit_rows=(rng.uniform(0, 1.2, (r, n)) * lens[:, None]).astype(
            np.float32),
        tier_rows=rng.integers(0, 4, (r, n)).astype(np.int32),
        infl_rows=rng.integers(0, 5, (r, 4)).astype(np.float32),
        s_r=[float(l) * 320 * 1024 for l in lens],
        input_len=[float(l) for l in lens],
    )
    scal = dict(
        tier_bw=[PAPER_TIER_BANDWIDTH[t] for t in range(4)],
        tier_lat=[PAPER_TIER_LATENCY[t] for t in range(4)],
        congestion=[0.1 * t for t in range(4)],
        iter_a=H100_TP4_ITER.a, iter_b=H100_TP4_ITER.b,
        m_min=2.0 * 1024**3, beta_max=64,
    )
    return pool, rows, scal


class TestCohortKernel:
    def test_cohort_rows_match_single_row_kernel(self):
        from repro.kernels.netkv_score import netkv_score, netkv_score_cohort

        r, n = 5, 24
        pool, rows, scal = _kernel_args(r, n, seed=11)
        costs, best = netkv_score_cohort(
            **pool, **rows, **scal, interpret=True)
        costs = np.asarray(costs)
        best = np.asarray(best)
        for i in range(r):
            c1, b1 = netkv_score(
                pool["free_mem"], pool["queued"], pool["batch"],
                rows["hit_rows"][i], rows["tier_rows"][i], pool["healthy"],
                pool["iter_scale"], scal["tier_bw"], scal["tier_lat"],
                scal["congestion"], rows["infl_rows"][i],
                s_r=rows["s_r"][i], input_len=rows["input_len"][i],
                iter_a=scal["iter_a"], iter_b=scal["iter_b"],
                m_min=scal["m_min"], beta_max=scal["beta_max"],
                interpret=True)
            assert np.array_equal(costs[i], np.asarray(c1)), f"row {i}"
            assert int(best[i]) == int(b1), f"row {i} argmin"

    def test_numpy_twin_matches_kernel(self):
        from repro.kernels.netkv_score import netkv_score_cohort

        pool, rows, scal = _kernel_args(4, 24, seed=12)
        c_k, b_k = netkv_score_cohort(**pool, **rows, **scal, interpret=True)
        c_n, b_n = netkv_score_cohort(**pool, **rows, **scal, numpy=True)
        assert np.array_equal(np.asarray(c_k), np.asarray(c_n))
        assert np.array_equal(np.asarray(b_k), np.asarray(b_n))

    def test_pallas_backend_cohort_walk(self):
        # The pallas-backed CohortSelector precomputes serial rows through
        # the cohort-axis kernel; the walk must still match the sequential
        # pallas select stream exactly (shared XLA program).
        _assert_walk_parity("netkv-full", r=4, n=24, seed=5,
                            backend="pallas")

    def test_pallas_backend_mixed_streamed(self):
        # Streamed rows bypass the kernel inside one cohort; serial rows
        # around them must keep their precomputed kernel scores valid.
        _assert_walk_parity("netkv-full", r=5, n=24, seed=6, streamed=True,
                            backend="pallas")


# --------------------------------------------------------------------------
# end-to-end layer: dispatch_mode="plane" vs "reference"
# --------------------------------------------------------------------------
GPU64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2)       # 64 GPUs
GPU256 = dict(n_pods=2, racks_per_pod=8, servers_per_rack=2)      # 256 GPUs


def _burst_trace(bursts: int = 12, width: int = 4):
    """Same-arrival bursts whose prefills finish at the same instant on
    idle instances — the shape that actually forms serial dispatch
    cohorts (Poisson arrivals rarely collide at float timestamps)."""
    trace, rid = [], 0
    for b in range(bursts):
        t = 0.1 + 0.4 * b
        for i in range(width):
            hashes = tuple(f"b{b}-{i}-{j}" for j in range(8))
            trace.append(Request(rid, t, 1024, 64, hashes, rid, 1.0))
            rid += 1
    return trace


def _drive(mode: str, sched: str, trace, seed: int = 3, **kw):
    cfg = SimConfig(scheduler=sched, dispatch_mode=mode, warmup=0.5,
                    measure=4.0, seed=seed, **kw)
    sim = Simulation(cfg)
    sim.loop.trace_log = []
    sizes = []
    if mode == "plane":
        orig = sim._cohort_selector
        sim._cohort_selector = lambda items, reqs, now: (
            sizes.append(len(items)), orig(items, reqs, now))[1]
    sim.run(trace, drain=10.0)
    outs = [
        (rs.req.request_id, rs.prefill_instance, rs.decode_instance, rs.tier,
         rs.s_eff, rs.rejected, rs.requeues, rs.prefill_end,
         rs.transfer_end, rs.first_token, rs.finish, rs.tokens_out,
         rs.hit_tokens, rs.sched_time)
        for rs in sim.records
    ]
    return outs, sim.loop.trace_log, sizes


def _assert_e2e_parity(sched: str, trace=None, min_cohort: int = 2,
                       seed: int = 3, **kw):
    trace = _burst_trace() if trace is None else trace
    o_p, l_p, sizes = _drive("plane", sched, trace, seed=seed, **kw)
    o_r, l_r, _ = _drive("reference", sched, trace, seed=seed, **kw)
    assert o_p == o_r, f"{sched}: outcomes diverge"
    assert l_p == l_r, f"{sched}: (time, lane) dispatch order diverges"
    # Guard against vacuous parity: the plane run must have actually
    # batched at least one multi-request cohort.
    assert sizes and max(sizes) >= min_cohort, \
        f"{sched}: no multi-request cohort formed (sizes={sizes[:8]}...)"


class TestDispatchModeParity:
    @pytest.mark.parametrize("sched", LADDER8)
    def test_64gpu_serial_bursts(self, sched):
        _assert_e2e_parity(sched, **GPU64)

    def test_64gpu_chunked_prefill(self):
        # Wider bursts stack several streams per prefill instance so
        # phase-3 (dispatch-ready) cohorts actually form.
        _assert_e2e_parity("netkv-full", trace=_burst_trace(8, 12),
                           **GPU64, chunk_tokens=512,
                           prefill_token_budget=1024)

    def test_64gpu_streamed_kv(self):
        _assert_e2e_parity("netkv-full", trace=_burst_trace(8, 12),
                           **GPU64, chunk_tokens=512,
                           prefill_token_budget=1024, kv_streaming=True)

    def test_64gpu_faults_and_rewires(self):
        faults = [FaultEvent(time=1.5, kind="kill_decode", instance_id=4),
                  FaultEvent(time=2.5, kind="add_decode")]
        rewires = [RewireEvent(time=2.0, scale={2: 0.25, 3: 0.25})]
        _assert_e2e_parity("netkv-full", **GPU64, faults=faults,
                           rewires=rewires)

    def test_64gpu_reference_event_engine(self):
        # Cohorts must also form (and stay bit-exact) on the legacy heap
        # event engine — drain_due is implemented on both.
        _assert_e2e_parity("netkv-full", **GPU64, event_engine="reference")

    def test_256gpu_netkv_full(self):
        _assert_e2e_parity("netkv-full", trace=_burst_trace(10, 6),
                           **GPU256)

    def test_unsupported_scheduler_falls_back(self):
        # netkv-batch has no cohort path: plane mode silently degrades to
        # per-request dispatch and must equal reference exactly.
        trace = _burst_trace(6, 3)
        o_p, l_p, sizes = _drive("plane", "netkv-batch", trace, **GPU64)
        o_r, l_r, _ = _drive("reference", "netkv-batch", trace, **GPU64)
        assert o_p == o_r and l_p == l_r
        assert not sizes

    def test_invalid_dispatch_mode_rejected(self):
        with pytest.raises(ValueError):
            Simulation(SimConfig(scheduler="rr", dispatch_mode="bogus",
                                 **GPU64))
