"""Multi-pod dry-run integration: one real cell per step kind, in a
subprocess (the dry-run forces 512 host devices; tests stay at 1)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run_cell(arch, shape, mesh, tmpdir):
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmpdir)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=540,
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    with open(os.path.join(str(tmpdir), f"{arch}__{shape}__{mesh}.json")) as f:
        return json.load(f)


@pytest.mark.parametrize("shape,mesh", [
    ("train_4k", "pod"),        # train step, 256 chips
    ("prefill_32k", "pod"),     # prefill, 256 chips
    ("decode_32k", "multipod"),  # decode, 512 chips (proves the pod axis)
])
def test_smollm_cells_compile(shape, mesh, tmp_path):
    rec = _run_cell("smollm-135m", shape, mesh, tmp_path)
    assert rec["status"] == "ok", rec.get("error")
    assert rec["n_devices"] == (512 if mesh == "multipod" else 256)
    # all three roofline inputs present
    assert rec["memory"].get("argument_size_in_bytes", 0) > 0
    assert rec["cost"].get("flops", 0) > 0
    assert "collectives_loop_aware" in rec
    # loop-aware accounting never undercounts the raw parse
    assert rec["collectives_loop_aware"]["total_bytes"] >= \
        rec["collectives"]["total_bytes"] * 0.5  # raw uses operand fallbacks


def test_skip_cell_recorded(tmp_path):
    rec = _run_cell("smollm-135m", "long_500k", "pod", tmp_path)
    assert rec["status"] == "skipped"
    assert "full attention" in rec["reason"]


def test_long_500k_compiles_for_ssm(tmp_path):
    rec = _run_cell("rwkv6-3b", "long_500k", "pod", tmp_path)
    assert rec["status"] == "ok", rec.get("error")
    m = rec["memory"]
    per_dev = (m["argument_size_in_bytes"] + m["temp_size_in_bytes"]
               + m["output_size_in_bytes"] - m.get("alias_size_in_bytes", 0))
    assert per_dev < 16e9  # O(1)-state decode fits trivially
