"""RolePlane: role-columned instances, prefill deflection, P:D flipping.

Covers the PR-10 tentpole and its satellites:

* role-flip parity drives — full ``Simulation`` runs with the LANE_ROLE
  slow loop converting instances prefill<->decode mid-trace must replay
  bit-exactly on the plane vs reference instance engines, under both
  event engines (the flips themselves, driven by the parity-proven
  prefill-backlog signal, land at identical instants on both arms),
* ``kill_prefill`` / ``add_prefill`` fault kinds with requeue semantics
  for in-flight (chunked) prefill,
* prefill deflection — storm smoke (nonzero deflected fraction, TTFT no
  worse than undeflected), configuration refusals, and the zero-deflection
  bit-exactness of the default config,
* ``DeflectedCohortSelector`` vs the sequential ``select_deflected``
  ladder: decisions AND RNG tie draws bit-identical,
* deflected-prefill compute telescopes to the monolithic ``c*l + d``
  (hypothesis property over chunk/budget/length mixes),
* per-role utilization + deflected-fraction metrics columns (NaN-safe).
"""

import math

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.cost import (
    H100_TP4_ITER,
    H100_TP4_PREFILL,
    LLAMA3_70B_KV,
    deflected_cost,
)
from repro.core.dispatch import DeflectedCohortSelector
from repro.core.schedulers import RequestInfo, make_scheduler
from repro.core.view import ClusterView, ROLE_DECODE, ROLE_PREFILL
from repro.sim import (
    EventLoop,
    FaultEvent,
    InstancePlane,
    RequestState,
    SimConfig,
    Simulation,
)
from repro.sim.metrics import aggregate_seeds, summarize
from repro.traces import generate_trace
from repro.traces.mooncake import Request

# Thin prefill pool on the 64-GPU tree: prefill-bottlenecked, so backlog
# crosses the flip/deflection thresholds under a storm.
TREE = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2, n_prefill=2)
STORM_RPS = 6.0


def _trace(seed, duration=5.0, rps=STORM_RPS):
    return generate_trace("rag", duration=duration, target_rps=rps, seed=seed)


def _run(engine, seed=0, faults=(), event_engine="plane", **kw):
    kw.setdefault("background", 0.2)
    kw.setdefault("chunk_tokens", 2048)
    kw.setdefault("prefill_token_budget", 4096)
    cfg = SimConfig(scheduler="netkv-full", seed=seed, warmup=1.0,
                    measure=3.0, instance_engine=engine, faults=faults,
                    event_engine=event_engine, **TREE, **kw)
    sim = Simulation(cfg)
    sim.run(_trace(seed), drain=40.0)
    return sim


def _outcomes(sim):
    recs = [
        (r.req.request_id, r.prefill_instance, r.prefill_start, r.prefill_end,
         r.sched_time, r.decode_instance, r.tier, r.s_eff, r.hit_tokens,
         r.transfer_end, r.admit_time, r.first_token, r.finish, r.tbt,
         r.tokens_out, r.rejected, r.requeues, r.deflected)
        for r in sim.records
    ]
    finish_order = sorted(
        (r.finish, r.req.request_id) for r in sim.records if r.finish >= 0
    )
    return recs, finish_order, sim.engine.cache_stats()


def _assert_parity(a, b):
    ra, fa, ca = _outcomes(a)
    rb, fb, cb = _outcomes(b)
    assert ra == rb
    assert fa == fb
    assert ca == cb


FLIP_KW = dict(role_flip_interval=0.25, role_flip_sustain=2,
               role_flip_hi=0.2, role_flip_lo=0.05)


class TestRoleFlipParity:
    @pytest.mark.parametrize("event_engine", ["plane", "reference"])
    def test_flip_parity_chunked(self, event_engine):
        """Mid-trace decode->prefill (and back) conversions must replay
        bit-exactly on both instance engines, under both event engines."""
        a = _run("plane", event_engine=event_engine, **FLIP_KW)
        b = _run("reference", event_engine=event_engine, **FLIP_KW)
        assert a.role_flips > 0          # the loop actually converted
        assert a.role_flips == b.role_flips
        _assert_parity(a, b)

    def test_flip_parity_serial(self):
        """Serial (non-chunked) prefill: flips route through the
        PrefillSim/pick_prefill path instead of the ChunkPlane."""
        a = _run("plane", chunk_tokens=None, prefill_token_budget=None,
                 **FLIP_KW)
        b = _run("reference", chunk_tokens=None, prefill_token_budget=None,
                 **FLIP_KW)
        assert a.role_flips > 0
        assert a.role_flips == b.role_flips
        _assert_parity(a, b)

    def test_flip_back_occurs(self):
        """With a post-storm quiet tail the controller must return at
        least one convert to decode duty (both directions exercised)."""
        sim = _run("plane", **FLIP_KW)
        # flips counts both directions; _flipped holds unreturned converts.
        assert sim.role_flips > len(sim._flipped)

    def test_trace_spans(self):
        sim = _run("plane", trace=True, deflection="on",
                   deflect_threshold=0.3, **FLIP_KW)
        kinds = {s[0] for s in sim.trace.spans()}
        assert "role_flip" in kinds
        assert "deflect" in kinds


class TestPrefillFaults:
    FAULTS = (
        FaultEvent(time=1.4, kind="kill_prefill", instance_id=0),
        FaultEvent(time=1.9, kind="add_prefill"),
    )

    @pytest.mark.parametrize("chunked", [True, False])
    def test_kill_add_prefill_parity(self, chunked):
        kw = {} if chunked else dict(chunk_tokens=None,
                                     prefill_token_budget=None)
        a = _run("plane", faults=self.FAULTS, **kw)
        b = _run("reference", faults=self.FAULTS, **kw)
        _assert_parity(a, b)
        # In-flight prefill work on the killed instance was requeued.
        assert sum(r.requeues for r in a.records) > 0

    def test_kill_prefill_requeue_semantics(self):
        """Victims re-enter through the arrival gate and eventually land
        on a surviving prefill instance (or the elastic join)."""
        sim = _run("plane", faults=self.FAULTS)
        requeued = [r for r in sim.records if r.requeues > 0]
        assert requeued
        for r in requeued:
            if r.finish >= 0:
                assert r.prefill_instance != 0


class TestDeflection:
    def test_storm_smoke(self):
        on = _run("plane", deflection="on", deflect_threshold=0.3)
        off = _run("plane")
        assert on.deflected > 0
        assert any(r.deflected for r in on.records)
        # Deflected requests carry the collapsed Eq. (4): born-local KV.
        for r in on.records:
            if r.deflected and r.finish >= 0:
                assert r.tier == 0 and r.s_eff == 0.0
                assert r.prefill_instance == r.decode_instance
        assert off.deflected == 0
        assert not any(r.deflected for r in off.records)

    def test_default_off_is_noop(self):
        """deflection="off" must not perturb the engine or the RNG
        stream: identical outcomes to a config that never knew about
        deflection (guards the default-path bit-exactness claim)."""
        a = _run("plane")
        b = _run("plane", deflection="off")
        _assert_parity(a, b)

    def test_refusals(self):
        base = dict(scheduler="netkv-full", **TREE)
        with pytest.raises(ValueError, match="plane instance engine"):
            Simulation(SimConfig(deflection="on", chunk_tokens=2048,
                                 instance_engine="reference", **base))
        with pytest.raises(ValueError, match="chunk_tokens"):
            Simulation(SimConfig(deflection="on", **base))
        with pytest.raises(ValueError, match="kv_streaming"):
            Simulation(SimConfig(deflection="on", chunk_tokens=2048,
                                 kv_streaming=True, **base))
        with pytest.raises(ValueError, match="deflection"):
            Simulation(SimConfig(deflection="maybe", **base))
        with pytest.raises(ValueError, match="chunk_autotune"):
            Simulation(SimConfig(chunk_autotune=True, **base))


class TestAutotuneParity:
    def test_autotune_parity(self):
        """The EWMA retune sequence is driven by the arrival stream alone,
        so both instance engines see identical chunking timelines."""
        a = _run("plane", chunk_autotune=True)
        b = _run("reference", chunk_autotune=True)
        assert a._chunk_cur != a.cfg.chunk_tokens  # the controller retuned
        assert a._chunk_cur == b._chunk_cur
        _assert_parity(a, b)


class TestDeflectedCohortSelector:
    def _view(self, n=8, seed=0):
        rng = np.random.default_rng(seed)
        cv = ClusterView(capacity=n)
        for i in range(n):
            cv.add_instance(
                i, free_memory=float(rng.uniform(2e9, 80e9)),
                queued=int(rng.integers(0, 6)), batch=int(rng.integers(0, 32)),
                healthy=bool(rng.random() > 0.1),
                iter_scale=float(rng.uniform(1.0, 2.0)),
                role=ROLE_DECODE if rng.random() > 0.2 else ROLE_PREFILL)
        return cv

    def test_matches_sequential_ladder(self):
        """select_row(0..R-1) vs fresh select_deflected calls against a
        hand-evolved view: decisions and RNG tie draws bit-identical."""
        model = H100_TP4_PREFILL
        rng = np.random.default_rng(7)
        reqs = [RequestInfo(r, int(rng.integers(64, 16384)),
                            float(rng.uniform(1e8, 30e9)))
                for r in range(12)]
        for seed in (0, 1):
            cv_a, cv_b = self._view(seed=seed), self._view(seed=seed)
            eta0 = np.asarray(np.random.default_rng(seed + 9).uniform(
                0.0, 2.0, cv_a.n))
            sched_a = make_scheduler("netkv-full", H100_TP4_ITER, 64, seed=3)
            sched_b = make_scheduler("netkv-full", H100_TP4_ITER, 64, seed=3)
            sel = DeflectedCohortSelector(sched_a, reqs, cv_a, eta0, model)
            eta = eta0.copy()
            for k, req in enumerate(reqs):
                da = sel.select_row(k)
                db = sched_b.select_deflected(req, cv_b, eta)
                assert (da is None) == (db is None)
                if da is None:
                    continue
                assert (da.instance_id, da.cost, da.s_eff, da.tier) == \
                       (db.instance_id, db.cost, db.s_eff, db.tier)
                j = cv_b.slot_of(db.instance_id)
                # The live engine's evolution between sequential calls:
                # ChunkPlane ETA fold + reserve-time pin.
                eta[j] += model.c * req.input_len + model.d
                cv_b.free_memory[j] = max(
                    cv_b.free_memory[j] - req.kv_bytes, 0.0)
            # Both RNG streams drew identically (same number of ties).
            assert sched_a._rng.random() == sched_b._rng.random()


class _Meta:
    def __init__(self, iid, srv):
        self.instance_id, self.server = iid, srv


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_deflected_telescoping(data):
    """Deflected-prefill compute on a decode host telescopes to the
    monolithic ``c*l + d`` per request — the same conservation law the
    main ChunkPlane obeys, now through the attachable deflect plane."""
    chunk = data.draw(st.integers(16, 2048), label="chunk")
    budget = data.draw(st.one_of(st.none(), st.integers(16, 8192)),
                       label="budget")
    lens = data.draw(st.lists(st.integers(1, 6000), min_size=1, max_size=6),
                     label="lens")
    model = H100_TP4_PREFILL
    loop = EventLoop()
    view = ClusterView(capacity=4)
    eng = InstancePlane([_Meta(0, (0, 0, 0))], [_Meta(1, (0, 0, 1))],
                        view=view, loop=loop, iter_model=H100_TP4_ITER,
                        prefill_model=model, beta_max=64,
                        kv_spec=LLAMA3_70B_KV, kv_budget=1e18,
                        chunk_tokens=chunk, prefill_token_budget=budget)
    eng.enable_deflection()
    got = []
    eng.on_deflect_done = lambda rs, now: got.append(rs)
    rss = [
        RequestState(
            req=Request(request_id=i, arrival=0.0, input_len=l, output_len=4,
                        block_hashes=((i, 0),), share_group=-1, slo=5.0),
            kv_bytes=1.0)
        for i, l in enumerate(lens)
    ]
    t0 = float(eng.deflect_eta_row(0.0)[view.slot_of(1)])
    assert t0 == 0.0                       # idle host: no deflect backlog
    for rs in rss:
        eng.submit_deflected(1, rs, 0.0)
    loop.run()
    assert len(got) == len(rss)
    assert all(rs.deflected for rs in rss)
    if len(rss) == 1:
        rs, l = rss[0], lens[0]
        assert rs.prefill_end - rs.prefill_start == pytest.approx(
            model.c * l + model.d, rel=1e-9)
    makespan = max(rs.prefill_end for rs in rss)
    assert makespan == pytest.approx(
        model.c * sum(lens) + model.d * len(lens), rel=1e-9)
    assert eng.deflect_busy_s == pytest.approx(makespan, rel=1e-9)


class TestRoleMetrics:
    def test_utilization_columns(self):
        sim = _run("plane", deflection="on", deflect_threshold=0.3)
        # Re-summarize from the finished state (run() already returned).
        m = summarize(sim.records, window=(1.0, 4.0), scheduler="netkv-full")
        assert math.isfinite(m.deflected_frac)
        assert m.deflected_frac >= 0.0

    def test_run_reports_utilization(self):
        cfg = SimConfig(scheduler="netkv-full", seed=0, warmup=1.0,
                        measure=3.0, background=0.2, chunk_tokens=2048,
                        prefill_token_budget=4096, **TREE)
        m = Simulation(cfg).run(_trace(0), drain=40.0)
        assert 0.0 < m.prefill_util <= 1.0
        assert 0.0 < m.decode_util <= 1.0
        assert m.deflected_frac == 0.0

    def test_nan_safe_empty_window(self):
        m = summarize([], window=(0.0, 1.0), scheduler="x")
        assert math.isnan(m.deflected_frac)
        assert math.isnan(m.prefill_util) and math.isnan(m.decode_util)
        agg = aggregate_seeds([m])
        assert math.isnan(agg["deflected_frac"])
