"""Multi-hop KV routing (beyond paper, §VII-D): staged-fetch planning."""

import pytest

from repro.core import CandidateState, H100_TP4_ITER, RequestInfo
from repro.core.multihop import NetKVMultiHop, StagingStore
from repro.core.oracle import OracleView, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY


def _view(cong=None):
    # prefill 0; decode 1 (tier 2), decode 2 (tier 3); store 100 near decode 2
    tiers = {(0, 1): 2, (0, 2): 3, (100, 1): 3, (100, 2): 1}
    return OracleView(
        tier_of=lambda a, b: tiers.get((a, b), 3),
        tier_bandwidth=PAPER_TIER_BANDWIDTH,
        tier_latency=PAPER_TIER_LATENCY,
        congestion=cong or {t: 0.0 for t in range(4)},
    )


REQ = RequestInfo(7, 8192, 8192 * 320 * 1024)
HASHES = tuple(("g", 0, j) for j in range(8192 // 16))


def _sched(stores):
    s = NetKVMultiHop(H100_TP4_ITER, 64, m_min=1e9, stores=stores)
    s.observe_request(HASHES)
    return s


def test_cold_store_behaves_like_netkv_full():
    s = _sched([StagingStore(100, capacity_bytes=1e12)])
    d = s.select(REQ, 0, [CandidateState(1, 4e11, 0, 4, 0.0),
                          CandidateState(2, 4e11, 0, 4, 0.0)], _view())
    assert s.plans[REQ.request_id].kind == "direct"
    assert d.tier == 2  # same-pod wins as in plain NetKV


def test_warm_store_enables_staged_fetch():
    store = StagingStore(100, capacity_bytes=1e12)
    store.insert(HASHES)  # full prefix resident near decode 2
    s = _sched([store])
    cands = [CandidateState(1, 4e11, 0, 4, 0.0), CandidateState(2, 4e11, 0, 4, 0.0)]
    d = s.select(REQ, 0, cands, _view())
    plan = s.plans[REQ.request_id]
    # decode 2 fetches the whole payload from the same-rack store (tier 1)
    assert plan.kind == "staged" and plan.store_id == 100
    assert d.instance_id == 2
    assert plan.staged_bytes > 0 and plan.direct_bytes == 0


def test_partial_hit_splits_legs():
    store = StagingStore(100, capacity_bytes=1e12)
    store.insert(HASHES[: len(HASHES) // 2])
    s = _sched([store])
    cands = [CandidateState(2, 4e11, 0, 4, 0.0)]
    d = s.select(REQ, 0, cands, _view())
    plan = s.plans[REQ.request_id]
    assert plan.kind == "staged"
    assert plan.staged_bytes > 0 and plan.direct_bytes > 0
    assert abs(plan.staged_bytes + plan.direct_bytes - REQ.kv_bytes) < 1e-3 * REQ.kv_bytes


def test_dram_bandwidth_caps_staged_leg():
    fast = StagingStore(100, capacity_bytes=1e12, dram_bw=1e12)
    slow = StagingStore(100, capacity_bytes=1e12, dram_bw=1e8)  # 100 MB/s
    fast.insert(HASHES)
    slow.insert(HASHES)
    t_fast = _sched([fast]).select(REQ, 0, [CandidateState(2, 4e11, 0, 4, 0.0)], _view())
    t_slow = _sched([slow]).select(REQ, 0, [CandidateState(2, 4e11, 0, 4, 0.0)], _view())
    assert t_fast.est_transfer_time < t_slow.est_transfer_time


def test_store_lru_eviction():
    store = StagingStore(100, capacity_bytes=3 * store_bpb() if False else 3 * (16 * 320 * 1024 / 4))
    store.insert([1, 2, 3, 4])
    assert store.hit_blocks([1]) == 0  # 1 evicted (LRU)
    assert store.hit_blocks([2, 3, 4]) == 3


def store_bpb():
    return 16 * 320 * 1024 / 4
