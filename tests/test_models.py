"""Model-zoo tests: per-arch smoke (forward+train step on CPU, shapes +
no-NaN) and decode-vs-full-forward parity for every block family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL, get_spec
from repro.models import (
    decode_step,
    encode,
    forward_logits,
    forward_train,
    init_params,
    param_count,
    param_specs,
    prefill,
)
from repro.train import make_optimizer, make_train_step, synth_batch


@pytest.mark.parametrize("arch", ALL)
def test_arch_smoke_forward_and_train_step(arch):
    """REDUCED config of the same family: one forward + one train step."""
    spec = get_spec(arch)
    cfg = spec.smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, global_batch=4, seq_len=32, seed=0, step=0)
    loss, parts = jax.jit(lambda p, b: forward_train(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    # one optimizer step
    opt = make_optimizer(spec.optimizer, lr=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, microbatches=2, batch_shards=1))
    p2, s2, m = step(params, state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ALL)
def test_arch_smoke_serve_shapes(arch):
    """Prefill + one decode step on the smoke config: shape + no-NaN."""
    spec = get_spec(arch)
    cfg = spec.smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.is_enc_dec:
        memory = encode(cfg, params,
                        jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)))
    pe = None
    if cfg.frontend == "vision":
        pe = jax.random.normal(jax.random.PRNGKey(3), (B, 4, cfg.d_model))
    logits, cache = prefill(cfg, params, toks, prefix_embeds=pe, memory=memory,
                            cache_len=S + 8)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    lg2, cache2 = decode_step(cfg, params, toks[:, -1:], cache)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all(), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["qwen3-14b", "jamba-v0.1-52b", "rwkv6-3b",
                                  "granite-moe-1b-a400m", "seamless-m4t-medium",
                                  "arctic-480b"])
def test_decode_parity_with_full_forward(arch):
    """decode_step(t) logits == full forward logits at position t (f32).

    MoE capacity DROPS depend on the token count, so parity holds only in
    the dropless regime: capacity_factor is raised to n_experts here (the
    serving engine runs the same dropless setting at smoke scale)."""
    spec = get_spec(arch)
    cfg = dataclasses.replace(spec.smoke, compute_dtype=jnp.float32)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=float(cfg.moe.n_experts)))
    params = init_params(cfg, jax.random.PRNGKey(1))
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S + 2), 0, cfg.vocab_size)
    memory = None
    if cfg.is_enc_dec:
        memory = encode(cfg, params,
                        jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model)))
    full, _ = forward_logits(cfg, params, toks, memory=memory)
    _, cache = prefill(cfg, params, toks[:, :S], memory=memory, cache_len=S + 4)
    lg, cache = decode_step(cfg, params, toks[:, S:S + 1], cache)
    scale = float(np.max(np.abs(np.asarray(full))))
    err = float(np.max(np.abs(np.asarray(lg[:, 0]) - np.asarray(full[:, S]))))
    assert err < 1e-3 * max(scale, 1.0), (arch, err)
    # a second step keeps parity
    lg2, _ = decode_step(cfg, params, toks[:, S + 1:S + 2], cache)
    err2 = float(np.max(np.abs(np.asarray(lg2[:, 0]) - np.asarray(full[:, S + 1]))))
    assert err2 < 1e-3 * max(scale, 1.0), (arch, err2)


def test_full_config_param_counts():
    """FULL configs match published sizes (exercised abstractly, no alloc)."""
    expect = {
        "qwen3-14b": 14.8e9, "phi3-medium-14b": 14.7e9, "smollm-135m": 0.16e9,
        "internlm2-20b": 19.9e9, "jamba-v0.1-52b": 51.6e9, "arctic-480b": 477e9,
        "granite-moe-1b-a400m": 1.4e9, "internvl2-76b": 70.5e9,
        "seamless-m4t-medium": 1.0e9, "rwkv6-3b": 3.1e9, "llama3-70b": 70.5e9,
    }
    for arch, target in expect.items():
        n = param_count(param_specs(get_spec(arch).model))
        assert abs(n - target) / target < 0.06, (arch, n, target)


def test_kv_spec_matches_paper_eq1():
    spec = get_spec("llama3-70b").kv_spec()
    assert spec.kv_bytes_per_token == 320 * 1024  # §III-B
    # attention-free: per-token KV is zero, fixed state dominates
    r = get_spec("rwkv6-3b").kv_spec()
    assert r.kv_bytes_per_token == 0 and r.fixed_state_bytes > 0
    # hybrid: only the attention layers contribute per-token bytes
    j = get_spec("jamba-v0.1-52b").kv_spec()
    assert j.kv_bytes_per_token == 2 * 4 * 8 * 128 * 2


def test_input_specs_cover_assigned_cells():
    """Every (arch x shape) cell is either well-defined or a documented skip."""
    from repro.configs import SHAPES

    n_cells = n_skips = 0
    for arch in ALL:
        if arch == "llama3-70b":
            continue  # paper model, not an assigned cell
        spec = get_spec(arch)
        for shape in SHAPES:
            n_cells += 1
            if shape in spec.runnable_shapes():
                ins = spec.input_specs(shape)
                assert ins, (arch, shape)
            else:
                assert shape in spec.skip_notes, (arch, shape)
                n_skips += 1
    assert n_cells == 40
    assert n_skips == 8  # long_500k for the 8 full-attention archs


def test_microbatch_split_preserves_rows():
    from repro.train.train_step import effective_microbatches, microbatch_split

    x = jnp.arange(32 * 3).reshape(32, 3)
    mb = effective_microbatches(32, 4, batch_shards=4)
    out = microbatch_split({"x": x}, mb, 4)["x"]
    assert out.shape == (4, 8, 3)
    # every row appears exactly once
    assert sorted(np.asarray(out).reshape(-1, 3)[:, 0].tolist()) == list(range(0, 96, 3))
    # multipod clamp: local batch 8 with requested mb 16 -> 8
    assert effective_microbatches(256, 16, 32) == 8
