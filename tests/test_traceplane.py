"""TracePlane: parity, zero-cost-off, forensics and exporter contracts.

The observability bar mirrors every prior plane's retirement bar: the
span set and every timestamp must be *bit-exact* across both event
engines (``event_engine="plane"`` / ``"reference"``) and both dispatch
modes (``dispatch_mode="plane"`` / ``"reference"``), and turning
tracing on must leave every simulated outcome untouched.
"""

from __future__ import annotations

import json
import math

import pytest

from repro.core.cost import decision_breakdown
from repro.sim import (
    FaultEvent, RewireEvent, SimConfig, Simulation, TracePlane,
    enable_tracing, trace_session, ttft_breakdown_rows,
)
from repro.sim.engine import enable_profiling, make_event_loop, profile_rows
from repro.sim.trace import BREAKDOWN_COLUMNS, FORENSICS_COLUMNS
from repro.traces import generate_trace

GPU64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2)       # 64 GPUs


def _drive(seed: int, cfg_kw: dict, rps: float = 45.0, *,
           scheduler: str = "netkv-full", trace: bool = True):
    tr = generate_trace("rag", duration=7.0, target_rps=rps, seed=seed)
    cfg = SimConfig(scheduler=scheduler, seed=seed, warmup=2.0,
                    measure=4.0, trace=trace, **cfg_kw)
    sim = Simulation(cfg)
    metrics = sim.run(tr, drain=25.0)
    return sim, metrics


def _all_modes(seed: int, cfg_kw: dict, rps: float = 45.0, **kw):
    out = {}
    for ee in ("plane", "reference"):
        for dm in ("plane", "reference"):
            sim, m = _drive(seed, dict(event_engine=ee, dispatch_mode=dm,
                                       **cfg_kw), rps, **kw)
            out[(ee, dm)] = (sim.trace.spans(), sim.trace.forensics_rows(), m)
    return out


def _assert_trace_parity(cfg_kw: dict, seed: int = 0, rps: float = 45.0,
                         **kw) -> None:
    drives = _all_modes(seed, cfg_kw, rps, **kw)
    spans0, dec0, m0 = drives[("plane", "plane")]
    assert spans0, "traced drive produced no spans"
    for key, (spans, dec, m) in drives.items():
        assert spans == spans0, f"span set diverges under {key}"
        assert dec == dec0, f"forensics rows diverge under {key}"
        assert m.ttft_mean == m0.ttft_mean


class TestTraceParity:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_64gpu_baseline(self, seed):
        _assert_trace_parity(dict(**GPU64, background=0.2), seed=seed)

    def test_64gpu_faults_rewires(self):
        faults = [
            FaultEvent(time=3.0, kind="kill_decode", instance_id=4),
            FaultEvent(time=3.5, kind="slowdown", instance_id=6, factor=1.5),
            FaultEvent(time=4.5, kind="add_decode"),
        ]
        rewires = [
            RewireEvent(time=3.2, scale={2: 0.25, 3: 0.25}),
            RewireEvent(time=5.0, scale={2: 4.0, 3: 4.0}),
        ]
        _assert_trace_parity(dict(**GPU64, background=0.15, faults=faults,
                                  rewires=rewires))

    def test_64gpu_streamed_kv(self):
        _assert_trace_parity(dict(**GPU64, background=0.1, chunk_tokens=512,
                                  prefill_token_budget=1024,
                                  kv_streaming=True))

    @pytest.mark.parametrize("scheduler", ["rr", "la", "ca", "cla"])
    def test_64gpu_ladder(self, scheduler):
        _assert_trace_parity(dict(**GPU64, background=0.2),
                             scheduler=scheduler)


class TestTraceOffIdentity:
    def test_tracing_changes_no_outcomes(self):
        cfg_kw = dict(**GPU64, background=0.2)
        s_off, m_off = _drive(0, dict(cfg_kw), trace=False)
        s_on, m_on = _drive(0, dict(cfg_kw), trace=True)
        assert s_off.trace is None
        assert m_off.ttft_mean == m_on.ttft_mean
        assert m_off.goodput_rps == m_on.goodput_rps
        off = [(rs.req.request_id, rs.first_token, rs.finish,
                rs.decode_instance) for rs in s_off.records]
        on = [(rs.req.request_id, rs.first_token, rs.finish,
               rs.decode_instance) for rs in s_on.records]
        assert off == on

    def test_untraced_metrics_still_attribute(self):
        # TTFT attribution derives from RequestState, so the new columns
        # are populated even without a TracePlane.
        _sim, m = _drive(0, dict(**GPU64, background=0.2), trace=False)
        assert math.isfinite(m.xfer_share_mean)
        assert math.isfinite(m.queue_wait_mean)
        assert 0.0 <= m.xfer_share_mean <= 1.0


class TestForensics:
    def test_stride_subsamples_deterministically(self):
        s1, _ = _drive(0, dict(**GPU64, background=0.2, trace_decisions=1))
        s4, _ = _drive(0, dict(**GPU64, background=0.2, trace_decisions=4))
        d1, d4 = s1.trace.forensics_rows(), s4.trace.forensics_rows()
        assert len(d1) > len(d4) > 0
        assert d4 == d1[::4]

    def test_winner_breakdown_recomputes(self):
        # Eq. (5) consistency on the recorded winner: cost = xfer + load
        # (load already bundles T_queue + T_decode), and decision_breakdown
        # terms are non-negative and finite.
        sim, _ = _drive(0, dict(**GPU64, background=0.2))
        rows = sim.trace.forensics_rows()
        assert rows
        for row in rows[:64]:
            r = dict(zip(FORENSICS_COLUMNS, row))
            if r["kind"] != "netkv-full":
                continue
            assert r["cost_win"] == pytest.approx(
                r["xfer_win"] + r["load_win"], rel=1e-12)
            if not math.isnan(r["cost_run"]):
                assert r["cost_win"] <= r["cost_run"] or math.isclose(
                    r["cost_win"], r["cost_run"])

    def test_decision_breakdown_terms(self):
        from repro.core.cost import H100_TP4_ITER
        xfer, queue, first = decision_breakdown(
            s_eff=1e9, tier_bw=50e9, congestion=0.1, n_inflight=2,
            tier_latency=1e-4, q_d=3, beta_d=60, beta_max=64,
            iter_model=H100_TP4_ITER)
        assert xfer > 0 and queue == 0.0 and first > 0
        assert queue == 0.0  # 3 blocked <= 4 free slots


class TestSpans:
    def test_lifecycle_span_consistency(self):
        sim, _ = _drive(0, dict(**GPU64, background=0.2))
        by_kind: dict[str, int] = {}
        for kind, req, t0, t1, inst, tier, a, b in sim.trace.spans():
            by_kind[kind] = by_kind.get(kind, 0) + 1
            assert t1 >= t0, (kind, req)
        for needed in ("queue", "prefill", "xfer", "admit_wait",
                       "first_iter", "decode"):
            assert by_kind.get(needed, 0) > 0, f"missing {needed} spans"

    def test_xfer_segments_carry_bottleneck(self):
        sim, _ = _drive(0, dict(**GPU64, background=0.2))
        segs = [s for s in sim.trace.spans() if s[0] == "xfer_seg"]
        assert segs, "no transfer segments recorded"
        # Every non-degenerate segment names the water-fill bottleneck link.
        with_link = [s for s in segs if s[7] >= 0]
        assert with_link, "no bottleneck links recorded"

    def test_chunk_spans_telescope(self):
        sim, _ = _drive(0, dict(**GPU64, background=0.1, chunk_tokens=512,
                                prefill_token_budget=1024))
        done: dict[int, float] = {}
        takes: dict[int, float] = {}
        for kind, req, t0, t1, inst, tier, a, b in sim.trace.spans():
            if kind != "chunk":
                continue
            takes[req] = takes.get(req, 0.0) + a
            done[req] = max(done.get(req, 0.0), b)
        assert takes
        for req, total in takes.items():
            assert total == done[req], f"req {req}: takes don't telescope"


class TestExporters:
    def test_chrome_events_shape(self):
        sim, _ = _drive(0, dict(**GPU64, background=0.2))
        ev = sim.trace.to_chrome_events(pid=7, label="unit")
        json.dumps(ev)  # serialisable
        kinds = {e["ph"] for e in ev}
        assert "X" in kinds and "M" in kinds and "i" in kinds
        slices = [e for e in ev if e["ph"] == "X"]
        assert all(e["dur"] >= 0.0 and e["pid"] == 7 for e in slices)
        assert any(e["tid"] == 0 for e in ev if e["ph"] == "i")

    def test_breakdown_rows_schema(self):
        sim, _ = _drive(0, dict(**GPU64, background=0.2))
        rows = ttft_breakdown_rows(sim.records, run="unit")
        assert rows
        for row in rows[:16]:
            assert tuple(row) == BREAKDOWN_COLUMNS
            parts = [row["queue_wait"], row["prefill"], row["xfer"],
                     row["admit_wait"], row["first_iter"]]
            if all(not math.isnan(p) for p in parts):
                assert sum(parts) == pytest.approx(row["ttft"], rel=1e-9)

    def test_session_write(self, tmp_path):
        sess = enable_tracing()
        try:
            sess.context = "unit"
            _drive(0, dict(**GPU64, background=0.2), trace=False)
            assert sess.n_runs == 1  # session auto-enables the TracePlane
            paths = sess.write(tmp_path)
        finally:
            enable_tracing(False)
        jpath, cpath = paths
        with open(jpath) as fh:
            doc = json.load(fh)
        assert doc["traceEvents"]
        with open(cpath) as fh:
            header = fh.readline().strip().split(",")
        assert header == list(BREAKDOWN_COLUMNS)
        assert trace_session() is None

    def test_session_pause_suppresses_runs(self):
        sess = enable_tracing()
        try:
            sess.paused = True
            sim, _ = _drive(0, dict(**GPU64, background=0.2), trace=False)
            assert sim.trace is None and sess.n_runs == 0
        finally:
            enable_tracing(False)


class TestProfileSession:
    def test_sequential_runs_are_independent(self):
        # Regression: the module-global accumulator used to leak select()
        # time credit across runs — the second run's rows included the
        # first run's totals.
        totals = []
        for _ in range(2):
            sess = enable_profiling(True)
            _drive(0, dict(**GPU64, background=0.2), trace=False)
            rows = profile_rows()
            assert rows, "profiling produced no rows"
            totals.append(sum(r["seconds"] for r in rows))
            assert rows == sess.profile_rows()
            enable_profiling(False)
        # Same drive twice: wall-clock noise aside, the second total must
        # be commensurate with the first, not cumulative (~2x).
        assert totals[1] < totals[0] * 1.7

    def test_loop_binds_session_at_construction(self):
        sess = enable_profiling(True)
        loop = make_event_loop("plane")
        assert loop.profile is sess
        enable_profiling(False)
        assert make_event_loop("plane").profile is None
        loop.note_select(0.25)
        assert sess.select_s == pytest.approx(0.25)
        assert profile_rows() == []  # module shim: no active session
