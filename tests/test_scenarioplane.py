"""ScenarioPlane parity and invariants: the jitted solvers vs their planes.

Three bit-exactness contracts (the ScenarioPlane's foundation):

* ``kernels.waterfill`` reproduces ``FlowPlane._recompute_rates`` — rates
  *and* the per-round bottleneck (link, share) trace — bit-for-bit under
  f64, on live FlowPlane states and on randomized flow tables (against an
  inline NumPy port of the plane's algorithm);
* ``sim.scenarios.cohort_step`` (``exact_clamp=True``) reproduces
  ``InstancePlane._step_rows_vector``'s token/finish/KV columns bit-for-bit
  on seeded 64- and 256-GPU event-loop drives (monkeypatched shadow check
  at every vectorised cohort step);
* batched ``ScenarioPlane.sweep`` row ``i`` is bit-identical to a solo run
  of scenario ``i`` at the same padding (vmap consistency).

The Pallas backend (f32 inner reduction) is tolerance-tested, never the
oracle.  Property-test variants ride through ``hypothesis_compat`` and
skip cleanly where hypothesis is absent; the plain seeded tests carry the
same coverage regardless.
"""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.jaxutil import enable_f64, f64_enabled
from repro.cluster import BackgroundTraffic, FatTree, FlowPlane
from repro.kernels import waterfill_rates, waterfill_rates_fast
from repro.sim import ScenarioPlane, ScenarioSpec, cohort_step_jit
from repro.sim.instances import InstancePlane
from repro.sim.simulator import SimConfig, Simulation
from repro.traces.mooncake import generate_trace

TREE_64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2, gpus_per_server=8)


# ------------------------------------------------------------------ helpers
def _servers(kw):
    return [
        (p, r, s)
        for p in range(kw["n_pods"])
        for r in range(kw["racks_per_pod"])
        for s in range(kw["servers_per_rack"])
    ]


def _loaded_plane(seed, n_transfers=40, bg=0.2, nic_policy="hash",
                  tree_kw=TREE_64, nics=2):
    """A FlowPlane mid-drive with ``n_transfers`` in-flight transfers."""
    tree = FatTree(**tree_kw, nics_per_server=nics)
    plane = FlowPlane(tree, BackgroundTraffic(bg), seed=seed,
                      nic_policy=nic_policy)
    rng = np.random.default_rng(seed + 7)
    servers = _servers(tree_kw)
    now = 0.0
    for _ in range(n_transfers):
        now += float(rng.exponential(0.002))
        i, j = rng.choice(len(servers), 2, replace=False)
        plane.start_transfer(servers[i], servers[j],
                             float(rng.uniform(1e7, 5e8)), now,
                             on_complete=lambda t, tt: None, n_flows=4)
    return plane, now


def _np_waterfill(paths, caps, active):
    """Inline NumPy port of ``FlowPlane._recompute_rates``'s fixed point
    (full-recompute path) — the second, independent parity oracle for
    randomized tables."""
    lp1 = caps.shape[0]
    pad = lp1 - 1
    P = np.where(active[:, None], paths, pad).astype(np.int64)
    flat = P.ravel()
    enc = np.full(lp1, flat.size + 1, np.int64)
    np.minimum.at(enc, flat, np.arange(flat.size))
    perm = np.argsort(enc, kind="stable")
    inv = np.empty_like(perm)
    inv[perm] = np.arange(lp1)
    P = inv[P]
    counts = np.bincount(P.ravel(), minlength=lp1)
    counts[inv[pad]] = 0
    caps_p = caps[perm].copy()
    rates = np.zeros(len(P), np.float64)
    unfixed = active.copy()
    trace = []
    while unfixed.any():
        shares = np.full(lp1, np.inf)
        np.divide(caps_p, counts, out=shares, where=counts > 0)
        lid = int(np.argmin(shares))
        share = shares[lid]
        if share == np.inf:
            rates[unfixed] = np.inf
            break
        trace.append((int(perm[lid]), float(share)))
        rows = np.flatnonzero(unfixed & (P == lid).any(axis=1))
        rates[rows] = share
        idx = P[rows].ravel()
        np.subtract.at(caps_p, idx, share)
        np.maximum(caps_p, 0.0, out=caps_p)
        np.subtract.at(counts, idx, 1)
        unfixed[rows] = False
    return rates, trace


def _random_table(seed):
    rng = np.random.default_rng(seed)
    n_links = int(rng.integers(4, 28))
    n_flows = int(rng.integers(1, 40))
    h = int(rng.integers(2, 7))
    caps = np.append(rng.uniform(1e7, 1e9, n_links), np.inf)
    paths = np.full((n_flows, h), n_links, np.int32)
    for f in range(n_flows):
        plen = int(rng.integers(1, min(h, n_links) + 1))
        paths[f, :plen] = rng.choice(n_links, plen, replace=False)
    active = rng.random(n_flows) < 0.85
    return paths, caps, active


def _check_waterfill_invariants(paths, caps, active, rates, trace):
    """Max-min structural invariants (the property-test contract)."""
    pad = caps.shape[0] - 1
    rates = np.asarray(rates)
    assert np.all(rates >= 0.0)
    assert np.all(rates[~active] == 0.0)
    load = np.zeros(caps.shape[0])
    for f in np.flatnonzero(active):
        for l in set(int(x) for x in paths[f] if x != pad):
            load[l] += rates[f]
    # Byte conservation: no link carries more than its residual capacity.
    assert np.all(load[:pad] <= caps[:pad] * (1 + 1e-9) + 1e-6)
    # Max-min: every active flow crosses >= 1 saturated link.
    for f in np.flatnonzero(active):
        links = [int(x) for x in paths[f] if x != pad]
        assert any(load[l] >= caps[l] * (1 - 1e-9) - 1e-6 for l in links), f
    # Progressive filling: bottleneck shares are non-decreasing.
    shares = [s for _, s in trace]
    assert all(a <= b * (1 + 1e-12) for a, b in zip(shares, shares[1:]))


# ------------------------------------------------------ f64 guard
class TestF64Guard:
    def test_enable_is_idempotent_and_sticky(self):
        import jax.numpy as jnp

        enable_f64()
        enable_f64()
        assert f64_enabled()
        assert jnp.zeros(1, jnp.float64).dtype == jnp.float64
        assert jnp.asarray(np.float64(1.5)).dtype == jnp.float64


# ------------------------------------------------- waterfill vs FlowPlane
class TestWaterfillFlowPlaneParity:
    @pytest.mark.parametrize("seed,nic", [(0, "hash"), (1, "rail-affine")])
    def test_rates_and_trace_bit_exact(self, seed, nic):
        plane, now = _loaded_plane(seed, nic_policy=nic)
        plane._wf_trace = []
        plane.refresh_rates(now)  # full recompute + trace
        slots = plane._ordered_slots()
        paths = plane.f_path[slots].astype(np.int32)
        caps = plane._resid_caps.copy()
        rates, tl, ts, r = waterfill_rates(paths, caps, backend="jax")
        assert np.array_equal(np.asarray(rates), plane.f_rate[slots])
        r = int(r)
        assert r == len(plane._wf_trace)
        ref_links = [l for l, _ in plane._wf_trace]
        ref_shares = np.array([s for _, s in plane._wf_trace])
        assert np.asarray(tl)[:r].tolist() == ref_links
        assert np.array_equal(np.asarray(ts)[:r], ref_shares)

    def test_inactive_rows_inert(self):
        plane, now = _loaded_plane(3)
        plane.refresh_rates(now)
        slots = plane._ordered_slots()
        paths = plane.f_path[slots].astype(np.int32)
        caps = plane._resid_caps.copy()
        # Append garbage rows masked inactive: identical result, zero rates.
        junk = np.tile(paths[:1], (5, 1))
        paths_pad = np.concatenate([paths, junk])
        active = np.append(np.ones(len(slots), bool), np.zeros(5, bool))
        rates, _, _, _ = waterfill_rates(paths_pad, caps, active,
                                         backend="jax")
        rates = np.asarray(rates)
        assert np.array_equal(rates[: len(slots)], plane.f_rate[slots])
        assert np.all(rates[len(slots):] == 0.0)

    def test_pallas_backend_close(self):
        plane, now = _loaded_plane(5)
        plane.refresh_rates(now)
        slots = plane._ordered_slots()
        paths = plane.f_path[slots].astype(np.int32)
        caps = plane._resid_caps.copy()
        rates, _, _, _ = waterfill_rates(paths, caps, backend="pallas")
        ref = plane.f_rate[slots]
        assert np.allclose(np.asarray(rates, np.float64), ref, rtol=1e-4)

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError):
            waterfill_rates(np.zeros((1, 2), np.int32),
                            np.array([1.0, np.inf]), backend="numpy")


# --------------------------------------------- waterfill randomized tables
class TestWaterfillRandomTables:
    def _one(self, seed):
        paths, caps, active = _random_table(seed)
        ref_rates, ref_trace = _np_waterfill(paths, caps, active)
        rates, tl, ts, r = waterfill_rates(paths, caps, active,
                                           backend="jax")
        rates = np.asarray(rates)
        assert np.array_equal(rates, ref_rates)
        r = int(r)
        assert np.asarray(tl)[:r].tolist() == [l for l, _ in ref_trace]
        assert np.array_equal(np.asarray(ts)[:r],
                              np.array([s for _, s in ref_trace]))
        _check_waterfill_invariants(paths, caps, active, rates, ref_trace)

    @pytest.mark.parametrize("seed", range(8))
    def test_parity_and_invariants_seeded(self, seed):
        self._one(seed)

    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_parity_and_invariants_property(self, seed):
        self._one(seed)


# -------------------------------------- parallel-bottleneck fast solver
class TestWaterfillFastSolver:
    """``waterfill_rates_fast`` fixes every level bottleneck per round
    instead of one; the max-min allocation is unique, so it must agree
    with the progressive reference up to residual-subtraction rounding."""

    @staticmethod
    def _nhops(paths, caps):
        lp1 = caps.shape[0]
        nh = np.zeros((paths.shape[0], lp1))
        for f in range(paths.shape[0]):
            for link in paths[f]:
                nh[f, int(link)] += 1
        nh[:, lp1 - 1] = 0.0
        return nh

    def _one(self, seed):
        paths, caps, active = _random_table(seed)
        ref_rates, ref_trace = _np_waterfill(paths, caps, active)
        fast = np.asarray(waterfill_rates_fast(paths, caps, active))
        np.testing.assert_allclose(fast, ref_rates, rtol=1e-9, atol=1e-6)
        _check_waterfill_invariants(paths, caps, active, fast, ref_trace)

    @pytest.mark.parametrize("seed", range(8))
    def test_matches_progressive_seeded(self, seed):
        self._one(seed)

    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_matches_progressive_property(self, seed):
        self._one(seed)

    @pytest.mark.parametrize("seed", [3, 11])
    def test_precomputed_incidence_matches_paths_form(self, seed):
        """The ScenarioPlane's gather path (``nhops=``) is bitwise the
        same program as the one-hot build from ``paths``."""
        paths, caps, active = _random_table(seed)
        a = np.asarray(waterfill_rates_fast(paths, caps, active))
        nh = self._nhops(paths, caps)
        b = np.asarray(waterfill_rates_fast(None, caps, active, nhops=nh))
        assert np.array_equal(a, b)


# --------------------------------------------------------- cohort step unit
class TestCohortStepUnit:
    def _mk(self, seed, rows=64, k=6):
        rng = np.random.default_rng(seed)
        import jax.numpy as jnp

        tokens = jnp.asarray(rng.integers(0, 9, rows))
        out_len = jnp.asarray(rng.integers(1, 10, rows))
        inst = jnp.asarray(rng.integers(0, k, rows))
        seq = jnp.asarray(np.arange(rows, dtype=np.int64))
        grown = jnp.asarray(rng.uniform(0.0, 4e8, rows))
        live = jnp.asarray(rng.random(rows) < 0.8)
        cohort = jnp.asarray(rng.random(k) < 0.7)
        pinned = jnp.asarray(np.append(rng.uniform(0.0, 1e9, k), 0.0))
        return tokens, out_len, inst, seq, grown, live, cohort, pinned

    def test_exact_matches_numpy_sequential(self):
        """exact_clamp reproduces the per-(inst, seq) sequential clamp the
        NumPy plane applies, bit-for-bit."""
        for seed in range(6):
            args = self._mk(seed)
            tokens, out_len, inst, seq, grown, live, cohort, pinned = (
                np.asarray(a) for a in args)
            t2, l2, p2, first, fin, fpi = cohort_step_jit(
                *args, kv_per_token=1e5, exact_clamp=True)
            # NumPy shadow.
            rows = live & cohort[np.clip(inst, 0, len(cohort) - 1)]
            toks = np.where(rows, tokens + 1, tokens)
            pin = pinned.copy()
            for i in np.flatnonzero(rows):
                pin[inst[i]] += 1e5
            fin_ref = rows & (toks >= out_len)
            order = np.lexsort((seq, inst))
            for i in order:
                if fin_ref[i]:
                    pin[inst[i]] = max(0.0, pin[inst[i]] - grown[i])
            assert np.array_equal(np.asarray(t2), toks)
            assert np.array_equal(np.asarray(l2), live & ~fin_ref)
            assert np.array_equal(np.asarray(p2)[:-1], pin[:-1])
            assert np.array_equal(np.asarray(fin), fin_ref)
            assert np.array_equal(np.asarray(first), rows & (toks == 1))
            k = len(cohort)
            fpi_ref = np.bincount(inst[fin_ref], minlength=k)
            assert np.array_equal(np.asarray(fpi), fpi_ref)

    def test_fused_clamp_close_to_exact(self):
        for seed in range(4):
            args = self._mk(seed)
            _, _, p_exact, *_ = cohort_step_jit(*args, kv_per_token=1e5,
                                                exact_clamp=True)
            _, _, p_fused, *_ = cohort_step_jit(*args, kv_per_token=1e5,
                                                exact_clamp=False)
            # Real instance slots only: the pad accumulator diverges by
            # design (exact routes non-finishers there as no-ops, fused
            # clamps it), and nothing ever reads it.
            assert np.allclose(np.asarray(p_exact)[:-1],
                               np.asarray(p_fused)[:-1],
                               rtol=1e-12, atol=1.0)


# ----------------------------------------- cohort step vs InstancePlane
def _pow2(n):
    p = 64
    while p < n:
        p *= 2
    return p


def _drive_cohort_parity(cfg_kw, trace_kw, drain):
    """Run the event loop with every vectorised cohort step shadowed by
    the jitted cohort_step (exact_clamp): tokens/live/pinned columns must
    match bit-for-bit after each step."""
    import jax.numpy as jnp

    calls = [0]
    orig = InstancePlane._step_rows_vector

    def wrapper(self, cohort, now):
        hi, n = self._r_hi, self.n_dec
        kpt = float(self.kv_per_token)
        R = _pow2(hi)  # pow2 padding bounds jit recompiles as hi grows
        grown = np.zeros(R, np.float64)
        for r in range(hi):
            if self.r_live[r]:
                rs = self.r_obj[r]
                grown[r] = rs.kv_bytes + rs.req.output_len * kpt

        def padded(a, fill):
            out = np.full(R, fill, a.dtype)
            out[:hi] = a[:hi]
            return out

        toks0 = padded(self.r_tokens, 0)
        out0 = padded(self.r_out, 1)
        inst0 = padded(self.r_inst, 0)
        seq0 = padded(self.r_seq, 0)
        live0 = padded(self.r_live, False)
        pin0 = self.d_pinned[:n].copy()
        orig(self, cohort, now)
        in_cohort = np.zeros(n, bool)
        in_cohort[np.asarray(cohort, int)] = True
        toks, live, pinned, _, _, _ = cohort_step_jit(
            jnp.asarray(toks0), jnp.asarray(out0), jnp.asarray(inst0),
            jnp.asarray(seq0), jnp.asarray(grown), jnp.asarray(live0),
            jnp.asarray(in_cohort), jnp.asarray(np.append(pin0, 0.0)),
            kv_per_token=kpt, exact_clamp=True)
        assert np.array_equal(np.asarray(toks)[:hi], self.r_tokens[:hi])
        assert np.array_equal(np.asarray(live)[:hi], self.r_live[:hi])
        assert np.array_equal(np.asarray(pinned)[:n], self.d_pinned[:n])
        calls[0] += 1

    InstancePlane._step_rows_vector = wrapper
    try:
        sim = Simulation(SimConfig(**cfg_kw))
        sim.engine.scalar_rows_max = -1  # force the vector path throughout
        trace = generate_trace("chatbot", **trace_kw)
        sim.run(trace, drain=drain)
    finally:
        InstancePlane._step_rows_vector = orig
    assert calls[0] > 100  # the vector path actually ran


class TestCohortStepPlaneParity:
    def test_bit_exact_64_gpu(self):
        _drive_cohort_parity(
            dict(scheduler="netkv-full", warmup=0.5, measure=2.0, seed=0),
            dict(duration=2.5, target_rps=10.0, seed=0), drain=6.0)

    def test_bit_exact_256_gpu(self):
        _drive_cohort_parity(
            dict(scheduler="netkv-full", warmup=0.5, measure=1.0, seed=1,
                 n_pods=4, racks_per_pod=2, servers_per_rack=4),
            dict(duration=1.5, target_rps=16.0, seed=1), drain=4.0)


# ------------------------------------------------------- vmap consistency
def _sweep_specs():
    base = dict(warmup=0.5, measure=2.0, drain=1.5, target_rps=8.0)
    return [
        ScenarioSpec(seed=0, scheduler="netkv-full", **base),
        ScenarioSpec(seed=0, scheduler="cla", **base),
        ScenarioSpec(seed=1, scheduler="netkv-static", chunk_tokens=256,
                     kv_streaming=True, **base),
        ScenarioSpec(seed=1, scheduler="netkv-full", nic_policy="rail-affine",
                     background=0.3, rewires=((1.0, {2: 0.5, 3: 0.5}),),
                     **base),
    ]


class TestScenarioPlane:
    def test_sweep_shapes_and_sanity(self):
        specs = _sweep_specs()
        plane = ScenarioPlane(specs, dt=0.01)
        out = plane.sweep()
        s = len(specs)
        for key in ("n_measured", "n_served", "ttft_mean", "ttft_p50",
                    "ttft_p95", "ttft_p99", "tbt_mean", "slo_attainment",
                    "goodput_rps"):
            assert key in out and out[key].shape == (s,), key
        assert np.all(out["n_measured"] > 0)
        assert np.all(out["n_served"] <= out["n_measured"])
        served = out["n_served"] > 0
        assert np.all(np.isfinite(out["ttft_p50"][served]))
        att = out["slo_attainment"]
        assert np.all((att >= 0.0) & (att <= 1.0) | np.isnan(att))

    def test_batched_rows_match_solo_runs_bitwise(self):
        specs = _sweep_specs()
        plane = ScenarioPlane(specs, dt=0.01)
        batched = plane.sweep(detail=True)
        for i, sp in enumerate(specs):
            solo = ScenarioPlane([sp], dt=0.01,
                                 max_requests=plane.max_requests
                                 ).sweep(detail=True)
            for key, val in batched.items():
                assert np.array_equal(np.asarray(val)[i],
                                      np.asarray(solo[key])[0],
                                      equal_nan=True), (key, i)

    def test_mixed_shapes_rejected(self):
        a = ScenarioSpec(seed=0)
        b = ScenarioSpec(seed=0, n_pods=4)
        with pytest.raises(ValueError):
            ScenarioPlane([a, b])
        c = ScenarioSpec(seed=0, measure=a.measure + 1.0)
        with pytest.raises(ValueError):
            ScenarioPlane([a, c])
        with pytest.raises(ValueError):
            ScenarioPlane([a], backend="tpu")
        with pytest.raises(ValueError):
            ScenarioPlane([])

    def test_max_requests_floor_enforced(self):
        sp = ScenarioSpec(seed=0, warmup=0.5, measure=2.0, drain=1.5,
                          target_rps=8.0)
        plane = ScenarioPlane([sp])
        with pytest.raises(ValueError):
            ScenarioPlane([sp], max_requests=plane.max_requests - 1)
