"""FlowPlane parity: columnar engine vs the retired per-object oracle.

Both engines are driven through an identical randomized op sequence
(transfer arrivals, completion-time advances, aborts, refresh ticks) on
seeded 64- and 256-GPU fat-trees.  After every op the per-flow rates and
residual bytes must match *bit-for-bit*, and at the end the transfer
completion order, finish times, per-tier byte counters and total delivered
bytes must be exactly equal — the FlowPlane's vectorised water-filling,
ordered np.add.at byte accumulation, and incremental (dirty-component)
recomputation are all exercised against the reference's full per-event
recompute.  Background is static (wander=0) here: the FlowPlane samples
time-varying background at refresh ticks by design, the reference at every
event, so exact parity is defined at static background.
"""

import numpy as np
import pytest

from repro.cluster import (
    BackgroundTraffic,
    FatTree,
    FlowPlane,
    ReferenceFlowNetwork,
)

TREE_64 = dict(n_pods=2, racks_per_pod=2, servers_per_rack=2, gpus_per_server=8)
TREE_256 = dict(n_pods=2, racks_per_pod=8, servers_per_rack=2, gpus_per_server=8)


def _servers(kw):
    return [
        (p, r, s)
        for p in range(kw["n_pods"])
        for r in range(kw["racks_per_pod"])
        for s in range(kw["servers_per_rack"])
    ]


def _flow_state(net):
    return {
        fid: (f.rate, f.bytes_remaining, f.path) for fid, f in net.flows.items()
    }


def _drive(tree_kw, seed, n_ops=80, bg=0.0, n_flows=4):
    """Run the same op sequence through both engines, comparing throughout."""
    plane = FlowPlane(FatTree(**tree_kw), BackgroundTraffic(bg), seed=seed)
    ref = ReferenceFlowNetwork(FatTree(**tree_kw), BackgroundTraffic(bg), seed=seed)
    wl = np.random.default_rng(seed + 0xF10)
    servers = _servers(tree_kw)
    done_a, done_b = [], []
    open_pairs = []   # (plane_transfer, ref_transfer)
    now = 0.0
    for _ in range(n_ops):
        now += float(wl.exponential(0.003))
        op = wl.random()
        if op < 0.55 or not open_pairs:
            i, j = wl.choice(len(servers), 2, replace=False)
            nbytes = float(wl.uniform(1e6, 5e8))
            ta = plane.start_transfer(
                servers[i], servers[j], nbytes, now,
                on_complete=lambda t, tt: done_a.append((t.transfer_id, tt)),
                n_flows=n_flows)
            tb = ref.start_transfer(
                servers[i], servers[j], nbytes, now,
                on_complete=lambda t, tt: done_b.append((t.transfer_id, tt)),
                n_flows=n_flows)
            open_pairs.append((ta, tb))
        elif op < 0.75:
            na, nb = plane.next_completion_time(now), ref.next_completion_time(now)
            assert na == nb
            if na is not None:
                now = na
                plane.advance(now)
                ref.advance(now)
        elif op < 0.9:
            plane.refresh_rates(now)
            ref.refresh_rates(now)
        else:
            k = int(wl.integers(len(open_pairs)))
            ta, tb = open_pairs.pop(k)
            if not ta.done:
                plane.abort_transfer(ta, now)
                ref.abort_transfer(tb, now)
        open_pairs = [(a, b) for a, b in open_pairs if not a.done]
        assert _flow_state(plane) == _flow_state(ref)
    # Drain everything still in flight.
    for _ in range(10_000):
        na, nb = plane.next_completion_time(now), ref.next_completion_time(now)
        assert na == nb
        if na is None:
            break
        now = na
        plane.advance(now)
        ref.advance(now)
    else:  # pragma: no cover
        pytest.fail("drain did not converge")
    return plane, ref, done_a, done_b


class TestBitExactParity:
    @pytest.mark.parametrize("tree_kw", [TREE_64, TREE_256],
                             ids=["64gpu", "256gpu"])
    @pytest.mark.parametrize("seed", range(4))
    def test_rates_completions_and_tier_bytes(self, tree_kw, seed):
        plane, ref, done_a, done_b = _drive(tree_kw, seed)
        # Completion ORDER and finish TIMES, exactly.
        assert done_a == done_b
        assert plane.completed_transfers == ref.completed_transfers
        # Per-tier byte counters and total delivered bytes, bit-for-bit.
        assert plane.tier_utilization_observed(0.0) == \
            ref.tier_utilization_observed(0.0)
        assert plane.bytes_delivered == ref.bytes_delivered

    @pytest.mark.parametrize("seed", range(2))
    def test_parity_under_static_background(self, seed):
        """Nonzero (static) background scales residual caps identically."""
        plane, ref, done_a, done_b = _drive(TREE_64, seed, n_ops=50, bg=0.3)
        assert done_a == done_b
        assert plane.bytes_delivered == ref.bytes_delivered
        assert plane.tier_utilization_observed(0.0) == \
            ref.tier_utilization_observed(0.0)

    def test_single_flow_transfers(self):
        """n_flows=1 exercises the per-transfer slot maps at minimum width."""
        plane, ref, done_a, done_b = _drive(TREE_64, 11, n_ops=40, n_flows=1)
        assert done_a == done_b
        assert plane.bytes_delivered == ref.bytes_delivered


class TestIncrementalRecompute:
    def test_disjoint_components_skip_recompute(self):
        """A tier-1 arrival in rack A must not move rack B's in-rack rates —
        and the plane must not even recompute them (counter check)."""
        tree = FatTree(**TREE_64)
        plane = FlowPlane(tree, BackgroundTraffic(0.0), seed=0)
        plane.start_transfer((0, 0, 0), (0, 0, 1), 1e9, 0.0, lambda t, n: None)
        rates_before = {f: v.rate for f, v in plane.flows.items()}
        calls = []
        orig = plane._recompute_rates

        def spy(dirty_links=None):
            calls.append(dirty_links)
            return orig(dirty_links=dirty_links)

        plane._recompute_rates = spy
        # Other pod, other rack: link-disjoint from the first transfer.
        plane.start_transfer((1, 1, 0), (1, 1, 1), 1e9, 0.0, lambda t, n: None)
        assert len(calls) == 1 and calls[0] is not None
        for fid, r in rates_before.items():
            assert plane.flows[fid].rate == r

    def test_shared_bottleneck_propagates(self):
        """Two transfers sharing the agg uplink: the second arrival halves
        the first one's rates (the dirty component includes it)."""
        tree = FatTree(n_tor_uplinks=1, n_agg_uplinks=1)
        plane = FlowPlane(tree, BackgroundTraffic(0.0), seed=0)
        plane.start_transfer((0, 0, 0), (1, 0, 0), 1e9, 0.0, lambda t, n: None,
                             n_flows=1)
        (f1,) = plane.flows.values()
        full = f1.rate
        plane.start_transfer((0, 0, 1), (1, 0, 1), 1e9, 0.0, lambda t, n: None,
                             n_flows=1)
        rates = sorted(f.rate for f in plane.flows.values())
        assert rates[0] == rates[1]
        assert abs(rates[0] - full / 2) / (full / 2) < 1e-9
