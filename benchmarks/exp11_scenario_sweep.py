"""Beyond-paper experiment 11: ScenarioPlane fleet-scale what-if sweeps.

A chunk-size × NIC-policy × scheduler × seed grid (36 cells in quick mode,
54 full) runs as **one** batched jitted program via
``sim.scenarios.ScenarioPlane`` and is raced against the serial event-loop
simulator on a subset of the same cells.  Reported:

* per-scenario TTFT/TBT/SLO/goodput rows (the what-if table itself);
* ``batched_sps`` — steady-state scenarios/s of the re-invoked jitted
  sweep (compile time reported separately, amortised across every grid
  this session runs);
* ``serial_sps`` — scenarios/s of ``run_sim`` on the baseline subset.

Acceptance gate (CI): ``batched_sps >= SWEEP_FLOOR * serial_sps``.  The
fluid sweep is a *ranking* model — the event loop stays the ground truth
for absolute paper numbers (see ``sim/scenarios.py``'s modelling
contract) — so the gate is purely about sweep throughput.
"""

from __future__ import annotations

import time

from repro.core.jaxutil import enable_f64
from repro.sim import ScenarioPlane, ScenarioSpec, SimConfig, run_sim
from repro.traces import generate_trace

from .common import emit, write_csv

SCHEDULERS = ["cla", "netkv-static", "netkv-full"]
CHUNKS = [None, 256, 1024]          # None = serial whole-request prefill
NIC_POLICIES = ["hash", "rail-affine"]
SWEEP_FLOOR = 5.0                   # batched_sps >= 5x serial_sps (CI gate)
SERIAL_CELLS = 4                    # event-loop baseline subset size

QUICK = dict(warmup=1.0, measure=4.0, drain=3.0, rps=10.0, seeds=2)
FULL = dict(warmup=2.0, measure=8.0, drain=4.0, rps=12.0, seeds=3)


def _grid(k) -> list[ScenarioSpec]:
    specs = []
    for sched in SCHEDULERS:
        for chunk in CHUNKS:
            for nic in NIC_POLICIES:
                for seed in range(k["seeds"]):
                    specs.append(ScenarioSpec(
                        seed=seed, scheduler=sched, target_rps=k["rps"],
                        warmup=k["warmup"], measure=k["measure"],
                        drain=k["drain"], chunk_tokens=chunk,
                        kv_streaming=chunk is not None, nic_policy=nic,
                        background=0.25))
    return specs


def _serial_baseline(specs, k) -> float:
    """Wall-clock of the event loop over a subset of the same grid cells."""
    subset = specs[:: max(len(specs) // SERIAL_CELLS, 1)][:SERIAL_CELLS]
    t0 = time.perf_counter()
    for sp in subset:
        cfg = SimConfig(
            scheduler=sp.scheduler, seed=sp.seed, warmup=sp.warmup,
            measure=sp.measure, chunk_tokens=sp.chunk_tokens,
            kv_streaming=sp.kv_streaming, nic_policy=sp.nic_policy,
            background=sp.background)
        trace = generate_trace(sp.profile, duration=sp.duration,
                               target_rps=sp.target_rps, seed=sp.seed)
        run_sim(cfg, trace)
    return len(subset) / (time.perf_counter() - t0)


def run(quick: bool = False):
    enable_f64()
    k = QUICK if quick else FULL
    specs = _grid(k)
    assert len(specs) >= 32, "grid must batch >= 32 scenarios"

    t0 = time.perf_counter()
    plane = ScenarioPlane(specs, dt=0.01)
    t_prep = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = plane.sweep()                       # compile + first run
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = plane.sweep()                       # steady state (cached jit)
    t_steady = time.perf_counter() - t0
    batched_sps = len(specs) / t_steady

    serial_sps = _serial_baseline(specs, k)
    speedup = batched_sps / serial_sps

    rows = []
    for i, sp in enumerate(specs):
        rows.append(dict(
            scheduler=sp.scheduler, chunk=sp.chunk_tokens or 0,
            nic_policy=sp.nic_policy, seed=sp.seed,
            n_measured=int(out["n_measured"][i]),
            n_served=int(out["n_served"][i]),
            ttft_mean=float(out["ttft_mean"][i]),
            ttft_p95=float(out["ttft_p95"][i]),
            tbt_mean=float(out["tbt_mean"][i]),
            slo_attainment=float(out["slo_attainment"][i]),
            goodput_rps=float(out["goodput_rps"][i]),
            batched_sps=batched_sps, serial_sps=serial_sps,
            sweep_speedup=speedup))
    write_csv("exp11_scenario_sweep", rows)
    print(f"  exp11: {len(specs)} scenarios in one program | "
          f"prep={t_prep:.2f}s compile={t_compile:.2f}s "
          f"steady={t_steady:.2f}s -> {batched_sps:.1f} scn/s "
          f"vs serial {serial_sps:.2f} scn/s ({speedup:.1f}x)")
    assert speedup >= SWEEP_FLOOR, (
        f"batched sweep {batched_sps:.1f} scn/s is only {speedup:.1f}x the "
        f"serial event loop ({serial_sps:.2f} scn/s); floor is "
        f"{SWEEP_FLOOR:.0f}x")
    return rows, batched_sps, serial_sps, speedup


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows, batched_sps, serial_sps, speedup = run(quick)
    emit("exp11_scenario_sweep", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"scenarios={len(rows)};batched={batched_sps:.1f}scn_s;"
         f"serial={serial_sps:.2f}scn_s;speedup={speedup:.1f}x")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
