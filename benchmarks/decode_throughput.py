"""Instance-plane throughput: cohort-stepped columnar engine vs the retired
per-object reference at 64 / 256 / 1024 decode instances.

Three arms per pool size:

* ``steady``  — every instance runs a full continuous batch (beta = 64) of
  long-output requests; the engines step K iteration rounds.  The reference
  pays one heap event + a Python dict walk per instance per round; the
  plane pays one cohort clock event with fused array accounting.  This is
  the simulator's decode hot path at scale.
* ``churn``   — short outputs with a queued backlog: every round finishes
  and admits requests, exercising finish bookkeeping, queue admission and
  the write-through sync.
* ``hit_row`` — one request scored against every instance's prefix cache:
  the RadixPlane broadcast LCP vs D per-instance ``hit_tokens`` walks (the
  per-decision scheduler cost ClusterView exposed in PR 1).

A fourth, prefill-side arm exercises the ChunkPlane: a submission storm of
mixed-length prompts routed by ``pick_prefill`` and prefilled to completion
— the chunk-interleaved plane (vectorised ETA argmin, one event per
iteration) vs the retired serial reference (per-pick Python queue walks).

Acceptance floors (CI-gated): the plane must hold >= 10x steady
iteration-step throughput at 1024 decode instances, and chunked prefill
must not fall below 1.0x the serial reference path.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.cost import H100_TP4_ITER, H100_TP4_PREFILL, LLAMA3_70B_KV
from repro.core.view import ClusterView
from repro.sim import EventLoop, InstancePlane, ReferenceInstanceEngine, RequestState
from repro.traces.mooncake import Request

from .common import emit, write_csv

SIZES = [64, 256, 1024]
QUICK_SIZES = [64, 1024]    # CI smoke reaches the acceptance size
BETA = 64                   # full continuous batch per instance
ROUNDS = 10                 # iteration rounds timed per arm
SPEEDUP_FLOOR = 10.0        # required plane/reference ratio at 1024
CHURN_FLOOR = 1.0           # vectorised epoch-batched admission gate at 1024
CHUNK_FLOOR = 1.0           # chunked plane vs serial reference prefill gate
PREFILL_N = 8               # prefill pool size for the chunked arm
PREFILL_REQS = 600          # submission-storm size
CHUNK_TOKENS = 512
CHUNK_BUDGET = 4096


class _Meta:
    def __init__(self, iid, srv):
        self.instance_id, self.server = iid, srv


def _mk_engine(kind: str, n_dec: int):
    loop = EventLoop()
    view = ClusterView(capacity=n_dec)
    dec = [_Meta(i, (0, 0, i)) for i in range(n_dec)]
    cls = InstancePlane if kind == "plane" else ReferenceInstanceEngine
    eng = cls([], dec, view=view, loop=loop, iter_model=H100_TP4_ITER,
              prefill_model=H100_TP4_PREFILL, beta_max=BETA,
              kv_spec=LLAMA3_70B_KV, kv_budget=1e18)
    eng.set_decode_callbacks(None, None)
    return loop, eng


def _req(rid: int, output_len: int, blocks: int = 4) -> RequestState:
    req = Request(request_id=rid, arrival=0.0, input_len=blocks * 16,
                  output_len=output_len,
                  block_hashes=tuple((rid, j) for j in range(blocks)),
                  share_group=-1, slo=5.0)
    return RequestState(req=req, kv_bytes=1e6)


def _populate(eng, n_dec: int, per_inst: int, output_len: int):
    rid = 0
    for i in range(n_dec):
        for _ in range(per_inst):
            eng.enqueue(i, _req(rid, output_len), 0.0)
            rid += 1
    eng.kick(range(n_dec), 0.0)


def _steady(kind: str, n_dec: int) -> float:
    """Wall seconds for ROUNDS synchronized full-batch iteration rounds."""
    loop, eng = _mk_engine(kind, n_dec)
    _populate(eng, n_dec, BETA, output_len=10**9)
    horizon = ROUNDS * H100_TP4_ITER(BETA) * 1.001
    t0 = time.perf_counter()
    loop.run(until=horizon)
    wall = time.perf_counter() - t0
    assert eng.total_iterations == n_dec * ROUNDS
    return wall


def _churn(kind: str, n_dec: int) -> float:
    """Wall seconds for ROUNDS rounds of finish-heavy decoding with a
    queued backlog (every round retires and admits a slice of the batch)."""
    loop, eng = _mk_engine(kind, n_dec)
    # Outputs 1..4 tokens: a quarter of the batch turns over each round.
    rid = 0
    for i in range(n_dec):
        for b in range(BETA * 2):       # half active, half queued backlog
            eng.enqueue(i, _req(rid, output_len=(b % 4) + 1), 0.0)
            rid += 1
    eng.kick(range(n_dec), 0.0)
    horizon = ROUNDS * H100_TP4_ITER(BETA) * 1.001
    t0 = time.perf_counter()
    loop.run(until=horizon)
    return time.perf_counter() - t0


def _hit_row(kind: str, n_dec: int, blocks: int = 128, reps: int = 20) -> float:
    """Per-decision scoring cost: one request vs every instance's cache.

    Every instance caches a random-depth slice of one shared prefix chain
    and the probe asks for the full chain, so each per-instance LCP walk
    (and the broadcast comparison) has real depth — the prefix-reuse regime
    the scheduler actually scores in, not the all-miss fast exit.
    """
    _, eng = _mk_engine(kind, n_dec)
    rng = np.random.default_rng(0)
    shared = tuple(("shared", j) for j in range(blocks))
    for i in range(n_dec):
        depth = int(rng.integers(blocks // 4, blocks + 1))
        req = Request(request_id=10_000 + i, arrival=0.0,
                      input_len=depth * 16, output_len=10**9,
                      block_hashes=shared[:depth], share_group=0, slo=5.0)
        eng.enqueue(i, RequestState(req=req, kv_bytes=1e6), 0.0)
    probe = Request(request_id=99_999, arrival=0.0, input_len=blocks * 16,
                    output_len=8, block_hashes=shared, share_group=0, slo=5.0)
    eng.fill_hits(probe)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        eng.fill_hits(probe)
    return (time.perf_counter() - t0) / reps


def _prefill_arm(kind: str, chunked: bool, n_req: int = PREFILL_REQS) -> float:
    """Wall seconds to route (pick_prefill) and fully prefill a submission
    storm of mixed-length prompts on an 8-instance pool."""
    loop = EventLoop()
    view = ClusterView(capacity=1)
    pre = [_Meta(i, (0, 0, i)) for i in range(PREFILL_N)]
    cls = InstancePlane if kind == "plane" else ReferenceInstanceEngine
    eng = cls(pre, [], view=view, loop=loop, iter_model=H100_TP4_ITER,
              prefill_model=H100_TP4_PREFILL, beta_max=BETA,
              kv_spec=LLAMA3_70B_KV, kv_budget=1e18,
              chunk_tokens=CHUNK_TOKENS if chunked else None,
              prefill_token_budget=CHUNK_BUDGET if chunked else None)
    done = []
    eng.on_prefill_done = lambda rs, now: done.append(rs)
    rss = [
        RequestState(
            req=Request(request_id=i, arrival=0.0,
                        input_len=1024 + (i % 7) * 512, output_len=1,
                        block_hashes=((i, 0),), share_group=-1, slo=5.0),
            kv_bytes=1e6,
        )
        for i in range(n_req)
    ]
    t0 = time.perf_counter()
    for rs in rss:
        eng.pick_prefill(0.0).submit(rs, 0.0)
    loop.run()
    wall = time.perf_counter() - t0
    assert len(done) == n_req
    return wall


def run(quick: bool = False) -> list[dict]:
    sizes = QUICK_SIZES if quick else SIZES
    rows = []
    for n in sizes:
        row = dict(decode_instances=n)
        plane_s = _steady("plane", n)
        ref_s = _steady("reference", n)
        row["plane_steady_iters_per_s"] = n * ROUNDS / plane_s
        row["ref_steady_iters_per_s"] = n * ROUNDS / ref_s
        row["steady_speedup"] = ref_s / plane_s
        row["plane_churn_s"] = _churn("plane", n)
        row["ref_churn_s"] = _churn("reference", n)
        row["churn_speedup"] = row["ref_churn_s"] / row["plane_churn_s"]
        row["plane_hit_row_us"] = _hit_row("plane", n) * 1e6
        row["ref_hit_row_us"] = _hit_row("reference", n) * 1e6
        row["hit_row_speedup"] = row["ref_hit_row_us"] / row["plane_hit_row_us"]
        print(f"  decode_throughput D={n}: steady {row['steady_speedup']:.1f}x "
              f"({row['plane_steady_iters_per_s']:.0f} vs "
              f"{row['ref_steady_iters_per_s']:.0f} inst-iter/s) "
              f"churn {row['churn_speedup']:.1f}x "
              f"hit_row {row['hit_row_speedup']:.1f}x")
        rows.append(row)
    # ChunkPlane prefill arm (pool-size independent, run once).
    prow = dict(decode_instances=0, arm="chunked_prefill",
                n_requests=PREFILL_REQS)
    prow["plane_chunked_prefill_s"] = _prefill_arm("plane", chunked=True)
    prow["ref_serial_prefill_s"] = _prefill_arm("reference", chunked=False)
    prow["chunked_prefill_speedup"] = (
        prow["ref_serial_prefill_s"] / prow["plane_chunked_prefill_s"])
    print(f"  decode_throughput prefill: chunked plane "
          f"{prow['chunked_prefill_speedup']:.1f}x vs serial reference "
          f"({prow['plane_chunked_prefill_s']*1e3:.0f}ms vs "
          f"{prow['ref_serial_prefill_s']*1e3:.0f}ms, {PREFILL_REQS} reqs)")
    rows.append(prow)
    write_csv("decode_throughput", rows)
    assert prow["chunked_prefill_speedup"] >= CHUNK_FLOOR, (
        f"ChunkPlane prefill {prow['chunked_prefill_speedup']:.2f}x vs the "
        f"serial reference is below the {CHUNK_FLOOR:.1f}x floor")
    # Acceptance gates, enforced wherever the 1024 arm runs (incl. CI smoke).
    for r in rows:
        if r["decode_instances"] >= 1024:
            assert r["steady_speedup"] >= SPEEDUP_FLOOR, (
                f"InstancePlane steady speedup {r['steady_speedup']:.1f}x at "
                f"{r['decode_instances']} instances is below the "
                f"{SPEEDUP_FLOOR:.0f}x floor")
            # Vectorised epoch-batched admission: the finish-heavy churn arm
            # must not be slower than the per-object reference.
            assert r["churn_speedup"] >= CHURN_FLOOR, (
                f"InstancePlane churn speedup {r['churn_speedup']:.2f}x at "
                f"{r['decode_instances']} instances is below the "
                f"{CHURN_FLOOR:.1f}x admission floor")
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    best = max((r for r in rows if "steady_speedup" in r),
               key=lambda r: r["decode_instances"])
    chunk = next(r for r in rows if r.get("arm") == "chunked_prefill")
    emit("decode_throughput", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"D{best['decode_instances']}:steady={best['steady_speedup']:.0f}x,"
         f"churn={best['churn_speedup']:.1f}x,"
         f"hit_row={best['hit_row_speedup']:.1f}x,"
         f"chunked_prefill={chunk['chunked_prefill_speedup']:.1f}x")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
