"""Beyond-paper experiments: (a) batch-level joint assignment (§VII-C future
work), (b) EWMA predictive congestion, (c) straggler-aware scoring, (d)
fault-injection resilience across the ladder."""

from __future__ import annotations

import time

from repro.sim import FaultEvent, SimConfig, run_sim
from repro.sim.metrics import aggregate_seeds
from repro.traces import generate_trace, profile_capacity

from .common import emit, knobs, write_csv


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    cap = profile_capacity("rag")
    rows = []

    def point(sched, label, cfg_extra=None, rate=1.6):
        runs = []
        for seed in range(k["seeds"]):
            trace = generate_trace("rag", duration=k["duration"],
                                   target_rps=cap * rate, seed=seed)
            cfg = SimConfig(scheduler=sched, seed=seed, background=0.25,
                            bg_wander=0.5, warmup=k["warmup"],
                            measure=k["measure"], **(cfg_extra or {}))
            runs.append(run_sim(cfg, trace))
        row = aggregate_seeds(runs)
        row["variant"] = label
        rows.append(row)
        print(f"  exp8 {label}: ttft={row['ttft_mean']*1e3:.0f}ms "
              f"slo={row['slo_attainment']:.3f}")
        return row

    # (a)+(b): the beyond-paper policies vs the paper's best
    point("netkv-full", "netkv-full(paper)")
    point("netkv-batch", "netkv-batch(beyond)")
    point("netkv-pred", "netkv-pred(beyond)")
    # (d) fault resilience: kill a decode instance mid-run
    faults = [FaultEvent(time=6.0, kind="kill_decode", instance_id=5)]
    point("cla", "cla+fault", {"faults": faults}, rate=1.0)
    point("netkv-full", "netkv-full+fault", {"faults": faults}, rate=1.0)
    # (c) straggler: slow an instance 4x
    slow = [FaultEvent(time=0.5, kind="slowdown", instance_id=7, factor=4.0)]
    point("netkv-full", "netkv-full+straggler", {"faults": slow}, rate=1.0)
    write_csv("exp8_beyond", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    by = {r["variant"]: r for r in rows}
    base = by["netkv-full(paper)"]["ttft_mean"]
    batch = (1 - by["netkv-batch(beyond)"]["ttft_mean"] / base) * 100
    pred = (1 - by["netkv-pred(beyond)"]["ttft_mean"] / base) * 100
    emit("exp8_beyond", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"batch={batch:+.1f}%;pred={pred:+.1f}%")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
