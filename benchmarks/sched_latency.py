"""Scheduler decision latency vs pool size: the retired per-candidate Python
loop vs the vectorised ClusterView scorer vs the Pallas ``netkv_score``
kernel (interpret mode on CPU) vs the jitted JAX scorer.

Paper reference point: <1.5 ms per decision at 1024 GPUs (256 decode
instances).  The vectorised NumPy path must beat the Python loop by >=5x at
1008 candidates; the JAX scorer must stay microseconds out to 16k instances.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    CandidateState,
    ClusterView,
    CohortItem,
    H100_TP4_ITER,
    RequestInfo,
    SelfContentionTracker,
    make_reference_scheduler,
    make_scheduler,
)
from repro.core.netkv_jax import JaxNetKV, PoolArrays
from repro.core.oracle import OracleView, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY

from .common import emit, write_csv

# D sweep for the 3-way comparison (48 / 240 / 1008 = 1024-GPU-class pools);
# the two largest pools run the vectorised + jitted paths only.
POOLS = [48, 240, 1008]
POOLS_BIG = [4096, 16384]

# DispatchPlane cohort arm: same-timestamp cohorts of R requests against
# D-wide pools, per-request select() vs one CohortSelector walk.  CI gates
# the 64-request / 2048-candidate point at COHORT_FLOOR x.
COHORT_SIZES = [1, 16, 64]
COHORT_POOLS = [1008, 2048]
COHORT_FLOOR = 3.0

REQ = RequestInfo(0, 8192, 8192 * 320 * 1024)


def _pool(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    cands = [
        CandidateState(i, float(rng.uniform(1e10, 4e11)),
                       int(rng.integers(0, 8)), int(rng.integers(0, 64)),
                       float(rng.integers(0, 8192)))
        for i in range(n)
    ]
    tiers = rng.integers(0, 4, n)
    view = OracleView(lambda p, d: int(tiers[d % n]), PAPER_TIER_BANDWIDTH,
                      PAPER_TIER_LATENCY, {t: 0.2 for t in range(4)})
    cv = ClusterView.from_candidates(cands, tier_fn=view.tier_of)
    cv.tier_row(0)  # warm the static row cache, as the simulator's view has
    return cands, cv, view


def _time_select(sched, target, view, reps: int) -> float:
    sched.select(REQ, 0, target, view, None)  # warm (jit/interpret compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        sched.select(REQ, 0, target, view, None)
    return (time.perf_counter() - t0) / reps


def micro_latency(pools=POOLS, with_pallas: bool = True, seed: int = 0) -> list[dict]:
    """Per-decision latency of netkv-full under each scoring path."""
    rows = []
    for n in pools:
        cands, cv, view = _pool(n, seed)
        reps = max(200 // max(n // 64, 1), 5)
        t_py = _time_select(
            make_reference_scheduler("netkv-full", H100_TP4_ITER, 64),
            cands, view, reps)
        t_np = _time_select(
            make_scheduler("netkv-full", H100_TP4_ITER, 64), cv, view, 200)
        row = dict(pool=n, python_ms=t_py * 1e3, numpy_ms=t_np * 1e3,
                   speedup=t_py / t_np)
        if with_pallas:
            t_pl = _time_select(
                make_scheduler("netkv-full", H100_TP4_ITER, 64, backend="pallas"),
                cv, view, 20)
            row["pallas_ms"] = t_pl * 1e3
        rows.append(row)
    return rows


def _cohort_case(n: int, r: int, seed: int = 0):
    """One cohort scenario: R dispatch-ready requests, random prefix hits,
    mixed prefill sources, against a D-wide pool snapshot."""
    rng = np.random.default_rng(seed + 7 * n + r)
    _, cv, view = _pool(n, seed)
    kv = REQ.kv_bytes
    # Prefill pool scales with the cluster (the sim's 1:3 prefill:decode
    # split gives ~n/3 sources; keep a conservative n/32 here so some
    # same-source invalidation still exercises the fallback path).
    n_src = max(8, n // 32)
    items = [
        CohortItem(RequestInfo(k, REQ.input_len, kv),
                   int(rng.integers(0, n_src)))
        for k in range(r)
    ]
    H = rng.integers(0, REQ.input_len, (r, n)).astype(np.float64)
    return cv, view, items, H


def _run_sequential(sched, cv, view, items, H, infl):
    """Per-request dispatch: fill the hit column, select, apply the delta."""
    n = cv.n
    out = []
    for k, it in enumerate(items):
        cv.hit_tokens[:n] = H[k]
        d = sched.select(it.req, it.prefill_id, cv, view, infl)
        out.append(d)
        if d is not None:
            cv.apply_assignment(cv.slot_of(d.instance_id), kv_bytes=d.s_eff)
    return out


def _run_cohort(sched, cv, view, items, H, infl):
    """DispatchPlane: one fused R x D precompute, then the argmin-row walk."""
    sel = sched.select_cohort(items, cv, view, infl, hit_matrix=H)
    out = []
    for k in range(len(items)):
        d = sel.select_row(k)
        out.append(d)
        if d is not None:
            cv.apply_assignment(cv.slot_of(d.instance_id), kv_bytes=d.s_eff)
    return out


def cohort_latency(pools=COHORT_POOLS, sizes=COHORT_SIZES,
                   seed: int = 0) -> list[dict]:
    """Per-decision latency: sequential select() vs the CohortSelector walk,
    with a bit-exact decision-parity check on every (pool, cohort) point."""
    rows = []
    for n in pools:
        for r in sizes:
            cv, view, items, H = _cohort_case(n, r, seed)
            free0 = cv.free_memory[: cv.n].copy()

            def arm(runner, reps):
                # Best-of-reps: each rep replays the same cohort from the same
                # pool state, so min is the noise-free per-decision latency.
                best = float("inf")
                for rep in range(reps):
                    cv.free_memory[: cv.n] = free0
                    sched = make_scheduler("netkv-full", H100_TP4_ITER, 64,
                                           seed=seed)
                    infl = SelfContentionTracker()
                    t0 = time.perf_counter()
                    out = runner(sched, cv, view, items, H, infl)
                    best = min(best, time.perf_counter() - t0)
                return out, best / r

            reps = max(5, 160 // r)
            seq, t_seq = arm(_run_sequential, reps)
            coh, t_coh = arm(_run_cohort, reps)
            assert seq == coh, (
                f"cohort decisions diverged from sequential at n={n} R={r}")
            rows.append(dict(pool=n, cohort=r, seq_us=t_seq * 1e6,
                             cohort_us=t_coh * 1e6, speedup=t_seq / t_coh))
    return rows


def run(quick: bool = False) -> list[dict]:
    # quick (the CI smoke) skips the interpret-mode Pallas arm: it measures
    # interpreter overhead, not a regression signal, and dominates wall-clock.
    rows = micro_latency(POOLS, with_pallas=not quick)
    # Jitted JAX scorer: steady state, compile excluded.
    jx = JaxNetKV(H100_TP4_ITER, 64)
    for row in rows:
        _, cv, view = _pool(row["pool"])
        pa = PoolArrays.from_view(cv, 0)
        jx.select_arrays(pa, REQ.kv_bytes, REQ.input_len, view, [0] * 4)
        t0 = time.perf_counter()
        for _ in range(50):
            jx.select_arrays(pa, REQ.kv_bytes, REQ.input_len, view, [0] * 4)
        row["jax_ms"] = (time.perf_counter() - t0) / 50 * 1e3
    if not quick:
        for n in POOLS_BIG:
            _, cv, view = _pool(n)
            t_np = _time_select(
                make_scheduler("netkv-full", H100_TP4_ITER, 64), cv, view, 100)
            pa = PoolArrays.from_view(cv, 0)
            jx.select_arrays(pa, REQ.kv_bytes, REQ.input_len, view, [0] * 4)
            t0 = time.perf_counter()
            for _ in range(50):
                jx.select_arrays(pa, REQ.kv_bytes, REQ.input_len, view, [0] * 4)
            rows.append(dict(pool=n, python_ms=float("nan"),
                             numpy_ms=t_np * 1e3, speedup=float("nan"),
                             pallas_ms=float("nan"),
                             jax_ms=(time.perf_counter() - t0) / 50 * 1e3))
    for r in rows:
        print(f"  sched_latency n={r['pool']}: python={r['python_ms']:.3f}ms "
              f"numpy={r['numpy_ms']:.3f}ms pallas={r.get('pallas_ms', float('nan')):.3f}ms "
              f"jax={r['jax_ms']:.3f}ms speedup={r['speedup']:.1f}x")
    write_csv("sched_latency", rows)
    crows = cohort_latency()
    for r in crows:
        print(f"  sched_latency cohort n={r['pool']} R={r['cohort']}: "
              f"seq={r['seq_us']:.1f}us cohort={r['cohort_us']:.1f}us "
              f"speedup={r['speedup']:.2f}x")
    write_csv("sched_latency_cohort", crows)
    gate = next(r for r in crows
                if r["pool"] == 2048 and r["cohort"] == 64)
    if gate["speedup"] < COHORT_FLOOR:
        raise SystemExit(
            f"cohort dispatch regression: {gate['speedup']:.2f}x at "
            f"R=64/D=2048, floor {COHORT_FLOOR}x")
    return rows + crows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    big = next(r for r in rows if r["pool"] == 1008)
    coh = next(r for r in rows if r.get("cohort") == 64 and r["pool"] == 2048)
    emit("sched_latency", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"pool{big['pool']}:py={big['python_ms']:.2f}ms,"
         f"np={big['numpy_ms']:.3f}ms,{big['speedup']:.0f}x,"
         f"cohort64@2048:{coh['speedup']:.1f}x")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
