"""Scheduler decision latency vs pool size: Python Alg. 1 loop vs the
vectorised JAX scorer vs the Pallas kernel (interpret mode on CPU).

Paper reference point: <1.5 ms per decision at 1024 GPUs (256 decode
instances).  The JAX scorer must stay microseconds out to 16k instances."""

from __future__ import annotations

import time

import numpy as np

from repro.core import CandidateState, H100_TP4_ITER, RequestInfo, make_scheduler
from repro.core.netkv_jax import JaxNetKV, PoolArrays
from repro.core.oracle import OracleView, PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY

from .common import emit, write_csv

POOLS = [12, 64, 256, 1024, 4096, 16384]


def run(quick: bool = False) -> list[dict]:
    pools = POOLS[:4] if quick else POOLS
    rng = np.random.default_rng(0)
    req = RequestInfo(0, 8192, 8192 * 320 * 1024)
    rows = []
    for n in pools:
        cands = [CandidateState(i, float(rng.uniform(1e10, 4e11)),
                                int(rng.integers(0, 8)), int(rng.integers(0, 64)),
                                float(rng.integers(0, 8192)))
                 for i in range(n)]
        tiers = rng.integers(0, 4, n)
        view = OracleView(lambda p, d: int(tiers[d % n]), PAPER_TIER_BANDWIDTH,
                          PAPER_TIER_LATENCY, {t: 0.2 for t in range(4)})
        # python loop
        py = make_scheduler("netkv-full", H100_TP4_ITER, 64)
        reps = max(200 // max(n // 64, 1), 5)
        t0 = time.perf_counter()
        for _ in range(reps):
            py.select(req, 0, cands, view, None)
        t_py = (time.perf_counter() - t0) / reps
        # jitted scorer (steady state: exclude compile)
        jx = JaxNetKV(H100_TP4_ITER, 64)
        pool = PoolArrays.from_candidates(cands, tiers)
        jx.select_arrays(pool, req.kv_bytes, req.input_len, view, [0] * 4)
        t0 = time.perf_counter()
        for _ in range(50):
            jx.select_arrays(pool, req.kv_bytes, req.input_len, view, [0] * 4)
        t_jax = (time.perf_counter() - t0) / 50
        rows.append(dict(pool=n, python_ms=t_py * 1e3, jax_ms=t_jax * 1e3))
        print(f"  sched_latency n={n}: python={t_py*1e3:.3f}ms jax={t_jax*1e3:.3f}ms")
    write_csv("sched_latency", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    big = rows[-1]
    emit("sched_latency", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"pool{big['pool']}:py={big['python_ms']:.2f}ms,jax={big['jax_ms']:.2f}ms")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
