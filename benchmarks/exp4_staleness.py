"""Experiment 4 (Fig. 2): oracle staleness sweep 100 ms -> 60 s.
TTFT/TBT/SLO must be essentially invariant (Prop. 2 + static-tier dominance)."""

from __future__ import annotations

import time

from .common import emit, knobs, run_point, write_csv

INTERVALS = [0.1, 1.0, 10.0, 60.0]
SCHEDULERS = ["cla", "netkv-static", "netkv-full"]


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    intervals = [0.1, 60.0] if quick else INTERVALS
    scheds = ["cla", "netkv-full"] if quick else SCHEDULERS
    rows = []
    for dt in intervals:
        for sched in scheds:
            row = run_point(sched, "rag", seeds=k["seeds"], duration=k["duration"],
                            warmup=k["warmup"], measure=k["measure"],
                            cfg_kw={"background": 0.2, "oracle_refresh": dt,
                                    "bg_wander": 0.4})
            row["oracle_refresh"] = dt
            rows.append(row)
            print(f"  exp4 dt={dt}s {sched}: ttft={row['ttft_mean']*1e3:.0f}ms")
    write_csv("exp4_staleness", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    nk = [r for r in rows if r["scheduler"] == "netkv-full"]
    spread = (max(r["ttft_mean"] for r in nk) - min(r["ttft_mean"] for r in nk)) / \
        min(r["ttft_mean"] for r in nk) * 100
    emit("exp4_staleness", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"ttft_spread_over_refresh={spread:.1f}%")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
