"""Experiment 4 (Fig. 2): oracle staleness sweep 100 ms -> 60 s.
TTFT/TBT/SLO must be essentially invariant (Prop. 2 + static-tier dominance).

Telemetry-noise axis: alongside the background model's ground truth
(``telemetry="model"``), the NetKV rows are repeated with
``telemetry="measured"`` — per-tier congestion aggregated from the
FlowPlane's per-link byte counters, *including* the scheduler's own KV
traffic (``NetworkCostOracle(source="measured")``).  Prop. 2's staleness
robustness should carry over to the noisier measured signal: tier rankings
survive both the self-traffic feedback and the refresh lag."""

from __future__ import annotations

import time

from .common import emit, knobs, run_point, write_csv

INTERVALS = [0.1, 1.0, 10.0, 60.0]
SCHEDULERS = ["cla", "netkv-static", "netkv-full"]
SOURCES = ["model", "measured"]


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    intervals = [0.1, 60.0] if quick else INTERVALS
    scheds = ["cla", "netkv-full"] if quick else SCHEDULERS
    rows = []
    for dt in intervals:
        for sched in scheds:
            # cla ignores the congestion signal entirely; netkv-static reads
            # only static tier scalars — the measured arm is meaningful for
            # the congestion-aware rung.
            sources = SOURCES if sched == "netkv-full" else ["model"]
            for src in sources:
                row = run_point(sched, "rag", seeds=k["seeds"],
                                duration=k["duration"], warmup=k["warmup"],
                                measure=k["measure"],
                                cfg_kw={"background": 0.2, "oracle_refresh": dt,
                                        "bg_wander": 0.4,
                                        "telemetry_source": src})
                row["oracle_refresh"] = dt
                row["telemetry"] = src
                rows.append(row)
                print(f"  exp4 dt={dt}s {sched} [{src}]: "
                      f"ttft={row['ttft_mean']*1e3:.0f}ms")
    write_csv("exp4_staleness", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    spreads = []
    for src in SOURCES:
        nk = [r for r in rows
              if r["scheduler"] == "netkv-full" and r["telemetry"] == src]
        if not nk:
            continue
        spread = (max(r["ttft_mean"] for r in nk) -
                  min(r["ttft_mean"] for r in nk)) / \
            min(r["ttft_mean"] for r in nk) * 100
        spreads.append(f"{src}={spread:.1f}%")
    emit("exp4_staleness", (time.time() - t0) * 1e6 / max(len(rows), 1),
         "ttft_spread_over_refresh:" + ";".join(spreads))


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
