"""Experiment 7 (Table V / Fig. 5): cluster scaling 64 -> 4096 GPUs
(flow-level), NetKV-vs-CLA* gap + transfer-time divergence + simulator
throughput (events/s, sim-seconds per wall-second — the FlowPlane's
scaling headroom) + scheduler decision latency (retired Python loop vs
vectorised ClusterView scorer vs the Pallas netkv_score kernel)."""

from __future__ import annotations

import time

import numpy as np

from repro.sim import SimConfig, run_sim
from repro.sim.metrics import aggregate_seeds
from repro.traces import generate_trace, profile_capacity

from .common import emit, knobs, write_csv

# (gpus, pods, racks/pod, servers/rack): 8 GPUs/server throughout.
# Racks scale within 2 pods so the packed prefill pool never swallows a
# whole pod (that would leave only tier-3 candidates and collapse every
# scheduler onto the same degenerate choice).  The 2048/4096 rows are
# FlowPlane territory: the retired per-object network model capped this
# sweep at 1024.
SCALES = [(64, 2, 2, 2), (128, 2, 4, 2), (256, 2, 8, 2), (512, 2, 16, 2),
          (1024, 2, 32, 2), (2048, 2, 64, 2), (4096, 2, 128, 2)]

# CI gate: EventPlane events/s on the 2048-GPU headline row must stay at
# least this multiple of the retired per-event heap engine
# (event_engine="reference") on the identical drive.  Local runs land
# ~3.5-4x; the floor is set conservatively (same pattern as CHURN_FLOOR /
# SPEEDUP_FLOOR in net_throughput).
EVENTS_FLOOR = 2.0

# CI gate: turning TracePlane on (spans + per-decision forensics) may cost
# at most this slowdown factor on the same 2048-GPU drive — tracing must
# stay cheap enough to leave on during triage runs.
TRACE_OVERHEAD_CAP = 1.10


def _headline_point():
    """The 2048-GPU gate row's shape + offered load."""
    gpus, pods, racks, servers = next(s for s in SCALES if s[0] == 2048)
    n_prefill = max(gpus // 64, 1) * 4
    n_decode = gpus // 4 - n_prefill
    cap = profile_capacity("rag", n_prefill=n_prefill, n_decode=n_decode,
                           tor_egress_bytes_per_s=8 * 50e9 / 8 * max(gpus // 64, 1))
    return gpus, pods, racks, servers, n_prefill, cap


def _event_engine_gate(k: dict) -> list[dict]:
    """Time the 2048-GPU netkv-full row under both event engines.

    The floor is a *traced-off* contract: an active ``--trace`` session is
    paused around the timed arms so the gate keeps measuring the same
    configuration CI has always gated on."""
    gpus, pods, racks, servers, n_prefill, cap = _headline_point()
    from repro.sim import Simulation, trace_session

    sess = trace_session()
    if sess is not None:
        sess.paused = True
    rows = []
    try:
        for engine in ("plane", "reference"):
            trace = generate_trace("rag", duration=k["duration"], target_rps=cap,
                                   seed=0)
            cfg = SimConfig(scheduler="netkv-full", seed=0, background=0.2,
                            n_pods=pods, racks_per_pod=racks,
                            servers_per_rack=servers, n_prefill=n_prefill,
                            warmup=k["warmup"], measure=k["measure"],
                            event_engine=engine)
            sim = Simulation(cfg)
            t0 = time.perf_counter()
            sim.run(trace)
            wall = time.perf_counter() - t0
            rows.append(dict(axis="event_engine", gpus=gpus, engine=engine,
                             events=int(sim.loop.processed), wall_s=wall,
                             events_per_s=sim.loop.processed / max(wall, 1e-9)))
    finally:
        if sess is not None:
            sess.paused = False
    ratio = rows[0]["events_per_s"] / max(rows[1]["events_per_s"], 1e-9)
    for r in rows:
        r["plane_vs_reference"] = ratio
    print(f"  exp7 event-engine 2048gpus: plane={rows[0]['events_per_s']:.0f}ev/s "
          f"reference={rows[1]['events_per_s']:.0f}ev/s ({ratio:.1f}x)")
    assert ratio >= EVENTS_FLOOR, (
        f"EventPlane throughput regressed: {ratio:.2f}x < {EVENTS_FLOOR}x "
        f"the reference engine on the 2048-GPU row")
    return rows


def _trace_overhead_gate(k: dict) -> list[dict]:
    """Traced-on vs traced-off events/s on the 2048-GPU plane row.

    Best-of-2 per arm (the gate bounds overhead, not noise); tracing is
    controlled explicitly per ``SimConfig`` with any ``--trace`` session
    paused, so the two arms differ only in TracePlane emission."""
    gpus, pods, racks, servers, n_prefill, cap = _headline_point()
    from repro.sim import Simulation, trace_session

    sess = trace_session()
    if sess is not None:
        sess.paused = True
    rows = []
    best = {False: 0.0, True: 0.0}
    try:
        for traced in (False, True):
            for rep in range(2):
                trace = generate_trace("rag", duration=k["duration"],
                                       target_rps=cap, seed=0)
                cfg = SimConfig(scheduler="netkv-full", seed=0, background=0.2,
                                n_pods=pods, racks_per_pod=racks,
                                servers_per_rack=servers, n_prefill=n_prefill,
                                warmup=k["warmup"], measure=k["measure"],
                                trace=traced)
                sim = Simulation(cfg)
                t0 = time.perf_counter()
                sim.run(trace)
                wall = time.perf_counter() - t0
                evs = sim.loop.processed / max(wall, 1e-9)
                best[traced] = max(best[traced], evs)
                rows.append(dict(axis="trace_overhead", gpus=gpus,
                                 traced=traced, rep=rep, wall_s=wall,
                                 events=int(sim.loop.processed),
                                 events_per_s=evs,
                                 spans=len(sim.trace.spans()) if sim.trace else 0))
    finally:
        if sess is not None:
            sess.paused = False
    overhead = best[False] / max(best[True], 1e-9)
    for r in rows:
        r["traced_overhead_x"] = overhead
    print(f"  exp7 trace-overhead 2048gpus: off={best[False]:.0f}ev/s "
          f"on={best[True]:.0f}ev/s ({(overhead - 1) * 100:.1f}% overhead)")
    assert overhead <= TRACE_OVERHEAD_CAP, (
        f"TracePlane overhead regressed: {overhead:.2f}x > "
        f"{TRACE_OVERHEAD_CAP}x on the 2048-GPU row")
    return rows


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    # quick keeps the two smallest scales plus the 2048-GPU headline row
    # (sub-second per seed under quick knobs) as the CI smoke.
    if quick:
        scales = SCALES[:2] + [next(s for s in SCALES if s[0] == 2048)]
    else:
        scales = SCALES
    rows = []
    for gpus, pods, racks, servers in scales:
        n_inst = gpus // 4 // 8  # keep prefill:decode = 1:3 per 16 instances
        n_prefill = max(gpus // 64, 1) * 4
        n_decode = gpus // 4 - n_prefill
        cap = profile_capacity("rag", n_prefill=n_prefill, n_decode=n_decode,
                               tor_egress_bytes_per_s=8 * 50e9 / 8 * max(gpus // 64, 1))
        # The fabric-capped offered load stops growing past ~1024 GPUs, so
        # extra seeds add little signal at the largest scales — 2 keep the
        # 2048/4096 rows CI-feasible.
        n_seeds = k["seeds"] if gpus < 2048 else min(k["seeds"], 2)
        for sched in ["cla", "netkv-full"]:
            runs = []
            lat = []
            events = sim_secs = wall = 0.0
            decode_iters = 0
            for seed in range(n_seeds):
                trace = generate_trace("rag", duration=k["duration"],
                                       target_rps=cap, seed=seed)
                cfg = SimConfig(scheduler=sched, seed=seed, background=0.2,
                                n_pods=pods, racks_per_pod=racks,
                                servers_per_rack=servers, n_prefill=n_prefill,
                                warmup=k["warmup"], measure=k["measure"])
                from repro.sim import Simulation

                sim = Simulation(cfg)
                t0 = time.perf_counter()
                runs.append(sim.run(trace))
                wall += time.perf_counter() - t0
                events += sim.loop.processed
                sim_secs += sim.loop.now
                decode_iters += sim.engine.total_iterations
                lat.extend(sim.decision_latencies)
            row = aggregate_seeds(runs)
            row.update(gpus=gpus, n_decode=n_decode,
                       decision_latency_ms=float(np.mean(lat)) * 1e3,
                       decision_latency_p99_ms=float(np.percentile(lat, 99)) * 1e3,
                       events_per_s=events / max(wall, 1e-9),
                       sim_s_per_wall_s=sim_secs / max(wall, 1e-9),
                       decode_iters_per_s=decode_iters / max(wall, 1e-9))
            rows.append(row)
            print(f"  exp7 {gpus}gpus {sched}: ttft={row['ttft_mean']*1e3:.0f}ms "
                  f"xfer={row['xfer_mean']*1e3:.0f}ms "
                  f"lat={row['decision_latency_ms']:.3f}ms "
                  f"{row['events_per_s']:.0f}ev/s "
                  f"{row['decode_iters_per_s']:.0f}dec-iter/s "
                  f"{row['sim_s_per_wall_s']:.1f}x realtime")
    write_csv("exp7_scalability", rows)
    write_csv("exp7_event_engine", _event_engine_gate(k))
    write_csv("exp7_trace_overhead", _trace_overhead_gate(k))
    # Per-decision scoring-path comparison at 1024-GPU-class pool sizes:
    # python loop vs vectorised NumPy vs Pallas kernel (interpret on CPU).
    from .sched_latency import micro_latency

    micro = micro_latency(with_pallas=not quick)
    for r in micro:
        print(f"  exp7 decision-latency D={r['pool']}: "
              f"python={r['python_ms']:.3f}ms numpy={r['numpy_ms']:.3f}ms "
              f"({r['speedup']:.1f}x)")
    write_csv("exp7_decision_latency", micro)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    by = {}
    for r in rows:
        by.setdefault(r["gpus"], {})[r["scheduler"]] = r
    parts = []
    for g, d in sorted(by.items()):
        delta = (1 - d["netkv-full"]["ttft_mean"] / d["cla"]["ttft_mean"]) * 100
        parts.append(f"{g}:{delta:.1f}%")
    worst_lat = max(r["decision_latency_p99_ms"] for r in rows)
    emit("exp7_scalability", (time.time() - t0) * 1e6 / max(len(rows), 1),
         ";".join(parts) + f";p99lat={worst_lat:.2f}ms")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
