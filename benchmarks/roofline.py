"""Roofline analysis (§Roofline): three terms per (arch x shape x mesh).

    compute term    = FLOPs / (chips * peak)
    memory term     = HBM bytes / (chips * hbm_bw)
    collective term = collective bytes / (chips * link_bw)

FLOPs and HBM bytes are ANALYTIC (exact formulas from the architecture —
XLA's cost_analysis counts while-loop bodies once, so its flops/bytes
undercount scanned work; we report it alongside as a diagnostic).
Collective bytes come from the loop-aware HLO parser (trip-count
multipliers from XLA's known_trip_count annotations), which read
per-device operand sizes — so the division by chips is already applied.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_spec  # noqa: E402
from repro.models import param_count, param_specs  # noqa: E402

from .common import emit, write_csv  # noqa: E402

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _expert_params(cfg) -> int:
    """Total parameters living inside MoE expert weight stacks."""
    if cfg.moe is None:
        return 0
    per_layer = cfg.moe.n_experts * 3 * cfg.d_model * cfg.moe.d_expert
    n_moe_layers = cfg.n_periods * sum(
        1 for f in cfg.ffn_pattern if f in ("moe", "moe_res"))
    return per_layer * n_moe_layers


def model_flops_terms(spec, shape_name: str) -> dict:
    """Analytic FLOPs: MODEL_FLOPS (6ND / 2ND convention) + attention."""
    cfg = spec.model
    sh = SHAPES[shape_name]
    s, b = sh["seq_len"], sh["global_batch"]
    n_total = param_count(param_specs(cfg))
    exp = _expert_params(cfg)
    n_active = n_total - exp + int(exp * cfg.moe.top_k / cfg.moe.n_experts) if exp else n_total
    n_embed = cfg.vocab_size * cfg.d_model
    n_mm = n_active - n_embed  # embedding gather does no matmul FLOPs
    l_attn = cfg.n_attn_layers + cfg.n_enc_layers
    h, dh = cfg.n_heads, cfg.d_head
    kind = sh["kind"]
    if kind == "train":
        tokens = b * s
        model = 6 * n_mm * tokens
        attn = 3 * 2 * b * s * s * h * dh * l_attn  # causal: S^2/2 x2 matmuls, x3 fwd+bwd
    elif kind == "prefill":
        tokens = b * s
        model = 2 * n_mm * tokens
        attn = 2 * b * s * s * h * dh * l_attn
    else:  # decode: one token against an S-long cache
        tokens = b
        model = 2 * n_mm * b
        attn = 4 * b * s * h * dh * cfg.n_attn_layers
    return dict(model_flops=float(model), attn_flops=float(attn),
                total_flops=float(model + attn), n_active=n_active,
                n_total=n_total, tokens=tokens)


def hbm_bytes(spec, shape_name: str, chips: int) -> float:
    """Analytic per-step global HBM traffic (napkin formulas, documented)."""
    cfg = spec.model
    sh = SHAPES[shape_name]
    s, b = sh["seq_len"], sh["global_batch"]
    n_total = param_count(param_specs(cfg))
    kind = sh["kind"]
    d = cfg.d_model
    L = cfg.n_layers + cfg.n_enc_layers
    kv_per_tok = 2 * cfg.n_kv_heads * cfg.d_head * 2 * (
        cfg.n_attn_layers + cfg.n_enc_layers)
    if kind == "train":
        mb = spec.train_microbatches
        # fwd + remat-recompute + bwd weight reads per microbatch, grad +
        # optimizer state r/w once, activation rw per layer.
        traffic = 3 * mb * n_total * 2 + 24 * n_total + 12 * L * (b * s) * d * 2
    elif kind == "prefill":
        traffic = n_total * 2 + (b * s) * kv_per_tok + 8 * L * (b * s) * d * 2
    else:
        # decode: stream weights + the whole KV cache once per token.
        cache = b * s * kv_per_tok
        from repro.models.model import state_bytes
        fixed = b * (state_bytes(cfg, 0))
        traffic = n_total * 2 + cache + fixed + 4 * L * b * d * 2
    return float(traffic)


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_rows(cells=None) -> list[dict]:
    rows = []
    for rec in cells or load_cells():
        if rec["status"] != "ok":
            if rec["status"] == "skipped":
                rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                                 mesh=rec["mesh"], status="skipped",
                                 note=rec.get("reason", "")))
            continue
        spec = get_spec(rec["arch"])
        chips = rec["n_devices"]
        ft = model_flops_terms(spec, rec["shape"])
        bytes_g = hbm_bytes(spec, rec["shape"], chips)
        coll = rec.get("collectives_loop_aware", rec["collectives"])
        compute_s = ft["total_flops"] / (chips * PEAK_FLOPS)
        memory_s = bytes_g / (chips * HBM_BW)
        collective_s = coll["total_bytes"] / ICI_BW  # per-device bytes already
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        m = rec["memory"]
        mem_gb = (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)
                  + m.get("output_size_in_bytes", 0) - m.get("alias_size_in_bytes", 0)) / 1e9
        hlo_flops = rec["cost"].get("flops", 0.0) * chips  # per-dev -> global
        rows.append(dict(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], status="ok",
            compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
            dominant=dominant,
            roofline_frac=compute_s / bound if bound > 0 else 1.0,
            model_flops=ft["model_flops"], total_flops=ft["total_flops"],
            useful_ratio=ft["model_flops"] / ft["total_flops"],
            hlo_flops_raw=hlo_flops,
            coll_gb=coll["total_bytes"] / 1e9,
            mem_gb_per_dev=mem_gb, fits_16gb=mem_gb <= 16.0,
            compile_s=rec.get("compile_s", 0),
        ))
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = roofline_rows()
    ok = [r for r in rows if r["status"] == "ok"]
    write_csv("roofline", rows)
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["mesh"] == "pod":
            print(f"  {r['arch']:22s} {r['shape']:12s} comp={r['compute_s']*1e3:9.2f}ms "
                  f"mem={r['memory_s']*1e3:9.2f}ms coll={r['collective_s']*1e3:9.2f}ms "
                  f"-> {r['dominant']:10s} frac={r['roofline_frac']:.2f} "
                  f"fit16={'Y' if r['fits_16gb'] else 'N'}")
    n_fit = sum(r["fits_16gb"] for r in ok)
    doms = {d: sum(1 for r in ok if r["dominant"] == d) for d in
            ("compute", "memory", "collective")}
    emit("roofline", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"cells={len(ok)};fit16={n_fit};dom={doms}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
