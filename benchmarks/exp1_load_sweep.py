"""Experiment 1 (Table II): load sweep 50%-250% of calibrated capacity,
three workload profiles, full baseline set; also emits the Table VI tier
distribution at RAG 100%."""

from __future__ import annotations

import time

from .common import emit, knobs, run_point, write_csv

SCHEDULERS = ["rr", "la", "ca", "cla", "netkv-static", "netkv-full"]
RATES = [0.5, 1.0, 2.0, 2.5]
PROFILES = ["chatbot", "rag", "long_context"]


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    rates = [1.0, 2.0] if quick else RATES
    profiles = ["rag"] if quick else PROFILES
    scheds = ["rr", "cla", "netkv-full"] if quick else SCHEDULERS
    rows = []
    for profile in profiles:
        for rate in rates:
            for sched in scheds:
                t0 = time.time()
                row = run_point(sched, profile, rate_frac=rate, seeds=k["seeds"],
                                duration=k["duration"], warmup=k["warmup"],
                                measure=k["measure"])
                row["wall_s"] = round(time.time() - t0, 1)
                rows.append(row)
                print(f"  exp1 {profile} {int(rate*100)}% {sched}: "
                      f"ttft={row['ttft_mean']*1e3:.0f}±{row['ttft_mean_std']*1e3:.0f}ms "
                      f"slo={row['slo_attainment']:.3f} xfer={row['xfer_mean']*1e3:.0f}ms "
                      f"t2:t3={row['tier2']:.2f}:{row['tier3']:.2f}")
    write_csv("exp1_load_sweep", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    rag = [r for r in rows if r["profile"] == "rag" and r["rate_frac"] == 1.0]
    rr = next(r for r in rag if r["scheduler"] == "rr")
    nk = next(r for r in rag if r["scheduler"] == "netkv-full")
    d = (1 - nk["ttft_mean"] / rr["ttft_mean"]) * 100
    emit("exp1_load_sweep", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"rag100:netkv_vs_rr={d:.1f}%;tiershift={rr['tier3']:.2f}->{nk['tier3']:.2f}")


if __name__ == "__main__":
    import sys

    main(quick="--quick" in sys.argv)
