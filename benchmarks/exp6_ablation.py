"""Experiment 6 (Table IV / Fig. 4): component ablation ladder
CLA* -> +static tier -> +self-contention -> +dynamic congestion, on all
three profiles; the static tier signal must dominate."""

from __future__ import annotations

import time

from .common import emit, knobs, run_point, write_csv

LADDER = ["cla", "netkv-topo", "netkv-static", "netkv-full"]
PROFILES = ["chatbot", "rag", "long_context"]


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    profiles = ["rag"] if quick else PROFILES
    rows = []
    for profile in profiles:
        for sched in LADDER:
            row = run_point(sched, profile, seeds=k["seeds"], duration=k["duration"],
                            warmup=k["warmup"], measure=k["measure"])
            rows.append(row)
            print(f"  exp6 {profile} {sched}: ttft={row['ttft_mean']*1e3:.0f}ms "
                  f"p99={row['ttft_p99']*1e3:.0f}ms tbt={row['tbt_mean']*1e3:.2f}ms")
    write_csv("exp6_ablation", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    prof = rows[0]["profile"]
    sub = {r["scheduler"]: r for r in rows if r["profile"] == prof}
    cla, topo, full = sub["cla"], sub["netkv-topo"], sub["netkv-full"]
    static_gain = (1 - topo["ttft_mean"] / cla["ttft_mean"]) * 100
    full_gain = (1 - full["ttft_mean"] / cla["ttft_mean"]) * 100
    emit("exp6_ablation", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"{prof}:static={static_gain:.1f}%of_full={full_gain:.1f}%")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
