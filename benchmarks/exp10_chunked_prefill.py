"""Beyond-paper experiment 10: ChunkPlane — chunked prefill x streamed KV.

Three axes over the rag workload (long-tailed 4k-64k inputs, the regime
where the network term matters most), TTFT/SLO per scheduler:

(a) **Chunk-size sweep** — serial whole-request prefill (the paper's
    model) vs chunk-interleaved prefill at 512 / 2048 tokens under a
    4096-token iteration budget.  Interleaving alone removes head-of-line
    blocking for short prompts but *delays* long ones — chunking without
    streaming is roughly TTFT-neutral on mixtures.
(b) **Streamed KV transfer** (``kv_streaming``) — completed chunks enter
    the FlowPlane while later chunks still prefill; decode admission
    waits for the last byte.  The transfer rides inside the prefill
    shadow, so mean TTFT and observed transfer time drop — the FlowKV
    low-latency-transfer effect, now scheduler-visible (the ladder's
    T_xfer column credits the overlap via ``prefill_remaining`` /
    ``tail_bytes``).
(c) **Long-context pin** (full mode) — the same comparison with inputs
    pinned to 16k tokens, Proposition 1's regime: the streaming win grows
    with context length.
"""

from __future__ import annotations

import time

from repro.sim import SimConfig, run_sim
from repro.sim.metrics import aggregate_seeds
from repro.traces import generate_trace, profile_capacity

from .common import emit, knobs, write_csv

SCHEDULERS = ["cla", "netkv-static", "netkv-full"]
CHUNKS = [512, 2048]
QUICK_CHUNKS = [2048]
BUDGET = 4096          # prefill iteration token budget (co-batches chunks)
LONG_LEN = 16384       # full-mode pinned-context arm
BACKGROUND = 0.4


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    chunks = QUICK_CHUNKS if quick else CHUNKS
    rows: list[dict] = []
    cap = profile_capacity("rag")

    def point(label, sched, cfg_kw, *, trace_kw=None, rate=1.0, **tags):
        runs = []
        for seed in range(k["seeds"]):
            trace = generate_trace("rag", duration=k["duration"],
                                   target_rps=cap * rate, seed=seed,
                                   **(trace_kw or {}))
            cfg = SimConfig(scheduler=sched, seed=seed, warmup=k["warmup"],
                            measure=k["measure"], background=BACKGROUND,
                            **cfg_kw)
            runs.append(run_sim(cfg, trace))
        row = aggregate_seeds(runs)
        row["variant"] = label
        row.update(tags)
        rows.append(row)
        print(f"  exp10 {label}: ttft={row['ttft_mean']*1e3:.0f}ms "
              f"xfer={row['xfer_mean']*1e3:.0f}ms "
              f"slo={row['slo_attainment']:.3f}")
        return row

    def arms(sched, chunk, streaming, **tags):
        if chunk is None:
            return point(f"serial-{sched}", sched, {}, chunk=0, streaming=0,
                         **tags)
        cfg = {"chunk_tokens": chunk, "prefill_token_budget": BUDGET,
               "kv_streaming": streaming}
        tag = f"c{chunk}{'s' if streaming else ''}"
        return point(f"{tag}-{sched}", sched, cfg, chunk=chunk,
                     streaming=int(streaming), **tags)

    # (a)+(b): chunk-size sweep x streaming on/off x schedulers.
    for sched in SCHEDULERS:
        arms(sched, None, False, axis="sweep")
        for chunk in chunks:
            arms(sched, chunk, False, axis="sweep")
            arms(sched, chunk, True, axis="sweep")
        # Auto-tuned arm: the EWMA controller picks chunk_tokens from the
        # observed input lengths instead of a fixed setting (RolePlane
        # satellite; compares against the fixed-chunk rows above).
        point(f"autotune-{sched}", sched,
              {"chunk_tokens": chunks[0], "prefill_token_budget": BUDGET,
               "chunk_autotune": True},
              axis="sweep", chunk=-1, streaming=0)
    # (c) long-context pin (full mode): serial vs best streamed arm.
    if not quick:
        for sched in ("cla", "netkv-full"):
            point(f"long-serial-{sched}", sched, {},
                  trace_kw={"input_len_override": LONG_LEN},
                  axis="long", chunk=0, streaming=0)
            point(f"long-c2048s-{sched}", sched,
                  {"chunk_tokens": 2048, "prefill_token_budget": BUDGET,
                   "kv_streaming": True},
                  trace_kw={"input_len_override": LONG_LEN},
                  axis="long", chunk=2048, streaming=1)
    write_csv("exp10_chunked_prefill", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    by = {r["variant"]: r for r in rows}
    chunk = QUICK_CHUNKS[0] if quick else CHUNKS[-1]
    # Headline: the streamed-chunk TTFT cut for netkv-full vs its serial
    # arm (the acceptance metric), plus the transfer-shadowing cut.
    serial = by["serial-netkv-full"]
    stream = by[f"c{chunk}s-netkv-full"]
    ttft_cut = (1 - stream["ttft_mean"] / serial["ttft_mean"]) * 100
    xfer_cut = (1 - stream["xfer_mean"] / serial["xfer_mean"]) * 100
    derived = (f"stream_ttft_cut={ttft_cut:.1f}%;"
               f"stream_xfer_cut={xfer_cut:.1f}%")
    if not quick:
        ls, lc = by["long-serial-netkv-full"], by["long-c2048s-netkv-full"]
        derived += f";long_ttft_cut={(1 - lc['ttft_mean'] / ls['ttft_mean']) * 100:.1f}%"
    emit("exp10_chunked_prefill",
         (time.time() - t0) * 1e6 / max(len(rows), 1), derived)


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
