"""Experiment 2 (Table III): context-length sweep at RAG 100% load —
Proposition 1's empirical face: the NetKV advantage grows with input length
while the workload stays schedulable."""

from __future__ import annotations

import time

from .common import emit, knobs, run_point, write_csv

LENGTHS = [1024, 4096, 8192, 16384, 32768]
SCHEDULERS = ["rr", "ca", "cla", "netkv-full"]


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    lengths = [4096, 16384] if quick else LENGTHS
    scheds = ["rr", "cla", "netkv-full"] if quick else SCHEDULERS
    rows = []
    for length in lengths:
        for sched in scheds:
            row = run_point(sched, "rag", seeds=k["seeds"], duration=k["duration"],
                            warmup=k["warmup"], measure=k["measure"],
                            trace_kw={"input_len_override": length})
            row["input_len"] = length
            rows.append(row)
            print(f"  exp2 len={length} {sched}: ttft={row['ttft_mean']*1e3:.0f}ms "
                  f"slo={row['slo_attainment']:.3f} "
                  f"xfer_share={row['xfer_share_mean']:.3f}")
    write_csv("exp2_context_sweep", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    deltas = []
    shares = []
    for length in sorted({r["input_len"] for r in rows}):
        sub = [r for r in rows if r["input_len"] == length]
        rr = next(r for r in sub if r["scheduler"] == "rr")
        nk = next(r for r in sub if r["scheduler"] == "netkv-full")
        deltas.append((length, (1 - nk["ttft_mean"] / rr["ttft_mean"]) * 100))
        # Proposition 1's mechanism, observed: the transfer share of TTFT
        # grows with context length, and NetKV keeps it below the baseline.
        shares.append((length, rr["xfer_share_mean"], nk["xfer_share_mean"]))
    trend = ";".join(f"{l}:{d:.1f}%" for l, d in deltas)
    share_trend = ";".join(f"{l}:rr={a:.2f}:nk={b:.2f}" for l, a, b in shares)
    emit("exp2_context_sweep", (time.time() - t0) * 1e6 / max(len(rows), 1),
         trend + "|xfer_share:" + share_trend)


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
