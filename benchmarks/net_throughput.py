"""Network-plane throughput: columnar FlowPlane vs the retired per-object
reference at 1k / 10k / 50k concurrent flows.

Two arms per population size on a 256-GPU fat-tree:

* ``recompute`` — one full progressive water-filling pass over every flow
  (the loop re-run on *every* flow arrival/completion plus every 0.1 s
  background tick; the simulator's network hot path at scale).
* ``churn``    — a start+abort transfer pair against the standing
  population, exercising the FlowPlane's incremental (dirty-component)
  recompute and O(flows-of-transfer) abort versus the reference's full
  recompute per event.

Each timed arm gets its own freshly populated engine plus an explicit
warmup rep before the clock starts: the engines share an RNG stream for
identical populations, and measuring them back-to-back on one standing
object let allocator/cache warm-ordering flatter whichever ran second.

The reference's O(rounds x links x flows) Python loop is timed with few
reps at 10k and skipped at 50k (it is minutes per pass there — the exact
wall that capped exp7 at 1024 GPUs).  Acceptance floor: the FlowPlane must
hold >= 10x recompute *and* churn throughput at >= 10k flows.
"""

from __future__ import annotations

import time

import numpy as np

from repro.cluster import BackgroundTraffic, FatTree, FlowPlane, ReferenceFlowNetwork
from repro.sim.engine import LANE_NET, EventLoop, EventPlane

from .common import emit, write_csv

TREE_KW = dict(n_pods=2, racks_per_pod=8, servers_per_rack=2, gpus_per_server=8)
SIZES = [1_000, 10_000, 50_000]
REF_CAP = 10_000          # reference arm is minutes/pass above this
QUICK_SIZES = [1_000, 10_000]   # CI smoke reaches the acceptance size
SPEEDUP_FLOOR = 10.0      # required FlowPlane/reference ratio at >= 10k flows
EVENTS_FLOOR = 3.0        # EventPlane vs EventLoop on NET-lane re-arm churn


def _servers(kw=TREE_KW):
    return [
        (p, r, s)
        for p in range((kw["n_pods"]))
        for r in range(kw["racks_per_pod"])
        for s in range(kw["servers_per_rack"])
    ]


def _populate(net, n_flows, seed):
    """Start n_flows/4 transfers between random distinct server pairs.

    Rate recomputation is suppressed during population (we are building a
    standing population to benchmark against, and a per-arrival recompute
    during setup is exactly the cost this benchmark measures) and run once
    at the end.
    """
    wl = np.random.default_rng(seed)
    servers = _servers()
    real = net._recompute_rates
    net._recompute_rates = lambda *a, **k: None
    try:
        for _ in range(n_flows // 4):
            i, j = wl.choice(len(servers), 2, replace=False)
            net.start_transfer(servers[i], servers[j], 1e12, 0.0,
                               lambda t, n: None, n_flows=4)
    finally:
        net._recompute_rates = real
    if isinstance(net, FlowPlane):
        net._recompute_rates(dirty_links=None)
    else:
        net._recompute_rates(0.0)
    return net


def _time(fn, reps: int) -> float:
    """Best-of-reps (timeit-style min) after one explicit warmup rep:
    robust to scheduler noise on shared hosts and to cache-warm ordering,
    both of which matter for the speedup-ratio acceptance gates."""
    fn()  # warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _churn(net):
    """One arrival + one abort against the standing population."""
    servers = _servers()

    def fn():
        t = net.start_transfer(servers[0], servers[-1], 1e12, 0.0,
                               lambda tr, now: None, n_flows=4)
        net.abort_transfer(t, 0.0)

    return fn


def _engine_churn_rows(n_standing=1_000, n_rearms=20_000) -> list[dict]:
    """NET-lane re-arm churn: EventPlane slot overwrite vs EventLoop
    cancel+push.

    This is the completion-timer pattern ``Simulation._reschedule_net``
    drives on every flow arrival/completion: the pending completion event
    is replaced with one at the new ETA.  The heap engine pays a cancel
    plus an O(log n) push (and periodic corpse compaction) against the
    standing population; the plane overwrites one slot tuple.  Gate:
    EventPlane must hold >= EVENTS_FLOOR x re-arm throughput.
    """
    noop = lambda now: None
    rows = []
    for cls in (EventPlane, EventLoop):
        loop = cls()
        for i in range(n_standing):
            loop.at(1e9 + i, noop)   # standing far-future population

        def fn():
            for i in range(n_rearms):
                loop.arm(LANE_NET, 1e6 + (i & 7), noop)

        best = _time(fn, reps=5)
        rows.append(dict(engine=cls.__name__, standing=n_standing,
                         rearms=n_rearms, best_s=best,
                         rearms_per_s=n_rearms / max(best, 1e-12)))
    ratio = rows[0]["rearms_per_s"] / max(rows[1]["rearms_per_s"], 1e-12)
    for r in rows:
        r["plane_vs_loop"] = ratio
    print(f"  net_throughput NET-lane churn: plane="
          f"{rows[0]['rearms_per_s']:.0f}/s loop={rows[1]['rearms_per_s']:.0f}/s "
          f"({ratio:.1f}x)")
    assert ratio >= EVENTS_FLOOR, (
        f"EventPlane NET-lane re-arm churn {ratio:.2f}x below the "
        f"{EVENTS_FLOOR:.0f}x floor vs EventLoop")
    return rows


def run(quick: bool = False) -> list[dict]:
    sizes = QUICK_SIZES if quick else SIZES
    rows = []
    for n in sizes:
        row = dict(flows=n)
        mk_plane = lambda: _populate(
            FlowPlane(FatTree(**TREE_KW), BackgroundTraffic(0.2)), n, 0)
        mk_ref = lambda: _populate(
            ReferenceFlowNetwork(FatTree(**TREE_KW), BackgroundTraffic(0.2)),
            n, 0)
        # Fresh engine per timed arm: the recompute arm's passes must not
        # pre-warm the churn arm's dirty-component bookkeeping (or vice
        # versa), and plane/reference must not share process-warm state.
        plane = mk_plane()
        row["plane_recompute_ms"] = _time(
            lambda: plane._recompute_rates(dirty_links=None),
            reps=max(50_000 // n, 3)) * 1e3
        plane_c = mk_plane()
        row["plane_churn_ms"] = _time(
            _churn(plane_c), reps=max(20_000 // n, 3)) * 1e3
        if n <= REF_CAP:
            ref = mk_ref()
            row["ref_recompute_ms"] = _time(
                lambda: ref._recompute_rates(0.0),
                reps=1 if n > 2_000 else 3) * 1e3
            row["recompute_speedup"] = (
                row["ref_recompute_ms"] / row["plane_recompute_ms"])
            ref_c = mk_ref()
            row["ref_churn_ms"] = _time(
                _churn(ref_c), reps=1 if n > 2_000 else 3) * 1e3
            row["churn_speedup"] = (
                row["ref_churn_ms"] / row["plane_churn_ms"])
        else:
            row["ref_recompute_ms"] = float("nan")
            row["recompute_speedup"] = float("nan")
            row["ref_churn_ms"] = float("nan")
            row["churn_speedup"] = float("nan")
        print(f"  net_throughput n={n}: plane={row['plane_recompute_ms']:.2f}ms "
              f"ref={row['ref_recompute_ms']:.1f}ms "
              f"({row['recompute_speedup']:.0f}x) "
              f"churn={row['plane_churn_ms']:.3f}ms/event "
              f"vs ref {row['ref_churn_ms']:.1f}ms "
              f"({row['churn_speedup']:.0f}x)")
        rows.append(row)
    write_csv("net_throughput", rows)
    write_csv("net_event_churn", _engine_churn_rows())
    # Acceptance gates, enforced wherever the 10k arm runs (incl. CI smoke).
    for r in rows:
        if r["flows"] >= 10_000 and np.isfinite(r["recompute_speedup"]):
            assert r["recompute_speedup"] >= SPEEDUP_FLOOR, (
                f"FlowPlane recompute speedup {r['recompute_speedup']:.1f}x at "
                f"{r['flows']} flows is below the {SPEEDUP_FLOOR:.0f}x floor")
        if r["flows"] >= 10_000 and np.isfinite(r["churn_speedup"]):
            assert r["churn_speedup"] >= SPEEDUP_FLOOR, (
                f"FlowPlane churn speedup {r['churn_speedup']:.1f}x at "
                f"{r['flows']} flows is below the {SPEEDUP_FLOOR:.0f}x floor")
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    with_speedup = [r for r in rows if np.isfinite(r["recompute_speedup"])]
    best = max(with_speedup, key=lambda r: r["flows"]) if with_speedup else rows[-1]
    emit("net_throughput", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"flows{best['flows']}:plane={best['plane_recompute_ms']:.2f}ms,"
         f"{best['recompute_speedup']:.0f}x;"
         f"flows{rows[-1]['flows']}churn={rows[-1]['plane_churn_ms']:.3f}ms,"
         f"{best['churn_speedup']:.0f}x")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
