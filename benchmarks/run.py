"""Benchmark orchestrator: one harness per paper table/figure.

Usage:
    python -m benchmarks.run [--quick] [--only exp1,roofline] [--profile]

Prints one ``name,us_per_call,derived`` CSV line per harness (stdout
contract) and writes full tables to artifacts/bench/*.csv.  With
``--profile`` the event engines accumulate per-lane / per-handler
cumulative dispatch time across every simulation the selected harnesses
run, written to artifacts/bench/event_profile.csv — the first place to
look when hunting where event time goes.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    decode_throughput,
    exp1_load_sweep,
    exp2_context_sweep,
    exp3_topology,
    exp4_staleness,
    exp5_prefix_sharing,
    exp6_ablation,
    exp7_scalability,
    exp8_beyond,
    exp9_extensions,
    exp10_chunked_prefill,
    exp11_scenario_sweep,
    exp12_deflection,
    net_throughput,
    roofline,
    sched_latency,
)

HARNESSES = {
    "exp1": exp1_load_sweep,       # Table II
    "exp2": exp2_context_sweep,    # Table III
    "exp3": exp3_topology,         # Fig. 1
    "exp4": exp4_staleness,        # Fig. 2
    "exp5": exp5_prefix_sharing,   # Fig. 3
    "exp6": exp6_ablation,         # Table IV / Fig. 4
    "exp7": exp7_scalability,      # Table V / Fig. 5
    "exp8": exp8_beyond,           # beyond-paper
    "exp9": exp9_extensions,       # beyond-paper: TopoPlane (multi-NIC + OCS rewire)
    "exp10": exp10_chunked_prefill,  # beyond-paper: ChunkPlane (chunked prefill + streamed KV)
    "exp11": exp11_scenario_sweep,   # beyond-paper: ScenarioPlane batched what-if sweeps
    "exp12": exp12_deflection,       # beyond-paper: RolePlane (deflection + P:D flips)
    "sched_latency": sched_latency,
    "net_throughput": net_throughput,      # FlowPlane vs reference engine
    "decode_throughput": decode_throughput,  # InstancePlane vs reference
    "roofline": roofline,          # §Roofline (reads dry-run artifacts)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated harness names")
    ap.add_argument("--profile", action="store_true",
                    help="write per-lane/per-handler event dispatch times "
                         "to artifacts/bench/event_profile.csv")
    ap.add_argument("--trace", action="store_true",
                    help="record TracePlane spans + decision forensics in "
                         "every simulation the selected harnesses run; "
                         "writes artifacts/bench/trace.json (Perfetto) and "
                         "artifacts/bench/ttft_breakdown.csv")
    args = ap.parse_args()
    if args.profile:
        from repro.sim.engine import enable_profiling
        enable_profiling(True)
    if args.trace:
        from repro.sim import enable_tracing
        enable_tracing(True)
    names = list(HARNESSES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = HARNESSES[name]
        t0 = time.time()
        try:
            mod.main(quick=args.quick)
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"{name},{(time.time()-t0)*1e6:.0f},ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.profile:
        from repro.sim.engine import profile_rows

        from .common import write_csv
        rows = profile_rows()
        if rows:
            path = write_csv("event_profile", rows)
            print(f"# event profile: {len(rows)} (lane, handler) rows -> {path}",
                  file=sys.stderr)
    if args.trace:
        from repro.sim import trace as _trace

        from .common import OUT_DIR
        sess = _trace._SESSION
        if sess is not None and sess.n_runs:
            for path in sess.write(OUT_DIR):
                print(f"# trace: {sess.n_runs} runs -> {path}",
                      file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
