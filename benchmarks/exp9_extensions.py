"""Beyond-paper experiment 9: TopoPlane studies on the dynamic fabric.

Three sweeps over the same rag workload, TTFT/SLO per scheduler:

(a) **NIC-count sweep** — 1/2/4/8 NICs per server (rail-optimised
    H100-class hosts).  Host egress scales with the NIC count while the
    per-transfer ceiling stays B_1, so the prefill-side nic_up bottleneck
    relaxes and the win shifts from "avoid the hot NIC" to "avoid the hot
    tier".
(b) **NIC-policy ablation** — hash vs least-loaded vs rail-affine vs the
    trace-adaptive policy (hash<->rail-affine on the observed transfer-size
    EWMA) at 4 NICs: how much of the multi-NIC win needs a smart rail
    choice, and whether adapting to the trace recovers the best static one.
(c) **OCS rewire schedule** — rack->pod uplinks (tiers 2+3) degrade to 25 %
    capacity mid-trace and are restored later (optical circuit
    reconfiguration).  The oracle only sees the swap at its next refresh,
    so schedulers route on pre-rewire bandwidths inside the staleness
    window — the paper's robustness claim under a capacity stress axis.
"""

from __future__ import annotations

import time

from repro.sim import RewireEvent, SimConfig, run_sim
from repro.sim.metrics import aggregate_seeds
from repro.traces import generate_trace, profile_capacity

from .common import emit, knobs, write_csv

NIC_SWEEP = [1, 2, 4, 8]
QUICK_NIC_SWEEP = [1, 4]
NIC_POLICIES = ["hash", "least-loaded", "rail-affine", "adaptive"]
SCHEDULERS = ["cla", "netkv-static", "netkv-full"]
DEGRADE = 0.25   # OCS event: tiers 2+3 drop to a quarter of capacity


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    nic_sweep = QUICK_NIC_SWEEP if quick else NIC_SWEEP
    rows = []
    cap = profile_capacity("rag")   # one workload profile across all arms

    def point(label, sched, cfg_kw, rate=1.0, **tags):
        runs = []
        for seed in range(k["seeds"]):
            trace = generate_trace("rag", duration=k["duration"],
                                   target_rps=cap * rate, seed=seed)
            cfg = SimConfig(scheduler=sched, seed=seed, warmup=k["warmup"],
                            measure=k["measure"], background=0.25, **cfg_kw)
            runs.append(run_sim(cfg, trace))
        row = aggregate_seeds(runs)
        row["variant"] = label
        row.update(tags)
        rows.append(row)
        print(f"  exp9 {label}: ttft={row['ttft_mean']*1e3:.0f}ms "
              f"xfer={row['xfer_mean']*1e3:.0f}ms slo={row['slo_attainment']:.3f}")
        return row

    # (a) NIC-count sweep: host egress bandwidth scales with the rail count.
    for nics in nic_sweep:
        for sched in SCHEDULERS:
            point(f"nic{nics}-{sched}", sched,
                  {"nics_per_server": nics, "nic_policy": "hash"},
                  axis="nic_sweep", nics=nics, nic_policy="hash")
    # (b) NIC-policy ablation at 4 rails (full mode only).
    if not quick:
        for policy in NIC_POLICIES:
            for sched in SCHEDULERS:
                point(f"pol-{policy}-{sched}", sched,
                      {"nics_per_server": 4, "nic_policy": policy},
                      axis="nic_policy", nics=4, nic_policy=policy)
    # (c) OCS schedule: degrade rack->pod uplinks a third into the
    # measurement window, restore two thirds in.
    t_deg = k["warmup"] + k["measure"] / 3
    t_res = k["warmup"] + 2 * k["measure"] / 3
    ocs = [RewireEvent(time=t_deg, scale={2: DEGRADE, 3: DEGRADE}),
           RewireEvent(time=t_res, scale={2: 1 / DEGRADE, 3: 1 / DEGRADE})]
    for sched in SCHEDULERS:
        point(f"ocs-{sched}", sched, {"rewires": ocs},
              axis="ocs", nics=1, nic_policy="hash", rewired=1)
        # Rewire-notified arm: the oracle force-refreshes on each
        # topo_epoch bump instead of routing on pre-rewire bandwidths
        # until its next scheduled refresh.  Both it and its stale control
        # run with a widened refresh interval — at the default 1 s the
        # staleness window is shorter than the decision cadence and the
        # two arms coincide.  Quick mode keeps one notified arm (the
        # network-aware scheduler).
        if not quick:
            point(f"ocs-stale-{sched}", sched,
                  {"rewires": ocs, "oracle_refresh": 4.0},
                  axis="ocs", nics=1, nic_policy="hash", rewired=1,
                  notified=0)
        if not quick or sched == "netkv-full":
            point(f"ocs-notified-{sched}", sched,
                  {"rewires": ocs, "notify_rewires": True,
                   "oracle_refresh": 4.0},
                  axis="ocs", nics=1, nic_policy="hash", rewired=1,
                  notified=1)
        if not quick:  # static-fabric control arm
            point(f"ocs-control-{sched}", sched, {},
                  axis="ocs", nics=1, nic_policy="hash", rewired=0)
    write_csv("exp9_extensions", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    by = {r["variant"]: r for r in rows}
    hi = max(r["nics"] for r in rows if r.get("axis") == "nic_sweep")
    nic = (1 - by[f"nic{hi}-netkv-full"]["ttft_mean"]
           / by["nic1-netkv-full"]["ttft_mean"]) * 100
    ocs = (1 - by["ocs-netkv-full"]["ttft_mean"]
           / by["ocs-cla"]["ttft_mean"]) * 100
    emit("exp9_extensions", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"nic{hi}_ttft_cut={nic:.1f}%;ocs_netkv_vs_cla={ocs:.1f}%")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
