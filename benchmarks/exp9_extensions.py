"""Beyond-paper experiment 9: (a) the TP=8 sparser-pool data point the paper
leaves open (§VII), (b) multi-hop DRAM staging under decode-cache pressure
(the Mooncake scenario: per-instance HBM caches thrash, the pod-level DRAM
store retains hot prefixes)."""

from __future__ import annotations

import time

from repro.sim import SimConfig, run_sim
from repro.sim.metrics import aggregate_seeds
from repro.traces import generate_trace, profile_capacity

from .common import emit, knobs, write_csv


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    rows = []

    def point(label, sched, cfg_kw, cap_kw=None, rate=1.0, trace_kw=None):
        cap = profile_capacity("rag", **(cap_kw or {}))
        runs = []
        for seed in range(k["seeds"]):
            trace = generate_trace("rag", duration=k["duration"],
                                   target_rps=cap * rate, seed=seed,
                                   **(trace_kw or {}))
            cfg = SimConfig(scheduler=sched, seed=seed, warmup=k["warmup"],
                            measure=k["measure"], background=0.2, **cfg_kw)
            runs.append(run_sim(cfg, trace))
        row = aggregate_seeds(runs)
        row["variant"] = label
        rows.append(row)
        print(f"  exp9 {label}: ttft={row['ttft_mean']*1e3:.0f}ms "
              f"xfer={row['xfer_mean']*1e3:.0f}ms slo={row['slo_attainment']:.3f}")
        return row

    # (a) TP=8: 8 instances (2 prefill + 6 decode) on the same 64 GPUs —
    # sparser candidate pool, bigger per-instance transfers.
    for sched in ["cla", "netkv-full"]:
        point(f"tp8-{sched}", sched,
              {"tp": 8, "n_prefill": 2, "hbm_free_per_gpu": 45e9},
              cap_kw={"n_prefill": 2, "n_decode": 6})
    # (b) decode-cache pressure: small per-instance KV budget thrashes the
    # local prefix caches; the per-pod DRAM store (multihop) retains them.
    pressured = {"hbm_free_per_gpu": 12e9}
    for sched in ["netkv-full", "netkv-multihop"]:
        point(f"pressure-{sched}", sched, dict(pressured), rate=1.2,
              trace_kw={"p_share": 0.8, "n_share_groups": 12})
    write_csv("exp9_extensions", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    by = {r["variant"]: r for r in rows}
    tp8 = (1 - by["tp8-netkv-full"]["ttft_mean"] / by["tp8-cla"]["ttft_mean"]) * 100
    mh = (1 - by["pressure-netkv-multihop"]["xfer_mean"]
          / by["pressure-netkv-full"]["xfer_mean"]) * 100
    emit("exp9_extensions", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"tp8_netkv_vs_cla={tp8:.1f}%;multihop_xfer_cut={mh:.1f}%")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
