"""Experiment 5 (Fig. 3): prefix-sharing sweep p_share 0 -> 0.9 — the
network-aware gain must stay roughly constant (orthogonal to cache-awareness)."""

from __future__ import annotations

import time

from .common import emit, knobs, run_point, write_csv

P_SHARES = [0.0, 0.3, 0.5, 0.7, 0.9]
SCHEDULERS = ["ca", "cla", "netkv-full"]


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    shares = [0.0, 0.7] if quick else P_SHARES
    scheds = ["cla", "netkv-full"] if quick else SCHEDULERS
    rows = []
    for ps in shares:
        for sched in scheds:
            row = run_point(sched, "rag", seeds=k["seeds"], duration=k["duration"],
                            warmup=k["warmup"], measure=k["measure"],
                            trace_kw={"p_share": ps})
            row["p_share"] = ps
            rows.append(row)
            print(f"  exp5 p={ps} {sched}: ttft={row['ttft_mean']*1e3:.0f}ms "
                  f"hit={row.get('tier0', 0):.2f}")
    write_csv("exp5_prefix_sharing", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    deltas = []
    for ps in sorted({r["p_share"] for r in rows}):
        sub = [r for r in rows if r["p_share"] == ps]
        cla = next(r for r in sub if r["scheduler"] == "cla")
        nk = next(r for r in sub if r["scheduler"] == "netkv-full")
        deltas.append((1 - nk["ttft_mean"] / cla["ttft_mean"]) * 100)
    emit("exp5_prefix_sharing", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"delta_range={min(deltas):.1f}%..{max(deltas):.1f}%")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
