"""Shared benchmark plumbing: seeded multi-run sweeps + CSV emission."""

from __future__ import annotations

import csv
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim import SimConfig, run_sim, trace_session  # noqa: E402
from repro.sim.metrics import aggregate_seeds  # noqa: E402
from repro.traces import generate_trace, profile_capacity  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

# Paper window: 5 s warmup + 15 s measurement.  --quick shrinks it.
FULL = dict(warmup=5.0, measure=15.0, duration=22.0, seeds=5)
QUICK = dict(warmup=2.0, measure=8.0, duration=11.0, seeds=2)


def knobs(quick: bool) -> dict:
    return QUICK if quick else FULL


def run_point(scheduler: str, profile: str, *, rate_frac: float = 1.0,
              seeds: int = 5, duration: float = 22.0, warmup: float = 5.0,
              measure: float = 15.0, trace_kw: dict | None = None,
              cfg_kw: dict | None = None, cap_kw: dict | None = None) -> dict:
    """One (scheduler, workload, rate) point aggregated over seeds."""
    cap = profile_capacity(profile, **(cap_kw or {}))
    sess = trace_session()
    if sess is not None:
        # Label this point's runs in the combined trace artifacts
        # (run.py --trace): "<profile>@<rate>" + the scheduler name the
        # Simulation itself appends.
        sess.context = f"{profile}@{rate_frac:g}"
    runs = []
    for seed in range(seeds):
        trace = generate_trace(profile, duration=duration,
                               target_rps=cap * rate_frac, seed=seed,
                               **(trace_kw or {}))
        cfg = SimConfig(scheduler=scheduler, seed=seed, warmup=warmup,
                        measure=measure, **(cfg_kw or {"background": 0.2}))
        runs.append(run_sim(cfg, trace))
    agg = aggregate_seeds(runs)
    agg.update(profile=profile, rate_frac=rate_frac)
    return agg


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    keys: list[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
    return path


def emit(name: str, us_per_call: float, derived: str) -> None:
    """run.py contract: ``name,us_per_call,derived`` CSV line on stdout."""
    print(f"{name},{us_per_call:.1f},{derived}")
