"""Beyond-paper experiment 12: RolePlane — prefill deflection under storms.

A prefill-storm grid over the rag workload (long-tailed 4k-64k inputs)
with a deliberately thin prefill pool (2 instances), so prefill queueing
— not the network or decode — dominates TTFT under load:

(a) **Storm axis** — calm (well under prefill capacity) vs storm (several
    times over it).  In calm cells the healthy-pool backlog never crosses
    ``deflect_threshold``, so deflection must be a bit-exact no-op
    (``deflected_frac == 0``).
(b) **Deflection on/off x schedulers** — with deflection on, arrivals
    that find the prefill pool backlogged are offered to decode hosts as
    prefill targets (Eq. (4) collapses: the KV is born in place, zero
    transfer, tier 0; ``Scheduler.select_deflected``).  Decode instances
    meter the deflected chunks through the attachable ChunkPlane, so
    decode SLOs degrade gracefully instead of prefill TTFT exploding.
(c) **Role-flip arm** — the same storm with the LANE_ROLE slow loop
    enabled: sustained backlog converts drained decode instances into
    prefill workers (and back when the storm passes).

The acceptance gate (main): under the storm arm, deflection-on must beat
deflection-off mean TTFT for at least one netkv scheduler, and
``deflected_frac`` must be nonzero only in storm cells.
"""

from __future__ import annotations

import math
import time

from repro.sim import SimConfig, run_sim
from repro.sim.metrics import aggregate_seeds
from repro.traces import generate_trace

from .common import emit, knobs, write_csv

SCHEDULERS = ["cla", "netkv-static", "netkv-full"]
STORMS = {"calm": 1.5, "storm": 6.0}   # absolute rps (n_prefill=2 pool)
N_PREFILL = 2                          # thin pool: prefill-bottlenecked
CHUNK = 2048
BUDGET = 4096
THRESHOLD = 0.5                        # seconds of prefill backlog
BACKGROUND = 0.2


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    rows: list[dict] = []

    def point(label, sched, rate, cfg_kw, **tags):
        runs = []
        sims = []
        for seed in range(k["seeds"]):
            trace = generate_trace("rag", duration=k["duration"],
                                   target_rps=rate, seed=seed)
            cfg = SimConfig(scheduler=sched, seed=seed, warmup=k["warmup"],
                            measure=k["measure"], background=BACKGROUND,
                            n_prefill=N_PREFILL, chunk_tokens=CHUNK,
                            prefill_token_budget=BUDGET, **cfg_kw)
            from repro.sim import Simulation
            sim = Simulation(cfg)
            runs.append(sim.run(trace, drain=40.0))
            sims.append(sim)
        row = aggregate_seeds(runs)
        row["variant"] = label
        row["deflections"] = sum(s.deflected for s in sims)
        row["role_flips"] = sum(s.role_flips for s in sims)
        row.update(tags)
        rows.append(row)
        print(f"  exp12 {label}: ttft={row['ttft_mean']*1e3:.0f}ms "
              f"slo={row['slo_attainment']:.3f} "
              f"defl_frac={row['deflected_frac']:.3f} "
              f"flips={row['role_flips']}")
        return row

    for storm, rate in STORMS.items():
        for sched in SCHEDULERS:
            point(f"{storm}-off-{sched}", sched, rate, {"deflection": "off"},
                  storm=storm, deflection=0, flips=0)
            point(f"{storm}-on-{sched}", sched, rate,
                  {"deflection": "on", "deflect_threshold": THRESHOLD},
                  storm=storm, deflection=1, flips=0)
    # (c) role-flip arm: storm + LANE_ROLE slow loop (deflection stays off
    # so the flip effect is isolated).
    point("storm-flip-netkv-full", "netkv-full", STORMS["storm"],
          {"role_flip_interval": 0.5, "role_flip_sustain": 2,
           "role_flip_hi": 0.3, "role_flip_lo": 0.05},
          storm="storm", deflection=0, flips=1)
    write_csv("exp12_deflection", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    by = {r["variant"]: r for r in rows}
    # Gate 1: deflected_frac nonzero ONLY in storm cells.
    for r in rows:
        frac = r["deflected_frac"]
        if r["storm"] == "calm" and frac > 0:
            raise RuntimeError(
                f"deflection fired in calm cell {r['variant']}: {frac}")
        if not r["deflection"] and frac > 0:
            raise RuntimeError(
                f"deflected_frac nonzero with deflection off: {r['variant']}")
    # Gate 2: under the storm, deflection-on beats deflection-off mean
    # TTFT for at least one netkv scheduler.
    wins = []
    for sched in ("netkv-static", "netkv-full"):
        off = by[f"storm-off-{sched}"]["ttft_mean"]
        on = by[f"storm-on-{sched}"]["ttft_mean"]
        if math.isfinite(off) and math.isfinite(on) and on < off:
            wins.append((sched, (1 - on / off) * 100))
    if not wins:
        raise RuntimeError("deflection-on failed to beat deflection-off "
                           "mean TTFT under the storm arm")
    sched, cut = max(wins, key=lambda w: w[1])
    storm_on = by[f"storm-on-{sched}"]
    derived = (f"storm_ttft_cut={cut:.1f}%({sched});"
               f"storm_defl_frac={storm_on['deflected_frac']:.2f};"
               f"flips={by['storm-flip-netkv-full']['role_flips']}")
    emit("exp12_deflection", (time.time() - t0) * 1e6 / max(len(rows), 1),
         derived)


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
