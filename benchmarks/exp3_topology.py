"""Experiment 3 (Fig. 1): topology sensitivity — cross-pod oversubscription
ratio x background-traffic intensity grid; NetKV's edge must grow along both
axes and win in every cell.  Full mode adds a rail-optimised replica of the
most-stressed cell (4 NICs per server) to show how much of the worst-case
gap multi-NIC hosts buy back without any scheduler change."""

from __future__ import annotations

import time

from repro.core.oracle import PAPER_TIER_BANDWIDTH

from .common import emit, knobs, run_point, write_csv

OVERSUB = [1, 2, 4, 8]          # B3 = B1 / oversub
BACKGROUND = [0.0, 0.1, 0.2, 0.4]
SCHEDULERS = ["cla", "netkv-static", "netkv-full"]


def run(quick: bool = False) -> list[dict]:
    k = knobs(quick)
    oversubs = [1, 8] if quick else OVERSUB
    bgs = [0.0, 0.4] if quick else BACKGROUND
    scheds = ["cla", "netkv-full"] if quick else SCHEDULERS
    rows = []
    for ov in oversubs:
        tier_bw = dict(PAPER_TIER_BANDWIDTH)
        tier_bw[3] = tier_bw[1] / ov
        tier_bw[2] = tier_bw[1] / max(ov / 2, 1)
        for bg in bgs:
            nic_counts = [1, 4] if (not quick and ov == max(oversubs)
                                    and bg == max(bgs)) else [1]
            for nics in nic_counts:
                for sched in scheds:
                    row = run_point(
                        sched, "rag", seeds=k["seeds"], duration=k["duration"],
                        warmup=k["warmup"], measure=k["measure"],
                        cfg_kw={"background": bg, "tier_bandwidth": tier_bw,
                                "nics_per_server": nics},
                        cap_kw={"background": bg,
                                "agg_egress_bytes_per_s": 8 * tier_bw[3],
                                "tor_egress_bytes_per_s": 8 * tier_bw[2]},
                    )
                    row.update(oversub=ov, bg=bg, nics=nics)
                    rows.append(row)
                    print(f"  exp3 {ov}:1 bg={bg} nics={nics} {sched}: "
                          f"ttft={row['ttft_mean']*1e3:.0f}ms")
    write_csv("exp3_topology", rows)
    return rows


def main(quick: bool = False) -> None:
    t0 = time.time()
    rows = run(quick)
    wins = total = 0
    corner = {}
    grid = [r for r in rows if r["nics"] == 1]   # multi-NIC replica excluded
    for ov in sorted({r["oversub"] for r in grid}):
        for bg in sorted({r["bg"] for r in grid}):
            sub = [r for r in grid if r["oversub"] == ov and r["bg"] == bg]
            cla = next(r for r in sub if r["scheduler"] == "cla")
            nk = next(r for r in sub if r["scheduler"] == "netkv-full")
            total += 1
            wins += nk["ttft_mean"] < cla["ttft_mean"]
            corner[(ov, bg)] = (1 - nk["ttft_mean"] / cla["ttft_mean"]) * 100
    lo = corner[min(corner)]
    hi = corner[max(corner)]
    emit("exp3_topology", (time.time() - t0) * 1e6 / max(len(rows), 1),
         f"wins={wins}/{total};minstress={lo:.1f}%;maxstress={hi:.1f}%")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
