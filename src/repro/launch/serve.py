"""Serving launcher: disaggregated cluster simulation at paper scale, or the
real-model executable cluster at smoke scale.

    python -m repro.launch.serve --profile rag --scheduler netkv-full
    python -m repro.launch.serve --real --arch qwen3-14b --requests 8
"""

from __future__ import annotations

import argparse


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="netkv-full")
    ap.add_argument("--profile", default="rag",
                    choices=["chatbot", "rag", "long_context"])
    ap.add_argument("--rate", type=float, default=1.0, help="fraction of capacity")
    ap.add_argument("--background", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="llama3-70b",
                    help="sets the KV-size model for the simulator")
    ap.add_argument("--real", action="store_true",
                    help="run real smoke-scale models end to end")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--faults", action="store_true",
                    help="inject a decode-instance failure mid-run")
    args = ap.parse_args()

    if args.real:
        import dataclasses

        import jax.numpy as jnp
        import numpy as np

        from repro.configs import get_spec
        from repro.serving import DisaggregatedCluster, ServeRequest

        cfg = dataclasses.replace(get_spec(args.arch).smoke,
                                  compute_dtype=jnp.float32)
        cluster = DisaggregatedCluster(cfg, scheduler=args.scheduler, cache_len=64)
        rng = np.random.default_rng(args.seed)
        reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size, size=24),
                             max_new=8, arrival=i * 0.02)
                for i in range(args.requests)]
        for r in cluster.serve(reqs):
            print(f"req{r.request_id}: decode@{r.decode_instance} tier{r.tier} "
                  f"xfer={r.transfer_bytes/1e3:.0f}KB ttft={r.ttft*1e3:.0f}ms "
                  f"tokens={r.tokens[:8]}")
        return 0

    from repro.configs import get_spec
    from repro.sim import FaultEvent, SimConfig, run_sim
    from repro.traces import generate_trace, profile_capacity

    kv = get_spec(args.arch).kv_spec()
    cap = profile_capacity(args.profile, kv_bytes_per_token=kv.kv_bytes_per_token or 1.0)
    trace = generate_trace(args.profile, duration=22.0,
                           target_rps=cap * args.rate, seed=args.seed)
    faults = [FaultEvent(time=8.0, kind="kill_decode", instance_id=5)] if args.faults else []
    cfg = SimConfig(scheduler=args.scheduler, seed=args.seed, kv_spec=kv,
                    background=args.background, faults=faults)
    m = run_sim(cfg, trace)
    print(f"{args.scheduler} on {args.profile} ({args.arch} KV) @ {args.rate:.0%}:")
    print(f"  TTFT mean={m.ttft_mean*1e3:.0f}ms p99={m.ttft_p99*1e3:.0f}ms")
    print(f"  TBT  mean={m.tbt_mean*1e3:.2f}ms  SLO={m.slo_attainment:.3f} "
          f"goodput={m.goodput_rps:.2f}rps")
    print(f"  transfer mean={m.xfer_mean*1e3:.0f}ms  tiers "
          f"2:{m.tier_fraction[2]:.2f} 3:{m.tier_fraction[3]:.2f}")
    if args.faults:
        print(f"  requeues after failure: {m.requeues}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
