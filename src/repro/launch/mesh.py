"""Production mesh construction.

Single pod:  (16, 16)      axes ("data", "model")          = 256 chips
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")   = 512 chips

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under dryrun.py (it forces 512 host devices)"
        )
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    import numpy as np

    devs = jax.devices()
    n = len(devs)
    data = n // model_axis
    return jax.sharding.Mesh(
        np.asarray(devs[: data * model_axis]).reshape(data, model_axis),
        ("data", "model"),
    )


def batch_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def batch_shards(multi_pod: bool) -> int:
    return 32 if multi_pod else 16
