import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (device count locks at
# first backend init).  Everything below may import jax.

import argparse
import json
import re
import subprocess
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL, SHAPES, get_spec
from repro.models import abstract_params, param_partition_specs
from repro.models.sharding import sanitize_specs
from repro.models.model import decode_step, forward_train, prefill
from repro.models.sharding import (
    LONG_RULES,
    SERVE_RULES,
    SERVE_RULES_MULTIPOD,
    TRAIN_RULES,
    TRAIN_RULES_MULTIPOD,
    axis_rules,
)
from repro.train import make_optimizer, make_train_step, opt_state_specs
from repro.launch.hlo_analysis import collective_bytes_loop_aware
from repro.launch.mesh import batch_axes, batch_shards, make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

# ---------------------------------------------------------------------------
# Collective-byte accounting from post-optimisation HLO text
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum operand bytes of every collective op in the compiled HLO."""
    per_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*\S+\s+([a-z\-]+)(?:-start|-done)?\(", stripped)
        if not m:
            continue
        op = m.group(1)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        shapes = _SHAPE_RE.findall(stripped)
        if not shapes:
            continue
        # first shape = output (possibly tuple elements first); operands follow
        # the opening paren — take shapes appearing after '('.
        paren = stripped.index("(")
        operand_shapes = _SHAPE_RE.findall(stripped[paren:])
        use = operand_shapes if operand_shapes else shapes[-1:]
        total = sum(_shape_bytes(dt, dims) for dt, dims in use)
        per_kind[base] += total
        counts[base] += 1
    return {
        "bytes_by_kind": per_kind,
        "counts": counts,
        "total_bytes": sum(per_kind.values()),
    }


def _memory_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    except Exception as e:  # pragma: no cover
        out["error"] = repr(e)
    return out


def _cost_dict(compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in dict(ca).items():
            if isinstance(v, (int, float)):
                out[k] = float(v)
    except Exception as e:  # pragma: no cover
        out["error"] = repr(e)
    return out


# ---------------------------------------------------------------------------
# Cell construction: (arch, shape, mesh) -> jitted fn + abstract args
# ---------------------------------------------------------------------------

def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _cache_specs(spec, shape_name: str, multi_pod: bool):
    return _cache_specs_for(spec, shape_name, multi_pod,
                            spec.input_specs(shape_name)["cache"])


def _cache_specs_for(spec, shape_name: str, multi_pod: bool, cache_tree):
    """PartitionSpec per decode-cache leaf, by leaf name."""
    ba = batch_axes(multi_pod)
    bt = ba if len(ba) > 1 else ba[0]
    long = shape_name == "long_500k"
    seq_mode = spec.decode_cache_shard == "seq"

    def leaf_spec(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name.startswith(("k", "v", "ck", "cv")) and nd == 5:
            if long:
                return P(None, None, "data", "model", None)
            if seq_mode:
                return P(None, bt, "model", None, None)
            return P(None, bt, None, "model", None)
        if name.startswith("ssm"):
            return P(None, None if long else bt, "model", None)
        if name.startswith("conv"):
            return P(None, None if long else bt, None, "model")
        if name.startswith("wkv"):
            return P(None, None if long else bt, "model", None, None)
        if name.startswith(("sa", "sc")):
            return P(None, None if long else bt, "model")
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    spec = get_spec(arch)
    cfg = spec.model
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    kind = SHAPES[shape_name]["kind"]
    ba = batch_axes(multi_pod)
    bt = ba if len(ba) > 1 else ba[0]

    if kind == "train":
        rules = dict(TRAIN_RULES_MULTIPOD if multi_pod else TRAIN_RULES)
        aparams = abstract_params(cfg, dtype=jnp.dtype(spec.train_param_dtype))
        pspecs = sanitize_specs(aparams, param_partition_specs(aparams, "train", multi_pod), sizes)
        opt = make_optimizer(spec.optimizer)
        astate = jax.eval_shape(opt.init, aparams)
        sspecs = sanitize_specs(astate, opt_state_specs(opt, aparams, astate, pspecs), sizes)
        abatch = spec.input_specs(shape_name)["batch"]
        bspecs = jax.tree.map(lambda l: P(bt, *([None] * (len(l.shape) - 1))), abatch)
        step = make_train_step(cfg, opt, microbatches=spec.train_microbatches,
                               batch_shards=batch_shards(multi_pod),
                               accum_dtype=jnp.dtype(spec.grad_accum_dtype))
        fn = jax.jit(
            step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, sspecs), _named(mesh, bspecs)),
            out_shardings=(_named(mesh, pspecs), _named(mesh, sspecs), None),
            donate_argnums=(0, 1),
        )
        args = (aparams, astate, abatch)
        return mesh, rules, fn, args

    # serving paths
    if shape_name == "long_500k":
        rules = dict(LONG_RULES)
    else:
        rules = dict(SERVE_RULES_MULTIPOD if multi_pod else SERVE_RULES)
    if spec.serve_fsdp:
        rules["fsdp"] = ("pod", "data") if multi_pod else ("data",)
        rules["experts"] = rules["fsdp"]
    mode = "train" if spec.serve_fsdp else "serve"
    aparams = abstract_params(cfg, dtype=jnp.bfloat16)
    pspecs = sanitize_specs(aparams, param_partition_specs(aparams, mode, multi_pod), sizes)
    ins = spec.input_specs(shape_name)

    if kind == "prefill":
        tok_spec = P(bt, None)

        def prefill_fn(params, tokens, frames=None, prefix_embeds=None):
            memory = None
            if cfg.is_enc_dec:
                from repro.models.model import encode

                memory = encode(cfg, params, frames)
            return prefill(cfg, params, tokens, prefix_embeds=prefix_embeds,
                           memory=memory, cache_len=SHAPES[shape_name]["seq_len"])

        # Explicit out shardings: without them the compiler may replicate the
        # produced KV cache (157 GB/device on arctic prefill_32k baseline).
        from repro.models.model import make_decode_cache

        acache = make_decode_cache(cfg, SHAPES[shape_name]["global_batch"],
                                   SHAPES[shape_name]["seq_len"],
                                   enc_len=SHAPES[shape_name]["seq_len"] if cfg.is_enc_dec else 0)
        ccspec = _named(mesh, sanitize_specs(
            acache, _cache_specs_for(spec, shape_name, multi_pod, acache), sizes))
        if cfg.is_enc_dec:
            ccspec = dict(ccspec)
            ccspec["cross_memory"] = NamedSharding(mesh, P(bt, None, None))
        out_sh = (None, ccspec)
        in_sh = [_named(mesh, pspecs), NamedSharding(mesh, tok_spec)]
        args = [aparams, ins["tokens"]]
        if "frames" in ins:
            in_sh.append(NamedSharding(mesh, P(bt, None, None)))
            args.append(ins["frames"])
            fn = jax.jit(lambda p, t, f: prefill_fn(p, t, frames=f),
                         in_shardings=tuple(in_sh), out_shardings=out_sh)
        elif "prefix_embeds" in ins:
            in_sh.append(NamedSharding(mesh, P(bt, None, None)))
            args.append(ins["prefix_embeds"])
            fn = jax.jit(lambda p, t, e: prefill_fn(p, t, prefix_embeds=e),
                         in_shardings=tuple(in_sh), out_shardings=out_sh)
        else:
            fn = jax.jit(prefill_fn, in_shardings=tuple(in_sh), out_shardings=out_sh)
        return mesh, rules, fn, tuple(args)

    # decode: READ-ONLY cache (paged semantics) — an in-place
    # dynamic-update-slice on a seq-sharded cache forces GSPMD to re-gather
    # the whole cache every step (85.9 GB/step on qwen3 decode_32k,
    # EXPERIMENTS.md §Perf); the new token's KV returns as a fragment.
    cspecs = sanitize_specs(ins["cache"], _cache_specs(spec, shape_name, multi_pod), sizes)
    tok_spec = P(None, None) if shape_name == "long_500k" else P(bt, None)
    fn = jax.jit(
        lambda p, t, c: decode_step(cfg, p, t, c, update_cache=False),
        in_shardings=(
            _named(mesh, pspecs),
            NamedSharding(mesh, tok_spec),
            _named(mesh, cspecs),
        ),
    )
    args = (aparams, ins["token"], ins["cache"])
    return mesh, rules, fn, args


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    t0 = time.time()
    spec = get_spec(arch)
    mesh_name = "multipod" if multi_pod else "pod"
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
    }
    if shape_name not in spec.runnable_shapes():
        rec["status"] = "skipped"
        rec["reason"] = spec.skip_notes.get(shape_name, "not applicable")
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    try:
        mesh, rules, fn, args = build_cell(arch, shape_name, multi_pod)
        with mesh, axis_rules(rules, mesh=mesh):
            lowered = fn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            hlo = compiled.as_text()
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower - t0, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)
        rec["memory"] = _memory_dict(compiled)
        rec["cost"] = _cost_dict(compiled)
        rec["collectives"] = collective_bytes(hlo)            # raw (loop-once)
        rec["collectives_loop_aware"] = collective_bytes_loop_aware(hlo)
        rec["hlo_bytes"] = len(hlo)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
    rec["wall_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run: lower+compile every cell")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true", help="every (arch x shape) via subprocesses")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = ALL if args.arch is None else [args.arch]
        shapes = list(SHAPES) if args.shape is None else [args.shape]
        failures = 0
        for arch in archs:
            for shape in shapes:
                for mesh in meshes:
                    path = os.path.join(args.out, f"{arch}__{shape}__{mesh}.json")
                    if args.skip_existing and os.path.exists(path):
                        with open(path) as f:
                            prev = json.load(f)
                        if prev.get("status") in ("ok", "skipped"):
                            print(f"[skip] {arch} {shape} {mesh}: cached {prev['status']}")
                            continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mesh,
                           "--out", args.out]
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       env={**os.environ})
                    tail = (r.stdout + r.stderr).strip().splitlines()
                    print(f"[{arch} {shape} {mesh}] rc={r.returncode} "
                          + (tail[-1] if tail else ""))
                    if r.returncode != 0:
                        failures += 1
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape required without --all"
    ok = True
    for mesh in meshes:
        rec = run_cell(args.arch, args.shape, mesh == "multipod", args.out)
        status = rec["status"]
        if status == "ok":
            mem = rec["memory"]
            per_dev = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 1e9
            flops = rec["cost"].get("flops", 0)
            print(f"{args.arch} {args.shape} {mesh}: OK compile={rec['compile_s']}s "
                  f"mem/dev={per_dev:.2f}GB flops={flops:.3g} "
                  f"coll={rec['collectives']['total_bytes']/1e9:.3f}GB")
        elif status == "skipped":
            print(f"{args.arch} {args.shape} {mesh}: SKIPPED ({rec['reason']})")
        else:
            print(f"{args.arch} {args.shape} {mesh}: ERROR {rec['error']}")
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
