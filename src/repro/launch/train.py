"""Training launcher: real training on CPU (smoke/reduced configs) or dry-run
lowering for the production mesh; checkpoint/restart built in.

    python -m repro.launch.train --arch smollm-135m --steps 200 --smoke
    python -m repro.launch.train --arch qwen3-14b --steps 100 --smoke --resume
"""

from __future__ import annotations

import argparse
import os
import time

import jax

from repro.configs import get_spec
from repro.models import init_params
from repro.train import (
    make_optimizer,
    make_train_step,
    restore_latest,
    save_checkpoint,
    synth_batch,
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    spec = get_spec(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    ckpt_dir = args.ckpt_dir or os.path.join("artifacts", "ckpt", args.arch)
    opt = make_optimizer(spec.optimizer, lr=args.lr)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state = opt.init(params)
    start = 0
    if args.resume:
        restored = restore_latest(ckpt_dir, {"params": params, "opt": state})
        if restored:
            start, tree = restored
            params, state = tree["params"], tree["opt"]
            print(f"resumed from step {start}")
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches,
                                      batch_shards=1))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = synth_batch(cfg, global_batch=args.batch, seq_len=args.seq,
                            seed=args.seed, step=i)
        params, state, metrics = step_fn(params, state, batch)
        if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
            save_checkpoint(ckpt_dir, i + 1, {"params": params, "opt": state})
        if i % 10 == 0 or i + 1 == args.steps:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/max(i-start+1,1):.2f}s/step)")
    print(f"done: {args.steps} steps, checkpoints in {ckpt_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
