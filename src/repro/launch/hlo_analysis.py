"""Loop-aware HLO accounting.

XLA's ``cost_analysis``/HLO text count a while-loop body ONCE, but our step
functions scan over layer periods, microbatches, attention chunks and MoE
dispatch chunks — so raw counts undercount looped collectives by the trip
product.  This parser segments the post-optimisation HLO into computations,
extracts each while's trip count from the largest integer constant in its
condition computation (the loop bound the induction variable is compared
against), and propagates multipliers through the call graph (while bodies,
fusions, calls).  Collective bytes are then summed with multipliers applied.

Validated against hand-built scans in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import re
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def split_computations(hlo: str) -> tuple[dict[str, list[str]], str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    current: str | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if current is None:
            m = _COMP_HDR.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                if stripped.startswith("ENTRY"):
                    entry = current
            continue
        if stripped == "}":
            current = None
            continue
        comps[current].append(stripped)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    """Largest integer constant in the while condition = the loop bound."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            v = int(m.group(1))
            if 1 < v <= 1_000_000:
                best = max(best, v)
    return best


def computation_multipliers(hlo: str) -> dict[str, float]:
    """Execution-count multiplier per computation, from ENTRY."""
    comps, entry = split_computations(hlo)
    if entry is None:
        return {name: 1.0 for name in comps}
    mult: dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0) -> None:
        if name not in comps or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(line)  # XLA-annotated exact trip count
                trips = int(tm.group(1)) if tm else _trip_count(comps.get(cond, []))
                visit(cond, m * (trips + 1), depth + 1)
                visit(body, m * trips, depth + 1)
                continue
            for cm in _CALL_RE.finditer(line):
                visit(cm.group(1), m, depth + 1)

    visit(entry, 1.0)
    return mult


def collective_bytes_loop_aware(hlo: str) -> dict[str, Any]:
    """Per-kind collective operand bytes with loop-trip multipliers."""
    comps, entry = split_computations(hlo)
    mult = computation_multipliers(hlo)
    per_kind = {k: 0.0 for k in COLLECTIVES}
    raw_kind = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for line in lines:
            om = re.search(r"=\s*\S+\s+([a-z\-]+?)(-start|-done)?\(", line)
            if not om:
                continue
            base = om.group(1)
            if base not in COLLECTIVES or om.group(2) == "-done":
                continue
            paren = line.index("(")
            shapes = _SHAPE_RE.findall(line[paren:])
            from_output = False
            if not shapes:
                # scheduled HLO omits operand types; fall back to the op's
                # OUTPUT shape and normalise to operand bytes below.
                shapes = _SHAPE_RE.findall(line[:paren])[:1]
                from_output = True
            if not shapes:
                continue
            total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            if from_output:
                gm = _GROUPS_RE.search(line)
                gs = int(gm.group(2)) if gm else 1
                if base == "all-gather" and gs > 0:
                    total /= gs          # output = group_size x operand
                elif base == "reduce-scatter":
                    total *= gs          # operand = group_size x output
            per_kind[base] += total * m
            raw_kind[base] += total
            counts[base] += 1
    return {
        "bytes_by_kind": {k: int(v) for k, v in per_kind.items()},
        "raw_bytes_by_kind": {k: int(v) for k, v in raw_kind.items()},
        "counts": counts,
        "total_bytes": int(sum(per_kind.values())),
        "raw_total_bytes": int(sum(raw_kind.values())),
    }
