"""repro: NetKV — network-aware decode-instance selection for disaggregated
LLM inference, as a production-grade JAX serving/training framework."""

__version__ = "1.0.0"
