"""Checkpoint/restart: atomic, resumable, pure numpy+json (no orbax).

Layout:  <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
renamed atomically so a preemption mid-write never corrupts the latest
checkpoint.  ``restore_latest`` returns the newest complete step.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrays = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        meta = {"step": step, "n_arrays": len(arrays), "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # Retention: keep the 3 newest.
    steps = sorted(list_checkpoints(ckpt_dir))
    for s in steps[:-3]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    return final


def list_checkpoints(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "meta.json")
        ):
            out.append(int(name[5:]))
    return sorted(out)


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure (and dtypes) of ``like``."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for keypath, leaf in flat[0]:
        k = jax.tree_util.keystr(keypath)
        arr = data[k]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def restore_latest(ckpt_dir: str, like: Any) -> tuple[int, Any] | None:
    steps = list_checkpoints(ckpt_dir)
    if not steps:
        return None
    step = steps[-1]
    return step, restore_checkpoint(ckpt_dir, step, like)
