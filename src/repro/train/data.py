"""Synthetic, seeded, step-indexed data pipeline.

Every batch is a pure function of (seed, step), so a restart from checkpoint
step N reproduces the exact remaining data stream — the property that makes
checkpoint/restart bitwise reproducible (verified in tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelConfig


def synth_batch(cfg: ModelConfig, *, global_batch: int, seq_len: int, seed: int,
                step: int) -> dict:
    """Markov-ish token stream: next token depends on previous (learnable)."""
    rng = np.random.default_rng((seed * 1_000_003 + step) & 0x7FFFFFFF)
    b = global_batch
    if cfg.frontend == "vision":
        s_tok = seq_len - cfg.n_prefix_embeds
    else:
        s_tok = seq_len
    # Learnable structure: tokens follow t[i+1] = (a*t[i] + noise) % V over a
    # reduced alphabet so small models can fit it in a few hundred steps.
    v = min(cfg.vocab_size, 256)
    a = 31
    t0 = rng.integers(0, v, size=(b, 1))
    noise = rng.integers(0, 3, size=(b, s_tok))
    toks = np.empty((b, s_tok), np.int64)
    toks[:, 0] = t0[:, 0]
    for i in range(1, s_tok):
        toks[:, i] = (a * toks[:, i - 1] + noise[:, i]) % v
    tokens = jnp.asarray(toks[:, :-1], jnp.int32)
    labels = jnp.asarray(toks[:, 1:], jnp.int32)
    # Pad back to requested length for shape stability.
    tokens = jnp.pad(tokens, ((0, 0), (0, 1)))
    labels = jnp.pad(labels, ((0, 0), (0, 1)))
    batch = {"tokens": tokens, "labels": labels}
    if cfg.is_enc_dec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, seq_len, cfg.d_model)), jnp.bfloat16
        )
    elif cfg.frontend == "vision":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_prefix_embeds, cfg.d_model)), jnp.bfloat16
        )
    return batch
