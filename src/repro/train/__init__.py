"""Training substrate: optimizers, sharded train step, checkpointing, data."""

from .optimizer import AdamW, Adafactor, make_optimizer, opt_state_specs
from .train_step import make_train_step, microbatch_split
from .checkpoint import (
    list_checkpoints,
    restore_checkpoint,
    restore_latest,
    save_checkpoint,
)
from .data import synth_batch

__all__ = [k for k in dir() if not k.startswith("_")]
