"""Sharded train step: grad-accumulated microbatches + remat'd backbone.

Microbatch layout: the global batch (B, ...) is viewed as
(batch_shards, mb, local/mb, ...) and the mb axis is moved to the front so
that every microbatch takes an equal slice from every data shard — no shard
idles during accumulation.  Gradients accumulate in f32 with the same
sharding as the parameters (FSDP), so the accumulator adds params/num_shards
bytes per chip.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, forward_train
from repro.models.sharding import constrain


def effective_microbatches(global_batch: int, mb: int, batch_shards: int) -> int:
    """Largest feasible mb <= requested that divides the per-shard batch."""
    local = max(global_batch // batch_shards, 1)
    mb = min(mb, local)
    while local % mb:
        mb -= 1
    return max(mb, 1)


def microbatch_split(batch, mb: int, batch_shards: int):
    def split(x):
        b = x.shape[0]
        local = b // batch_shards
        x = x.reshape(batch_shards, mb, local // mb, *x.shape[1:])
        x = jnp.moveaxis(x, 1, 0)
        return x.reshape(mb, b // mb, *x.shape[3:])

    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, optimizer, microbatches: int = 1,
                    batch_shards: int = 1, aux_weight: float = 0.01,
                    accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, mb_batch):
        loss, parts = forward_train(cfg, params, mb_batch, aux_weight=aux_weight)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        gb = jax.tree.leaves(batch)[0].shape[0]
        mb_eff = effective_microbatches(gb, microbatches, batch_shards)
        if mb_eff <= 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            mbs = microbatch_split(batch, mb_eff, batch_shards)

            def body(carry, mb_batch):
                gsum, lsum = carry
                (loss, _), g = grad_fn(params, mb_batch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gsum, g
                )
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / mb_eff, gsum)
            loss = lsum / mb_eff
            parts = {}
        new_params, new_state = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
