"""Optimizers in pure JAX: AdamW and (factored) Adafactor.

Adafactor is the default for the MoE giants (arctic-480b, jamba-52b): its
factored second moment keeps optimizer state ~O(params/1000), which is what
lets train_4k fit 16 GB/chip HBM at 256 chips (DESIGN §7).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}

    def state_partition_specs(self, param_spec_tree):
        return {
            "m": param_spec_tree,
            "v": param_spec_tree,
            "step": P(),
        }


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params):
        def leaf_state(p):
            if self._factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "acc": jax.tree.map(leaf_state, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-self.decay)

        def upd(g, acc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if self._factored(g.shape):
                vr = beta * acc["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * acc["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                    + self.eps
                )
                cfac = jax.lax.rsqrt(vc + self.eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                new_acc = {"vr": vr, "vc": vc}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + self.eps)
                new_acc = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            newp = p.astype(jnp.float32) - self.lr * (
                u + self.weight_decay * p.astype(jnp.float32)
            )
            return newp.astype(p.dtype), new_acc

        out = jax.tree.map(upd, grads, state["acc"], params,
                           is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
        # out mirrors params' structure with (new_param, new_acc) leaf tuples
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_acc = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"acc": new_acc, "step": step}

    def state_partition_specs(self, param_spec_tree):
        def leaf_spec(spec):
            dims = tuple(spec) if spec is not None else ()
            def pad(d, n):
                d = list(d)
                while len(d) < n:
                    d.append(None)
                return d
            # vr: drop last dim; vc: drop second-to-last.  We cannot know the
            # rank here, so emit specs lazily via a callable resolved by the
            # launcher against the abstract state.
            return spec
        # The launcher maps acc leaves by name using param specs:
        return {"acc": param_spec_tree, "step": P()}


def make_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise ValueError(name)


def opt_state_specs(opt, abstract_params, abstract_state, param_spec_tree):
    """PartitionSpec tree matching ``abstract_state`` exactly.

    Adam m/v mirror params; Adafactor vr/vc drop one dim from the param spec.
    """
    if isinstance(opt, AdamW):
        return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}

    params_flat = jax.tree_util.tree_leaves_with_path(abstract_params)
    specs_flat = jax.tree_util.tree_leaves(param_spec_tree, is_leaf=lambda x: isinstance(x, P))
    spec_by_path = {
        jax.tree_util.keystr(p): s for (p, _), s in zip(params_flat, specs_flat)
    }

    def acc_spec(path, leaf):
        # path into state: acc/<param path...>/{vr|vc|v}
        kind = str(path[-1].key)
        ppath = jax.tree_util.keystr(path[1:-1])
        pspec = spec_by_path.get(ppath, P())
        dims = list(tuple(pspec)) if pspec else []
        while len(dims) < len(leaf.shape) + (1 if kind in ("vr", "vc") else 0):
            dims.append(None)
        if kind == "vr":
            dims = dims[:-1]
        elif kind == "vc":
            dims = dims[:-2] + dims[-1:]
        dims = dims[: len(leaf.shape)]
        while len(dims) < len(leaf.shape):
            dims.append(None)
        return P(*dims)

    acc = jax.tree_util.tree_map_with_path(
        lambda p, l: acc_spec(p, l), abstract_state["acc"]
    )
    return {"acc": acc, "step": P()}
