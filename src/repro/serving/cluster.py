"""Disaggregated serving cluster: real models + NetKV routing + timed fabric.

The executable end-to-end driver (examples/disaggregated_cluster.py):
prefill engines and decode engines hold REAL weights; the KV cache moves
through kv_pack/kv_unpack; the flow-level fat-tree provides transfer
*timing*; NetKV (or any ladder policy) picks the decode instance per
request.  Generated tokens are exact (tests compare against a monolithic
forward), while TTFT statistics come from the simulated clock.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.cluster.network import BackgroundTraffic, FlowNetwork
from repro.cluster.topology import FatTree, make_instances
from repro.core.cost import B_TOK, IterTimeModel, PrefillTimeModel
from repro.core.oracle import NetworkCostOracle, SelfContentionTracker
from repro.core.schedulers import RequestInfo, make_scheduler
from repro.core.view import ClusterView
from repro.models.model import ModelConfig, init_params
from .engine import DecodeEngine, PrefillEngine
from .transfer import pack_transfer, unpack_transfer


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0


@dataclasses.dataclass
class ServeResult:
    request_id: int
    tokens: list[int]
    prefill_instance: int
    decode_instance: int
    tier: int
    transfer_bytes: int
    ttft: float           # simulated-clock TTFT
    transfer_time: float


class DisaggregatedCluster:
    """Small-cluster executable disaggregated serving with NetKV routing."""

    def __init__(self, cfg: ModelConfig, *, scheduler: str = "netkv-full",
                 n_prefill: int = 2, n_decode: int = 4, n_slots: int = 4,
                 cache_len: int = 256, seed: int = 0,
                 tree: FatTree | None = None, background: float = 0.2):
        import jax

        self.cfg = cfg
        self.cache_len = cache_len
        params = init_params(cfg, jax.random.PRNGKey(seed))
        self.tree = tree or FatTree()
        self.net = FlowNetwork(self.tree, BackgroundTraffic(background), seed=seed)
        pre_meta, dec_meta = make_instances(self.tree, tp=4,
                                            n_prefill=max(n_prefill, 1))
        pre_meta = pre_meta[:n_prefill]
        dec_meta = dec_meta[:n_decode]
        self.prefill = [
            PrefillEngine(m.instance_id, cfg, params, cache_len) for m in pre_meta
        ]
        self.decode = [
            DecodeEngine(m.instance_id, cfg, params, n_slots=n_slots,
                         cache_len=cache_len)
            for m in dec_meta
        ]
        self._server_of = {m.instance_id: m.server for m in (*pre_meta, *dec_meta)}
        self.iter_model = IterTimeModel(a=0.0124, b=1.6e-5)
        self.oracle = NetworkCostOracle(
            tier_of=lambda a, b: self.tree.tier(self._server_of[a], self._server_of[b]),
            topology=self.tree,
            telemetry_fn=lambda now: self.net.tier_congestion(now),
        )
        self.inflight = SelfContentionTracker()
        self.sched = make_scheduler(scheduler, self.iter_model, beta_max=n_slots,
                                    m_min=0.0)
        self.clock = 0.0
        # Per-decode-instance block-hash sets for the prefix-hit signal.
        self._cached_hashes: dict[int, set] = {d.instance_id: set() for d in self.decode}

    # ------------------------------------------------------------------ serve
    def _hit_pages(self, decode_id: int, prompt: np.ndarray) -> int:
        cached = self._cached_hashes[decode_id]
        pages = 0
        for start in range(0, len(prompt) - len(prompt) % B_TOK, B_TOK):
            h = hash(tuple(prompt[start:start + B_TOK].tolist()))
            if h in cached:
                pages += 1
            else:
                break
        return pages

    def _remember(self, decode_id: int, prompt: np.ndarray) -> None:
        cached = self._cached_hashes[decode_id]
        for start in range(0, len(prompt) - len(prompt) % B_TOK, B_TOK):
            cached.add(hash(tuple(prompt[start:start + B_TOK].tolist())))

    def serve(self, requests: Sequence[ServeRequest]) -> list[ServeResult]:
        results = []
        for req in sorted(requests, key=lambda r: r.arrival):
            self.clock = max(self.clock, req.arrival)
            # 1. prefill on the least-loaded prefill engine (round robin here).
            pe = self.prefill[req.request_id % len(self.prefill)]
            pre = pe.run(req.request_id, req.prompt)
            prefill_time = 5e-5 * len(req.prompt) + 0.015
            t_prefill_done = self.clock + prefill_time

            # 2. decode-instance selection (Algorithm 1 over columnar state).
            view = self.oracle.view(t_prefill_done)
            cv = ClusterView(tier_fn=view.tier_of, capacity=len(self.decode))
            for d in self.decode:
                cv.add_instance(
                    d.instance_id,
                    free_memory=float(len(d.free_slots())) * 1e12,  # slot-gated
                    queued=0,
                    batch=d.beta,
                    hit_tokens=float(self._hit_pages(d.instance_id, req.prompt) * B_TOK),
                    healthy=len(d.free_slots()) > 0,
                )
            info = RequestInfo(req.request_id, len(req.prompt), float(pre.kv_bytes))
            decision = self.sched.select(info, pe.instance_id, cv, view, self.inflight)
            assert decision is not None, "no feasible decode instance"
            de = next(d for d in self.decode if d.instance_id == decision.instance_id)

            # 3. pack + timed transfer + unpack (real tensors move).
            hit_pages = self._hit_pages(de.instance_id, req.prompt)
            buffers, nbytes = pack_transfer(pre.cache, hit_pages)
            done = []
            self.net.start_transfer(
                self._server_of[pe.instance_id], self._server_of[de.instance_id],
                float(max(nbytes, 1)), t_prefill_done,
                on_complete=lambda tr, t: done.append(t), n_flows=4,
            )
            t = t_prefill_done
            while not done:
                nxt = self.net.next_completion_time(t)
                if nxt is None:
                    break
                t = nxt
                self.net.advance(t)
            t_transfer_done = done[0] if done else t_prefill_done
            cache = dict(unpack_transfer(buffers, pre.cache))
            cache["pos"] = pre.cache["pos"]
            pre_landed = dataclasses.replace(pre, cache=cache)

            # 4. decode until done.
            de.admit(req.request_id, pre_landed, req.max_new)
            if self.sched.uses_self_contention:
                self.inflight.decr(pe.instance_id, decision.tier)
            self._remember(de.instance_id, req.prompt)
            toks = [pre.first_token]
            while any(s.active and s.request_id == req.request_id for s in de.slots):
                emitted = de.step()
                toks.extend(t for rid, t in emitted if rid == req.request_id)
            t_first = t_transfer_done + self.iter_model(de.beta + 1)
            results.append(ServeResult(
                request_id=req.request_id,
                tokens=toks,
                prefill_instance=pe.instance_id,
                decode_instance=de.instance_id,
                tier=decision.tier,
                transfer_bytes=nbytes,
                ttft=t_first - req.arrival + prefill_time,
                transfer_time=t_transfer_done - t_prefill_done,
            ))
            self.clock = t_transfer_done
        return results
