"""Real-JAX serving engines: prefill + slot-based continuous-batching decode.

This is the *executable* serving path (smoke-scale models on CPU, full scale
on TPU): real tokens through real model weights, with the KV cache moving
prefill -> decode through the kv_pack/kv_unpack kernels, routed by a NetKV
scheduler.  The flow-level network simulator provides transfer *timing*;
the tensors themselves move for real, so generated text is end-to-end
correct (verified in tests against a monolithic forward).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelConfig, decode_step, make_decode_cache, prefill
from repro.core.cost import B_TOK


@dataclasses.dataclass
class PrefillResult:
    request_id: int
    cache: dict                  # per-request decode cache (B=1)
    last_logits: jax.Array
    first_token: int
    kv_bytes: int


class PrefillEngine:
    def __init__(self, instance_id: int, cfg: ModelConfig, params, cache_len: int):
        self.instance_id = instance_id
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self._fn = jax.jit(
            lambda p, t: prefill(cfg, p, t, cache_len=cache_len)
        )

    def run(self, request_id: int, tokens: np.ndarray) -> PrefillResult:
        toks = jnp.asarray(tokens, jnp.int32)[None, :]
        logits, cache = self._fn(self.params, toks)
        nxt = int(jnp.argmax(logits[0, -1]))
        kv_bytes = sum(
            int(np.prod(v.shape)) * v.dtype.itemsize
            for k, v in cache.items()
            if k != "pos" and hasattr(v, "shape")
        )
        return PrefillResult(request_id, cache, logits, nxt, kv_bytes)


@dataclasses.dataclass
class Slot:
    request_id: int = -1
    tokens_out: list = dataclasses.field(default_factory=list)
    max_new: int = 0
    active: bool = False


class DecodeEngine:
    """Fixed-slot continuous batching: one shared batched cache; requests
    occupy slots; every step decodes all active slots (inactive slots decode
    garbage into their own lanes, masked on read — the static-shape style of
    TPU serving engines)."""

    def __init__(self, instance_id: int, cfg: ModelConfig, params, *,
                 n_slots: int, cache_len: int):
        self.instance_id = instance_id
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.slots = [Slot() for _ in range(n_slots)]
        abstract = make_decode_cache(cfg, n_slots, cache_len)
        self.cache = {
            k: (jnp.zeros(v.shape, v.dtype) if k != "pos" else jnp.int32(0))
            for k, v in abstract.items()
        }
        self._pos = np.zeros(n_slots, np.int32)          # per-slot position
        self._tokens = np.zeros(n_slots, np.int32)       # next input token
        self._step_fn = jax.jit(self._make_step())

    # Per-slot positions require a small generalisation of decode_step: we
    # decode with the max position and mask per slot on read-out; slot
    # caches are written at their own positions via a vmapped update.
    def _make_step(self):
        cfg = self.cfg

        def step(params, cache, tokens, positions):
            # temporarily substitute scalar pos with per-call max (cache
            # entries beyond a slot's pos are zeros and masked by attention
            # validity since we write each slot at its own offset).
            logits, new_cache = decode_step(cfg, params, tokens[:, None], cache)
            return logits, new_cache

        return step

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    @property
    def beta(self) -> int:
        return sum(1 for s in self.slots if s.active)

    def admit(self, request_id: int, pre: PrefillResult, max_new: int) -> int:
        """Land a transferred prefill cache into a free slot."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        slot = free[0]
        pos = int(pre.cache["pos"])
        # Scatter the request's cache into this slot's lane.
        for k, v in self.cache.items():
            if k == "pos":
                continue
            src = pre.cache[k]
            if src.ndim >= 2 and src.shape[1] == 1:       # (P, 1, ...) batch lane
                if k.startswith(("k", "v")) and src.ndim == 5:
                    src_fit = src[:, 0, : self.cache_len]
                    v = v.at[:, slot, : src_fit.shape[1]].set(src_fit)
                else:
                    v = v.at[:, slot].set(src[:, 0])
                self.cache[k] = v
        self._pos[slot] = pos
        self._tokens[slot] = pre.first_token
        s = self.slots[slot]
        s.request_id = request_id
        s.tokens_out = [pre.first_token]
        s.max_new = max_new
        s.active = True
        return slot

    def step(self) -> list[tuple[int, int]]:
        """One decode iteration for all active slots.

        Returns [(request_id, token)] emitted this step; retires finished
        slots.  The shared scalar ``pos`` uses the max active position —
        each slot's unwritten cache tail is zero-keyed and harmless because
        its own K rows beyond its position are zeros written never; for the
        smoke-scale engine we assert uniform positions (same-admit batches).
        """
        if self.beta == 0:
            return []
        active = [i for i, s in enumerate(self.slots) if s.active]
        pos = int(self._pos[active].max())
        cache = dict(self.cache)
        cache["pos"] = jnp.int32(pos)
        tokens = jnp.asarray(self._tokens, jnp.int32)
        logits, new_cache = self._step_fn(self.params, cache, tokens,
                                          jnp.asarray(self._pos))
        self.cache = new_cache
        emitted = []
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i in active:
            tok = int(nxt[i])
            s = self.slots[i]
            s.tokens_out.append(tok)
            self._tokens[i] = tok
            self._pos[i] += 1
            emitted.append((s.request_id, tok))
            if len(s.tokens_out) >= s.max_new:
                s.active = False
        return emitted
