"""KV transfer path: page the prefill cache, pack to a contiguous buffer.

On TPU the pack runs the Pallas ``kv_pack`` kernel (single large DMA out);
here it validates in interpret mode.  The byte count it returns is what the
NetKV cost model prices (Eq. 1/2): callers skip packing the prefix-hit pages
(Eq. 2's lambda term).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.cost import B_TOK
from repro.kernels import ops


def paged_view(k_cache, page_tokens: int = B_TOK):
    """(P, 1, S, KV, dh) per-request cache leaf -> (P*S/page, page, KV, dh)."""
    p, b, s, kv, dh = k_cache.shape
    assert b == 1
    n_pages = s // page_tokens
    return k_cache.reshape(p * n_pages, page_tokens, kv, dh)


def pack_transfer_chunk(cache: dict, hit_pages: int, start_page: int,
                        end_page: int | None = None, *, final: bool = True,
                        page_tokens: int = B_TOK):
    """Pack one *streamed chunk* of the cache: attention pages in
    ``[max(hit_pages, start_page), min(end_page, valid))``.

    This is the executable twin of the simulator's ``kv_streaming`` path
    (ChunkPlane): as each prefill chunk's KV becomes ready, its pages are
    packed and shipped while later chunks are still computing.  Sequence-
    length-independent state (Mamba SSM / RWKV WKV / token-shift) is only
    consistent once the whole prompt is processed, so it rides with the
    ``final`` chunk.  Concatenating the chunk tables of a full sweep
    reproduces ``pack_transfer``'s pages and byte total exactly
    (byte conservation, ``tests/test_serving_e2e.py``).

    Returns (buffers dict, total_bytes).
    """
    buffers = {}
    total = 0
    for name, leaf in cache.items():
        if name == "pos" or not hasattr(leaf, "shape"):
            continue
        if name.startswith(("k", "v")) and leaf.ndim == 5:
            pos = int(cache["pos"])
            n_pages_valid = max((pos + page_tokens - 1) // page_tokens, 0)
            lo = max(hit_pages, start_page)
            hi = n_pages_valid if end_page is None else min(end_page, n_pages_valid)
            pool = paged_view(leaf, page_tokens)
            periods = leaf.shape[0]
            pages_per_period = leaf.shape[2] // page_tokens
            table = []
            for per in range(periods):
                for pg in range(lo, hi):
                    table.append(per * pages_per_period + pg)
            if not table:
                continue
            buf = ops.kv_pack(pool, jnp.asarray(table, jnp.int32))
            buffers[name] = (buf, tuple(table))
            total += buf.size * buf.dtype.itemsize
        elif final:
            # Fixed-size state (Mamba/RWKV/pos-independent): ships whole,
            # with the last chunk.
            buffers[name] = (leaf, None)
            total += leaf.size * leaf.dtype.itemsize
    return buffers, total


def pack_transfer(cache: dict, hit_pages: int, page_tokens: int = B_TOK):
    """Pack every non-hit page of the attention KV leaves into one buffer.

    Returns (buffers dict, total_bytes) — the effective transfer payload
    s_eff of Eq. (2), materialised.  Equivalent to a single whole-range
    chunk of :func:`pack_transfer_chunk`.
    """
    return pack_transfer_chunk(cache, hit_pages, 0, None, final=True,
                               page_tokens=page_tokens)


def merge_chunk_buffers(chunks: list[dict]) -> dict:
    """Merge per-chunk buffer dicts (in chunk order) into one transfer-
    equivalent dict suitable for :func:`unpack_transfer`: paged leaves get
    their buffers concatenated along the page axis and their tables
    chained; fixed-state leaves take the last (final-chunk) value."""
    out: dict = {}
    for buffers in chunks:
        for name, (buf, table) in buffers.items():
            if table is None:
                out[name] = (buf, None)
            elif name in out:
                prev, ptab = out[name]
                out[name] = (jnp.concatenate([prev, buf], axis=0),
                             ptab + tuple(table))
            else:
                out[name] = (buf, tuple(table))
    return out


def unpack_transfer(buffers: dict, like_cache: dict, page_tokens: int = B_TOK):
    """Reassemble a per-request cache dict from transfer buffers."""
    out = {}
    for name, leaf in like_cache.items():
        if name == "pos" or not hasattr(leaf, "shape"):
            continue
        if name in buffers:
            buf, table = buffers[name]
            if table is None:
                out[name] = buf
            else:
                pool = jnp.zeros(
                    (int(np.prod((leaf.shape[0], leaf.shape[2] // page_tokens))),
                     page_tokens, leaf.shape[3], leaf.shape[4]),
                    leaf.dtype,
                )
                pool = ops.kv_unpack(pool, buf, jnp.asarray(table, jnp.int32))
                out[name] = pool.reshape(leaf.shape)
        else:
            out[name] = jnp.zeros(leaf.shape, leaf.dtype)
    return out
