"""Executable disaggregated serving: real engines + NetKV routing."""

from .engine import DecodeEngine, PrefillEngine, PrefillResult
from .cluster import DisaggregatedCluster, ServeRequest, ServeResult
from .transfer import pack_transfer, unpack_transfer

__all__ = ["DecodeEngine", "PrefillEngine", "PrefillResult",
           "DisaggregatedCluster", "ServeRequest", "ServeResult",
           "pack_transfer", "unpack_transfer"]
