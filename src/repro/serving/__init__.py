"""Executable disaggregated serving: real engines + NetKV routing."""

from .engine import DecodeEngine, PrefillEngine, PrefillResult
from .cluster import DisaggregatedCluster, ServeRequest, ServeResult
from .transfer import (
    merge_chunk_buffers, pack_transfer, pack_transfer_chunk, unpack_transfer,
)

__all__ = ["DecodeEngine", "PrefillEngine", "PrefillResult",
           "DisaggregatedCluster", "ServeRequest", "ServeResult",
           "merge_chunk_buffers", "pack_transfer", "pack_transfer_chunk",
           "unpack_transfer"]
