"""Retired per-object instance engine — kept as the InstancePlane parity oracle.

This module preserves the seed's prefill / decode / block-cache
implementations verbatim (``PrefillSim``, ``DecodeSim``, ``BlockCache``):
one heap event per decode iteration per instance, a Python dict of
``RequestState`` walked per token, and an ``OrderedDict`` LRU scanned per
hit-length query.  The production engine in ``sim/instances.py``
(``InstancePlane``) is struct-of-arrays with a single cohort-stepped
iteration clock and must stay *bit-identical* to this module — same TTFT,
TBT, finish times/order, per-instance cache-hit tokens and cache counters —
``tests/test_instanceplane_parity.py`` enforces it on seeded 64/256-GPU
runs.  Benchmarks use this engine as the "reference" arm
(``benchmarks/decode_throughput.py``).

Two intentional divergences from the seed, applied to BOTH engines:

* **KV-growth clamp** — the seed let decode-side KV growth push
  ``pinned_bytes`` past ``kv_budget`` with the scheduler then scoring the
  instance with *negative* free memory (phantom negative capacity).  Both
  engines now clamp the scheduler-visible ``free_memory`` at zero; growth
  still evicts the LRU cache each iteration (``evict_to``) exactly as
  before.
* **Two-phase admission** — ``admit_after_transfer`` is split into
  ``admit_enqueue`` (blocks resident, join the queue) + ``admit_kick``
  (start/continue iterating), so the simulator can admit every transfer
  landing in the same net tick as one epoch: enqueue all, then kick each
  touched instance once.  Same-instant landings on an idle instance
  therefore join the *same* first iteration instead of serialising on
  arrival order.  ``admit_after_transfer`` (= enqueue + kick) is retained
  for callers driving a single instance directly.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Callable, Hashable, Optional, Sequence

from repro.core.cost import B_TOK, IterTimeModel, ModelKVSpec, PrefillTimeModel
from repro.core.view import ROLE_DECODE, ROLE_PREFILL, ClusterView
from .engine import LANE_CLOCK, LANE_PREFILL, EventLoop


class BlockCache:
    """LRU over block hashes, budgeted in bytes (retired; see RadixPlane)."""

    def __init__(self, budget_bytes: float, bytes_per_block: float):
        self.budget = budget_bytes
        self.bytes_per_block = bytes_per_block
        self._lru: OrderedDict[Hashable, None] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def bytes_used(self) -> float:
        return len(self._lru) * self.bytes_per_block

    def __contains__(self, h: Hashable) -> bool:
        return h in self._lru

    def lcp_blocks(self, hashes: Sequence[Hashable]) -> int:
        """|LCP_block(h_r, K_d)|: leading blocks all present in the cache."""
        n = 0
        for h in hashes:
            if h in self._lru:
                n += 1
            else:
                break
        return n

    def hit_tokens(self, hashes: Sequence[Hashable], input_len: int) -> int:
        """lambda_r(d) = B_tok * LCP, clamped to the true input length."""
        return min(self.lcp_blocks(hashes) * B_TOK, input_len)

    def touch(self, hashes: Sequence[Hashable]) -> None:
        """Mark blocks as recently used (move to MRU end)."""
        for h in hashes:
            if h in self._lru:
                self._lru.move_to_end(h)
                self.hits += 1
            else:
                self.misses += 1

    def insert(self, hashes: Sequence[Hashable], protected: float = 0.0) -> None:
        """Insert blocks, evicting LRU entries beyond budget.

        ``protected`` bytes are pinned elsewhere (active batches) and shrink
        the evictable budget.
        """
        for h in hashes:
            self._lru[h] = None
            self._lru.move_to_end(h)
        limit = max(self.budget - protected, 0.0)
        while self.bytes_used > limit and self._lru:
            self._lru.popitem(last=False)
            self.evictions += 1

    def evict_to(self, protected: float) -> None:
        limit = max(self.budget - protected, 0.0)
        while self.bytes_used > limit and self._lru:
            self._lru.popitem(last=False)
            self.evictions += 1


class PrefillSim:
    """Serial prefill compute queue, T_prefill(l) = c*l + d (retired)."""

    def __init__(self, instance_id: int, server, prefill_model: PrefillTimeModel,
                 loop: EventLoop):
        self.instance_id = instance_id
        self.server = server
        self.model = prefill_model
        self.loop = loop
        self.busy_until = 0.0
        self.queue: deque = deque()
        self.running = None
        self.on_done: Callable | None = None
        self.healthy = True
        self.busy_s = 0.0        # telemetry: cumulative prefill seconds

    def submit(self, rs, now: float) -> None:
        rs.prefill_instance = self.instance_id
        self.queue.append(rs)
        self._maybe_start(now)

    def eta(self, now: float) -> float:
        """Earliest time a new request would *finish* prefill here."""
        t = max(self.busy_until, now)
        for rs in self.queue:
            t += self.model(rs.req.input_len)
        return t

    def _maybe_start(self, now: float) -> None:
        if self.running is not None or not self.queue or not self.healthy:
            return
        rs = self.queue.popleft()
        self.running = rs
        rs.prefill_start = max(now, self.busy_until)
        dur = self.model(rs.req.input_len)
        self.busy_s += dur
        self.busy_until = rs.prefill_start + dur
        self.loop.at(self.busy_until, self._finish, lane=LANE_PREFILL)

    def _finish(self, now: float) -> None:
        rs = self.running
        if rs is None:
            return
        rs.prefill_end = now
        self.running = None
        if self.on_done is not None:
            self.on_done(rs, now)
        self._maybe_start(now)


class ChunkedPrefillSim:
    """Scalar chunk-interleaved prefill oracle (per-object).

    The per-object mirror of the plane's ``ChunkPlane``: requests split
    into ``chunk_tokens``-token chunks, one prefill iteration serves the
    active requests' head chunks round-robin under ``token_budget`` tokens,
    costing ``c * tokens_served + d * first_chunks`` (the fixed overhead
    rides with the first chunk, so per-request compute telescopes to the
    monolithic ``c*l + d``).  ``on_chunk(rs, tokens_ready, now)`` fires as
    each chunk's KV becomes ready; ``on_done`` when the last one does.
    Must stay bit-exact with ``ChunkPlane``
    (``tests/test_chunkplane.py``), exactly like ``PrefillSim`` is the
    serial oracle.
    """

    def __init__(self, instance_id: int, server, prefill_model: PrefillTimeModel,
                 loop: EventLoop, chunk_tokens: int,
                 token_budget: int | None = None):
        self.instance_id = instance_id
        self.server = server
        self.model = prefill_model
        self.loop = loop
        self.chunk = int(chunk_tokens)
        self.budget = int(token_budget) if token_budget is not None \
            else int(chunk_tokens)
        self.busy_until = 0.0
        self.backlog = 0         # unclaimed tokens over all active requests
        self.pending = 0         # requests whose fixed overhead d is unpaid
        self.streams: list = []  # [rs, done_tokens, cancelled] in RR order
        self.inflight = None     # [(stream, take), ...] of the running iter
        self.on_done: Callable | None = None
        self.on_chunk: Callable | None = None
        self.healthy = True
        self.iterations = 0
        self.busy_s = 0.0        # telemetry: cumulative iteration seconds
        self.trace = None        # TracePlane sink; mirrors ChunkPlane
        self._iter_base = 0.0    # running iteration's start, kept while tracing

    @property
    def queued(self) -> int:
        return len(self.streams)

    def eta(self, now: float) -> float:
        """Drain time of the current backlog (new request's own c*l + d is
        an argmin-invariant constant, like PrefillSim.eta's convention)."""
        return max(self.busy_until, now) + self.model.c * self.backlog \
            + self.model.d * self.pending

    def submit(self, rs, now: float) -> None:
        rs.prefill_instance = self.instance_id
        self.streams.append([rs, 0, False])
        self.backlog += rs.req.input_len
        self.pending += 1
        self._maybe_start(now)

    def cancel(self, rs) -> None:
        for i, st in enumerate(self.streams):
            if st[0] is rs:
                break
        else:
            return
        del self.streams[i]
        st[2] = True
        claimed = st[1]
        if self.inflight is not None:
            for entry, take in self.inflight:
                if entry is st:
                    claimed += take
                    break
        self.backlog -= max(rs.req.input_len - claimed, 0)
        if st[1] == 0 and claimed == 0:
            self.pending -= 1

    def _maybe_start(self, now: float) -> None:
        if self.inflight is not None or not self.healthy or self.backlog == 0:
            return
        base = float(max(self.busy_until, now))
        budget = self.budget
        served = []
        total = 0
        nfirst = 0
        for st in self.streams:
            if budget <= 0:
                break
            take = min(self.chunk, st[0].req.input_len - st[1], budget)
            if st[1] == 0:
                nfirst += 1
                st[0].prefill_start = base
            served.append((st, take))
            budget -= take
            total += take
        self.backlog -= total
        self.pending -= nfirst
        self.busy_until = base + (self.model.c * total + self.model.d * nfirst)
        self.busy_s += self.busy_until - base
        if self.trace is not None:
            self._iter_base = base
        self.inflight = served
        self.loop.at(self.busy_until, self._iteration_done, lane=LANE_PREFILL)

    def _iteration_done(self, now: float) -> None:
        served = self.inflight
        self.inflight = None
        self.iterations += 1
        # Token accounting + stream-list splice BEFORE callbacks (which can
        # synchronously requeue/submit back into this instance) — mirrors
        # ChunkPlane._iteration_done's phase order exactly.
        rotated = []
        live = []
        n_live = 0
        tr = self.trace
        base = self._iter_base
        for st, take in served:
            if st[2]:
                continue
            n_live += 1
            st[1] += take
            if tr is not None:
                tr.chunk(st[0], self.instance_id, base, now, take, st[1])
            live.append(st)
            if st[1] < st[0].req.input_len:
                rotated.append(st)
        self.streams = self.streams[n_live:] + rotated
        for st in live:
            if st[2]:
                continue
            rs = st[0]
            if self.on_chunk is not None:
                self.on_chunk(rs, st[1], now)
            if st[1] >= rs.req.input_len:
                rs.prefill_end = now
                if self.on_done is not None:
                    self.on_done(rs, now)
        self._maybe_start(now)


class DecodeSim:
    """Continuous-batching decode instance with per-instance heap events
    (retired; the production engine is ``InstancePlane``)."""

    def __init__(
        self,
        instance_id: int,
        server,
        iter_model: IterTimeModel,
        beta_max: int,
        kv_budget: float,
        kv_spec: ModelKVSpec,
        loop: EventLoop,
        view: Optional[ClusterView] = None,
    ):
        self.instance_id = instance_id
        self.server = server
        self.iter_model = iter_model
        self.beta_max = beta_max
        self.kv_budget = kv_budget
        self.kv_spec = kv_spec
        self.loop = loop
        self.cache = BlockCache(kv_budget, bytes_per_block=kv_spec.kv_bytes_per_token * B_TOK)
        self.active: dict = {}
        self.queue: deque = deque()
        self.pinned_bytes = 0.0
        self.healthy = True
        self.iter_scale = 1.0          # true slowdown factor (straggler)
        self.iter_scale_est = 1.0      # scheduler-visible EWMA estimate
        self._iterating = False
        self._iter_event = None
        self.iterations = 0
        self.busy_s = 0.0        # telemetry: cumulative iteration seconds
        self.on_first_token: Callable | None = None
        self.on_finish: Callable | None = None
        self.view = view
        self.slot = view.add_instance(
            instance_id, free_memory=kv_budget, healthy=True
        ) if view is not None else -1

    # ---- scheduler-visible state (§III-C) --------------------------------
    @property
    def beta(self) -> int:
        return len(self.active)

    @property
    def queued(self) -> int:
        return len(self.queue)

    @property
    def free_memory(self) -> float:
        # LRU cache is evictable => counts as free.  Clamped at zero: decode
        # KV growth can overcommit the budget, and a negative value would
        # reach the scheduler as phantom negative capacity.
        return max(self.kv_budget - self.pinned_bytes, 0.0)

    def hit_tokens(self, req) -> int:
        return self.cache.hit_tokens(req.block_hashes, req.input_len)

    def _sync(self) -> None:
        """Write scheduler-visible scalars through to the view column slot."""
        v = self.view
        if v is None:
            return
        s = self.slot
        v.free_memory[s] = max(self.kv_budget - self.pinned_bytes, 0.0)
        v.queued[s] = len(self.queue)
        v.batch[s] = len(self.active)
        v.iter_scale[s] = self.iter_scale_est

    def mark_detected(self, now: float = 0.0) -> None:
        """Fault detection fired: health becomes scheduler-visible."""
        if self.view is not None:
            self.view.healthy[self.slot] = self.healthy

    # ---- lifecycle ---------------------------------------------------------
    def reserve(self, rs, now: float) -> None:
        """Pin KV for an inbound transfer (memory committed at dispatch)."""
        self.pinned_bytes += rs.kv_bytes
        self.cache.evict_to(self.pinned_bytes)
        self._sync()

    def admit_enqueue(self, rs, now: float) -> None:
        """Transfer landed: blocks now resident; join the batch queue."""
        self.cache.insert(rs.req.block_hashes, protected=self.pinned_bytes)
        self.queue.append(rs)
        self._sync()

    def admit_kick(self, now: float) -> None:
        """Second admission phase: start/continue iterating."""
        self._maybe_iterate(now)
        self._sync()

    def admit_after_transfer(self, rs, now: float) -> None:
        """Single-instance convenience: enqueue + kick in one call."""
        self.admit_enqueue(rs, now)
        self.admit_kick(now)

    def release(self, rs) -> None:
        self.pinned_bytes = max(0.0, self.pinned_bytes - rs.kv_bytes)
        self._sync()

    def fail(self, now: float) -> list:
        """Hard failure: drop all state, return the victims for re-scheduling.

        Engine-side health flips immediately; the *scheduler-visible*
        ``healthy`` column only flips when ``mark_detected`` fires after the
        configured detection delay, so dispatches in the window bounce.
        """
        self.healthy = False
        victims = list(self.active.values()) + list(self.queue)
        self.active.clear()
        self.queue.clear()
        self.pinned_bytes = 0.0
        self.cache = BlockCache(self.kv_budget, self.cache.bytes_per_block)
        if self._iter_event is not None:
            self.loop.cancel(self._iter_event)
            self._iter_event = None
        self._iterating = False
        self._sync()
        return victims

    # ---- continuous batching ------------------------------------------------
    def _admit(self, now: float) -> None:
        while self.queue and len(self.active) < self.beta_max:
            rs = self.queue.popleft()
            rs.admit_time = now
            rs.tbt = self.iter_model(self.beta + 1) * self.iter_scale  # §VI-A: TBT at entry
            self.active[rs.req.request_id] = rs

    def _maybe_iterate(self, now: float) -> None:
        if self._iterating or not self.healthy:
            return
        if not self.active and not self.queue:
            return
        self._admit(now)
        if not self.active:
            return
        self._iterating = True
        self._sync()
        dur = self.iter_model(self.beta) * self.iter_scale
        self.busy_s += dur
        self._iter_event = self.loop.after(dur, self._iter_done,
                                           lane=LANE_CLOCK)

    def _iter_done(self, now: float) -> None:
        self._iterating = False
        self._iter_event = None
        if not self.healthy:
            return
        self.iterations += 1
        # EWMA straggler estimator the scheduler reads (beyond paper, §DESIGN 8).
        self.iter_scale_est += 0.2 * (self.iter_scale - self.iter_scale_est)
        finished: list = []
        for rs in self.active.values():
            rs.tokens_out += 1
            if rs.tokens_out == 1:
                rs.first_token = now
                if self.on_first_token:
                    self.on_first_token(rs, now)
            # Decode-side KV growth: one token per iteration.
            self.pinned_bytes += self.kv_spec.kv_bytes_per_token
            if rs.tokens_out >= rs.req.output_len:
                finished.append(rs)
        for rs in finished:
            del self.active[rs.req.request_id]
            rs.finish = now
            grown = rs.kv_bytes + rs.req.output_len * self.kv_spec.kv_bytes_per_token
            self.pinned_bytes = max(0.0, self.pinned_bytes - grown)
            if self.on_finish:
                self.on_finish(rs, now)
        self.cache.evict_to(self.pinned_bytes)
        self._maybe_iterate(now)
        self._sync()


class ReferenceInstanceEngine:
    """Engine-protocol adapter over the retired per-object sims.

    ``Simulation`` speaks one instance-engine protocol (pick_prefill /
    fill_hits / reserve / enqueue / kick / fail / ...); this adapter routes
    it to ``PrefillSim``/``DecodeSim`` objects so the parity tests can run
    the full simulator on either engine.
    """

    kind = "reference"

    def __init__(self, pre_meta, dec_meta, *, view: ClusterView, loop: EventLoop,
                 iter_model: IterTimeModel, prefill_model: PrefillTimeModel,
                 beta_max: int, kv_spec: ModelKVSpec, kv_budget: float,
                 chunk_tokens: int | None = None,
                 prefill_token_budget: int | None = None):
        self.view = view
        self.loop = loop
        self.iter_model = iter_model
        self.prefill_model = prefill_model
        self.beta_max = beta_max
        self.kv_spec = kv_spec
        self.kv_budget = kv_budget
        self.chunk_tokens = chunk_tokens
        self.prefill_token_budget = prefill_token_budget
        if chunk_tokens is not None:
            self.prefill = [
                ChunkedPrefillSim(m.instance_id, m.server, prefill_model,
                                  loop, chunk_tokens, prefill_token_budget)
                for m in pre_meta
            ]
        else:
            self.prefill = [
                PrefillSim(m.instance_id, m.server, prefill_model, loop)
                for m in pre_meta
            ]
        self._pre_by_id = {p.instance_id: p for p in self.prefill}
        self.decode = [
            DecodeSim(m.instance_id, m.server, iter_model, beta_max,
                      kv_budget, kv_spec, loop, view=view)
            for m in dec_meta
        ]
        self._by_id = {d.instance_id: d for d in self.decode}
        self._trace = None

    # ------------------------------------------------------------- callbacks
    @property
    def trace(self):
        return self._trace

    @trace.setter
    def trace(self, tr) -> None:
        """TracePlane sink — fanned out to the chunked prefill sims, which
        emit per-chunk spans (mirrors ``InstancePlane.trace`` wiring)."""
        self._trace = tr
        if self.chunk_tokens is not None:
            for p in self.prefill:
                p.trace = tr

    @property
    def on_prefill_done(self):
        return self.prefill[0].on_done if self.prefill else None

    @on_prefill_done.setter
    def on_prefill_done(self, fn) -> None:
        self._on_done_fn = fn     # stored: add_prefill copies it to new sims
        for p in self.prefill:
            p.on_done = fn

    @property
    def on_chunk_done(self):
        return self.prefill[0].on_chunk if self.prefill \
            and self.chunk_tokens is not None else None

    @on_chunk_done.setter
    def on_chunk_done(self, fn) -> None:
        self._on_chunk_fn = fn    # stored: add_prefill copies it to new sims
        for p in self.prefill:
            p.on_chunk = fn

    def set_decode_callbacks(self, on_first_token, on_finish) -> None:
        self._on_first_token = on_first_token
        self._on_finish = on_finish
        for d in self.decode:
            d.on_first_token = on_first_token
            d.on_finish = on_finish

    # --------------------------------------------------------------- prefill
    def pick_prefill(self, now: float):
        healthy = [p for p in self.prefill if p.healthy]
        if not healthy:
            return None
        return min(healthy, key=lambda p: p.eta(now))

    def cancel_prefill(self, rs) -> None:
        """Drop a request still prefilling (chunked fault-requeue path)."""
        if self.chunk_tokens is not None:
            self._pre_by_id[rs.prefill_instance].cancel(rs)

    def prefill_backlog(self, now: float) -> float:
        """RolePlane imbalance signal: min healthy drain ETA minus ``now``
        (mirrors ``InstancePlane.prefill_backlog`` bit-for-bit)."""
        etas = [p.eta(now) for p in self.prefill if p.healthy]
        if not etas:
            return float("inf")
        return min(etas) - now

    def add_prefill(self, iid: int, server):
        """Elastic prefill membership (RolePlane flips, ``add_prefill``
        fault kind).  New sims inherit the current chunk/budget settings
        and the engine-level callbacks, like ``add_decode`` does."""
        if self.chunk_tokens is not None:
            tmpl = self.prefill[0] if self.prefill else None
            p = ChunkedPrefillSim(
                iid, server, self.prefill_model, self.loop,
                tmpl.chunk if tmpl else self.chunk_tokens,
                tmpl.budget if tmpl else self.prefill_token_budget)
            p.on_chunk = getattr(self, "_on_chunk_fn", None)
            p.trace = self._trace
        else:
            p = PrefillSim(iid, server, self.prefill_model, self.loop)
        p.on_done = getattr(self, "_on_done_fn", None)
        self.prefill.append(p)
        self._pre_by_id[iid] = p
        return p

    def fail_prefill(self, iid: int, now: float) -> list:
        """Hard prefill failure (``kill_prefill``): drop queued/in-flight
        work and return the victims — running/stream order, then queue."""
        p = self._pre_by_id[iid]
        p.healthy = False
        victims: list = []
        if self.chunk_tokens is not None:
            for st in list(p.streams):
                victims.append(st[0])
                p.cancel(st[0])
            return victims
        if p.running is not None:
            victims.append(p.running)
            p.running = None
        victims.extend(p.queue)
        p.queue.clear()
        return victims

    def prefill_drained(self, iid: int) -> bool:
        p = self._pre_by_id[iid]
        if self.chunk_tokens is not None:
            return not p.streams and p.inflight is None
        return p.running is None and not p.queue

    def decode_drained(self, iid: int) -> bool:
        d = self._by_id[iid]
        return d.healthy and not d.active and not d.queue

    def flip_role(self, iid: int, role: int, now: float) -> None:
        """Planned role transition — per-object mirror of
        ``InstancePlane.flip_role`` (drain is the caller's job).  A
        decode->prefill flip swaps in a fresh BlockCache: the prefix cache
        hands off (contents and counters), matching RadixPlane's
        ``reset_instance``."""
        d = self._by_id[iid]
        if role == ROLE_PREFILL:
            self.view.role[d.slot] = ROLE_PREFILL
            d.cache = BlockCache(d.kv_budget, d.cache.bytes_per_block)
            d._sync()
            p = self._pre_by_id.get(iid)
            if p is not None:
                p.healthy = True
            else:
                self.add_prefill(iid, d.server)
        elif role == ROLE_DECODE:
            self._pre_by_id[iid].healthy = False
            self.view.role[d.slot] = ROLE_DECODE
            d._sync()
        else:
            raise ValueError(f"unknown role {role!r}")

    def set_chunking(self, chunk_tokens: int, token_budget: int) -> None:
        """Retune chunk size / token budget (auto-tuner; mirrors
        ``InstancePlane.set_chunking``)."""
        if self.chunk_tokens is None:
            raise ValueError("set_chunking requires chunked prefill")
        if int(chunk_tokens) <= 0 or int(token_budget) <= 0:
            raise ValueError("chunk_tokens / token_budget must be positive")
        for p in self.prefill:
            p.chunk = int(chunk_tokens)
            p.budget = int(token_budget)

    # ---------------------------------------------------------------- decode
    def decode_by_id(self, iid: int) -> DecodeSim:
        return self._by_id[iid]

    def is_healthy(self, iid: int) -> bool:
        return self._by_id[iid].healthy

    def fill_hits(self, req) -> None:
        """Refresh the per-request hit_tokens scratch column in-place."""
        hits = self.view.hit_tokens
        for d in self.decode:
            hits[d.slot] = float(d.hit_tokens(req))

    def hit_tokens(self, iid: int, req) -> float:
        return float(self._by_id[iid].hit_tokens(req))

    def hit_rows(self, reqs):
        """(R, D) hit-token matrix for a dispatch cohort (protocol totality
        with InstancePlane.hit_rows; per-object walks, no bitmask)."""
        import numpy as np

        H = np.zeros((len(reqs), len(self.decode)), np.float64)
        for k, req in enumerate(reqs):
            for d in self.decode:
                H[k, d.slot] = float(d.hit_tokens(req))
        return H

    def evictions_of(self, iid: int) -> int:
        return int(self._by_id[iid].cache.evictions)

    def reserve(self, iid: int, rs, now: float) -> None:
        self._by_id[iid].reserve(rs, now)

    def release(self, iid: int, rs) -> None:
        self._by_id[iid].release(rs)

    def enqueue(self, iid: int, rs, now: float) -> None:
        self._by_id[iid].admit_enqueue(rs, now)

    def kick(self, iids, now: float) -> None:
        for iid in iids:
            self._by_id[iid].admit_kick(now)

    def fail(self, iid: int, now: float) -> list:
        return self._by_id[iid].fail(now)

    def mark_detected(self, iid: int, now: float) -> None:
        self._by_id[iid].mark_detected(now)

    def set_iter_scale(self, iid: int, factor: float) -> None:
        self._by_id[iid].iter_scale = factor

    def add_decode(self, iid: int, server, kv_budget: float | None = None) -> DecodeSim:
        d = DecodeSim(iid, server, self.iter_model, self.beta_max,
                      self.kv_budget if kv_budget is None else kv_budget,
                      self.kv_spec, self.loop, view=self.view)
        d.on_first_token = getattr(self, "_on_first_token", None)
        d.on_finish = getattr(self, "_on_finish", None)
        self.decode.append(d)
        self._by_id[iid] = d
        return d

    def finalize(self) -> None:
        """Per-object engine mutates RequestState in place — nothing to flush."""

    # ------------------------------------------------------------ telemetry
    @property
    def total_iterations(self) -> int:
        return sum(d.iterations for d in self.decode)

    @property
    def prefill_busy_s(self) -> float:
        return sum(p.busy_s for p in self.prefill)

    @property
    def decode_busy_s(self) -> float:
        return sum(d.busy_s for d in self.decode)

    @property
    def deflect_busy_s(self) -> float:
        return 0.0   # deflection is plane-engine-only

    def cache_stats(self) -> list[dict]:
        """Per-instance cache counters for the parity tests."""
        return [
            dict(instance_id=d.instance_id, hits=d.cache.hits,
                 misses=d.cache.misses, evictions=d.cache.evictions,
                 bytes_used=d.cache.bytes_used)
            for d in self.decode
        ]
