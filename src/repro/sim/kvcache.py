"""RadixPlane: array-backed multi-instance prefix KV cache (§III-B).

Block size B_tok = 16 tokens.  A request's content is a sequence of block
hashes; the cache hit length lambda_r(d) is B_tok times the longest common
*block-aligned prefix* between the request and instance d's cache contents —
a hit requires every earlier block to also be present (LCP semantics, not
set membership).

The retired per-instance ``BlockCache`` (an ``OrderedDict`` LRU, kept
verbatim in ``sim/reference.py`` and re-exported here) answered
``hit_tokens`` with one Python dict walk *per candidate per scheduling
decision* — the O(|D| * blocks) loop the scheduler hot path at 1000-GPU
scale is made of.  ``RadixPlane`` keeps every decode instance's cache in one
shared columnar structure:

* **Interned block ids** — each distinct block hash is interned once into a
  dense id; presence is a packed uint64 bitmask row per block over instance
  slots (``present[block_id, word]``), so membership of one request's m
  blocks against all D instances is a single fancy-index + shift broadcast.
* **Broadcast LCP** — ``hit_row`` computes lambda_r(d) for *all* instances
  at once: chunked leading-ones count over the (m, D) membership matrix,
  with instances eliminated from later chunks the moment they miss (the
  vector analogue of the per-instance early-exit walk).
* **Array LRU clocks** — each instance's recency order is an append-only
  int64 log of block ids with lazy invalidation: insert/touch append (and
  invalidate the block's previous log entry), eviction pops from the head
  skipping invalidated entries.  This reproduces the ``OrderedDict``
  ``move_to_end`` / ``popitem(last=False)`` order exactly
  (``tests/test_radixplane.py`` proves it on random hash streams).

All counters (hits/misses/evictions) and byte accounting match the retired
``BlockCache`` bit-for-bit; ``reset_instance`` mirrors the reference's
cache replacement on instance failure (counters reset too).
"""

from __future__ import annotations

from typing import Hashable, Sequence

import numpy as np

from repro.core.cost import B_TOK, n_blocks
from .reference import BlockCache  # retired single-instance LRU (parity oracle)

__all__ = ["B_TOK", "BlockCache", "RadixPlane", "n_blocks"]

_ONE = np.uint64(1)


class RadixPlane:
    """Columnar LRU prefix cache over every decode instance's HBM budget."""

    def __init__(self, bytes_per_block: float, *, block_capacity: int = 1024,
                 instance_capacity: int = 16):
        self.bytes_per_block = float(bytes_per_block)
        self.n = 0                                  # registered instances
        self._intern: dict[Hashable, int] = {}      # block hash -> dense id
        self._hash_of: list[Hashable] = []          # dense id -> block hash
        self._free_bids: list[int] = []             # recycled dense ids
        self._bcap = max(int(block_capacity), 64)
        self._icap = max(int(instance_capacity), 1)
        self._W = (self._icap + 63) // 64
        self.present = np.zeros((self._bcap, self._W), np.uint64)
        # Per-slot word/bit coordinates for the broadcast membership gather.
        self._word = np.arange(self._icap, dtype=np.intp) // 64
        self._bit = (np.arange(self._icap, dtype=np.uint64) % np.uint64(64))
        # How many instances currently hold each block: when it drops to
        # zero the dense id (and its presence row) is recycled, so memory
        # tracks *resident* distinct blocks, not blocks ever seen — the
        # same boundedness the per-instance BlockCache had.
        self._refcnt = np.zeros(self._bcap, np.int64)
        # Per-instance scalar columns.
        self.budget = np.zeros(self._icap, np.float64)
        self.count = np.zeros(self._icap, np.int64)     # resident blocks
        self.hits = np.zeros(self._icap, np.int64)
        self.misses = np.zeros(self._icap, np.int64)
        self.evictions = np.zeros(self._icap, np.int64)
        # Per-instance LRU clock: append-only log of block ids (-1 = stale
        # entry, lazily skipped), head cursor, block id -> log index.  The
        # log is a plain int list: appends/invalidations are O(1) C-level
        # ops on the per-admit path, compacted when stale entries dominate.
        self._log: list[list[int]] = []
        self._head: list[int] = []
        self._pos: list[dict[int, int]] = []

    # ------------------------------------------------------------ membership
    def add_instance(self, budget_bytes: float) -> int:
        """Register one decode instance; returns its (stable) slot."""
        if self.n == self._icap:
            self._grow_instances()
        s = self.n
        self.n += 1
        self.budget[s] = float(budget_bytes)
        self._log.append([])
        self._head.append(0)
        self._pos.append({})
        return s

    def _grow_instances(self) -> None:
        icap = self._icap * 2
        for name in ("budget", "count", "hits", "misses", "evictions"):
            old = getattr(self, name)
            new = np.zeros(icap, old.dtype)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)
        W = (icap + 63) // 64
        if W > self._W:
            present = np.zeros((self._bcap, W), np.uint64)
            present[:, : self._W] = self.present
            self.present = present
            self._W = W
        self._icap = icap
        self._word = np.arange(icap, dtype=np.intp) // 64
        self._bit = (np.arange(icap, dtype=np.uint64) % np.uint64(64))

    def _grow_blocks(self) -> None:
        bcap = self._bcap * 2
        present = np.zeros((bcap, self._W), np.uint64)
        present[: self._bcap] = self.present
        self.present = present
        refcnt = np.zeros(bcap, np.int64)
        refcnt[: self._bcap] = self._refcnt
        self._refcnt = refcnt
        self._bcap = bcap

    def _block_id(self, h: Hashable) -> int:
        bid = self._intern.get(h)
        if bid is None:
            if self._free_bids:
                bid = self._free_bids.pop()
                self._hash_of[bid] = h
            else:
                bid = len(self._hash_of)
                if bid == self._bcap:
                    self._grow_blocks()
                self._hash_of.append(h)
            self._intern[h] = bid
        return bid

    def _release_bid(self, bid: int) -> None:
        """Last holder evicted the block: recycle its dense id."""
        del self._intern[self._hash_of[bid]]
        self._hash_of[bid] = None
        self._free_bids.append(bid)

    # --------------------------------------------------------------- LRU log
    def _maybe_compact(self, s: int) -> None:
        """Rewrite the log when stale (invalidated) entries dominate."""
        log = self._log[s]
        if len(log) > 64 and len(log) > 4 * len(self._pos[s]):
            live = [b for b in log[self._head[s]:] if b >= 0]
            self._log[s] = live
            self._head[s] = 0
            pos = self._pos[s]
            for j, b in enumerate(live):
                pos[b] = j

    def _evict_one(self, s: int) -> None:
        log, head = self._log[s], self._head[s]
        while log[head] < 0:
            head += 1
        bid = log[head]
        log[head] = -1
        self._head[s] = head + 1
        del self._pos[s][bid]
        self.present[bid, s >> 6] &= ~(_ONE << self._bit[s])
        self._refcnt[bid] -= 1
        if self._refcnt[bid] == 0:
            self._release_bid(bid)
        self.count[s] -= 1
        self.evictions[s] += 1

    def _evict_to_limit(self, s: int, limit: float) -> None:
        # Same float comparison sequence as the reference's
        # ``while bytes_used > limit`` loop.
        bpb = self.bytes_per_block
        n = int(self.count[s])
        while n > 0 and n * bpb > limit:
            self._evict_one(s)
            n -= 1

    # ------------------------------------------------------------------- API
    def bytes_used(self, s: int) -> float:
        return float(self.count[s]) * self.bytes_per_block

    def contains(self, s: int, h: Hashable) -> bool:
        bid = self._intern.get(h)
        return bid is not None and bid in self._pos[s]

    def lcp_blocks(self, s: int, hashes: Sequence[Hashable]) -> int:
        """|LCP_block(h_r, K_s)| for a single instance (scalar walk)."""
        pos = self._pos[s]
        intern = self._intern
        n = 0
        for h in hashes:
            bid = intern.get(h)
            if bid is None or bid not in pos:
                break
            n += 1
        return n

    def hit_tokens(self, s: int, hashes: Sequence[Hashable], input_len: int) -> int:
        """lambda_r(s) = B_tok * LCP, clamped to the true input length."""
        return min(self.lcp_blocks(s, hashes) * B_TOK, input_len)

    def hit_row(self, hashes: Sequence[Hashable], input_len: int,
                out: np.ndarray | None = None) -> np.ndarray:
        """lambda_r(d) for one request against ALL instances — one broadcast.

        Chunked leading-ones count over the packed presence bitmask:
        instances drop out of later chunks as soon as they miss, so total
        work tracks the reference's early-exit walks, vectorised over D.
        """
        n = self.n
        res = out if out is not None else np.zeros(n, np.float64)
        # A hash never inserted anywhere is absent from every cache, so the
        # LCP of every instance is capped at the first unknown block.
        ids: list[int] = []
        intern = self._intern
        for h in hashes:
            bid = intern.get(h)
            if bid is None:
                break
            ids.append(bid)
        if not ids or n == 0:
            res[:n] = 0.0
            return res
        lcp = self._lcp_row(np.asarray(ids, np.intp))
        np.minimum(lcp * B_TOK, float(input_len), out=res[:n])
        return res

    def _lcp_row(self, idv: np.ndarray) -> np.ndarray:
        """(n,) leading-ones LCP block count for one interned-id prefix."""
        n = self.n
        lcp = np.zeros(n, np.int64)
        alive = np.arange(n, dtype=np.intp)
        word, bit = self._word, self._bit
        for c in range(0, len(idv), 64):
            sub = self.present[idv[c:c + 64]]                  # (ch, W)
            m = (sub[:, word[alive]] >> bit[alive]) & _ONE     # (ch, |alive|)
            bad = m == 0
            anybad = bad.any(axis=0)
            lcp[alive] += np.where(anybad, bad.argmax(axis=0), sub.shape[0])
            alive = alive[~anybad]
            if alive.size == 0:
                break
        return lcp

    def hit_rows(self, reqs: Sequence) -> np.ndarray:
        """Stacked ``hit_row`` for a dispatch cohort: the (R, n) lambda matrix.

        Shared prefixes are the common case inside a same-timestamp cohort
        (agentic trees, RAG fan-out), so identical interned-id prefixes reuse
        one broadcast LCP through a tuple-keyed memo.  Row k is bit-identical
        to ``hit_row(reqs[k].block_hashes, reqs[k].input_len)`` against the
        cache state at call time.
        """
        n = self.n
        H = np.zeros((len(reqs), n), np.float64)
        intern = self._intern
        memo: dict[tuple, np.ndarray] = {}
        for k, req in enumerate(reqs):
            ids: list[int] = []
            for h in req.block_hashes:
                bid = intern.get(h)
                if bid is None:
                    break
                ids.append(bid)
            if not ids or n == 0:
                continue
            key = tuple(ids)
            lcp = memo.get(key)
            if lcp is None:
                memo[key] = lcp = self._lcp_row(np.asarray(ids, np.intp))
            np.minimum(lcp * B_TOK, float(req.input_len), out=H[k])
        return H

    def touch(self, s: int, hashes: Sequence[Hashable]) -> None:
        """Mark blocks as recently used (move to MRU end of the clock log)."""
        pos = self._pos[s]
        log = self._log[s]
        intern = self._intern
        hit = miss = 0
        for h in hashes:
            bid = intern.get(h)
            j = pos.get(bid) if bid is not None else None
            if j is not None:
                log[j] = -1
                pos[bid] = len(log)
                log.append(bid)
                hit += 1
            else:
                miss += 1
        self.hits[s] += hit
        self.misses[s] += miss
        self._maybe_compact(s)

    def insert(self, s: int, hashes: Sequence[Hashable],
               protected: float = 0.0) -> None:
        """Insert blocks at MRU, evicting LRU entries beyond budget.

        ``protected`` bytes are pinned elsewhere (active batches) and shrink
        the evictable budget.
        """
        pos = self._pos[s]
        log = self._log[s]
        block_id = self._block_id
        fresh: list[int] = []
        for h in hashes:
            bid = block_id(h)
            j = pos.get(bid)
            if j is not None:
                log[j] = -1
            else:
                fresh.append(bid)
            pos[bid] = len(log)
            log.append(bid)
        if fresh:
            # One fancy-indexed OR for every newly-present block.
            idx = np.asarray(fresh, np.intp)
            self.present[idx, s >> 6] |= _ONE << self._bit[s]
            self._refcnt[idx] += 1
            self.count[s] += len(fresh)
        self._maybe_compact(s)
        self._evict_to_limit(s, max(float(self.budget[s]) - protected, 0.0))

    def evict_to(self, s: int, protected: float) -> None:
        self._evict_to_limit(s, max(float(self.budget[s]) - protected, 0.0))

    def evict_cohort(self, slots: np.ndarray, protected: np.ndarray) -> None:
        """``evict_to`` across a cohort: one vector over-budget test, the
        per-block eviction loop only runs where growth overran the budget."""
        limits = np.maximum(self.budget[slots] - protected, 0.0)
        over = (self.count[slots] * self.bytes_per_block > limits).nonzero()[0]
        for j in over:
            self._evict_to_limit(int(slots[j]), float(limits[j]))

    def reset_instance(self, s: int) -> None:
        """Instance failure: drop contents AND counters (the reference swaps
        in a brand-new BlockCache, so hits/misses/evictions restart at 0)."""
        pos = self._pos[s]
        if pos:
            idx = np.fromiter(pos, np.intp, len(pos))
            self.present[idx, s >> 6] &= ~(_ONE << self._bit[s])
            self._refcnt[idx] -= 1
            for bid in idx[self._refcnt[idx] == 0].tolist():
                self._release_bid(bid)
        self._pos[s] = {}
        self._log[s] = []
        self._head[s] = 0
        self.count[s] = 0
        self.hits[s] = 0
        self.misses[s] = 0
        self.evictions[s] = 0
