"""Block-level prefix KV cache with LRU eviction (§III-B).

Block size B_tok = 16 tokens.  A request's content is a sequence of block
hashes; the cache hit length lambda_r(d) is B_tok times the longest common
*block-aligned prefix* between the request and the cache contents — a hit
requires every earlier block to also be present (LCP semantics, not set
membership).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Sequence

from repro.core.cost import B_TOK, n_blocks


class BlockCache:
    """LRU over block hashes, budgeted in bytes."""

    def __init__(self, budget_bytes: float, bytes_per_block: float):
        self.budget = budget_bytes
        self.bytes_per_block = bytes_per_block
        self._lru: OrderedDict[Hashable, None] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def bytes_used(self) -> float:
        return len(self._lru) * self.bytes_per_block

    def __contains__(self, h: Hashable) -> bool:
        return h in self._lru

    def lcp_blocks(self, hashes: Sequence[Hashable]) -> int:
        """|LCP_block(h_r, K_d)|: leading blocks all present in the cache."""
        n = 0
        for h in hashes:
            if h in self._lru:
                n += 1
            else:
                break
        return n

    def hit_tokens(self, hashes: Sequence[Hashable], input_len: int) -> int:
        """lambda_r(d) = B_tok * LCP, clamped to the true input length."""
        return min(self.lcp_blocks(hashes) * B_TOK, input_len)

    def touch(self, hashes: Sequence[Hashable]) -> None:
        """Mark blocks as recently used (move to MRU end)."""
        for h in hashes:
            if h in self._lru:
                self._lru.move_to_end(h)
                self.hits += 1
            else:
                self.misses += 1

    def insert(self, hashes: Sequence[Hashable], protected: float = 0.0) -> None:
        """Insert blocks, evicting LRU entries beyond budget.

        ``protected`` bytes are pinned elsewhere (active batches) and shrink
        the evictable budget.
        """
        for h in hashes:
            self._lru[h] = None
            self._lru.move_to_end(h)
        limit = max(self.budget - protected, 0.0)
        while self.bytes_used > limit and self._lru:
            self._lru.popitem(last=False)
            self.evictions += 1

    def evict_to(self, protected: float) -> None:
        limit = max(self.budget - protected, 0.0)
        while self.bytes_used > limit and self._lru:
            self._lru.popitem(last=False)
            self.evictions += 1
