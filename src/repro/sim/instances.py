"""Prefill / decode instance models with continuous batching (§III-C, §VI-B).

PrefillSim: serial compute queue, T_prefill(l) = c*l + d.  The prefill-side
KV buffer is held until the transfer-complete callback (vLLM KVConnector
semantics), so a decode-instance failure during transfer can re-schedule
without re-running prefill.

DecodeSim: continuous batching at iteration boundaries (Orca-style): a
request arriving mid-iteration waits for the current step to finish before
joining the active batch; each iteration every active request emits one
token.  Memory: aggregate KV budget; active (pinned) KV plus an LRU block
cache of completed prefixes (evictable, so it counts as free to the
scheduler, matching vLLM block-manager semantics).

Scheduler-visible state lives in a shared ``ClusterView`` column plane:
every DecodeSim mutation writes its (free_memory, queued, batch,
iter_scale_est) scalars through to its column slot, so scheduling events
read current cluster state with zero per-request rebuilding.  The one
column a DecodeSim never writes is ``healthy`` — health becomes
scheduler-visible only via ``mark_detected`` after the fault detection
delay (see Simulation._on_fault).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

from repro.core.cost import IterTimeModel, ModelKVSpec, PrefillTimeModel
from repro.core.view import ClusterView
from repro.traces.mooncake import Request
from .engine import EventLoop
from .kvcache import B_TOK, BlockCache


@dataclasses.dataclass
class RequestState:
    req: Request
    kv_bytes: float
    prefill_instance: int = -1
    prefill_start: float = -1.0
    prefill_end: float = -1.0
    sched_time: float = -1.0
    decode_instance: int = -1
    tier: int = -1
    s_eff: float = 0.0
    hit_tokens: float = 0.0
    transfer_end: float = -1.0
    admit_time: float = -1.0
    first_token: float = -1.0
    finish: float = -1.0
    tbt: float = -1.0
    tokens_out: int = 0
    rejected: bool = False
    requeues: int = 0  # fault-tolerance: times re-scheduled after a failure

    @property
    def ttft(self) -> float:
        return self.first_token - self.req.arrival if self.first_token >= 0 else float("inf")


class PrefillSim:
    def __init__(self, instance_id: int, server, prefill_model: PrefillTimeModel,
                 loop: EventLoop):
        self.instance_id = instance_id
        self.server = server
        self.model = prefill_model
        self.loop = loop
        self.busy_until = 0.0
        self.queue: deque[RequestState] = deque()
        self.running: Optional[RequestState] = None
        self.on_done: Callable[[RequestState, float], None] | None = None
        self.healthy = True

    def submit(self, rs: RequestState, now: float) -> None:
        rs.prefill_instance = self.instance_id
        self.queue.append(rs)
        self._maybe_start(now)

    def eta(self, now: float) -> float:
        """Earliest time a new request would *finish* prefill here."""
        t = max(self.busy_until, now)
        for rs in self.queue:
            t += self.model(rs.req.input_len)
        return t

    def _maybe_start(self, now: float) -> None:
        if self.running is not None or not self.queue or not self.healthy:
            return
        rs = self.queue.popleft()
        self.running = rs
        rs.prefill_start = max(now, self.busy_until)
        dur = self.model(rs.req.input_len)
        self.busy_until = rs.prefill_start + dur
        self.loop.at(self.busy_until, self._finish)

    def _finish(self, now: float) -> None:
        rs = self.running
        if rs is None:
            return
        rs.prefill_end = now
        self.running = None
        if self.on_done is not None:
            self.on_done(rs, now)
        self._maybe_start(now)


class DecodeSim:
    def __init__(
        self,
        instance_id: int,
        server,
        iter_model: IterTimeModel,
        beta_max: int,
        kv_budget: float,
        kv_spec: ModelKVSpec,
        loop: EventLoop,
        view: Optional[ClusterView] = None,
    ):
        self.instance_id = instance_id
        self.server = server
        self.iter_model = iter_model
        self.beta_max = beta_max
        self.kv_budget = kv_budget
        self.kv_spec = kv_spec
        self.loop = loop
        self.cache = BlockCache(kv_budget, bytes_per_block=kv_spec.kv_bytes_per_token * B_TOK)
        self.active: dict[int, RequestState] = {}
        self.queue: deque[RequestState] = deque()
        self.pinned_bytes = 0.0
        self.healthy = True
        self.iter_scale = 1.0          # true slowdown factor (straggler)
        self.iter_scale_est = 1.0      # scheduler-visible EWMA estimate
        self._iterating = False
        self._iter_event = None
        self.iterations = 0
        self.on_first_token: Callable[[RequestState, float], None] | None = None
        self.on_finish: Callable[[RequestState, float], None] | None = None
        self.view = view
        self.slot = view.add_instance(
            instance_id, free_memory=kv_budget, healthy=True
        ) if view is not None else -1

    # ---- scheduler-visible state (§III-C) --------------------------------
    @property
    def beta(self) -> int:
        return len(self.active)

    @property
    def queued(self) -> int:
        return len(self.queue)

    @property
    def free_memory(self) -> float:
        # LRU cache is evictable => counts as free.
        return self.kv_budget - self.pinned_bytes

    def hit_tokens(self, req: Request) -> int:
        return self.cache.hit_tokens(req.block_hashes, req.input_len)

    def _sync(self) -> None:
        """Write scheduler-visible scalars through to the view column slot."""
        v = self.view
        if v is None:
            return
        s = self.slot
        v.free_memory[s] = self.kv_budget - self.pinned_bytes
        v.queued[s] = len(self.queue)
        v.batch[s] = len(self.active)
        v.iter_scale[s] = self.iter_scale_est

    def mark_detected(self, now: float = 0.0) -> None:
        """Fault detection fired: health becomes scheduler-visible."""
        if self.view is not None:
            self.view.healthy[self.slot] = self.healthy

    # ---- lifecycle ---------------------------------------------------------
    def reserve(self, rs: RequestState, now: float) -> None:
        """Pin KV for an inbound transfer (memory committed at dispatch)."""
        self.pinned_bytes += rs.kv_bytes
        self.cache.evict_to(self.pinned_bytes)
        self._sync()

    def admit_after_transfer(self, rs: RequestState, now: float) -> None:
        """Transfer landed: blocks now resident; join the batch queue."""
        self.cache.insert(rs.req.block_hashes, protected=self.pinned_bytes)
        self.queue.append(rs)
        self._maybe_iterate(now)
        self._sync()

    def release(self, rs: RequestState) -> None:
        self.pinned_bytes = max(0.0, self.pinned_bytes - rs.kv_bytes)
        self._sync()

    def fail(self, now: float) -> list[RequestState]:
        """Hard failure: drop all state, return the victims for re-scheduling.

        Engine-side health flips immediately; the *scheduler-visible*
        ``healthy`` column only flips when ``mark_detected`` fires after the
        configured detection delay, so dispatches in the window bounce.
        """
        self.healthy = False
        victims = list(self.active.values()) + list(self.queue)
        self.active.clear()
        self.queue.clear()
        self.pinned_bytes = 0.0
        self.cache = BlockCache(self.kv_budget, self.cache.bytes_per_block)
        if self._iter_event is not None:
            self.loop.cancel(self._iter_event)
            self._iter_event = None
        self._iterating = False
        self._sync()
        return victims

    # ---- continuous batching ------------------------------------------------
    def _admit(self, now: float) -> None:
        while self.queue and len(self.active) < self.beta_max:
            rs = self.queue.popleft()
            rs.admit_time = now
            rs.tbt = self.iter_model(self.beta + 1) * self.iter_scale  # §VI-A: TBT at entry
            self.active[rs.req.request_id] = rs

    def _maybe_iterate(self, now: float) -> None:
        if self._iterating or not self.healthy:
            return
        if not self.active and not self.queue:
            return
        self._admit(now)
        if not self.active:
            return
        self._iterating = True
        self._sync()
        dur = self.iter_model(self.beta) * self.iter_scale
        self._iter_event = self.loop.after(dur, self._iter_done)

    def _iter_done(self, now: float) -> None:
        self._iterating = False
        self._iter_event = None
        if not self.healthy:
            return
        self.iterations += 1
        # EWMA straggler estimator the scheduler reads (beyond paper, §DESIGN 8).
        self.iter_scale_est += 0.2 * (self.iter_scale - self.iter_scale_est)
        finished: list[RequestState] = []
        for rs in self.active.values():
            rs.tokens_out += 1
            if rs.tokens_out == 1:
                rs.first_token = now
                if self.on_first_token:
                    self.on_first_token(rs, now)
            # Decode-side KV growth: one token per iteration.
            self.pinned_bytes += self.kv_spec.kv_bytes_per_token
            if rs.tokens_out >= rs.req.output_len:
                finished.append(rs)
        for rs in finished:
            del self.active[rs.req.request_id]
            rs.finish = now
            grown = rs.kv_bytes + rs.req.output_len * self.kv_spec.kv_bytes_per_token
            self.pinned_bytes = max(0.0, self.pinned_bytes - grown)
            if self.on_finish:
                self.on_finish(rs, now)
        self.cache.evict_to(self.pinned_bytes)
        self._maybe_iterate(now)
        self._sync()
