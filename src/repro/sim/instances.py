"""InstancePlane: columnar prefill/decode lifecycle engine (§III-C, §VI-B).

The retired per-object engine (kept verbatim in ``sim/reference.py``) posts
one heap event per decode instance per continuous-batching iteration and
walks a Python dict of ``RequestState`` per token — at 1000-instance scale
that bookkeeping *is* the simulator hot path.  ``InstancePlane`` replaces it
with struct-of-arrays state and a single **cohort-stepped iteration clock**:

* **Instance columns** (slot-indexed, aligned with ``ClusterView`` slots):
  active count, queue length, pinned KV bytes, per-instance budget, true and
  EWMA-estimated straggler scale, iteration count, and the *next-iteration
  deadline* (``+inf`` when idle).  One event-loop timer fires at the minimum
  deadline and steps the whole cohort of instances due at that instant —
  replacing D per-instance ``_iter_done`` events with one.
* **Request table**: active decoding requests live in parallel columns
  (tokens_out / output_len / instance slot / admission seq / object ref), so
  per-iteration token accounting, first-token detection, finish detection
  and decode-side KV growth are fused array ops over the cohort's rows.
* **Prefill columns**: serial prefill queues keep per-instance
  ``busy_until`` and an *exact left-fold* ETA column, so arrival routing
  (min-ETA healthy instance) is one masked argmin instead of a Python scan
  that re-sums every queue.
* **ChunkPlane** (``chunk_tokens`` set): the serial queues are replaced by
  a chunk-interleaved continuous-batching prefill model — requests split
  into fixed-token chunks, per-instance chunk queues round-robin
  interleaved under a token budget per prefill iteration, per-chunk
  admission callbacks (``on_chunk_done``) as each chunk's KV becomes
  ready.  ``chunk_tokens=None`` (default) keeps the serial columns
  untouched and bit-exact vs ``sim/reference.py``.
* **RadixPlane cache**: per-instance prefix caches share one packed
  presence bitmask, so lambda_r(d) against all D instances is a single
  broadcast LCP (``fill_hits``).
* **Write-through**: scheduler-visible scalars sync to the ``ClusterView``
  columns in one vectorised assignment per event (the one column never
  written here is ``healthy`` — that flips only via ``mark_detected`` after
  the fault-detection delay; see Simulation._on_fault).

Semantics are bit-identical to the reference engine — same TTFT/TBT/finish
times, same cache-hit tokens, same RNG stream consumption downstream —
enforced by ``tests/test_instanceplane_parity.py`` on seeded 64/256-GPU
runs.  Within one clock tick the cohort's instances are processed in slot
order; the reference interleaves per-instance events by heap sequence, but
same-timestamp instance steps are independent (per-instance accumulators,
per-request fields), so outcomes agree exactly.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.cost import (
    B_TOK,
    IterTimeModel,
    ModelKVSpec,
    PrefillTimeModel,
    iter_time_vector,
)
from repro.core.view import ROLE_DECODE, ROLE_PREFILL, ClusterView
from repro.traces.mooncake import Request
from .engine import LANE_CLOCK, LANE_PREFILL, EventLoop
from .kvcache import RadixPlane


@dataclasses.dataclass
class RequestState:
    req: Request
    kv_bytes: float
    prefill_instance: int = -1
    prefill_start: float = -1.0
    prefill_end: float = -1.0
    sched_time: float = -1.0
    decode_instance: int = -1
    tier: int = -1
    s_eff: float = 0.0
    hit_tokens: float = 0.0
    transfer_end: float = -1.0
    admit_time: float = -1.0
    first_token: float = -1.0
    finish: float = -1.0
    tbt: float = -1.0
    tokens_out: int = 0
    rejected: bool = False
    requeues: int = 0  # fault-tolerance: times re-scheduled after a failure
    deflected: bool = False  # RolePlane: prefilled on the decode host itself
    # ---- chunked-prefill / streamed-transfer bookkeeping (ChunkPlane) ----
    tokens_ready: int = 0        # prefilled tokens whose KV exists (chunked)
    streamed_bytes: float = 0.0  # bytes handed to the network so far
    stream_open: int = 0         # in-flight streamed chunk transfers
    stream_scheduled: bool = False  # decode instance chosen at first chunk
    stream_last: bool = False    # final chunk's bytes are in the network

    @property
    def ttft(self) -> float:
        return self.first_token - self.req.arrival if self.first_token >= 0 else float("inf")


class PrefillHandle:
    """Per-instance window into the prefill columns (test/driver surface)."""

    __slots__ = ("_p", "s")

    def __init__(self, plane: "InstancePlane", s: int):
        self._p = plane
        self.s = s

    @property
    def instance_id(self) -> int:
        return int(self._p.p_ids[self.s])

    @property
    def server(self):
        return self._p.p_server[self.s]

    @property
    def healthy(self) -> bool:
        return bool(self._p.p_healthy[self.s])

    @healthy.setter
    def healthy(self, v: bool) -> None:
        self._p.p_healthy[self.s] = bool(v)

    @property
    def busy_until(self) -> float:
        p = self._p
        if p.chunks is not None:
            return float(p.chunks.busy[self.s])
        return float(p.p_busy[self.s])

    @property
    def queued(self) -> int:
        p = self._p
        if p.chunks is not None:
            return len(p.chunks.streams[self.s])
        return int(p.p_qlen[self.s])

    def submit(self, rs: RequestState, now: float) -> None:
        self._p.submit_prefill(self.s, rs, now)

    def eta(self, now: float) -> float:
        p = self._p
        if p.chunks is not None:
            return p.chunks.eta(self.s, now)
        if p.p_qlen[self.s] > 0:
            return float(p.p_eta[self.s])
        return float(max(p.p_busy[self.s], now))


class DecodeHandle:
    """Per-instance window into the decode columns (test/driver surface)."""

    __slots__ = ("_p", "slot")

    def __init__(self, plane: "InstancePlane", slot: int):
        self._p = plane
        self.slot = slot

    @property
    def instance_id(self) -> int:
        return int(self._p.d_ids[self.slot])

    @property
    def server(self):
        return self._p.d_server[self.slot]

    @property
    def healthy(self) -> bool:
        """Engine-side truth (scheduler sees view.healthy, which lags)."""
        return bool(self._p.d_healthy[self.slot])

    @property
    def iterations(self) -> int:
        return int(self._p.d_iterations[self.slot])

    @property
    def iter_scale(self) -> float:
        return float(self._p.d_iter_scale[self.slot])

    @iter_scale.setter
    def iter_scale(self, v: float) -> None:
        self._p.d_iter_scale[self.slot] = float(v)

    @property
    def iter_scale_est(self) -> float:
        return float(self._p.d_iter_scale_est[self.slot])

    @property
    def beta(self) -> int:
        return int(self._p.d_active[self.slot])

    @property
    def queued(self) -> int:
        return int(self._p.d_qlen[self.slot])

    @property
    def free_memory(self) -> float:
        p = self._p
        return float(max(p.d_budget[self.slot] - p.d_pinned[self.slot], 0.0))

    @property
    def pinned_bytes(self) -> float:
        return float(self._p.d_pinned[self.slot])

    def hit_tokens(self, req: Request) -> int:
        return self._p.cache.hit_tokens(self.slot, req.block_hashes, req.input_len)


class _ChunkStream:
    """One request's chunk progress on a prefill instance."""

    __slots__ = ("rs", "done", "cancelled")

    def __init__(self, rs: RequestState):
        self.rs = rs
        self.done = 0            # tokens whose KV is ready
        self.cancelled = False   # requeued mid-prefill (fault path)


class ChunkPlane:
    """Chunk-interleaved continuous-batching prefill engine.

    Replaces the serial per-request prefill queues when
    ``chunk_tokens`` is set: each request is split into fixed-token
    chunks, and every *prefill iteration* serves the head of each
    active request's chunk queue in round-robin order under a token
    budget (Sarathi/DeepSpeed-FastGen-style chunked prefill).  The
    iteration costs ``c * tokens_served + d * first_chunks`` — the
    fixed per-request overhead ``d`` is charged once, with the first
    chunk, so the total compute a request receives telescopes to
    exactly the monolithic ``T_prefill(l) = c*l + d`` (chunk-duration
    conservation, property-tested in ``tests/test_chunkplane.py``).

    As each chunk's KV becomes ready the plane fires
    ``owner.on_chunk_done(rs, tokens_ready, now)`` — the hook the
    simulator uses to *stream* completed chunks into the FlowPlane
    while later chunks are still prefilling (``SimConfig.kv_streaming``).

    Columnar state (slot-indexed like the serial prefill columns):
    ``busy`` (end of the in-flight iteration), ``backlog`` (unclaimed
    tokens over all active requests) and ``pending`` (requests whose
    fixed overhead ``d`` is still unpaid), so arrival routing is one
    masked argmin over ``max(busy, now) + c*backlog + d*pending`` —
    the same value the scalar reference oracle
    (``sim/reference.py::ChunkedPrefillSim``) computes per instance,
    bit-for-bit.
    """

    def __init__(self, owner: "InstancePlane", n_pre: int, *,
                 chunk_tokens: int, token_budget: int | None,
                 ids_attr: str = "p_ids", healthy_attr: str = "p_healthy",
                 deflect: bool = False):
        if int(chunk_tokens) <= 0:
            raise ValueError("chunk_tokens must be positive")
        self.owner = owner
        self.model = owner.prefill_model
        self.chunk = int(chunk_tokens)
        self.budget = int(token_budget) if token_budget is not None \
            else int(chunk_tokens)
        if self.budget <= 0:
            raise ValueError("prefill_token_budget must be positive")
        # The plane is *attachable*: ``ids_attr``/``healthy_attr`` name the
        # owner columns its slots index, so the same token-budget iteration
        # clock can meter prefill hosts (the default) or decode hosts
        # (RolePlane's deflected-prefill twin, ``deflect=True``).  Column
        # arrays are re-read through getattr at use time because the owner
        # reallocates them on growth.  Deflect mode reroutes completion
        # callbacks to ``on_deflect_done`` and emits "deflect" trace spans.
        self._ids_attr = ids_attr
        self._healthy_attr = healthy_attr
        self.deflect_mode = deflect
        self.busy = np.zeros(n_pre, np.float64)
        self.backlog = np.zeros(n_pre, np.int64)
        self.pending = np.zeros(n_pre, np.int64)
        self.streams: list[list[_ChunkStream]] = [[] for _ in range(n_pre)]
        self.inflight: list[Optional[list]] = [None] * n_pre
        self.iterations = 0      # telemetry: chunked prefill iterations
        self.busy_s = 0.0        # telemetry: cumulative iteration seconds
        # Iteration start times, kept only while tracing (chunk spans need
        # the [start, end) interval of the iteration that served them).
        self.iter_base = np.zeros(n_pre, np.float64)

    def add_slot(self) -> int:
        """Grow by one slot (elastic owner columns: add_decode/add_prefill)."""
        s = len(self.busy)
        self.busy = np.append(self.busy, 0.0)
        self.backlog = np.append(self.backlog, np.int64(0))
        self.pending = np.append(self.pending, np.int64(0))
        self.iter_base = np.append(self.iter_base, 0.0)
        self.streams.append([])
        self.inflight.append(None)
        return s

    # ------------------------------------------------------------- routing
    def eta_row(self, now: float, n: int) -> np.ndarray:
        """Earliest-start estimate per instance: drain time of the current
        backlog.  The new request's own ``c*l + d`` is an argmin-invariant
        constant shift, exactly like the serial ETA fold's convention."""
        return (np.maximum(self.busy[:n], now)
                + self.model.c * self.backlog[:n]
                + self.model.d * self.pending[:n])

    def eta(self, s: int, now: float) -> float:
        return float(max(self.busy[s], now)
                     + self.model.c * self.backlog[s]
                     + self.model.d * self.pending[s])

    # ------------------------------------------------------------ lifecycle
    def submit(self, s: int, rs: RequestState, now: float) -> None:
        self.streams[s].append(_ChunkStream(rs))
        self.backlog[s] += rs.req.input_len
        self.pending[s] += 1
        self._maybe_start(s, now)

    def cancel(self, s: int, rs: RequestState) -> None:
        """Drop a request mid-prefill (fault requeue).  Tokens already
        claimed by the in-flight iteration stay charged — that compute is
        physically spent — but the unclaimed remainder leaves the backlog
        and the stream fires no further callbacks."""
        streams = self.streams[s]
        for i, st in enumerate(streams):
            if st.rs is rs:
                break
        else:
            return
        del streams[i]
        st.cancelled = True
        claimed = st.done
        infl = self.inflight[s]
        if infl is not None:
            for entry, take in infl:
                if entry is st:
                    claimed += take
                    break
        self.backlog[s] -= max(rs.req.input_len - claimed, 0)
        if st.done == 0 and claimed == 0:
            # Overhead unpaid and not claimed by the running iteration.
            self.pending[s] -= 1

    # ------------------------------------------------- iteration scheduling
    def _maybe_start(self, s: int, now: float) -> None:
        if self.inflight[s] is not None \
                or not getattr(self.owner, self._healthy_attr)[s] \
                or self.backlog[s] == 0:
            return
        base = float(max(self.busy[s], now))
        budget = self.budget
        served: list[tuple[_ChunkStream, int]] = []
        total = 0
        nfirst = 0
        # Round-robin: the stream list order IS the serve order; every
        # stream has unclaimed tokens (finished ones are removed), so the
        # served set is a prefix of the list, one chunk each, until the
        # token budget runs out.
        for st in self.streams[s]:
            if budget <= 0:
                break
            take = min(self.chunk, st.rs.req.input_len - st.done, budget)
            if st.done == 0:
                nfirst += 1
                st.rs.prefill_start = base
            served.append((st, take))
            budget -= take
            total += take
        self.backlog[s] -= total
        self.pending[s] -= nfirst
        self.busy[s] = base + (self.model.c * total + self.model.d * nfirst)
        self.busy_s += float(self.busy[s]) - base
        if self.owner.trace is not None:
            self.iter_base[s] = base
        self.inflight[s] = served
        self.owner.loop.arm_slot(LANE_PREFILL, s, float(self.busy[s]),
                                 self._iteration_done)

    def _iteration_done(self, s: int, now: float) -> None:
        served = self.inflight[s]
        self.inflight[s] = None
        self.iterations += 1
        streams = self.streams[s]
        owner = self.owner
        # Phase 1+2: account tokens and splice the stream list BEFORE any
        # callback fires — a callback can synchronously re-enter this
        # instance (streamed transfer completes instantly -> detection-
        # window bounce -> requeue -> submit back here), and _maybe_start
        # must then see consistent state, not the stale served prefix.
        rotated: list[_ChunkStream] = []
        live: list[_ChunkStream] = []
        n_live = 0               # served entries still present in `streams`
        tr = owner.trace
        iid = int(getattr(owner, self._ids_attr)[s])
        base = float(self.iter_base[s])
        kind = "deflect" if self.deflect_mode else "chunk"
        for st, take in served:
            if st.cancelled:
                continue
            n_live += 1
            st.done += take
            if tr is not None:
                tr.chunk(st.rs, iid, base, now, take, st.done, kind=kind)
            live.append(st)
            if st.done < st.rs.req.input_len:
                rotated.append(st)
        # Served entries are the first n_live list items; unfinished ones
        # rotate to the back (behind arrivals that landed mid-iteration).
        self.streams[s] = streams[n_live:] + rotated
        # Phase 3: callbacks, in served order; skip entries a previous
        # callback cancelled (requeued mid-phase).  With cohort dispatch
        # enabled, a multi-stream iteration hands the whole served batch
        # over in one call so same-instant selections fuse (the handler
        # replicates this loop's per-stream semantics exactly).  Deflected
        # chunks never stream or cohort-dispatch: the KV is born on the
        # decode host, so only the completion callback matters.
        if self.deflect_mode:
            cohort_cb, chunk_cb, done_cb = None, None, owner.on_deflect_done
        else:
            cohort_cb = owner.on_phase3_cohort
            chunk_cb = owner.on_chunk_done
            done_cb = owner.on_prefill_done
        if cohort_cb is not None and len(live) > 1:
            cohort_cb(live, now)
        else:
            for st in live:
                if st.cancelled:
                    continue
                rs = st.rs
                if chunk_cb is not None:
                    chunk_cb(rs, st.done, now)
                if st.done >= rs.req.input_len:
                    rs.prefill_end = now
                    if done_cb is not None:
                        done_cb(rs, now)
        self._maybe_start(s, now)


class InstancePlane:
    """Struct-of-arrays prefill/decode engine with one cohort iteration clock."""

    kind = "plane"

    def __init__(self, pre_meta, dec_meta, *, view: ClusterView, loop: EventLoop,
                 iter_model: IterTimeModel, prefill_model: PrefillTimeModel,
                 beta_max: int, kv_spec: ModelKVSpec, kv_budget: float,
                 chunk_tokens: int | None = None,
                 prefill_token_budget: int | None = None):
        self.view = view
        self.loop = loop
        self.iter_model = iter_model
        self.prefill_model = prefill_model
        self.beta_max = beta_max
        self.kv_spec = kv_spec
        self.kv_budget = kv_budget
        self.kv_per_token = kv_spec.kv_bytes_per_token
        self.chunk_tokens = chunk_tokens
        self.on_prefill_done: Callable[[RequestState, float], None] | None = None
        self.on_chunk_done: Callable[[RequestState, int, float], None] | None = None
        # RolePlane deflected-prefill completion hook (fires from the
        # deflect ChunkPlane over decode slots; see enable_deflection).
        self.on_deflect_done: Callable[[RequestState, float], None] | None = None
        # TracePlane sink (sim/trace.py), set by the Simulation when
        # tracing; None keeps every emission site a dead branch.
        self.trace = None
        # Cohort dispatch hooks (SimConfig.dispatch_mode="plane"): when set,
        # same-timestamp prefill completions are handed over as one batch so
        # the simulator can run a single fused R x D selection instead of R
        # sequential ones.  None keeps the per-request paths untouched.
        self.on_prefill_cohort: Callable[[list, float], None] | None = None
        self.on_phase3_cohort: Callable[[list, float], None] | None = None
        self._on_first_token: Callable | None = None
        self._on_finish: Callable | None = None

        # ---------- prefill columns (fixed membership) --------------------
        n_pre = len(pre_meta)
        self.n_pre = n_pre
        self.p_ids = np.array([m.instance_id for m in pre_meta], np.int64)
        self.p_server = [m.server for m in pre_meta]
        self.p_busy = np.zeros(n_pre, np.float64)
        # Exact left-fold ETA: when the queue is non-empty this equals the
        # reference's  max(busy, now) + sum(T_prefill)  walk bit-for-bit
        # (queue non-empty implies running implies busy_until >= now).
        self.p_eta = np.zeros(n_pre, np.float64)
        self.p_qlen = np.zeros(n_pre, np.int64)
        self.p_healthy = np.ones(n_pre, bool)
        self.p_queue: list[deque] = [deque() for _ in range(n_pre)]
        self.p_running: list[Optional[RequestState]] = [None] * n_pre
        self.prefill = [PrefillHandle(self, s) for s in range(n_pre)]
        self._pre_slot = {int(i): s for s, i in enumerate(self.p_ids)}
        # ChunkPlane replaces the serial columns when chunk_tokens is set;
        # chunk_tokens=None leaves every serial code path untouched.
        self.chunks = ChunkPlane(
            self, n_pre, chunk_tokens=chunk_tokens,
            token_budget=prefill_token_budget,
        ) if chunk_tokens is not None else None
        # Deflect twin over the decode slots (None until enable_deflection).
        self.deflect: ChunkPlane | None = None
        # Per-role busy-second accumulators (RunMetrics utilization rows).
        self._p_busy_s = 0.0      # serial prefill (chunked lives in .chunks)
        self.decode_busy_s = 0.0

        # ---------- decode columns (elastic membership) -------------------
        cap = max(len(dec_meta), 1)
        self.n_dec = 0
        self.d_ids = np.zeros(cap, np.int64)
        self.d_server: list = []
        self.d_budget = np.zeros(cap, np.float64)
        self.d_pinned = np.zeros(cap, np.float64)
        self.d_active = np.zeros(cap, np.int64)
        self.d_qlen = np.zeros(cap, np.int64)
        self.d_healthy = np.zeros(cap, bool)
        self.d_iter_scale = np.ones(cap, np.float64)
        self.d_iter_scale_est = np.ones(cap, np.float64)
        self.d_iterations = np.zeros(cap, np.int64)
        self.d_deadline = np.full(cap, np.inf, np.float64)
        self.d_queue: list[deque] = []
        self.decode: list[DecodeHandle] = []
        self.cache = RadixPlane(
            kv_spec.kv_bytes_per_token * B_TOK,
            instance_capacity=cap,
        )

        # ---------- request table (active decoding requests) --------------
        rcap = 64
        self.r_live = np.zeros(rcap, bool)
        self.r_tokens = np.zeros(rcap, np.int64)
        self.r_out = np.zeros(rcap, np.int64)
        self.r_inst = np.zeros(rcap, np.int64)
        self.r_seq = np.zeros(rcap, np.int64)
        self.r_obj: list[Optional[RequestState]] = [None] * rcap
        self._r_free: list[int] = list(range(rcap - 1, -1, -1))
        self._r_hi = 0            # rows ever allocated (scan bound)
        self._next_seq = 0        # global admission sequence
        # Admission-ordered row indices per instance: lets small cohorts
        # step through a scalar fast path (identical arithmetic, no
        # full-table scan) while large cohorts take the fused array path.
        self._inst_rows: list[list[int]] = []
        self.scalar_rows_max = 256   # cohort row count below which the
        #                              scalar path runs (tests pin 0 / inf
        #                              to force either path)

        # The cohort iteration clock lives in the loop's LANE_CLOCK slot
        # (arm/disarm with dedupe) — no per-plane event bookkeeping.

        for m in dec_meta:
            self.add_decode(m.instance_id, m.server)

    # ------------------------------------------------------------- callbacks
    def set_decode_callbacks(self, on_first_token, on_finish) -> None:
        self._on_first_token = on_first_token
        self._on_finish = on_finish

    # ----------------------------------------------------------------- sync
    def _sync_slot(self, s: int) -> None:
        """Write-through for one touched slot (reserve/enqueue/release paths
        mutate a single instance; rewriting all D columns would put O(D)
        work on every request event)."""
        v = self.view
        v.free_memory[s] = max(self.d_budget[s] - self.d_pinned[s], 0.0)
        v.queued[s] = self.d_qlen[s]
        v.batch[s] = self.d_active[s]
        v.iter_scale[s] = self.d_iter_scale_est[s]

    def _sync_rows(self, idx: np.ndarray) -> None:
        """Write-through for a cohort of slots."""
        v = self.view
        v.free_memory[idx] = np.maximum(self.d_budget[idx] - self.d_pinned[idx], 0.0)
        v.queued[idx] = self.d_qlen[idx]
        v.batch[idx] = self.d_active[idx]
        v.iter_scale[idx] = self.d_iter_scale_est[idx]

    # --------------------------------------------------------------- prefill
    def pick_prefill(self, now: float) -> Optional[PrefillHandle]:
        n = self.n_pre
        if n == 0 or not self.p_healthy[:n].any():
            return None
        if self.chunks is not None:
            eta = self.chunks.eta_row(now, n)
        else:
            eta = np.where(self.p_qlen[:n] > 0, self.p_eta[:n],
                           np.maximum(self.p_busy[:n], now))
        eta = np.where(self.p_healthy[:n], eta, np.inf)
        return self.prefill[int(np.argmin(eta))]

    def prefill_backlog(self, now: float) -> float:
        """RolePlane imbalance signal: best-case prefill wait in seconds.

        Min-over-healthy-instances drain ETA minus ``now`` — the value the
        deflection gate and the P:D flip controller threshold against.
        ``inf`` when no healthy prefill instance exists.
        """
        n = self.n_pre
        if n == 0 or not self.p_healthy[:n].any():
            return float("inf")
        if self.chunks is not None:
            eta = self.chunks.eta_row(now, n)
        else:
            eta = np.where(self.p_qlen[:n] > 0, self.p_eta[:n],
                           np.maximum(self.p_busy[:n], now))
        eta = np.where(self.p_healthy[:n], eta, np.inf)
        return float(eta.min()) - now

    def submit_prefill(self, s: int, rs: RequestState, now: float) -> None:
        rs.prefill_instance = int(self.p_ids[s])
        if self.chunks is not None:
            self.chunks.submit(s, rs, now)
            return
        q = self.p_queue[s]
        q.append(rs)
        # ETA-fold shortcut, audited at the queue-drain boundary (see
        # tests/test_chunkplane.py::TestSerialEtaBoundary): with the queue
        # previously non-empty, p_eta already holds the exact left fold and
        # a request is necessarily running, so p_busy >= now and appending
        # one term keeps the fold exact.  With the queue previously empty
        # p_busy may be stale (< now, instance idle), but _prefill_start
        # below immediately pops this request and rebuilds the fold from
        # max(now, p_busy) — the transient value is never observable.  The
        # one unreachable gap: an *unhealthy* instance holds a stale fold
        # until it next starts, and pick_prefill masks it to inf anyway.
        base = self.p_eta[s] if len(q) > 1 else self.p_busy[s]
        self.p_eta[s] = base + self.prefill_model(rs.req.input_len)
        self.p_qlen[s] = len(q)
        self._prefill_start(s, now)

    def cancel_prefill(self, rs: RequestState) -> None:
        """Drop a request that is still prefilling (fault-requeue path).

        Only reachable in chunked mode: with serial prefill, transfers —
        and hence fault requeues — only exist after prefill completes.
        Deflected requests cancel on the deflect plane (decode slots).
        """
        if rs.deflected and self.deflect is not None:
            self.deflect.cancel(self.view.slot_of(rs.prefill_instance), rs)
        elif self.chunks is not None:
            self.chunks.cancel(self._pre_slot[rs.prefill_instance], rs)

    def _prefill_start(self, s: int, now: float) -> None:
        if self.p_running[s] is not None or not self.p_queue[s] \
                or not self.p_healthy[s]:
            return
        rs = self.p_queue[s].popleft()
        self.p_running[s] = rs
        rs.prefill_start = float(max(now, self.p_busy[s]))
        dur = self.prefill_model(rs.req.input_len)
        self._p_busy_s += dur
        self.p_busy[s] = rs.prefill_start + dur
        # Rebuild the ETA fold from the new base — the same left-to-right
        # addition order the reference's eta() walk performs.
        eta = self.p_busy[s]
        for queued in self.p_queue[s]:
            eta = eta + self.prefill_model(queued.req.input_len)
        self.p_eta[s] = eta
        self.p_qlen[s] = len(self.p_queue[s])
        self.loop.arm_slot(LANE_PREFILL, s, float(self.p_busy[s]),
                           self._prefill_finish)

    def _prefill_finish(self, s: int, now: float) -> None:
        rs = self.p_running[s]
        if rs is None:
            return
        if self.on_prefill_cohort is not None:
            # Cohort dispatch: absorb every other prefill completion due at
            # this exact instant (they are the engine's next dispatches
            # anyway — drain_due only takes heads that precede all other
            # pending events), mark them all finished, then hand the batch
            # to the simulator for one fused selection.  Successor prefills
            # start after the dispatches, matching the per-event order for
            # everything observable: a prefill start only arms a strictly
            # future timer and touches no decode state.
            drained = self.loop.drain_due(LANE_PREFILL, self._prefill_finish)
            slots = [s] + drained
            batch: list[RequestState] = []
            for s2 in slots:
                rs2 = self.p_running[s2]
                if rs2 is None:
                    continue
                rs2.prefill_end = now
                self.p_running[s2] = None
                batch.append(rs2)
            if len(batch) > 1:
                self.on_prefill_cohort(batch, now)
            elif batch and self.on_prefill_done is not None:
                self.on_prefill_done(batch[0], now)
            for s2 in slots:
                self._prefill_start(s2, now)
            return
        rs.prefill_end = now
        self.p_running[s] = None
        if self.on_prefill_done is not None:
            self.on_prefill_done(rs, now)
        self._prefill_start(s, now)

    def add_prefill(self, iid: int, server) -> PrefillHandle:
        """Elastic prefill membership: append one prefill slot (RolePlane
        flips and the ``add_prefill`` fault kind)."""
        s = self.n_pre
        self.n_pre = s + 1
        self.p_ids = np.append(self.p_ids, np.int64(iid))
        self.p_server.append(server)
        self.p_busy = np.append(self.p_busy, 0.0)
        self.p_eta = np.append(self.p_eta, 0.0)
        self.p_qlen = np.append(self.p_qlen, np.int64(0))
        self.p_healthy = np.append(self.p_healthy, True)
        self.p_queue.append(deque())
        self.p_running.append(None)
        h = PrefillHandle(self, s)
        self.prefill.append(h)
        self._pre_slot[int(iid)] = s
        if self.chunks is not None:
            self.chunks.add_slot()
        return h

    def fail_prefill(self, iid: int, now: float) -> list[RequestState]:
        """Hard prefill failure: drop queued/in-flight work, return victims
        for re-scheduling (``kill_prefill`` fault kind).  Victims come back
        in the reference's order: the running request (chunked: stream list
        order), then the queue."""
        s = self._pre_slot[iid]
        self.p_healthy[s] = False
        victims: list[RequestState] = []
        if self.chunks is not None:
            for st in list(self.chunks.streams[s]):
                victims.append(st.rs)
                self.chunks.cancel(s, st.rs)
            return victims
        if self.p_running[s] is not None:
            victims.append(self.p_running[s])
            self.p_running[s] = None
        victims.extend(self.p_queue[s])
        self.p_queue[s].clear()
        self.p_qlen[s] = 0
        return victims

    def prefill_drained(self, iid: int) -> bool:
        """No running or queued prefill work on ``iid`` (flip precondition)."""
        s = self._pre_slot[iid]
        if self.chunks is not None:
            return not self.chunks.streams[s] and self.chunks.inflight[s] is None
        return self.p_running[s] is None and not self.p_queue[s]

    def decode_drained(self, iid: int) -> bool:
        """No active batch, queue, or deflected stream on ``iid``."""
        s = self.view.slot_of(iid)
        if self.d_active[s] or self.d_qlen[s] or not self.d_healthy[s]:
            return False
        if self.deflect is not None and (
                self.deflect.streams[s] or self.deflect.inflight[s] is not None):
            return False
        return True

    def flip_role(self, iid: int, role: int, now: float) -> None:
        """Planned role transition (RolePlane slow control loop).

        The caller drains first (``decode_drained``/``prefill_drained``);
        the flip itself is then pure bookkeeping: the ``ClusterView`` role
        column resyncs so the scheduler ladder and the cohort selector mask
        the instance out of (or back into) the candidate set, and a
        decode->prefill flip performs the RadixPlane handoff — the prefix
        cache is dropped (contents *and* counters), because a prefill host
        keeps no decode-side radix state.
        """
        s = self.view.slot_of(iid)
        if role == ROLE_PREFILL:
            self.view.role[s] = ROLE_PREFILL
            self.cache.reset_instance(s)
            self._sync_slot(s)
            ps = self._pre_slot.get(int(iid))
            if ps is not None:
                self.p_healthy[ps] = True
            else:
                self.add_prefill(iid, self.d_server[s])
        elif role == ROLE_DECODE:
            self.p_healthy[self._pre_slot[int(iid)]] = False
            self.view.role[s] = ROLE_DECODE
            self._sync_slot(s)
        else:
            raise ValueError(f"unknown role {role!r}")

    # ------------------------------------------------------------ deflection
    def enable_deflection(self) -> ChunkPlane:
        """Attach the deflect ChunkPlane over the decode slots.

        Reuses the prefill plane's chunk/budget settings: a deflected
        request is metered by the same token-budget iteration clock, just
        on a decode host — its KV is born there, so completion feeds
        straight into reserve/enqueue with no transfer.
        """
        if self.chunks is None:
            raise ValueError("deflection requires chunked prefill "
                             "(set chunk_tokens)")
        if self.deflect is None:
            self.deflect = ChunkPlane(
                self, self.n_dec, chunk_tokens=self.chunks.chunk,
                token_budget=self.chunks.budget,
                ids_attr="d_ids", healthy_attr="d_healthy", deflect=True,
            )
        return self.deflect

    def deflect_eta_row(self, now: float) -> np.ndarray:
        """Per-decode-slot deflected-chunk drain ETA (Eq. (5) deflected
        branch's ETA_defl term, aligned with ClusterView slots)."""
        return self.deflect.eta_row(now, self.n_dec)

    def submit_deflected(self, iid: int, rs: RequestState, now: float) -> None:
        rs.prefill_instance = int(iid)
        rs.deflected = True
        self.deflect.submit(self.view.slot_of(iid), rs, now)

    def set_chunking(self, chunk_tokens: int, token_budget: int) -> None:
        """Retune chunk size / per-iteration token budget (auto-tuner).

        Iterations already in flight keep their claimed durations; the next
        ``_maybe_start`` on every instance reads the new values.
        """
        if self.chunks is None:
            raise ValueError("set_chunking requires chunked prefill")
        if int(chunk_tokens) <= 0 or int(token_budget) <= 0:
            raise ValueError("chunk_tokens / token_budget must be positive")
        for plane in (self.chunks, self.deflect):
            if plane is not None:
                plane.chunk = int(chunk_tokens)
                plane.budget = int(token_budget)

    # ---------------------------------------------------------------- decode
    def add_decode(self, iid: int, server, kv_budget: float | None = None
                   ) -> DecodeHandle:
        budget = self.kv_budget if kv_budget is None else kv_budget
        s = self.view.add_instance(iid, free_memory=budget, healthy=True)
        if s != self.n_dec:  # pragma: no cover - plane is the sole registrar
            raise RuntimeError("view slots out of step with InstancePlane")
        if self.n_dec == len(self.d_ids):
            self._grow_decode()
        self.n_dec += 1
        self.d_ids[s] = iid
        self.d_server.append(server)
        self.d_budget[s] = budget
        self.d_pinned[s] = 0.0
        self.d_active[s] = 0
        self.d_qlen[s] = 0
        self.d_healthy[s] = True
        self.d_iter_scale[s] = 1.0
        self.d_iter_scale_est[s] = 1.0
        self.d_iterations[s] = 0
        self.d_deadline[s] = np.inf
        self.d_queue.append(deque())
        self._inst_rows.append([])
        self.cache.add_instance(budget)
        if self.deflect is not None:
            self.deflect.add_slot()
        h = DecodeHandle(self, s)
        self.decode.append(h)
        return h

    def _grow_decode(self) -> None:
        cap = len(self.d_ids) * 2
        for name in ("d_ids", "d_budget", "d_pinned", "d_active", "d_qlen",
                     "d_healthy", "d_iter_scale", "d_iter_scale_est",
                     "d_iterations"):
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype)
            new[: self.n_dec] = old[: self.n_dec]
            setattr(self, name, new)
        dl = np.full(cap, np.inf, np.float64)
        dl[: self.n_dec] = self.d_deadline[: self.n_dec]
        self.d_deadline = dl

    def decode_by_id(self, iid: int) -> DecodeHandle:
        return self.decode[self.view.slot_of(iid)]

    def is_healthy(self, iid: int) -> bool:
        return bool(self.d_healthy[self.view.slot_of(iid)])

    # --------------------------------------------------------------- scoring
    def fill_hits(self, req: Request) -> None:
        """lambda_r(d) for all instances in one broadcast LCP comparison."""
        self.cache.hit_row(req.block_hashes, req.input_len,
                           out=self.view.hit_tokens)

    def hit_tokens(self, iid: int, req: Request) -> float:
        return float(self.cache.hit_tokens(
            self.view.slot_of(iid), req.block_hashes, req.input_len))

    def hit_rows(self, reqs) -> np.ndarray:
        """lambda_r(d) for a dispatch cohort: (R, D) hit-token matrix in one
        pass over the shared presence bitmask (see RadixPlane.hit_rows)."""
        return self.cache.hit_rows(reqs)

    def evictions_of(self, iid: int) -> int:
        """Cumulative eviction count for one instance (cohort-dispatch
        staleness watch: a changed count invalidates cached hit rows)."""
        return int(self.cache.evictions[self.view.slot_of(iid)])

    # -------------------------------------------------------------- lifecycle
    def reserve(self, iid: int, rs: RequestState, now: float) -> None:
        """Pin KV for an inbound transfer (memory committed at dispatch)."""
        s = self.view.slot_of(iid)
        self.d_pinned[s] += rs.kv_bytes
        self.cache.evict_to(s, float(self.d_pinned[s]))
        self._sync_slot(s)

    def release(self, iid: int, rs: RequestState) -> None:
        s = self.view.slot_of(iid)
        self.d_pinned[s] = max(0.0, float(self.d_pinned[s]) - rs.kv_bytes)
        self._sync_slot(s)

    def enqueue(self, iid: int, rs: RequestState, now: float) -> None:
        """Transfer landed: blocks now resident; join the batch queue."""
        s = self.view.slot_of(iid)
        self.cache.insert(s, rs.req.block_hashes,
                          protected=float(self.d_pinned[s]))
        self.d_queue[s].append(rs)
        self.d_qlen[s] += 1
        self._sync_slot(s)

    def kick(self, iids, now: float) -> None:
        """Epoch admission: start/continue iterating every touched instance."""
        for iid in iids:
            s = self.view.slot_of(iid)
            self._maybe_iterate(s, now)
            self._sync_slot(s)
        self._reschedule_clock()

    def set_iter_scale(self, iid: int, factor: float) -> None:
        self.d_iter_scale[self.view.slot_of(iid)] = float(factor)

    def mark_detected(self, iid: int, now: float) -> None:
        """Fault detection fired: health becomes scheduler-visible."""
        s = self.view.slot_of(iid)
        self.view.healthy[s] = bool(self.d_healthy[s])

    def fail(self, iid: int, now: float) -> list[RequestState]:
        """Hard failure: drop all state, return victims for re-scheduling.

        Victims are returned in the reference's order: active requests in
        admission order, then the queued requests in queue order.
        """
        s = self.view.slot_of(iid)
        self.d_healthy[s] = False
        rows = self._inst_rows[s]
        self._inst_rows[s] = []
        victims = [self.r_obj[r] for r in rows]  # admission order
        victims.extend(self.d_queue[s])
        if self.deflect is not None:
            # Deflected requests still prefilling on the dead host requeue
            # like everything else (post-prefill ones are already queued).
            for st in list(self.deflect.streams[s]):
                victims.append(st.rs)
                self.deflect.cancel(s, st.rs)
        for r in rows:
            self._free_row(r)
        self.d_queue[s].clear()
        self.d_qlen[s] = 0
        self.d_active[s] = 0
        self.d_pinned[s] = 0.0
        self.cache.reset_instance(s)
        self.d_deadline[s] = np.inf
        self._sync_slot(s)
        self._reschedule_clock()
        return victims

    # ---------------------------------------------------- continuous batching
    def _reserve_rows(self, k: int) -> None:
        """Grow the request table until at least ``k`` rows are free."""
        while len(self._r_free) < k:
            rcap = len(self.r_live)
            new_cap = rcap * 2
            for name in ("r_live", "r_tokens", "r_out", "r_inst", "r_seq"):
                old = getattr(self, name)
                new = np.zeros(new_cap, old.dtype)
                new[:rcap] = old
                setattr(self, name, new)
            self.r_obj.extend([None] * rcap)
            self._r_free.extend(range(new_cap - 1, rcap - 1, -1))

    def _alloc_row(self) -> int:
        if not self._r_free:
            self._reserve_rows(1)
        r = self._r_free.pop()
        self._r_hi = max(self._r_hi, r + 1)
        return r

    def _free_row(self, r: int) -> None:
        self.r_live[r] = False
        self.r_obj[r] = None
        self._r_free.append(r)

    def _maybe_iterate(self, s: int, now: float) -> None:
        if self.d_deadline[s] < np.inf or not self.d_healthy[s]:
            return
        active = int(self.d_active[s])
        q = self.d_queue[s]
        if active == 0 and not q:
            return
        if q and active < self.beta_max:
            # Admit from the queue at the iteration boundary (Orca-style),
            # the whole kick-epoch cohort in one vectorised batch: row
            # allocation is a single free-list slice (same pop order as
            # repeated _alloc_row), the table columns are fancy-index
            # writes, and the per-request TBT-at-entry values — t_iter of
            # the batch size each request joins — come out of one
            # iter_time_vector call (element-for-element the IEEE op
            # sequence of the scalar iter_model, so rs.tbt stays
            # bit-identical to the reference's per-request computation).
            k = min(len(q), self.beta_max - active)
            self._reserve_rows(k)
            free = self._r_free
            rows = free[-k:][::-1]           # == k successive .pop()s
            del free[-k:]
            self._r_hi = max(self._r_hi, max(rows) + 1)
            admitted = [q.popleft() for _ in range(k)]
            idx = np.array(rows, np.intp)
            self.r_live[idx] = True
            self.r_tokens[idx] = 0
            self.r_out[idx] = [rs.req.output_len for rs in admitted]
            self.r_inst[idx] = s
            seq = self._next_seq
            self.r_seq[idx] = np.arange(seq, seq + k)
            self._next_seq = seq + k
            scale = float(self.d_iter_scale[s])
            # §VI-A: TBT at entry — batch sizes active+1 .. active+k.
            tbts = (iter_time_vector(self.iter_model,
                                     np.arange(active + 1, active + k + 1))
                    * scale).tolist()
            r_obj = self.r_obj
            for r, rs, tbt in zip(rows, admitted, tbts):
                rs.admit_time = now
                rs.tbt = tbt
                r_obj[r] = rs
            self._inst_rows[s].extend(rows)
            active += k
            self.d_qlen[s] = len(q)
            self.d_active[s] = active
        if active == 0:
            return
        dur = self.iter_model(active) * float(self.d_iter_scale[s])
        self.decode_busy_s += dur
        self.d_deadline[s] = now + dur

    def _reschedule_clock(self) -> None:
        n = self.n_dec
        t = float(self.d_deadline[:n].min()) if n else np.inf
        if np.isfinite(t):
            self.loop.arm(LANE_CLOCK, t, self._step, dedupe=True)
        else:
            self.loop.disarm(LANE_CLOCK)

    def _step(self, now: float) -> None:
        """Clock-lane dispatch: step every instance due at ``now``.

        On a batched engine this is a *horizon loop*: after the due cohort
        steps, the plane keeps absorbing its own future iteration
        boundaries — fused per-instance runs via ``_fast_forward`` where no
        admission/first-token/finish can occur, in-batch cohort steps via
        ``lane_tick`` otherwise — up to the earliest event pending on any
        other lane.  Nothing else can dispatch inside that window, so the
        absorbed boundaries observe exactly the state the reference engine
        would hand them, one heap pop at a time.  On the reference engine
        it is one cohort step + re-arm, as before.
        """
        loop = self.loop
        if not loop.batched:
            self._step_cohort(now)
            self._reschedule_clock()
            return
        while True:
            self._step_cohort(now)
            h = loop.lane_horizon(LANE_CLOCK)
            t = self._fast_forward(h)
            if t < h:
                # Next boundary still precedes every other lane but needs
                # the full cohort step (admission pending, first token or
                # finish due, or a deadline tie across instances).
                loop.lane_tick(LANE_CLOCK, t)   # advances loop.now first
                now = t
                continue
            if t < np.inf:
                loop.arm(LANE_CLOCK, t, self._step, dedupe=True)
            else:
                loop.disarm(LANE_CLOCK)
            return

    def _fast_forward(self, h: float) -> float:
        """Fuse eligible instances' iteration boundaries strictly below ``h``.

        An instance qualifies while nothing observable can happen at its
        boundaries: healthy, empty admit queue, all rows past their first
        token, and stopping one boundary short of the earliest finish.  For
        such a run the per-boundary work collapses to scalar float updates —
        the *same op sequence* the cohort step performs (EWMA estimator,
        one ``+= kv_per_token`` per active row, cache evict-to-limit,
        ``deadline += t_iter``), so state lands bit-identical to stepping
        through the engine.  Returns the new earliest deadline.
        """
        n = self.n_dec
        dl = self.d_deadline
        if not n:
            return float(np.inf)
        cand = (dl[:n] < h).nonzero()[0]
        if cand.size:
            loop = self.loop
            cache = self.cache
            trace = loop.trace_log is not None
            kpt = float(self.kv_per_token)
            bpb = cache.bytes_per_block
            budget = cache.budget
            count = cache.count
            evict = cache._evict_to_limit
            iter_model = self.iter_model
            r_tokens, r_out = self.r_tokens, self.r_out
            est = self.d_iter_scale_est
            for s_ in cand:
                s = int(s_)
                if not self.d_healthy[s] or self.d_qlen[s]:
                    continue
                rows = self._inst_rows[s]
                if not rows:
                    continue
                mintok = 10 ** 9
                max_k = 10 ** 9
                for r in rows:
                    tk = int(r_tokens[r])
                    rem = int(r_out[r]) - tk
                    if tk < mintok:
                        mintok = tk
                    if rem < max_k:
                        max_k = rem
                max_k -= 1      # the boundary reaching a finish runs slow
                if mintok < 1 or max_k <= 0:
                    continue
                active = len(rows)
                scale = float(self.d_iter_scale[s])
                dur = iter_model(active) * scale
                t = float(dl[s])
                e = float(est[s])
                p = float(self.d_pinned[s])
                cb = float(budget[s])
                nb = int(count[s])
                k = 0
                times = [] if trace else None
                while t < h and k < max_k:
                    e = e + 0.2 * (scale - e)
                    for _ in range(active):
                        p = p + kpt
                    limit = cb - p
                    if limit < 0.0:
                        limit = 0.0
                    if nb * bpb > limit:
                        evict(s, limit)
                        nb = int(count[s])
                    if trace:
                        times.append(t)
                    k += 1
                    t = t + dur
                if not k:
                    continue
                self.decode_busy_s += k * dur
                dl[s] = t
                est[s] = e
                self.d_pinned[s] = p
                self.d_iterations[s] += k
                for r in rows:
                    r_tokens[r] += k
                self._sync_slot(s)
                loop.lane_ticks(LANE_CLOCK, k, times=times)
        return float(dl[:n].min())

    def _step_cohort(self, now: float) -> None:
        """Cohort iteration boundary: every instance due at ``now`` steps.

        Token accounting, first-token detection, decode-side KV growth and
        finish detection are fused array ops over the cohort's request rows;
        per-finish bookkeeping runs in admission order per instance, exactly
        reproducing the reference's dict-ordered float accounting.  Small
        cohorts (<= ``scalar_rows_max`` active rows) take a scalar path over
        the per-instance row lists instead of the full-table scan — the
        arithmetic is operation-for-operation the same, so both paths stay
        bit-identical to the reference (the parity tests pin the threshold
        to force each).
        """
        n = self.n_dec
        cohort = (self.d_deadline[:n] <= now).nonzero()[0]
        if cohort.size:
            est = self.d_iter_scale_est
            if cohort.size == 1:
                # Overwhelmingly common with staggered deadlines: one
                # instance due — scalar bookkeeping, same arithmetic.
                s = int(cohort[0])
                self.d_deadline[s] = np.inf
                self.d_iterations[s] += 1
                est[s] += 0.2 * (self.d_iter_scale[s] - est[s])
                nrows = len(self._inst_rows[s])
            else:
                self.d_deadline[cohort] = np.inf
                self.d_iterations[cohort] += 1
                est[cohort] += 0.2 * (self.d_iter_scale[cohort] - est[cohort])
                nrows = int(self.d_active[cohort].sum())
            if nrows <= self.scalar_rows_max:
                self._step_rows_scalar(cohort, now)
            else:
                self._step_rows_vector(cohort, now)
            # Growth may overcommit: evict the LRU cache down to the pin
            # level on every iterating instance (reference does this each
            # _iter_done), then start the next iteration / admit waiters.
            self.cache.evict_cohort(cohort, self.d_pinned[cohort])
            if cohort.size > 4:
                # Vector restart for instances with nothing to admit (the
                # steady-state bulk of a synchronized cohort): deadline =
                # now + t_iter(beta) * scale, elementwise — the same op
                # sequence as _maybe_iterate's scalar arithmetic.
                easy = (self.d_qlen[cohort] == 0) & (self.d_active[cohort] > 0) \
                    & self.d_healthy[cohort]
                ez = cohort[easy]
                if ez.size:
                    dur = iter_time_vector(self.iter_model, self.d_active[ez]) \
                        * self.d_iter_scale[ez]
                    self.decode_busy_s += float(dur.sum())
                    self.d_deadline[ez] = now + dur
                rest = cohort[~easy]
            else:
                rest = cohort
            for s in rest:
                self._maybe_iterate(int(s), now)
            if cohort.size == 1:
                self._sync_slot(int(cohort[0]))
            else:
                self._sync_rows(cohort)

    def _step_rows_scalar(self, cohort, now: float) -> None:
        """Small-cohort token accounting: per-row scalar ops, no table scan."""
        r_tokens, r_out, r_obj = self.r_tokens, self.r_out, self.r_obj
        pinned = self.d_pinned
        kpt = float(self.kv_per_token)
        for s_ in cohort:
            s = int(s_)
            rows = self._inst_rows[s]
            if not rows:
                continue
            finished: list[int] = []
            for r in rows:
                t = int(r_tokens[r]) + 1
                r_tokens[r] = t
                if t == 1:
                    rs = r_obj[r]
                    rs.first_token = now
                    if self._on_first_token:
                        self._on_first_token(rs, now)
                # Decode-side KV growth: one token per active request —
                # one scalar add per request, as the reference does.
                pinned[s] += kpt
                if t >= r_out[r]:
                    finished.append(r)
            if finished:
                for r in finished:
                    rs = r_obj[r]
                    rs.finish = now
                    rs.tokens_out = int(r_tokens[r])
                    grown = rs.kv_bytes + rs.req.output_len * self.kv_per_token
                    pinned[s] = max(0.0, float(pinned[s]) - grown)
                    self._free_row(r)
                    self.d_active[s] -= 1
                    if self._on_finish:
                        self._on_finish(rs, now)
                gone = set(finished)
                self._inst_rows[s] = [r for r in rows if r not in gone]

    def _step_rows_vector(self, cohort, now: float) -> None:
        """Large-cohort token accounting: fused array ops over the table."""
        n = self.n_dec
        hi = self._r_hi
        in_cohort = np.zeros(n, bool)
        in_cohort[cohort] = True
        rows = (self.r_live[:hi] & in_cohort[self.r_inst[:hi]]).nonzero()[0]
        if not rows.size:
            return
        self.r_tokens[rows] += 1
        toks = self.r_tokens[rows]
        for r in rows[toks == 1]:
            rs = self.r_obj[r]
            rs.first_token = now
            if self._on_first_token:
                self._on_first_token(rs, now)
        # Decode-side KV growth: one token per active request.  np.add.at
        # applies the equal-sized additions sequentially per instance
        # accumulator — bit-identical to the reference's one-request-at-a-
        # time += loop.
        np.add.at(self.d_pinned, self.r_inst[rows], float(self.kv_per_token))
        fin = rows[toks >= self.r_out[rows]]
        if fin.size:
            # Finish bookkeeping in admission order per instance — the
            # reference's dict order, and the order the per-instance
            # max(0, pinned - grown) clamp sequence depends on.
            order = np.lexsort((self.r_seq[fin], self.r_inst[fin]))
            fin = fin[order]
            fin_rows = fin.tolist()                     # one bulk convert
            fin_insts = self.r_inst[fin].tolist()
            fin_toks = self.r_tokens[fin].tolist()
            r_live, r_obj = self.r_live, self.r_obj
            free = self._r_free
            pinned = self.d_pinned
            active = self.d_active
            kpt = self.kv_per_token
            on_finish = self._on_finish
            touched: dict[int, set] = {}
            for r, s, t in zip(fin_rows, fin_insts, fin_toks):
                rs = r_obj[r]
                rs.finish = now
                rs.tokens_out = t
                grown = rs.kv_bytes + rs.req.output_len * kpt
                pinned[s] = max(0.0, float(pinned[s]) - grown)
                r_live[r] = False
                r_obj[r] = None
                free.append(r)
                touched.setdefault(s, set()).add(r)
                active[s] -= 1
                if on_finish:
                    on_finish(rs, now)
            # One admission-order rebuild per touched instance (a per-finish
            # list.remove would be O(beta) per finished request).
            for s, gone in touched.items():
                self._inst_rows[s] = [
                    r for r in self._inst_rows[s] if r not in gone
                ]

    def finalize(self) -> None:
        """Write per-request token counts back to the RequestState objects.

        The reference engine mutates ``rs.tokens_out`` per token; the plane
        keeps the count columnar and flushes it once at end of run (finished
        requests are flushed at finish time), so records of requests still
        decoding at the horizon report the same partial progress.
        """
        for r in np.flatnonzero(self.r_live[: self._r_hi]):
            self.r_obj[r].tokens_out = int(self.r_tokens[r])

    # ------------------------------------------------------------ telemetry
    @property
    def total_iterations(self) -> int:
        return int(self.d_iterations[: self.n_dec].sum())

    @property
    def prefill_busy_s(self) -> float:
        """Cumulative prefill compute seconds (serial or chunked)."""
        if self.chunks is not None:
            return self.chunks.busy_s
        return self._p_busy_s

    @property
    def deflect_busy_s(self) -> float:
        """Cumulative deflected-prefill compute seconds on decode hosts."""
        return self.deflect.busy_s if self.deflect is not None else 0.0

    def cache_stats(self) -> list[dict]:
        """Per-instance cache counters for the parity tests."""
        c = self.cache
        return [
            dict(instance_id=int(self.d_ids[s]), hits=int(c.hits[s]),
                 misses=int(c.misses[s]), evictions=int(c.evictions[s]),
                 bytes_used=c.bytes_used(s))
            for s in range(self.n_dec)
        ]
