"""Discrete-event disaggregated-serving simulator."""

from .engine import EventLoop
from .kvcache import B_TOK, BlockCache, n_blocks
from .instances import DecodeSim, PrefillSim, RequestState
from .metrics import RunMetrics, aggregate_seeds, summarize
from .simulator import FaultEvent, SimConfig, Simulation, run_sim

__all__ = [
    "EventLoop", "B_TOK", "BlockCache", "n_blocks", "DecodeSim", "PrefillSim",
    "RequestState", "RunMetrics", "aggregate_seeds", "summarize",
    "FaultEvent", "SimConfig", "Simulation", "run_sim",
]
