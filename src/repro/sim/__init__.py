"""Discrete-event disaggregated-serving simulator."""

from .engine import EventLoop
from .kvcache import B_TOK, BlockCache, RadixPlane, n_blocks
from .instances import (
    ChunkPlane, DecodeHandle, InstancePlane, PrefillHandle, RequestState,
)
from .reference import (
    ChunkedPrefillSim, DecodeSim, PrefillSim, ReferenceInstanceEngine,
)
from .metrics import RunMetrics, aggregate_seeds, summarize
from .scenarios import ScenarioPlane, ScenarioSpec, cohort_step, cohort_step_jit
from .simulator import FaultEvent, RewireEvent, SimConfig, Simulation, run_sim

__all__ = [
    "EventLoop", "B_TOK", "BlockCache", "RadixPlane", "n_blocks",
    "ChunkPlane", "InstancePlane", "DecodeHandle", "PrefillHandle",
    "ChunkedPrefillSim", "DecodeSim", "PrefillSim", "ReferenceInstanceEngine",
    "RequestState", "RunMetrics", "aggregate_seeds", "summarize",
    "ScenarioPlane", "ScenarioSpec", "cohort_step", "cohort_step_jit",
    "FaultEvent", "RewireEvent", "SimConfig", "Simulation", "run_sim",
]
