"""Discrete-event disaggregated-serving simulator."""

from .engine import (
    LANE_ARRIVAL, LANE_CLOCK, LANE_FAULT, LANE_GENERIC, LANE_NET,
    LANE_PREFILL, LANE_REWIRE, LANE_ROLE, LANE_TICK, LANE_NAMES, N_LANES,
    EventLoop, EventPlane, make_event_loop,
)
from .kvcache import B_TOK, BlockCache, RadixPlane, n_blocks
from .instances import (
    ChunkPlane, DecodeHandle, InstancePlane, PrefillHandle, RequestState,
)
from .reference import (
    ChunkedPrefillSim, DecodeSim, PrefillSim, ReferenceInstanceEngine,
)
from .metrics import RunMetrics, aggregate_seeds, summarize
from .scenarios import ScenarioPlane, ScenarioSpec, cohort_step, cohort_step_jit
from .simulator import FaultEvent, RewireEvent, SimConfig, Simulation, run_sim
from .trace import (
    TracePlane, TraceSession, enable_tracing, trace_session,
    ttft_attribution, ttft_breakdown_rows,
)

__all__ = [
    "EventLoop", "EventPlane", "make_event_loop",
    "LANE_GENERIC", "LANE_ARRIVAL", "LANE_FAULT", "LANE_REWIRE", "LANE_NET",
    "LANE_TICK", "LANE_CLOCK", "LANE_ROLE", "LANE_PREFILL", "LANE_NAMES",
    "N_LANES",
    "B_TOK", "BlockCache", "RadixPlane", "n_blocks",
    "ChunkPlane", "InstancePlane", "DecodeHandle", "PrefillHandle",
    "ChunkedPrefillSim", "DecodeSim", "PrefillSim", "ReferenceInstanceEngine",
    "RequestState", "RunMetrics", "aggregate_seeds", "summarize",
    "ScenarioPlane", "ScenarioSpec", "cohort_step", "cohort_step_jit",
    "FaultEvent", "RewireEvent", "SimConfig", "Simulation", "run_sim",
    "TracePlane", "TraceSession", "enable_tracing", "trace_session",
    "ttft_attribution", "ttft_breakdown_rows",
]
