"""TracePlane: columnar request-lifecycle tracing and decision forensics.

The observability engine in the repo's plane idiom: spans and forensics
rows live in parallel columns (struct-of-arrays, appended live and
materialised per-request at ``finalize``), are derived exclusively from
*sim* time (never wall clock), and are bit-exact across both event
engines (``EventPlane`` / reference heap) and both dispatch modes
(``CohortSelector`` / per-request ``select()``) — the parity suites
assert span-set and timestamp equality the same way they assert
outcomes.

Three layers:

1. **Lifecycle spans** — per-request ``queue → prefill (per chunk under
   ChunkPlane) → xfer (per stream segment under kv_streaming, with tier
   and the bottleneck link from FlowPlane's water-fill) → admit_wait →
   first_iter → decode``.  Endpoint timestamps already live on
   ``RequestState`` and are parity-guaranteed, so whole-phase spans are
   derived at ``finalize(records)``; only chunk spans, transfer
   segments and latency-only hops are emitted live, each behind an
   ``is not None`` guard so tracing-off allocates nothing on the hot
   path.
2. **Decision forensics** — for each (cohort) selection, the winner and
   runner-up candidates' per-component cost breakdown (cache / load /
   transfer / congestion terms of Eq. (4)/(6)/(7)), captured under a
   deterministic sampling stride (a call counter, never RNG or wall
   clock, so the sampled set is identical across dispatch modes).
3. **Exporters** — Chrome/Perfetto trace-event JSON (one track per
   instance plus a scheduler track) and ``ttft_breakdown.csv`` rows,
   plus the ``ttft_attribution`` summary feeding ``RunMetrics``.

``TraceSession`` aggregates many runs (one benchmark process) into a
single combined trace.json + ttft_breakdown.csv artifact pair.
"""

from __future__ import annotations

import csv
import json
import os
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from .instances import RequestState

# Span kinds (the trace-event ``name``):
#   queue      arrival -> prefill_start          (prefill track)
#   prefill    prefill_start -> prefill_end      (prefill track)
#   chunk      one ChunkPlane iteration's slice  (prefill track; a=tokens, b=done)
#   xfer       prefill_end -> transfer_end       (decode track; a=s_eff)
#   xfer_seg   one Transfer on the wire          (decode track; a=bytes, b=bottleneck link)
#   lat        latency-only hop (0 bytes)        (decode track)
#   admit_wait transfer_end -> admit_time        (decode track)
#   first_iter admit_time -> first_token         (decode track)
#   decode     first_token -> finish             (decode track; a=tokens_out)
#   deflect    one deflected-prefill chunk slice (decode track; a=tokens, b=done)
#   role_flip  RolePlane P:D transition instant  (decode track; a=new role)
SPAN_KINDS = (
    "queue", "prefill", "chunk", "xfer", "xfer_seg", "lat",
    "admit_wait", "first_iter", "decode", "deflect", "role_flip",
)
_PREFILL_TRACK = frozenset(("queue", "prefill", "chunk"))

FORENSICS_COLUMNS = (
    "time", "kind", "request_id", "prefill_id", "win", "run",
    "tier_win", "tier_run", "congestion",
    "cost_win", "cost_run", "cache_win", "cache_run",
    "load_win", "load_run", "xfer_win", "xfer_run",
)

BREAKDOWN_COLUMNS = (
    "run", "request_id", "arrival", "queue_wait", "prefill", "xfer",
    "admit_wait", "first_iter", "ttft", "xfer_share", "tier",
    "prefill_instance", "decode_instance", "hit_tokens", "requeues",
)


def _mean(a) -> float:
    return float(np.mean(a)) if len(a) else float("nan")


def _pct(a, q) -> float:
    return float(np.percentile(a, q)) if len(a) else float("nan")


class TracePlane:
    """Columnar span + forensics store for one ``Simulation`` run."""

    __slots__ = (
        "now", "_stride", "_n_dec",
        "s_kind", "s_req", "s_t0", "s_t1", "s_inst", "s_tier", "s_a", "s_b",
        "_dec", "_seg_seen",
    )

    def __init__(self, decision_stride: int = 1):
        self.now = 0.0  # sim time of the in-flight decision (set by the dispatcher)
        self._stride = max(1, int(decision_stride))
        self._n_dec = 0
        # Span columns (struct-of-arrays; one append per span).
        self.s_kind: list[str] = []
        self.s_req: list[int] = []
        self.s_t0: list[float] = []
        self.s_t1: list[float] = []
        self.s_inst: list[int] = []
        self.s_tier: list[int] = []
        self.s_a: list[float] = []
        self.s_b: list[float] = []
        self._dec: list[tuple] = []
        self._seg_seen: set[int] = set()

    # ------------------------------------------------------------------
    # live emission (hot-path callers guard on ``trace is not None``)

    def span(self, kind, req, t0, t1, inst, tier=-1, a=0.0, b=-1.0) -> None:
        self.s_kind.append(kind)
        self.s_req.append(int(req))
        self.s_t0.append(float(t0))
        self.s_t1.append(float(t1))
        self.s_inst.append(int(inst))
        self.s_tier.append(int(tier))
        self.s_a.append(float(a))
        self.s_b.append(float(b))

    def chunk(self, rs, inst, t0, t1, take, done, kind: str = "chunk") -> None:
        """One prefill chunk finishing an instance iteration (``kind=
        "deflect"`` when the chunk ran on a decode host via RolePlane)."""
        self.span(kind, rs.req.request_id, t0, t1, inst,
                  a=float(take), b=float(done))

    def role_flip(self, iid, now, role) -> None:
        """One RolePlane P:D transition (zero-duration instant)."""
        self.span("role_flip", -1, now, now, iid, a=float(role))

    def segment(self, rs, transfer) -> None:
        """One completed KV ``Transfer`` (deduped across callback paths)."""
        tid = transfer.transfer_id
        if tid in self._seg_seen:
            return
        self._seg_seen.add(tid)
        end = transfer.finish_time
        self.span("xfer_seg", rs.req.request_id, transfer.start_time,
                  transfer.start_time if end is None else end,
                  rs.decode_instance, tier=transfer.tier,
                  a=transfer.total_bytes, b=float(transfer.bottleneck_link))

    def lat_segment(self, rs, t0, t1) -> None:
        """A latency-only (zero-byte) transfer hop."""
        self.span("lat", rs.req.request_id, t0, t1, rs.decode_instance,
                  tier=rs.tier)

    # ------------------------------------------------------------------
    # decision forensics

    def want_decision(self) -> bool:
        """Deterministic sampling: counts every decision, records each
        ``decision_stride``-th.  The counter advances on both dispatch
        modes' call sites in lockstep, so the sampled set is identical."""
        n = self._n_dec
        self._n_dec = n + 1
        return n % self._stride == 0

    def decision(self, kind, request_id, prefill_id, win, run,
                 tier_win, tier_run, congestion,
                 cost_win, cost_run, cache_win, cache_run,
                 load_win, load_run, xfer_win, xfer_run) -> None:
        self._dec.append((
            float(self.now), kind, int(request_id), int(prefill_id),
            int(win), int(run), int(tier_win), int(tier_run),
            float(congestion),
            float(cost_win), float(cost_run), float(cache_win),
            float(cache_run), float(load_win), float(load_run),
            float(xfer_win), float(xfer_run),
        ))

    # ------------------------------------------------------------------
    # finalisation + views

    def finalize(self, records) -> None:
        """Derive whole-phase lifecycle spans from ``RequestState`` rows.

        The endpoint timestamps are the same fields the parity suites
        already assert bit-equal across engines, so derived spans are
        parity-free by construction."""
        for rs in records:
            rid = rs.req.request_id
            arr = rs.req.arrival
            if rs.prefill_start >= 0.0:
                self.span("queue", rid, arr, rs.prefill_start,
                          rs.prefill_instance)
                if rs.prefill_end >= rs.prefill_start:
                    self.span("prefill", rid, rs.prefill_start,
                              rs.prefill_end, rs.prefill_instance)
            if rs.transfer_end >= 0.0 and rs.prefill_end >= 0.0:
                self.span("xfer", rid, rs.prefill_end, rs.transfer_end,
                          rs.decode_instance, tier=rs.tier, a=rs.s_eff)
                if rs.admit_time >= 0.0:
                    self.span("admit_wait", rid, rs.transfer_end,
                              rs.admit_time, rs.decode_instance,
                              tier=rs.tier)
            if rs.first_token >= 0.0 and rs.admit_time >= 0.0:
                self.span("first_iter", rid, rs.admit_time, rs.first_token,
                          rs.decode_instance)
            if rs.finish >= 0.0 and rs.first_token >= 0.0:
                self.span("decode", rid, rs.first_token, rs.finish,
                          rs.decode_instance, a=float(rs.tokens_out))

    def spans(self) -> list[tuple]:
        """Canonical span list (insertion order) for parity asserts."""
        return list(zip(self.s_kind, self.s_req, self.s_t0, self.s_t1,
                        self.s_inst, self.s_tier, self.s_a, self.s_b))

    def forensics_rows(self) -> list[tuple]:
        return list(self._dec)

    def columns(self) -> dict[str, np.ndarray]:
        """Span columns as arrays (the struct-of-arrays view)."""
        return {
            "kind": np.asarray(self.s_kind, dtype=object),
            "req": np.asarray(self.s_req, dtype=np.int64),
            "t0": np.asarray(self.s_t0, dtype=np.float64),
            "t1": np.asarray(self.s_t1, dtype=np.float64),
            "inst": np.asarray(self.s_inst, dtype=np.int64),
            "tier": np.asarray(self.s_tier, dtype=np.int64),
            "a": np.asarray(self.s_a, dtype=np.float64),
            "b": np.asarray(self.s_b, dtype=np.float64),
        }

    # ------------------------------------------------------------------
    # exporters

    def to_chrome_events(self, pid: int = 1, label: str = "run") -> list[dict]:
        """Chrome/Perfetto trace-event list: ``ph:"X"`` duration slices
        on one track (tid) per instance, decisions as ``ph:"i"`` instants
        on the scheduler track (tid 0).  ts/dur are sim-microseconds."""
        ev: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": label},
        }, {
            "name": "thread_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "scheduler"},
        }]
        named: set[int] = set()
        for kind, req, t0, t1, inst, tier, a, b in self.spans():
            tid = 0 if inst < 0 else int(inst) + 1
            if tid not in named and tid != 0:
                named.add(tid)
                side = "prefill" if kind in _PREFILL_TRACK else "decode"
                ev.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": f"{side} {inst}"}})
            ev.append({
                "name": kind, "cat": "lifecycle", "ph": "X", "pid": pid,
                "tid": tid, "ts": t0 * 1e6, "dur": max(0.0, t1 - t0) * 1e6,
                "args": {"req": req, "tier": tier, "a": a, "b": b},
            })
        for row in self._dec:
            args = dict(zip(FORENSICS_COLUMNS, row))
            ev.append({
                "name": f"select:{row[1]}", "cat": "decision", "ph": "i",
                "pid": pid, "tid": 0, "ts": row[0] * 1e6, "s": "t",
                "args": args,
            })
        return ev


# ----------------------------------------------------------------------
# TTFT attribution (records -> per-phase shares; NaN-safe)


def ttft_attribution(records, window) -> dict[str, float]:
    """Per-phase TTFT attribution over the measurement window.

    Returns means and p95s of queue wait (arrival -> prefill start),
    prefill, admit wait (last KV byte -> batch admission) and the
    transfer *share* of TTFT.  NaN-safe on degenerate windows per the
    ``summarize`` contract (empty -> NaN columns)."""
    lo, hi = window
    done = [r for r in records
            if lo <= r.req.arrival < hi and not r.rejected
            and r.first_token >= 0.0]
    qw = [r.prefill_start - r.req.arrival for r in done
          if r.prefill_start >= 0.0]
    pf = [r.prefill_end - r.prefill_start for r in done
          if r.prefill_end >= 0.0 and r.prefill_start >= 0.0]
    aw = [r.admit_time - r.transfer_end for r in done
          if r.admit_time >= 0.0 and r.transfer_end >= 0.0]
    xs = [(r.transfer_end - r.prefill_end) / r.ttft for r in done
          if r.transfer_end >= 0.0 and r.prefill_end >= 0.0 and r.ttft > 0.0]
    return {
        "queue_wait_mean": _mean(qw), "queue_wait_p95": _pct(qw, 95),
        "prefill_mean": _mean(pf), "prefill_p95": _pct(pf, 95),
        "admit_wait_mean": _mean(aw), "admit_wait_p95": _pct(aw, 95),
        "xfer_share_mean": _mean(xs), "xfer_share_p95": _pct(xs, 95),
    }


def ttft_breakdown_rows(records, run: str = "") -> list[dict]:
    """One ``ttft_breakdown.csv`` row per finished request."""
    rows = []
    for rs in records:
        if rs.first_token < 0.0:
            continue
        arr = rs.req.arrival
        qw = rs.prefill_start - arr if rs.prefill_start >= 0.0 else float("nan")
        pf = (rs.prefill_end - rs.prefill_start
              if rs.prefill_end >= 0.0 and rs.prefill_start >= 0.0
              else float("nan"))
        xf = (rs.transfer_end - rs.prefill_end
              if rs.transfer_end >= 0.0 and rs.prefill_end >= 0.0
              else float("nan"))
        aw = (rs.admit_time - rs.transfer_end
              if rs.admit_time >= 0.0 and rs.transfer_end >= 0.0
              else float("nan"))
        fi = (rs.first_token - rs.admit_time
              if rs.admit_time >= 0.0 else float("nan"))
        ttft = rs.ttft
        rows.append({
            "run": run, "request_id": rs.req.request_id, "arrival": arr,
            "queue_wait": qw, "prefill": pf, "xfer": xf, "admit_wait": aw,
            "first_iter": fi, "ttft": ttft,
            "xfer_share": xf / ttft if ttft > 0.0 else float("nan"),
            "tier": rs.tier, "prefill_instance": rs.prefill_instance,
            "decode_instance": rs.decode_instance,
            "hit_tokens": rs.hit_tokens, "requeues": rs.requeues,
        })
    return rows


# ----------------------------------------------------------------------
# process-wide session (benchmark aggregation)


class TraceSession:
    """Aggregates the traces of every ``Simulation`` run while active.

    ``Simulation`` auto-enables its ``TracePlane`` and registers
    ``(label, trace, records)`` here at the end of ``run()``.  Harnesses
    set ``context`` so arms are distinguishable; gates that must measure
    traced-off throughput set ``paused`` around their arms."""

    def __init__(self):
        self.runs: list[tuple[str, TracePlane, list]] = []
        self.context = ""
        self.paused = False

    @property
    def n_runs(self) -> int:
        return len(self.runs)

    def register(self, scheduler: str, trace: TracePlane, records) -> None:
        prefix = f"{self.context}/" if self.context else ""
        self.runs.append((f"{prefix}{scheduler}#{len(self.runs)}",
                          trace, records))

    def write(self, out_dir, max_chrome: int = 4) -> list[str]:
        """Write ``trace.json`` (first ``max_chrome`` runs, one pid each)
        and ``ttft_breakdown.csv`` (all runs).  Returns written paths."""
        os.makedirs(out_dir, exist_ok=True)
        events: list[dict] = []
        for pid, (label, trace, _records) in enumerate(
                self.runs[:max_chrome], start=1):
            events.extend(trace.to_chrome_events(pid=pid, label=label))
        jpath = os.path.join(out_dir, "trace.json")
        with open(jpath, "w") as fh:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)
        cpath = os.path.join(out_dir, "ttft_breakdown.csv")
        with open(cpath, "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=list(BREAKDOWN_COLUMNS))
            w.writeheader()
            for label, _trace, records in self.runs:
                for row in ttft_breakdown_rows(records, run=label):
                    w.writerow(row)
        return [jpath, cpath]


_SESSION: TraceSession | None = None


def enable_tracing(on: bool = True) -> TraceSession | None:
    """Start (or stop) a process-wide trace session; returns it."""
    global _SESSION
    _SESSION = TraceSession() if on else None
    return _SESSION


def trace_session() -> TraceSession | None:
    """The active session, or None (paused sessions count as inactive)."""
    if _SESSION is not None and _SESSION.paused:
        return None
    return _SESSION
