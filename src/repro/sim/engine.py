"""Deterministic discrete-event engines: heap-based ``EventLoop`` (the
bit-exact reference) and the typed-lane, horizon-batched ``EventPlane``.

Both engines expose one **lane API** so client code is engine-agnostic:

* ``at``/``after``/``cancel`` — the classic per-event interface (the
  *generic* lane; callers may tag a lane for telemetry).
* ``load_cursor(lane, times, payloads, handler)`` — bulk-load a presorted
  event stream (trace arrivals, fault/rewire schedules).  On the plane the
  lane becomes an array cursor: no heap entries, no closures.
* ``arm(lane, time, fn)`` / ``disarm(lane)`` — single-slot re-armable
  timers (net completion, net tick, the instance-iteration clock).  With
  ``dedupe=True`` re-arming at the unchanged requested time is a no-op
  that draws no sequence number — exactly the short-circuit the clock's
  old cancel/re-add path performed.
* ``arm_slot(lane, idx, time, fn)`` — per-index one-shot timers
  (prefill/chunk iteration finish); ``fn(idx, now)`` at fire time.
* ``lane_horizon(lane)`` / ``lane_tick`` / ``lane_ticks`` — the horizon
  batching hooks: a cohort handler dispatched from lane L may keep
  processing its own future work up to the earliest event pending on any
  *other* lane (or the run's ``until``), reporting the work it absorbed so
  ``processed`` counts and the event-order trace stay comparable.

**Sequence parity.**  Every enqueue draws one monotone sequence number in
API-call order on both engines, and ties on time break by sequence — so
two engines driven through the identical call sequence dispatch pending
events in the identical relative order.  ``tests/test_eventplane_parity``
and the hypothesis property test in ``tests/test_engine.py`` enforce this,
including same-timestamp cohorts, cancellations and the backwards-rounding
``at()`` clamp.

**Event-order trace.**  Setting ``loop.trace_log = []`` records one
``(time, lane)`` entry per dispatched event.  Horizon-batched cohort steps
buffer their entries and flush them time-sorted with same-time entries
merged — matching the reference engine, which pops one heap event per
same-timestamp cohort.
"""

from __future__ import annotations

import heapq
import itertools
import time as _time
from typing import Callable, Sequence

# ---------------------------------------------------------------- lanes
LANE_GENERIC = 0   # plain at()/after() events (completions, timers, ...)
LANE_ARRIVAL = 1   # trace arrivals (cursor)
LANE_FAULT = 2     # fault schedule (cursor)
LANE_REWIRE = 3    # OCS rewire schedule (cursor)
LANE_NET = 4       # next flow-completion timer (slot)
LANE_TICK = 5      # fixed-interval network rate refresh (slot)
LANE_CLOCK = 6     # instance-iteration cohort clock (slot, horizon-batched)
LANE_ROLE = 7      # RolePlane P:D imbalance controller timer (slot)
LANE_PREFILL = 8   # per-instance prefill/chunk iteration timers (multi-slot)
N_LANES = 9
LANE_NAMES = ("generic", "arrival", "fault", "rewire", "net", "tick",
              "clock", "role", "prefill")

_CURSOR_LANES = (LANE_ARRIVAL, LANE_FAULT, LANE_REWIRE)
_SLOT_LANES = (LANE_NET, LANE_TICK, LANE_CLOCK, LANE_ROLE)

_INF = float("inf")

# ------------------------------------------------------------- profiling


class ProfileSession:
    """Per-lane / per-handler cumulative dispatch time for one profiled run.

    Each event loop binds the session active at its construction, so
    back-to-back benchmark arms in one process each debit their own
    session — ``select``-lane credit cannot leak across runs the way the
    old module-global accumulator allowed.  ``rows``: (lane name,
    handler qualname) -> [count, seconds]; the select accumulator lets
    run loops debit scheduler-select time from the owning handler's row
    and credit a dedicated ("select", ...) row instead."""

    __slots__ = ("rows", "select_s", "select_n")

    def __init__(self) -> None:
        self.rows: dict[tuple[str, str], list] = {}
        self.select_s = 0.0
        self.select_n = 0

    def note_select(self, seconds: float, name: str = "scheduler.select") -> None:
        self.select_s += seconds
        self.select_n += 1
        key = ("select", name)
        ent = self.rows.get(key)
        if ent is None:
            self.rows[key] = [1, seconds]
        else:
            ent[0] += 1
            ent[1] += seconds

    def add(self, lane: str, handler: str, dt: float) -> None:
        key = (lane, handler)
        ent = self.rows.get(key)
        if ent is None:
            self.rows[key] = [1, dt]
        else:
            ent[0] += 1
            ent[1] += dt

    def profile_rows(self) -> list[dict]:
        rows = [
            dict(lane=lane, handler=handler, events=cnt, seconds=sec,
                 us_per_event=sec / cnt * 1e6 if cnt else 0.0)
            for (lane, handler), (cnt, sec) in self.rows.items()
        ]
        rows.sort(key=lambda r: -r["seconds"])
        return rows


# The session new loops bind (``benchmarks/run.py --profile`` enables one
# for the whole process; tests create scoped ones per run).
_CURRENT: ProfileSession | None = None


def enable_profiling(on: bool = True) -> ProfileSession | None:
    """Start a fresh process-wide ProfileSession (or stop profiling).

    Returns the new session; loops constructed while it is current bind
    it for their lifetime, so re-enabling mid-process starts clean totals
    without retroactively crediting already-running loops."""
    global _CURRENT
    _CURRENT = ProfileSession() if on else None
    return _CURRENT


def note_select(seconds: float, name: str = "scheduler.select") -> None:
    """Report one scheduler-select's wall time to the current session.

    Compat shim — the simulator reports through its own loop's
    ``note_select`` so credit lands in the session that loop debits."""
    if _CURRENT is not None:
        _CURRENT.note_select(seconds, name)


def profile_rows() -> list[dict]:
    """Current session's dispatch profile as CSV-ready rows (slowest first)."""
    if _CURRENT is None:
        return []
    return _CURRENT.profile_rows()


def _handler_name(fn) -> str:
    return getattr(fn, "__qualname__", None) or repr(fn)


class Event:
    __slots__ = ("time", "seq", "fn", "cancelled", "lane",
                 "slot_idx", "slot_fn")

    def __init__(self, time: float, seq: int, fn: Callable[[float], None],
                 lane: int = LANE_GENERIC):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.cancelled = False
        self.lane = lane
        # arm_slot() wraps the handler in a closure; drain_due() needs the
        # raw (idx, fn) pair to recognise same-handler events, so arm_slot
        # records them here.  None for every other enqueue path.
        self.slot_idx = None
        self.slot_fn = None

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventLoop:
    """Single-heap engine: one entry per event, lazy cancellation.

    Kept as the bit-exact parity oracle (``SimConfig.event_engine=
    "reference"``); the lane methods below translate one-for-one into the
    same ``at``/``cancel`` sequences the pre-lane call sites performed, so
    the heap sees identical (time, seq) streams.
    """

    batched = False   # no horizon batching: lane_horizon() yields nothing

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.processed = 0
        self._live = 0  # pending non-cancelled events (O(1) empty())
        self.profile = _CURRENT  # ProfileSession bound for this loop's life
        # Single-slot lanes: lane -> (requested_time, Event).  The event is
        # consumed in-place by run() (cancelled=True), so arm() after a
        # fire re-arms without a cancel — the behaviour the old per-site
        # ``self._net_event = None`` bookkeeping implemented by hand.
        self._slots: list[tuple[float, Event] | None] = [None] * N_LANES
        self.trace_log: list[tuple[float, int]] | None = None

    def at(self, time: float, fn: Callable[[float], None],
           lane: int = LANE_GENERIC) -> Event:
        if time < self.now - 1e-12:
            time = self.now  # clamp: callbacks may round slightly backwards
        ev = Event(max(time, self.now), next(self._seq), fn, lane)
        heapq.heappush(self._heap, ev)
        self._live += 1
        return ev

    def after(self, delay: float, fn: Callable[[float], None],
              lane: int = LANE_GENERIC) -> Event:
        return self.at(self.now + max(delay, 0.0), fn, lane)

    def cancel(self, ev: Event) -> None:
        if not ev.cancelled:
            ev.cancelled = True
            self._live -= 1
            # Heap hygiene: cancelled events linger until popped (lazy
            # deletion), so a cancel-heavy drive (fault/rewire churn
            # re-arming completion timers) can balloon the heap with
            # corpses.  Compact when they outnumber the live entries.
            heap = self._heap
            if len(heap) > 64 and len(heap) - self._live > self._live:
                self._heap = [e for e in heap if not e.cancelled]
                heapq.heapify(self._heap)

    # ------------------------------------------------------------ lane API
    def load_cursor(self, lane: int, times: Sequence[float], payloads,
                    handler) -> None:
        """Bulk-load a schedule; ``handler(payload, now)`` per entry.

        Equivalent to the in-order ``at()`` loop the call sites used to
        run — one sequence number per entry, same clamping.
        """
        for t, p in zip(times, payloads):
            self.at(t, (lambda now, p=p, h=handler: h(p, now)), lane=lane)

    def arm(self, lane: int, time: float, fn, dedupe: bool = False) -> None:
        slot = self._slots[lane]
        if slot is not None and not slot[1].cancelled:
            if dedupe and time == slot[0]:
                return          # unchanged deadline: draw no sequence number
            self.cancel(slot[1])
        self._slots[lane] = (time, self.at(time, fn, lane=lane))

    def disarm(self, lane: int) -> None:
        slot = self._slots[lane]
        if slot is not None:
            self._slots[lane] = None
            self.cancel(slot[1])

    def arm_slot(self, lane: int, idx: int, time: float, fn) -> None:
        """Per-index one-shot timer; never cancelled (handlers guard)."""
        ev = self.at(time, (lambda now, i=idx, f=fn: f(i, now)), lane=lane)
        ev.slot_idx = idx
        ev.slot_fn = fn

    def drain_due(self, lane: int, fn) -> list[int]:
        """Pop every next-in-order ``arm_slot`` event due right now.

        Collects the contiguous run of heap heads that fire at ``now`` on
        ``lane`` with handler ``fn`` — exactly the events ``run()`` would
        dispatch back-to-back next — and consumes them (processed counts,
        trace entries) so the caller can handle the whole same-timestamp
        cohort in one pass.  Stops at the first non-matching head: an
        interleaved event on another lane keeps its place in global order.
        """
        out: list[int] = []
        heap = self._heap
        while heap:
            ev = heap[0]
            if ev.cancelled:
                heapq.heappop(heap)
                continue
            # Equality, not identity: handlers are bound methods, and each
            # attribute access creates a fresh bound-method object.
            if ev.time != self.now or ev.lane != lane or ev.slot_fn != fn:
                break
            heapq.heappop(heap)
            ev.cancelled = True
            self._live -= 1
            self.processed += 1
            if self.trace_log is not None:
                self.trace_log.append((ev.time, ev.lane))
            out.append(ev.slot_idx)
        return out

    def lane_horizon(self, lane: int) -> float:
        return self.now     # batched is False: callers never batch on this

    def lane_tick(self, lane: int, time: float) -> None:
        self.processed += 1
        self.now = time
        if self.trace_log is not None:
            self.trace_log.append((time, lane))

    def lane_ticks(self, lane: int, count: int, times=None) -> None:
        self.processed += count
        if self.trace_log is not None and times:
            self.trace_log.extend((t, lane) for t in times)

    # ------------------------------------------------------------------ run
    def note_select(self, seconds: float, name: str = "scheduler.select") -> None:
        """Report one scheduler-select's wall time to this loop's session."""
        if self.profile is not None:
            self.profile.note_select(seconds, name)

    def run(self, until: float = float("inf"), max_events: int = 50_000_000) -> None:
        log = self.trace_log
        prof = self.profile
        while self._heap and self.processed < max_events:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if ev.time > until:
                heapq.heappush(self._heap, ev)  # put back (still live) for resume
                self.now = until
                return
            self.now = ev.time
            self.processed += 1
            self._live -= 1
            # Mark consumed: a late cancel() on an already-fired event (a
            # caller holding a stale reference) must be a no-op, not a second
            # _live decrement that would make empty() lie.
            ev.cancelled = True
            if log is not None:
                log.append((ev.time, ev.lane))
            if prof is None:
                ev.fn(self.now)
            else:
                t0 = _time.perf_counter()
                s0 = prof.select_s
                ev.fn(self.now)
                # Debit scheduler-select time reported via note_select():
                # it is credited to the dedicated ("select", ...) row, so
                # the owning handler's row shows event plumbing only.
                dt = _time.perf_counter() - t0 - (prof.select_s - s0)
                prof.add(LANE_NAMES[ev.lane], _handler_name(ev.fn), dt)
        if self._heap and self.processed >= max_events:
            raise RuntimeError("event budget exhausted — runaway simulation?")

    def empty(self) -> bool:
        """True when no live (non-cancelled) events are pending.

        Counter-based: the previous implementation linearly scanned the whole
        heap, and ``Simulation._net_tick`` calls this every 0.1 s of sim time.
        """
        return self._live == 0

    def next_time(self) -> float | None:
        """Fire time of the earliest live event (None when idle).

        Lazily pops cancelled heap heads so repeated peeks stay O(1)
        amortised; used by drivers that pace a simulation from outside
        (``benchmarks/decode_throughput.py``).
        """
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0].time if self._heap else None


class EventPlane:
    """Typed-lane engine: columnar cursors, O(1) slots, one small scan.

    Instead of one heap entry + closure per event, each lane keeps the
    cheapest structure its traffic allows:

    * **cursors** (arrivals, faults, rewires) — the schedule is known up
      front, so it lives as parallel time/payload arrays with a position
      cursor; enqueue cost is one bulk sort at load, pop cost is an index
      increment.
    * **slots** (net completion, net tick, iteration clock) — at most one
      pending event; re-arm overwrites in place, nothing is ever lazily
      cancelled.
    * **multi-slot** (prefill timers) — a lean tuple heap, no Event
      objects, no per-fire closures.
    * **generic** — a plain Event heap for everything else, with the same
      lazy-cancel + compaction hygiene as the reference loop.

    The run loop scans the eight lane heads for the minimum (time, seq) —
    a bounded Python scan that replaces heappop+heappush bookkeeping — and
    hands ``LANE_CLOCK`` dispatches a *horizon* (``lane_horizon``): the
    cohort handler may absorb all of its own future boundaries up to the
    earliest pending event on any other lane without bouncing through the
    engine (see ``InstancePlane._step``).
    """

    batched = True

    def __init__(self) -> None:
        self._seq = itertools.count()
        self.now = 0.0
        self.processed = 0
        self._live = 0
        self._until = _INF
        self.profile = _CURRENT  # ProfileSession bound for this loop's life
        # generic lane: Event heap + live-in-heap counter for compaction
        self._gen: list[Event] = []
        self._gen_live = 0
        # cursor lanes: parallel arrays + position (None until loaded)
        self._cur_t: list[list[float] | None] = [None] * N_LANES
        self._cur_seq: list[list[int] | None] = [None] * N_LANES
        self._cur_p: list[list | None] = [None] * N_LANES
        self._cur_fn: list[Callable | None] = [None] * N_LANES
        self._cur_pos: list[int] = [0] * N_LANES
        # single-slot lanes: (requested_time, eff_time, seq, fn)
        self._slot: list[tuple | None] = [None] * N_LANES
        # multi-slot lane (prefill): heap of (eff_time, seq, idx, fn)
        self._mslot: list[tuple] = []
        self.trace_log: list[tuple[float, int]] | None = None
        self._batch_buf: list[tuple[float, int]] = []

    # ------------------------------------------------------------- enqueue
    def at(self, time: float, fn: Callable[[float], None],
           lane: int = LANE_GENERIC) -> Event:
        now = self.now
        if time < now - 1e-12:
            time = now
        ev = Event(time if time > now else now, next(self._seq), fn, lane)
        heapq.heappush(self._gen, ev)
        self._live += 1
        self._gen_live += 1
        return ev

    def after(self, delay: float, fn: Callable[[float], None],
              lane: int = LANE_GENERIC) -> Event:
        return self.at(self.now + max(delay, 0.0), fn, lane)

    def cancel(self, ev: Event) -> None:
        if not ev.cancelled:
            ev.cancelled = True
            self._live -= 1
            self._gen_live -= 1
            gen = self._gen
            if len(gen) > 64 and len(gen) - self._gen_live > self._gen_live:
                self._gen = [e for e in gen if not e.cancelled]
                heapq.heapify(self._gen)

    def load_cursor(self, lane: int, times: Sequence[float], payloads,
                    handler) -> None:
        """Load a schedule as a sorted array cursor.

        Sequence numbers are drawn in input order and entries sorted by
        (clamped time, seq) — the dispatch order the reference loop's
        in-order ``at()`` calls produce, without any heap entries.
        """
        now = self.now
        seqs = [next(self._seq) for _ in times]
        eff = [t if t > now else now for t in times]
        order = sorted(range(len(seqs)), key=lambda i: (eff[i], seqs[i]))
        new_t = [eff[i] for i in order]
        new_s = [seqs[i] for i in order]
        new_p = [payloads[i] for i in order]
        pos = self._cur_pos[lane]
        old_t = self._cur_t[lane]
        if old_t is not None and pos < len(old_t):
            # Merge with an unconsumed earlier load (rare; keeps the API
            # total).  Old entries all predate the new seqs.
            new_t = old_t[pos:] + new_t
            new_s = self._cur_seq[lane][pos:] + new_s
            new_p = self._cur_p[lane][pos:] + new_p
            order = sorted(range(len(new_t)), key=lambda i: (new_t[i], new_s[i]))
            new_t = [new_t[i] for i in order]
            new_s = [new_s[i] for i in order]
            new_p = [new_p[i] for i in order]
        self._cur_t[lane] = new_t
        self._cur_seq[lane] = new_s
        self._cur_p[lane] = new_p
        self._cur_fn[lane] = handler
        self._cur_pos[lane] = 0
        self._live += len(seqs)

    def arm(self, lane: int, time: float, fn, dedupe: bool = False) -> None:
        slot = self._slot[lane]
        if slot is not None and dedupe and slot[0] == time:
            return              # unchanged deadline: draw no sequence number
        now = self.now
        eff = time if time > now else now
        self._slot[lane] = (time, eff, next(self._seq), fn)
        if slot is None:
            self._live += 1

    def disarm(self, lane: int) -> None:
        if self._slot[lane] is not None:
            self._slot[lane] = None
            self._live -= 1

    def arm_slot(self, lane: int, idx: int, time: float, fn) -> None:
        now = self.now
        eff = time if time > now else now
        heapq.heappush(self._mslot, (eff, next(self._seq), idx, fn))
        self._live += 1

    def _globally_next(self, t: float, seq: int) -> bool:
        """No event on any other lane precedes (t, seq) in dispatch order."""
        gen = self._gen
        while gen and gen[0].cancelled:
            heapq.heappop(gen)
        if gen and (gen[0].time, gen[0].seq) < (t, seq):
            return False
        for l in _CURSOR_LANES:
            ts = self._cur_t[l]
            if ts is not None:
                pos = self._cur_pos[l]
                if pos < len(ts) and (ts[pos], self._cur_seq[l][pos]) < (t, seq):
                    return False
        for l in _SLOT_LANES:
            slot = self._slot[l]
            if slot is not None and (slot[1], slot[2]) < (t, seq):
                return False
        return True

    def drain_due(self, lane: int, fn) -> list[int]:
        """Pop every next-in-order ``arm_slot`` event due right now.

        Multi-slot counterpart of :meth:`EventLoop.drain_due`: consumes the
        run of ``_mslot`` heads that fire at ``now`` with handler ``fn`` and
        are globally next (no pending event on any other lane ties in ahead
        of them by sequence), so the caller can batch the same-timestamp
        cohort.  Each drained event is counted and traced as if ``run()``
        had dispatched it.
        """
        out: list[int] = []
        ms = self._mslot
        while ms:
            m = ms[0]
            # Equality, not identity: bound-method handlers are fresh
            # objects at every attribute access.
            if m[0] != self.now or m[3] != fn \
                    or not self._globally_next(m[0], m[1]):
                break
            heapq.heappop(ms)
            self._live -= 1
            self.processed += 1
            if self.trace_log is not None:
                self.trace_log.append((m[0], lane))
            out.append(m[2])
        return out

    # ------------------------------------------------------ batching hooks
    def lane_horizon(self, lane: int) -> float:
        """Earliest pending time on any lane but ``lane`` (and ``until``).

        A cohort handler dispatched from ``lane`` may absorb all of its own
        work strictly below this time without changing global event order:
        nothing else can fire inside the window.
        """
        h = self._until
        gen = self._gen
        while gen and gen[0].cancelled:
            heapq.heappop(gen)
        if gen and gen[0].time < h:
            h = gen[0].time
        for l in _CURSOR_LANES:
            if l == lane:
                continue
            ts = self._cur_t[l]
            if ts is not None:
                pos = self._cur_pos[l]
                if pos < len(ts) and ts[pos] < h:
                    h = ts[pos]
        for l in _SLOT_LANES:
            if l == lane:
                continue
            slot = self._slot[l]
            if slot is not None and slot[1] < h:
                h = slot[1]
        if lane != LANE_PREFILL and self._mslot and self._mslot[0][0] < h:
            h = self._mslot[0][0]
        return h

    def lane_tick(self, lane: int, time: float) -> None:
        """One in-batch cohort step absorbed by a horizon-batched handler."""
        self.processed += 1
        self.now = time
        if self.trace_log is not None:
            self._batch_buf.append((time, lane))

    def lane_ticks(self, lane: int, count: int, times=None) -> None:
        """Bulk report of fused per-instance steps (see _fast_forward)."""
        self.processed += count
        if self.trace_log is not None and times:
            buf = self._batch_buf
            for t in times:
                buf.append((t, lane))

    def _flush_batch_log(self) -> None:
        """Order-restore the batch window's entries.

        Fused per-instance runs interleave in time with in-batch cohort
        steps; all of them land strictly inside the horizon window, so a
        sort puts them in global dispatch order and same-time entries merge
        into one — the reference pops one heap event per same-timestamp
        cohort.
        """
        buf = self._batch_buf
        buf.sort()
        log = self.trace_log
        last = None
        for entry in buf:
            if entry[0] != last:
                log.append(entry)
                last = entry[0]
        buf.clear()

    def note_select(self, seconds: float, name: str = "scheduler.select") -> None:
        """Report one scheduler-select's wall time to this loop's session."""
        if self.profile is not None:
            self.profile.note_select(seconds, name)

    # ------------------------------------------------------------------ run
    def run(self, until: float = float("inf"), max_events: int = 50_000_000) -> None:
        self._until = until
        gen = self._gen
        cur_t, cur_seq = self._cur_t, self._cur_seq
        cur_pos = self._cur_pos
        slots = self._slot
        ms = self._mslot
        log_on = self.trace_log is not None
        prof = self.profile
        while self.processed < max_events:
            while gen and gen[0].cancelled:
                heapq.heappop(gen)
            lane = -1
            best_t = _INF
            best_seq = 0
            if gen:
                ev = gen[0]
                best_t = ev.time
                best_seq = ev.seq
                lane = LANE_GENERIC
            for l in _CURSOR_LANES:
                ts = cur_t[l]
                if ts is not None:
                    pos = cur_pos[l]
                    if pos < len(ts):
                        t = ts[pos]
                        if t < best_t or (t == best_t and cur_seq[l][pos] < best_seq):
                            best_t = t
                            best_seq = cur_seq[l][pos]
                            lane = l
            for l in _SLOT_LANES:
                slot = slots[l]
                if slot is not None:
                    t = slot[1]
                    if t < best_t or (t == best_t and slot[2] < best_seq):
                        best_t = t
                        best_seq = slot[2]
                        lane = l
            if ms:
                m = ms[0]
                t = m[0]
                if t < best_t or (t == best_t and m[1] < best_seq):
                    best_t = t
                    lane = LANE_PREFILL
            if lane < 0:
                break                       # exhausted (now stays put)
            if best_t > until:
                self.now = until            # events stay pending for resume
                return
            self.now = best_t
            self.processed += 1
            self._live -= 1
            if log_on:
                self.trace_log.append((best_t, lane))
            if prof is not None:
                t0 = _time.perf_counter()
                s0 = prof.select_s
            if lane == LANE_GENERIC:
                ev = heapq.heappop(gen)
                ev.cancelled = True         # consumed: late cancel is a no-op
                self._gen_live -= 1
                fn = ev.fn
                fn(best_t)
            elif lane < LANE_NET:
                pos = cur_pos[lane]
                cur_pos[lane] = pos + 1
                fn = self._cur_fn[lane]
                fn(self._cur_p[lane][pos], best_t)
            elif lane < LANE_PREFILL:
                slot = slots[lane]
                slots[lane] = None
                fn = slot[3]
                fn(best_t)
            else:
                m = heapq.heappop(ms)
                fn = m[3]
                fn(m[2], best_t)
            if prof is not None:
                # Same select-time debit as the reference loop (see above).
                dt = _time.perf_counter() - t0 - (prof.select_s - s0)
                prof.add(LANE_NAMES[lane], _handler_name(fn), dt)
            if self._batch_buf:
                self._flush_batch_log()
        if self.processed >= max_events and self._pending():
            raise RuntimeError("event budget exhausted — runaway simulation?")

    def _pending(self) -> bool:
        if self._gen or self._mslot:
            return True
        for l in _CURSOR_LANES:
            ts = self._cur_t[l]
            if ts is not None and self._cur_pos[l] < len(ts):
                return True
        return any(self._slot[l] is not None for l in _SLOT_LANES)

    def empty(self) -> bool:
        return self._live == 0

    def next_time(self) -> float | None:
        gen = self._gen
        while gen and gen[0].cancelled:
            heapq.heappop(gen)
        t = _INF
        if gen:
            t = gen[0].time
        for l in _CURSOR_LANES:
            ts = self._cur_t[l]
            if ts is not None:
                pos = self._cur_pos[l]
                if pos < len(ts) and ts[pos] < t:
                    t = ts[pos]
        for l in _SLOT_LANES:
            slot = self._slot[l]
            if slot is not None and slot[1] < t:
                t = slot[1]
        if self._mslot and self._mslot[0][0] < t:
            t = self._mslot[0][0]
        return None if t == _INF else t


def make_event_loop(kind: str) -> EventLoop | EventPlane:
    if kind == "reference":
        return EventLoop()
    if kind == "plane":
        return EventPlane()
    raise ValueError(f"unknown event_engine {kind!r}")
