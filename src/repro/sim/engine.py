"""Minimal deterministic discrete-event engine (heap-based)."""

from __future__ import annotations

import heapq
import itertools
from typing import Callable


class Event:
    __slots__ = ("time", "seq", "fn", "cancelled")

    def __init__(self, time: float, seq: int, fn: Callable[[float], None]):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.cancelled = False

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventLoop:
    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.processed = 0
        self._live = 0  # pending non-cancelled events (O(1) empty())

    def at(self, time: float, fn: Callable[[float], None]) -> Event:
        if time < self.now - 1e-12:
            time = self.now  # clamp: callbacks may round slightly backwards
        ev = Event(max(time, self.now), next(self._seq), fn)
        heapq.heappush(self._heap, ev)
        self._live += 1
        return ev

    def after(self, delay: float, fn: Callable[[float], None]) -> Event:
        return self.at(self.now + max(delay, 0.0), fn)

    def cancel(self, ev: Event) -> None:
        if not ev.cancelled:
            ev.cancelled = True
            self._live -= 1

    def run(self, until: float = float("inf"), max_events: int = 50_000_000) -> None:
        while self._heap and self.processed < max_events:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if ev.time > until:
                heapq.heappush(self._heap, ev)  # put back (still live) for resume
                self.now = until
                return
            self.now = ev.time
            self.processed += 1
            self._live -= 1
            # Mark consumed: a late cancel() on an already-fired event (a
            # caller holding a stale reference) must be a no-op, not a second
            # _live decrement that would make empty() lie.
            ev.cancelled = True
            ev.fn(self.now)
        if self._heap and self.processed >= max_events:
            raise RuntimeError("event budget exhausted — runaway simulation?")

    def empty(self) -> bool:
        """True when no live (non-cancelled) events are pending.

        Counter-based: the previous implementation linearly scanned the whole
        heap, and ``Simulation._net_tick`` calls this every 0.1 s of sim time.
        """
        return self._live == 0

    def next_time(self) -> float | None:
        """Fire time of the earliest live event (None when idle).

        Lazily pops cancelled heap heads so repeated peeks stay O(1)
        amortised; used by drivers that pace a simulation from outside
        (``benchmarks/decode_throughput.py``).
        """
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0].time if self._heap else None
