"""End-to-end disaggregated-serving simulation (§VI-A/B).

Wires trace -> prefill pool -> scheduler (decode-instance selection) ->
flow-level network transfer -> continuous-batching decode -> metrics.

Scheduler decisions use only scheduler-visible state: per-instance compute
metrics refreshed at each scheduling event and oracle-provided network
metrics refreshed every Delta_oracle seconds; the scheduler cannot observe
per-flow network state or future arrivals.

The instance layer is pluggable (``SimConfig.instance_engine``):

* ``"plane"`` (default) — the columnar ``InstancePlane`` with one
  cohort-stepped iteration clock and the array-backed RadixPlane cache.
* ``"reference"`` — the retired per-object ``PrefillSim``/``DecodeSim``
  engine (``sim/reference.py``), kept as the bit-exact parity oracle and
  benchmark baseline.

Admission is **epoch-batched**: every transfer completion the FlowPlane
pops at one net instant is enqueued first, then each touched decode
instance is kicked exactly once — so same-instant landings on an idle
instance join the same first iteration, and the network sees one
``_reschedule_net`` per epoch.  Window-batched scheduling (netkv-batch)
similarly opens a FlowPlane *arrival epoch* around its dispatch burst: all
transfers start, then one union dirty-component rate recompute runs
(bit-identical rates; see ``FlowPlane.begin_epoch``).
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Sequence

import numpy as np

from repro.core.cost import (
    H100_TP4_ITER,
    H100_TP4_PREFILL,
    IterTimeModel,
    LLAMA3_70B_KV,
    ModelKVSpec,
    PrefillTimeModel,
)
from repro.core.dispatch import CohortItem, supports_cohort
from repro.core.oracle import NetworkCostOracle, SelfContentionTracker
from repro.core.schedulers import RequestInfo, make_scheduler
from repro.core.batch_assign import NetKVBatch
from repro.core.multihop import NetKVMultiHop, StagingStore
from repro.core.view import ClusterView, ROLE_DECODE, ROLE_PREFILL
from repro.cluster.network import BackgroundTraffic, FlowPlane, Transfer
from repro.cluster.topology import FatTree, make_instances
from repro.traces.mooncake import Request
from .engine import (
    LANE_ARRIVAL,
    LANE_FAULT,
    LANE_NET,
    LANE_REWIRE,
    LANE_ROLE,
    LANE_TICK,
    make_event_loop,
)
from .instances import InstancePlane, RequestState
from .metrics import RunMetrics, summarize
from .reference import ReferenceInstanceEngine
from .trace import TracePlane, trace_session


@dataclasses.dataclass
class FaultEvent:
    time: float
    # "kill_decode" | "add_decode" | "slowdown" | "kill_prefill" | "add_prefill"
    kind: str
    instance_id: int = -1
    factor: float = 2.0  # slowdown factor
    detection_delay: float = 0.25


@dataclasses.dataclass
class RewireEvent:
    """Scheduled OCS reconfiguration: swap tier capacities at ``time``.

    ``tier_bandwidth`` sets absolute per-tier bytes/s; ``scale`` multiplies
    the current values (both partial maps; ``scale`` applies after).  The
    swap is atomic at ``time``: the FlowPlane re-water-fills every in-flight
    flow immediately, while the scheduler keeps routing on the oracle's
    pre-rewire snapshot until the next oracle refresh.
    """

    time: float
    tier_bandwidth: dict | None = None
    scale: dict | None = None


@dataclasses.dataclass
class SimConfig:
    scheduler: str = "netkv-full"
    scheduler_kwargs: dict = dataclasses.field(default_factory=dict)
    # topology
    n_pods: int = 2
    racks_per_pod: int = 2
    servers_per_rack: int = 2
    gpus_per_server: int = 8
    tier_bandwidth: dict | None = None
    tier_latency: dict | None = None
    n_tor_uplinks: int = 8
    n_agg_uplinks: int = 8
    nics_per_server: int = 1
    nic_policy: str = "hash"                # "hash" | "least-loaded" | "rail-affine"
    # instances
    tp: int = 4
    n_prefill: int = 4
    beta_max: int = 64
    hbm_free_per_gpu: float = 45e9          # §VI-A: 45 GB free HBM per GPU
    kv_spec: ModelKVSpec = LLAMA3_70B_KV
    iter_model: IterTimeModel = H100_TP4_ITER
    prefill_model: PrefillTimeModel = H100_TP4_PREFILL
    m_min: float = 2e9
    instance_engine: str = "plane"          # "plane" | "reference"
    # chunked prefill (ChunkPlane): None = serial whole-request prefill
    # (bit-exact legacy model); an int enables chunk-interleaved prefill
    # with that chunk size.
    chunk_tokens: int | None = None
    prefill_token_budget: int | None = None  # tokens per prefill iteration
    # Stream completed chunks into the network while later chunks still
    # prefill: the decode instance is selected at *first* chunk readiness
    # and each chunk's KV bytes enter the FlowPlane as it completes; decode
    # admission still waits for the last byte.  Requires chunk_tokens.
    kv_streaming: bool = False
    # oracle / network
    oracle_refresh: float = 1.0
    telemetry_source: str = "model"         # "model" | "measured"
    background: float | dict = 0.0
    bg_wander: float = 0.25
    inflight_cap: int = 16
    # run windows
    warmup: float = 5.0
    measure: float = 15.0
    seed: int = 0
    # faults / elasticity / topology dynamics
    faults: Sequence[FaultEvent] = ()
    rewires: Sequence[RewireEvent] = ()     # OCS capacity timeline
    # Rewire notifications: when True every RewireEvent also forces an
    # out-of-band oracle refresh at the reconfiguration instant, so the
    # scheduler prices the new capacities immediately instead of riding
    # the stale snapshot until the periodic refresh (exp9's
    # notified-vs-stale arms).
    notify_rewires: bool = False
    net_tick: float = 0.1                   # rate refresh for wandering bg
    # "auto" elides the fixed-interval net tick while background traffic is
    # piecewise-constant AND no flow is in the air — ticks that are provably
    # no-ops — re-arming on the preserved tick grid when a transfer starts.
    # "always" keeps every tick (the pre-EventPlane behaviour; outcomes are
    # identical either way).
    net_tick_mode: str = "auto"             # "auto" | "always"
    event_engine: str = "plane"             # "plane" | "reference"
    # DispatchPlane: "plane" batches every same-timestamp cohort of
    # dispatch-ready requests through one fused R x D selection
    # (core/dispatch.py — bit-exact vs the per-request path, including the
    # RNG tie-break stream); "reference" keeps one Scheduler.select call
    # per request.  "plane" silently degrades to per-request selection for
    # schedulers without a cohort path (netkv-batch, netkv-multihop), the
    # reference instance engine, or a zero oracle refresh interval (where
    # each sequential select would legitimately observe fresher telemetry).
    dispatch_mode: str = "plane"            # "plane" | "reference"
    staging_capacity: float = 512e9         # per-pod DRAM KV store (multihop)
    # TracePlane (sim/trace.py): lifecycle spans + decision forensics.
    # Off by default — no span allocation, no hook calls on the hot path.
    # Also auto-enabled for the run when a process-wide TraceSession is
    # active (benchmarks/run.py --trace).
    trace: bool = False
    trace_decisions: int = 1                # record every Nth decision
    # RolePlane: prefill deflection.  When the healthy prefill pool's
    # backlog (earliest drain ETA minus now) exceeds deflect_threshold
    # seconds, an arriving request is offered to the decode instances as a
    # *prefill* target first: Eq. (4) collapses to a zero-transfer KV term
    # (the KV is born on the decode host: s_eff = 0, tier 0) plus the
    # host's deflected-chunk-queue drain ETA.  Requires the plane instance
    # engine and chunk_tokens (deflected prefill is metered by an
    # attachable ChunkPlane over the decode slots).
    deflection: str = "off"                 # "off" | "on"
    deflect_threshold: float = 0.5          # seconds of prefill backlog
    # RolePlane: dynamic P:D flipping — a slow control loop on LANE_ROLE
    # samples prefill backlog every role_flip_interval seconds (0 = off)
    # and, after role_flip_sustain consecutive samples beyond a bound,
    # converts ONE drained instance: decode -> prefill above role_flip_hi,
    # the most recent convert back below role_flip_lo.  Pool floors
    # (min_prefill / min_decode healthy instances) are never crossed.
    role_flip_interval: float = 0.0
    role_flip_sustain: int = 3
    role_flip_hi: float = 0.75
    role_flip_lo: float = 0.15
    min_prefill: int = 1
    min_decode: int = 1
    # ChunkPlane auto-tuning: adapt chunk_tokens (and a 4x token budget) to
    # the observed arrival input-length EWMA.  Requires chunk_tokens; driven
    # by the arrival stream alone, so both instance engines see identical
    # retune sequences (parity-safe).
    chunk_autotune: bool = False


class Simulation:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.loop = make_event_loop(cfg.event_engine)
        self.tree = FatTree(
            cfg.n_pods, cfg.racks_per_pod, cfg.servers_per_rack, cfg.gpus_per_server,
            tier_bandwidth=cfg.tier_bandwidth, tier_latency=cfg.tier_latency,
            n_tor_uplinks=cfg.n_tor_uplinks, n_agg_uplinks=cfg.n_agg_uplinks,
            nics_per_server=cfg.nics_per_server,
        )
        bg = cfg.background
        self.bg = bg if isinstance(bg, BackgroundTraffic) else BackgroundTraffic(
            bg, wander=cfg.bg_wander, seed=cfg.seed
        )
        self.net = FlowPlane(self.tree, self.bg, seed=cfg.seed,
                             nic_policy=cfg.nic_policy)
        pre_meta, dec_meta = make_instances(self.tree, tp=cfg.tp, n_prefill=cfg.n_prefill)
        kv_budget = cfg.hbm_free_per_gpu * cfg.tp
        self._server_of = {
            i.instance_id: i.server for i in (*pre_meta, *dec_meta)
        }
        # Columnar scheduler-visible state plane, maintained incrementally by
        # the instance engine (write-through), never rebuilt per request.
        self.view = ClusterView(
            tier_fn=lambda a, b: self.tree.tier(self._server_of[a], self._server_of[b]),
            capacity=max(len(dec_meta), 1),
        )
        if cfg.kv_streaming:
            if cfg.chunk_tokens is None:
                raise ValueError("kv_streaming requires chunk_tokens")
            if cfg.scheduler == "netkv-multihop":
                raise ValueError("kv_streaming does not compose with the "
                                 "staged multihop scheduler")
            if cfg.scheduler == "netkv-batch":
                # Streamed requests are committed per-request at first-chunk
                # readiness; silently running the windowed joint assigner in
                # that mode would degrade it to greedy select() under its
                # own name.  Refuse until a first-chunk-keyed window exists
                # (ROADMAP: streaming-aware batch window).
                raise ValueError("kv_streaming does not compose with the "
                                 "windowed netkv-batch scheduler yet")
        eng_kw = dict(view=self.view, loop=self.loop, iter_model=cfg.iter_model,
                      prefill_model=cfg.prefill_model, beta_max=cfg.beta_max,
                      kv_spec=cfg.kv_spec, kv_budget=kv_budget,
                      chunk_tokens=cfg.chunk_tokens,
                      prefill_token_budget=cfg.prefill_token_budget)
        if cfg.instance_engine == "reference":
            self.engine = ReferenceInstanceEngine(pre_meta, dec_meta, **eng_kw)
        elif cfg.instance_engine == "plane":
            self.engine = InstancePlane(pre_meta, dec_meta, **eng_kw)
        else:
            raise ValueError(f"unknown instance_engine {cfg.instance_engine!r}")
        self.prefill = self.engine.prefill
        self.decode = self.engine.decode
        # topology= wires the static B_tau/L_tau maps to the live tree, so
        # rewires surface at the next oracle refresh (not before).
        self.oracle = NetworkCostOracle(
            tier_of=lambda a, b: self.tree.tier(self._server_of[a], self._server_of[b]),
            topology=self.tree,
            telemetry_fn=lambda now: self.net.tier_congestion(now),
            measured_fn=lambda now: self.net.measured_tier_congestion(now),
            source=cfg.telemetry_source,
            refresh_interval=cfg.oracle_refresh,
        )
        self.inflight = SelfContentionTracker(cap=cfg.inflight_cap)
        if cfg.scheduler == "netkv-multihop":
            # Beyond paper (§VII-D): one CPU-DRAM staging store per pod,
            # hosted on the last rack's first server.
            stores = []
            for pod in range(cfg.n_pods):
                node_id = 1000 + pod
                self._extra_servers = getattr(self, "_extra_servers", {})
                srv = (pod, cfg.racks_per_pod - 1, 0)
                stores.append(StagingStore(
                    node_id,
                    capacity_bytes=cfg.staging_capacity,
                    bytes_per_block=16 * cfg.kv_spec.kv_bytes_per_token or 1.0,
                ))
                self._server_of[node_id] = srv
            self.sched = NetKVMultiHop(
                cfg.iter_model, cfg.beta_max, m_min=cfg.m_min, stores=stores,
                **cfg.scheduler_kwargs,
            )
        else:
            self.sched = make_scheduler(
                cfg.scheduler, cfg.iter_model, cfg.beta_max, m_min=cfg.m_min,
                **cfg.scheduler_kwargs,
            )
        self.records: list[RequestState] = []
        self.rejected = 0
        self.decision_latencies: list[float] = []
        # Net-tick elision state: _tick_next replays the exact float grid
        # the old after()-chain produced (sequential now + net_tick adds);
        # _tick_idle means the chain is dormant and must be woken by the
        # next network activity.
        self._tick_next = 0.0
        self._tick_idle = False
        self._net_tick_elidable = (cfg.net_tick_mode == "auto"
                                   and self.bg.is_static)
        self._batch_window: list[tuple[RequestState, int]] = []
        self._batch_timer = None
        self._inbound: dict[int, list] = {}   # decode id -> [(rs, transfer)]
        self._epoch: list | None = None       # landing buffer during net fire
        # Effective chunk granularity: the largest take a single iteration
        # can give one request (sizes the streamed-tail estimate).
        self._chunk_eff = None
        if cfg.chunk_tokens is not None:
            budget = cfg.prefill_token_budget or cfg.chunk_tokens
            self._chunk_eff = min(cfg.chunk_tokens, budget)
        self.engine.on_prefill_done = self._on_prefill_done
        if cfg.kv_streaming:
            self.engine.on_chunk_done = self._on_chunk_done
        if cfg.dispatch_mode not in ("plane", "reference"):
            raise ValueError(f"unknown dispatch_mode {cfg.dispatch_mode!r}")
        self._cohort_ok = (
            cfg.dispatch_mode == "plane"
            and isinstance(self.engine, InstancePlane)
            and supports_cohort(self.sched)
            and cfg.oracle_refresh > 0
        )
        if self._cohort_ok:
            self.engine.on_prefill_cohort = self._prefill_cohort
            if cfg.chunk_tokens is not None:
                self.engine.on_phase3_cohort = self._phase3_cohort
        self.engine.set_decode_callbacks(lambda rs, now: None,
                                         lambda rs, now: None)
        # TracePlane: created only when asked for — every emission site
        # below is behind an ``is not None`` guard, so the untraced hot
        # path costs one attribute load per site and allocates nothing.
        self.trace: TracePlane | None = None
        if cfg.trace or trace_session() is not None:
            self.trace = TracePlane(decision_stride=cfg.trace_decisions)
            self.engine.trace = self.trace
            self.sched.trace_hook = self.trace
            self.net.record_bottlenecks = True
        # RolePlane: deflection + P:D flip state.
        if cfg.deflection not in ("off", "on"):
            raise ValueError(f"unknown deflection {cfg.deflection!r}")
        self._deflect_on = cfg.deflection == "on"
        if self._deflect_on:
            if not isinstance(self.engine, InstancePlane):
                raise ValueError("deflection requires the plane instance engine")
            if cfg.chunk_tokens is None:
                raise ValueError("deflection requires chunk_tokens")
            if cfg.kv_streaming:
                # Deflected KV never crosses the wire, so there is nothing
                # to stream; refuse rather than silently mix the modes.
                raise ValueError("deflection does not compose with kv_streaming")
            self.engine.enable_deflection()
            self.engine.on_deflect_done = self._on_deflect_done
        self.deflected = 0
        self.role_flips = 0
        self._flipped: list[int] = []   # decode->prefill converts, flip-back LIFO
        self._hi_run = 0
        self._lo_run = 0
        if cfg.chunk_autotune and cfg.chunk_tokens is None:
            raise ValueError("chunk_autotune requires chunk_tokens")
        self._chunk_cur = cfg.chunk_tokens
        self._len_ewma = -1.0

    # ---------------------------------------------------------------- trace
    def load_trace(self, trace: Sequence[Request]) -> None:
        kv_bytes = self.cfg.kv_spec.kv_bytes
        arrivals: list[float] = []
        states: list[RequestState] = []
        for req in trace:
            rs = RequestState(req=req, kv_bytes=float(kv_bytes(req.input_len)))
            self.records.append(rs)
            arrivals.append(req.arrival)
            states.append(rs)
        # Whole schedules are known up front: bulk-load them as lane
        # cursors (presorted array + position on the plane engine; the
        # equivalent in-order at() sequence on the reference engine).
        self.loop.load_cursor(LANE_ARRIVAL, arrivals, states, self._on_arrival)
        faults = list(self.cfg.faults)
        if faults:
            self.loop.load_cursor(LANE_FAULT, [f.time for f in faults],
                                  faults, self._on_fault)
        rewires = list(self.cfg.rewires)
        if rewires:
            self.loop.load_cursor(LANE_REWIRE, [rw.time for rw in rewires],
                                  rewires, self._on_rewire)
        if self.cfg.net_tick > 0:
            self._tick_next = self.loop.now + self.cfg.net_tick
            self.loop.arm(LANE_TICK, self._tick_next, self._net_tick)
        if self.cfg.role_flip_interval > 0:
            self.loop.arm(LANE_ROLE, self.loop.now + self.cfg.role_flip_interval,
                          self._role_tick)

    # ------------------------------------------------------------ prefill side
    def _on_arrival(self, rs: RequestState, now: float) -> None:
        if self.cfg.chunk_autotune:
            self._autotune(rs.req.input_len)
        if self._deflect_on and \
                self.engine.prefill_backlog(now) > self.cfg.deflect_threshold:
            if self._deflect_one(rs, now):
                return
        target = self.engine.pick_prefill(now)
        if target is None:
            rs.rejected = True
            self.rejected += 1
            return
        target.submit(rs, now)

    # -------------------------------------------------- RolePlane: deflection
    def _deflect_one(self, rs: RequestState, now: float) -> bool:
        """Offer ``rs`` to the decode instances as a prefill target.

        The deflected ladder (``Scheduler.select_deflected``) scores
        ROLE_DECODE rows with Eq. (4) collapsed to a zero-transfer KV term;
        on acceptance the request is committed exactly like a dispatch —
        sched_time, reserve() pin — except its KV is born in place (tier 0,
        s_eff = 0).  Returns False (fall back to the prefill pool) when no
        decode row is feasible.
        """
        info = self._make_info(rs, False)
        if self.trace is not None:
            self.trace.now = now
        t0 = _time.perf_counter()
        decision = self.sched.select_deflected(
            info, self.view, self.engine.deflect_eta_row(now))
        dt = _time.perf_counter() - t0
        self.decision_latencies.append(dt)
        self.loop.note_select(dt)
        if decision is None:
            return False
        iid = decision.instance_id
        rs.sched_time = now
        rs.decode_instance = iid
        rs.tier = 0
        rs.s_eff = 0.0
        rs.hit_tokens = 0.0
        self.engine.reserve(iid, rs, now)
        self.engine.submit_deflected(iid, rs, now)
        self.deflected += 1
        return True

    def _on_deflect_done(self, rs: RequestState, now: float) -> None:
        """Deflected prefill finished *on the decode host itself*: the KV
        is already resident, so admission is immediate — no transfer and no
        base-latency hop (the network term collapsed at selection time)."""
        if rs.rejected:
            return
        rs.transfer_end = now
        iid = rs.decode_instance
        if not self.engine.is_healthy(iid):
            # Host died while the deflected chunks were still metering:
            # release the reserve() pin and re-run from scratch.
            self.engine.release(iid, rs)
            self._requeue(rs, now)
            return
        self.engine.enqueue(iid, rs, now)
        self.engine.kick((iid,), now)

    # ---------------------------------------------- RolePlane: P:D flipping
    def _role_tick(self, now: float) -> None:
        """Slow control loop: sample prefill backlog on the role lane,
        convert one drained instance per sustained-imbalance episode."""
        sig = self.engine.prefill_backlog(now)
        if sig > self.cfg.role_flip_hi:
            self._hi_run += 1
            self._lo_run = 0
        elif sig < self.cfg.role_flip_lo:
            self._lo_run += 1
            self._hi_run = 0
        else:
            self._hi_run = self._lo_run = 0
        if self._hi_run >= self.cfg.role_flip_sustain:
            if self._flip_to_prefill(now):
                self._hi_run = 0
        elif self._lo_run >= self.cfg.role_flip_sustain and self._flipped:
            if self._flip_back(now):
                self._lo_run = 0
        if not self.loop.empty():
            self.loop.arm(LANE_ROLE, now + self.cfg.role_flip_interval,
                          self._role_tick)

    def _n_prefill_role(self) -> int:
        eng = self.engine
        if isinstance(eng, InstancePlane):
            return int(eng.p_healthy[: eng.n_pre].sum())
        return sum(1 for p in eng.prefill if p.healthy)

    def _flip_to_prefill(self, now: float) -> bool:
        """Sustained prefill starvation: convert the lowest-id drained
        decode instance (no active batch, queue, deflected stream, or
        in-flight inbound transfer) to a prefill worker."""
        v = self.view
        cands = [int(v.ids[s]) for s in range(v.n)
                 if v.role[s] == ROLE_DECODE
                 and self.engine.is_healthy(int(v.ids[s]))]
        if len(cands) - 1 < self.cfg.min_decode:
            return False
        for iid in sorted(cands):
            if self._inbound.get(iid):
                continue
            if not self.engine.decode_drained(iid):
                continue
            self.engine.flip_role(iid, ROLE_PREFILL, now)
            self._flipped.append(iid)
            self.role_flips += 1
            if self.trace is not None:
                self.trace.role_flip(iid, now, ROLE_PREFILL)
            return True
        return False

    def _flip_back(self, now: float) -> bool:
        """Sustained prefill idleness: return the most recent convert to
        decode duty once its prefill work has drained."""
        iid = self._flipped[-1]
        if not self.engine.prefill_drained(iid):
            return False
        if self._n_prefill_role() - 1 < self.cfg.min_prefill:
            return False
        self.engine.flip_role(iid, ROLE_DECODE, now)
        self._flipped.pop()
        self.role_flips += 1
        if self.trace is not None:
            self.trace.role_flip(iid, now, ROLE_DECODE)
        return True

    # ------------------------------------------------ ChunkPlane auto-tuning
    def _autotune(self, input_len: int) -> None:
        """EWMA-driven chunk-size controller.

        Tracks arrival input lengths (EWMA, alpha 0.3) and retunes
        ``chunk_tokens`` to the largest power of two at most 1/8 of the
        typical length, clamped to [128, 2048], with a 4x iteration token
        budget — so a typical request prefills in a handful of
        interleavable chunks instead of one monolithic slice (short inputs)
        or hundreds of tiny ones (long inputs).
        """
        l = float(input_len)
        if self._len_ewma < 0:
            self._len_ewma = l
        else:
            self._len_ewma += 0.3 * (l - self._len_ewma)
        target = self._len_ewma / 8.0
        chunk = 128
        while chunk * 2 <= target and chunk < 2048:
            chunk *= 2
        if chunk != self._chunk_cur:
            self._chunk_cur = chunk
            self.engine.set_chunking(chunk, 4 * chunk)
            self._chunk_eff = chunk

    def _on_prefill_done(self, rs: RequestState, now: float) -> None:
        if rs.rejected:
            # Already rejected at first-chunk scheduling (kv_streaming):
            # the remaining chunks prefilled in vain; don't schedule (or
            # count the rejection) a second time.
            return
        if rs.stream_scheduled:
            # Streaming path: the decode instance was chosen at first-chunk
            # readiness and every chunk's bytes are already in (or through)
            # the network — the final chunk's transfer was started by
            # _on_chunk_done at this same instant.  A 100 % prefix hit
            # never streams anything: admission is latency-only from here.
            if rs.s_eff <= 0.0 and rs.stream_open == 0 and not rs.stream_last:
                lat = self.tree.tier_latency[rs.tier]
                if self.trace is not None:
                    self.trace.lat_segment(rs, now, now + lat)
                self.loop.after(lat,
                                lambda t, rs=rs: self._on_transfer_done(rs, None, t))
            return
        if isinstance(self.sched, NetKVBatch) and self.sched.window > 0:
            self._batch_window.append((rs, rs.prefill_instance))
            if self._batch_timer is None:
                self._batch_timer = self.loop.after(self.sched.window, self._flush_batch)
            return
        self._schedule_one(rs, now)

    # ------------------------------------------------------- streamed chunks
    def _on_chunk_done(self, rs: RequestState, tokens_ready: int, now: float) -> None:
        """One prefill chunk's KV is ready (kv_streaming only): select the
        decode instance on the first chunk, then stream each chunk's bytes
        into the FlowPlane while later chunks are still prefilling."""
        rs.tokens_ready = tokens_ready
        if rs.rejected:
            return
        if not rs.stream_scheduled:
            self._schedule_one(rs, now, streaming=True)
            if not rs.stream_scheduled:
                return          # rejected: remaining chunks prefill in vain
        self._stream_chunks(rs, now)

    def _stream_chunks(self, rs: RequestState, now: float) -> None:
        """Hand every newly-ready, non-prefix-hit byte to the network.

        Cumulative-fraction accounting: after k of the shippable tokens are
        ready the total streamed bytes equal ``s_eff * k / ship_total``, so
        per-chunk deltas telescope to *exactly* ``s_eff`` at the last chunk
        (byte conservation, property-tested across mid-stream rewires).
        """
        if rs.s_eff <= 0.0:
            return              # full prefix hit: nothing ever streams
        req = rs.req
        l = req.input_len
        last = rs.tokens_ready >= l
        hit = min(rs.hit_tokens, float(l))
        ship_total = float(l) - hit
        shipped = min(max(float(rs.tokens_ready) - hit, 0.0), ship_total)
        cum = rs.s_eff if last else rs.s_eff * (shipped / ship_total)
        delta = cum - rs.streamed_bytes
        rs.streamed_bytes = cum
        if last:
            rs.stream_last = True
        if delta > 0.0:
            src = self._server_of[rs.prefill_instance]
            dst = self._server_of[rs.decode_instance]
            rs.stream_open += 1
            tr = self.net.start_transfer(
                src, dst, delta, now,
                on_complete=lambda t, tt, rs=rs: self._on_chunk_transfer_done(rs, t, tt),
                n_flows=self.cfg.tp,
            )
            self._inbound.setdefault(rs.decode_instance, []).append((rs, tr))
            if not self.net.in_epoch:
                self._reschedule_net(now)
        elif last and rs.stream_open == 0:
            # Degenerate: the tail rounded to zero bytes with nothing in
            # flight — admission is latency-only, like a full hit.
            lat = self.tree.tier_latency[rs.tier]
            if self.trace is not None:
                self.trace.lat_segment(rs, now, now + lat)
            self.loop.after(lat, lambda t, rs=rs: self._on_transfer_done(rs, None, t))

    def _on_chunk_transfer_done(self, rs: RequestState, transfer, now: float) -> None:
        if self.trace is not None:
            self.trace.segment(rs, transfer)
        rs.stream_open -= 1
        if rs.stream_last and rs.stream_open == 0:
            # Last byte of the last chunk: admit through the usual
            # epoch-batched completion path (which clears every _inbound
            # entry of this request).
            self._on_transfer_done(rs, transfer, now)
            return
        # Intermediate chunk landed: the entry deliberately STAYS in
        # _inbound.  It is the fault path's only handle on a streamed
        # request caught *between* chunk transfers (stream_open == 0, next
        # chunk still prefilling) — kill_decode must cancel its stream and
        # requeue it at fault time, not after the remaining chunks finish
        # streaming to a dead instance.  Aborting an already-completed
        # transfer is a no-op in both network engines.

    # ------------------------------------------------------------- scheduling
    def _fill_hits(self, req: Request) -> None:
        """Refresh the per-request hit_tokens scratch column in-place."""
        self.engine.fill_hits(req)

    def _make_info(self, rs: RequestState, streaming: bool,
                   tokens_ready: int = 0) -> RequestInfo:
        req = rs.req
        info = RequestInfo(req.request_id, req.input_len, rs.kv_bytes)
        if streaming:
            # Streamed-transfer information set (Eq. 3 extension): bytes
            # keep becoming ready for prefill_remaining more seconds, and
            # the final-chunk tail can only enter the network at the end —
            # the ladder's T_xfer column credits the overlap accordingly.
            info.prefill_remaining = self.cfg.prefill_model.c * max(
                req.input_len - tokens_ready, 0)
            info.tail_bytes = rs.kv_bytes * (
                min(self._chunk_eff, req.input_len) / req.input_len)
        return info

    def _schedule_one(self, rs: RequestState, now: float,
                      streaming: bool = False) -> None:
        req = rs.req
        info = self._make_info(rs, streaming, rs.tokens_ready)
        self._fill_hits(req)
        view = self.oracle.view(now)
        if isinstance(self.sched, NetKVMultiHop):
            self.sched.observe_request(req.block_hashes)
        if self.trace is not None:
            self.trace.now = now
        t0 = _time.perf_counter()
        decision = self.sched.select(info, rs.prefill_instance, self.view, view,
                                     self.inflight)
        dt = _time.perf_counter() - t0
        self.decision_latencies.append(dt)
        self.loop.note_select(dt)
        if decision is None:
            rs.rejected = True
            self.rejected += 1
            return
        if streaming:
            self._dispatch_stream(rs, decision, now)
        else:
            self._dispatch(rs, decision, now)

    # --------------------------------------------------- cohort dispatch
    def _cohort_selector(self, items, reqs, now: float):
        """One fused R x D selection for a same-timestamp dispatch cohort.

        The stacked hit matrix and the oracle snapshot play the role of the
        per-request ``_fill_hits`` + ``oracle.view`` calls (untimed on the
        sequential path too); ``hit_fn``/``evictions_fn`` wire the selector's
        reserve-time eviction watch to the live caches.
        """
        H = self.engine.hit_rows(reqs)
        view = self.oracle.view(now)
        return self.sched.select_cohort(
            items, self.view, view, self.inflight,
            hit_matrix=H,
            hit_fn=lambda r, iid: self.engine.hit_tokens(iid, reqs[r]),
            evictions_fn=self.engine.evictions_of,
        )

    def _schedule_row(self, sel, k: int, rs: RequestState, now: float,
                      streaming: bool = False) -> None:
        """Cohort-path twin of ``_schedule_one``: row k's batched decision,
        with the cohort's one-time setup cost folded into the first row's
        latency so the per-decision metric stays comparable."""
        if self.trace is not None:
            self.trace.now = now
        t0 = _time.perf_counter()
        decision = sel.select_row(k)
        dt = (_time.perf_counter() - t0) + sel.take_setup_time()
        self.decision_latencies.append(dt)
        self.loop.note_select(dt)
        if decision is None:
            rs.rejected = True
            self.rejected += 1
            return
        if streaming:
            self._dispatch_stream(rs, decision, now)
        else:
            self._dispatch(rs, decision, now)

    def _prefill_cohort(self, batch, now: float) -> None:
        """Serial-prefill cohort hook: every prefill completing at this
        instant dispatches through one fused selection, each row's Decision
        (and its reserve / self-contention side effects) applied before the
        next row — bit-exact vs per-request ``_on_prefill_done`` calls."""
        items = [CohortItem(self._make_info(rs, False), rs.prefill_instance)
                 for rs in batch]
        sel = self._cohort_selector(items, [rs.req for rs in batch], now)
        for k, rs in enumerate(batch):
            if rs.rejected:
                continue        # skipped row: draws no tie-break, like the
                #                 sequential guard in _on_prefill_done
            self._schedule_row(sel, k, rs, now)

    def _phase3_cohort(self, live, now: float) -> None:
        """Chunked-prefill cohort hook: ChunkPlane's phase-3 callback loop
        with the same-instant selections fused.

        Replicates ``ChunkPlane._iteration_done`` phase 3 per stream —
        tokens_ready update, first-chunk scheduling (kv_streaming), chunk
        streaming, prefill-done handling — with rows that need a decode
        selection routed through one CohortSelector.  Rows whose sequential
        predicate flips mid-walk (a callback cancelled or rejected the
        stream) fall back exactly as the per-stream path would.
        """
        streaming = self.cfg.kv_streaming
        jobs = []
        for st in live:
            if st.cancelled or st.rs.rejected:
                continue
            if streaming:
                if not st.rs.stream_scheduled:
                    jobs.append(st)
            elif st.done >= st.rs.req.input_len:
                jobs.append(st)
        sel = None
        row: dict[int, int] = {}
        if len(jobs) > 1:
            items = [
                CohortItem(self._make_info(st.rs, streaming, st.done),
                           st.rs.prefill_instance)
                for st in jobs
            ]
            sel = self._cohort_selector(items, [st.rs.req for st in jobs], now)
            row = {id(st): k for k, st in enumerate(jobs)}
        for st in live:
            if st.cancelled:
                continue
            rs = st.rs
            if streaming:
                # _on_chunk_done with the fused selection spliced in.
                rs.tokens_ready = st.done
                if not rs.rejected:
                    if not rs.stream_scheduled:
                        k = row.get(id(st))
                        if sel is not None and k is not None:
                            self._schedule_row(sel, k, rs, now, streaming=True)
                        else:
                            self._schedule_one(rs, now, streaming=True)
                    if rs.stream_scheduled:
                        self._stream_chunks(rs, now)
            if st.done >= rs.req.input_len:
                rs.prefill_end = now
                k = row.get(id(st)) if not streaming else None
                if sel is not None and k is not None and not rs.rejected \
                        and not rs.stream_scheduled:
                    self._schedule_row(sel, k, rs, now)
                else:
                    self._on_prefill_done(rs, now)

    def _flush_batch(self, now: float) -> None:
        window, self._batch_window = self._batch_window, []
        self._batch_timer = None
        if not window:
            return
        reqs = [
            (RequestInfo(rs.req.request_id, rs.req.input_len, rs.kv_bytes), pid)
            for rs, pid in window
        ]
        hit_matrix = np.empty((len(window), self.view.n))
        for i, (rs, _) in enumerate(window):
            self._fill_hits(rs.req)
            hit_matrix[i] = self.view.column("hit_tokens")
        view = self.oracle.view(now)
        if self.trace is not None:
            self.trace.now = now
        t0 = _time.perf_counter()
        decisions = self.sched.select_batch(reqs, (self.view, hit_matrix), view,
                                            self.inflight)
        dt = _time.perf_counter() - t0
        self.decision_latencies.append(dt / len(window))
        self.loop.note_select(dt)
        # Arrival epoch: the whole dispatch burst lands at one timestamp, so
        # the FlowPlane admits it with a single union rate recompute.
        self.net.begin_epoch()
        try:
            for (rs, pid), dec in zip(window, decisions):
                if dec is None:
                    rs.rejected = True
                    self.rejected += 1
                else:
                    self._dispatch(rs, dec, now)
        finally:
            self.net.end_epoch()
        self._reschedule_net(now)

    def _dispatch_stream(self, rs: RequestState, decision, now: float) -> None:
        """Streaming dispatch: commit the decode target and its memory at
        first-chunk time; _stream_chunks moves the actual bytes."""
        rs.sched_time = now
        rs.decode_instance = decision.instance_id
        rs.tier = decision.tier
        rs.s_eff = decision.s_eff
        rs.hit_tokens = self.engine.hit_tokens(decision.instance_id, rs.req)
        self.engine.reserve(decision.instance_id, rs, now)
        rs.stream_scheduled = True

    def _dispatch(self, rs: RequestState, decision, now: float) -> None:
        rs.sched_time = now
        rs.decode_instance = decision.instance_id
        rs.tier = decision.tier
        rs.s_eff = decision.s_eff
        rs.hit_tokens = self.engine.hit_tokens(decision.instance_id, rs.req)
        self.engine.reserve(decision.instance_id, rs, now)
        src = self._server_of[rs.prefill_instance]
        dst = self._server_of[decision.instance_id]
        if decision.s_eff <= 0.0:
            # 100% prefix hit: only base latency applies.
            lat = self.tree.tier_latency[decision.tier]
            if self.trace is not None:
                self.trace.lat_segment(rs, now, now + lat)
            self.loop.after(lat, lambda t, rs=rs: self._on_transfer_done(rs, None, t))
            return
        plan = None
        if isinstance(self.sched, NetKVMultiHop):
            plan = self.sched.plans.get(rs.req.request_id)
        if plan is not None and plan.kind == "staged":
            # Two parallel legs: store->d (staged) and p->d (remainder).
            pending = {"n": 0}

            def leg_done(tr, t, rs=rs, pending=pending, plan=plan):
                if self.trace is not None:
                    self.trace.segment(rs, tr)
                pending["n"] -= 1
                if pending["n"] == 0:
                    self.sched.staged_leg_done(plan.store_id)
                    self._on_transfer_done(rs, tr, t)

            store_src = self._server_of[plan.store_id]
            for leg_src, nbytes in ((store_src, plan.staged_bytes),
                                    (src, plan.direct_bytes)):
                if nbytes <= 0:
                    continue
                pending["n"] += 1
                tr = self.net.start_transfer(
                    leg_src, dst, nbytes, now, on_complete=leg_done,
                    n_flows=self.cfg.tp)
                self._inbound.setdefault(decision.instance_id, []).append((rs, tr))
            if pending["n"] == 0:  # fully resident: latency only
                lat = self.tree.tier_latency[decision.tier]
                if self.trace is not None:
                    self.trace.lat_segment(rs, now, now + lat)
                self.loop.after(lat, lambda t, rs=rs: self._on_transfer_done(rs, None, t))
            if not self.net.in_epoch:
                self._reschedule_net(now)
            return
        transfer = self.net.start_transfer(
            src, dst, decision.s_eff, now,
            on_complete=lambda tr, t, rs=rs: self._on_transfer_done(rs, tr, t),
            n_flows=self.cfg.tp,
        )
        self._inbound.setdefault(decision.instance_id, []).append((rs, transfer))
        if not self.net.in_epoch:
            self._reschedule_net(now)

    # -------------------------------------------------------------- transfers
    def _complete_transfer(self, rs: RequestState, transfer, now: float):
        """Bookkeeping for one landed transfer.

        Returns the decode instance id to kick, or None when the request
        bounced (dispatched inside a fault-detection window) and requeued.
        """
        rs.transfer_end = now
        if transfer is not None:
            if self.trace is not None:
                # Deduped by transfer id, so the streamed last chunk and the
                # staged final leg (already emitted above) don't double-count.
                self.trace.segment(rs, transfer)
            lst = self._inbound.get(rs.decode_instance, [])
            self._inbound[rs.decode_instance] = [
                (r, t) for (r, t) in lst if r is not rs
            ]
        if self.sched.uses_self_contention:
            self.inflight.decr(rs.prefill_instance, rs.tier)
        if isinstance(self.sched, NetKVMultiHop):
            # write-through: the landed prefix populates the dst pod's store.
            pod = self._server_of[rs.decode_instance][0]
            self.sched.on_transfer_complete(rs.req.block_hashes, 1000 + pod)
        iid = rs.decode_instance
        if not self.engine.is_healthy(iid):
            # Dispatched inside the detection window: the landed transfer
            # bounces — release the pin taken at reserve() and requeue.
            self.engine.release(iid, rs)
            self._requeue(rs, now)
            return None
        self.engine.enqueue(iid, rs, now)
        return iid

    def _on_transfer_done(self, rs: RequestState, transfer, now: float) -> None:
        if self._epoch is not None:
            # Same-net-instant landing: buffered, admitted as one epoch in
            # _net_fire (enqueue all, then one kick per touched instance).
            self._epoch.append((rs, transfer))
            return
        iid = self._complete_transfer(rs, transfer, now)
        if iid is not None:
            self.engine.kick((iid,), now)
        self._reschedule_net(now)

    def _decode_by_id(self, iid: int):
        return self.engine.decode_by_id(iid)  # O(1): ClusterView.slot_of

    def _reschedule_net(self, now: float) -> None:
        if self._tick_idle:
            self._wake_tick(now)
        nct = self.net.next_completion_time(now)
        if nct is None:
            return
        self.loop.arm(LANE_NET, nct, self._net_fire)

    def _net_fire(self, now: float) -> None:
        # Buffer every completion this advance pops (the FlowPlane already
        # batch-pops all flows finishing at one instant), then admit them as
        # a single InstancePlane epoch.
        self._epoch = []
        try:
            self.net.advance(now)
        finally:
            epoch, self._epoch = self._epoch, None
        touched: list[int] = []
        for rs, transfer in epoch:
            iid = self._complete_transfer(rs, transfer, now)
            if iid is not None and iid not in touched:
                touched.append(iid)
        if touched:
            self.engine.kick(touched, now)
        self._reschedule_net(now)

    def _net_tick(self, now: float) -> None:
        self.net.refresh_rates(now)
        self._reschedule_net(now)
        if self.loop.empty():
            return
        self._tick_next = now + self.cfg.net_tick
        if self._net_tick_elidable and self.net.n_flows_active == 0:
            # Static background + empty network: every tick until the next
            # transfer starts would refresh rates to the values they already
            # hold.  Go dormant; _reschedule_net wakes the chain on the
            # preserved grid as soon as a flow enters the plane.
            self._tick_idle = True
            return
        self.loop.arm(LANE_TICK, self._tick_next, self._net_tick)

    def _wake_tick(self, now: float) -> None:
        self._tick_idle = False
        t = self._tick_next
        tick = self.cfg.net_tick
        while t <= now:
            t = t + tick     # replay the skipped grid points exactly
        self._tick_next = t
        self.loop.arm(LANE_TICK, t, self._net_tick)

    # ------------------------------------------------------ topology dynamics
    def _on_rewire(self, rw: RewireEvent, now: float) -> None:
        """OCS reconfiguration fires: swap capacities, re-water-fill, and
        re-arm the completion timer (every in-flight ETA just moved).  The
        oracle is *not* poked unless ``notify_rewires`` is set — by default
        the scheduler keeps its stale pre-rewire snapshot until the next
        refresh interval elapses; with notifications it refreshes at the
        reconfiguration instant."""
        self.tree.rewire(tier_bandwidth=rw.tier_bandwidth, scale=rw.scale)
        self.net.on_rewire(now)
        if self.cfg.notify_rewires:
            self.oracle.force_refresh(now)
        self._reschedule_net(now)

    # ------------------------------------------------------ faults/elasticity
    def _on_fault(self, f: FaultEvent, now: float) -> None:
        if f.kind == "kill_decode":
            victims = self.engine.fail(f.instance_id, now)
            seen: set[int] = set()
            for rs, transfer in self._inbound.pop(f.instance_id, []):
                self.net.abort_transfer(transfer, now)
                if id(rs) in seen:
                    continue  # one request, many flows (streamed chunks /
                    #           staged legs): requeue + decrement once
                seen.add(id(rs))
                if self.sched.uses_self_contention:
                    self.inflight.decr(rs.prefill_instance, rs.tier)
                victims.append(rs)
            # Health flips scheduler-visible after the detection delay; until
            # then new dispatches to this instance bounce and requeue.
            self.loop.after(
                f.detection_delay,
                lambda t, i=f.instance_id: self.engine.mark_detected(i, t))
            for rs in victims:
                self._requeue(rs, now)
            self._reschedule_net(now)
        elif f.kind == "slowdown":
            self.engine.set_iter_scale(f.instance_id, f.factor)
        elif f.kind == "add_decode":
            new_id = max(self._server_of) + 1
            # Elastic join: place on the decode-hosting server with the
            # fewest healthy resident decode instances (ties -> lowest
            # server coordinate), so capacity lands where the pool is thin.
            pop: dict[tuple[int, int, int], int] = {}
            for d in self.decode:
                pop.setdefault(d.server, 0)
                if d.healthy:
                    pop[d.server] += 1
            srv = min(sorted(pop), key=pop.get)
            self._server_of[new_id] = srv
            self.engine.add_decode(new_id, srv)
        elif f.kind == "kill_prefill":
            victims = self.engine.fail_prefill(f.instance_id, now)
            for rs in victims:
                if rs.decode_instance >= 0:
                    # Streamed dispatch caught mid-prefill: abort its
                    # in-flight inbound flows and release the reserve()
                    # pin before re-running from scratch.
                    lst = self._inbound.get(rs.decode_instance, [])
                    mine = [(r, t) for (r, t) in lst if r is rs]
                    self._inbound[rs.decode_instance] = [
                        (r, t) for (r, t) in lst if r is not rs
                    ]
                    for _, tr in mine:
                        self.net.abort_transfer(tr, now)
                    if self.sched.uses_self_contention:
                        self.inflight.decr(rs.prefill_instance, rs.tier)
                    self.engine.release(rs.decode_instance, rs)
                self._requeue(rs, now)
            self._reschedule_net(now)
        elif f.kind == "add_prefill":
            new_id = max(self._server_of) + 1
            # Elastic prefill join: add_decode's placement policy over the
            # prefill-hosting servers.
            pop = {}
            for p in self.prefill:
                pop.setdefault(p.server, 0)
                if p.healthy:
                    pop[p.server] += 1
            srv = min(sorted(pop), key=pop.get)
            self._server_of[new_id] = srv
            self.engine.add_prefill(new_id, srv)
        else:
            raise ValueError(f.kind)

    def _requeue(self, rs: RequestState, now: float) -> None:
        """Fault path: re-run the request through prefill + scheduling.

        The prefill-side KV buffer was released when the transfer completed,
        so a decode-side loss after admit requires a fresh prefill; a loss
        during transfer could reuse the buffer, but we conservatively re-run
        prefill in both cases (counts in ``requeues``).
        """
        rs.requeues += 1
        if rs.prefill_instance >= 0:
            # Streamed dispatch may die while chunks are still prefilling:
            # drop any live chunk stream before re-running from scratch.
            # Unconditional on purpose — ``prefill_end`` may hold a *stale*
            # earlier attempt's finish time while the current attempt is
            # mid-prefill; cancel is a no-op when no stream is live.
            self.engine.cancel_prefill(rs)
        rs.decode_instance = -1
        rs.tokens_out = 0
        rs.transfer_end = -1.0
        rs.prefill_end = -1.0  # the fresh attempt re-runs prefill in full
        # Streaming bookkeeping restarts with the fresh prefill attempt.
        rs.tokens_ready = 0
        rs.streamed_bytes = 0.0
        rs.stream_open = 0
        rs.stream_scheduled = False
        rs.stream_last = False
        # A deflected attempt that died re-runs through the ordinary
        # arrival gate (it may deflect again, or prefill normally).
        rs.deflected = False
        # Clear every per-attempt field from the failed attempt: a stale
        # first_token/admit_time would report a phantom TTFT for a request
        # that never decoded, and stale tier/s_eff/hit_tokens would skew the
        # tier-fraction and hit-rate metrics toward the dead instance.
        rs.sched_time = -1.0
        rs.first_token = -1.0
        rs.admit_time = -1.0
        rs.tier = -1
        rs.s_eff = 0.0
        rs.hit_tokens = 0.0
        if rs.requeues > 3:
            rs.rejected = True
            self.rejected += 1
            return
        self._on_arrival(rs, now)

    # ------------------------------------------------------------------- run
    def run(self, trace: Sequence[Request], drain: float = 60.0) -> RunMetrics:
        self.load_trace(trace)
        horizon = self.cfg.warmup + self.cfg.measure + drain
        self.loop.run(until=horizon)
        self.engine.finalize()
        if self.trace is not None:
            # Whole-phase lifecycle spans derive from RequestState
            # timestamps at the end — zero hot-path cost for them.
            self.trace.finalize(self.records)
            sess = trace_session()
            if sess is not None:
                sess.register(self.cfg.scheduler, self.trace, self.records)
        # Per-role utilization: busy seconds over instance-seconds.  The
        # denominators use the final pool sizes (handle lists grow under
        # add_* faults and role flips) — a telemetry approximation, not a
        # parity-checked outcome.
        elapsed = max(self.loop.now, 1e-9)
        n_pre = len(self.prefill)
        n_dec = len(self.decode)
        prefill_util = (self.engine.prefill_busy_s / (n_pre * elapsed)
                        if n_pre else float("nan"))
        decode_util = ((self.engine.decode_busy_s + self.engine.deflect_busy_s)
                       / (n_dec * elapsed) if n_dec else float("nan"))
        return summarize(
            self.records,
            window=(self.cfg.warmup, self.cfg.warmup + self.cfg.measure),
            scheduler=self.cfg.scheduler,
            decision_latencies=self.decision_latencies,
            rejected=self.rejected,
            decode_iterations=self.engine.total_iterations,
            prefill_util=prefill_util,
            decode_util=decode_util,
        )


def run_sim(cfg: SimConfig, trace: Sequence[Request], drain: float = 60.0) -> RunMetrics:
    return Simulation(cfg).run(trace, drain=drain)
