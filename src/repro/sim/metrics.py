"""Metric collection: TTFT, TBT, SLO attainment, goodput, transfer stats."""

from __future__ import annotations

import dataclasses

import numpy as np

from .trace import ttft_attribution


@dataclasses.dataclass
class RunMetrics:
    scheduler: str
    n_measured: int
    n_rejected: int
    n_unfinished: int
    ttft_mean: float
    ttft_p50: float
    ttft_p95: float
    ttft_p99: float
    tbt_mean: float
    tbt_p95: float
    slo_attainment: float
    goodput_rps: float
    xfer_mean: float
    xfer_p95: float
    tier_fraction: dict[int, float]
    hit_frac_mean: float
    decision_latency_mean: float
    decision_latency_p99: float
    requeues: int = 0
    decode_iterations: int = 0  # continuous-batching steps across instances
    # TTFT attribution (sim/trace.py::ttft_attribution): per-phase shares
    # of time-to-first-token over the measurement window.  NaN on
    # degenerate windows, like every distributional metric above.
    queue_wait_mean: float = float("nan")
    queue_wait_p95: float = float("nan")
    prefill_mean: float = float("nan")
    prefill_p95: float = float("nan")
    admit_wait_mean: float = float("nan")
    admit_wait_p95: float = float("nan")
    xfer_share_mean: float = float("nan")
    xfer_share_p95: float = float("nan")
    # RolePlane telemetry: per-role compute utilization over the run (busy
    # seconds / instance-seconds, NaN when a role has no instances) and the
    # fraction of finished measured requests whose prefill was deflected
    # onto a decode host (0.0 with deflection off, NaN on empty windows).
    prefill_util: float = float("nan")
    decode_util: float = float("nan")
    deflected_frac: float = float("nan")

    def row(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("tier_fraction")
        for t in range(4):
            d[f"tier{t}"] = self.tier_fraction.get(t, 0.0)
        return d


def _pct(a: np.ndarray, q: float) -> float:
    """NaN-safe percentile: np.percentile raises on empty input."""
    return float(np.percentile(a, q)) if a.size else float("nan")


def _mean(a: np.ndarray) -> float:
    return float(a.mean()) if a.size else float("nan")


def summarize(records, *, window: tuple[float, float], scheduler: str,
              decision_latencies=(), rejected: int = 0,
              decode_iterations: int = 0,
              prefill_util: float = float("nan"),
              decode_util: float = float("nan")) -> RunMetrics:
    """Aggregate per-request records whose ARRIVAL falls in the window.

    Degenerate windows are first-class: when nothing arrives (or nothing
    reaches its first token) inside the window every distributional metric
    is NaN rather than a crash or a fabricated sentinel — mid-sweep a
    starved arm must produce a row that ``aggregate_seeds`` (which filters
    non-finite values) can digest.  The previous implementation fed
    ``np.percentile`` empty arrays (e.g. ``done`` non-empty but no record
    with a valid TBT) and padded others with fake ``[0.0]``/``[inf]``
    entries that skewed downstream means.
    """
    lo, hi = window
    meas = [r for r in records if lo <= r.req.arrival < hi and not r.rejected]
    done = [r for r in meas if r.first_token >= 0]
    unfinished = len(meas) - len(done)
    ttfts = np.array([r.ttft for r in done], np.float64)
    fin_ttfts = ttfts[np.isfinite(ttfts)]
    tbts = np.array([r.tbt for r in done if r.tbt >= 0], np.float64)
    # Transfer time: from prefill end (scheduling) to transfer landed.
    xfers = np.array([r.transfer_end - r.prefill_end for r in done
                      if r.transfer_end >= 0], np.float64)
    slo_ok = sum(1 for r in done if r.ttft <= r.req.slo)
    span = max(hi - lo, 1e-9)
    tiers = [r.tier for r in done if r.tier >= 0]
    tier_frac = {
        t: (sum(1 for x in tiers if x == t) / max(len(tiers), 1)) for t in range(4)
    }
    hits = np.array(
        [min(r.hit_tokens, r.req.input_len) / max(r.req.input_len, 1) for r in done],
        np.float64,
    )
    dl = np.asarray(decision_latencies, np.float64)
    return RunMetrics(
        scheduler=scheduler,
        n_measured=len(meas),
        n_rejected=rejected,
        n_unfinished=unfinished,
        ttft_mean=_mean(fin_ttfts),
        ttft_p50=_pct(ttfts, 50),
        ttft_p95=_pct(ttfts, 95),
        ttft_p99=_pct(ttfts, 99),
        tbt_mean=_mean(tbts),
        tbt_p95=_pct(tbts, 95),
        slo_attainment=slo_ok / len(meas) if meas else float("nan"),
        goodput_rps=slo_ok / span,
        xfer_mean=_mean(xfers),
        xfer_p95=_pct(xfers, 95),
        tier_fraction=tier_frac,
        hit_frac_mean=_mean(hits),
        decision_latency_mean=_mean(dl),
        decision_latency_p99=_pct(dl, 99),
        requeues=sum(r.requeues for r in meas),
        decode_iterations=decode_iterations,
        prefill_util=prefill_util,
        decode_util=decode_util,
        deflected_frac=(sum(1 for r in done if r.deflected) / len(done)
                        if done else float("nan")),
        **ttft_attribution(records, window),
    )


def aggregate_seeds(runs: list[RunMetrics]) -> dict:
    """mean ± std across seeds for the headline metrics."""
    keys = ["ttft_mean", "ttft_p99", "tbt_mean", "slo_attainment", "xfer_mean",
            "goodput_rps", "xfer_share_mean",
            "prefill_util", "decode_util", "deflected_frac"]
    out = {"scheduler": runs[0].scheduler, "n_seeds": len(runs)}
    for k in keys:
        vals = np.array([getattr(r, k) for r in runs], dtype=np.float64)
        vals = vals[np.isfinite(vals)]
        out[k] = float(vals.mean()) if vals.size else float("nan")
        out[k + "_std"] = float(vals.std()) if vals.size else float("nan")
    for t in range(4):
        out[f"tier{t}"] = float(np.mean([r.tier_fraction.get(t, 0.0) for r in runs]))
    return out
