"""ScenarioPlane: fleet-scale what-if sweeps as one batched JAX program.

The event-loop simulator answers one scenario at a time at Python speed;
every beyond-paper study on the ROADMAP (optimality gaps at scale, chunk ×
NIC-policy × rewire grids, autoscaling policies) needs *thousands* of
scenarios.  This module fuses the two NumPy fixed-point hot loops the
planes already isolated —

* ``FlowPlane._recompute_rates``  -> ``kernels.waterfill`` (jitted
  ``lax.while_loop`` + optional Pallas inner reduction, bit-exact under
  f64, proven by ``tests/test_scenarioplane.py``);
* ``InstancePlane._step_rows_vector``'s token/finish/KV-growth array ops
  -> :func:`cohort_step` (jitted, bit-exact in ``exact_clamp`` mode);

— into a fixed-timestep fluid scenario model and ``vmap``s a leading
*scenario axis* over it: seeds × scheduler × chunk size × NIC policy ×
rewire schedules run as **one** jitted device program
(:meth:`ScenarioPlane.sweep`), returning per-scenario TTFT/TBT/SLO summary
arrays.

Modelling contract: the two ported solvers are bit-exact against their
NumPy planes; the surrounding scenario engine is a *fluid* (dt-stepped)
approximation of the event loop — same cost model (Eqs. (2)-(7)), same
max-min network, same continuous-batching iteration clock, but scheduling
decisions quantise to ``dt`` and the radix cache is not modelled
(``s_eff = s_r``).  It ranks policies; the event loop remains the ground
truth for absolute paper numbers.  Batched row ``i`` is bit-identical to a
solo run of scenario ``i`` at the same padding (the vmap-consistency
test): every loop body is a no-op for converged/padded lanes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.cost import (
    H100_TP4_ITER, H100_TP4_PREFILL, IterTimeModel, LLAMA3_70B_KV,
    ModelKVSpec, PrefillTimeModel,
)
from repro.core.jaxutil import enable_f64
from repro.cluster.topology import FatTree, MAX_PATH_LEN, make_instances, make_nic_policy
from repro.traces.mooncake import generate_trace

BIG = 1e30
_SEQ_LIM = np.int64(1) << 32


# ----------------------------------------------------------- cohort step
def cohort_step(tokens, out_len, inst, seq, grown, live, inst_cohort, pinned,
                *, kv_per_token: float, exact_clamp: bool = True):
    """One continuous-batching iteration over the request table, jitted.

    The array-op core of ``InstancePlane._step_rows_vector``: every live
    row of an iterating instance gains one token, pins ``kv_per_token``
    more bytes on its instance, and rows reaching ``out_len`` finish,
    releasing ``grown`` bytes clamped at zero *in admission order per
    instance* — the order the reference engine's float accounting depends
    on.  ``exact_clamp=True`` reproduces that sequence with a
    ``lax.scan`` over (instance, seq)-sorted rows (bit-exact vs the
    NumPy plane, see ``tests/test_scenarioplane.py``);
    ``exact_clamp=False`` fuses the release into one segment-sum +
    single clamp (order-free, what the fluid sweep uses).

    Shapes: rows ``(R,)``; ``inst_cohort`` ``(K,)`` bool (instances
    iterating now); ``pinned`` ``(K + 1,)`` with a pad accumulator slot.
    Returns ``(tokens, live, pinned, first, fin, fin_per_inst)``.
    """
    import jax
    import jax.numpy as jnp

    k = inst_cohort.shape[0]
    inst_c = jnp.clip(inst, 0, k - 1)
    rows = live & inst_cohort[inst_c]
    tokens = jnp.where(rows, tokens + 1, tokens)
    first = rows & (tokens == 1)
    # Equal-sized per-row increments: scatter-add order cannot change the
    # per-instance float accumulation (mirrors np.add.at's sequence).
    tgt = jnp.where(rows, inst_c, k)
    pinned = pinned.at[tgt].add(jnp.asarray(kv_per_token, pinned.dtype))
    fin = rows & (tokens >= out_len)
    if exact_clamp:
        key = jnp.where(
            fin, inst_c.astype(jnp.int64) * _SEQ_LIM + seq.astype(jnp.int64),
            jnp.iinfo(jnp.int64).max)
        order = jnp.argsort(key, stable=True)

        def _clamp(p, r):
            isf = fin[r]
            s = jnp.where(isf, inst_c[r], k)
            cur = p[s]
            new = jnp.maximum(0.0, cur - grown[r])
            return p.at[s].set(jnp.where(isf, new, cur)), None

        pinned, _ = jax.lax.scan(_clamp, pinned, order)
    else:
        rel = jnp.zeros_like(pinned).at[jnp.where(fin, inst_c, k)].add(grown)
        pinned = jnp.maximum(0.0, pinned - rel)
    live = live & ~fin
    fin_per_inst = jnp.zeros(k + 1, jnp.int64).at[
        jnp.where(fin, inst_c, k)].add(1)[:k]
    return tokens, live, pinned, first, fin, fin_per_inst


_COHORT_JIT = None


def cohort_step_jit(*args, **kwargs):
    """Jitted :func:`cohort_step` (recompiles per shape; ``kv_per_token``
    rides as a traced operand so values don't retrigger compilation)."""
    global _COHORT_JIT
    if _COHORT_JIT is None:
        import jax

        _COHORT_JIT = jax.jit(cohort_step, static_argnames=("exact_clamp",))
    return _COHORT_JIT(*args, **kwargs)


# ------------------------------------------------------------- scenarios
@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One cell of a what-if grid (mirrors the SimConfig knobs the fluid
    engine models).  ``rewires`` is a schedule of ``(time, {tier: scale})``
    multiplicative capacity edits (the OCS timeline)."""

    seed: int = 0
    scheduler: str = "netkv-full"   # "cla" | "netkv-static" | "netkv-full"
    profile: str = "chatbot"
    target_rps: float = 16.0
    warmup: float = 2.0
    measure: float = 8.0
    drain: float = 4.0
    chunk_tokens: int | None = None
    kv_streaming: bool = False
    nic_policy: str = "hash"
    background: float = 0.0
    rewires: Sequence[tuple] = ()
    # cluster shape (must match across one sweep: one batched program)
    n_pods: int = 2
    racks_per_pod: int = 2
    servers_per_rack: int = 2
    gpus_per_server: int = 8
    nics_per_server: int = 1
    tp: int = 4
    n_prefill: int = 4
    beta_max: int = 64
    hbm_free_per_gpu: float = 45e9
    m_min: float = 2e9
    kv_spec: ModelKVSpec = LLAMA3_70B_KV
    iter_model: IterTimeModel = H100_TP4_ITER
    prefill_model: PrefillTimeModel = H100_TP4_PREFILL
    # CacheLoadAware weights (only read when scheduler == "cla")
    w_cache: float = 1.0
    w_load: float = 1.0

    @property
    def duration(self) -> float:
        return self.warmup + self.measure

    @property
    def horizon(self) -> float:
        return self.duration + self.drain

    def tree_shape(self) -> tuple:
        return (self.n_pods, self.racks_per_pod, self.servers_per_rack,
                self.gpus_per_server, self.nics_per_server, self.tp,
                self.n_prefill)


_SCHED_FLAGS = {
    # (use_xfer, use_cong): cla scores load only; netkv-static prices
    # transfers at raw tier bandwidth; netkv-full adds congestion +
    # self-contention (Eq. (4)).
    "cla": (0.0, 0.0),
    "netkv-static": (1.0, 0.0),
    "netkv-full": (1.0, 1.0),
}


class ScenarioPlane:
    """Batched fluid scenario engine: prep on host, sweep as one program.

    ``backend`` selects the water-filling inner solver exactly as
    ``netkv-full``'s scorer does: ``"jax"`` (default, f64) or ``"pallas"``
    (TPU kernel for the share/argmin reduction; interpret mode off-TPU).
    """

    def __init__(self, scenarios: Sequence[ScenarioSpec], *, dt: float = 0.01,
                 backend: str = "jax", max_requests: int | None = None,
                 interpret: bool | None = None):
        import jax

        enable_f64()
        if not scenarios:
            raise ValueError("need at least one scenario")
        if backend not in ("jax", "pallas"):
            raise ValueError(f"unknown ScenarioPlane backend {backend!r}")
        shapes = {s.tree_shape() for s in scenarios}
        if len(shapes) != 1:
            raise ValueError("all scenarios in one sweep must share a "
                             f"cluster shape; got {sorted(shapes)}")
        horizons = {s.horizon for s in scenarios}
        if len(horizons) != 1:
            raise ValueError("all scenarios in one sweep must share "
                             "warmup+measure+drain (one step count)")
        self.scenarios = list(scenarios)
        self.dt = float(dt)
        self.backend = backend
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else bool(interpret))
        self.n_steps = int(math.ceil(scenarios[0].horizon / self.dt))
        self._prep(max_requests)

    # ------------------------------------------------------------- host prep
    def _prep(self, max_requests: int | None) -> None:
        s0 = self.scenarios[0]
        tree = FatTree(
            s0.n_pods, s0.racks_per_pod, s0.servers_per_rack,
            s0.gpus_per_server, nics_per_server=s0.nics_per_server)
        pre_meta, dec_meta = make_instances(tree, tp=s0.tp,
                                            n_prefill=s0.n_prefill)
        self.tree = tree
        self.n_prefill = len(pre_meta)
        self.n_decode = len(dec_meta)
        p_srv = [i.server for i in pre_meta]
        d_srv = [i.server for i in dec_meta]
        p_idx = np.array([tree.server_index(s) for s in p_srv], np.int64)
        d_idx = np.array([tree.server_index(s) for s in d_srv], np.int64)
        self.tier_pd = tree.tier_vec(p_idx[:, None], d_idx[None, :])

        per_scn = []
        for spec in self.scenarios:
            reqs = generate_trace(spec.profile, duration=spec.duration,
                                  target_rps=spec.target_rps, seed=spec.seed)
            per_scn.append(self._prep_one(spec, reqs, tree, p_srv, d_srv))
        r_max = max(p["arrival"].size for p in per_scn)
        if max_requests is not None:
            if max_requests < r_max:
                raise ValueError(
                    f"max_requests={max_requests} < largest trace {r_max}")
            r_max = max_requests
        self.max_requests = r_max

        def pad(key, fill, dtype):
            out = np.full((len(per_scn), r_max), fill, dtype)
            for i, p in enumerate(per_scn):
                out[i, : p[key].size] = p[key]
            return out

        self.arrival = pad("arrival", np.inf, np.float64)
        self.s_eff = pad("s_eff", 0.0, np.float64)
        self.out_len = pad("out_len", 1, np.int64)
        self.slo = pad("slo", np.inf, np.float64)
        self.src_p = pad("src_p", 0, np.int64)
        self.prefill_end = pad("prefill_end", np.inf, np.float64)
        self.xfer_ready = pad("xfer_ready", np.inf, np.float64)
        self.path_table = np.stack([p["path_table"] for p in per_scn])
        self.bw_mult = np.stack([p["bw_mult"] for p in per_scn])
        self.bg_util = np.stack([p["bg_util"] for p in per_scn])
        self.link_cap = np.stack([p["link_cap"] for p in per_scn])
        self.tier_lat = np.stack([p["tier_lat"] for p in per_scn])
        # Compact the link axis to links the prefill->decode paths actually
        # cross: water-filling cost scales with (R, L) and a 64-GPU tree has
        # ~120 links of which the path tables touch only a fraction.  One
        # representative link per populated tier is always kept so the
        # derived p50 tier-bandwidth summary stays defined (capacities are
        # uniform per tier here, so the p50 is unchanged by the subset).
        used = np.unique(self.path_table)
        used = used[used < tree.n_links].astype(np.int64)
        for t in range(4):
            tier_ids = np.nonzero(tree.link_tier == t)[0]
            if tier_ids.size and not np.any(np.isin(tier_ids, used)):
                used = np.append(used, tier_ids[:1])
        used = np.unique(used)
        remap = np.full(tree.n_links + 1, used.size, np.int64)
        remap[used] = np.arange(used.size)
        self.link_ids = used                       # compact -> global id
        self.path_table = remap[self.path_table].astype(np.int32)
        self.link_cap = self.link_cap[:, used]
        self._link_tier_c = np.asarray(tree.link_tier)[used]
        flags = np.array([_SCHED_FLAGS[s.scheduler] for s in self.scenarios],
                         np.float64)
        self.use_xfer, self.use_cong = flags[:, 0], flags[:, 1]
        as_arr = lambda f, d=np.float64: np.array(
            [f(s) for s in self.scenarios], d)
        self.beta_max = as_arr(lambda s: s.beta_max)
        self.mem_total = as_arr(lambda s: s.hbm_free_per_gpu * s.tp)
        self.m_min = as_arr(lambda s: s.m_min)
        self.kpt = as_arr(lambda s: float(s.kv_spec.kv_bytes_per_token))
        self.iter_a = as_arr(lambda s: s.iter_model.a)
        self.iter_b = as_arr(lambda s: s.iter_model.b)
        self.w_cache = as_arr(lambda s: s.w_cache)
        self.w_load = as_arr(lambda s: s.w_load)
        self.warmup_arr = as_arr(lambda s: s.warmup)
        self.measure_arr = as_arr(lambda s: s.measure)
        self.seeds = np.array([s.seed for s in self.scenarios], np.uint32)

    def _prep_one(self, spec, reqs, tree, p_srv, d_srv) -> dict:
        """Host-side per-scenario tables: trace columns, serial prefill
        queueing, chunk-streamed transfer readiness, ECMP path table."""
        n = len(reqs)
        arrival = np.array([r.arrival for r in reqs], np.float64)
        in_len = np.array([r.input_len for r in reqs], np.int64)
        out_len = np.maximum(
            np.array([r.output_len for r in reqs], np.int64), 1)
        slo = np.array([r.slo for r in reqs], np.float64)
        s_eff = np.array(
            [float(spec.kv_spec.kv_bytes(int(l))) for l in in_len], np.float64)
        # Round-robin prefill assignment; serial per-instance prefill queue
        # (chunking changes *readiness*, not total prefill seconds).
        src_p = np.arange(n, dtype=np.int64) % len(p_srv)
        busy = np.zeros(len(p_srv), np.float64)
        pf_start = np.zeros(n, np.float64)
        pf_end = np.zeros(n, np.float64)
        pm = spec.prefill_model
        for j in range(n):
            p = src_p[j]
            pf_start[j] = max(arrival[j], busy[p])
            busy[p] = pf_start[j] + pm(int(in_len[j]))
            pf_end[j] = busy[p]
        if spec.chunk_tokens:
            # ChunkPlane semantics: the decode instance is selected (and,
            # when streaming, bytes start moving) at first-chunk readiness.
            first_chunk = pf_start + np.array(
                [pm(min(int(l), int(spec.chunk_tokens))) for l in in_len])
            ready = first_chunk if spec.kv_streaming else pf_end
        else:
            ready = pf_end
        # ECMP path table: one uplink draw per (prefill, decode) pair from
        # the scenario's RNG stream, NIC pair from the scenario's policy.
        rng = np.random.default_rng(spec.seed)
        policy = make_nic_policy(spec.nic_policy)
        policy.bind(lambda lids: np.zeros(np.shape(lids), np.int64))
        pt = np.full((len(p_srv), len(d_srv), MAX_PATH_LEN), tree.n_links,
                     np.int32)
        for pi, ps in enumerate(p_srv):
            for di, ds in enumerate(d_srv):
                t = tree.tier(ps, ds)
                nics = (0, 0) if t == 0 else policy.pick(
                    tree, tree.server_index(ps), tree.server_index(ds), rng)
                row, _ = tree.path_row(ps, ds, rng, nics=nics)
                pt[pi, di] = np.where(row < 0, tree.n_links, row)
        # Capacity timeline: cumulative multiplicative tier scaling per step.
        mult = np.ones((self.n_steps, 4), np.float64)
        cur = np.ones(4, np.float64)
        edits = sorted((float(t), dict(sc)) for t, sc in spec.rewires)
        k0 = 0
        for t_ev, sc in edits:
            k1 = min(self.n_steps, max(0, int(math.ceil(t_ev / self.dt))))
            mult[k0:k1] = cur
            for tier, f in sc.items():
                cur[int(tier)] *= float(f)
            k0 = k1
        mult[k0:] = cur
        bg = np.array([
            0.0 if t == 0 else min(max(float(spec.background), 0.0), 0.95)
            for t in range(4)], np.float64)
        return dict(
            arrival=arrival, s_eff=s_eff, out_len=out_len, slo=slo,
            src_p=src_p, prefill_end=pf_end, xfer_ready=ready,
            path_table=pt, bw_mult=mult, bg_util=bg,
            link_cap=tree.link_capacity.copy(),
            tier_lat=np.array([tree.tier_latency[t] for t in range(4)],
                              np.float64),
        )

    # ------------------------------------------------------------- the sweep
    def sweep(self, *, detail: bool = False) -> dict:
        """Run every scenario in one jitted, vmapped program.

        Returns a dict of per-scenario summary arrays (``ttft_mean``,
        ``ttft_p50/p95/p99``, ``tbt_mean``, ``slo_attainment``,
        ``goodput_rps``, ``n_measured``, ``n_served``); with
        ``detail=True`` adds per-request ``t_first``/``t_fin``/``tokens``
        (the vmap-consistency test surface).
        """
        import jax
        import jax.numpy as jnp

        out = self._sweep_jit()(
            jnp.asarray(self.arrival), jnp.asarray(self.s_eff),
            jnp.asarray(self.out_len), jnp.asarray(self.slo),
            jnp.asarray(self.src_p), jnp.asarray(self.prefill_end),
            jnp.asarray(self.xfer_ready), jnp.asarray(self.path_table),
            jnp.asarray(self.bw_mult), jnp.asarray(self.bg_util),
            jnp.asarray(self.link_cap), jnp.asarray(self.tier_lat),
            jnp.asarray(self.use_xfer), jnp.asarray(self.use_cong),
            jnp.asarray(self.beta_max), jnp.asarray(self.mem_total),
            jnp.asarray(self.m_min), jnp.asarray(self.kpt),
            jnp.asarray(self.iter_a), jnp.asarray(self.iter_b),
            jnp.asarray(self.w_cache), jnp.asarray(self.w_load),
            jnp.asarray(self.warmup_arr), jnp.asarray(self.measure_arr),
            jax.vmap(jax.random.PRNGKey)(jnp.asarray(self.seeds)),
        )
        res = {k: np.asarray(v) for k, v in out.items()}
        if not detail:
            for k in ("t_first", "t_fin", "tokens"):
                res.pop(k)
        return res

    def _sweep_jit(self):
        import jax

        if not hasattr(self, "_jitted"):
            one = lambda *a: _run_one(
                *a, tier_pd=self.tier_pd, dt=self.dt, n_steps=self.n_steps,
                use_pallas=(self.backend == "pallas"),
                interpret=self.interpret,
                link_tier=self._link_tier_c)
            self._jitted = jax.jit(jax.vmap(one))
        return self._jitted


def _run_one(arrival, s_eff, out_len, slo, src_p, prefill_end, xfer_ready,
             path_table, bw_mult, bg_util, link_cap, tier_lat, use_xfer,
             use_cong, beta_max, mem_total, m_min, kpt, iter_a, iter_b,
             w_cache, w_load, warmup, measure, key, *, tier_pd, dt, n_steps,
             use_pallas, interpret, link_tier):
    """One scenario's fluid run (traced once, vmapped over the batch)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.waterfill import waterfill_rates_fast

    R = arrival.shape[0]
    P, D, H = path_table.shape
    L = link_cap.shape[0]
    tier_pd = jnp.asarray(tier_pd, jnp.int32)
    link_tier_j = jnp.asarray(link_tier, jnp.int32)
    valid = jnp.isfinite(arrival)
    base_bw = _tier_base_bw(link_cap, link_tier_j)   # (4,) p50 per tier
    # Per-scenario RNG: a static tie-break jitter on the (request, decode)
    # cost surface, standing in for the event scheduler's arrival-order
    # tie-breaking (identical between batched and solo runs of a seed).
    jitter = jax.random.uniform(key, (R, D), jnp.float64) * 1e-9
    resid_base = link_cap * (1.0 - bg_util[link_tier_j])
    # Flow->link hop counts per (prefill, decode) pair, built once at trace
    # time: per-step routing is then a (R, L+1) gather instead of a one-hot
    # incidence rebuild, which dominated the vmapped step cost on CPU.
    inc_pd = (path_table[:, :, :, None]
              == jnp.arange(L + 1, dtype=path_table.dtype)[None, None,
                                                           None, :]
              ).sum(axis=2).astype(jnp.float64)
    inc_pd = inc_pd.at[:, :, L].set(0.0)

    def step(k, st):
        (tokens, live, inst, r_tier, xfer_rem, xfer_on, arrived, admitted,
         t_first, t_fin, pinned, credit, d_queued, tier_infl) = st
        t0 = k * dt
        t1 = t0 + dt
        d_active = _seg_count(inst, live, D)
        # --- A: decode-instance selection (Eqs. (2)-(7)) ------------------
        ready = valid & (xfer_ready <= t0) & (inst < 0)
        free_d = mem_total - pinned[:D]
        feas = free_d[None, :] >= (s_eff[:, None] + m_min)
        tier_rd = tier_pd[src_p]                      # (R, D)
        tier_bw = base_bw * bw_mult[k]                # derived p50 summary
        infl = jnp.where(use_cong > 0.5, tier_infl.astype(jnp.float64),
                         jnp.zeros(4))
        cong = jnp.where(use_cong > 0.5, bg_util, jnp.zeros(4))
        beff = tier_bw * (1.0 - cong) / (1.0 + infl)  # Eq. (4), per tier
        t_xfer = s_eff[:, None] / jnp.maximum(beff[tier_rd], 1e-9) \
            + tier_lat[tier_rd]                       # Eq. (3)
        t_it = iter_a + iter_b * d_active             # (D,)
        blocked = jnp.maximum(
            0.0, d_queued + d_active - beta_max)      # Eq. (6)
        t_queue = blocked * t_it
        t_dec = iter_a + iter_b * (d_active + 1.0)    # Eq. (7)
        cost_net = t_xfer + (t_queue + t_dec)[None, :]
        cost_cla = w_cache * 1.0 + w_load * (
            (d_active + d_queued) / jnp.maximum(beta_max, 1.0))[None, :]
        cost = jnp.where(use_xfer > 0.5, cost_net, cost_cla) + jitter
        cost = jnp.where(feas, cost, BIG)
        best = jnp.argmin(cost, axis=1).astype(jnp.int32)
        ok = ready & (cost[jnp.arange(R), best] < BIG * 0.5)
        # Sequential-decision emulation: at most max(1, open slots) new
        # dispatches per instance per dt; the rest retry next step.
        onehot = (ok[:, None] & (best[:, None] == jnp.arange(D)[None, :]))
        rank = (jnp.cumsum(onehot, axis=0) - onehot.astype(jnp.int64))[
            jnp.arange(R), best]
        slots = jnp.maximum(beta_max - d_active - d_queued, 1.0)
        take = ok & (rank < slots[best])
        inst = jnp.where(take, best, inst)
        new_tier = tier_rd[jnp.arange(R), best]
        r_tier = jnp.where(take, new_tier, r_tier)
        xfer_on = xfer_on | take
        d_queued = d_queued + _seg_count(best, take, D)
        tier_infl = tier_infl + _seg_count(new_tier, take, 4)
        # --- B: max-min fair transfer drain (the jitted water-filling) ----
        caps = jnp.append(resid_base * bw_mult[k][link_tier_j], jnp.inf)
        # Parallel-bottleneck variant: identical max-min allocation, but
        # ~levels while_loop rounds instead of one per concurrent transfer
        # (the sweep's dominant cost; see kernels/waterfill.py).
        nhops = inc_pd[src_p, jnp.clip(inst, 0, D - 1)]
        rates = waterfill_rates_fast(
            None, caps, xfer_on, nhops=nhops,
            use_pallas=use_pallas, interpret=interpret)
        xfer_rem = jnp.where(
            xfer_on, jnp.maximum(xfer_rem - rates.astype(jnp.float64) * dt,
                                 0.0), xfer_rem)
        done = xfer_on & (xfer_rem <= 1.0) & (t1 >= prefill_end)
        xfer_on = xfer_on & ~done
        arrived = arrived | done
        tier_infl = tier_infl - _seg_count(r_tier, done, 4)
        # --- C: FCFS admission into the decode batch ----------------------
        wait = arrived & ~admitted
        inst_c = jnp.clip(inst, 0, D - 1)
        oh_w = wait[:, None] & (inst_c[:, None] == jnp.arange(D)[None, :])
        rank_w = (jnp.cumsum(oh_w, axis=0) - oh_w.astype(jnp.int64))[
            jnp.arange(R), inst_c]
        cum_mem = (jnp.cumsum(oh_w * s_eff[:, None], axis=0)
                   - oh_w * s_eff[:, None])[jnp.arange(R), inst_c]
        admit = wait & (rank_w < (beta_max - d_active)[inst_c]) & (
            pinned[inst_c] + cum_mem + s_eff <= mem_total - m_min)
        admitted = admitted | admit
        live = live | admit
        pinned = pinned.at[jnp.where(admit, inst_c, D)].add(
            jnp.where(admit, s_eff, 0.0))
        d_queued = d_queued - _seg_count(inst_c, admit, D)
        d_active = _seg_count(inst, live, D)
        # --- D: continuous-batching iteration clock + cohort step ---------
        t_it = iter_a + iter_b * d_active
        credit = credit + dt
        fire = (credit >= t_it) & (d_active > 0)
        credit = jnp.where(fire, credit - t_it, credit)
        tokens, live, pinned, first, fin, _ = cohort_step(
            tokens, out_len, inst, jnp.arange(R, dtype=jnp.int64),
            s_eff + out_len * kpt, live, fire, pinned,
            kv_per_token=kpt, exact_clamp=False)
        t_first = jnp.where(first & (t_first < 0), t1, t_first)
        t_fin = jnp.where(fin, t1, t_fin)
        return (tokens, live, inst, r_tier, xfer_rem, xfer_on, arrived,
                admitted, t_first, t_fin, pinned, credit, d_queued, tier_infl)

    st0 = (
        jnp.zeros(R, jnp.int64),                    # tokens
        jnp.zeros(R, bool),                         # live (decoding)
        jnp.full(R, -1, jnp.int32),                 # decode instance
        jnp.zeros(R, jnp.int32),                    # transfer tier
        s_eff.astype(jnp.float64),                  # xfer bytes remaining
        jnp.zeros(R, bool),                         # transfer active
        jnp.zeros(R, bool),                         # KV landed
        jnp.zeros(R, bool),                         # admitted to batch
        jnp.full(R, -1.0, jnp.float64),             # first-token time
        jnp.full(R, -1.0, jnp.float64),             # finish time
        jnp.zeros(D + 1, jnp.float64),              # pinned KV (+pad slot)
        jnp.zeros(D, jnp.float64),                  # iteration credit
        jnp.zeros(D, jnp.int64),                    # scheduled, not admitted
        jnp.zeros(4, jnp.int64),                    # own in-flight per tier
    )
    st = jax.lax.fori_loop(0, n_steps, step, st0)
    (tokens, live, inst, _, _, _, _, admitted, t_first, t_fin, *_rest) = st
    return _summarize(arrival, slo, out_len, t_first, t_fin, tokens,
                      warmup, measure, valid)


def _seg_count(idx, mask, n):
    import jax.numpy as jnp

    return jnp.zeros(n + 1, jnp.int64).at[
        jnp.where(mask, jnp.clip(idx, 0, n - 1), n)].add(1)[:n]


def _tier_base_bw(link_cap, link_tier):
    """p50 per-tier capacity of the columnar link table (the oracle's
    derived tier_bandwidth summary, computed in-program)."""
    import jax.numpy as jnp

    out = []
    for t in range(4):
        sel = link_tier == t
        big = jnp.where(sel, link_cap, jnp.nan)
        out.append(jnp.nanmedian(big))
    return jnp.stack(out)


def _masked_pct(x, mask, q, r):
    import jax.numpy as jnp

    n = mask.sum()
    s = jnp.sort(jnp.where(mask, x, jnp.inf))
    pos = (q / 100.0) * jnp.maximum(n - 1, 0)
    lo = jnp.clip(jnp.floor(pos).astype(jnp.int64), 0, r - 1)
    hi = jnp.clip(jnp.ceil(pos).astype(jnp.int64), 0, r - 1)
    frac = pos - jnp.floor(pos)
    v = s[lo] * (1.0 - frac) + s[hi] * frac
    return jnp.where(n > 0, v, jnp.nan)


def _summarize(arrival, slo, out_len, t_first, t_fin, tokens, warmup,
               measure, valid):
    import jax.numpy as jnp

    r = arrival.shape[0]
    meas = valid & (arrival >= warmup) & (arrival < warmup + measure)
    served = meas & (t_first >= 0)
    ttft = jnp.where(served, t_first - arrival, jnp.inf)
    fin_ok = meas & (t_fin >= 0) & (out_len > 1)
    tbt = jnp.where(fin_ok, (t_fin - t_first)
                    / jnp.maximum(out_len - 1, 1).astype(jnp.float64),
                    jnp.inf)
    n_meas = meas.sum()
    n_served = served.sum()
    slo_ok = (served & (ttft <= slo)).sum()
    mean = lambda v, m: jnp.where(
        m.sum() > 0, jnp.where(m, v, 0.0).sum() / jnp.maximum(m.sum(), 1),
        jnp.nan)
    return dict(
        n_measured=n_meas,
        n_served=n_served,
        ttft_mean=mean(ttft, served),
        ttft_p50=_masked_pct(ttft, served, 50.0, r),
        ttft_p95=_masked_pct(ttft, served, 95.0, r),
        ttft_p99=_masked_pct(ttft, served, 99.0, r),
        tbt_mean=mean(tbt, fin_ok),
        slo_attainment=jnp.where(
            n_meas > 0, slo_ok / jnp.maximum(n_meas, 1), jnp.nan),
        goodput_rps=slo_ok / jnp.maximum(measure, 1e-9),
        t_first=t_first,
        t_fin=t_fin,
        tokens=tokens,
    )
