"""Mooncake-statistics synthetic trace generation (§VI-A; DESIGN.md §5).

The container is offline, so the 23K-request Mooncake trace is synthesised to
its published marginal statistics: bursty arrivals (two-state MMPP), a
heavy-tailed log-normal input-length mixture, log-normal output lengths, and
prefix sharing with probability p_share drawn from a Zipf pool of shared
prefixes.  Timestamps are compressed by a single multiplicative factor to
achieve the target arrival rate while preserving burstiness — the paper's
procedure verbatim.

Three workload profiles (§VI-A):

  chatbot       inputs <= 8K,        p_share = 0.3, TTFT SLO 2 s
  rag           inputs in [4K, 64K], p_share = 0.7, TTFT SLO 5 s
  long_context  inputs > 16K,        p_share = 0.1, TTFT SLO 10 s
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.cost import B_TOK, n_blocks


@dataclasses.dataclass(frozen=True)
class Profile:
    name: str
    min_input: int
    max_input: int
    p_share: float
    slo: float          # TTFT SLO, seconds
    out_mu: float       # log-normal output length params
    out_sigma: float


PROFILES = {
    "chatbot": Profile("chatbot", 16, 8_192, 0.30, 2.0, np.log(220.0), 0.8),
    "rag": Profile("rag", 4_096, 65_536, 0.70, 5.0, np.log(180.0), 0.7),
    "long_context": Profile("long_context", 16_385, 131_072, 0.10, 10.0, np.log(140.0), 0.7),
}


@dataclasses.dataclass
class Request:
    request_id: int
    arrival: float
    input_len: int
    output_len: int
    block_hashes: tuple
    share_group: int    # -1 = unshared
    slo: float


def _sample_input_lengths(rng: np.random.Generator, n: int, prof: Profile) -> np.ndarray:
    """Heavy-tailed mixture matching the Mooncake length histogram shape:
    a body of conversational lengths and a long RAG/document tail."""
    body = rng.lognormal(mean=np.log(2600.0), sigma=1.0, size=n)
    tail = rng.lognormal(mean=np.log(14000.0), sigma=0.7, size=n)
    pick_tail = rng.random(n) < 0.25
    lens = np.where(pick_tail, tail, body)
    # Rejection-free: clip into the profile filter window.
    return np.clip(lens, prof.min_input, prof.max_input).astype(np.int64)


def _mmpp_arrivals(rng: np.random.Generator, n: int, base_rate: float,
                   burst_factor: float = 4.0, dwell_calm: float = 1.2,
                   dwell_burst: float = 0.35) -> np.ndarray:
    """Two-state Markov-modulated Poisson arrivals (bursty, like the trace)."""
    times = np.empty(n)
    t, state = 0.0, 0
    state_end = rng.exponential(dwell_calm)
    for i in range(n):
        rate = base_rate * (burst_factor if state == 1 else 1.0)
        t += rng.exponential(1.0 / rate)
        while t > state_end:
            state = 1 - state
            state_end = t + rng.exponential(dwell_burst if state == 1 else dwell_calm)
        times[i] = t
    return times


def generate_trace(
    profile: str | Profile,
    *,
    duration: float,
    target_rps: float,
    seed: int = 0,
    p_share: float | None = None,
    input_len_override: int | None = None,
    n_share_groups: int = 48,
    zipf_a: float = 1.4,
) -> list[Request]:
    """Synthesise a trace of ``duration`` seconds at ``target_rps`` mean rate.

    ``p_share`` / ``input_len_override`` support Experiments 5 and 2.
    """
    prof = PROFILES[profile] if isinstance(profile, str) else profile
    if p_share is None:
        p_share = prof.p_share
    rng = np.random.default_rng(seed)
    n = max(int(duration * target_rps * 1.3) + 8, 8)
    raw = _mmpp_arrivals(rng, n, base_rate=max(target_rps, 1e-6) / 1.9)
    # Single multiplicative compression to the target rate over the window.
    span = raw[-1] - raw[0]
    want_n = max(int(duration * target_rps), 1)
    arrivals = (raw - raw[0]) * (duration / span) * (n / max(want_n, 1))
    arrivals = arrivals[arrivals < duration][:want_n * 2]

    m = len(arrivals)
    if input_len_override is not None:
        in_lens = np.full(m, int(input_len_override), dtype=np.int64)
    else:
        in_lens = _sample_input_lengths(rng, m, prof)
    out_lens = np.clip(
        rng.lognormal(prof.out_mu, prof.out_sigma, size=m), 1, 2048
    ).astype(np.int64)

    # Shared-prefix pool: group id ~ Zipf, per-group prefix length in blocks.
    group_prefix_blocks = rng.integers(
        low=max(2, prof.min_input // (2 * B_TOK)),
        high=max(3, prof.max_input // (2 * B_TOK)),
        size=n_share_groups,
    )
    reqs: list[Request] = []
    for i in range(m):
        l_in = int(in_lens[i])
        blocks = n_blocks(l_in)
        if rng.random() < p_share:
            g = int(min(rng.zipf(zipf_a), n_share_groups) - 1)
            pb = int(min(group_prefix_blocks[g], max(blocks - 1, 1)))
            hashes = tuple(("g", g, j) for j in range(pb)) + tuple(
                ("r", i, j) for j in range(blocks - pb)
            )
        else:
            g = -1
            hashes = tuple(("r", i, j) for j in range(blocks))
        reqs.append(
            Request(
                request_id=i,
                arrival=float(arrivals[i]),
                input_len=l_in,
                output_len=int(out_lens[i]),
                block_hashes=hashes,
                share_group=g,
                slo=prof.slo,
            )
        )
    return reqs


def calibrated_capacity_rps(
    *,
    n_prefill: int,
    n_decode: int,
    beta_max: int,
    mean_input: float,
    mean_output: float,
    prefill_model,
    iter_model,
    kv_bytes_per_token: float = 0.0,
    mean_hit_frac: float = 0.0,
    egress_bytes_per_s: float = float("inf"),
    headroom: float = 0.85,
) -> float:
    """Analytic 100 %-capacity point (requests/s) for rate sweeps.

    Prefill: n_p serial instances, each 1/T_prefill(E[l]) rps.
    Decode:  each instance completes beta_max requests per E[out] iterations.
    Network: the prefill rack's ToR egress divided by the mean effective
             transfer size (the binding resource for long-context profiles).
    """
    prefill_rps = n_prefill / prefill_model(mean_input)
    decode_rps = n_decode * beta_max / (mean_output * iter_model(beta_max))
    if kv_bytes_per_token > 0 and egress_bytes_per_s != float("inf"):
        mean_eff = kv_bytes_per_token * mean_input * (1.0 - mean_hit_frac)
        net_rps = egress_bytes_per_s * headroom / max(mean_eff, 1.0)
    else:
        net_rps = float("inf")
    return min(prefill_rps, decode_rps, net_rps)


def empirical_means(profile: str, seed: int = 0, n: int = 4000) -> tuple[float, float]:
    prof = PROFILES[profile]
    rng = np.random.default_rng(seed)
    ins = _sample_input_lengths(rng, n, prof)
    outs = np.clip(rng.lognormal(prof.out_mu, prof.out_sigma, size=n), 1, 2048)
    return float(ins.mean()), float(outs.mean())


def profile_capacity(profile: str, *, n_prefill: int = 4, n_decode: int = 12,
                     beta_max: int = 64, kv_bytes_per_token: float = 327_680.0,
                     tor_egress_bytes_per_s: float = 8 * 50e9 / 8,
                     agg_egress_bytes_per_s: float = 8 * 25e9 / 8,
                     tier3_frac: float = 0.67, background: float = 0.2,
                     headroom: float = 0.35,
                     prefill_model=None, iter_model=None, seed: int = 0) -> float:
    """Per-workload calibrated capacity (the sweeps' 100 % point).

    The network term uses the *binding* fabric constraint under
    topology-agnostic routing: either the prefill rack's ToR egress, or the
    pod agg layer carrying ``tier3_frac`` of the traffic (uniform candidate
    choice sends 8/12 of transfers cross-pod).  ``headroom`` absorbs MMPP
    burstiness and ECMP imbalance so that 100 % sits at the knee, not past
    it — the paper's sweeps remain meaningful up to 250 %.
    """
    from repro.core.cost import H100_TP4_ITER, H100_TP4_PREFILL

    prof = PROFILES[profile]
    mi, mo = empirical_means(profile, seed=seed)
    fabric = min(tor_egress_bytes_per_s, agg_egress_bytes_per_s / max(tier3_frac, 1e-6))
    fabric *= (1.0 - background)
    return calibrated_capacity_rps(
        n_prefill=n_prefill, n_decode=n_decode, beta_max=beta_max,
        mean_input=mi, mean_output=mo,
        prefill_model=prefill_model or H100_TP4_PREFILL,
        iter_model=iter_model or H100_TP4_ITER,
        kv_bytes_per_token=kv_bytes_per_token,
        mean_hit_frac=prof.p_share * 0.55,
        egress_bytes_per_s=fabric,
        headroom=headroom,
    )
