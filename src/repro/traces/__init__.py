"""Workload traces: Mooncake-statistics synthetic generator + profiles."""

from .mooncake import PROFILES, Profile, Request, calibrated_capacity_rps, empirical_means, generate_trace, profile_capacity

__all__ = ["PROFILES", "Profile", "Request", "calibrated_capacity_rps", "empirical_means", "generate_trace", "profile_capacity"]
