"""Pallas TPU rwkv_scan: chunked WKV-6 recurrence.

RWKV-6 prefill is a sequential recurrence over time; the pure-jnp path
(repro.models.rwkv) scans one token at a time with the (dh x dh) state in
HBM-resident carry.  This kernel processes ``chunk`` tokens per grid step
with the state held in VMEM scratch across the sequential chunk axis, so
the state never round-trips HBM — the TPU-hierarchy adaptation of the
CUDA wkv kernel (which keeps state in registers/shared memory).

Inputs r,k,v,w: (B, T, H, dh); u: (H, dh).  Outputs y: (B, T, H, dh) and the
final state (B, H, dh, dh) for decode handoff / state transfer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax moved TPUCompilerParams -> CompilerParams across releases;
# resolve whichever this version ships.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_scr,
                 *, chunk: int):
    ci = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0].astype(jnp.float32)                     # (dh,)

    def step(t, state):
        r = r_ref[0, t, 0, :].astype(jnp.float32)        # (dh,)
        k = k_ref[0, t, 0, :].astype(jnp.float32)
        v = v_ref[0, t, 0, :].astype(jnp.float32)
        w = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = k[:, None] * v[None, :]                     # (dh, dh)
        y = jnp.sum(r[:, None] * (state + u[:, None] * kv), axis=0)
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        return state * w[:, None] + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(ci == n_c - 1)
    def _finish():
        s_out_ref[0, 0] = state_scr[...].astype(s_out_ref.dtype)


def rwkv_scan(r, k, v, w, u, *, chunk: int = 128, interpret: bool = False):
    """Chunked WKV-6.  r/k/v/w: (B, T, H, dh) with w the per-step decay in
    (0,1); u: (H, dh) bonus.  Returns (y, final_state)."""
    b, t, h, dh = r.shape
    assert t % chunk == 0, (t, chunk)
    grid = (b, h, t // chunk)
    kernel = functools.partial(_rwkv_kernel, chunk=chunk)
    in_spec = pl.BlockSpec((1, chunk, 1, dh), lambda bi, hi, ci: (bi, ci, hi, 0))
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec, in_spec,
                  pl.BlockSpec((1, dh), lambda bi, hi, ci: (hi, 0))],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, dh), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, dh), r.dtype),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_out
