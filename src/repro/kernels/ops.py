"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels run compiled (Mosaic); on CPU they execute in
``interpret=True`` mode, which runs the kernel body op-by-op and is the
validation path in this container.  ``force_reference=True`` switches to the
pure-jnp oracle (used by the serving engine when kernels are disabled).
"""

from __future__ import annotations

import functools

import jax

from . import ref as _ref
from .flash_decode import flash_decode as _flash_decode
from .kv_pack import kv_pack as _kv_pack, kv_unpack as _kv_unpack
from .netkv_score import netkv_score as _netkv_score
from .rwkv_scan import rwkv_scan as _rwkv_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_s", "force_reference"))
def flash_decode(q, k_cache, v_cache, pos, *, block_s: int = 512,
                 force_reference: bool = False):
    if force_reference:
        return _ref.flash_decode_ref(q, k_cache, v_cache, pos)
    return _flash_decode(q, k_cache, v_cache, pos, block_s=block_s,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("force_reference",))
def kv_pack(pool, block_table, *, force_reference: bool = False):
    if force_reference:
        return _ref.kv_pack_ref(pool, block_table)
    return _kv_pack(pool, block_table, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("force_reference",), donate_argnums=(0,))
def kv_unpack(pool, buf, block_table, *, force_reference: bool = False):
    if force_reference:
        return _ref.kv_unpack_ref(pool, buf, block_table)
    return _kv_unpack(pool, buf, block_table, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=(
    "s_r", "input_len", "iter_a", "iter_b", "m_min", "beta_max", "force_reference"))
def _netkv_score_jit(free_mem, queued, batch, hit_tokens, tier, healthy, iter_scale,
                     tier_bw, tier_lat, congestion, n_inflight, *,
                     s_r, input_len, iter_a, iter_b, m_min, beta_max,
                     force_reference):
    kw = dict(s_r=s_r, input_len=input_len, iter_a=iter_a, iter_b=iter_b,
              m_min=m_min, beta_max=beta_max)
    if force_reference:
        return _ref.netkv_score_ref(
            free_mem, queued, batch, hit_tokens, tier, healthy, iter_scale,
            tier_bw, tier_lat, congestion, n_inflight, **kw)
    return _netkv_score(
        free_mem, queued, batch, hit_tokens, tier, healthy, iter_scale,
        tier_bw, tier_lat, congestion, n_inflight,
        interpret=_interpret(), **kw)


def netkv_score(free_mem, queued, batch, hit_tokens, tier, healthy, iter_scale,
                tier_bw, tier_lat, congestion, n_inflight, *,
                s_r: float, input_len: float, iter_a: float, iter_b: float,
                m_min: float, beta_max: int, force_reference: bool = False):
    import jax.numpy as jnp

    arrs = [jnp.asarray(a) for a in (free_mem, queued, batch, hit_tokens, tier,
                                     healthy, iter_scale, tier_bw, tier_lat,
                                     congestion, n_inflight)]
    return _netkv_score_jit(*arrs, s_r=s_r, input_len=input_len, iter_a=iter_a,
                            iter_b=iter_b, m_min=m_min, beta_max=beta_max,
                            force_reference=force_reference)


@functools.partial(jax.jit, static_argnames=("chunk", "force_reference"))
def rwkv_scan(r, k, v, w, u, *, chunk: int = 128, force_reference: bool = False):
    if force_reference:
        return _ref.rwkv_scan_ref(r, k, v, w, u)
    return _rwkv_scan(r, k, v, w, u, chunk=chunk, interpret=_interpret())
