"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_decode_ref(q, k_cache, v_cache, pos):
    """q: (B,H,dh); k/v: (B,S,KV,dh); pos: scalar -> (B,H,dh)."""
    b, h, dh = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    qg = q.reshape(b, kv, g, dh).astype(jnp.float32)
    kx = k_cache.astype(jnp.float32)
    vx = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, kx) * dh ** -0.5
    mask = jnp.arange(s)[None, None, None, :] < pos
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vx)
    return out.reshape(b, h, dh).astype(q.dtype)


def kv_pack_ref(pool, block_table):
    return jnp.take(pool, jnp.asarray(block_table, jnp.int32), axis=0)


def kv_unpack_ref(pool, buf, block_table):
    return pool.at[jnp.asarray(block_table, jnp.int32)].set(buf)


def netkv_score_ref(free_mem, queued, batch, hit_tokens, tier, healthy, iter_scale,
                    tier_bw, tier_lat, congestion, n_inflight,
                    *, s_r, input_len, iter_a, iter_b, m_min, beta_max):
    """Identical arithmetic to repro.core.netkv_jax.score_pool."""
    free_mem = jnp.asarray(free_mem, jnp.float32)
    hit = jnp.minimum(jnp.asarray(hit_tokens, jnp.float32), input_len)
    s_eff = s_r * (1.0 - hit / max(input_len, 1.0))
    tier = jnp.asarray(tier, jnp.int32)
    bw = jnp.asarray(tier_bw, jnp.float32)[tier]
    lat = jnp.asarray(tier_lat, jnp.float32)[tier]
    cong = jnp.asarray(congestion, jnp.float32)[tier]
    infl = jnp.asarray(n_inflight, jnp.float32)[tier]
    beff = bw * (1.0 - cong) / (1.0 + infl)
    t_xfer = s_eff / jnp.maximum(beff, 1e-9) + lat
    batch = jnp.asarray(batch, jnp.float32)
    scale = jnp.asarray(iter_scale, jnp.float32)
    t_iter = (iter_a + iter_b * batch) * scale
    blocked = jnp.maximum(0.0, jnp.asarray(queued, jnp.float32) - (beta_max - batch))
    t_queue = blocked * t_iter
    t_dec = (iter_a + iter_b * (batch + 1.0)) * scale
    cost = t_xfer + t_queue + t_dec
    feasible = (jnp.asarray(healthy, jnp.float32) > 0.5) & (free_mem >= s_eff + m_min)
    cost = jnp.where(feasible, cost, 3.0e38)
    return cost, jnp.argmin(cost).astype(jnp.int32)


def rwkv_scan_ref(r, k, v, w, u):
    """Sequential WKV-6 reference.  r/k/v/w: (B,T,H,dh); u: (H,dh)."""
    b, t, h, dh = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs                          # (B,H,dh)
        kv = k_t[..., :, None] * v_t[..., None, :]       # (B,H,dh,dh)
        y = jnp.sum(r_t[..., :, None] * (state + uf[None, :, :, None] * kv), axis=-2)
        state = state * w_t[..., :, None] + kv
        return state, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))
    s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), final
