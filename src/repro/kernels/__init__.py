"""Pallas TPU kernels (validated in interpret mode on CPU):

  flash_decode  GQA decode attention over long KV caches (online softmax)
  kv_pack       paged-KV gather -> contiguous transfer buffer (FlowKV on TPU)
  kv_unpack     decode-side scatter back into the page pool
  netkv_score   Algorithm 1 scoring + masked argmin, fused
  rwkv_scan     chunked WKV-6 recurrence with VMEM-resident state
  waterfill     FlowPlane's max-min fixed point as a jitted while_loop
                (Pallas share/argmin inner reduction; f64 jax path is
                bit-exact vs the NumPy plane)
"""

from . import ops, ref
from .ops import flash_decode, kv_pack, kv_unpack, netkv_score, rwkv_scan
from .waterfill import waterfill_fixed_point, waterfill_rates, waterfill_rates_fast

__all__ = ["ops", "ref", "flash_decode", "kv_pack", "kv_unpack", "netkv_score",
           "rwkv_scan", "waterfill_fixed_point", "waterfill_rates",
           "waterfill_rates_fast"]
