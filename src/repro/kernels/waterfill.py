"""JAX/Pallas progressive water-filling: FlowPlane's fixed point, jitted.

``FlowPlane._recompute_rates`` is the per-event hot loop of the network
model: each round divides residual link capacities by unfixed-flow counts,
argmins for the bottleneck link (first-encounter tie-break), fixes every
unfixed flow crossing it at the bottleneck share, and subtracts that share
from the capacities along their paths.  This module re-expresses the whole
fixed point as a ``lax.while_loop`` over padded fixed-width tables so it can
be jitted, ``vmap``ed over a scenario axis, and fused into the ScenarioPlane
sweep program (``sim/scenarios.py``).

Bit-exactness (``backend="jax"``, f64): the JAX path reproduces the NumPy
plane's rates and per-round bottleneck (link, share) sequence exactly:

* the encounter permutation is rebuilt with ``.at[flat].min`` + stable
  argsort — inactive (masked) rows are routed to the pad link, which never
  participates in the argmin (count 0 -> share inf), and the *relative*
  order of real links is unchanged, so the first-minimum tie-break matches;
* per-round capacity updates subtract the *same* share from each target, so
  XLA's scatter-add order cannot change the result; count updates are exact
  integers;
* shares are single f64 divisions and the argmin picks the first minimum in
  scan order — IEEE-identical to ``np.argmin`` on CPU.

``backend="pallas"`` swaps the inner share/argmin reduction for a TPU
Pallas kernel (f32, ``interpret=True`` off-TPU, following the
``netkv_score`` pattern) and is tolerance-tested, not bit-exact — the NumPy
plane stays the parity oracle either way.

The loop body is a no-op once a problem instance converges (all shares inf
-> zero deltas, rates untouched), which is what makes ``vmap`` over a batch
of instances with different round counts sound: converged lanes idle while
stragglers finish.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.jaxutil import enable_f64

LANES = 128
BIG = 3.0e38


# ------------------------------------------------------------ Pallas kernel
def _share_argmin_kernel(caps_ref, counts_ref, best_ref, share_ref, *,
                         n_real: int):
    """shares = caps/counts where counts>0 (else BIG); emit (argmin, min)."""
    caps = caps_ref[...]
    counts = counts_ref[...]
    lane = jax.lax.broadcasted_iota(jnp.int32, caps.shape, 1)
    ok = (counts > 0.0) & (lane < n_real)
    shares = jnp.where(ok, caps / jnp.where(ok, counts, 1.0), BIG)
    best_ref[0, 0] = jnp.argmin(shares[0]).astype(jnp.int32)
    # min == shares[argmin] bitwise; a reduction avoids a dynamic gather.
    share_ref[0, 0] = jnp.min(shares)


def _pallas_share_argmin(caps_p, counts, interpret: bool):
    """One water-filling round's bottleneck pick as a fused VMEM pass."""
    n = caps_p.shape[0]
    dp = -(-n // LANES) * LANES
    pad = dp - n
    c = jnp.asarray(caps_p, jnp.float32)
    k = jnp.asarray(counts, jnp.float32)
    if pad:
        c = jnp.pad(c, (0, pad))
        k = jnp.pad(k, (0, pad))
    kern = functools.partial(_share_argmin_kernel, n_real=n)
    best, share = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, dp), lambda i: (0, 0))] * 2,
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(c.reshape(1, dp), k.reshape(1, dp))
    return best[0, 0], share[0, 0]


# ------------------------------------------------------------- fixed point
def waterfill_fixed_point(paths, caps, active, *, use_pallas: bool = False,
                          interpret: bool = False):
    """Traceable max-min fair fixed point (jit/vmap-safe, fixed shapes).

    Args:
      paths: (F, H) int32 link ids per flow; short paths padded with the
        virtual pad link ``L`` (``caps.shape[0] - 1``).
      caps: (L + 1,) residual link capacities, ``caps[L] = +inf``.
      active: (F,) bool; inactive rows get rate 0 and touch nothing.

    Returns ``(rates (F,), trace_links (F,), trace_shares (F,), n_rounds)``
    where the trace records each round's bottleneck in *original* link ids
    (−1 padding past ``n_rounds``) — the sequence
    ``FlowPlane._recompute_rates`` logs into ``_wf_trace``.
    """
    F, H = paths.shape
    lp1 = caps.shape[0]
    pad_link = lp1 - 1
    dtype = jnp.float32 if use_pallas else caps.dtype
    caps = caps.astype(dtype)
    active = active.astype(bool)
    P0 = jnp.where(active[:, None], paths.astype(jnp.int32),
                   jnp.int32(pad_link))
    flat = P0.ravel()
    npos = flat.shape[0]
    # First-encounter order (flow-creation x hop): the reference tie-break.
    enc = jnp.full(lp1, npos + 1, jnp.int64)
    enc = enc.at[flat].min(jnp.arange(npos, dtype=jnp.int64))
    perm = jnp.argsort(enc, stable=True)
    inv = jnp.zeros(lp1, jnp.int32).at[perm].set(
        jnp.arange(lp1, dtype=jnp.int32))
    P = inv[P0]
    counts0 = jnp.zeros(lp1, jnp.int64).at[P.ravel()].add(1)
    ppad = inv[pad_link]
    counts0 = counts0.at[ppad].set(0)
    caps_p0 = caps[perm]
    tr_n = max(F, 1)
    state = (
        jnp.zeros(F, dtype),                       # rates
        active,                                    # unfixed
        caps_p0,
        counts0,
        jnp.full(tr_n, -1, jnp.int32),             # trace: bottleneck links
        jnp.full(tr_n, jnp.inf, dtype),            # trace: bottleneck shares
        jnp.int32(0),                              # rounds completed
        active.sum(dtype=jnp.int32),               # flows still unfixed
    )

    def cond(st):
        return st[7] > 0

    def body(st):
        rates, unfixed, caps_p, counts, tl, ts, r, nuf = st
        if use_pallas:
            lid, share = _pallas_share_argmin(caps_p, counts, interpret)
            share = share.astype(dtype)
            is_inf = share >= jnp.array(BIG * 0.5, dtype)
        else:
            shares = jnp.where(counts > 0, caps_p / counts.astype(dtype),
                               jnp.array(jnp.inf, dtype))
            lid = jnp.argmin(shares).astype(jnp.int32)
            share = shares[lid]
            is_inf = jnp.isinf(share)
        onb = unfixed & (P == lid).any(axis=1)
        newly = jnp.where(is_inf, unfixed, onb)    # inf: reference breaks,
        rates = jnp.where(                         # stranding rest at inf
            newly, jnp.where(is_inf, jnp.array(jnp.inf, dtype), share), rates)
        # Fixed rows subtract along their whole padded path (pad hops land
        # on ppad, capacity +inf — mirroring the reference); non-fixed rows
        # are routed to ppad with the same share, a pure no-op.
        sub = jnp.where(is_inf, jnp.array(0, dtype), share)
        idx = jnp.where((onb & ~is_inf)[:, None], P, ppad).ravel()
        caps_p = jnp.maximum(caps_p.at[idx].add(-sub), 0.0)
        counts = counts.at[idx].add(-1)
        nfixed = newly.sum(dtype=jnp.int32)
        nuf = jnp.where(is_inf, jnp.int32(0), nuf - nfixed)
        unfixed = unfixed & ~newly
        tl = tl.at[r].set(jnp.where(is_inf, tl[r],
                                    perm[lid].astype(jnp.int32)))
        ts = ts.at[r].set(jnp.where(is_inf, ts[r], share))
        r = r + jnp.where(is_inf, jnp.int32(0), jnp.int32(1))
        return (rates, unfixed, caps_p, counts, tl, ts, r, nuf)

    rates, _, _, _, tl, ts, r, _ = jax.lax.while_loop(cond, body, state)
    return rates, tl, ts, r


# ------------------------------------------------- parallel fixed point
def _shares_kernel(caps_ref, counts_ref, out_ref):
    """Elementwise fair shares: caps/counts where counts>0, BIG elsewhere."""
    caps = caps_ref[...]
    counts = counts_ref[...]
    ok = counts > 0.0
    out_ref[...] = jnp.where(ok, caps / jnp.where(ok, counts, 1.0), BIG)


def _pallas_shares(caps, counts, interpret: bool):
    n = caps.shape[0]
    dp = -(-n // LANES) * LANES
    pad = dp - n
    c = jnp.asarray(caps, jnp.float32)
    k = jnp.asarray(counts, jnp.float32)
    if pad:
        c = jnp.pad(c, (0, pad))
        k = jnp.pad(k, (0, pad))
    out = pl.pallas_call(
        _shares_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, dp), lambda i: (0, 0))] * 2,
        out_specs=pl.BlockSpec((1, dp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(c.reshape(1, dp), k.reshape(1, dp))
    return out[0, :n]


def waterfill_rates_fast(paths, caps, active, *, nhops=None,
                         use_pallas: bool = False, interpret: bool = False):
    """Parallel-bottleneck max-min fixed point: same allocation, ~levels
    rounds instead of ~flows rounds, scatter-free dense rounds.

    The progressive solver (:func:`waterfill_fixed_point`) fixes **one**
    bottleneck link per round to reproduce the reference's per-round trace
    — so F link-disjoint transfers cost F rounds even though they are
    independent.  This variant applies the classic parallel water-filling
    step instead: every link whose fair share is minimal along *all* of
    its unfixed flows' paths is a level bottleneck, and all of them fix
    simultaneously.  The max-min allocation is unique, so the rates agree
    with the progressive solver (up to residual-subtraction rounding —
    tolerance-tested, not bitwise); the per-round trace is not defined
    here.

    Everything runs on the dense flow->link incidence table ``nhops``
    (F, L + 1): each round's unfixed-flow counts and consumed capacities
    are matvecs and the per-flow/per-link minima are masked reduces — no
    scatters, whose element-serial CPU lowering under ``vmap`` dominated
    the ScenarioPlane sweep's step cost.  Callers with static routing can
    pass ``nhops`` precomputed (hops of flow f on link l; the pad column
    is re-zeroed and inactive rows masked here), skipping the one-hot
    build — the ScenarioPlane gathers per-(prefill, decode) incidence
    rows instead of rebuilding them every dt step.
    """
    lp1 = caps.shape[0]
    pad_link = lp1 - 1
    dtype = jnp.float32 if use_pallas else caps.dtype
    caps0 = caps.astype(dtype)
    active = active.astype(bool)
    inf = jnp.array(jnp.inf, dtype)
    if nhops is None:
        P = jnp.where(active[:, None], paths.astype(jnp.int32),
                      jnp.int32(pad_link))
        nhops = (P[:, :, None]
                 == jnp.arange(lp1, dtype=jnp.int32)[None, None, :]
                 ).sum(axis=1).astype(dtype)
    else:
        nhops = jnp.where(active[:, None], nhops.astype(dtype), 0)
    nhops = nhops.at[:, pad_link].set(0)
    F = nhops.shape[0]
    on_f = nhops > 0.5                             # (F, lp1) once per call
    state = (
        jnp.zeros(F, dtype),                       # rates (fixed flows)
        active,                                    # unfixed
        active.sum(dtype=jnp.int32),               # flows still unfixed
    )

    def cond(st):
        return st[2] > 0

    def body(st):
        rates, unfixed, nuf = st
        counts = unfixed.astype(dtype) @ nhops               # (lp1,)
        used = jnp.where(jnp.isfinite(rates), rates,
                         jnp.array(0, dtype)) @ nhops
        caps_c = jnp.maximum(caps0 - used, 0.0)
        if use_pallas:
            shares = _pallas_shares(caps_c, counts, interpret).astype(dtype)
            shares = jnp.where(shares >= jnp.array(BIG * 0.5, dtype), inf,
                               shares)
        else:
            shares = jnp.where(counts > 0.5, caps_c / counts, inf)
        live = on_f & unfixed[:, None]
        sfmat = jnp.where(live, shares[None, :], inf)        # (F, lp1)
        s_f = sfmat.min(axis=1)                    # per-flow bottleneck share
        # Per-link min of its unfixed flows' bottleneck shares: link l is a
        # level bottleneck iff share_l <= that min, i.e. every flow on l
        # has its path minimum at l.
        lfm = jnp.where(live, s_f[:, None], inf).min(axis=0)
        fixable = (counts > 0.5) & (shares <= lfm)
        fix = unfixed & jnp.isfinite(s_f) & (
            live & fixable[None, :] & (shares[None, :] <= s_f[:, None])
        ).any(axis=1)
        anyfix = fix.any()
        # Stall (no finite share left): strand the rest at inf, mirroring
        # the progressive solver's break.
        rates = jnp.where(fix, s_f, rates)
        rates = jnp.where(~anyfix & unfixed, inf, rates)
        nuf = jnp.where(anyfix, nuf - fix.sum(dtype=jnp.int32),
                        jnp.int32(0))
        unfixed = jnp.where(anyfix, unfixed & ~fix,
                            jnp.zeros_like(unfixed))
        return (rates, unfixed, nuf)

    rates, _, _ = jax.lax.while_loop(cond, body, state)
    return rates


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _waterfill_jit(paths, caps, active, *, use_pallas, interpret):
    return waterfill_fixed_point(paths, caps, active, use_pallas=use_pallas,
                                 interpret=interpret)


def waterfill_rates(paths, caps, active=None, *, backend: str = "jax",
                    interpret: bool | None = None):
    """Public entry: jitted water-filling over one flow table.

    ``backend="jax"`` is the f64 bit-exact path; ``backend="pallas"`` runs
    the inner reduction as a TPU kernel (f32, interpret mode off-TPU).
    """
    enable_f64()
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown waterfill backend {backend!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    paths = jnp.asarray(paths, jnp.int32)
    caps = jnp.asarray(caps, jnp.float64)
    if active is None:
        active = jnp.ones(paths.shape[0], bool)
    else:
        active = jnp.asarray(active, bool)
    return _waterfill_jit(paths, caps, active,
                          use_pallas=(backend == "pallas"),
                          interpret=interpret)
