"""Pallas TPU netkv_score: Algorithm 1's scoring loop as one fused kernel.

At 1000+ node scale the per-request scheduler scoring (lines 3-13 of
Alg. 1) runs over thousands of candidates; this kernel fuses Eq. (2)-(7)
elementwise math with the masked argmin reduction in a single VMEM pass.
Tier lookups use a one-hot contraction over the 4 tiers (no gather).

Candidates are padded to a multiple of 128 lanes; padding is masked
infeasible.  Scalars (s_r, l_r, iter model, m_min, beta_max) ride SMEM
scalar prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BIG = 3.0e38


def _score_kernel(scal_ref, free_ref, queued_ref, batch_ref, hit_ref, tier_ref,
                  healthy_ref, scale_ref, bw_ref, lat_ref, cong_ref, infl_ref,
                  cost_ref, best_ref, *, n_real: int):
    s_r = scal_ref[0]
    l_r = scal_ref[1]
    iter_a = scal_ref[2]
    iter_b = scal_ref[3]
    m_min = scal_ref[4]
    beta_max = scal_ref[5]

    hit = jnp.minimum(hit_ref[...], l_r)
    s_eff = s_r * (1.0 - hit / jnp.maximum(l_r, 1.0))                    # Eq. (2)

    tier = tier_ref[...]
    beff = jnp.zeros_like(s_eff)
    lat = jnp.zeros_like(s_eff)
    for t in range(4):
        sel = (tier == t).astype(jnp.float32)
        bt = bw_ref[0, t] * (1.0 - cong_ref[0, t]) / (1.0 + infl_ref[0, t])  # Eq. (4)
        beff = beff + sel * bt
        lat = lat + sel * lat_ref[0, t]
    t_xfer = s_eff / jnp.maximum(beff, 1e-9) + lat                       # Eq. (3)

    t_iter = (iter_a + iter_b * batch_ref[...]) * scale_ref[...]
    blocked = jnp.maximum(0.0, queued_ref[...] - (beta_max - batch_ref[...]))
    t_queue = blocked * t_iter                                           # Eq. (6)
    t_dec = (iter_a + iter_b * (batch_ref[...] + 1.0)) * scale_ref[...]  # Eq. (7)

    cost = t_xfer + t_queue + t_dec                                      # Eq. (5)
    lane = jax.lax.broadcasted_iota(jnp.int32, cost.shape, 1)
    feasible = (healthy_ref[...] > 0.5) & (free_ref[...] >= s_eff + m_min) & (lane < n_real)
    cost = jnp.where(feasible, cost, BIG)
    cost_ref[...] = cost
    best_ref[0, 0] = jnp.argmin(cost[0]).astype(jnp.int32)


def netkv_score(free_mem, queued, batch, hit_tokens, tier, healthy, iter_scale,
                tier_bw, tier_lat, congestion, n_inflight,
                *, s_r: float, input_len: float, iter_a: float, iter_b: float,
                m_min: float, beta_max: int, interpret: bool = False):
    """All candidate arrays are (D,).  Returns (costs (D,), best_idx ())."""
    d = free_mem.shape[0]
    dp = -(-d // LANES) * LANES
    pad = dp - d

    def prep(x, dtype=jnp.float32):
        x = jnp.asarray(x, dtype)
        if pad:
            x = jnp.pad(x, (0, pad))
        return x.reshape(1, dp)

    scal = jnp.asarray([s_r, input_len, iter_a, iter_b, m_min, float(beta_max)],
                       jnp.float32)
    kernel = functools.partial(_score_kernel, n_real=d)
    costs, best = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(1,),
            in_specs=[pl.BlockSpec((1, dp), lambda i, s: (0, 0))] * 7
            + [pl.BlockSpec((1, 4), lambda i, s: (0, 0))] * 4,
            out_specs=[
                pl.BlockSpec((1, dp), lambda i, s: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, s: (0, 0), memory_space=pltpu.SMEM),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((1, dp), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        scal,
        prep(free_mem), prep(queued), prep(batch), prep(hit_tokens),
        prep(tier, jnp.int32), prep(healthy), prep(iter_scale),
        jnp.asarray(tier_bw, jnp.float32).reshape(1, 4),
        jnp.asarray(tier_lat, jnp.float32).reshape(1, 4),
        jnp.asarray(congestion, jnp.float32).reshape(1, 4),
        jnp.asarray(n_inflight, jnp.float32).reshape(1, 4),
    )
    return costs[0, :d], best[0, 0]
