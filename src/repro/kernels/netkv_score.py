"""Pallas TPU netkv_score: Algorithm 1's scoring loop as one fused kernel.

At 1000+ node scale the per-request scheduler scoring (lines 3-13 of
Alg. 1) runs over thousands of candidates; this kernel fuses Eq. (2)-(7)
elementwise math with the masked argmin reduction in a single VMEM pass.
Tier lookups use a one-hot contraction over the 4 tiers (no gather).

Candidates are padded to a multiple of 128 lanes; padding is masked
infeasible.  Scalars (s_r, l_r, iter model, m_min, beta_max) ride SMEM
scalar prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BIG = 3.0e38


def netkv_score(free_mem, queued, batch, hit_tokens, tier, healthy, iter_scale,
                tier_bw, tier_lat, congestion, n_inflight,
                *, s_r: float, input_len: float, iter_a: float, iter_b: float,
                m_min: float, beta_max: int, interpret: bool = False):
    """All candidate arrays are (D,).  Returns (costs (D,), best_idx ()).

    Single-row view of :func:`netkv_score_cohort` — one program serves both
    the sequential selector and the cohort dispatch path, which is what makes
    their costs bit-identical (two differently-shaped XLA programs are free
    to fuse/FMA differently; one shared program is not).
    """
    costs, best = netkv_score_cohort(
        free_mem, queued, batch,
        jnp.asarray(hit_tokens, jnp.float32).reshape(1, -1),
        jnp.asarray(tier, jnp.int32).reshape(1, -1),
        healthy, iter_scale, tier_bw, tier_lat, congestion,
        jnp.asarray(n_inflight, jnp.float32).reshape(1, 4),
        s_r=[s_r], input_len=[input_len], iter_a=iter_a, iter_b=iter_b,
        m_min=m_min, beta_max=beta_max, interpret=interpret,
    )
    return costs[0], best[0]


def _score_cohort_kernel(scal_ref, free_ref, queued_ref, batch_ref, hit_ref,
                         tier_ref, healthy_ref, scale_ref, rscal_ref, bw_ref,
                         lat_ref, cong_ref, infl_ref, cost_ref, best_ref,
                         *, n_real: int):
    """One grid step per cohort row: Eq. (2)-(7) + masked argmin, with the
    per-request scalars (s_r, l_r) riding a rowed block — row i is
    bit-identical to a single-row ``netkv_score`` call on the same snapshot.
    The per-row scalars deliberately arrive as a *block* rather than as
    ``scal_ref[base + program_id]``: a traced gather index changes XLA's
    fusion/FMA decisions for everything downstream, which costs bit-parity
    across cohort sizes (observed as 1-ulp cost drift off-TPU)."""
    s_r = rscal_ref[0, 0]
    l_r = rscal_ref[0, 1]
    iter_a = scal_ref[0]
    iter_b = scal_ref[1]
    m_min = scal_ref[2]
    beta_max = scal_ref[3]

    hit = jnp.minimum(hit_ref[...], l_r)
    s_eff = s_r * (1.0 - hit / jnp.maximum(l_r, 1.0))                    # Eq. (2)

    tier = tier_ref[...]
    beff = jnp.zeros_like(s_eff)
    lat = jnp.zeros_like(s_eff)
    for t in range(4):
        sel = (tier == t).astype(jnp.float32)
        bt = bw_ref[0, t] * (1.0 - cong_ref[0, t]) / (1.0 + infl_ref[0, t])  # Eq. (4)
        beff = beff + sel * bt
        lat = lat + sel * lat_ref[0, t]
    t_xfer = s_eff / jnp.maximum(beff, 1e-9) + lat                       # Eq. (3)

    t_iter = (iter_a + iter_b * batch_ref[...]) * scale_ref[...]
    blocked = jnp.maximum(0.0, queued_ref[...] - (beta_max - batch_ref[...]))
    t_queue = blocked * t_iter                                           # Eq. (6)
    t_dec = (iter_a + iter_b * (batch_ref[...] + 1.0)) * scale_ref[...]  # Eq. (7)

    cost = t_xfer + t_queue + t_dec                                      # Eq. (5)
    lane = jax.lax.broadcasted_iota(jnp.int32, cost.shape, 1)
    feasible = (healthy_ref[...] > 0.5) & (free_ref[...] >= s_eff + m_min) & (lane < n_real)
    cost = jnp.where(feasible, cost, BIG)
    cost_ref[...] = cost
    best_ref[0, 0] = jnp.argmin(cost[0]).astype(jnp.int32)


def netkv_score_cohort(free_mem, queued, batch, hit_rows, tier_rows, healthy,
                       iter_scale, tier_bw, tier_lat, congestion, infl_rows,
                       *, s_r, input_len, iter_a: float, iter_b: float,
                       m_min: float, beta_max: int, interpret: bool = False,
                       numpy: bool = False):
    """Cohort-axis ``netkv_score``: R requests against one D-wide snapshot.

    Pool columns (free_mem/queued/batch/healthy/iter_scale) are (D,) and
    shared; ``hit_rows``/``tier_rows`` are (R, D) and ``infl_rows``/``s_r``/
    ``input_len`` are per-row (self-contention and KV size vary with the
    prefill source).  Returns (costs (R, D), best (R,)) where row i matches
    a single-row ``netkv_score`` call bit-for-bit (same f32 op sequence,
    grid-stepped over the cohort axis).  ``numpy=True`` routes through the
    f32 NumPy twin — the fallback when no XLA backend is usable.
    """
    if numpy:
        return _netkv_score_cohort_np(
            free_mem, queued, batch, hit_rows, tier_rows, healthy, iter_scale,
            tier_bw, tier_lat, congestion, infl_rows, s_r=s_r,
            input_len=input_len, iter_a=iter_a, iter_b=iter_b, m_min=m_min,
            beta_max=beta_max)
    r, d = hit_rows.shape[0], free_mem.shape[0]
    dp = -(-d // LANES) * LANES
    pad = dp - d

    hit_rows = jnp.asarray(hit_rows, jnp.float32)
    tier_rows = jnp.asarray(tier_rows, jnp.int32)
    infl_rows = jnp.asarray(infl_rows, jnp.float32).reshape(r, 4)
    s_rv = jnp.asarray(s_r, jnp.float32).reshape(r)
    l_rv = jnp.asarray(input_len, jnp.float32).reshape(r)
    rq = r
    if r == 1:
        # grid=(1,) unrolls the body and XLA fuses the unrolled program
        # differently than the r>=2 grid loop (ulp-level cost drift).  Pad
        # to two identical rows so every call — any cohort size, and the
        # single-row ``netkv_score`` wrapper — runs the same loop program.
        hit_rows = jnp.concatenate([hit_rows, hit_rows])
        tier_rows = jnp.concatenate([tier_rows, tier_rows])
        infl_rows = jnp.concatenate([infl_rows, infl_rows])
        s_rv = jnp.concatenate([s_rv, s_rv])
        l_rv = jnp.concatenate([l_rv, l_rv])
        r = 2

    def prep(x, dtype=jnp.float32):
        x = jnp.asarray(x, dtype)
        if pad:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        return x.reshape(-1, dp)

    scal = jnp.asarray([iter_a, iter_b, m_min, float(beta_max)], jnp.float32)
    rscal = jnp.stack([s_rv, l_rv, jnp.zeros(r, jnp.float32),
                       jnp.zeros(r, jnp.float32)], axis=1)
    kernel = functools.partial(_score_cohort_kernel, n_real=d)
    shared = pl.BlockSpec((1, dp), lambda i, s: (0, 0))
    rowed = pl.BlockSpec((1, dp), lambda i, s: (i, 0))
    costs, best = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(r,),
            in_specs=[shared, shared, shared, rowed, rowed, shared, shared]
            + [pl.BlockSpec((1, 4), lambda i, s: (i, 0))]
            + [pl.BlockSpec((1, 4), lambda i, s: (0, 0))] * 3
            + [pl.BlockSpec((1, 4), lambda i, s: (i, 0))],
            out_specs=[
                rowed,
                pl.BlockSpec((1, 1), lambda i, s: (i, 0),
                             memory_space=pltpu.SMEM),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((r, dp), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        scal,
        prep(free_mem), prep(queued), prep(batch), prep(hit_rows),
        prep(tier_rows, jnp.int32), prep(healthy), prep(iter_scale), rscal,
        jnp.asarray(tier_bw, jnp.float32).reshape(1, 4),
        jnp.asarray(tier_lat, jnp.float32).reshape(1, 4),
        jnp.asarray(congestion, jnp.float32).reshape(1, 4),
        infl_rows,
    )
    return costs[:rq, :d], best[:rq, 0]


def _netkv_score_cohort_np(free_mem, queued, batch, hit_rows, tier_rows,
                           healthy, iter_scale, tier_bw, tier_lat, congestion,
                           infl_rows, *, s_r, input_len, iter_a, iter_b,
                           m_min, beta_max):
    """f32 NumPy twin of the cohort kernel (same op order, no XLA)."""
    import numpy as np

    f32 = np.float32
    d = free_mem.shape[0]
    free = np.asarray(free_mem, f32)[None, :]
    que = np.asarray(queued, f32)[None, :]
    bat = np.asarray(batch, f32)[None, :]
    hlt = np.asarray(healthy, f32)[None, :]
    scl = np.asarray(iter_scale, f32)[None, :]
    hit_rows = np.asarray(hit_rows, f32)
    tier = np.asarray(tier_rows, np.int32)
    bw = np.asarray(tier_bw, f32)
    lat4 = np.asarray(tier_lat, f32)
    cong = np.asarray(congestion, f32)
    infl = np.asarray(infl_rows, f32)
    s_rv = np.asarray(s_r, f32)[:, None]
    l_rv = np.asarray(input_len, f32)[:, None]
    a, b = f32(iter_a), f32(iter_b)
    mm, bm = f32(m_min), f32(float(beta_max))

    hit = np.minimum(hit_rows, l_rv)
    s_eff = s_rv * (f32(1.0) - hit / np.maximum(l_rv, f32(1.0)))
    beff = np.zeros_like(s_eff)
    lat = np.zeros_like(s_eff)
    for t in range(4):
        sel = (tier == t).astype(f32)
        bt = bw[t] * (f32(1.0) - cong[t]) / (f32(1.0) + infl[:, t:t + 1])
        beff = beff + sel * bt
        lat = lat + sel * lat4[t]
    t_xfer = s_eff / np.maximum(beff, f32(1e-9)) + lat
    t_iter = (a + b * bat) * scl
    blocked = np.maximum(f32(0.0), que - (bm - bat))
    t_queue = blocked * t_iter
    t_dec = (a + b * (bat + f32(1.0))) * scl
    cost = t_xfer + t_queue + t_dec
    feasible = (hlt > f32(0.5)) & (free >= s_eff + mm)
    cost = np.where(feasible, cost, f32(BIG))
    return cost[:, :d], np.argmin(cost, axis=1).astype(np.int32)
