"""Pallas TPU kv_pack: gather paged KV blocks into a contiguous DMA buffer.

FlowKV (cited by the paper as the transfer-mechanism optimisation) shows
that contiguous layout dominates per-transfer latency; on TPU the analogue
is packing the non-contiguous paged KV-cache blocks selected by the block
table into one contiguous HBM buffer so the prefill->decode transfer is a
single large DMA instead of per-page descriptors.

The block table rides scalar prefetch (SMEM); each grid step copies one
page through VMEM.  ``kv_unpack`` is the decode-side inverse (scatter).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(idx_ref, pool_ref, out_ref):
    # BlockSpec index_map already routed the right page into pool_ref.
    out_ref[...] = pool_ref[...]


def kv_pack(pool: jax.Array, block_table: jax.Array, *,
            interpret: bool = False) -> jax.Array:
    """pool: (n_pages, page_tokens, KV, dh); block_table: (n_sel,) int32.

    Returns (n_sel, page_tokens, KV, dh) — the selected pages, contiguous.
    """
    n_pages, page_tokens, kv, dh = pool.shape
    n_sel = block_table.shape[0]
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_sel,),
            in_specs=[
                pl.BlockSpec((1, page_tokens, kv, dh),
                             lambda i, idx: (idx[i], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, page_tokens, kv, dh),
                                   lambda i, idx: (i, 0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_sel, page_tokens, kv, dh), pool.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), pool)


def _unpack_kernel(idx_ref, pool_ref, buf_ref, out_ref):
    del pool_ref  # aliased with out_ref; untouched pages keep pool contents
    out_ref[...] = buf_ref[...]


def kv_unpack(pool: jax.Array, buf: jax.Array, block_table: jax.Array, *,
              interpret: bool = False) -> jax.Array:
    """Inverse of kv_pack: scatter ``buf``'s pages into ``pool`` at the block
    table's page ids (in-place via input/output aliasing — the decode side
    receives the transfer buffer and lands it in freshly allocated pages)."""
    n_sel, page_tokens, kv, dh = buf.shape
    n_pages = pool.shape[0]
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_sel,),
            in_specs=[
                pl.BlockSpec((1, page_tokens, kv, dh), lambda i, idx: (idx[i], 0, 0, 0)),
                pl.BlockSpec((1, page_tokens, kv, dh), lambda i, idx: (i, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, page_tokens, kv, dh),
                                   lambda i, idx: (idx[i], 0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_pages, page_tokens, kv, dh), buf.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), pool, buf)
