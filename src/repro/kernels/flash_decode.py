"""Pallas TPU flash-decode: GQA single-token attention over a long KV cache.

The decode phase the NetKV scheduler feeds is memory-bandwidth bound: one
query token must stream the whole KV cache from HBM.  This kernel tiles the
cache into VMEM blocks of ``block_s`` positions, keeps the online-softmax
running statistics (m, l, acc) in VMEM scratch across the sequential grid
axis, and writes the normalised output on the last block — the TPU-native
analogue of flash-decoding (no warp shuffles: the within-block reduction
vectorises on the VPU/MXU, the across-block reduction rides the sequential
grid).

Layout: q is regrouped to (B, KV, G, dh) where G = H // KV query heads share
one KV head; the kernel processes one (batch, kv-head) pair per grid cell.
``pos`` (valid cache length) arrives via scalar prefetch in SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax moved TPUCompilerParams -> CompilerParams across releases;
# resolve whichever this version ships.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, block_s: int, scale: float):
    sblk = pl.program_id(2)
    n_sblk = pl.num_programs(2)

    @pl.when(sblk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (block_s, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # (block_s, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, S_blk)
    ids = sblk * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(ids < pos_ref[0], s, NEG_INF)

    m_prev = m_scr[...]                                  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                               # (G, S_blk)
    alpha = jnp.exp(m_prev - m_new)                      # (G, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sblk == n_sblk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 pos: jax.Array, *, block_s: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q: (B, H, dh); k/v_cache: (B, S, KV, dh); pos: scalar valid length.

    Returns (B, H, dh).  H must be a multiple of KV (GQA grouping).
    """
    b, h, dh = q.shape
    _, s_max, kv, _ = k_cache.shape
    assert h % kv == 0, (h, kv)
    g = h // kv
    assert s_max % block_s == 0, (s_max, block_s)
    scale = dh ** -0.5
    qg = q.reshape(b, kv, g, dh)
    grid = (b, kv, s_max // block_s)

    kernel = functools.partial(_flash_decode_kernel, block_s=block_s, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, dh), lambda bi, ki, si, pos: (bi, ki, 0, 0)),
                pl.BlockSpec((1, block_s, 1, dh), lambda bi, ki, si, pos: (bi, si, ki, 0)),
                pl.BlockSpec((1, block_s, 1, dh), lambda bi, ki, si, pos: (bi, si, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, dh), lambda bi, ki, si, pos: (bi, ki, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), qg, k_cache, v_cache)
    return out.reshape(b, h, dh)
