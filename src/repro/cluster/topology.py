"""Multi-tier fat-tree cluster topology (§III-A, §VI-A).

The evaluation cluster: 2 pods x 2 racks x 2 servers x 8 GPUs = 64 GPUs.
Locality tiers:

  tier 0  same server   (NVLink / intra-host ICI)
  tier 1  same rack     (NIC -> ToR -> NIC)
  tier 2  same pod      (+ ToR uplink -> agg -> ToR downlink)
  tier 3  cross pod     (+ agg uplink -> core -> agg downlink)

Directed links are materialised for the flow-level simulator; ECMP gives
each ToR/agg ``n_uplinks`` parallel uplinks chosen uniformly at random per
flow (so correlated flows can collide below capacity, §VI-B).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.oracle import PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY

# Longest possible path: nic_up, tor_up, agg_up, agg_down, tor_down, nic_down.
MAX_PATH_LEN = 6


@dataclasses.dataclass(frozen=True)
class GpuCoord:
    pod: int
    rack: int
    server: int
    slot: int


@dataclasses.dataclass(frozen=True)
class Link:
    link_id: int
    kind: str          # "nvlink" | "nic_up" | "nic_down" | "tor_up" | "tor_down" | "agg_up" | "agg_down"
    tier: int          # the tier whose bandwidth class this link belongs to
    capacity: float    # bytes/s


@dataclasses.dataclass(frozen=True)
class Instance:
    """A TP group: ``tp`` GPUs on one server, acting as one schedulable unit."""

    instance_id: int
    role: str           # "prefill" | "decode"
    server: tuple[int, int, int]  # (pod, rack, server)
    gpu_ids: tuple[int, ...]


class FatTree:
    def __init__(
        self,
        n_pods: int = 2,
        racks_per_pod: int = 2,
        servers_per_rack: int = 2,
        gpus_per_server: int = 8,
        tier_bandwidth: dict[int, float] | None = None,
        tier_latency: dict[int, float] | None = None,
        n_tor_uplinks: int = 8,
        n_agg_uplinks: int = 8,
    ) -> None:
        self.n_pods = n_pods
        self.racks_per_pod = racks_per_pod
        self.servers_per_rack = servers_per_rack
        self.gpus_per_server = gpus_per_server
        self.tier_bandwidth = dict(tier_bandwidth or PAPER_TIER_BANDWIDTH)
        self.tier_latency = dict(tier_latency or PAPER_TIER_LATENCY)
        self.n_tor_uplinks = n_tor_uplinks
        self.n_agg_uplinks = n_agg_uplinks

        self.n_gpus = n_pods * racks_per_pod * servers_per_rack * gpus_per_server
        self._coords = [self._coord_of(g) for g in range(self.n_gpus)]

        # --- materialise directed links -----------------------------------
        self.links: list[Link] = []
        self._nic_up: dict[tuple[int, int, int], int] = {}
        self._nic_down: dict[tuple[int, int, int], int] = {}
        self._nvlink: dict[tuple[int, int, int], int] = {}
        self._tor_up: dict[tuple[int, int], list[int]] = {}
        self._tor_down: dict[tuple[int, int], list[int]] = {}
        self._agg_up: dict[int, list[int]] = {}
        self._agg_down: dict[int, list[int]] = {}

        # Per-uplink capacity is B_tau: one transfer's shard flows share one
        # ECMP uplink choice (they hash on the same host pair), so the
        # per-transfer uncontested ceiling equals the cost model's B_tau,
        # while the segment aggregate is n_uplinks * B_tau and two transfers
        # collide on an uplink with probability 1/n_uplinks (§VI-B).
        def add(kind: str, tier: int) -> int:
            lid = len(self.links)
            self.links.append(Link(lid, kind, tier, self.tier_bandwidth[tier]))
            return lid

        for p in range(n_pods):
            for r in range(racks_per_pod):
                for s in range(servers_per_rack):
                    key = (p, r, s)
                    self._nvlink[key] = add("nvlink", 0)
                    self._nic_up[key] = add("nic_up", 1)
                    self._nic_down[key] = add("nic_down", 1)
                rack = (p, r)
                self._tor_up[rack] = [add("tor_up", 2) for _ in range(n_tor_uplinks)]
                self._tor_down[rack] = [add("tor_down", 2) for _ in range(n_tor_uplinks)]
            self._agg_up[p] = [add("agg_up", 3) for _ in range(n_agg_uplinks)]
            self._agg_down[p] = [add("agg_down", 3) for _ in range(n_agg_uplinks)]

        # --- columnar link/path plane (FlowPlane substrate) ----------------
        # Flat arrays mirroring the dicts above so the flow simulator can
        # build per-flow path rows and residual-capacity vectors without
        # touching Python objects.  Server index: (pod * racks + rack) *
        # servers_per_rack + server.
        self.n_links = len(self.links)
        self.link_capacity = np.array([l.capacity for l in self.links], np.float64)
        self.link_tier = np.array([l.tier for l in self.links], np.int64)
        self.n_servers = n_pods * racks_per_pod * servers_per_rack
        n_racks = n_pods * racks_per_pod
        self._srv_nvlink = np.zeros(self.n_servers, np.int32)
        self._srv_nic_up = np.zeros(self.n_servers, np.int32)
        self._srv_nic_down = np.zeros(self.n_servers, np.int32)
        self._rack_tor_up = np.zeros((n_racks, n_tor_uplinks), np.int32)
        self._rack_tor_down = np.zeros((n_racks, n_tor_uplinks), np.int32)
        self._pod_agg_up = np.zeros((n_pods, n_agg_uplinks), np.int32)
        self._pod_agg_down = np.zeros((n_pods, n_agg_uplinks), np.int32)
        for (p, r, s), lid in self._nvlink.items():
            si = self.server_index((p, r, s))
            self._srv_nvlink[si] = lid
            self._srv_nic_up[si] = self._nic_up[(p, r, s)]
            self._srv_nic_down[si] = self._nic_down[(p, r, s)]
        for (p, r), lids in self._tor_up.items():
            self._rack_tor_up[p * racks_per_pod + r] = lids
            self._rack_tor_down[p * racks_per_pod + r] = self._tor_down[(p, r)]
        for p, lids in self._agg_up.items():
            self._pod_agg_up[p] = lids
            self._pod_agg_down[p] = self._agg_down[p]

    # -- coordinates --------------------------------------------------------
    def _coord_of(self, gpu: int) -> GpuCoord:
        per_server = self.gpus_per_server
        per_rack = per_server * self.servers_per_rack
        per_pod = per_rack * self.racks_per_pod
        return GpuCoord(
            pod=gpu // per_pod,
            rack=(gpu % per_pod) // per_rack,
            server=(gpu % per_rack) // per_server,
            slot=gpu % per_server,
        )

    def coord(self, gpu: int) -> GpuCoord:
        return self._coords[gpu]

    def server_of(self, gpu: int) -> tuple[int, int, int]:
        c = self._coords[gpu]
        return (c.pod, c.rack, c.server)

    def server_index(self, srv: tuple[int, int, int]) -> int:
        """Flat index of a (pod, rack, server) triple into the link tables."""
        p, r, s = srv
        return (p * self.racks_per_pod + r) * self.servers_per_rack + s

    # -- tiers ---------------------------------------------------------------
    def tier(self, a: GpuCoord | tuple[int, int, int], b: GpuCoord | tuple[int, int, int]) -> int:
        """tau(p, d) for two servers (or GPU coords)."""
        pa = a if isinstance(a, tuple) else (a.pod, a.rack, a.server)
        pb = b if isinstance(b, tuple) else (b.pod, b.rack, b.server)
        if pa == pb:
            return 0
        if pa[:2] == pb[:2]:
            return 1
        if pa[0] == pb[0]:
            return 2
        return 3

    def tier_vec(self, src_idx: np.ndarray, dst_idx: np.ndarray) -> np.ndarray:
        """Vectorised tau over flat server indices (broadcasting)."""
        spr, rpp = self.servers_per_rack, self.racks_per_pod
        src_rack, dst_rack = src_idx // spr, dst_idx // spr
        src_pod, dst_pod = src_rack // rpp, dst_rack // rpp
        t = np.full(np.broadcast(src_idx, dst_idx).shape, 3, np.int64)
        t[src_pod == dst_pod] = 2
        t[src_rack == dst_rack] = 1
        t[src_idx == dst_idx] = 0
        return t

    # -- paths (ECMP) ---------------------------------------------------------
    def path_row(
        self, src: tuple[int, int, int], dst: tuple[int, int, int], rng,
        out: np.ndarray | None = None,
    ) -> tuple[np.ndarray, int]:
        """Fixed-width link-id row (padded with -1) + path length.

        Same ECMP model and — critically — the *same RNG draw sequence* as
        ``flow_path``, so the columnar FlowPlane and the per-object reference
        pick identical uplinks under a shared seed.
        """
        if out is None:
            out = np.full(MAX_PATH_LEN, -1, np.int32)
        t = self.tier(src, dst)
        si, di = self.server_index(src), self.server_index(dst)
        if t == 0:
            out[0] = self._srv_nvlink[si]
            return out, 1
        out[0] = self._srv_nic_up[si]
        k = 1
        if t >= 2:
            out[k] = self._rack_tor_up[si // self.servers_per_rack][
                rng.integers(self.n_tor_uplinks)]
            k += 1
        if t == 3:
            out[k] = self._pod_agg_up[src[0]][rng.integers(self.n_agg_uplinks)]
            out[k + 1] = self._pod_agg_down[dst[0]][rng.integers(self.n_agg_uplinks)]
            k += 2
        if t >= 2:
            out[k] = self._rack_tor_down[di // self.servers_per_rack][
                rng.integers(self.n_tor_uplinks)]
            k += 1
        out[k] = self._srv_nic_down[di]
        return out, k + 1

    def flow_path(
        self, src: tuple[int, int, int], dst: tuple[int, int, int], rng
    ) -> list[int]:
        """Directed link ids traversed by one flow src-server -> dst-server.

        ECMP is modelled as a uniform random uplink pick at flow start
        (tor_up/agg_up on the source side, agg_down/tor_down on the
        destination side), per §VI-B.
        """
        row, k = self.path_row(src, dst, rng)
        return [int(l) for l in row[:k]]

    def base_latency(self, src, dst) -> float:
        return self.tier_latency[self.tier(src, dst)]

    def links_of_tier(self, tier: int) -> Iterator[Link]:
        return (l for l in self.links if l.tier == tier)


def make_instances(
    tree: FatTree, tp: int = 4, n_prefill: int = 4, placement: str = "pack"
) -> tuple[list[Instance], list[Instance]]:
    """Partition the cluster into TP groups and split prefill/decode pools.

    Paper setup: 64 GPUs at TP=4 -> 16 instances: 4 prefill + 12 decode.
    TP groups never span servers (gpus_per_server % tp == 0).

    placement="pack" (paper-faithful): the prefill pool fills whole racks in
    order, so prefill never shares a server or rack with decode — Table VI's
    footnote that tier 0 and tier 1 are unreached.  placement="spread"
    stride-places prefill across racks (exercises tiers 0-3; used by tests).
    """
    assert tree.gpus_per_server % tp == 0, "TP group must fit in a server"
    groups: list[tuple[tuple[int, int, int], tuple[int, ...]]] = []
    for g0 in range(0, tree.n_gpus, tp):
        gpus = tuple(range(g0, g0 + tp))
        groups.append((tree.server_of(g0), gpus))
    n_total = len(groups)
    assert 0 < n_prefill < n_total
    if placement == "pack":
        prefill_idx = set(range(n_prefill))
    elif placement == "spread":
        stride = max(1, n_total // n_prefill)
        prefill_idx = set(range(0, stride * n_prefill, stride))
    else:
        raise ValueError(placement)
    prefill, decode = [], []
    for i, (srv, gpus) in enumerate(groups):
        role = "prefill" if i in prefill_idx else "decode"
        inst = Instance(instance_id=i, role=role, server=srv, gpu_ids=gpus)
        (prefill if role == "prefill" else decode).append(inst)
    return prefill, decode
