"""Multi-tier fat-tree cluster topology (§III-A, §VI-A) — the TopoPlane.

The evaluation cluster: 2 pods x 2 racks x 2 servers x 8 GPUs = 64 GPUs.
Locality tiers:

  tier 0  same server   (NVLink / intra-host ICI)
  tier 1  same rack     (NIC -> ToR -> NIC)
  tier 2  same pod      (+ ToR uplink -> agg -> ToR downlink)
  tier 3  cross pod     (+ agg uplink -> core -> agg downlink)

Directed links are materialised for the flow-level simulator; ECMP gives
each ToR/agg ``n_uplinks`` parallel uplinks chosen uniformly at random per
flow (so correlated flows can collide below capacity, §VI-B).

The link structure itself is a first-class, time-varying simulation object:

* **Multi-NIC hosts** — ``nics_per_server`` materialises N nic_up/nic_down
  pairs per server (rail-optimised H100-class hosts carry 4-8), each at the
  full tier-1 bandwidth class, so host egress scales with the NIC count
  while the per-transfer uncontested ceiling stays B_1.  Which NIC a
  transfer rides is a pluggable :class:`NicPolicy` (``hash`` /
  ``least-loaded`` / ``rail-affine``) resolved at flow start by the network
  engine.  ``nics_per_server=1`` reproduces the single-NIC link table (same
  link ids, same ECMP RNG stream) bit-for-bit.
* **Capacity timeline** — :meth:`FatTree.rewire` atomically swaps tier
  capacities mid-run (an OCS reconfiguration event).  Both the columnar
  link table (``link_capacity``) and the per-object ``Link`` records are
  rebuilt so the FlowPlane and the reference engine observe the same swap;
  callers holding in-flight flows must follow with a full rate recompute
  (``FlowPlane.on_rewire`` / ``ReferenceFlowNetwork.refresh_rates``) so no
  flow is silently left over the new capacity.  ``topo_epoch`` counts
  rewires for staleness bookkeeping.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Mapping

import numpy as np

from repro.core.oracle import PAPER_TIER_BANDWIDTH, PAPER_TIER_LATENCY

# Longest possible path: nic_up, tor_up, agg_up, agg_down, tor_down, nic_down.
MAX_PATH_LEN = 6


# -- NIC-choice policies -----------------------------------------------------
class NicPolicy:
    """Picks the (src_nic, dst_nic) pair for one transfer at flow start.

    The policy is owned by a network engine instance (FlowPlane or the
    reference); engines drive it in identical call order, so two engines
    with their *own* policy instances stay bit-exact under a shared seed.
    With one NIC per server every policy returns ``(0, 0)`` without
    consuming RNG draws — the single-NIC stream is untouched.
    """

    name = "base"

    def bind(self, load_fn) -> None:
        """Attach an engine-side ``load_fn(link_ids) -> open-flow counts``."""
        self._load_fn = load_fn

    def observe(self, nbytes: float) -> None:
        """Engines report each transfer's size before asking for a pick —
        stateless policies ignore it; the adaptive policy tracks the
        distribution."""

    def pick(self, tree: "FatTree", si: int, di: int, rng) -> tuple[int, int]:
        raise NotImplementedError


class HashNicPolicy(NicPolicy):
    """Per-transfer uniform hash, the multi-rail analogue of ECMP (§VI-B):
    one independent draw per endpoint, so correlated transfers can collide
    on a NIC below aggregate host capacity."""

    name = "hash"

    def pick(self, tree, si, di, rng):
        n = tree.nics_per_server
        if n == 1:
            return 0, 0
        return int(rng.integers(n)), int(rng.integers(n))


class LeastLoadedNicPolicy(NicPolicy):
    """argmin open-flow count over each endpoint's NICs (ties -> lowest
    NIC index), the QP-count rail selection real multi-rail RDMA stacks
    apply.  Needs the engine's ``bind``-ed load counters."""

    name = "least-loaded"
    _load_fn = None

    def pick(self, tree, si, di, rng):
        n = tree.nics_per_server
        if n == 1 or self._load_fn is None:
            return 0, 0
        up = self._load_fn(tree._srv_nic_up[si])
        down = self._load_fn(tree._srv_nic_down[di])
        return int(np.argmin(up)), int(np.argmin(down))


class RailAffineNicPolicy(NicPolicy):
    """Rail-optimised placement: src and dst use the *same* rail index
    (NIC i talks to NIC i through the rail's dedicated fabric), rails
    assigned round-robin across transfer starts."""

    name = "rail-affine"

    def __init__(self) -> None:
        self._turn = 0

    def pick(self, tree, si, di, rng):
        n = tree.nics_per_server
        if n == 1:
            return 0, 0
        rail = self._turn % n
        self._turn += 1
        return rail, rail


class AdaptiveNicPolicy(NicPolicy):
    """Trace-adaptive rail choice: switch hash <-> rail-affine on the
    observed transfer-size distribution.

    Rail-affine wins for large/persistent transfers (a dedicated rail end
    to end, no hash collisions below host capacity); hash wins for
    small/many (round-robin rails would synchronise bursts onto one rail
    pair).  The policy tracks an EWMA of observed transfer sizes and
    delegates each pick to whichever specialist the current mean selects —
    above ``threshold_bytes`` rail-affine, below it hash.  The first
    ``warm`` observations always use hash (the paper's default), so a
    cold start matches the hash baseline bit-for-bit.
    """

    name = "adaptive"

    def __init__(self, threshold_bytes: float = 256e6, alpha: float = 0.1,
                 warm: int = 8) -> None:
        self._hash = HashNicPolicy()
        self._rail = RailAffineNicPolicy()
        self.threshold_bytes = float(threshold_bytes)
        self.alpha = float(alpha)
        self.warm = int(warm)
        self.ewma = 0.0
        self.seen = 0

    def observe(self, nbytes: float) -> None:
        self.seen += 1
        if self.seen == 1:
            self.ewma = float(nbytes)
        else:
            self.ewma += self.alpha * (float(nbytes) - self.ewma)

    def pick(self, tree, si, di, rng):
        if self.seen > self.warm and self.ewma >= self.threshold_bytes:
            return self._rail.pick(tree, si, di, rng)
        return self._hash.pick(tree, si, di, rng)


NIC_POLICIES = {
    "hash": HashNicPolicy,
    "least-loaded": LeastLoadedNicPolicy,
    "rail-affine": RailAffineNicPolicy,
    "adaptive": AdaptiveNicPolicy,
}


def make_nic_policy(policy: "str | NicPolicy") -> NicPolicy:
    """Resolve a policy name (or pass through an instance).

    Engines that must stay mutually bit-exact (plane vs reference) should
    each resolve their own instance from the name — rail-affine carries a
    round-robin counter, least-loaded binds engine-local load counters.
    """
    if isinstance(policy, NicPolicy):
        return policy
    try:
        return NIC_POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown NIC policy {policy!r}; known: {sorted(NIC_POLICIES)}"
        ) from None


@dataclasses.dataclass(frozen=True)
class GpuCoord:
    pod: int
    rack: int
    server: int
    slot: int


@dataclasses.dataclass(frozen=True)
class Link:
    link_id: int
    kind: str          # "nvlink" | "nic_up" | "nic_down" | "tor_up" | "tor_down" | "agg_up" | "agg_down"
    tier: int          # the tier whose bandwidth class this link belongs to
    capacity: float    # bytes/s


@dataclasses.dataclass(frozen=True)
class Instance:
    """A TP group: ``tp`` GPUs on one server, acting as one schedulable unit."""

    instance_id: int
    role: str           # "prefill" | "decode"
    server: tuple[int, int, int]  # (pod, rack, server)
    gpu_ids: tuple[int, ...]


class FatTree:
    def __init__(
        self,
        n_pods: int = 2,
        racks_per_pod: int = 2,
        servers_per_rack: int = 2,
        gpus_per_server: int = 8,
        tier_bandwidth: dict[int, float] | None = None,
        tier_latency: dict[int, float] | None = None,
        n_tor_uplinks: int = 8,
        n_agg_uplinks: int = 8,
        nics_per_server: int = 1,
    ) -> None:
        self.n_pods = n_pods
        self.racks_per_pod = racks_per_pod
        self.servers_per_rack = servers_per_rack
        self.gpus_per_server = gpus_per_server
        self.tier_bandwidth = dict(tier_bandwidth or PAPER_TIER_BANDWIDTH)
        self.tier_latency = dict(tier_latency or PAPER_TIER_LATENCY)
        self.n_tor_uplinks = n_tor_uplinks
        self.n_agg_uplinks = n_agg_uplinks
        if nics_per_server < 1:
            raise ValueError("nics_per_server must be >= 1")
        self.nics_per_server = int(nics_per_server)
        self.topo_epoch = 0   # rewire generation counter

        self.n_gpus = n_pods * racks_per_pod * servers_per_rack * gpus_per_server
        self._coords = [self._coord_of(g) for g in range(self.n_gpus)]

        # --- materialise directed links -----------------------------------
        self.links: list[Link] = []
        self._nic_up: dict[tuple[int, int, int], list[int]] = {}
        self._nic_down: dict[tuple[int, int, int], list[int]] = {}
        self._nvlink: dict[tuple[int, int, int], int] = {}
        self._tor_up: dict[tuple[int, int], list[int]] = {}
        self._tor_down: dict[tuple[int, int], list[int]] = {}
        self._agg_up: dict[int, list[int]] = {}
        self._agg_down: dict[int, list[int]] = {}

        # Per-uplink capacity is B_tau: one transfer's shard flows share one
        # ECMP uplink choice (they hash on the same host pair), so the
        # per-transfer uncontested ceiling equals the cost model's B_tau,
        # while the segment aggregate is n_uplinks * B_tau and two transfers
        # collide on an uplink with probability 1/n_uplinks (§VI-B).
        def add(kind: str, tier: int) -> int:
            lid = len(self.links)
            self.links.append(Link(lid, kind, tier, self.tier_bandwidth[tier]))
            return lid

        # NIC link ids are contiguous per direction (all ups, then all downs)
        # so that nics_per_server=1 reproduces the historical per-server
        # nvlink, nic_up, nic_down id sequence exactly.
        for p in range(n_pods):
            for r in range(racks_per_pod):
                for s in range(servers_per_rack):
                    key = (p, r, s)
                    self._nvlink[key] = add("nvlink", 0)
                    self._nic_up[key] = [
                        add("nic_up", 1) for _ in range(self.nics_per_server)]
                    self._nic_down[key] = [
                        add("nic_down", 1) for _ in range(self.nics_per_server)]
                rack = (p, r)
                self._tor_up[rack] = [add("tor_up", 2) for _ in range(n_tor_uplinks)]
                self._tor_down[rack] = [add("tor_down", 2) for _ in range(n_tor_uplinks)]
            self._agg_up[p] = [add("agg_up", 3) for _ in range(n_agg_uplinks)]
            self._agg_down[p] = [add("agg_down", 3) for _ in range(n_agg_uplinks)]

        # --- columnar link/path plane (FlowPlane substrate) ----------------
        # Flat arrays mirroring the dicts above so the flow simulator can
        # build per-flow path rows and residual-capacity vectors without
        # touching Python objects.  Server index: (pod * racks + rack) *
        # servers_per_rack + server.
        self.n_links = len(self.links)
        self.link_capacity = np.array([l.capacity for l in self.links], np.float64)
        self.link_tier = np.array([l.tier for l in self.links], np.int64)
        self.n_servers = n_pods * racks_per_pod * servers_per_rack
        n_racks = n_pods * racks_per_pod
        self._srv_nvlink = np.zeros(self.n_servers, np.int32)
        # NIC tables carry a per-server NIC axis; column 0 is the historical
        # single-NIC link for every server.
        self._srv_nic_up = np.zeros((self.n_servers, self.nics_per_server), np.int32)
        self._srv_nic_down = np.zeros((self.n_servers, self.nics_per_server), np.int32)
        self._rack_tor_up = np.zeros((n_racks, n_tor_uplinks), np.int32)
        self._rack_tor_down = np.zeros((n_racks, n_tor_uplinks), np.int32)
        self._pod_agg_up = np.zeros((n_pods, n_agg_uplinks), np.int32)
        self._pod_agg_down = np.zeros((n_pods, n_agg_uplinks), np.int32)
        for (p, r, s), lid in self._nvlink.items():
            si = self.server_index((p, r, s))
            self._srv_nvlink[si] = lid
            self._srv_nic_up[si] = self._nic_up[(p, r, s)]
            self._srv_nic_down[si] = self._nic_down[(p, r, s)]
        for (p, r), lids in self._tor_up.items():
            self._rack_tor_up[p * racks_per_pod + r] = lids
            self._rack_tor_down[p * racks_per_pod + r] = self._tor_down[(p, r)]
        for p, lids in self._agg_up.items():
            self._pod_agg_up[p] = lids
            self._pod_agg_down[p] = self._agg_down[p]

    # -- coordinates --------------------------------------------------------
    def _coord_of(self, gpu: int) -> GpuCoord:
        per_server = self.gpus_per_server
        per_rack = per_server * self.servers_per_rack
        per_pod = per_rack * self.racks_per_pod
        return GpuCoord(
            pod=gpu // per_pod,
            rack=(gpu % per_pod) // per_rack,
            server=(gpu % per_rack) // per_server,
            slot=gpu % per_server,
        )

    def coord(self, gpu: int) -> GpuCoord:
        return self._coords[gpu]

    def server_of(self, gpu: int) -> tuple[int, int, int]:
        c = self._coords[gpu]
        return (c.pod, c.rack, c.server)

    def server_index(self, srv: tuple[int, int, int]) -> int:
        """Flat index of a (pod, rack, server) triple into the link tables."""
        p, r, s = srv
        return (p * self.racks_per_pod + r) * self.servers_per_rack + s

    # -- tiers ---------------------------------------------------------------
    def tier(self, a: GpuCoord | tuple[int, int, int], b: GpuCoord | tuple[int, int, int]) -> int:
        """tau(p, d) for two servers (or GPU coords)."""
        pa = a if isinstance(a, tuple) else (a.pod, a.rack, a.server)
        pb = b if isinstance(b, tuple) else (b.pod, b.rack, b.server)
        if pa == pb:
            return 0
        if pa[:2] == pb[:2]:
            return 1
        if pa[0] == pb[0]:
            return 2
        return 3

    def tier_vec(self, src_idx: np.ndarray, dst_idx: np.ndarray) -> np.ndarray:
        """Vectorised tau over flat server indices (broadcasting)."""
        spr, rpp = self.servers_per_rack, self.racks_per_pod
        src_rack, dst_rack = src_idx // spr, dst_idx // spr
        src_pod, dst_pod = src_rack // rpp, dst_rack // rpp
        t = np.full(np.broadcast(src_idx, dst_idx).shape, 3, np.int64)
        t[src_pod == dst_pod] = 2
        t[src_rack == dst_rack] = 1
        t[src_idx == dst_idx] = 0
        return t

    # -- capacity timeline (OCS rewiring) ------------------------------------
    def rewire(
        self,
        tier_bandwidth: Mapping[int, float] | None = None,
        scale: Mapping[int, float] | None = None,
    ) -> int:
        """Atomically swap tier capacities mid-run (OCS reconfiguration).

        ``tier_bandwidth`` sets absolute per-tier bytes/s; ``scale``
        multiplies the current values (both may be partial maps).  Every
        link of a touched tier gets the new capacity in the same call —
        both the columnar ``link_capacity`` table (FlowPlane substrate) and
        the per-object ``Link`` records (reference engine substrate), so
        the two network engines observe one consistent swap.  The caller
        owning in-flight flows must follow with a full rate recompute
        (``FlowPlane.on_rewire`` / ``ReferenceFlowNetwork.refresh_rates``):
        rates assigned under the old capacities are not feasible under the
        new ones.  Returns the new ``topo_epoch``.
        """
        if tier_bandwidth:
            for t, b in tier_bandwidth.items():
                if int(t) not in self.tier_bandwidth:
                    raise KeyError(f"unknown tier {t}")
                self.tier_bandwidth[int(t)] = float(b)
        if scale:
            for t, f in scale.items():
                self.tier_bandwidth[int(t)] = self.tier_bandwidth[int(t)] * float(f)
        touched = set()
        for m in (tier_bandwidth, scale):
            if m:
                touched |= {int(t) for t in m}
        if not touched:
            touched = set(range(4))
        # Only links of touched tiers are rewritten: a tier-level swap must
        # not clobber per-link ``rewire_links`` edits elsewhere.  (For
        # untouched tiers the old full rebuild recomputed the same values,
        # so this is bit-identical absent per-link edits.)
        caps = np.array([self.tier_bandwidth[t] for t in range(4)], np.float64)
        mask = np.isin(self.link_tier, sorted(touched))
        self.link_capacity[mask] = caps[self.link_tier[mask]]
        for lid in np.flatnonzero(mask).tolist():
            self.links[lid] = dataclasses.replace(
                self.links[lid], capacity=float(self.link_capacity[lid]))
        self.topo_epoch += 1
        return self.topo_epoch

    def rewire_links(self, link_ids, capacity) -> int:
        """Retarget *individual* links' capacities (per-link OCS edit).

        ``capacity`` is a scalar or per-link array of bytes/s applied to
        ``link_ids``.  The columnar ``link_capacity`` table and the
        per-object ``Link`` records are both updated, and
        ``tier_bandwidth`` is refreshed as a **derived p50-per-tier
        summary** of the per-link table — mutated in place, because the
        ``NetworkCostOracle`` holds a live reference to this dict — so
        tier-granular consumers (cost model Eq. (3), staleness snapshots)
        keep a representative figure while the flow simulator sees exact
        per-link values.  Callers owning in-flight flows must follow with
        ``FlowPlane.on_rewire_links(link_ids, now)``, which re-water-fills
        only the dirty component of the edited links.  Note a subsequent
        tier-level :meth:`rewire` of the same tier resets its per-link
        edits (it reasserts one capacity per tier).  Returns the new
        ``topo_epoch``.
        """
        lids = np.asarray(link_ids, np.int64).ravel()
        if lids.size == 0:
            return self.topo_epoch
        if np.any((lids < 0) | (lids >= self.n_links)):
            raise IndexError("link id out of range")
        caps = np.broadcast_to(np.asarray(capacity, np.float64), lids.shape)
        if np.any(~np.isfinite(caps)) or np.any(caps <= 0):
            raise ValueError("link capacity must be finite and > 0")
        self.link_capacity[lids] = caps
        for lid, c in zip(lids.tolist(), caps.tolist()):
            self.links[lid] = dataclasses.replace(self.links[lid],
                                                  capacity=float(c))
        for t in np.unique(self.link_tier[lids]).tolist():
            sel = self.link_tier == t
            self.tier_bandwidth[int(t)] = float(
                np.median(self.link_capacity[sel]))
        self.topo_epoch += 1
        return self.topo_epoch

    # -- paths (ECMP) ---------------------------------------------------------
    def path_row(
        self, src: tuple[int, int, int], dst: tuple[int, int, int], rng,
        out: np.ndarray | None = None, nics: tuple[int, int] = (0, 0),
    ) -> tuple[np.ndarray, int]:
        """Fixed-width link-id row (padded with -1) + path length.

        Same ECMP model and — critically — the *same RNG draw sequence* as
        ``flow_path``, so the columnar FlowPlane and the per-object reference
        pick identical uplinks under a shared seed.  ``nics`` selects the
        (src, dst) NIC pair; the engines resolve it through their
        :class:`NicPolicy` before building the path.
        """
        if out is None:
            out = np.full(MAX_PATH_LEN, -1, np.int32)
        t = self.tier(src, dst)
        si, di = self.server_index(src), self.server_index(dst)
        if t == 0:
            out[0] = self._srv_nvlink[si]
            return out, 1
        out[0] = self._srv_nic_up[si, nics[0]]
        k = 1
        if t >= 2:
            out[k] = self._rack_tor_up[si // self.servers_per_rack][
                rng.integers(self.n_tor_uplinks)]
            k += 1
        if t == 3:
            out[k] = self._pod_agg_up[src[0]][rng.integers(self.n_agg_uplinks)]
            out[k + 1] = self._pod_agg_down[dst[0]][rng.integers(self.n_agg_uplinks)]
            k += 2
        if t >= 2:
            out[k] = self._rack_tor_down[di // self.servers_per_rack][
                rng.integers(self.n_tor_uplinks)]
            k += 1
        out[k] = self._srv_nic_down[di, nics[1]]
        return out, k + 1

    def flow_path(
        self, src: tuple[int, int, int], dst: tuple[int, int, int], rng,
        nics: tuple[int, int] = (0, 0),
    ) -> list[int]:
        """Directed link ids traversed by one flow src-server -> dst-server.

        ECMP is modelled as a uniform random uplink pick at flow start
        (tor_up/agg_up on the source side, agg_down/tor_down on the
        destination side), per §VI-B.
        """
        row, k = self.path_row(src, dst, rng, nics=nics)
        return [int(l) for l in row[:k]]

    def base_latency(self, src, dst) -> float:
        return self.tier_latency[self.tier(src, dst)]

    def links_of_tier(self, tier: int) -> Iterator[Link]:
        return (l for l in self.links if l.tier == tier)


def make_instances(
    tree: FatTree, tp: int = 4, n_prefill: int = 4, placement: str = "pack"
) -> tuple[list[Instance], list[Instance]]:
    """Partition the cluster into TP groups and split prefill/decode pools.

    Paper setup: 64 GPUs at TP=4 -> 16 instances: 4 prefill + 12 decode.
    TP groups never span servers (gpus_per_server % tp == 0).

    placement="pack" (paper-faithful): the prefill pool fills whole racks in
    order, so prefill never shares a server or rack with decode — Table VI's
    footnote that tier 0 and tier 1 are unreached.  placement="spread"
    stride-places prefill across racks (exercises tiers 0-3; used by tests).
    """
    assert tree.gpus_per_server % tp == 0, "TP group must fit in a server"
    groups: list[tuple[tuple[int, int, int], tuple[int, ...]]] = []
    for g0 in range(0, tree.n_gpus, tp):
        gpus = tuple(range(g0, g0 + tp))
        groups.append((tree.server_of(g0), gpus))
    n_total = len(groups)
    assert 0 < n_prefill < n_total
    if placement == "pack":
        prefill_idx = set(range(n_prefill))
    elif placement == "spread":
        stride = max(1, n_total // n_prefill)
        prefill_idx = set(range(0, stride * n_prefill, stride))
    else:
        raise ValueError(placement)
    prefill, decode = [], []
    for i, (srv, gpus) in enumerate(groups):
        role = "prefill" if i in prefill_idx else "decode"
        inst = Instance(instance_id=i, role=role, server=srv, gpu_ids=gpus)
        (prefill if role == "prefill" else decode).append(inst)
    return prefill, decode
