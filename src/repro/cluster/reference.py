"""Retired per-object fluid network simulator — kept as the parity oracle.

This is the seed's ``FlowNetwork`` verbatim: a Python dict of ``Flow``
dataclasses, an O(rounds x links x flows) progressive water-filling loop
re-run on every flow arrival/completion, and per-flow Python scans in
``advance`` / ``next_completion_time`` / ``abort_transfer``.  The
production engine in ``network.py`` (``FlowPlane``) is a columnar
struct-of-arrays rewrite and must stay *bit-exact* to this module — same
per-flow rates, same transfer completion order and finish times, same
per-tier byte counters, same ECMP RNG stream consumption —
``tests/test_flowplane_parity.py`` enforces it, exactly like
``core/reference.py`` does for the scheduler ladder.  Benchmarks use this
loop as the "python" baseline arm (``benchmarks/net_throughput.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from .topology import FatTree, NicPolicy, make_nic_policy


@dataclasses.dataclass
class Flow:
    flow_id: int
    transfer: "Transfer"
    path: tuple[int, ...]
    bytes_remaining: float
    rate: float = 0.0


class ReferenceFlowNetwork:
    """Fluid flow simulator over the fat-tree's directed links (per-object).

    Multi-NIC topologies and capacity rewires are supported the per-object
    way: the NIC policy is resolved per transfer through the same
    ``NicPolicy`` protocol (engine-local instance, identical call order =
    identical RNG stream), and ``_recompute_rates`` reads link capacities
    live from ``tree.links``, so a ``FatTree.rewire`` takes effect at the
    next ``refresh_rates`` call — the rewire-time hook mirroring
    ``FlowPlane.on_rewire``.
    """

    def __init__(self, tree: FatTree, background, seed: int = 0,
                 nic_policy: "str | NicPolicy" = "hash"):
        self.tree = tree
        self.bg = background
        self.rng = np.random.default_rng(seed)
        self.nic_policy = make_nic_policy(nic_policy)
        self.nic_policy.bind(self._nic_load)
        self.flows: dict[int, Flow] = {}
        self._next_flow = 0
        self._next_transfer = 0
        self._last_advance = 0.0
        self.completed_transfers = 0
        self.bytes_delivered = 0.0
        self._tier_bytes = {0: 0.0, 1: 0.0, 2: 0.0, 3: 0.0}

    def _nic_load(self, lids) -> np.ndarray:
        """Open-flow count per candidate NIC link (least-loaded policy)."""
        cnt: dict[int, int] = {}
        for f in self.flows.values():
            for l in f.path:
                cnt[l] = cnt.get(l, 0) + 1
        return np.array([cnt.get(int(l), 0) for l in lids], np.int64)

    # ------------------------------------------------------------------ API
    def start_transfer(
        self,
        src: tuple[int, int, int],
        dst: tuple[int, int, int],
        total_bytes: float,
        now: float,
        on_complete: Callable[["Transfer", float], None],
        n_flows: int = 4,
    ) -> "Transfer":
        """Begin a KV transfer of ``total_bytes`` as n parallel shard flows."""
        from .network import Transfer

        self.advance(now)
        tier = self.tree.tier(src, dst)
        t = Transfer(
            self._next_transfer, src, dst, tier, total_bytes, now, on_complete
        )
        self._next_transfer += 1
        if total_bytes <= 0:
            # Pure-latency transfer (100 % prefix hit): complete immediately
            # after base latency; caller handles via zero-byte fast path.
            t.done = True
            t.finish_time = now + self.tree.tier_latency[tier]
            return t
        per_flow = total_bytes / n_flows
        # One ECMP hash per transfer: TP shard flows share the host pair and
        # take the same uplinks, so the per-transfer uncontested ceiling is
        # exactly B_tau while distinct transfers can still collide.  NIC
        # pair resolved at flow start, same policy call order (observe then
        # pick, tier-0 exempt) as the plane.
        if tier == 0:
            nics = (0, 0)
        else:
            self.nic_policy.observe(total_bytes)
            nics = self.nic_policy.pick(
                self.tree, self.tree.server_index(src),
                self.tree.server_index(dst), self.rng)
        path = tuple(self.tree.flow_path(src, dst, self.rng, nics=nics))
        for _ in range(n_flows):
            f = Flow(self._next_flow, t, path, per_flow)
            self._next_flow += 1
            self.flows[f.flow_id] = f
            t.flows_open += 1
        self._recompute_rates(now)
        return t

    def abort_transfer(self, transfer, now: float) -> None:
        """Tear down every flow of ``transfer`` immediately (flow removal
        reconciles the open-flow counts ``_nic_load`` recounts from, and
        ``flows_open`` drops to zero with them — lockstep with FlowPlane)."""
        self.advance(now)
        dead = [fid for fid, f in self.flows.items() if f.transfer is transfer]
        for fid in dead:
            del self.flows[fid]
        transfer.aborted = True
        transfer.done = True
        transfer.flows_open = 0
        if dead:
            self._recompute_rates(now)

    def open_flow_counts(self) -> np.ndarray:
        """Per-link open-flow counts recounted from live flows (the parity
        oracle for FlowPlane's incremental ``_link_nflows``)."""
        cnt = np.zeros(self.tree.n_links, np.int64)
        for f in self.flows.values():
            for l in f.path:
                cnt[l] += 1
        return cnt

    def advance(self, now: float) -> None:
        """Drain bytes at current rates from the last advance point to now."""
        dt = now - self._last_advance
        if dt < 0:
            raise ValueError(f"time went backwards: {self._last_advance} -> {now}")
        if dt == 0.0 or not self.flows:
            self._last_advance = now
            return
        finished: list[Flow] = []
        for f in self.flows.values():
            moved = min(f.bytes_remaining, f.rate * dt)
            f.bytes_remaining -= moved
            self.bytes_delivered += moved
            self._tier_bytes[f.transfer.tier] += moved
            # 1-byte completion threshold: float residue from rate*dt would
            # otherwise strand sub-byte remainders and storm the event loop.
            if f.bytes_remaining <= 1.0:
                finished.append(f)
        self._last_advance = now
        if finished:
            done_transfers = []
            for f in finished:
                del self.flows[f.flow_id]
                f.transfer.flows_open -= 1
                if f.transfer.flows_open == 0 and not f.transfer.aborted:
                    f.transfer.done = True
                    f.transfer.finish_time = now
                    done_transfers.append(f.transfer)
            self._recompute_rates(now)
            for t in done_transfers:
                self.completed_transfers += 1
                t.on_complete(t, now)

    def next_completion_time(self, now: float) -> Optional[float]:
        """Earliest moment any flow drains at current rates (None if idle)."""
        best = None
        for f in self.flows.values():
            if f.rate <= 0:
                continue
            eta = now + f.bytes_remaining / f.rate + 1e-9
            if best is None or eta < best:
                best = eta
        return best

    def refresh_rates(self, now: float) -> None:
        """Periodic tick so time-varying background traffic takes effect."""
        self.advance(now)
        if self.flows:
            self._recompute_rates(now)

    # -------------------------------------------------------- water-filling
    def _recompute_rates(self, now: float) -> None:
        if not self.flows:
            return
        flows_on_link: dict[int, list[int]] = {}
        for fid, f in self.flows.items():
            for lid in f.path:
                flows_on_link.setdefault(lid, []).append(fid)
        caps = {
            lid: self.tree.links[lid].capacity
            * (1.0 - self.bg.util(self.tree.links[lid].tier, now))
            for lid in flows_on_link
        }
        unfixed = set(self.flows.keys())
        while unfixed:
            bottleneck = None
            for lid, fl in flows_on_link.items():
                active = [fid for fid in fl if fid in unfixed]
                if not active:
                    continue
                share = caps[lid] / len(active)
                if bottleneck is None or share < bottleneck[0]:
                    bottleneck = (share, lid, active)
            if bottleneck is None:  # pragma: no cover - every flow has links
                for fid in unfixed:
                    self.flows[fid].rate = float("inf")
                break
            share, lid, active = bottleneck
            for fid in active:
                self.flows[fid].rate = share
                unfixed.discard(fid)
                for l2 in self.flows[fid].path:
                    caps[l2] = max(0.0, caps.get(l2, 0.0) - share)
            flows_on_link.pop(lid, None)

    # ------------------------------------------------------------ telemetry
    def tier_congestion(self, now: float) -> dict[int, float]:
        """Operator-side per-tier congestion, *excluding* marked KV flows."""
        return self.bg.tier_map(now)

    def tier_utilization_observed(self, now: float):
        """Diagnostic: cumulative KV bytes moved per tier (for Table VI)."""
        return dict(self._tier_bytes)
