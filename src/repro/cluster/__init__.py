"""Cluster substrate: fat-tree topology + columnar flow-level network model.

``FlowPlane`` is the production struct-of-arrays engine; ``FlowNetwork`` is
its backwards-compatible alias.  ``ReferenceFlowNetwork`` (cluster/reference)
is the retired per-object implementation kept as the bit-exact parity oracle.
The TopoPlane additions (multi-NIC hosts, NIC-choice policies, OCS capacity
rewiring) live in ``topology.py``.
"""

from .topology import (
    FatTree,
    HashNicPolicy,
    Instance,
    LeastLoadedNicPolicy,
    Link,
    MAX_PATH_LEN,
    NIC_POLICIES,
    NicPolicy,
    RailAffineNicPolicy,
    make_instances,
    make_nic_policy,
)
from .network import BackgroundTraffic, FlowNetwork, FlowPlane, FlowView, Transfer
from .reference import Flow, ReferenceFlowNetwork

__all__ = [
    "FatTree", "Instance", "Link", "MAX_PATH_LEN", "make_instances",
    "NicPolicy", "HashNicPolicy", "LeastLoadedNicPolicy",
    "RailAffineNicPolicy", "NIC_POLICIES", "make_nic_policy",
    "BackgroundTraffic", "Flow", "FlowNetwork", "FlowPlane", "FlowView",
    "ReferenceFlowNetwork", "Transfer",
]
