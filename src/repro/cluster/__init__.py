"""Cluster substrate: fat-tree topology + columnar flow-level network model.

``FlowPlane`` is the production struct-of-arrays engine; ``FlowNetwork`` is
its backwards-compatible alias.  ``ReferenceFlowNetwork`` (cluster/reference)
is the retired per-object implementation kept as the bit-exact parity oracle.
"""

from .topology import FatTree, Instance, Link, MAX_PATH_LEN, make_instances
from .network import BackgroundTraffic, FlowNetwork, FlowPlane, FlowView, Transfer
from .reference import Flow, ReferenceFlowNetwork

__all__ = [
    "FatTree", "Instance", "Link", "MAX_PATH_LEN", "make_instances",
    "BackgroundTraffic", "Flow", "FlowNetwork", "FlowPlane", "FlowView",
    "ReferenceFlowNetwork", "Transfer",
]
