"""Cluster substrate: fat-tree topology + flow-level network model."""

from .topology import FatTree, Instance, Link, make_instances
from .network import BackgroundTraffic, Flow, FlowNetwork, Transfer

__all__ = [
    "FatTree", "Instance", "Link", "make_instances",
    "BackgroundTraffic", "Flow", "FlowNetwork", "Transfer",
]
