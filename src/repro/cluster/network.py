"""FlowPlane: columnar flow-level network model (max-min fair sharing + ECMP).

Each KV transfer is realised as ``n_flows`` parallel flows (one per TP shard)
sharing the source NIC and one ECMP uplink choice.  On every flow
arrival/completion the coexisting flows on shared links are re-evaluated
(progressive water-filling), the model RDMA congestion control (DCQCN)
converges to.  Background traffic is a steady-state per-link utilisation
fraction that scales down residual capacity — the mean-field approximation
of §VI-B — optionally time-varying for the staleness experiments.

The engine mirrors the ``ClusterView`` pattern (§ PR 1): flows live in
struct-of-arrays NumPy columns (``bytes_remaining``, ``rate``, ``tier``,
``transfer``, fixed-width ``path`` rows built from ``FatTree.path_row``),
so water-filling is a vectorised bincount/argmin fixed-point, ``advance``
drains every flow in fused array ops, ``next_completion_time`` is one
argmin, and abort/completion are O(flows-of-transfer) via a transfer->slot
map.  Two scale levers beyond vectorisation:

* **Incremental recomputation** — an arriving/departing flow only dirties
  the connected component of flows it shares links with (transitively);
  rates outside that component are provably unchanged by max-min
  decomposition, so they are not recomputed.
* **Piecewise-constant background sampling** — residual link capacities are
  sampled from ``BackgroundTraffic`` at construction and at every
  ``refresh_rates`` tick (0.1 s of sim time) instead of at every event, so
  incremental recomputes stay exact between ticks.  With static background
  this is identical to per-event sampling.

The retired per-object implementation lives in ``cluster/reference.py``
(``ReferenceFlowNetwork``) as the parity oracle: rates, transfer completion
order, finish times and per-tier byte counters must match it bit-for-bit
(``tests/test_flowplane_parity.py``) — which is why the byte accumulators
below use ordered ``np.add.at`` reductions (sequential, reference-order
float addition) rather than pairwise ``sum``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from .topology import FatTree, MAX_PATH_LEN, NicPolicy, make_nic_policy


class BackgroundTraffic:
    """Per-tier offered-load fraction, optionally time-varying.

    ``base[tier]`` is the mean utilisation; with ``wander > 0`` the
    instantaneous value follows a slow sinusoid + per-refresh jitter
    (seeded), giving the oracle something real to track in Exp. 4.
    """

    def __init__(
        self,
        base: dict[int, float] | float = 0.0,
        wander: float = 0.0,
        period: float = 7.0,
        seed: int = 0,
    ) -> None:
        if isinstance(base, (int, float)):
            base = {0: 0.0, 1: float(base), 2: float(base), 3: float(base)}
        self.base = {t: float(base.get(t, 0.0)) for t in range(4)}
        self.wander = wander
        self.period = period
        self._phase = {t: np.random.default_rng(seed + t).uniform(0, 2 * math.pi) for t in range(4)}

    def util(self, tier: int, now: float) -> float:
        u = self.base[tier]
        if self.wander > 0.0 and u > 0.0:
            u = u * (1.0 + self.wander * math.sin(2 * math.pi * now / self.period + self._phase[tier]))
        return float(min(max(u, 0.0), 0.95))

    def tier_map(self, now: float) -> dict[int, float]:
        return {t: self.util(t, now) for t in range(4)}

    @property
    def is_static(self) -> bool:
        """True when ``util`` is time-invariant (the wander sinusoid is off
        or never applied) — the condition under which idle net ticks are
        provably no-ops and may be elided."""
        return self.wander <= 0.0 or not any(self.base.values())


@dataclasses.dataclass
class Transfer:
    transfer_id: int
    src: tuple[int, int, int]
    dst: tuple[int, int, int]
    tier: int
    total_bytes: float
    start_time: float
    on_complete: Callable[["Transfer", float], None]
    flows_open: int = 0
    done: bool = False
    aborted: bool = False
    finish_time: float | None = None
    # Link id the water-fill fixed this transfer's flows at (every flow of
    # one transfer shares a path, so they fix in the same round at the same
    # link).  Only populated when ``FlowPlane.record_bottlenecks`` is on;
    # -1 for latency-only / aborted / untraced transfers.
    bottleneck_link: int = -1


@dataclasses.dataclass
class FlowView:
    """Read-only per-flow view materialised from the columns (debug/tests)."""

    flow_id: int
    transfer: Transfer
    path: tuple[int, ...]
    bytes_remaining: float
    rate: float


class FlowPlane:
    """Columnar fluid flow simulator over the fat-tree's directed links."""

    def __init__(self, tree: FatTree, background: BackgroundTraffic, seed: int = 0,
                 capacity: int = 64, nic_policy: "str | NicPolicy" = "hash"):
        self.tree = tree
        self.bg = background
        self.rng = np.random.default_rng(seed)
        # NIC choice is resolved here, at flow start: the policy sees the
        # engine's live per-link open-flow counters (least-loaded) or its
        # own counters (rail-affine), so it must be engine-local — parity
        # drives resolve one instance per engine from the name.
        self.nic_policy = make_nic_policy(nic_policy)
        self.nic_policy.bind(lambda lids: self._link_nflows[lids])
        self._next_flow = 0
        self._next_transfer = 0
        self._last_advance = 0.0
        self.completed_transfers = 0
        self.bytes_delivered = 0.0
        self._tier_bytes = np.zeros(4, np.float64)
        # ---- flow columns (slot-indexed; slots recycled via a free list) --
        cap = max(int(capacity), 1)
        self.f_id = np.full(cap, -1, np.int64)
        self.f_bytes = np.zeros(cap, np.float64)          # bytes_remaining
        self.f_rate = np.zeros(cap, np.float64)
        self.f_tier = np.zeros(cap, np.int64)
        self.f_transfer = np.full(cap, -1, np.int64)      # transfer id
        self.f_bneck = np.full(cap, -1, np.int64)         # last bottleneck link
        # Path rows are padded with the virtual link id ``n_links`` (capacity
        # +inf, never a bottleneck), so every array op can ignore ragged
        # path lengths without masking.  int16 link ids (topologies under
        # ~32k links, i.e. any fat tree this repo builds) keep the stable
        # argsort in the water-filling CSR build on NumPy's radix path.
        self._pad = tree.n_links
        self._path_dtype = np.int16 if tree.n_links < 2**15 - 1 else np.int32
        self.f_path = np.full((cap, MAX_PATH_LEN), self._pad, self._path_dtype)
        self._free: list[int] = list(range(cap - 1, -1, -1))
        # Creation-order registry of live slots (dict => preserves insertion
        # order under deletion, mirroring the reference's flow dict).
        self._slot_order: dict[int, None] = {}
        self._transfers: dict[int, Transfer] = {}         # open transfers
        self._tslots: dict[int, list[int]] = {}           # transfer -> slots
        # Arrival epoch: while open, start_transfer defers its rate
        # recomputation and accumulates dirty links; end_epoch runs one
        # union recompute (see begin_epoch).
        self._epoch_dirty: list[np.ndarray] | None = None
        # Per-link open-flow count, maintained incrementally on flow
        # add/remove (slot [pad] accumulates padding hops; never read).
        # Feeds the least-loaded NIC policy's argmin.
        self._link_nflows = np.zeros(tree.n_links + 1, np.int64)
        # ---- residual capacity plane (piecewise-constant bg sampling) ----
        self._resid_caps = np.empty(tree.n_links + 1, np.float64)
        self._bg_time = 0.0
        self._sample_background(0.0)
        # Optional water-filling instrumentation: when a list, every
        # recompute appends its per-round (bottleneck link id, share)
        # sequence — the oracle trace the jitted solver
        # (``kernels.waterfill``) must reproduce exactly.
        self._wf_trace: list[tuple[int, float]] | None = None
        # TracePlane instrumentation: when on, each water-fill round also
        # stamps the fixing link id into ``f_bneck`` so a completing
        # Transfer can report the bottleneck that set its final rate.
        self.record_bottlenecks = False

    # ------------------------------------------------------------- internals
    def _sample_background(self, now: float) -> None:
        """(Re)sample bg utilisation into the residual-capacity vector."""
        u = np.array([self.bg.util(t, now) for t in range(4)], np.float64)
        self._resid_caps[:-1] = self.tree.link_capacity * (1.0 - u[self.tree.link_tier])
        self._resid_caps[-1] = np.inf
        self._bg_time = now

    def _ordered_slots(self) -> np.ndarray:
        return np.fromiter(self._slot_order, np.intp, len(self._slot_order))

    def _grow(self) -> None:
        cap = len(self.f_id)
        new_cap = cap * 2
        for name in ("f_id", "f_bytes", "f_rate", "f_tier", "f_transfer",
                     "f_bneck"):
            old = getattr(self, name)
            new = np.zeros(new_cap, old.dtype)
            new[:cap] = old
            setattr(self, name, new)
        path = np.full((new_cap, MAX_PATH_LEN), self._pad, self._path_dtype)
        path[:cap] = self.f_path
        self.f_path = path
        self._free.extend(range(new_cap - 1, cap - 1, -1))

    def _alloc_slot(self) -> int:
        if not self._free:
            self._grow()
        return self._free.pop()

    def _remove_slot(self, s: int) -> None:
        del self._slot_order[s]
        self.f_id[s] = -1
        self.f_rate[s] = 0.0
        # Real links appear at most once per row, so fancy subtraction is
        # exact for them (the pad slot collects garbage; never read).
        self._link_nflows[self.f_path[s]] -= 1
        self.f_path[s] = self._pad
        self._free.append(s)

    # ------------------------------------------------------------------ API
    def start_transfer(
        self,
        src: tuple[int, int, int],
        dst: tuple[int, int, int],
        total_bytes: float,
        now: float,
        on_complete: Callable[[Transfer, float], None],
        n_flows: int = 4,
    ) -> Transfer:
        """Begin a KV transfer of ``total_bytes`` as n parallel shard flows."""
        self.advance(now)
        tier = self.tree.tier(src, dst)
        t = Transfer(
            self._next_transfer, src, dst, tier, total_bytes, now, on_complete
        )
        self._next_transfer += 1
        if total_bytes <= 0:
            # Pure-latency transfer (100 % prefix hit): complete immediately
            # after base latency; caller handles via zero-byte fast path.
            t.done = True
            t.finish_time = now + self.tree.tier_latency[tier]
            return t
        per_flow = total_bytes / n_flows
        # One ECMP hash per transfer: TP shard flows share the host pair and
        # take the same uplinks, so the per-transfer uncontested ceiling is
        # exactly B_tau while distinct transfers can still collide.  Same
        # RNG draw sequence as the reference's flow_path.  The NIC pair is
        # resolved here, at flow start, by the engine's NIC policy (tier 0
        # never crosses a NIC and must not consume policy draws or size
        # observations).
        if tier == 0:
            nics = (0, 0)
        else:
            self.nic_policy.observe(total_bytes)
            nics = self.nic_policy.pick(
                self.tree, self.tree.server_index(src),
                self.tree.server_index(dst), self.rng)
        row, plen = self.tree.path_row(src, dst, self.rng, nics=nics)
        row = np.where(row < 0, self._pad, row).astype(self._path_dtype)
        slots = []
        for _ in range(n_flows):
            s = self._alloc_slot()
            self.f_id[s] = self._next_flow
            self._next_flow += 1
            self.f_bytes[s] = per_flow
            self.f_rate[s] = 0.0
            self.f_tier[s] = tier
            self.f_transfer[s] = t.transfer_id
            self.f_bneck[s] = -1
            self.f_path[s] = row
            self._slot_order[s] = None
            slots.append(s)
            t.flows_open += 1
        self._transfers[t.transfer_id] = t
        self._tslots[t.transfer_id] = slots
        self._link_nflows[row] += n_flows
        if self._epoch_dirty is not None:
            self._epoch_dirty.append(row[:plen])
        else:
            self._recompute_rates(dirty_links=row[:plen])
        return t

    # -------------------------------------------------------- arrival epochs
    @property
    def in_epoch(self) -> bool:
        return self._epoch_dirty is not None

    def begin_epoch(self) -> None:
        """Batch same-instant transfer arrivals into one rate recompute.

        Water-filling rates depend only on the *current* flow set, so
        admitting a burst of same-timestamp transfers and recomputing once
        over the union of their dirty links yields bit-identical final
        rates to the per-arrival recompute sequence (no time passes between
        the arrivals, so no bytes drain at the intermediate rates) — one
        dirty-component pass instead of one per transfer.
        """
        if self._epoch_dirty is not None:
            raise RuntimeError("FlowPlane epoch already open")
        self._epoch_dirty = []

    def end_epoch(self) -> None:
        dirty, self._epoch_dirty = self._epoch_dirty, None
        if dirty:
            self._recompute_rates(dirty_links=np.concatenate(dirty))

    def abort_transfer(self, transfer: Transfer, now: float) -> None:
        """Tear down every flow of ``transfer`` immediately.

        The per-link open-flow counters (``_link_nflows``, the signal the
        ``least-loaded`` NIC policy argmins over) are reconciled *here*, at
        abort time, by ``_remove_slot`` — not when the flow would later
        have been popped — and ``flows_open`` drops to zero with them, so
        the Transfer record and the counters stay in lockstep with the
        reference engine's recount (``tests/test_chunkplane.py`` proves
        counter parity after fault-driven aborts).
        """
        self.advance(now)
        dead = [s for s in self._tslots.pop(transfer.transfer_id, ())
                if s in self._slot_order]
        touched = self.f_path[dead, :].ravel() if dead else None
        for s in dead:
            self._remove_slot(s)
        self._transfers.pop(transfer.transfer_id, None)
        transfer.aborted = True
        transfer.done = True
        transfer.flows_open = 0
        if dead:
            self._recompute_rates(dirty_links=touched)

    def advance(self, now: float) -> None:
        """Drain bytes at current rates from the last advance point to now."""
        dt = now - self._last_advance
        if dt < 0:
            raise ValueError(f"time went backwards: {self._last_advance} -> {now}")
        if dt == 0.0 or not self._slot_order:
            self._last_advance = now
            return
        slots = self._ordered_slots()
        rem = self.f_bytes[slots]
        moved = np.minimum(rem, self.f_rate[slots] * dt)
        self.f_bytes[slots] = rem - moved
        # Ordered (sequential) accumulation: np.add.at applies the additions
        # in index order, reproducing the reference's per-flow running sums
        # bit-for-bit where a pairwise .sum() would not.
        acc = np.array([self.bytes_delivered])
        np.add.at(acc, np.zeros(len(slots), np.intp), moved)
        self.bytes_delivered = float(acc[0])
        np.add.at(self._tier_bytes, self.f_tier[slots], moved)
        self._last_advance = now
        # 1-byte completion threshold: float residue from rate*dt would
        # otherwise strand sub-byte remainders and storm the event loop.
        finished = slots[self.f_bytes[slots] <= 1.0]
        if len(finished) == 0:
            return
        touched = self.f_path[finished, :].ravel()
        done_transfers: list[Transfer] = []
        for s in finished:           # creation order, matching the reference
            tid = int(self.f_transfer[s])
            if self.record_bottlenecks:
                self._transfers[tid].bottleneck_link = int(self.f_bneck[s])
            self._remove_slot(s)
            t = self._transfers[tid]
            t.flows_open -= 1
            self._tslots[tid].remove(s)
            if t.flows_open == 0:
                del self._transfers[tid]
                del self._tslots[tid]
                if not t.aborted:
                    t.done = True
                    t.finish_time = now
                    done_transfers.append(t)
        self._recompute_rates(dirty_links=touched)
        for t in done_transfers:
            self.completed_transfers += 1
            t.on_complete(t, now)

    def next_completion_time(self, now: float) -> Optional[float]:
        """Earliest moment any flow drains at current rates (None if idle)."""
        if not self._slot_order:
            return None
        slots = self._ordered_slots()
        rates = self.f_rate[slots]
        live = rates > 0
        if not live.any():
            return None
        etas = self.f_bytes[slots][live] / rates[live]
        return float(now + etas.min() + 1e-9)

    def refresh_rates(self, now: float) -> None:
        """Periodic tick: resample background, full water-filling pass."""
        self.advance(now)
        self._sample_background(now)
        if self._slot_order:
            self._recompute_rates(dirty_links=None)

    def on_rewire(self, now: float) -> None:
        """Topology capacities changed (``FatTree.rewire``): re-water-fill.

        Bytes drain at the old rates up to ``now`` (the reconfiguration
        instant), then the residual-capacity plane is rebuilt from the new
        ``link_capacity`` table and every in-flight flow is re-water-filled
        in one full pass — the swap moves capacity under *all* components at
        once, so no flow may keep a rate assigned against the old
        capacities (it could silently sit over the new ones).
        """
        if self._epoch_dirty is not None:
            raise RuntimeError("cannot rewire inside an open arrival epoch")
        self.refresh_rates(now)

    def on_rewire_links(self, link_ids, now: float) -> None:
        """Per-link capacity retarget (``FatTree.rewire_links``): refresh
        only the touched links' residuals and re-water-fill their dirty
        component.

        Unlike the tier-level :meth:`on_rewire`, a per-link edit provably
        cannot move any rate outside the connected component of flows
        crossing the edited links (max-min decomposes over link-disjoint
        components), so the full refresh pass is skipped.  The residual is
        rebuilt with the background utilisation as of the *last sample
        tick* (``_bg_time``), keeping the piecewise-constant sampling
        contract: all other links' residuals stay untouched between ticks.
        """
        if self._epoch_dirty is not None:
            raise RuntimeError("cannot rewire inside an open arrival epoch")
        self.advance(now)
        lids = np.unique(np.asarray(link_ids, np.int64).ravel())
        if lids.size == 0:
            return
        u = np.array([self.bg.util(t, self._bg_time) for t in range(4)],
                     np.float64)
        tiers = self.tree.link_tier[lids]
        self._resid_caps[lids] = self.tree.link_capacity[lids] * (1.0 - u[tiers])
        if self._slot_order:
            self._recompute_rates(dirty_links=lids)

    # -------------------------------------------------------- water-filling
    def _recompute_rates(self, dirty_links: np.ndarray | None = None) -> None:
        """Vectorised progressive water-filling (max-min fair sharing).

        ``dirty_links=None`` recomputes every flow.  Otherwise only the
        connected component of flows reachable from ``dirty_links`` through
        shared links is recomputed: max-min allocations decompose exactly
        over link-disjoint components, so untouched flows keep their rates
        (bit-for-bit what a full recompute would assign them).
        """
        if not self._slot_order:
            return
        slots = self._ordered_slots()
        P = self.f_path[slots]                       # (k, MAX_PATH_LEN)
        pad = self._pad
        if dirty_links is not None:
            link_dirty = np.zeros(pad + 1, bool)
            link_dirty[dirty_links] = True
            link_dirty[pad] = False
            flow_dirty = np.zeros(len(slots), bool)
            while True:
                hit = link_dirty[P].any(axis=1) & ~flow_dirty
                if not hit.any():
                    break
                flow_dirty |= hit
                link_dirty[self.f_path[slots[hit]].ravel()] = True
                link_dirty[pad] = False
            if not flow_dirty.any():
                return
            slots = slots[flow_dirty]
            P = P[flow_dirty]
        k = len(slots)
        flat = P.ravel()                             # row-major: flow x hop
        # First-encounter order per link (flow-creation x hop order) — the
        # tie-break the reference's insertion-ordered dict scan applies.
        # The whole fixed point runs in *encounter-permuted* link space so
        # the per-round bottleneck pick is a single argmin (first minimum in
        # scan order == first-encountered link with the minimal share).
        enc = np.full(pad + 1, flat.size + 1, np.int64)
        np.minimum.at(enc, flat, np.arange(flat.size))
        perm = np.argsort(enc, kind="stable")        # unseen links sort last
        inv = np.empty_like(perm)
        inv[perm] = np.arange(pad + 1)
        P = inv[P].astype(self._path_dtype)          # permuted path matrix
        flat = P.ravel()
        counts = np.bincount(flat, minlength=pad + 1)
        ppad = int(inv[pad])
        counts[ppad] = 0
        # CSR link -> flow-row index, built once per recompute.  The stable
        # sort keeps rows in flow-creation order within each link, which is
        # both the reference's per-link flow order (for the residual
        # subtraction sequence) and what makes each round O(flows-on-link).
        csr_order = np.argsort(flat, kind="stable")
        csr_rows = csr_order // MAX_PATH_LEN
        csr_start = np.searchsorted(flat[csr_order], np.arange(pad + 2))
        caps = self._resid_caps[perm]
        shares = np.empty(pad + 1, np.float64)
        unfixed = np.ones(k, bool)
        rates = np.zeros(k, np.float64)
        n_unfixed = k
        while n_unfixed:
            shares.fill(np.inf)
            np.divide(caps, counts, out=shares, where=counts > 0)
            lid = int(np.argmin(shares))             # enc-order tie-break
            share = shares[lid]
            if share == np.inf:  # pragma: no cover - every flow has links
                rates[unfixed] = np.inf
                break
            if self._wf_trace is not None:
                self._wf_trace.append((int(perm[lid]), float(share)))
            rows = csr_rows[csr_start[lid]:csr_start[lid + 1]]
            fixed_rows = rows[unfixed[rows]]         # flow-creation order
            rates[fixed_rows] = share
            if self.record_bottlenecks:
                self.f_bneck[slots[fixed_rows]] = perm[lid]
            idx = P[fixed_rows].ravel()              # reference subtraction order
            np.subtract.at(caps, idx, share)
            np.maximum(caps, 0.0, out=caps)
            np.subtract.at(counts, idx, 1)           # padded hops go negative:
            n_unfixed -= len(fixed_rows)             # counts<=0 is never active
            unfixed[fixed_rows] = False
        self.f_rate[slots] = rates

    # ------------------------------------------------------------ telemetry
    def open_flow_counts(self) -> np.ndarray:
        """Per-link open-flow counters (real links only) — the incremental
        state the least-loaded NIC policy reads; must equal a from-scratch
        recount of live flows at all times, including right after aborts."""
        return self._link_nflows[:-1].copy()

    def tier_congestion(self, now: float) -> dict[int, float]:
        """Operator-side per-tier congestion, *excluding* marked KV flows.

        The scheduler's own transfers ride a dedicated DSCP class (§III-D),
        so the operator's aggregation reports only external (background)
        utilisation — this is exactly what keeps c_tau and n_inflight from
        double counting.
        """
        return self.bg.tier_map(now)

    def tier_utilization_observed(self, now: float) -> dict[int, float]:
        """Diagnostic: cumulative KV bytes moved per tier (for Table VI)."""
        return {t: float(self._tier_bytes[t]) for t in range(4)}

    def link_utilization(self) -> tuple[np.ndarray, np.ndarray]:
        """(per-link aggregate flow rate, residual capacity) diagnostics.

        Real (non-padding) links only; feeds the max-min invariant tests and
        the measured-telemetry oracle aggregation.
        """
        load = np.zeros(self._pad + 1, np.float64)
        if self._slot_order:
            slots = self._ordered_slots()
            np.add.at(load, self.f_path[slots].ravel(),
                      np.repeat(self.f_rate[slots], self.f_path.shape[1]))
        load[self._pad] = 0.0
        return load[:-1], self._resid_caps[:-1].copy()

    def measured_tier_congestion(self, now: float, include_kv: bool = True
                                 ) -> dict[int, float]:
        """Per-tier congestion aggregated from *measured* link counters.

        Instead of the background model's ground truth
        (``tier_congestion``), this sums what switch byte counters would
        report on every link of a tier — background occupancy
        (capacity - residual) plus, with ``include_kv``, the scheduler's own
        in-flight KV flow rates (an operator whose aggregation cannot
        subtract the KV DSCP class) — divided by the tier's aggregate raw
        capacity.  This is the realistic telemetry regime for the staleness
        experiments: the signal now contains self-traffic feedback and
        ECMP-imbalance noise the mean-field model hides.
        """
        load, resid = self.link_utilization()
        cap = self.tree.link_capacity
        used = cap - resid
        if include_kv:
            used = used + np.minimum(load, resid)
        tiers = self.tree.link_tier
        cap_t = np.bincount(tiers, weights=cap, minlength=4)[:4]
        used_t = np.bincount(tiers, weights=used, minlength=4)[:4]
        with np.errstate(invalid="ignore", divide="ignore"):
            u = np.where(cap_t > 0, used_t / np.maximum(cap_t, 1e-12), 0.0)
        return {t: float(np.clip(u[t], 0.0, 0.999)) for t in range(4)}

    # ---------------------------------------------------------------- debug
    @property
    def flows(self) -> dict[int, FlowView]:
        """Per-flow object view materialised on demand (tests/debug only)."""
        out = {}
        for s in self._slot_order:
            path = tuple(int(l) for l in self.f_path[s] if l != self._pad)
            out[int(self.f_id[s])] = FlowView(
                flow_id=int(self.f_id[s]),
                transfer=self._transfers[int(self.f_transfer[s])],
                path=path,
                bytes_remaining=float(self.f_bytes[s]),
                rate=float(self.f_rate[s]),
            )
        return out

    @property
    def n_flows_active(self) -> int:
        return len(self._slot_order)


# The production engine; the per-object original is
# ``cluster.reference.ReferenceFlowNetwork``.
FlowNetwork = FlowPlane
