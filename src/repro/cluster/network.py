"""Flow-level network model: per-link max-min fair sharing + ECMP (§VI-B).

Each KV transfer is realised as ``n_flows`` parallel flows (one per TP shard)
sharing the source NIC, each ECMP-hashed independently onto uplinks.  On
every flow arrival/completion all coexisting flows on shared links are
re-evaluated (progressive water-filling), the model RDMA congestion control
(DCQCN) converges to.  Background traffic is a steady-state per-link
utilisation fraction that scales down residual capacity — the mean-field
approximation of §VI-B — optionally time-varying for the staleness and
congestion-dynamics experiments.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from .topology import FatTree


class BackgroundTraffic:
    """Per-tier offered-load fraction, optionally time-varying.

    ``base[tier]`` is the mean utilisation; with ``wander > 0`` the
    instantaneous value follows a slow sinusoid + per-refresh jitter
    (seeded), giving the oracle something real to track in Exp. 4.
    """

    def __init__(
        self,
        base: dict[int, float] | float = 0.0,
        wander: float = 0.0,
        period: float = 7.0,
        seed: int = 0,
    ) -> None:
        if isinstance(base, (int, float)):
            base = {0: 0.0, 1: float(base), 2: float(base), 3: float(base)}
        self.base = {t: float(base.get(t, 0.0)) for t in range(4)}
        self.wander = wander
        self.period = period
        self._phase = {t: np.random.default_rng(seed + t).uniform(0, 2 * math.pi) for t in range(4)}

    def util(self, tier: int, now: float) -> float:
        u = self.base[tier]
        if self.wander > 0.0 and u > 0.0:
            u = u * (1.0 + self.wander * math.sin(2 * math.pi * now / self.period + self._phase[tier]))
        return float(min(max(u, 0.0), 0.95))

    def tier_map(self, now: float) -> dict[int, float]:
        return {t: self.util(t, now) for t in range(4)}


@dataclasses.dataclass
class Flow:
    flow_id: int
    transfer: "Transfer"
    path: tuple[int, ...]
    bytes_remaining: float
    rate: float = 0.0


@dataclasses.dataclass
class Transfer:
    transfer_id: int
    src: tuple[int, int, int]
    dst: tuple[int, int, int]
    tier: int
    total_bytes: float
    start_time: float
    on_complete: Callable[["Transfer", float], None]
    flows_open: int = 0
    done: bool = False
    aborted: bool = False
    finish_time: float | None = None


class FlowNetwork:
    """Fluid flow simulator over the fat-tree's directed links."""

    def __init__(self, tree: FatTree, background: BackgroundTraffic, seed: int = 0):
        self.tree = tree
        self.bg = background
        self.rng = np.random.default_rng(seed)
        self.flows: dict[int, Flow] = {}
        self._next_flow = 0
        self._next_transfer = 0
        self._last_advance = 0.0
        self.completed_transfers = 0
        self.bytes_delivered = 0.0
        self._tier_bytes = {0: 0.0, 1: 0.0, 2: 0.0, 3: 0.0}

    # ------------------------------------------------------------------ API
    def start_transfer(
        self,
        src: tuple[int, int, int],
        dst: tuple[int, int, int],
        total_bytes: float,
        now: float,
        on_complete: Callable[[Transfer, float], None],
        n_flows: int = 4,
    ) -> Transfer:
        """Begin a KV transfer of ``total_bytes`` as n parallel shard flows."""
        self.advance(now)
        tier = self.tree.tier(src, dst)
        t = Transfer(
            self._next_transfer, src, dst, tier, total_bytes, now, on_complete
        )
        self._next_transfer += 1
        if total_bytes <= 0:
            # Pure-latency transfer (100 % prefix hit): complete immediately
            # after base latency; caller handles via zero-byte fast path.
            t.done = True
            t.finish_time = now + self.tree.tier_latency[tier]
            return t
        per_flow = total_bytes / n_flows
        # One ECMP hash per transfer: TP shard flows share the host pair and
        # take the same uplinks, so the per-transfer uncontested ceiling is
        # exactly B_tau while distinct transfers can still collide.
        path = tuple(self.tree.flow_path(src, dst, self.rng))
        for _ in range(n_flows):
            f = Flow(self._next_flow, t, path, per_flow)
            self._next_flow += 1
            self.flows[f.flow_id] = f
            t.flows_open += 1
        self._recompute_rates(now)
        return t

    def abort_transfer(self, transfer: Transfer, now: float) -> None:
        self.advance(now)
        dead = [fid for fid, f in self.flows.items() if f.transfer is transfer]
        for fid in dead:
            del self.flows[fid]
        transfer.aborted = True
        transfer.done = True
        if dead:
            self._recompute_rates(now)

    def advance(self, now: float) -> None:
        """Drain bytes at current rates from the last advance point to now."""
        dt = now - self._last_advance
        if dt < 0:
            raise ValueError(f"time went backwards: {self._last_advance} -> {now}")
        if dt == 0.0 or not self.flows:
            self._last_advance = now
            return
        finished: list[Flow] = []
        for f in self.flows.values():
            moved = min(f.bytes_remaining, f.rate * dt)
            f.bytes_remaining -= moved
            self.bytes_delivered += moved
            self._tier_bytes[f.transfer.tier] += moved
            # 1-byte completion threshold: float residue from rate*dt would
            # otherwise strand sub-byte remainders and storm the event loop.
            if f.bytes_remaining <= 1.0:
                finished.append(f)
        self._last_advance = now
        if finished:
            done_transfers: list[Transfer] = []
            for f in finished:
                del self.flows[f.flow_id]
                f.transfer.flows_open -= 1
                if f.transfer.flows_open == 0 and not f.transfer.aborted:
                    f.transfer.done = True
                    f.transfer.finish_time = now
                    done_transfers.append(f.transfer)
            self._recompute_rates(now)
            for t in done_transfers:
                self.completed_transfers += 1
                t.on_complete(t, now)

    def next_completion_time(self, now: float) -> Optional[float]:
        """Earliest moment any flow drains at current rates (None if idle)."""
        best = None
        for f in self.flows.values():
            if f.rate <= 0:
                continue
            eta = now + f.bytes_remaining / f.rate + 1e-9
            if best is None or eta < best:
                best = eta
        return best

    def refresh_rates(self, now: float) -> None:
        """Periodic tick so time-varying background traffic takes effect."""
        self.advance(now)
        if self.flows:
            self._recompute_rates(now)

    # -------------------------------------------------------- water-filling
    def _recompute_rates(self, now: float) -> None:
        if not self.flows:
            return
        flows_on_link: dict[int, list[int]] = {}
        for fid, f in self.flows.items():
            for lid in f.path:
                flows_on_link.setdefault(lid, []).append(fid)
        caps = {
            lid: self.tree.links[lid].capacity
            * (1.0 - self.bg.util(self.tree.links[lid].tier, now))
            for lid in flows_on_link
        }
        unfixed = set(self.flows.keys())
        while unfixed:
            bottleneck = None
            for lid, fl in flows_on_link.items():
                active = [fid for fid in fl if fid in unfixed]
                if not active:
                    continue
                share = caps[lid] / len(active)
                if bottleneck is None or share < bottleneck[0]:
                    bottleneck = (share, lid, active)
            if bottleneck is None:  # pragma: no cover - every flow has links
                for fid in unfixed:
                    self.flows[fid].rate = float("inf")
                break
            share, lid, active = bottleneck
            for fid in active:
                self.flows[fid].rate = share
                unfixed.discard(fid)
                for l2 in self.flows[fid].path:
                    caps[l2] = max(0.0, caps.get(l2, 0.0) - share)
            flows_on_link.pop(lid, None)

    # ------------------------------------------------------------ telemetry
    def tier_congestion(self, now: float) -> dict[int, float]:
        """Operator-side per-tier congestion, *excluding* marked KV flows.

        The scheduler's own transfers ride a dedicated DSCP class (§III-D),
        so the operator's aggregation reports only external (background)
        utilisation — this is exactly what keeps c_tau and n_inflight from
        double counting.
        """
        return self.bg.tier_map(now)

    def tier_utilization_observed(self, now: float, window_bytes: bool = False):
        """Diagnostic: cumulative KV bytes moved per tier (for Table VI)."""
        return dict(self._tier_bytes)
