"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
— llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="smollm-135m", d_model=576, n_layers=30, n_heads=9, n_kv_heads=3,
    d_head=64, d_ff=1536, vocab_size=49152, rope_theta=1e4, remat=True,
)
SMOKE = ModelConfig(
    name="smollm-135m-smoke", d_model=96, n_layers=3, n_heads=3, n_kv_heads=3,
    d_head=32, d_ff=192, vocab_size=512,
)
SPEC = ArchSpec(
    arch_id="smollm-135m", model=CONFIG, smoke=SMOKE,
    source="[hf:HuggingFaceTB/SmolLM-135M; hf]", train_microbatches=4,
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
