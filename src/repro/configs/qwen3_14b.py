"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="qwen3-14b", d_model=5120, n_layers=40, n_heads=40, n_kv_heads=8,
    d_head=128, d_ff=17408, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    remat=True,
)
SMOKE = ModelConfig(
    name="qwen3-14b-smoke", d_model=128, n_layers=4, n_heads=8, n_kv_heads=2,
    d_head=16, d_ff=256, vocab_size=512, qk_norm=True,
)
SPEC = ArchSpec(
    arch_id="qwen3-14b", model=CONFIG, smoke=SMOKE,
    source="[hf:Qwen/Qwen3-8B; hf]", train_microbatches=8,
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
