"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay.  [arXiv:2404.05892; hf]

NetKV arch-applicability note (DESIGN §4): the transferred decode state is
O(1) in sequence length (WKV + shift states), so Prop. 1's context-length
amplification does not apply; the scheduler still routes the state transfer.
"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="rwkv6-3b", d_model=2560, n_layers=32, n_heads=40, n_kv_heads=40,
    d_head=64, d_ff=8960, vocab_size=65536,
    block_pattern=("rwkv",), ffn_pattern=("none",), remat=True,
)
SMOKE = ModelConfig(
    name="rwkv6-smoke", d_model=128, n_layers=3, n_heads=2, n_kv_heads=2,
    d_head=64, d_ff=256, vocab_size=512,
    block_pattern=("rwkv",), ffn_pattern=("none",),
)
SPEC = ArchSpec(
    arch_id="rwkv6-3b", model=CONFIG, smoke=SMOKE,
    source="[arXiv:2404.05892; hf]", train_microbatches=4,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
