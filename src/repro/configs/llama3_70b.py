"""llama3-70b — the PAPER's evaluation model (§VI-A): 80L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256.  KV = 320 KB/token aggregate (Eq. 1).
[arXiv:2407.21783; hf]"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="llama3-70b", d_model=8192, n_layers=80, n_heads=64, n_kv_heads=8,
    d_head=128, d_ff=28672, vocab_size=128256, rope_theta=5e5, remat=True,
)
SMOKE = ModelConfig(
    name="llama3-70b-smoke", d_model=128, n_layers=4, n_heads=8, n_kv_heads=2,
    d_head=16, d_ff=256, vocab_size=512,
)
SPEC = ArchSpec(
    arch_id="llama3-70b", model=CONFIG, smoke=SMOKE,
    source="[arXiv:2407.21783; hf]", train_microbatches=16,
    serve_fsdp=True, decode_cache_shard="seq",
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
