"""Architecture registry: --arch <id> resolution."""

from __future__ import annotations

import importlib

_MODULES = {
    "qwen3-14b": "qwen3_14b",
    "phi3-medium-14b": "phi3_medium_14b",
    "smollm-135m": "smollm_135m",
    "internlm2-20b": "internlm2_20b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "arctic-480b": "arctic_480b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "internvl2-76b": "internvl2_76b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "rwkv6-3b": "rwkv6_3b",
    "llama3-70b": "llama3_70b",   # the paper's own model
}

ASSIGNED = [k for k in _MODULES if k != "llama3-70b"]
ALL = list(_MODULES)


def get_spec(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ALL}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SPEC
