"""seamless-m4t-medium [audio]: enc-dec 12L+12L d_model=1024 16H (GQA kv=16)
d_ff=4096 vocab=256206 — multimodal; audio frontend is a STUB providing
precomputed frame embeddings to the encoder.  [arXiv:2308.11596; hf]"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="seamless-m4t-medium", d_model=1024, n_layers=12, n_heads=16, n_kv_heads=16,
    d_head=64, d_ff=4096, vocab_size=256206,
    n_enc_layers=12, frontend="audio", rope_theta=1e4, remat=True,
)
SMOKE = ModelConfig(
    name="seamless-smoke", d_model=128, n_layers=2, n_heads=4, n_kv_heads=4,
    d_head=32, d_ff=256, vocab_size=512, n_enc_layers=2, frontend="audio",
)
SPEC = ArchSpec(
    arch_id="seamless-m4t-medium", model=CONFIG, smoke=SMOKE,
    source="[arXiv:2308.11596; hf]", train_microbatches=8,
    skip_notes={"long_500k": "encoder-decoder full attention: 500k decode skipped"},
)
