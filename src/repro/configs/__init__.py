"""Per-architecture configs (--arch <id>) + benchmark input shapes."""

from .base import ArchSpec, SHAPES
from .registry import ALL, ASSIGNED, get_spec

__all__ = ["ArchSpec", "SHAPES", "ALL", "ASSIGNED", "get_spec"]
