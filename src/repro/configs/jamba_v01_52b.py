"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every 2nd layer.
[arXiv:2403.19887; hf]

Period of 8 layers: attention at position 4 (rest Mamba); MoE on odd
positions (e:2 spacing), dense FFN elsewhere; Mamba layers carry no extra
FFN at even positions per the published block diagram simplification.
"""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig
from .base import ArchSpec

_BLOCKS = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")
_FFN = ("dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8,
    d_head=128, d_ff=14336, vocab_size=65536,
    block_pattern=_BLOCKS, ffn_pattern=_FFN,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, dispatch_chunks=8),
    rope_theta=1e4, remat=True,
)
SMOKE = ModelConfig(
    name="jamba-52b-smoke", d_model=128, n_layers=8, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab_size=512,
    block_pattern=_BLOCKS, ffn_pattern=_FFN,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=256),
)
SPEC = ArchSpec(
    arch_id="jamba-v0.1-52b", model=CONFIG, smoke=SMOKE,
    source="[arXiv:2403.19887; hf]", train_microbatches=16,
    optimizer="adafactor",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
