"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512,
MoE 32e top-8, vocab=49155.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", d_model=1024, n_layers=24, n_heads=16, n_kv_heads=8,
    d_head=64, d_ff=512, vocab_size=49155,
    ffn_pattern=("moe",),
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512, dispatch_chunks=4),
    rope_theta=1e4, remat=True,
)
SMOKE = ModelConfig(
    name="granite-moe-smoke", d_model=128, n_layers=3, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=96, vocab_size=512,
    ffn_pattern=("moe",), moe=MoEConfig(n_experts=8, top_k=4, d_expert=96),
)
SPEC = ArchSpec(
    arch_id="granite-moe-1b-a400m", model=CONFIG, smoke=SMOKE,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
    train_microbatches=8,
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
