"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT frontend (STUB: precomputed patch embeddings) +
InternLM2-76B-class backbone.  [arXiv:2404.16821; unverified]"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="internvl2-76b", d_model=8192, n_layers=80, n_heads=64, n_kv_heads=8,
    d_head=128, d_ff=28672, vocab_size=128256,
    frontend="vision", n_prefix_embeds=256, rope_theta=1e6, remat=True,
)
SMOKE = ModelConfig(
    name="internvl2-smoke", d_model=128, n_layers=4, n_heads=8, n_kv_heads=2,
    d_head=16, d_ff=256, vocab_size=512, frontend="vision", n_prefix_embeds=8,
)
SPEC = ArchSpec(
    arch_id="internvl2-76b", model=CONFIG, smoke=SMOKE,
    source="[arXiv:2404.16821; unverified]", train_microbatches=16,
    serve_fsdp=True, decode_cache_shard="seq",
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
