"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="phi3-medium-14b", d_model=5120, n_layers=40, n_heads=40, n_kv_heads=10,
    d_head=128, d_ff=17920, vocab_size=100352, rope_theta=1e4, remat=True,
)
SMOKE = ModelConfig(
    name="phi3-medium-14b-smoke", d_model=128, n_layers=4, n_heads=8, n_kv_heads=2,
    d_head=16, d_ff=256, vocab_size=512,
)
SPEC = ArchSpec(
    arch_id="phi3-medium-14b", model=CONFIG, smoke=SMOKE,
    source="[arXiv:2404.14219; unverified]", train_microbatches=8,
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
