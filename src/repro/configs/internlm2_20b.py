"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA.  [arXiv:2403.17297; hf]"""

from repro.models.model import ModelConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="internlm2-20b", d_model=6144, n_layers=48, n_heads=48, n_kv_heads=8,
    d_head=128, d_ff=16384, vocab_size=92544, rope_theta=1e6, remat=True,
)
SMOKE = ModelConfig(
    name="internlm2-20b-smoke", d_model=128, n_layers=4, n_heads=8, n_kv_heads=2,
    d_head=16, d_ff=256, vocab_size=512,
)
SPEC = ArchSpec(
    arch_id="internlm2-20b", model=CONFIG, smoke=SMOKE,
    source="[arXiv:2403.17297; hf]", train_microbatches=8,
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
