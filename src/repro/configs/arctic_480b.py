"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual.  [hf:Snowflake/snowflake-arctic-base; hf]"""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig
from .base import ArchSpec

CONFIG = ModelConfig(
    name="arctic-480b", d_model=7168, n_layers=35, n_heads=56, n_kv_heads=8,
    d_head=128, d_ff=4864, vocab_size=32000,
    ffn_pattern=("moe_res",),
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864, dense_residual=True,
                  dispatch_chunks=16),
    rope_theta=1e4, remat=True,
)
SMOKE = ModelConfig(
    name="arctic-480b-smoke", d_model=128, n_layers=3, n_heads=8, n_kv_heads=2,
    d_head=16, d_ff=96, vocab_size=512,
    ffn_pattern=("moe_res",),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=96, dense_residual=True),
)
SPEC = ArchSpec(
    arch_id="arctic-480b", model=CONFIG, smoke=SMOKE,
    source="[hf:Snowflake/snowflake-arctic-base; hf]",
    train_microbatches=16, optimizer="adafactor", serve_fsdp=True,
    train_param_dtype="bfloat16", grad_accum_dtype="bfloat16",
    skip_notes={"long_500k": "pure full attention: 500k decode skipped (DESIGN §4)"},
)
