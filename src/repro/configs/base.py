"""ArchSpec: an assigned architecture + its training/serving knobs + the
four benchmark input shapes as ShapeDtypeStruct factories (no allocation).

Shapes (assignment):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill
  decode_32k   seq 32,768  global_batch 128   -> decode_step (KV cache @ 32k)
  long_500k    seq 524,288 global_batch 1     -> decode_step; SSM/hybrid only
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.cost import ModelKVSpec
from repro.models.model import ModelConfig, make_decode_cache, state_bytes

SHAPES = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    model: ModelConfig
    smoke: ModelConfig
    source: str                           # [source; verified-tier]
    train_microbatches: int = 16
    optimizer: str = "adamw"              # "adamw" | "adafactor"
    train_param_dtype: str = "float32"    # "bfloat16" for the MoE giants
    grad_accum_dtype: str = "float32"     # "bfloat16" halves accumulator HBM
    serve_fsdp: bool = False              # shard serving weights over data too
    decode_cache_shard: str = "seq"       # "seq" | "heads" (seq always divides the mesh)
    shapes: tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: dict[str, str] = dataclasses.field(default_factory=dict)

    def kv_spec(self) -> ModelKVSpec:
        """Simulator-side transfer-size model (Eq. 1 generalised)."""
        m = self.model
        fixed = state_bytes(m, 0)
        return ModelKVSpec(
            name=self.arch_id,
            n_layers=m.n_layers,
            n_kv_heads=m.n_kv_heads,
            d_head=m.d_head,
            bytes_per_elem=2,
            n_attn_layers=m.n_attn_layers,
            fixed_state_bytes=fixed,
            tp=4,
        )

    # ------------------------------------------------------------- input specs
    def input_specs(self, shape_name: str) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the step function."""
        if shape_name not in SHAPES:
            raise KeyError(shape_name)
        if shape_name not in self.shapes:
            raise ValueError(
                f"{self.arch_id} skips {shape_name}: "
                f"{self.skip_notes.get(shape_name, 'not applicable')}"
            )
        sh = SHAPES[shape_name]
        s, b = sh["seq_len"], sh["global_batch"]
        m = self.model
        i32 = jnp.int32
        if sh["kind"] == "train":
            batch: dict[str, Any] = {}
            if m.is_enc_dec:
                batch["frames"] = jax.ShapeDtypeStruct((b, s, m.d_model), jnp.bfloat16)
                batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
                batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            elif m.frontend == "vision":
                npfx = m.n_prefix_embeds
                batch["embeds"] = jax.ShapeDtypeStruct((b, npfx, m.d_model), jnp.bfloat16)
                batch["tokens"] = jax.ShapeDtypeStruct((b, s - npfx), i32)
                batch["labels"] = jax.ShapeDtypeStruct((b, s - npfx), i32)
            else:
                batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
                batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            return {"batch": batch}
        if sh["kind"] == "prefill":
            out: dict[str, Any] = {}
            if m.is_enc_dec:
                out["frames"] = jax.ShapeDtypeStruct((b, s, m.d_model), jnp.bfloat16)
                out["tokens"] = jax.ShapeDtypeStruct((b, 256), i32)
            elif m.frontend == "vision":
                npfx = m.n_prefix_embeds
                out["prefix_embeds"] = jax.ShapeDtypeStruct((b, npfx, m.d_model), jnp.bfloat16)
                out["tokens"] = jax.ShapeDtypeStruct((b, s - npfx), i32)
            else:
                out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            return out
        # decode
        cache = make_decode_cache(self.model, b, s, enc_len=s if m.is_enc_dec else 0)
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "cache": cache,
        }

    def runnable_shapes(self) -> list[str]:
        return list(self.shapes)
