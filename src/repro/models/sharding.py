"""Logical-axis sharding: rules resolved against the active mesh.

Model code annotates activations with *logical* axes ("batch", "seq",
"model_dim", "heads", "ff", "vocab", "experts"); the launcher installs a rule
set mapping logical axes onto mesh axes.  Outside a rules context every
constraint is a no-op, so smoke tests run unsharded on one CPU device.

Parameter partition specs are derived from leaf paths:
  train mode -> FSDP + TP (weights sharded over data AND model axes)
  serve mode -> TP only (weights replicated over data, batch sharded)
"""

from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar[Mapping[str, tuple[str, ...]] | None] = contextvars.ContextVar(
    "logical_axis_rules", default=None
)


_MESH: contextvars.ContextVar = contextvars.ContextVar("axis_rules_mesh", default=None)


@contextlib.contextmanager
def axis_rules(rules: Mapping[str, tuple[str, ...] | str | None], mesh=None):
    norm = {}
    for k, v in rules.items():
        if v is None:
            norm[k] = ()
        elif isinstance(v, str):
            norm[k] = (v,)
        else:
            norm[k] = tuple(v)
    token = _RULES.set(norm)
    mtoken = _MESH.set(mesh)
    try:
        yield
    finally:
        _RULES.reset(token)
        _MESH.reset(mtoken)


def current_rules():
    return _RULES.get()


def current_mesh():
    return _MESH.get()


def logical_to_spec(axes: Sequence[str | None]) -> P | None:
    rules = _RULES.get()
    if rules is None:
        return None
    dims = []
    for a in axes:
        if a is None:
            dims.append(None)
        else:
            mesh_axes = rules.get(a, ())
            dims.append(mesh_axes if len(mesh_axes) > 1 else (mesh_axes[0] if mesh_axes else None))
    return P(*dims)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op without rules."""
    spec = logical_to_spec(axes)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter partition specs by leaf path.
# Patterns map path-regex -> logical axes per dim (excluding the leading
# period-stack dim, which is always unsharded).
# ---------------------------------------------------------------------------
_PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed$", ("vocab", "fsdp")),
    (r"lm_head$", ("fsdp", "vocab")),
    (r"pos_embed$", (None, "fsdp")),
    # attention
    (r"(wq|wk|wv)$", ("fsdp", "heads")),
    (r"wo$", ("heads", "fsdp")),
    # dense mlp
    (r"(w_gate|w_up|gate|up)$", ("fsdp", "ff")),
    (r"(w_down|down)$", ("ff", "fsdp")),
    # moe (leading expert dim)
    (r"router$", ("fsdp", None)),
    (r"moe/(w_gate|w_up)$", ("experts", "fsdp_moe", "ff")),
    (r"moe/w_down$", ("experts", "ff", "fsdp_moe")),
    (r"res_(gate|up)$", ("fsdp", "ff")),
    (r"res_down$", ("ff", "fsdp")),
    # mamba
    (r"in_proj$", ("fsdp", "ff")),
    (r"out_proj$", ("ff", "fsdp")),
    (r"x_proj$", ("ff", None)),
    (r"dt_proj$", (None, "ff")),
    (r"(a_log|d_skip|dt_bias)$", ("ff",)),
    (r"conv_w$", (None, "ff")),
    # rwkv
    (r"(w_r|w_k|w_v|w_g)$", ("fsdp", "heads")),
    (r"w_o$", ("heads", "fsdp")),
    (r"cm_k$", ("fsdp", "ff")),
    (r"cm_v$", ("ff", "fsdp")),
    (r"cm_r$", ("fsdp", "heads")),
    (r"(mu_lora_a|decay_lora_a)$", ("fsdp", None)),
    (r"(mu_lora_b|decay_lora_b)$", (None, "fsdp")),
]

TRAIN_RULES = {
    "batch": ("data",), "seq": (), "model_dim": (),
    "heads": ("model",), "ff": ("model",), "vocab": ("model",),
    "experts": ("data",), "fsdp": ("data",), "fsdp_moe": (),
    "kv_seq": (),
}
TRAIN_RULES_MULTIPOD = {
    # FSDP over BOTH pod and data axes: a 480B model's optimizer state only
    # fits when sharded across all 512 chips (EXPERIMENTS.md §Perf arctic).
    **TRAIN_RULES, "batch": ("pod", "data"), "fsdp": ("pod", "data"),
    "experts": ("pod", "data"),
}
SERVE_RULES = {
    "batch": ("data",), "seq": (), "model_dim": (),
    "heads": ("model",), "ff": ("model",), "vocab": ("model",),
    "experts": ("data",), "fsdp": (), "fsdp_moe": (),
    "kv_seq": ("model",),   # prefill-produced KV caches shard S over model
}
SERVE_RULES_MULTIPOD = {**SERVE_RULES, "batch": ("pod", "data")}
# Long-context (batch=1): shard the KV/sequence dim over data instead.
LONG_RULES = {**SERVE_RULES, "batch": (), "kv_seq": ("data",), "seq": ()}
LONG_RULES_MULTIPOD = {**LONG_RULES}


def _spec_for_path(path: str, ndim: int, rules: Mapping[str, tuple[str, ...]],
                   stacked: bool) -> P:
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            dims: list = []
            if stacked:
                dims.append(None)
            for a in axes:
                if a is None:
                    dims.append(None)
                else:
                    ma = rules.get(a, ())
                    dims.append(ma if len(ma) > 1 else (ma[0] if ma else None))
            # pad/trim to ndim
            while len(dims) < ndim:
                dims.append(None)
            return P(*dims[:ndim])
    return P(*([None] * ndim))


def sanitize_specs(abstract_tree, spec_tree, mesh_axis_sizes: Mapping[str, int]):
    """Drop sharding on dims not divisible by their assigned mesh axes.

    Explicit pjit in_shardings require exact divisibility (GSPMD pads only
    internal constraints); non-divisible cases (kv=8 heads over a 16-way
    model axis, vocab=49155, 40 RWKV heads) fall back to replication on that
    dim — recorded per cell in the dry-run JSON via spec comparison.
    """

    def fix(leaf, spec):
        if spec is None:
            return spec
        dims = list(tuple(spec))
        while len(dims) < len(leaf.shape):
            dims.append(None)
        out = []
        for size, d in zip(leaf.shape, dims):
            if d is None:
                out.append(None)
                continue
            axes = list(d) if isinstance(d, tuple) else [d]
            # Fall back to suffixes of the axis tuple before replicating:
            # e.g. 16 experts over ("pod","data")=32 -> ("data",)=16.
            chosen = None
            while axes:
                total = 1
                for a in axes:
                    total *= mesh_axis_sizes[a]
                if size % total == 0:
                    chosen = tuple(axes) if len(axes) > 1 else axes[0]
                    break
                axes = axes[1:]
            out.append(chosen)
        return P(*out)

    return jax.tree.map(fix, abstract_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_partition_specs(abstract_params, mode: str = "train", multi_pod: bool = False):
    """PartitionSpec pytree for a params pytree of ShapeDtypeStructs."""
    if mode == "train":
        rules = TRAIN_RULES_MULTIPOD if multi_pod else TRAIN_RULES
    else:
        rules = SERVE_RULES_MULTIPOD if multi_pod else SERVE_RULES
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        stacked = pstr.startswith(("layers", "enc_layers", "cross_layers"))
        specs.append(_spec_for_path(pstr, len(leaf.shape), rules, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)
