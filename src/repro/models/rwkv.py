"""RWKV-6 (Finch) block: data-dependent decay linear attention.

Time-mix with per-channel data-dependent decay  w_t = exp(-exp(w0 + lora(x)))
and a rank-reduced ddlerp token shift; channel-mix FFN.  Attention-free: the
decode state is (B, H, dh, dh) WKV state + two (B, d) shift states per layer,
independent of sequence length — the arch-applicability case where NetKV's
transfer term loses its context-length scaling (DESIGN §4).

Prefill/train run a sequential ``lax.scan`` over time (the chunked-parallel
Pallas kernel ``rwkv_scan`` accelerates this on TPU); decode is an O(1)
state update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import InitSpec

HEAD_DIM = 64
LORA_R = 32


def rwkv_param_specs(d_model: int, d_ff: int) -> dict:
    h = d_model // HEAD_DIM
    return {
        # time-mix
        "mu_base": InitSpec((5, d_model)),            # r,k,v,w,g static lerp
        "mu_lora_a": InitSpec((d_model, LORA_R)),
        "mu_lora_b": InitSpec((LORA_R, 5 * d_model), scale=0.0, kind="zeros"),
        "w_r": InitSpec((d_model, d_model)),
        "w_k": InitSpec((d_model, d_model)),
        "w_v": InitSpec((d_model, d_model)),
        "w_g": InitSpec((d_model, d_model)),
        "w_o": InitSpec((d_model, d_model)),
        "decay_base": InitSpec((d_model,), kind="zeros"),
        "decay_lora_a": InitSpec((d_model, LORA_R)),
        "decay_lora_b": InitSpec((LORA_R, d_model), scale=0.0, kind="zeros"),
        "bonus_u": InitSpec((h, HEAD_DIM)),
        "ln_x": InitSpec((d_model,), kind="ones"),
        # channel-mix
        "cm_mu": InitSpec((2, d_model)),
        "cm_k": InitSpec((d_model, d_ff)),
        "cm_v": InitSpec((d_ff, d_model)),
        "cm_r": InitSpec((d_model, d_model)),
    }


def _ddlerp(params, x, x_prev):
    """Data-dependent token-shift: five mixed streams (r,k,v,w,g)."""
    d = x.shape[-1]
    delta = x_prev - x
    lora = jnp.tanh(jnp.einsum("...d,dr->...r", delta, params["mu_lora_a"]))
    dyn = jnp.einsum("...r,re->...e", lora, params["mu_lora_b"]).reshape(*x.shape[:-1], 5, d)
    mix = params["mu_base"] + dyn                       # (...,5,d)
    return x[..., None, :] + delta[..., None, :] * mix  # (...,5,d)


def _decay(params, xw):
    lora = jnp.tanh(jnp.einsum("...d,dr->...r", xw, params["decay_lora_a"]))
    w = params["decay_base"] + jnp.einsum("...r,rd->...d", lora, params["decay_lora_b"])
    return jnp.exp(-jnp.exp(w.astype(jnp.float32)))     # (..., d) in (0,1)


def _group_norm(x, scale):
    # per-head RMS-style norm on (..., H, dh) flattened back to (..., d)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).reshape(
        *x.shape[:-2], -1
    ) * scale


def rwkv_time_mix(params: dict, x: jax.Array, wkv0: jax.Array | None = None,
                  shift0: jax.Array | None = None):
    """x: (B, S, d) -> (out, (wkv_state, last_x)) sequential over S."""
    b, s, d = x.shape
    h = d // HEAD_DIM
    x_prev = jnp.concatenate(
        [shift0[:, None, :] if shift0 is not None else jnp.zeros((b, 1, d), x.dtype),
         x[:, :-1]], axis=1)
    mixed = _ddlerp(params, x, x_prev)                   # (B,S,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]
    r = jnp.einsum("bsd,de->bse", xr, params["w_r"]).reshape(b, s, h, HEAD_DIM)
    k = jnp.einsum("bsd,de->bse", xk, params["w_k"]).reshape(b, s, h, HEAD_DIM)
    v = jnp.einsum("bsd,de->bse", xv, params["w_v"]).reshape(b, s, h, HEAD_DIM)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["w_g"]))
    w = _decay(params, xw).reshape(b, s, h, HEAD_DIM)    # f32
    u = params["bonus_u"].astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                        # (B,h,dh) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)      # (B,h,dh,dh) f32
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv)
        state = state * w_t[..., None] + kv
        return state, y

    s0 = wkv0 if wkv0 is not None else jnp.zeros((b, h, HEAD_DIM, HEAD_DIM), jnp.float32)
    xs = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3)                        # (B,S,h,dh) f32
    y = _group_norm(y, params["ln_x"]).astype(x.dtype)  # (B,S,d)
    out = jnp.einsum("bsd,de->bse", y * g, params["w_o"])
    return out, (final, x[:, -1])


def rwkv_time_mix_step(params: dict, x: jax.Array, wkv: jax.Array, x_prev: jax.Array):
    """Single token: x (B,1,d); wkv (B,h,dh,dh) f32; x_prev (B,d)."""
    b, _, d = x.shape
    h = d // HEAD_DIM
    mixed = _ddlerp(params, x[:, 0], x_prev)             # (B,5,d)
    xr, xk, xv, xw, xg = [mixed[:, i] for i in range(5)]
    r = jnp.einsum("bd,de->be", xr, params["w_r"]).reshape(b, h, HEAD_DIM).astype(jnp.float32)
    k = jnp.einsum("bd,de->be", xk, params["w_k"]).reshape(b, h, HEAD_DIM).astype(jnp.float32)
    v = jnp.einsum("bd,de->be", xv, params["w_v"]).reshape(b, h, HEAD_DIM).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bd,de->be", xg, params["w_g"]))
    w = _decay(params, xw).reshape(b, h, HEAD_DIM)
    u = params["bonus_u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, wkv + u[None, :, :, None] * kv)
    new_wkv = wkv * w[..., None] + kv
    y = _group_norm(y, params["ln_x"]).astype(x.dtype)
    out = jnp.einsum("bd,de->be", y * g, params["w_o"])[:, None, :]
    return out, new_wkv, x[:, 0]


def rwkv_channel_mix(params: dict, x: jax.Array, shift0: jax.Array | None = None):
    """Channel-mix FFN with token shift; returns (out, last_x)."""
    b, s, d = x.shape
    x_prev = jnp.concatenate(
        [shift0[:, None, :] if shift0 is not None else jnp.zeros((b, 1, d), x.dtype),
         x[:, :-1]], axis=1)
    delta = x_prev - x
    xk = x + delta * params["cm_mu"][0]
    xr = x + delta * params["cm_mu"][1]
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["cm_k"])))
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm_r"])) * jnp.einsum(
        "bsf,fd->bsd", kk, params["cm_v"]
    )
    return out, x[:, -1]


def rwkv_channel_mix_step(params: dict, x: jax.Array, x_prev: jax.Array):
    b, _, d = x.shape
    delta = x_prev - x[:, 0]
    xk = x[:, 0] + delta * params["cm_mu"][0]
    xr = x[:, 0] + delta * params["cm_mu"][1]
    kk = jnp.square(jax.nn.relu(jnp.einsum("bd,df->bf", xk, params["cm_k"])))
    out = jax.nn.sigmoid(jnp.einsum("bd,de->be", xr, params["cm_r"])) * jnp.einsum(
        "bf,fd->bd", kk, params["cm_v"]
    )
    return out[:, None, :], x[:, 0]
