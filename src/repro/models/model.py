"""Unified LM model builder: dense / MoE / Mamba-hybrid / RWKV / enc-dec / VLM.

A model is a stack of ``n_periods`` identical *periods*; a period is a short
heterogeneous sequence of blocks (``block_pattern``) with per-position FFN
choices (``ffn_pattern``).  Dense transformers use a period of length 1;
Jamba uses the published 8-layer period (1 attention : 7 Mamba, MoE every
second layer).  Layer parameters are stacked over the period axis and the
forward pass is a single ``jax.lax.scan`` — compile time stays flat in depth.

Entry points (all pure functions of (params, inputs)):
  forward_train(cfg, params, batch)          -> scalar loss (+aux)
  prefill(cfg, params, tokens, ...)          -> (logits_last, cache)
  decode_step(cfg, params, token, cache)     -> (logits, cache)
  encode(cfg, params, frames)                -> encoder memory  (enc-dec)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .attention import (
    chunked_causal_attention,
    cross_attention,
    decode_attention,
    seq_sharded_decode_attention,
)
from .common import InitSpec, abstractify, materialise, rms_norm, apply_rope, swiglu
from .moe import (
    MoEConfig,
    moe_ffn,
    moe_param_specs,
    moe_residual_param_specs,
    moe_with_residual,
)
from .rwkv import (
    HEAD_DIM as RWKV_HEAD_DIM,
    rwkv_channel_mix,
    rwkv_channel_mix_step,
    rwkv_param_specs,
    rwkv_time_mix,
    rwkv_time_mix_step,
)
from .ssm import D_CONV, D_STATE, mamba_decode_step, mamba_forward, mamba_param_specs
from .sharding import constrain, current_mesh, current_rules


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    block_pattern: tuple[str, ...] = ("attn",)
    ffn_pattern: tuple[str, ...] = ("dense",)
    qk_norm: bool = False
    rope_theta: float = 1e6
    moe: Optional[MoEConfig] = None
    n_enc_layers: int = 0                  # > 0 => encoder-decoder
    frontend: Optional[str] = None         # None | "vision" | "audio"
    n_prefix_embeds: int = 0               # VLM: stub patch embeddings per sample
    norm_eps: float = 1e-6
    attn_chunk: int = 1024
    compute_dtype: Any = jnp.bfloat16
    remat: bool = False          # checkpoint each period (training memory)

    def __post_init__(self):
        assert len(self.block_pattern) == len(self.ffn_pattern)
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by period "
            f"{len(self.block_pattern)}"
        )

    @property
    def period_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period_len

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return all(b != "attn" for b in self.block_pattern)

    @property
    def n_attn_layers(self) -> int:
        return self.n_periods * sum(1 for b in self.block_pattern if b == "attn")


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    specs = {
        "ln": InitSpec((d,), kind="ones"),
        "wq": InitSpec((d, h * dh)),
        "wk": InitSpec((d, kv * dh)),
        "wv": InitSpec((d, kv * dh)),
        "wo": InitSpec((h * dh, d)),
    }
    if cfg.qk_norm:
        specs["q_norm"] = InitSpec((dh,), kind="ones")
        specs["k_norm"] = InitSpec((dh,), kind="ones")
    return specs


def _ffn_specs(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind == "dense":
        return {
            "ln": InitSpec((d,), kind="ones"),
            "gate": InitSpec((d, cfg.d_ff)),
            "up": InitSpec((d, cfg.d_ff)),
            "down": InitSpec((cfg.d_ff, d)),
        }
    if kind == "moe":
        return {"ln": InitSpec((d,), kind="ones"), "moe": moe_param_specs(d, cfg.moe)}
    if kind == "moe_res":
        return {
            "ln": InitSpec((d,), kind="ones"),
            "moe": moe_residual_param_specs(d, cfg.d_ff, cfg.moe),
        }
    if kind == "none":
        return {}
    raise ValueError(kind)


def _block_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "attn":
        return _attn_specs(cfg)
    if kind == "mamba":
        return {"ln": InitSpec((cfg.d_model,), kind="ones"), **mamba_param_specs(cfg.d_model)}
    if kind == "rwkv":
        return {
            "ln1": InitSpec((cfg.d_model,), kind="ones"),
            "ln2": InitSpec((cfg.d_model,), kind="ones"),
            **rwkv_param_specs(cfg.d_model, cfg.d_ff),
        }
    raise ValueError(kind)


def _stack(tree, n: int):
    """Prefix every InitSpec shape with the period-stack dim."""
    return jax.tree.map(
        lambda s: InitSpec((n, *s.shape), s.scale, s.dtype, s.kind),
        tree,
        is_leaf=lambda x: isinstance(x, InitSpec),
    )


def param_specs(cfg: ModelConfig) -> dict:
    period: dict[str, Any] = {}
    for i, (blk, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        period[f"b{i}"] = _block_specs(cfg, blk)
        if blk != "rwkv" and ffn != "none":
            period[f"f{i}"] = _ffn_specs(cfg, ffn)
    specs: dict[str, Any] = {
        "embed": InitSpec((cfg.vocab_size, cfg.d_model), scale=0.01),
        "out_norm": InitSpec((cfg.d_model,), kind="ones"),
        "lm_head": InitSpec((cfg.d_model, cfg.vocab_size)),
        "layers": _stack(period, cfg.n_periods),
    }
    if cfg.is_enc_dec:
        enc_period = {"b0": _attn_specs(cfg), "f0": _ffn_specs(cfg, "dense")}
        specs["enc_layers"] = _stack(enc_period, cfg.n_enc_layers)
        specs["enc_norm"] = InitSpec((cfg.d_model,), kind="ones")
        # decoder cross-attention per attention position
        cross = {}
        for i, blk in enumerate(cfg.block_pattern):
            if blk == "attn":
                cross[f"c{i}"] = _attn_specs(cfg)
        specs["cross_layers"] = _stack(cross, cfg.n_periods)
    return specs


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    return materialise(param_specs(cfg), key, dtype)


def abstract_params(cfg: ModelConfig, dtype=None):
    return abstractify(param_specs(cfg), dtype)


# ---------------------------------------------------------------------------
# Blocks (sequence mode: train / prefill)
# ---------------------------------------------------------------------------

def _attn_seq(cfg, p, x, positions, causal=True, return_kv=False):
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", xn, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", xn, p["wk"]).reshape(b, s, kv, dh)
    v = jnp.einsum("bsd,de->bse", xn, p["wv"]).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    att = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk, causal=causal)
    out = jnp.einsum("bse,ed->bsd", att.reshape(b, s, h * dh), p["wo"])
    if return_kv:
        return out, (k, v)
    return out, None


def _cross_seq(cfg, p, x, memory_kv):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", xn, p["wq"]).reshape(b, s, h, dh)
    k_mem, v_mem = memory_kv
    att = cross_attention(q, k_mem, v_mem)
    return jnp.einsum("bse,ed->bsd", att.reshape(b, s, h * dh), p["wo"])


def _ffn_apply(cfg, kind, p, x):
    if kind == "dense":
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        return swiglu(xn, p["gate"], p["up"], p["down"]), 0.0
    if kind == "moe":
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        out, aux = moe_ffn(xn, p["moe"], cfg.moe)
        return out, aux
    if kind == "moe_res":
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        out, aux = moe_with_residual(xn, p["moe"], cfg.moe)
        return out, aux
    raise ValueError(kind)


def _period_seq(cfg: ModelConfig, period_params, x, positions, collect_cache: bool,
                causal: bool = True, cross_params=None, memory_kv=None):
    """Apply one period in sequence mode.  Returns (x, aux, cache_dict)."""
    aux = 0.0
    cache: dict[str, Any] = {}
    for i, (blk, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        p = period_params[f"b{i}"]
        if blk == "attn":
            out, kvpair = _attn_seq(cfg, p, x, positions, causal=causal,
                                    return_kv=collect_cache)
            x = x + out
            if collect_cache:
                cache[f"k{i}"], cache[f"v{i}"] = kvpair
            if cross_params is not None and f"c{i}" in cross_params:
                x = x + _cross_seq(cfg, cross_params[f"c{i}"], x, memory_kv[f"c{i}"])
        elif blk == "mamba":
            xn = rms_norm(x, p["ln"], cfg.norm_eps)
            out, state = mamba_forward(p, xn)
            x = x + out
            if collect_cache:
                cache[f"ssm{i}"] = state["ssm"]
                cache[f"conv{i}"] = state["conv"]
        elif blk == "rwkv":
            xn = rms_norm(x, p["ln1"], cfg.norm_eps)
            out, (wkv, last_x) = rwkv_time_mix(p, xn)
            x = x + out
            xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            out2, last_x2 = rwkv_channel_mix(p, xn2)
            x = x + out2
            if collect_cache:
                cache[f"wkv{i}"] = wkv
                cache[f"sa{i}"] = last_x
                cache[f"sc{i}"] = last_x2
        else:
            raise ValueError(blk)
        if blk != "rwkv" and ffn != "none":
            out, a = _ffn_apply(cfg, ffn, period_params[f"f{i}"], x)
            x = x + out
            aux = aux + a
        x = constrain(x, "batch", None, None)
    return x, aux, cache


# ---------------------------------------------------------------------------
# Top-level sequence forward
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, tokens, prefix_embeds):
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.compute_dtype), x], axis=1)
    return constrain(x, "batch", None, None)


def _backbone_seq(cfg, params, x, collect_cache=False, causal=True, memory=None):
    """Scan the period stack over x.  Returns (x, aux, stacked_cache)."""
    positions = jnp.arange(x.shape[1])[None, :]
    layers = jax.tree.map(lambda a: a.astype(cfg.compute_dtype)
                          if a.dtype == jnp.float32 and a.ndim > 1 else a, params["layers"])
    cross_stack = params.get("cross_layers")
    memory_kv_stack = None
    if memory is not None and cross_stack is not None:
        # Precompute cross-attention KV from encoder memory once per period.
        memory_kv_stack = {}
        b, se, d = memory.shape
        kvh, dh = cfg.n_kv_heads, cfg.d_head
        for name in cross_stack:
            k = jnp.einsum("bsd,pde->pbse", memory, cross_stack[name]["wk"].astype(cfg.compute_dtype))
            v = jnp.einsum("bsd,pde->pbse", memory, cross_stack[name]["wv"].astype(cfg.compute_dtype))
            memory_kv_stack[name] = (
                k.reshape(cfg.n_periods, b, se, kvh, dh),
                v.reshape(cfg.n_periods, b, se, kvh, dh),
            )

    def body(carry, xs):
        h, aux = carry
        if memory_kv_stack is not None:
            period_params, cross_p, mem_kv = xs
            mem_kv = {k: v for k, v in mem_kv.items()}
        else:
            period_params = xs
            cross_p, mem_kv = None, None
        h, a, cache = _period_seq(cfg, period_params, h, positions, collect_cache,
                                  causal=causal, cross_params=cross_p, memory_kv=mem_kv)
        return (h, aux + a), cache

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if memory_kv_stack is not None:
        cross_cd = jax.tree.map(lambda a: a.astype(cfg.compute_dtype)
                                if a.dtype == jnp.float32 and a.ndim > 1 else a, cross_stack)
        mem_by_name = {name: {"k": kv[0], "v": kv[1]} for name, kv in memory_kv_stack.items()}
        xs = (layers, cross_cd, {n: (d["k"], d["v"]) for n, d in mem_by_name.items()})
        (x, aux), caches = jax.lax.scan(body, (x, 0.0), xs)
    else:
        (x, aux), caches = jax.lax.scan(body, (x, 0.0), layers)
    return x, aux, caches


def forward_logits(cfg: ModelConfig, params, tokens, prefix_embeds=None,
                   memory=None, causal=True):
    """Full-sequence logits (train).  tokens: (B, S)."""
    x = _embed_inputs(cfg, params, tokens, prefix_embeds)
    x, aux, _ = _backbone_seq(cfg, params, x, collect_cache=False, causal=causal,
                              memory=memory)
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cfg.compute_dtype))
    return constrain(logits, "batch", None, "vocab"), aux


def encode(cfg: ModelConfig, params, frames):
    """Encoder stack over stub frame/patch embeddings (B, T, d)."""
    x = constrain(frames.astype(cfg.compute_dtype), "batch", None, None)
    positions = jnp.arange(x.shape[1])[None, :]
    enc = jax.tree.map(lambda a: a.astype(cfg.compute_dtype)
                       if a.dtype == jnp.float32 and a.ndim > 1 else a, params["enc_layers"])

    def body(h, period_params):
        out, _ = _attn_seq(cfg, period_params["b0"], h, positions, causal=False)
        h = h + out
        o, _ = _ffn_apply(cfg, "dense", period_params["f0"], h)
        return h + o, None

    x, _ = jax.lax.scan(body, x, enc)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward_train(cfg: ModelConfig, params, batch, aux_weight: float = 0.01):
    """Causal-LM (or seq2seq) loss.  batch: {"tokens", "labels", [frames|embeds]}."""
    memory = None
    if cfg.is_enc_dec:
        memory = encode(cfg, params, batch["frames"])
    prefix = batch.get("embeds") if cfg.frontend == "vision" else None
    logits, aux = forward_logits(cfg, params, batch["tokens"], prefix_embeds=prefix,
                                 memory=memory)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, prefix_embeds=None, memory=None,
            cache_len: int | None = None):
    """Run the prompt; return (last-token logits, decode cache).

    The attention KV cache is padded to ``cache_len`` (>= prompt length) so
    decode can append tokens in place.
    """
    x = _embed_inputs(cfg, params, tokens, prefix_embeds)
    s_total = x.shape[1]
    cache_len = cache_len or s_total
    x, aux, caches = _backbone_seq(cfg, params, x, collect_cache=True, memory=memory)
    # Pad K/V leaves from prompt length to cache_len.
    pad = cache_len - s_total

    def pad_kv(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name.startswith(("k", "v")) and leaf.ndim == 5:  # (P,B,S,KV,dh)
            if pad > 0:
                leaf = jnp.pad(leaf, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            return constrain(leaf, None, "batch", "kv_seq", None, None)
        return leaf

    caches = jax.tree_util.tree_map_with_path(pad_kv, caches)
    if cfg.is_enc_dec and memory is not None:
        # Cache the cross-attention KV (computed once from encoder memory).
        cross_stack = jax.tree.map(
            lambda a: a.astype(cfg.compute_dtype) if a.dtype == jnp.float32 and a.ndim > 1 else a,
            params["cross_layers"])
        b, se, _ = memory.shape
        kvh, dh = cfg.n_kv_heads, cfg.d_head
        for name in cross_stack:
            i = name[1:]  # "c3" -> "3"
            k = jnp.einsum("bsd,pde->pbse", memory.astype(cfg.compute_dtype),
                           cross_stack[name]["wk"]).reshape(cfg.n_periods, b, se, kvh, dh)
            v = jnp.einsum("bsd,pde->pbse", memory.astype(cfg.compute_dtype),
                           cross_stack[name]["wv"]).reshape(cfg.n_periods, b, se, kvh, dh)
            caches[f"ck{i}"], caches[f"cv{i}"] = k, v
        caches["cross_memory"] = memory
    caches["pos"] = jnp.int32(s_total)
    x = rms_norm(x[:, -1:], params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cfg.compute_dtype))
    return logits, caches


def _period_decode(cfg, period_params, x, cache, pos, cross_params=None, memory=None,
                   update_cache=True):
    """One-token period application.

    ``pos`` is scalar (uniform batch — the dry-run/benchmark case) or (B,)
    per-slot positions (the continuous-batching serving engine).

    ``update_cache=False`` treats the KV cache as read-only (paged-decode
    semantics): the current token's KV is merged into the softmax and
    returned as a fragment for the engine to land asynchronously — no
    dynamic-update-slice on the (sharded) cache.
    """
    new_cache = {}
    per_slot = getattr(pos, "ndim", 0) == 1
    for i, (blk, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        p = period_params[f"b{i}"]
        if blk == "attn":
            b = x.shape[0]
            h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
            xn = rms_norm(x, p["ln"], cfg.norm_eps)
            q = jnp.einsum("bsd,de->bse", xn, p["wq"]).reshape(b, 1, h, dh)
            k = jnp.einsum("bsd,de->bse", xn, p["wk"]).reshape(b, 1, kvh, dh)
            v = jnp.einsum("bsd,de->bse", xn, p["wv"]).reshape(b, 1, kvh, dh)
            if cfg.qk_norm:
                q = rms_norm(q, p["q_norm"], cfg.norm_eps)
                k = rms_norm(k, p["k_norm"], cfg.norm_eps)
            posv = (pos[:, None] if per_slot else jnp.full((1, 1), pos))
            q = apply_rope(q, posv, cfg.rope_theta)
            k = apply_rope(k, posv, cfg.rope_theta)
            if not update_cache:
                rules = current_rules() or {}
                mesh = current_mesh()
                seq_axes = tuple(rules.get("kv_seq", ()))
                if mesh is not None and seq_axes and h % kvh == 0:
                    # seq-sharded cache: explicit partial-softmax shard_map
                    batch_axes = tuple(rules.get("batch", ()))
                    att = seq_sharded_decode_attention(
                        q, cache[f"k{i}"], cache[f"v{i}"], pos, k, v,
                        mesh=mesh, batch_axes=batch_axes, seq_axes=seq_axes)
                else:
                    att = decode_attention(q, cache[f"k{i}"], cache[f"v{i}"], pos,
                                           k_new=k, v_new=v)
                new_cache[f"kf{i}"], new_cache[f"vf{i}"] = k, v
            elif per_slot:
                # Indices must share one dtype: literal 0s widen to int64
                # when x64 is enabled while pos stays int32.
                upd = jax.vmap(
                    lambda c, kv, pp: jax.lax.dynamic_update_slice(
                        c, kv, (pp, jnp.zeros_like(pp), jnp.zeros_like(pp)))
                )
                k_cache = upd(cache[f"k{i}"], k.astype(cache[f"k{i}"].dtype), pos)
                v_cache = upd(cache[f"v{i}"], v.astype(cache[f"v{i}"].dtype), pos)
                valid_len = (pos + 1)[:, None, None, None]
            else:
                posi = jnp.asarray(pos)
                z = jnp.zeros((), posi.dtype)
                k_cache = jax.lax.dynamic_update_slice(
                    cache[f"k{i}"], k.astype(cache[f"k{i}"].dtype),
                    (z, posi, z, z))
                v_cache = jax.lax.dynamic_update_slice(
                    cache[f"v{i}"], v.astype(cache[f"v{i}"].dtype),
                    (z, posi, z, z))
                valid_len = pos + 1
            if update_cache:
                att = decode_attention(q, k_cache, v_cache, valid_len)
                new_cache[f"k{i}"], new_cache[f"v{i}"] = k_cache, v_cache
            x = x + jnp.einsum("bse,ed->bsd", att.reshape(b, 1, h * dh), p["wo"])
            if cross_params is not None and f"c{i}" in cross_params:
                cp = cross_params[f"c{i}"]
                xn2 = rms_norm(x, cp["ln"], cfg.norm_eps)
                qc = jnp.einsum("bsd,de->bse", xn2, cp["wq"]).reshape(b, 1, h, dh)
                att2 = cross_attention(qc, cache[f"ck{i}"], cache[f"cv{i}"])
                x = x + jnp.einsum("bse,ed->bsd", att2.reshape(b, 1, h * dh), cp["wo"])
                new_cache[f"ck{i}"], new_cache[f"cv{i}"] = cache[f"ck{i}"], cache[f"cv{i}"]
        elif blk == "mamba":
            xn = rms_norm(x, p["ln"], cfg.norm_eps)
            out, st = mamba_decode_step(p, xn, {"ssm": cache[f"ssm{i}"], "conv": cache[f"conv{i}"]})
            x = x + out
            new_cache[f"ssm{i}"], new_cache[f"conv{i}"] = st["ssm"], st["conv"]
        elif blk == "rwkv":
            xn = rms_norm(x, p["ln1"], cfg.norm_eps)
            out, wkv, last = rwkv_time_mix_step(p, xn, cache[f"wkv{i}"], cache[f"sa{i}"])
            x = x + out
            xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            out2, last2 = rwkv_channel_mix_step(p, xn2, cache[f"sc{i}"])
            x = x + out2
            new_cache[f"wkv{i}"], new_cache[f"sa{i}"], new_cache[f"sc{i}"] = wkv, last, last2
        if blk != "rwkv" and ffn != "none":
            out, _ = _ffn_apply(cfg, ffn, period_params[f"f{i}"], x)
            x = x + out
    return x, new_cache


def decode_step(cfg: ModelConfig, params, token, cache, *, update_cache=True):
    """token: (B, 1) int32 -> (logits (B,1,V), updated cache).

    ``cache["pos"]`` may be scalar (uniform) or (B,) per-slot positions.
    ``update_cache=False``: read-only cache; new-KV fragments (kf/vf leaves)
    are returned instead of updated k/v (paged-decode, see _period_decode)."""
    pos = cache["pos"]
    x = params["embed"][token].astype(cfg.compute_dtype)
    x = constrain(x, "batch", None, None)
    layers = jax.tree.map(lambda a: a.astype(cfg.compute_dtype)
                          if a.dtype == jnp.float32 and a.ndim > 1 else a, params["layers"])
    layer_cache = {k: v for k, v in cache.items() if k not in ("pos", "cross_memory")}
    cross_stack = params.get("cross_layers")
    if cross_stack is not None:
        cross_stack = jax.tree.map(lambda a: a.astype(cfg.compute_dtype)
                                   if a.dtype == jnp.float32 and a.ndim > 1 else a, cross_stack)

    def body(h, xs):
        if cross_stack is not None:
            period_params, cross_p, cache_slice = xs
        else:
            period_params, cache_slice = xs
            cross_p = None
        h, new_cache = _period_decode(cfg, period_params, h, cache_slice, pos,
                                      cross_params=cross_p,
                                      update_cache=update_cache)
        return h, new_cache

    xs = (layers, cross_stack, layer_cache) if cross_stack is not None else (layers, layer_cache)
    x, new_layer_cache = jax.lax.scan(body, x, xs)
    out = dict(new_layer_cache)
    out["pos"] = pos + 1
    if "cross_memory" in cache:
        out["cross_memory"] = cache["cross_memory"]
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cfg.compute_dtype))
    return constrain(logits, "batch", None, "vocab"), out


def make_decode_cache(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int = 0):
    """Abstract cache shapes for the dry-run decode path (ShapeDtypeStruct)."""
    caches: dict[str, Any] = {}
    per = {}
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    p = cfg.n_periods
    cd = cfg.compute_dtype
    for i, blk in enumerate(cfg.block_pattern):
        if blk == "attn":
            per[f"k{i}"] = jax.ShapeDtypeStruct((p, batch, cache_len, kvh, dh), cd)
            per[f"v{i}"] = jax.ShapeDtypeStruct((p, batch, cache_len, kvh, dh), cd)
            if cfg.is_enc_dec:
                per[f"ck{i}"] = jax.ShapeDtypeStruct((p, batch, enc_len, kvh, dh), cd)
                per[f"cv{i}"] = jax.ShapeDtypeStruct((p, batch, enc_len, kvh, dh), cd)
        elif blk == "mamba":
            d_inner = 2 * cfg.d_model
            per[f"ssm{i}"] = jax.ShapeDtypeStruct((p, batch, d_inner, D_STATE), jnp.float32)
            per[f"conv{i}"] = jax.ShapeDtypeStruct((p, batch, D_CONV - 1, d_inner), cd)
        elif blk == "rwkv":
            h = cfg.d_model // RWKV_HEAD_DIM
            per[f"wkv{i}"] = jax.ShapeDtypeStruct((p, batch, h, RWKV_HEAD_DIM, RWKV_HEAD_DIM), jnp.float32)
            per[f"sa{i}"] = jax.ShapeDtypeStruct((p, batch, cfg.d_model), cd)
            per[f"sc{i}"] = jax.ShapeDtypeStruct((p, batch, cfg.d_model), cd)
    caches.update(per)
    caches["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return caches


def state_bytes(cfg: ModelConfig, seq_len: int) -> int:
    """Transferred decode-state bytes for one request (Eq. 1 generalised)."""
    total = 0
    p = cfg.n_periods
    for i, blk in enumerate(cfg.block_pattern):
        if blk == "attn":
            total += 2 * p * seq_len * cfg.n_kv_heads * cfg.d_head * 2
        elif blk == "mamba":
            total += p * (2 * cfg.d_model * D_STATE * 4 + (D_CONV - 1) * 2 * cfg.d_model * 2)
        elif blk == "rwkv":
            h = cfg.d_model // RWKV_HEAD_DIM
            total += p * (h * RWKV_HEAD_DIM * RWKV_HEAD_DIM * 4 + 2 * cfg.d_model * 2)
    return total
