"""Model zoo: unified dense/MoE/hybrid/SSM/enc-dec LM in JAX."""

from .model import (
    ModelConfig,
    abstract_params,
    decode_step,
    encode,
    forward_logits,
    forward_train,
    init_params,
    make_decode_cache,
    param_specs,
    prefill,
    state_bytes,
)
from .moe import MoEConfig
from .common import param_count
from .sharding import (
    axis_rules,
    constrain,
    param_partition_specs,
    SERVE_RULES,
    SERVE_RULES_MULTIPOD,
    TRAIN_RULES,
    TRAIN_RULES_MULTIPOD,
    LONG_RULES,
)

__all__ = [k for k in dir() if not k.startswith("_")]
