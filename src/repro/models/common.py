"""Shared model components: norms, RoPE, activations, init helpers.

All forward math runs in ``compute_dtype`` (bf16 by default) with f32 norms
and softmax accumulation, matching production LM frameworks.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (..., S, H, d_head); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class InitSpec:
    """Deferred parameter: shape + init scale; materialised by init_params or
    turned into ShapeDtypeStruct by the dry-run (no allocation)."""

    shape: tuple[int, ...]
    scale: float = 0.02
    dtype: Any = jnp.float32
    kind: str = "normal"  # "normal" | "zeros" | "ones"


def materialise(tree, key: jax.Array, dtype=None):
    """Turn a tree of InitSpec into concrete arrays (traceable)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, InitSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dt = dtype or spec.dtype
        if spec.kind == "zeros":
            out.append(jnp.zeros(spec.shape, dt))
        elif spec.kind == "ones":
            out.append(jnp.ones(spec.shape, dt))
        else:
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * spec.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstractify(tree, dtype=None):
    """Tree of InitSpec -> tree of ShapeDtypeStruct (for .lower() dry-runs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, InitSpec),
    )


def param_count(tree) -> int:
    import numpy as np

    return int(
        sum(
            np.prod(l.shape)
            for l in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, InitSpec))
        )
    )
