"""Mamba (S6) block for the Jamba hybrid architecture.

Selective state-space layer: input-dependent (Delta, B, C) with a diagonal
state transition; sequential ``lax.scan`` over time for prefill/train and an
O(1) single-step update for decode.  The recurrent state (B, d_inner,
d_state) plus the conv tail (B, d_conv-1, d_inner) is the *transferred*
decode state for NetKV on hybrid models (DESIGN §4): unlike KV it does not
grow with sequence length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import InitSpec

D_STATE = 16
D_CONV = 4


def mamba_param_specs(d_model: int) -> dict:
    d_inner = 2 * d_model
    dt_rank = max(d_model // 16, 1)
    return {
        "in_proj": InitSpec((d_model, 2 * d_inner)),
        "conv_w": InitSpec((D_CONV, d_inner)),
        "conv_b": InitSpec((d_inner,), kind="zeros"),
        "x_proj": InitSpec((d_inner, dt_rank + 2 * D_STATE)),
        "dt_proj": InitSpec((dt_rank, d_inner)),
        "dt_bias": InitSpec((d_inner,), kind="zeros"),
        "a_log": InitSpec((d_inner, D_STATE), kind="ones"),
        "d_skip": InitSpec((d_inner,), kind="ones"),
        "out_proj": InitSpec((d_inner, d_model)),
    }


def _ssm_coeffs(params, x_in):
    """x_in: (..., d_inner) -> (dt, B, C) input-dependent coefficients."""
    dt_rank = params["dt_proj"].shape[0]
    proj = jnp.einsum("...i,ik->...k", x_in, params["x_proj"])
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + D_STATE], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("...r,ri->...i", dt, params["dt_proj"]) + params["dt_bias"])
    return dt, bmat, cmat


def mamba_forward(params: dict, x: jax.Array) -> tuple[jax.Array, dict]:
    """x: (B, S, d_model) -> (out, final_state) via sequential scan."""
    b, s, _ = x.shape
    d_inner = params["conv_w"].shape[1]
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    # Depthwise causal conv along time.
    pad = jnp.zeros((b, D_CONV - 1, d_inner), x_in.dtype)
    xc = jnp.concatenate([pad, x_in], axis=1)
    conv = sum(
        xc[:, i : i + s, :] * params["conv_w"][i][None, None, :] for i in range(D_CONV)
    ) + params["conv_b"]
    conv = jax.nn.silu(conv)

    dt, bmat, cmat = _ssm_coeffs(params, conv)          # (B,S,di),(B,S,N),(B,S,N)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))   # (di, N)

    def step(state, inputs):
        conv_t, dt_t, b_t, c_t = inputs                  # (B,di),(B,di),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a)                # (B,di,N)
        state = state * da + (dt_t * conv_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bin,bn->bi", state, c_t)
        return state, y

    s0 = jnp.zeros((b, d_inner, D_STATE), jnp.float32)
    xs = (
        conv.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        bmat.transpose(1, 0, 2).astype(jnp.float32),
        cmat.transpose(1, 0, 2).astype(jnp.float32),
    )
    # Two-level scan with a checkpointed inner chunk: a flat scan would save
    # the (B, d_inner, N) state at every timestep for backward (40 GB/device
    # on jamba train_4k); chunking keeps one state per TIME_CHUNK.
    TIME_CHUNK = 256
    if s % TIME_CHUNK == 0 and s > TIME_CHUNK:
        n_out = s // TIME_CHUNK

        def inner(state, xs_chunk):
            return jax.lax.scan(step, state, xs_chunk)

        def outer(state, xs_chunk):
            state, ys = jax.checkpoint(
                inner, policy=jax.checkpoint_policies.nothing_saveable
            )(state, xs_chunk)
            return state, ys

        xs_chunked = jax.tree.map(
            lambda a: a.reshape(n_out, TIME_CHUNK, *a.shape[1:]), xs)
        final_state, ys = jax.lax.scan(outer, s0, xs_chunked)
        ys = ys.reshape(s, *ys.shape[2:])
    else:
        final_state, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)            # (B,S,di)
    y = y + conv * params["d_skip"]
    out = jnp.einsum("bsi,id->bsd", y * jax.nn.silu(z), params["out_proj"])
    state = {"ssm": final_state, "conv": xc[:, -(D_CONV - 1):, :]}
    return out, state


def mamba_decode_step(params: dict, x: jax.Array, state: dict) -> tuple[jax.Array, dict]:
    """x: (B, 1, d_model); state: {"ssm": (B,di,N) f32, "conv": (B,D_CONV-1,di)}."""
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)                  # (B,1,di)
    xc = jnp.concatenate([state["conv"], x_in], axis=1)  # (B,D_CONV,di)
    conv = sum(xc[:, i, :] * params["conv_w"][i][None, :] for i in range(D_CONV))
    conv = jax.nn.silu(conv + params["conv_b"])          # (B,di)
    dt, bmat, cmat = _ssm_coeffs(params, conv)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
    new_ssm = state["ssm"] * da + (dt * conv).astype(jnp.float32)[..., None] * bmat.astype(
        jnp.float32
    )[:, None, :]
    y = jnp.einsum("bin,bn->bi", new_ssm, cmat.astype(jnp.float32)).astype(x.dtype)
    y = y + conv * params["d_skip"]
    out = jnp.einsum("bi,id->bd", y * jax.nn.silu(z[:, 0]), params["out_proj"])[:, None, :]
    return out, {"ssm": new_ssm, "conv": xc[:, 1:, :]}
