"""GQA attention: chunked-causal (prefill/train), single-token (decode),
bidirectional (encoder) and cross (enc-dec decoder) variants.

The prefill/train path never materialises the full S x S score matrix: a
``lax.scan`` over query chunks keeps live memory at (B, Hq, chunk, S) — the
pure-XLA analogue of flash attention, required for the 32K-prefill shapes on
a 16 GB HBM budget.  On real TPU the decode path is replaced by the Pallas
``flash_decode`` kernel (repro.kernels.ops); the XLA path here is its oracle
and the dry-run lowering target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

NEG_INF = -2.0e38


def _gqa_expand(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, dh) -> (B, S, H, dh) by repeating each KV head H/KV times."""
    b, s, kv, dh = k.shape
    rep = n_heads // kv if n_heads % kv == 0 else -1
    if rep == -1:
        # Non-divisible head ratio (padded sharding archs): tile + slice.
        reps = -(-n_heads // kv)
        return jnp.tile(k[:, :, :, None, :], (1, 1, 1, reps, 1)).reshape(b, s, kv * reps, dh)[
            :, :, :n_heads
        ]
    return jnp.repeat(k, rep, axis=2)


def chunked_causal_attention(
    q: jax.Array,  # (B, S, H, dh)
    k: jax.Array,  # (B, S, KV, dh)
    v: jax.Array,  # (B, S, KV, dh)
    *,
    chunk: int = 1024,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Memory-bounded attention; returns (B, S, H, dh)."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    grouped = h % kv == 0
    if grouped:
        g = h // kv
        if s <= chunk:
            return _attn_block_grouped(q.reshape(b, s, kv, g, dh), k, v, 0,
                                       causal, scale)
    else:
        kx = _gqa_expand(k, h)
        vx = _gqa_expand(v, h)
        if s <= chunk:
            return _attn_block(q, kx, vx, 0, causal, scale)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        qi, i = xs
        if grouped:
            out = _attn_block_grouped(qi.reshape(b, chunk, kv, h // kv, dh),
                                      k, v, i * chunk, causal, scale)
        else:
            out = _attn_block(qi, kx, vx, i * chunk, causal, scale)
        return carry, out

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, dh)
    return out[:, :s]


def _attn_block(q, kx, vx, q_offset, causal, scale):
    """q: (B, C, H, dh) against full kx/vx: (B, S, H, dh)."""
    b, c, h, dh = q.shape
    s = kx.shape[1]
    logits = jnp.einsum("bchd,bshd->bhcs", q, kx).astype(jnp.float32) * scale
    if causal:
        q_pos = q_offset + jnp.arange(c)[:, None]
        k_pos = jnp.arange(s)[None, :]
        logits = jnp.where(k_pos <= q_pos, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhcs,bshd->bchd", probs, vx)


def _attn_block_grouped(qg, k, v, q_offset, causal, scale):
    """Grouped GQA block: qg (B, C, KV, G, dh) against raw k/v (B, S, KV, dh)
    — never materialises the head-expanded (B, S, H, dh) cache (5x the KV
    bytes at 5:1 GQA; the prefill-path analogue of §Perf decode iter 2)."""
    b, c, kv, g, dh = qg.shape
    s = k.shape[1]
    logits = jnp.einsum("bckgd,bskd->bkgcs", qg, k).astype(jnp.float32) * scale
    if causal:
        q_pos = q_offset + jnp.arange(c)[:, None]
        k_pos = jnp.arange(s)[None, :]
        logits = jnp.where((k_pos <= q_pos)[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(qg.dtype)
    out = jnp.einsum("bkgcs,bskd->bckgd", probs, v)
    return out.reshape(b, c, kv * g, dh)


def decode_attention(
    q: jax.Array,        # (B, 1, H, dh)
    k_cache: jax.Array,  # (B, S_max, KV, dh)
    v_cache: jax.Array,  # (B, S_max, KV, dh)
    pos: jax.Array,      # scalar int: number of valid cache entries
    *,
    k_new: jax.Array | None = None,  # (B, 1, KV, dh): the current token's KV
    v_new: jax.Array | None = None,
    scale: float | None = None,
) -> jax.Array:
    """One-token attention against a (possibly padded) KV cache.

    With ``k_new``/``v_new`` the cache is treated as READ-ONLY and the
    current token's self-attention term is merged into the softmax — the
    paged-decode formulation that avoids a dynamic-update-slice on a
    sharded cache (a full cache re-gather under GSPMD; see EXPERIMENTS.md
    §Perf iteration on decode_32k).
    """
    b, _, h, dh = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    if h % kv == 0:
        # Grouped GQA: contract q groups directly against the KV cache —
        # never materialises the (B, S, H, dh) head-expanded cache (5x the
        # cache bytes on 5:1 GQA, and the trigger for GSPMD's seq->heads
        # re-gather; EXPERIMENTS.md §Perf decode iteration 2).
        g = h // kv
        qg = q.reshape(b, 1, kv, g, dh)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32) * scale
        valid = jnp.arange(s)[None, None, None, None, :] < jnp.asarray(pos).reshape(-1, 1, 1, 1, 1)
        logits = jnp.where(valid, logits, NEG_INF)
        if k_new is not None:
            self_logit = jnp.einsum("bqkgd,bnkd->bkgqn", qg, k_new).astype(jnp.float32) * scale
            logits = jnp.concatenate([logits, self_logit], axis=-1)
            probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
            out = jnp.einsum("bkgqs,bskd->bqkgd", probs[..., :s], v_cache)
            out = out + jnp.einsum("bkgqn,bnkd->bqkgd", probs[..., s:], v_new)
        else:
            probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
            out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
        return out.reshape(b, 1, h, dh)
    kx = _gqa_expand(k_cache, h)
    vx = _gqa_expand(v_cache, h)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kx).astype(jnp.float32) * scale
    valid = jnp.arange(s)[None, None, None, :] < jnp.asarray(pos).reshape(-1, 1, 1, 1)
    logits = jnp.where(valid, logits, NEG_INF)
    if k_new is not None:
        kn = _gqa_expand(k_new, h)
        vn = _gqa_expand(v_new, h)
        self_logit = jnp.einsum("bqhd,bnhd->bhqn", q, kn).astype(jnp.float32) * scale
        logits = jnp.concatenate([logits, self_logit], axis=-1)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs[..., :s], vx)
        out = out + jnp.einsum("bhqn,bnhd->bqhd", probs[..., s:], vn)
        return out
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, vx)


def sharded_decode_attention(
    q, k_cache, v_cache, pos, *, mesh, seq_axis: str, scale: float | None = None
):
    """Sequence-parallel decode: the KV cache is sharded along S across
    ``seq_axis``; each shard computes partial (max, num, den) statistics and
    merges with psum — the TPU-native long-context decode path (DESIGN §3).

    Call under shard_map with k_cache/v_cache sharded on dim 1.
    """
    b, _, h, dh = q.shape
    s_local = k_cache.shape[1]
    scale = scale if scale is not None else dh ** -0.5
    idx = jax.lax.axis_index(seq_axis)
    start = idx * s_local
    kx = _gqa_expand(k_cache, h)
    vx = _gqa_expand(v_cache, h)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kx).astype(jnp.float32) * scale
    valid = (start + jnp.arange(s_local))[None, None, None, :] < pos
    logits = jnp.where(valid, logits, NEG_INF)
    local_max = jnp.max(logits, axis=-1, keepdims=True)
    global_max = jax.lax.pmax(local_max, seq_axis)
    p = jnp.exp(logits - global_max)
    num = jnp.einsum("bhqs,bshd->bqhd", p.astype(q.dtype), vx).astype(jnp.float32)
    den = jnp.sum(p, axis=-1)[..., None].transpose(0, 2, 1, 3)  # (B,1,H,1)
    num = jax.lax.psum(num, seq_axis)
    den = jax.lax.psum(den, seq_axis)
    return (num / jnp.maximum(den, 1e-30)).astype(q.dtype)


def cross_attention(
    q: jax.Array,       # (B, S_dec, H, dh)
    k_mem: jax.Array,   # (B, S_enc, KV, dh)
    v_mem: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """Full (non-causal) attention over a fixed encoder memory."""
    h = q.shape[2]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    kx = _gqa_expand(k_mem, h)
    vx = _gqa_expand(v_mem, h)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kx).astype(jnp.float32) * scale
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, vx)


def seq_sharded_decode_attention(q, k_cache, v_cache, pos, k_new, v_new, *,
                                 mesh, batch_axes, seq_axes,
                                 scale: float | None = None):
    """Read-only GQA decode attention with the KV cache sharded along S.

    Explicit shard_map: each seq shard computes partial (max, num, den)
    online-softmax statistics and merges with pmax/psum over ``seq_axes`` —
    collectives are O(B*H*dh) per layer instead of GSPMD's full-cache
    re-gather (EXPERIMENTS.md §Perf decode iteration 3).  The self-token
    term is added on shard 0 only.
    """
    # jax promoted shard_map to the top level and renamed check_rep ->
    # check_vma across releases; resolve whichever this version ships
    # (mirrors the pltpu.CompilerParams shim).
    try:
        from jax import shard_map
        replication_check = {"check_vma": False}
    except ImportError:
        from jax.experimental.shard_map import shard_map
        replication_check = {"check_rep": False}

    b, _, h, dh = q.shape
    kv = k_cache.shape[2]
    assert h % kv == 0
    g = h // kv
    scale = scale if scale is not None else dh ** -0.5
    bt = batch_axes if len(batch_axes) != 1 else batch_axes[0]
    sq = seq_axes if len(seq_axes) != 1 else seq_axes[0]
    seq_axis_names = tuple(seq_axes)

    def local(qg, kc, vc, pos_s, kn, vn):
        s_loc = kc.shape[1]
        idx = jax.lax.axis_index(seq_axis_names)
        start = idx * s_loc
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc).astype(jnp.float32) * scale
        ids = start + jnp.arange(s_loc)
        valid = ids[None, None, None, None, :] < jnp.asarray(pos_s).reshape(-1, 1, 1, 1, 1)
        logits = jnp.where(valid, logits, NEG_INF)
        self_logit = jnp.einsum("bqkgd,bnkd->bkgqn", qg, kn).astype(jnp.float32) * scale
        on_first = (idx == 0)
        self_logit = jnp.where(on_first, self_logit, NEG_INF)
        m_loc = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True),
                            jnp.max(self_logit, axis=-1, keepdims=True))
        m = jax.lax.pmax(m_loc, seq_axis_names)
        pl = jnp.exp(logits - m)
        psl = jnp.exp(self_logit - m)
        num = jnp.einsum("bkgqs,bskd->bkgqd", pl.astype(vc.dtype), vc).astype(jnp.float32)
        num = num + jnp.einsum("bkgqn,bnkd->bkgqd", psl.astype(vn.dtype), vn).astype(jnp.float32)
        den = jnp.sum(pl, axis=-1, keepdims=True) + jnp.sum(psl, axis=-1, keepdims=True)
        num = jax.lax.psum(num, seq_axis_names)
        den = jax.lax.psum(den, seq_axis_names)
        return (num / jnp.maximum(den, 1e-30)).astype(qg.dtype)

    qg = q.reshape(b, 1, kv, g, dh)
    out = shard_map(
        local, mesh=mesh,
        in_specs=(P(bt, None, None, None, None), P(bt, sq, None, None),
                  P(bt, sq, None, None), P(), P(bt, None, None, None),
                  P(bt, None, None, None)),
        out_specs=P(bt, None, None, None, None),
        **replication_check,
    )(qg, k_cache, v_cache, jnp.asarray(pos, jnp.int32), k_new, v_new)
    return out.reshape(b, 1, h, dh)
