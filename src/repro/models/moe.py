"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Top-k routing with a per-expert token capacity C = ceil(T * k / E * cf);
overflow tokens are dropped (their combine weight is zero), the standard
TPU-friendly dispatch that keeps every tensor statically shaped.  Dispatch
and combine are scatter/gather ops so that, with experts sharded over the
model axis, GSPMD lowers them to all-to-alls (expert parallelism).

Variants:
  * plain top-k (granite: 32e top-8, jamba: 16e top-2)
  * MoE + parallel dense residual branch (arctic: 128e top-2 + dense FFN)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import InitSpec, swiglu
from .sharding import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int            # expert hidden size
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic-style parallel dense FFN
    dispatch_chunks: int = 1      # token-chunked dispatch (memory vs launch)


def moe_param_specs(d_model: int, cfg: MoEConfig) -> dict:
    e, f = cfg.n_experts, cfg.d_expert
    return {
        "router": InitSpec((d_model, e)),
        "w_gate": InitSpec((e, d_model, f)),
        "w_up": InitSpec((e, d_model, f)),
        "w_down": InitSpec((e, f, d_model)),
    }


def moe_ffn(x: jax.Array, params: dict, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    aux_loss is the standard load-balancing loss (mean_e f_e * p_e * E).

    With ``dispatch_chunks > 1`` the token stream is processed in chunks via
    a checkpointed scan: the scatter/gather dispatch buffers (which GSPMD
    cannot partition along the indexed expert dim) shrink by the chunk
    count — the fix that brought arctic-480b prefill_32k from 157 GB/device
    to budget (EXPERIMENTS.md §Perf iteration 1).
    """
    b, s, d = x.shape
    nc = cfg.dispatch_chunks
    if nc > 1 and s % nc == 0:
        xc = x.reshape(b, nc, s // nc, d).transpose(1, 0, 2, 3)

        def chunk_fn(carry, xi):
            out, aux = _moe_ffn_once(xi, params, cfg)
            return carry, (out, aux)

        _, (outs, auxs) = jax.lax.scan(
            jax.checkpoint(chunk_fn,
                           policy=jax.checkpoint_policies.nothing_saveable),
            None, xc)
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        return out, jnp.mean(auxs)
    return _moe_ffn_once(x, params, cfg)


def _moe_ffn_once(x: jax.Array, params: dict, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(t * k / e * cfg.capacity_factor), 1)
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss.
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)    # (T, k, E)
    frac_tokens = onehot.sum(axis=(0, 1)) / (t * k)
    frac_probs = probs.mean(axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * e

    # Position of each (token, k) slot within its expert's capacity buffer.
    flat_e = expert_idx.reshape(-1)                              # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    onehot_flat = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)     # (T*k, E)
    pos_in_e = jnp.cumsum(onehot_flat, axis=0) - onehot_flat     # (T*k, E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    gate_kept = jnp.where(keep, flat_gate, 0.0)
    slot = jnp.where(keep, pos, cap)                              # overflow -> spill row

    # Scatter tokens into (E, cap+1, d); the +1 row absorbs overflow.
    # The expert-dim sharding constraints below pin the expert einsums to
    # expert-local compute (EP): without them GSPMD all-gathers the FULL
    # expert weight stacks in f32 inside every scan iteration (1.9 TB x512
    # on jamba train_4k — EXPERIMENTS.md §Perf MoE iteration).
    token_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, slot].add(xf[token_idx])   # raw tokens; gates at combine
    buf = constrain(buf[:, :cap], "experts", None, None)          # (E, cap, d)

    # Expert computation (einsum over stacked expert weights), expert-local.
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, params["w_up"]
    )
    h = constrain(h, "experts", None, "ff")
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])           # (E, cap, d)
    y = constrain(y, "experts", None, None)

    # Gather back and combine with gate weights.
    y = jnp.concatenate([y, jnp.zeros((e, 1, d), y.dtype)], axis=1)  # spill row = 0
    picked = y[flat_e, slot]                                      # (T*k, d)
    combined = jnp.zeros((t, d), x.dtype).at[token_idx].add(
        picked * gate_kept[:, None].astype(x.dtype)
    )
    combined = constrain(combined, "batch", None)
    return combined.reshape(b, s, d), aux.astype(jnp.float32)


def moe_with_residual(x, params, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """Arctic: dense FFN residual branch in parallel with the MoE."""
    moe_out, aux = moe_ffn(x, params, cfg)
    dense = swiglu(x, params["res_gate"], params["res_up"], params["res_down"])
    return moe_out + dense, aux


def moe_residual_param_specs(d_model: int, d_ff: int, cfg: MoEConfig) -> dict:
    specs = moe_param_specs(d_model, cfg)
    specs.update(
        res_gate=InitSpec((d_model, d_ff)),
        res_up=InitSpec((d_model, d_ff)),
        res_down=InitSpec((d_ff, d_model)),
    )
    return specs
