"""Beyond-paper: batch-level joint decode-instance assignment.

The paper's §VII-C lists as future work: "the per-request greedy does not
jointly optimise across concurrent requests; a batch-level formulation could
yield better results at higher computational cost."  This module implements
that formulation.

Requests that arrive within an assignment window W (default 10 ms) are
assigned *jointly*: we run a regret-minimising greedy over the
(request x candidate) cost matrix that re-evaluates marginal costs after each
commitment, so two same-window requests from one prefill instance are not
both sent down the same tier at its pre-dispatch n_inflight, and queue growth
on a popular decode instance is charged to later assignments.

This is the classic auction/regret heuristic for the assignment problem —
O(W^2 |D|) per window instead of O(|D|) per request, matching the paper's
"higher computational cost" caveat, and it strictly generalises Algorithm 1
(window of 1 == NetKV-Full).  Each commit round evaluates the full
(remaining-requests x candidates) cost matrix as vectorised array ops over
the ``ClusterView`` columns plus the virtualised (free, queued, batch)
deltas — no per-candidate Python loops.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .oracle import OracleView, SelfContentionTracker, TIERS
from .schedulers import (
    Decision,
    NetKVFull,
    RequestInfo,
    v_iter_time,
    v_s_eff,
    v_transfer_time,
)
from .view import ClusterView, as_cluster_view


class NetKVBatch(NetKVFull):
    name = "netkv-batch"

    def __init__(self, *args, window: float = 0.010, **kwargs):
        super().__init__(*args, **kwargs)
        self.window = window

    # Single-request path stays Alg. 1 (used when the window holds 1 request).
    def _coerce_batch(self, cands_per_req, oracle):
        """Accept (ClusterView, hits (R,D)) or legacy per-request lists."""
        if (isinstance(cands_per_req, tuple) and len(cands_per_req) == 2
                and isinstance(cands_per_req[0], ClusterView)):
            cv, hits = cands_per_req
            return cv, np.asarray(hits, np.float64)
        cv = as_cluster_view(cands_per_req[0], oracle)
        hits = np.array(
            [[c.hit_tokens for c in cl] for cl in cands_per_req], np.float64
        )
        return cv, hits

    def select_batch(
        self,
        reqs: Sequence[tuple[RequestInfo, int]],
        cands_per_req,  # (ClusterView, hits) | Sequence[Sequence[CandidateState]]
        oracle: OracleView,
        inflight: Optional[SelfContentionTracker] = None,
    ) -> list[Optional[Decision]]:
        """Jointly assign a window of (request, prefill_id) pairs.

        ``hits[i]`` is request i's prefix-hit column over the shared pool
        (hit_tokens is request-specific; load/memory state is shared and
        virtualised below).  Returns one Decision (or None = reject) per
        input, in input order.
        """
        n = len(reqs)
        cv, hits = self._coerce_batch(cands_per_req, oracle)
        assert hits.shape == (n, cv.n)
        out: list[Optional[Decision]] = [None] * n
        ids = cv.column("ids")
        healthy = cv.column("healthy")
        iter_scale = cv.column("iter_scale")
        # Virtual shared state we mutate as we commit assignments.
        vfree = cv.column("free_memory").astype(np.float64)
        vqueued = cv.column("queued").astype(np.int64)
        vbatch = cv.column("batch").astype(np.int64)
        vinflight: dict[tuple[int, int], int] = {}
        # Request-side constants: s_eff rows and tier rows.
        s_eff_rows = np.stack([
            v_s_eff(req.kv_bytes, hits[i], req.input_len)
            for i, (req, _) in enumerate(reqs)
        ])
        tier_rows = [cv.tier_row(pid) for _, pid in reqs]
        cong = {t: oracle.congestion.get(t, 0.0) for t in TIERS}
        remaining = list(range(n))

        while remaining:
            # Shared load terms under the current virtual state (one pass).
            t_iter = v_iter_time(self.iter_model, vbatch)
            blocked = np.maximum(0, vqueued - (self.beta_max - vbatch))
            t_queue = iter_scale * (blocked * t_iter)
            t_dec = iter_scale * v_iter_time(self.iter_model, vbatch + 1)
            # Regret-minimising pick: commit the request whose best-vs-second
            # gap is largest (it has the most to lose from waiting).
            best_pick = None  # (neg_regret, best_cost, i, slot, t_x, tier)
            for i in remaining:
                _, pid = reqs[i]
                s_eff = s_eff_rows[i]
                feas = np.flatnonzero(healthy & (vfree >= s_eff + self.m_min))
                if feas.size == 0:
                    continue
                n_by = {
                    t: (inflight.get(pid, t) if inflight is not None else 0)
                    + vinflight.get((pid, t), 0)
                    for t in TIERS
                }
                t_x = v_transfer_time(s_eff, tier_rows[i], oracle.tier_bandwidth,
                                      cong, n_by, oracle.tier_latency)
                cost = t_x + t_queue + t_dec
                cf = cost[feas]
                order = np.lexsort((ids[feas], cf))  # ties -> lowest id
                b = int(feas[order[0]])
                best_cost = float(cost[b])
                regret = (float(cf[order[1]]) - best_cost
                          if feas.size > 1 else float("inf"))
                entry = (-regret, best_cost, i, b, float(t_x[b]),
                         int(tier_rows[i][b]))
                if best_pick is None or entry < best_pick:
                    best_pick = entry
            if best_pick is None:
                break  # everything left is infeasible
            _, best_cost, i, b, t_x_b, tier = best_pick
            _, pid = reqs[i]
            s_eff_b = float(s_eff_rows[i][b])
            # Commit: mutate virtual state so later picks see the consequences.
            vfree[b] -= s_eff_b
            vbatch[b] = min(vbatch[b] + 1, self.beta_max)
            if vbatch[b] >= self.beta_max:
                vqueued[b] += 1
            vinflight[(pid, tier)] = vinflight.get((pid, tier), 0) + 1
            if inflight is not None:
                inflight.incr(pid, tier)
            out[i] = Decision(int(ids[b]), best_cost, t_x_b, tier, s_eff_b)
            remaining.remove(i)
        return out
