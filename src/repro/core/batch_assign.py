"""Beyond-paper: batch-level joint decode-instance assignment.

The paper's §VII-C lists as future work: "the per-request greedy does not
jointly optimise across concurrent requests; a batch-level formulation could
yield better results at higher computational cost."  This module implements
that formulation.

Requests that arrive within an assignment window W (default 10 ms) are
assigned *jointly*: we run a regret-minimising greedy over the
(request x candidate) cost matrix that re-evaluates marginal costs after each
commitment, so two same-window requests from one prefill instance are not
both sent down the same tier at its pre-dispatch n_inflight, and queue growth
on a popular decode instance is charged to later assignments.

This is the classic auction/regret heuristic for the assignment problem: it
is O(W^2 |D|) per window instead of O(|D|) per request, matching the paper's
"higher computational cost" caveat, and it strictly generalises Algorithm 1
(window of 1 == NetKV-Full).
"""

from __future__ import annotations

from typing import Optional, Sequence

from .cost import transfer_time
from .oracle import OracleView, SelfContentionTracker
from .schedulers import CandidateState, Decision, NetKVFull, RequestInfo


class NetKVBatch(NetKVFull):
    name = "netkv-batch"

    def __init__(self, *args, window: float = 0.010, **kwargs):
        super().__init__(*args, **kwargs)
        self.window = window

    # Single-request path stays Alg. 1 (used when the window holds 1 request).
    def select_batch(
        self,
        reqs: Sequence[tuple[RequestInfo, int]],
        cands_per_req: Sequence[Sequence[CandidateState]],
        oracle: OracleView,
        inflight: Optional[SelfContentionTracker] = None,
    ) -> list[Optional[Decision]]:
        """Jointly assign a window of (request, prefill_id) pairs.

        ``cands_per_req[i]`` is request i's view of the pool (hit_tokens is
        request-specific; load/memory state is shared and virtualised below).
        Returns one Decision (or None = reject) per input, in input order.
        """
        n = len(reqs)
        assert len(cands_per_req) == n
        out: list[Optional[Decision]] = [None] * n
        # Virtual shared state we mutate as we commit assignments.
        vstate = {
            c.instance_id: [c.free_memory, c.queued, c.batch_size]
            for c in cands_per_req[0]
        }
        vinflight: dict[tuple[int, int], int] = {}
        remaining = list(range(n))

        def marginal_cost(i: int, c: CandidateState):
            req, pid = reqs[i]
            if c.instance_id not in vstate:
                vstate[c.instance_id] = [c.free_memory, c.queued, c.batch_size]
            free, queued, beta = vstate[c.instance_id]
            s_eff = self._s_eff(req, c)
            if not c.healthy or free < s_eff + self.m_min:
                return None
            tier = oracle.tier_of(pid, c.instance_id)
            n_in = (inflight.get(pid, tier) if inflight is not None else 0) + vinflight.get(
                (pid, tier), 0
            )
            cong = oracle.congestion.get(tier, 0.0)
            t_x = transfer_time(
                s_eff, oracle.tier_bandwidth[tier], cong, n_in, oracle.tier_latency[tier]
            )
            vq = CandidateState(
                c.instance_id, free, queued, beta, c.hit_tokens, c.healthy, c.iter_scale
            )
            cost = t_x + self._t_queue(vq) + self._t_decode(vq)
            return cost, t_x, tier, s_eff

        while remaining:
            # Regret-minimising pick: commit the request whose best-vs-second
            # gap is largest (it has the most to lose from waiting).
            best_pick = None  # (neg_regret, i, (cost, t_x, tier, s_eff, cid))
            for i in remaining:
                scored = []
                for c in cands_per_req[i]:
                    mc = marginal_cost(i, c)
                    if mc is not None:
                        scored.append((mc[0], c.instance_id, mc))
                if not scored:
                    continue
                scored.sort()
                best = scored[0]
                regret = (scored[1][0] - best[0]) if len(scored) > 1 else float("inf")
                entry = (-regret, best[0], i, best)
                if best_pick is None or entry < best_pick:
                    best_pick = entry
            if best_pick is None:
                break  # everything left is infeasible
            _, _, i, (cost, cid, (c_cost, t_x, tier, s_eff)) = best_pick
            req, pid = reqs[i]
            # Commit: mutate virtual state so later picks see the consequences.
            vstate[cid][0] -= s_eff
            vstate[cid][2] = min(vstate[cid][2] + 1, self.beta_max)
            if vstate[cid][2] >= self.beta_max:
                vstate[cid][1] += 1
            vinflight[(pid, tier)] = vinflight.get((pid, tier), 0) + 1
            if inflight is not None:
                inflight.incr(pid, tier)
            out[i] = Decision(cid, c_cost, t_x, tier, s_eff)
            remaining.remove(i)
        return out
