"""NetKV core: cost model, network cost oracle, scheduler ladder."""

from .cost import (
    GBPS,
    GiB,
    H100_TP4_ITER,
    H100_TP4_PREFILL,
    IterTimeModel,
    LLAMA3_70B_KV,
    ModelKVSpec,
    PrefillTimeModel,
    effective_bandwidth,
    effective_bandwidth_tiers,
    effective_transfer_bytes,
    first_decode_time,
    post_prefill_latency,
    queue_time,
    transfer_time,
)
from .oracle import (
    EWMACongestionPredictor,
    NetworkCostOracle,
    OracleView,
    PAPER_TIER_BANDWIDTH,
    PAPER_TIER_LATENCY,
    SelfContentionTracker,
    TransferIntent,
    TIERS,
)
from .view import ClusterView, as_cluster_view
from .schedulers import (
    CandidateState,
    CacheAware,
    CacheLoadAware,
    Decision,
    LADDER,
    LoadAware,
    NetKVFull,
    NetKVPredictive,
    NetKVStatic,
    NetKVTopoOnly,
    RequestInfo,
    RoundRobin,
    Scheduler,
    make_scheduler,
)
from .batch_assign import NetKVBatch
from .dispatch import CohortItem, CohortSelector, supports_cohort
from .reference import REFERENCE_LADDER, make_reference_scheduler
from .propositions import (
    Prop1Instance,
    prop1_condition,
    prop1_latencies,
    prop1_rhs,
    prop2_epsilon_bound,
    prop2_ordering_preserved,
)

__all__ = [k for k in dir() if not k.startswith("_")]
