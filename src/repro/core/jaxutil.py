"""Shared JAX configuration guards.

The fused scenario engine's bit-exactness claims (water-filling and cohort
step vs their NumPy planes) hold only under double precision; JAX defaults
to f32 unless ``jax_enable_x64`` is flipped *before* the arrays involved are
created.  Tests, benchmarks and ``sim/scenarios.py`` all route through
:func:`enable_f64` so the flag is set exactly once, idempotently, and there
is a single place asserting it actually took (guarding against an import
that raced a traced function).
"""

from __future__ import annotations

_enabled = False


def enable_f64() -> None:
    """Idempotently enable 64-bit JAX types (safe to call repeatedly)."""
    global _enabled
    if _enabled:
        return
    import jax

    jax.config.update("jax_enable_x64", True)
    _enabled = True


def f64_enabled() -> bool:
    import jax

    return bool(jax.config.jax_enable_x64)
