"""Beyond-paper: multi-hop KV routing through DRAM staging caches (§VII-D).

The paper's future-work sketch: "Multi-hop KV routing extends NetKV to
architectures that stage KV state through intermediate caches in CPU DRAM
or SSDs: the oracle exposes tier information for both hops and the cost
model sums the two transfer times, with the greedy generalising naturally."

Implementation: a cluster hosts ``StagingStore`` nodes (CPU-DRAM block
caches, Mooncake-style).  For a request whose prefix blocks live in a store,
NetKV-MultiHop scores each decode candidate d over the best *plan*:

  direct:            T(p -> d, s_eff)
  staged(s):         max( T(s -> d, s_hit),  T(p -> d, s_miss) )   [parallel]

where s_hit is the portion of the payload resident in store s (fetched over
the s->d path at the store's DRAM-capped bandwidth) and s_miss is the
remainder that must still come from the prefill instance.  Completed
transfers populate the stores (write-through), so hot shared prefixes
migrate close to every pod — cutting cross-pod bytes beyond what
decode-local prefix caches can.

Cost arithmetic reuses Eqs. (2)-(4) per hop as vectorised array ops over the
``ClusterView`` columns: each store contributes one candidate-wide leg-time
vector, and the plan choice is an elementwise min across plans.  Prop. 2's
staleness tolerance applies hop-wise.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .oracle import TIERS
from .schedulers import Decision, NetKVFull, v_transfer_time
from .view import as_cluster_view


@dataclasses.dataclass
class StagingStore:
    """CPU-DRAM block cache on a host (instance-id addressable)."""

    node_id: int
    capacity_bytes: float
    dram_bw: float = 40e9          # sustained DRAM->NIC read bandwidth
    bytes_per_block: float = 16 * 320 * 1024 / 4

    def __post_init__(self):
        from collections import OrderedDict

        self._lru: "OrderedDict" = OrderedDict()

    @property
    def bytes_used(self) -> float:
        return len(self._lru) * self.bytes_per_block

    def hit_blocks(self, hashes: Sequence) -> int:
        n = 0
        for h in hashes:
            if h in self._lru:
                n += 1
            else:
                break
        return n

    def insert(self, hashes: Sequence) -> None:
        for h in hashes:
            self._lru[h] = None
            self._lru.move_to_end(h)
        while self.bytes_used > self.capacity_bytes and self._lru:
            self._lru.popitem(last=False)


@dataclasses.dataclass
class HopPlan:
    kind: str                     # "direct" | "staged"
    store_id: int = -1
    t_xfer: float = 0.0
    staged_bytes: float = 0.0
    direct_bytes: float = 0.0


class NetKVMultiHop(NetKVFull):
    """NetKV-Full + staged-fetch planning over DRAM KV stores."""

    name = "netkv-multihop"

    def __init__(self, *args, stores: Sequence[StagingStore] = (),
                 block_tokens: int = 16, **kwargs):
        super().__init__(*args, **kwargs)
        self.stores = list(stores)
        self.block_tokens = block_tokens
        self._req_hashes: Sequence = ()
        self.plans: dict[int, HopPlan] = {}
        # Self-contention on the store's egress NIC — the same idea the
        # paper applies to prefill NICs (n_inflight^tau), hop-wise.
        self.store_inflight: dict[int, int] = {}

    def observe_request(self, block_hashes: Sequence) -> None:
        """Simulator hook: the current request's block-hash sequence."""
        self._req_hashes = tuple(block_hashes)

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        cv = as_cluster_view(cands, oracle)
        s_eff, mask = self._prep(req, cv)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        tier_row = cv.tier_row(prefill_id)
        # Direct plan: one p->d leg under Alg. 1's information set.
        t_best = self._xfer_vec(req, cv, prefill_id, oracle, inflight, s_eff, tier_row)
        plan_store = np.full(cv.n, -1, np.int64)       # -1 == direct
        plan_staged = np.zeros(cv.n)
        plan_direct = s_eff.copy()
        # Staged plans: per store, one candidate-wide pair of leg vectors.
        # Tokens already on the decode candidate are not refetched from
        # anywhere; staging competes only for the remainder.
        if self._req_hashes:
            hit = cv.column("hit_tokens")
            bytes_per_tok = req.kv_bytes / max(req.input_len, 1)
            cong = self._congestion_by_tier(oracle)
            n_by = self._n_by_tier(inflight, prefill_id)
            for store in self.stores:
                hit_blocks = store.hit_blocks(self._req_hashes)
                hit_tokens = min(hit_blocks * self.block_tokens, req.input_len)
                extra = np.maximum(hit_tokens - hit, 0.0)
                staged_bytes = extra * bytes_per_tok
                direct_bytes = np.maximum(s_eff - staged_bytes, 0.0)
                s_tier_row = cv.tier_row(store.node_id)
                bw_capped = {t: min(oracle.tier_bandwidth[t], store.dram_bw)
                             for t in TIERS}
                n_store = self.store_inflight.get(store.node_id, 0)
                t_staged_leg = v_transfer_time(
                    staged_bytes, s_tier_row, bw_capped, cong,
                    {t: n_store for t in TIERS}, oracle.tier_latency)
                t_direct_leg = v_transfer_time(
                    direct_bytes, tier_row, oracle.tier_bandwidth, cong, n_by,
                    oracle.tier_latency)
                t = np.maximum(t_staged_leg, t_direct_leg)  # parallel fetch
                better = (s_eff > 0.0) & (extra > 0.0) & (t < t_best)
                t_best = np.where(better, t, t_best)
                plan_store = np.where(better, store.node_id, plan_store)
                plan_staged = np.where(better, staged_bytes, plan_staged)
                plan_direct = np.where(better, direct_bytes, plan_direct)
        cost = t_best + self._t_queue_vec(cv) + self._t_decode_vec(cv)
        j = int(idx[np.lexsort((self._ties(idx.size), cost[idx]))[0]])
        tier = int(tier_row[j])
        staged = plan_store[j] >= 0
        best_plan = HopPlan(
            "staged" if staged else "direct",
            int(plan_store[j]), float(t_best[j]),
            float(plan_staged[j]) if staged else 0.0, float(plan_direct[j]),
        )
        if inflight is not None and best_plan.kind == "direct":
            inflight.incr(prefill_id, tier)
        if best_plan.kind == "staged":
            self.store_inflight[best_plan.store_id] = \
                self.store_inflight.get(best_plan.store_id, 0) + 1
        self.plans[req.request_id] = best_plan
        return Decision(int(cv.ids[j]), float(cost[j]), best_plan.t_xfer, tier,
                        float(s_eff[j]))

    def on_transfer_complete(self, block_hashes: Sequence, store_id: int | None = None):
        """Write-through: landed prefixes populate the (nearest) store."""
        targets = [s for s in self.stores if store_id is None or s.node_id == store_id]
        for s in targets:
            s.insert(block_hashes)

    def staged_leg_done(self, store_id: int) -> None:
        cur = self.store_inflight.get(store_id, 0)
        if cur > 1:
            self.store_inflight[store_id] = cur - 1
        else:
            self.store_inflight.pop(store_id, None)
