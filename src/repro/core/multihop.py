"""Beyond-paper: multi-hop KV routing through DRAM staging caches (§VII-D).

The paper's future-work sketch: "Multi-hop KV routing extends NetKV to
architectures that stage KV state through intermediate caches in CPU DRAM
or SSDs: the oracle exposes tier information for both hops and the cost
model sums the two transfer times, with the greedy generalising naturally."

Implementation: a cluster hosts ``StagingStore`` nodes (CPU-DRAM block
caches, Mooncake-style).  For a request whose prefix blocks live in a store,
NetKV-MultiHop scores each decode candidate d over the best *plan*:

  direct:            T(p -> d, s_eff)
  staged(s):         max( T(s -> d, s_hit),  T(p -> d, s_miss) )   [parallel]

where s_hit is the portion of the payload resident in store s (fetched over
the s->d path at the store's DRAM-capped bandwidth) and s_miss is the
remainder that must still come from the prefill instance.  Completed
transfers populate the stores (write-through), so hot shared prefixes
migrate close to every pod — cutting cross-pod bytes beyond what
decode-local prefix caches can.

Cost arithmetic reuses Eqs. (2)-(4) per hop; Prop. 2's staleness tolerance
applies hop-wise.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .cost import effective_bandwidth, transfer_time
from .oracle import OracleView, SelfContentionTracker
from .schedulers import CandidateState, Decision, NetKVFull, RequestInfo


@dataclasses.dataclass
class StagingStore:
    """CPU-DRAM block cache on a host (instance-id addressable)."""

    node_id: int
    capacity_bytes: float
    dram_bw: float = 40e9          # sustained DRAM->NIC read bandwidth
    bytes_per_block: float = 16 * 320 * 1024 / 4

    def __post_init__(self):
        from collections import OrderedDict

        self._lru: "OrderedDict" = OrderedDict()

    @property
    def bytes_used(self) -> float:
        return len(self._lru) * self.bytes_per_block

    def hit_blocks(self, hashes: Sequence) -> int:
        n = 0
        for h in hashes:
            if h in self._lru:
                n += 1
            else:
                break
        return n

    def insert(self, hashes: Sequence) -> None:
        for h in hashes:
            self._lru[h] = None
            self._lru.move_to_end(h)
        while self.bytes_used > self.capacity_bytes and self._lru:
            self._lru.popitem(last=False)


@dataclasses.dataclass
class HopPlan:
    kind: str                     # "direct" | "staged"
    store_id: int = -1
    t_xfer: float = 0.0
    staged_bytes: float = 0.0
    direct_bytes: float = 0.0


class NetKVMultiHop(NetKVFull):
    """NetKV-Full + staged-fetch planning over DRAM KV stores."""

    name = "netkv-multihop"

    def __init__(self, *args, stores: Sequence[StagingStore] = (),
                 block_tokens: int = 16, **kwargs):
        super().__init__(*args, **kwargs)
        self.stores = list(stores)
        self.block_tokens = block_tokens
        self._req_hashes: Sequence = ()
        self.plans: dict[int, HopPlan] = {}
        # Self-contention on the store's egress NIC — the same idea the
        # paper applies to prefill NICs (n_inflight^tau), hop-wise.
        self.store_inflight: dict[int, int] = {}

    def observe_request(self, block_hashes: Sequence) -> None:
        """Simulator hook: the current request's block-hash sequence."""
        self._req_hashes = tuple(block_hashes)

    def _plan(self, req: RequestInfo, cand: CandidateState, prefill_id: int,
              oracle: OracleView, inflight) -> HopPlan:
        t_direct, tier, s_eff = self._xfer(req, cand, prefill_id, oracle, inflight)
        best = HopPlan("direct", t_xfer=t_direct, direct_bytes=s_eff)
        if s_eff <= 0 or not self._req_hashes:
            return best
        bytes_per_tok = req.kv_bytes / max(req.input_len, 1)
        # Tokens already on the decode candidate are not refetched from
        # anywhere; staging competes only for the remainder.
        for store in self.stores:
            hit_blocks = store.hit_blocks(self._req_hashes)
            hit_tokens = min(hit_blocks * self.block_tokens, req.input_len)
            extra = max(hit_tokens - cand.hit_tokens, 0.0)
            if extra <= 0:
                continue
            staged_bytes = extra * bytes_per_tok
            direct_bytes = max(s_eff - staged_bytes, 0.0)
            s_tier = oracle.tier_of(store.node_id, cand.instance_id)
            c = self._congestion(oracle, s_tier)
            bw = min(oracle.tier_bandwidth[s_tier], store.dram_bw)
            n_store = self.store_inflight.get(store.node_id, 0)
            t_staged_leg = transfer_time(staged_bytes, bw, c, n_store,
                                         oracle.tier_latency[s_tier])
            p_tier = oracle.tier_of(prefill_id, cand.instance_id)
            t_direct_leg = transfer_time(
                direct_bytes, oracle.tier_bandwidth[p_tier],
                self._congestion(oracle, p_tier),
                self._n_inflight(inflight, prefill_id, p_tier),
                oracle.tier_latency[p_tier],
            )
            t = max(t_staged_leg, t_direct_leg)  # parallel fetch
            if t < best.t_xfer:
                best = HopPlan("staged", store.node_id, t, staged_bytes,
                               direct_bytes)
        return best

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best_c, best_plan, best_cost, best_tie = None, None, float("inf"), 2.0
        for c in feas:
            plan = self._plan(req, c, prefill_id, oracle, inflight)
            cost = plan.t_xfer + self._t_queue(c) + self._t_decode(c)
            tie = self._tie()
            if cost < best_cost or (cost == best_cost and tie < best_tie):
                best_c, best_plan, best_cost, best_tie = c, plan, cost, tie
        assert best_c is not None
        tier = oracle.tier_of(prefill_id, best_c.instance_id)
        if inflight is not None and best_plan.kind == "direct":
            inflight.incr(prefill_id, tier)
        if best_plan.kind == "staged":
            self.store_inflight[best_plan.store_id] =                 self.store_inflight.get(best_plan.store_id, 0) + 1
        self.plans[req.request_id] = best_plan
        s_eff = self._s_eff(req, best_c)
        d = Decision(best_c.instance_id, best_cost, best_plan.t_xfer, tier, s_eff)
        return d

    def on_transfer_complete(self, block_hashes: Sequence, store_id: int | None = None):
        """Write-through: landed prefixes populate the (nearest) store."""
        targets = [s for s in self.stores if store_id is None or s.node_id == store_id]
        for s in targets:
            s.insert(block_hashes)

    def staged_leg_done(self, store_id: int) -> None:
        cur = self.store_inflight.get(store_id, 0)
        if cur > 1:
            self.store_inflight[store_id] = cur - 1
        else:
            self.store_inflight.pop(store_id, None)
