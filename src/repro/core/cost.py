"""Cost model for disaggregated decode-instance selection.

Implements Eqs. (1)-(7) of the paper:

  (1) KV cache size          s_r = 2 * n_layers * n_kv_heads * d_head * l_r * b_elem
  (2) effective transfer     s_eff(d) = s_r * (1 - lambda_r(d) / l_r)
  (3) transfer time          T_xfer = s_eff / B_eff(p, d) + L_tau
  (4) effective bandwidth    B_eff = B_tau * (1 - c_tau) / (1 + n_inflight^tau(p))
  (6) queueing delay         T_queue = max(0, q_d - (beta_max - beta_d)) * t_iter(beta_d)
  (7) first decode step      T_decode = t_iter(beta_d + 1)

All quantities are SI: bytes, bytes/s, seconds.  The module is pure and
side-effect free so it can be consumed from the Python simulator, the
vectorised JAX scorer, and the Pallas scoring kernel's reference oracle
without divergence.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

GiB = 1024.0 ** 3
GBPS = 1e9 / 8.0  # 1 Gbps in bytes/s
B_TOK = 16  # block size in tokens for block-level prefix matching (SIII-B)


def n_blocks(tokens: int) -> int:
    return (tokens + B_TOK - 1) // B_TOK


@dataclasses.dataclass(frozen=True)
class ModelKVSpec:
    """Per-model constants needed by Eq. (1) and its generalisation.

    For attention models ``state_bytes_per_token`` is the Eq. (1) coefficient
    (2 * n_layers * n_kv_heads * d_head * b_elem).  For hybrid / SSM models
    the transferred state has a sequence-length-independent component
    (``fixed_state_bytes``: Mamba SSM + conv state, RWKV WKV + token-shift
    state) on top of the per-token KV of any attention layers.
    """

    name: str
    n_layers: int
    n_kv_heads: int
    d_head: int
    bytes_per_elem: int = 2  # FP16 / BF16
    n_attn_layers: int | None = None  # hybrid: attention layers only
    fixed_state_bytes: int = 0  # SSM/RWKV per-request constant state
    tp: int = 1  # tensor-parallel degree: per-shard flows

    @property
    def kv_bytes_per_token(self) -> int:
        """Eq. (1) coefficient: aggregate KV bytes per token."""
        layers = self.n_attn_layers if self.n_attn_layers is not None else self.n_layers
        return 2 * layers * self.n_kv_heads * self.d_head * self.bytes_per_elem

    def kv_bytes(self, input_len: int) -> int:
        """Eq. (1) + fixed-state generalisation: total transferred bytes."""
        return self.kv_bytes_per_token * input_len + self.fixed_state_bytes


# Llama-3-70B at TP=4 -- the paper's evaluation model (320 KB/token aggregate).
LLAMA3_70B_KV = ModelKVSpec(
    name="llama3-70b", n_layers=80, n_kv_heads=8, d_head=128, bytes_per_elem=2, tp=4
)


def effective_transfer_bytes(s_r: float, hit_tokens: float, input_len: int) -> float:
    """Eq. (2): s_eff = s_r * (1 - lambda/l).  hit_tokens is clamped to [0, l]."""
    if input_len <= 0:
        return 0.0
    frac = min(max(hit_tokens, 0.0), float(input_len)) / float(input_len)
    return s_r * (1.0 - frac)


def effective_bandwidth(
    tier_bw: float, congestion: float, n_inflight: int
) -> float:
    """Eq. (4): B_eff = B_tau (1 - c_tau) / (1 + n_inflight).

    ``tier_bw`` in bytes/s; ``congestion`` in [0, 1); ``n_inflight`` >= 0.
    """
    c = min(max(congestion, 0.0), 0.999999)
    return tier_bw * (1.0 - c) / (1.0 + max(n_inflight, 0))


def effective_bandwidth_tiers(
    tier_bandwidth, congestion_by_tier, n_by_tier
) -> "np.ndarray":
    """Eq. (4) across all four tiers at once: B_eff per tier as a (4,) array.

    Element-for-element the same IEEE operation sequence as four scalar
    ``effective_bandwidth`` calls — the ladder's ``v_transfer_time`` and the
    DispatchPlane's cohort scorer both gather from this row, so bit-exact
    parity between them reduces to sharing it.
    """
    import numpy as np

    from .oracle import TIERS

    return np.array(
        [effective_bandwidth(tier_bandwidth[t], congestion_by_tier[t],
                             n_by_tier[t]) for t in TIERS],
        dtype=np.float64,
    )


def transfer_time(
    s_eff: float, tier_bw: float, congestion: float, n_inflight: int, tier_latency: float
) -> float:
    """Eq. (3): T_xfer = s_eff / B_eff + L_tau."""
    if s_eff <= 0.0:
        return tier_latency
    beff = effective_bandwidth(tier_bw, congestion, n_inflight)
    return s_eff / beff + tier_latency


def streamed_transfer_time(
    s_eff: float,
    tier_bw: float,
    congestion: float,
    n_inflight: int,
    tier_latency: float,
    prefill_remaining: float = 0.0,
    tail_bytes: float | None = None,
) -> float:
    """Eq. (3) under chunk-streamed prefill/transfer overlap (ChunkPlane).

    Chunks enter the network as they prefill, so the last byte lands at
    the later of (a) the pipe draining all ``s_eff`` bytes from now and
    (b) the final chunk — ``tail_bytes``, which only exists once prefill
    ends ``prefill_remaining`` seconds from now — crossing the wire:

        T_xfer = max(s_eff / B_eff,  prefill_remaining + tail / B_eff) + L_tau

    With ``prefill_remaining == 0`` and ``tail_bytes in (None, >= s_eff)``
    this is exactly ``transfer_time`` — the serial model.
    """
    if s_eff <= 0.0:
        return tier_latency
    beff = effective_bandwidth(tier_bw, congestion, n_inflight)
    tail = s_eff if tail_bytes is None else min(max(tail_bytes, 0.0), s_eff)
    return max(s_eff / beff, prefill_remaining + tail / beff) + tier_latency


def deflected_cost(deflect_eta, decode_load):
    """Deflected-candidate branch of the Eq. (5) objective (RolePlane).

    When a prefill storm deflects chunked prefill onto a decode host, the
    KV is *born* on the target — Eq. (2) gives s_eff = 0 and Eq. (3)/(4)
    collapse entirely (no wire, no tier, no self-contention).  What
    remains is the target's deflected-chunk-queue drain ETA plus the
    decode-side Eq. (6)/(7) load (``decode_load`` = T_queue + T_decode,
    pre-summed by the caller so the sequential ladder and the fused R x D
    cohort path share one IEEE op sequence — bit-exact parity between
    them reduces to sharing this helper):

        C_defl[d] = ETA_defl(d) + (T_queue(d) + T_decode(d))
    """
    return deflect_eta + decode_load


@dataclasses.dataclass(frozen=True)
class IterTimeModel:
    """Piecewise-linear iteration-time model  t_iter(beta) = a + b * beta.

    Optionally piecewise: ``breaks``/``slopes`` extend beyond the first
    segment, matching the paper's 'piecewise-linear function fitted from
    published profiling data'.
    """

    a: float  # base seconds
    b: float  # seconds per batched request
    breaks: Sequence[float] = ()
    slopes: Sequence[float] = ()

    def __call__(self, beta: float) -> float:
        t = self.a + self.b * max(beta, 0.0)
        for brk, slope in zip(self.breaks, self.slopes):
            if beta > brk:
                t += slope * (beta - brk)
        return t


def iter_time_vector(model: "IterTimeModel", beta) -> "np.ndarray":
    """Vectorised ``IterTimeModel.__call__`` over a beta array.

    Element-for-element the same IEEE operation sequence as the scalar
    call (the InstancePlane's cohort deadline computation relies on this
    for bit-exact parity with the per-object reference engine).
    """
    import numpy as np

    beta = np.asarray(beta)
    t = model.a + model.b * np.maximum(beta, 0.0)
    for brk, slope in zip(model.breaks, model.slopes):
        t = np.where(beta > brk, t + slope * (beta - brk), t)
    return t


@dataclasses.dataclass(frozen=True)
class PrefillTimeModel:
    """T_prefill(l) = c * l + d (piecewise-linear in prompt length)."""

    c: float  # seconds per token
    d: float  # base seconds

    def __call__(self, input_len: int) -> float:
        return self.c * input_len + self.d


# Fits triangulated from DistServe / vLLM v0.6 / MLPerf Inference v5.0
# (Llama-2/3-70B class at TP=4 on H100).  Deliberately biased toward *fast*
# decode, per the paper, so the network term is conservatively weighted.
# t_iter spans [12.4 ms @ beta=0, 13.4 ms @ beta=64] — the paper's observed
# absolute TBT band across all runs is 12.55-13.42 ms (§VI-J).
H100_TP4_ITER = IterTimeModel(a=0.0124, b=1.6e-5)        # 12.4 ms + 16 us/req
H100_TP4_PREFILL = PrefillTimeModel(c=5.0e-5, d=0.015)   # 50 us/token + 15 ms
# TPU v5e preset derived with the same published-roofline methodology.
V5E_TP4_ITER = IterTimeModel(a=0.0168, b=2.2e-5)
V5E_TP4_PREFILL = PrefillTimeModel(c=6.8e-5, d=0.019)


def queue_time(q_d: int, beta_d: int, beta_max: int, iter_model: IterTimeModel) -> float:
    """Eq. (6): requests blocked behind a full batch wait one iter each."""
    blocked = max(0, q_d - (beta_max - beta_d))
    return blocked * iter_model(beta_d)


def first_decode_time(beta_d: int, iter_model: IterTimeModel) -> float:
    """Eq. (7): the first decode step after joining the batch on d."""
    return iter_model(beta_d + 1)


def post_prefill_latency(
    *,
    s_r: float,
    hit_tokens: float,
    input_len: int,
    tier_bw: float,
    congestion: float,
    n_inflight: int,
    tier_latency: float,
    q_d: int,
    beta_d: int,
    beta_max: int,
    iter_model: IterTimeModel,
) -> float:
    """Eq. (5) objective for one candidate: T_xfer + T_queue + T_decode."""
    s_eff = effective_transfer_bytes(s_r, hit_tokens, input_len)
    return (
        transfer_time(s_eff, tier_bw, congestion, n_inflight, tier_latency)
        + queue_time(q_d, beta_d, beta_max, iter_model)
        + first_decode_time(beta_d, iter_model)
    )


def decision_breakdown(
    *,
    s_eff: float,
    tier_bw: float,
    congestion: float,
    n_inflight: int,
    tier_latency: float,
    q_d: int,
    beta_d: int,
    beta_max: int,
    iter_model: IterTimeModel,
) -> tuple[float, float, float]:
    """Eq. (5) split into its Eq. (3)/(6)/(7) terms: (T_xfer, T_queue,
    T_decode) for one candidate — the schema of a TracePlane forensics
    row's transfer/load components.  Pure, so tests can recompute a
    recorded winner's breakdown and assert bit-equality."""
    return (
        transfer_time(s_eff, tier_bw, congestion, n_inflight, tier_latency),
        queue_time(q_d, beta_d, beta_max, iter_model),
        first_decode_time(beta_d, iter_model),
    )


def feasible(m_d: float, s_eff: float, m_min: float) -> bool:
    """Feasibility: D_r = {d : m_d >= s_eff(d) + m_min}."""
    return m_d >= s_eff + m_min
