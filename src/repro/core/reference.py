"""Retired per-candidate Python scoring loop — kept as the parity oracle.

This is the seed's scheduler ladder verbatim: O(|D|) Python iteration over
``CandidateState`` objects, one tie-break RNG draw per feasible candidate.
The production ladder in ``schedulers.py`` is vectorised over ``ClusterView``
and must stay *bit-identical* to this module (same winner, same ``Decision``
cost/tier/s_eff, same rejection behaviour, same RNG stream consumption) —
``tests/test_view_parity.py`` enforces it.  Benchmarks also use this loop as
the "python" baseline arm.

The single intentional divergence from the seed: ``ReferenceNetKVPredictive``
advances its EWMA predictor once per ``select`` call instead of once per
scored candidate (the seed's per-candidate update made candidate costs
depend on their scan position — an artifact, not a design).  The vectorised
``NetKVPredictive`` implements the same once-per-select semantics.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .cost import (
    IterTimeModel,
    effective_transfer_bytes,
    first_decode_time,
    queue_time,
    transfer_time,
)
from .oracle import OracleView, SelfContentionTracker, EWMACongestionPredictor, TIERS
from .schedulers import CandidateState, Decision, RequestInfo


class ReferenceScheduler:
    """Base: feasibility filter + shared component models (seed semantics)."""

    name = "base"
    uses_tier = False
    uses_self_contention = False
    uses_congestion = False

    def __init__(self, iter_model: IterTimeModel, beta_max: int,
                 m_min: float = 2 * 1024**3, seed: int = 0):
        self.iter_model = iter_model
        self.beta_max = beta_max
        self.m_min = m_min
        self._rng = np.random.default_rng(seed + 0xC0FFEE)

    def _tie(self) -> float:
        return float(self._rng.random())

    def _s_eff(self, req: RequestInfo, cand: CandidateState) -> float:
        return effective_transfer_bytes(req.kv_bytes, cand.hit_tokens, req.input_len)

    def feasible(self, req: RequestInfo, cands: Sequence[CandidateState]):
        return [
            c for c in cands
            if c.healthy and c.free_memory >= self._s_eff(req, c) + self.m_min
        ]

    def _t_queue(self, cand: CandidateState) -> float:
        return cand.iter_scale * queue_time(
            cand.queued, cand.batch_size, self.beta_max, self.iter_model
        )

    def _t_decode(self, cand: CandidateState) -> float:
        return cand.iter_scale * first_decode_time(cand.batch_size, self.iter_model)

    def _xfer(self, req, cand, prefill_id, oracle, inflight):
        tier = oracle.tier_of(prefill_id, cand.instance_id)
        s_eff = self._s_eff(req, cand)
        c = self._congestion(oracle, tier)
        n = self._n_inflight(inflight, prefill_id, tier)
        t = transfer_time(
            s_eff, oracle.tier_bandwidth[tier], c, n, oracle.tier_latency[tier]
        )
        return t, tier, s_eff

    def _congestion(self, oracle: OracleView, tier: int) -> float:
        return oracle.congestion.get(tier, 0.0) if self.uses_congestion else 0.0

    def _n_inflight(self, inflight, prefill_id, tier) -> int:
        if self.uses_self_contention and inflight is not None:
            return inflight.get(prefill_id, tier)
        return 0

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        raise NotImplementedError


class ReferenceRoundRobin(ReferenceScheduler):
    name = "rr"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._next = 0

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        feas.sort(key=lambda c: c.instance_id)
        cand = feas[self._next % len(feas)]
        self._next += 1
        tier = oracle.tier_of(prefill_id, cand.instance_id)
        return Decision(cand.instance_id, 0.0, 0.0, tier, self._s_eff(req, cand))


class ReferenceLoadAware(ReferenceScheduler):
    name = "la"

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best = min(feas, key=lambda c: (self._t_queue(c) + self._t_decode(c), self._tie()))
        tier = oracle.tier_of(prefill_id, best.instance_id)
        return Decision(
            best.instance_id,
            self._t_queue(best) + self._t_decode(best),
            0.0,
            tier,
            self._s_eff(req, best),
        )


class ReferenceCacheAware(ReferenceScheduler):
    name = "ca"

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best = min(
            feas,
            key=lambda c: (-c.hit_tokens, self._t_queue(c) + self._t_decode(c), self._tie()),
        )
        tier = oracle.tier_of(prefill_id, best.instance_id)
        return Decision(best.instance_id, -best.hit_tokens, 0.0, tier, self._s_eff(req, best))


class ReferenceCacheLoadAware(ReferenceScheduler):
    name = "cla"

    def __init__(self, *args, w_cache: float = 1.0, w_load: float = 1.0, **kwargs):
        super().__init__(*args, **kwargs)
        self.w_cache = w_cache
        self.w_load = w_load

    def _score(self, req: RequestInfo, cand: CandidateState) -> float:
        miss = 1.0 - min(cand.hit_tokens, req.input_len) / max(req.input_len, 1)
        load = (self._t_queue(cand) + self._t_decode(cand)) / self.iter_model(self.beta_max)
        return self.w_cache * miss + self.w_load * load

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best = min(feas, key=lambda c: (self._score(req, c), self._tie()))
        tier = oracle.tier_of(prefill_id, best.instance_id)
        return Decision(
            best.instance_id, self._score(req, best), 0.0, tier, self._s_eff(req, best)
        )


class ReferenceNetKVFull(ReferenceScheduler):
    name = "netkv-full"
    uses_tier = True
    uses_self_contention = True
    uses_congestion = True

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best, best_cost, best_x, best_tier, best_seff = None, float("inf"), 0.0, 0, 0.0
        best_tie = 2.0
        for c in feas:
            t_x, tier, s_eff = self._xfer(req, c, prefill_id, oracle, inflight)
            cost = t_x + self._t_queue(c) + self._t_decode(c)
            tie = self._tie()
            if cost < best_cost or (cost == best_cost and tie < best_tie):
                best, best_cost, best_x, best_tier, best_seff = c, cost, t_x, tier, s_eff
                best_tie = tie
        assert best is not None
        if inflight is not None:
            inflight.incr(prefill_id, best_tier)
        return Decision(best.instance_id, best_cost, best_x, best_tier, best_seff)


class ReferenceNetKVStatic(ReferenceNetKVFull):
    name = "netkv-static"
    uses_congestion = False


class ReferenceNetKVTopoOnly(ReferenceNetKVFull):
    name = "netkv-topo"
    uses_self_contention = False
    uses_congestion = False

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        return super().select(req, prefill_id, cands, oracle, inflight=None)


class ReferenceNetKVPredictive(ReferenceNetKVFull):
    name = "netkv-pred"

    def __init__(self, *args, predictor: EWMACongestionPredictor | None = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.predictor = predictor or EWMACongestionPredictor()

    def _congestion(self, oracle: OracleView, tier: int) -> float:
        return self.predictor.predict(tier)

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        self.predictor.update(oracle.congestion)  # once per decision
        return super().select(req, prefill_id, cands, oracle, inflight)


REFERENCE_LADDER = {
    "rr": ReferenceRoundRobin,
    "la": ReferenceLoadAware,
    "ca": ReferenceCacheAware,
    "cla": ReferenceCacheLoadAware,
    "netkv-topo": ReferenceNetKVTopoOnly,
    "netkv-static": ReferenceNetKVStatic,
    "netkv-full": ReferenceNetKVFull,
    "netkv-pred": ReferenceNetKVPredictive,
}


def make_reference_scheduler(name: str, iter_model: IterTimeModel, beta_max: int,
                             **kw) -> ReferenceScheduler:
    try:
        cls = REFERENCE_LADDER[name]
    except KeyError:
        raise ValueError(
            f"unknown reference scheduler {name!r}; known: {sorted(REFERENCE_LADDER)}"
        )
    return cls(iter_model, beta_max, **kw)
