"""The network cost oracle — the operator→scheduler interface (§III-E).

The operator publishes four maps every ``refresh_interval`` seconds:

  * ``tier_map``        static: (instance, instance) -> tier id in {0,1,2,3}
  * ``tier_bandwidth``  static: tier -> bytes/s
  * ``tier_latency``    static: tier -> seconds
  * ``congestion``      dynamic: tier -> [0, 1)

The scheduler reads a *snapshot* (``OracleView``) that is immutable between
refreshes — this is exactly the staleness regime analysed by Proposition 2.
Optionally the scheduler sends ``TransferIntent`` hints back to the operator.

The oracle is deliberately tiny: tier classification + per-tier scalars.  It
carries no raw topology, no per-link state, and no inference semantics.

RolePlane note: *deflected* prefill (``Scheduler.select_deflected``) never
consults the oracle — the KV materialises on the decode host itself, so
Eq. (3)/(4) collapse to a zero-transfer term (tier 0, no congestion, no
self-contention hint) and the only network-adjacent input is the host's
deflected-chunk drain ETA from the instance engine.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

TIERS = (0, 1, 2, 3)

# Paper defaults (§VI-A): B0=450 GB/s NVLink, B1=100 Gbps ToR,
# B2=50 Gbps (2:1 oversub), B3=25 Gbps (4:1 oversub).
PAPER_TIER_BANDWIDTH = {
    0: 450e9,            # bytes/s (NVLink)
    1: 100e9 / 8,        # 100 Gbps
    2: 50e9 / 8,         # 50 Gbps
    3: 25e9 / 8,         # 25 Gbps
}
PAPER_TIER_LATENCY = {0: 1e-6, 1: 3e-6, 2: 8e-6, 3: 15e-6}

# TPU-fabric preset (see DESIGN.md §3): intra-host ICI / slice ICI /
# intra-pod DCN / cross-pod DCN.
TPU_TIER_BANDWIDTH = {0: 400e9, 1: 50e9, 2: 25e9 / 8 * 4, 3: 25e9 / 8}
TPU_TIER_LATENCY = {0: 1e-6, 1: 5e-6, 2: 10e-6, 3: 25e-6}


@dataclasses.dataclass(frozen=True)
class OracleView:
    """Immutable snapshot consumed by the scheduler between refreshes."""

    tier_of: Callable[[int, int], int]
    tier_bandwidth: Mapping[int, float]
    tier_latency: Mapping[int, float]
    congestion: Mapping[int, float]
    timestamp: float = 0.0

    def __post_init__(self) -> None:
        # The per-tier arrays are a function of the (immutable) snapshot, so
        # compute them once here instead of allocating three fresh arrays on
        # every dispatch.  Read-only so a caller can't corrupt the cache.
        bw = np.array([self.tier_bandwidth[t] for t in TIERS], dtype=np.float64)
        lat = np.array([self.tier_latency[t] for t in TIERS], dtype=np.float64)
        cong = np.array([self.congestion.get(t, 0.0) for t in TIERS],
                        dtype=np.float64)
        for a in (bw, lat, cong):
            a.flags.writeable = False
        object.__setattr__(self, "_bw_arr", bw)
        object.__setattr__(self, "_lat_arr", lat)
        object.__setattr__(self, "_cong_arr", cong)

    def bandwidth_array(self) -> np.ndarray:
        return self._bw_arr

    def latency_array(self) -> np.ndarray:
        return self._lat_arr

    def congestion_array(self) -> np.ndarray:
        return self._cong_arr

    def est_transfer_time(
        self,
        s_eff: float,
        tier: int,
        n_inflight: int = 0,
        prefill_remaining: float = 0.0,
        tail_bytes: float | None = None,
    ) -> float:
        """Eq. (3) through this snapshot's maps, overlap-aware.

        With the defaults this is the serial T_xfer; with
        ``prefill_remaining``/``tail_bytes`` set it is the streamed-chunk
        estimate (``cost.streamed_transfer_time``): bytes keep becoming
        ready while prefill runs, so only the final-chunk tail is forced
        to cross the wire after prefill ends.  The scalar twin of the
        ladder's vectorised ``v_transfer_time`` column.
        """
        from .cost import streamed_transfer_time

        return streamed_transfer_time(
            s_eff, self.tier_bandwidth[tier], self.congestion.get(tier, 0.0),
            n_inflight, self.tier_latency[tier],
            prefill_remaining=prefill_remaining, tail_bytes=tail_bytes,
        )


@dataclasses.dataclass
class TransferIntent:
    """Optional scheduler→operator hint for an upcoming KV flow."""

    src: int
    dst: int
    bytes: int
    priority: int = 0
    deadline: float | None = None


class NetworkCostOracle:
    """Operator-side oracle with a refresh clock.

    ``telemetry_fn(now) -> {tier: congestion}`` is the operator's aggregation
    of switch counters (INT/sFlow/SNMP), *excluding* the scheduler's own
    marked KV flows (DSCP class), per §III-D.  The scheduler only ever sees
    the last published snapshot.

    ``source`` selects where the congestion signal comes from:

    * ``"model"`` (default) — ``telemetry_fn``, the background model's
      ground-truth per-tier utilisation (the paper's idealised operator).
    * ``"measured"`` — ``measured_fn``, per-tier congestion aggregated from
      the network plane's *per-link byte counters*, including the
      scheduler's own in-flight KV traffic (an operator that cannot
      subtract the KV DSCP class).  This opens a realistic telemetry-noise
      axis for the staleness experiments
      (``FlowPlane.measured_tier_congestion``).

    **Rewire awareness**: the "static" per-tier maps are held as *live*
    references (pass ``topology=`` or the topology's own dicts) and
    snapshotted into the immutable ``OracleView`` at each refresh.  An OCS
    rewire (``FatTree.rewire``) therefore reaches the scheduler only at the
    *next* refresh — between a rewire and that refresh the scheduler routes
    on pre-rewire bandwidths, which is exactly the staleness regime of
    Prop. 2 extended to the capacity axis.  The previous construction-time
    ``dict()`` copy drifted silently from any topology whose capacities
    changed (or whose caller mutated its ``tier_bandwidth`` after build).
    """

    def __init__(
        self,
        tier_of: Callable[[int, int], int],
        tier_bandwidth: Mapping[int, float] | None = None,
        tier_latency: Mapping[int, float] | None = None,
        telemetry_fn: Callable[[float], Mapping[int, float]] | None = None,
        refresh_interval: float = 1.0,
        measured_fn: Callable[[float], Mapping[int, float]] | None = None,
        source: str = "model",
        topology=None,
    ) -> None:
        if source not in ("model", "measured"):
            raise ValueError(f"unknown telemetry source {source!r}")
        if source == "measured" and measured_fn is None:
            raise ValueError("source='measured' requires measured_fn")
        self.tier_of = tier_of
        if topology is not None:
            # Wire the static maps straight to the live topology dicts.
            tier_bandwidth = tier_bandwidth if tier_bandwidth is not None \
                else topology.tier_bandwidth
            tier_latency = tier_latency if tier_latency is not None \
                else topology.tier_latency
        # Live references, NOT copies: a rewire mutates these in place and
        # the next refresh snapshots the new values.  The paper defaults are
        # copied so nobody can corrupt the module constants through us.
        self.tier_bandwidth = tier_bandwidth if tier_bandwidth is not None \
            else dict(PAPER_TIER_BANDWIDTH)
        self.tier_latency = tier_latency if tier_latency is not None \
            else dict(PAPER_TIER_LATENCY)
        self._telemetry_fn = telemetry_fn or (lambda now: {t: 0.0 for t in TIERS})
        self._measured_fn = measured_fn
        self.source = source
        self.refresh_interval = refresh_interval
        self._last_refresh = -float("inf")
        self._snapshot: OracleView | None = None
        self.intents: list[TransferIntent] = []
        self.refreshes = 0

    def view(self, now: float) -> OracleView:
        """Return the current snapshot, refreshing if the interval elapsed."""
        if self._snapshot is None or now - self._last_refresh >= self.refresh_interval:
            fn = self._measured_fn if self.source == "measured" else self._telemetry_fn
            congestion = {t: float(np.clip(c, 0.0, 0.999)) for t, c in fn(now).items()}
            for t in TIERS:
                congestion.setdefault(t, 0.0)
            self._snapshot = OracleView(
                tier_of=self.tier_of,
                # Immutable copies: the snapshot must hold the pre-rewire
                # values until the next refresh, not track the live dicts.
                tier_bandwidth=dict(self.tier_bandwidth),
                tier_latency=dict(self.tier_latency),
                congestion=congestion,
                timestamp=now,
            )
            self._last_refresh = now
            self.refreshes += 1
        return self._snapshot

    def force_refresh(self, now: float) -> "OracleView":
        """Out-of-band refresh: drop the snapshot and rebuild immediately.

        The rewire-notification path (``SimConfig.notify_rewires``): an OCS
        controller that *tells* the operator it moved capacity, instead of
        letting the scheduler route on a stale pre-rewire snapshot until the
        periodic interval elapses.  Counts as a normal refresh.
        """
        self._snapshot = None
        return self.view(now)

    def submit_intent(self, intent: TransferIntent) -> None:
        self.intents.append(intent)


class SelfContentionTracker:
    """n_inflight^tau(p): the scheduler's own in-flight flows per (p, tier).

    Incremented on dispatch, decremented via the engine's transfer-complete
    callback (vLLM ``KVConnectorBase_V1.get_finished`` equivalent).  Capped
    (default 16 ~ NIC saturated flow count) to avoid runaway under overload.
    """

    def __init__(self, cap: int = 16) -> None:
        self.cap = cap
        self._counts: dict[tuple[int, int], int] = {}

    def get(self, prefill_id: int, tier: int) -> int:
        return self._counts.get((prefill_id, tier), 0)

    def incr(self, prefill_id: int, tier: int) -> None:
        key = (prefill_id, tier)
        self._counts[key] = min(self.cap, self._counts.get(key, 0) + 1)

    def decr(self, prefill_id: int, tier: int) -> None:
        key = (prefill_id, tier)
        cur = self._counts.get(key, 0)
        if cur <= 1:
            self._counts.pop(key, None)
        else:
            self._counts[key] = cur - 1

    def snapshot(self, prefill_id: int) -> dict[int, int]:
        return {t: self.get(prefill_id, t) for t in TIERS}


class EWMACongestionPredictor:
    """Beyond-paper: predictive congestion via exponential smoothing (§VII-D).

    Replaces the instantaneous snapshot with a one-step-ahead forecast
    ``c_hat = alpha * obs + (1 - alpha) * c_hat`` plus a trend term
    (Holt's linear method, damped).  Prop. 2's large staleness tolerance is
    what makes this safe: a modest forecast error never flips tier order.
    """

    def __init__(self, alpha: float = 0.4, beta: float = 0.2, damp: float = 0.9) -> None:
        self.alpha, self.beta, self.damp = alpha, beta, damp
        self._level: dict[int, float] = {}
        self._trend: dict[int, float] = {}

    def update(self, congestion: Mapping[int, float]) -> None:
        for t, obs in congestion.items():
            lvl = self._level.get(t)
            if lvl is None:
                self._level[t], self._trend[t] = float(obs), 0.0
                continue
            trend = self._trend.get(t, 0.0)
            new_level = self.alpha * float(obs) + (1 - self.alpha) * (lvl + self.damp * trend)
            self._trend[t] = self.beta * (new_level - lvl) + (1 - self.beta) * self.damp * trend
            self._level[t] = new_level

    def predict(self, tier: int) -> float:
        lvl = self._level.get(tier, 0.0) + self.damp * self._trend.get(tier, 0.0)
        return float(np.clip(lvl, 0.0, 0.999))

    def predicted_map(self, tiers: Sequence[int] = TIERS) -> dict[int, float]:
        return {t: self.predict(t) for t in tiers}
