"""Propositions 1 and 2 as executable predicates.

These are used by the property-based tests (hypothesis) to check that the
cost model and the scheduler respect the paper's analytical claims, and by
EXPERIMENTS.md to report the empirical staleness margin.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Prop1Instance:
    """Two-candidate instance of Proposition 1.

    d1: same-rack (bandwidth B1, congestion c1, hit ratio rho1)
    d2: cross-pod (bandwidth B3 = B1/k, congestion c3, hit ratio rho2 >= rho1)
    """

    s_r: float
    B1: float
    k: float
    c1: float
    c3: float
    rho1: float
    rho2: float
    t_queue_d1: float = 0.0
    t_queue_d2: float = 0.0


def prop1_rhs(inst: Prop1Instance) -> float:
    """Right-hand side of Eq. (8)."""
    band = inst.k * (1.0 - inst.c1) / (1.0 - inst.c3) * (1.0 - inst.rho2)
    queue = inst.B1 * (1.0 - inst.c1) / inst.s_r * (inst.t_queue_d2 - inst.t_queue_d1)
    return band + queue


def prop1_condition(inst: Prop1Instance) -> bool:
    """True iff the same-rack candidate d1 wins despite the colder cache."""
    return (1.0 - inst.rho1) < prop1_rhs(inst)


def prop1_latencies(inst: Prop1Instance) -> tuple[float, float]:
    """Direct post-prefill latencies (transfer + queue) of (d1, d2)."""
    t1 = inst.s_r * (1.0 - inst.rho1) / (inst.B1 * (1.0 - inst.c1)) + inst.t_queue_d1
    B3 = inst.B1 / inst.k
    t2 = inst.s_r * (1.0 - inst.rho2) / (B3 * (1.0 - inst.c3)) + inst.t_queue_d2
    return t1, t2


def prop2_epsilon_bound(B_hi: float, c_hi: float, B_lo: float, c_lo: float) -> float:
    """Eq. (9): staleness tolerance for preserving the tier ordering.

    Requires the true ordering B_hi (1 - c_hi) > B_lo (1 - c_lo); returns the
    largest per-tier congestion error epsilon that cannot invert it.  A
    non-positive return means no tolerance exists (the faster tier is at or
    past the crossover, e.g. near saturation).
    """
    return (B_hi * (1.0 - c_hi) - B_lo * (1.0 - c_lo)) / (B_hi + B_lo)


def prop2_ordering_preserved(
    B_hi: float, c_hi: float, B_lo: float, c_lo: float, eps: float
) -> bool:
    """Worst-case stale ordering check: inflate the fast tier, deflate the slow."""
    stale_hi = B_hi * (1.0 - min(c_hi + eps, 0.999999))
    stale_lo = B_lo * (1.0 - max(c_lo - eps, 0.0))
    return stale_hi > stale_lo
