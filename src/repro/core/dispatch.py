"""DispatchPlane: cohort-batched decode selection with fused R x D scoring.

EventPlane delivers dispatch-ready requests in same-timestamp cohorts
(arrival bursts, epoch-batched transfer completions, chunk-ready streams),
but PRs 1-7 still invoked the scheduler once per request: every dispatch
re-ran a ``RadixPlane.hit_row``, rebuilt the Eq. (6)/(7) load columns, and
paid a D log D ``lexsort`` — the last per-event Python hot path at
2048-4096 GPUs.  ``CohortSelector`` amortises all of it over the cohort:

* ONE stacked ``hit_rows`` call builds the (R, n) prefix-hit matrix H
  (``sim/kvcache.py``; shared prefixes across the cohort dedupe to one
  broadcast LCP each),
* s_eff, T_queue, T_decode and T_xfer are evaluated as R x D matrices in
  one broadcast pass per prefill-source group (queue/batch/straggler
  columns are *cohort-invariant*: nothing enqueues or admits between the
  argmin rows of one cohort, so Eq. (6)/(7) are computed once),
* the per-row winner is a min-scan (min -> equal-cost slice -> tie argmin)
  proven order-identical to the ladder's stable ``lexsort``,
* between rows only the *winning column* moves (memory pinned at reserve,
  self-contention +1, reserve-time cache eviction), so each assignment
  applies an O(1) delta — ``ClusterView.apply_assignment`` for external
  drivers, eviction-counter watches + per-source inflight invalidation
  internally — instead of a full re-score.

**Bit-exactness is the contract**, same as every prior plane: walking
``select_row(0..R-1)`` produces the identical ``Decision`` stream —
including the RNG tie-break draws, ``RoundRobin._next`` cursor,
``SelfContentionTracker`` increments and ``NetKVPredictive`` EWMA updates —
as R sequential ``Scheduler.select`` calls against the live view.  Rows
whose precomputed scores a delta invalidated (a reserve-time eviction
changed their hit row, or an earlier same-source assignment bumped
n_inflight) recompute through the scheduler's own vector helpers at their
turn, so the fallback *is* the sequential op sequence.  The per-request
path stays available as ``SimConfig.dispatch_mode="reference"``.

``netkv-full(backend="pallas")`` rows score through the cohort-axis Pallas
kernel (``kernels/netkv_score.netkv_score_cohort``) computed once on the
snapshot; a row falls back to the single-row kernel only if a later
assignment flipped any candidate's f32 feasibility bit for that row.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from .cost import deflected_cost, effective_bandwidth_tiers, transfer_time
from .oracle import OracleView, SelfContentionTracker, TIERS
from .schedulers import (
    CacheAware,
    CacheLoadAware,
    Decision,
    LoadAware,
    NetKVFull,
    NetKVPredictive,
    NetKVStatic,
    NetKVTopoOnly,
    RequestInfo,
    RoundRobin,
    Scheduler,
    _runner_up,
)
from .view import ROLE_DECODE, ClusterView

__all__ = ["CohortItem", "CohortSelector", "DeflectedCohortSelector",
           "supports_cohort"]

# Exact-type -> scoring shape.  Subclasses of the ladder types are not
# assumed to keep the parent's op sequence, so membership is by type.
_KIND = {
    RoundRobin: "rr",
    LoadAware: "la",
    CacheAware: "ca",
    CacheLoadAware: "cla",
    NetKVTopoOnly: "netkv",
    NetKVStatic: "netkv",
    NetKVFull: "netkv",
    NetKVPredictive: "netkv",
}


def supports_cohort(sched: Scheduler) -> bool:
    """True when ``sched`` has a bit-exact cohort path.

    Exact ladder types only: netkv-batch's windowed joint assigner and the
    staged multihop scheduler run their own batching and fall back to the
    per-request dispatch path.
    """
    return type(sched) in _KIND


@dataclasses.dataclass
class CohortItem:
    """One dispatch-ready request inside a same-timestamp cohort."""

    req: RequestInfo
    prefill_id: int


def _pick_min(idx: np.ndarray, key: np.ndarray, ties: np.ndarray) -> int:
    """argmin with RNG tie-break == ``idx[np.lexsort((ties, key[idx]))[0]]``.

    The stable lexsort's head is: minimal key, then minimal tie, then lowest
    position.  ``argmin`` returns the first occurrence, which reproduces the
    positional tie exactly; ``==`` treats -0.0 and 0.0 as equal on both
    paths.
    """
    sub = key[idx]
    pos = np.flatnonzero(sub == sub.min())
    if pos.size > 1:
        return int(idx[pos[int(np.argmin(ties[pos]))]])
    return int(idx[pos[0]])


def _pick_min2(idx: np.ndarray, k1: np.ndarray, k2: np.ndarray,
               ties: np.ndarray) -> int:
    """Two-key variant == ``idx[np.lexsort((ties, k2[idx], k1[idx]))[0]]``."""
    s1 = k1[idx]
    p1 = np.flatnonzero(s1 == s1.min())
    if p1.size == 1:
        return int(idx[p1[0]])
    s2 = k2[idx[p1]]
    p2 = p1[np.flatnonzero(s2 == s2.min())]
    if p2.size > 1:
        return int(idx[p2[int(np.argmin(ties[p2]))]])
    return int(idx[p2[0]])


class CohortSelector:
    """Batched selection over one same-timestamp dispatch cohort.

    Construct once per cohort (the R x D precompute), then call
    ``select_row(k)`` for k = 0..R-1 *in order*, dispatching each returned
    ``Decision`` before the next call (reserve/incr exactly as the
    sequential path would).  Rows may be skipped — a skipped row simply
    never draws its ties, like a request that never reached ``select``.

    ``hit_fn(k, iid)`` / ``evictions_fn(iid)`` wire the reserve-time
    eviction watch: after each assignment the selector polls the winner's
    eviction counter and refreshes the affected hit-matrix column for the
    remaining rows.  Omit both when nothing evicts between rows (pure
    benchmarks, frozen views).
    """

    def __init__(
        self,
        sched: Scheduler,
        items: Sequence[CohortItem],
        cv: ClusterView,
        oracle: OracleView,
        inflight: Optional[SelfContentionTracker] = None,
        *,
        hit_matrix: np.ndarray,
        hit_fn: Optional[Callable[[int, int], float]] = None,
        evictions_fn: Optional[Callable[[int], int]] = None,
    ) -> None:
        t0 = time.perf_counter()
        kind = _KIND.get(type(sched))
        if kind is None:
            raise ValueError(
                f"no cohort path for scheduler type {type(sched).__name__}")
        self._sched = sched
        self._items = list(items)
        self._cv = cv
        self._oracle = oracle
        self._inflight = inflight
        self._hit_fn = hit_fn
        self._evictions_fn = evictions_fn
        self._kind = kind
        R = len(self._items)
        n = cv.n
        self.H = np.asarray(hit_matrix, np.float64)
        if self.H.shape != (R, n):
            raise ValueError(f"hit_matrix shape {self.H.shape} != {(R, n)}")

        # s_eff as one broadcast: per-element identical to v_s_eff per row
        # (rows with input_len <= 0 are all-zero there, zeroed here).
        kv_col = np.array([it.req.kv_bytes for it in self._items],
                          np.float64)[:, None]
        l_vec = np.array([it.req.input_len for it in self._items], np.float64)
        l_col = np.where(l_vec > 0.0, l_vec, 1.0)[:, None]
        frac = np.minimum(np.maximum(self.H, 0.0), l_col) / l_col
        self.SE = kv_col * (1.0 - frac)
        self.SE[l_vec <= 0.0] = 0.0

        self._dirty = np.zeros(R, bool)
        self._infl_dirty: set[int] = set()
        self._watch: dict[int, tuple[int, int]] = {}   # iid -> (slot, count)
        self._load = self._loadn = None
        self._tx = None
        self._has_tx = np.zeros(R, bool)
        self._pl_costs = self._pl_best = self._pl_thr32 = None
        self._free0 = self._healthy0 = None

        if kind in ("la", "ca", "cla"):
            # Cohort-invariant Eq. (6)/(7): queue/batch/straggler columns do
            # not move between the rows of one cohort, so the sequential
            # per-select recompute yields these exact bits every time.
            load = sched._t_queue_vec(cv) + sched._t_decode_vec(cv)
            self._load = load
            if kind == "cla":
                self._loadn = load / sched.iter_model(sched.beta_max)
        elif kind == "netkv":
            self._is_pred = isinstance(sched, NetKVPredictive)
            self._pallas = sched.backend == "pallas"
            self._streamed = np.array(
                [it.req.prefill_remaining > 0.0 or it.req.tail_bytes is not None
                 for it in self._items], bool)
            self._t_q = sched._t_queue_vec(cv)
            self._t_d = sched._t_decode_vec(cv)
            if not self._is_pred:
                # NetKVPredictive's congestion read advances its EWMA — a
                # per-select side effect that must happen at each row's
                # *turn*, so pred rows always recompute (no precompute).
                self._build_netkv(R, n)
        t1 = time.perf_counter()
        self._setup_s = t1 - t0

    # ------------------------------------------------------------ netkv build
    def _build_netkv(self, R: int, n: int) -> None:
        sched = self._sched
        cv, oracle = self._cv, self._oracle
        infl = self._inflight if sched.uses_self_contention else None
        cong = sched._congestion_by_tier(oracle)
        lat = oracle.latency_array()
        # Group rows by prefill source: one tier-row gather + one Eq. (4)
        # row per source, then every cost component as a broadcast matrix.
        # Only t_x is materialised R x D; the final cost row is summed
        # lazily at each row's turn (two L2-resident O(D) adds) so skipped
        # and fallback rows never pay for it.
        by_pid: dict[int, list[int]] = {}
        for k, it in enumerate(self._items):
            by_pid.setdefault(it.prefill_id, []).append(k)
        np_rows = np.flatnonzero(~self._streamed) if self._pallas else None
        if self._pallas and np_rows is not None and np_rows.size == 0:
            np_rows = None
        self._tx = np.zeros((R, n), np.float64)
        for pid, rows in by_pid.items():
            tier_row = cv.tier_row(pid)
            beff = effective_bandwidth_tiers(
                oracle.tier_bandwidth, cong, sched._n_by_tier(infl, pid))
            lat_row = lat[tier_row]
            b_row = beff[tier_row]
            serial = [k for k in rows if not self._streamed[k]]
            if serial and not self._pallas:
                se = self.SE[serial]
                self._tx[serial] = np.where(
                    se <= 0.0, lat_row, se / b_row + lat_row)
                self._has_tx[serial] = True
            tail_none = [k for k in rows if self._streamed[k]
                         and self._items[k].req.tail_bytes is None]
            tailed = [k for k in rows if self._streamed[k]
                      and self._items[k].req.tail_bytes is not None]
            if tail_none:
                se = self.SE[tail_none]
                pr = np.array([self._items[k].req.prefill_remaining
                               for k in tail_none], np.float64)[:, None]
                t_stream = np.maximum(se / b_row, pr + se / b_row)
                self._tx[tail_none] = np.where(
                    se <= 0.0, lat_row, t_stream + lat_row)
                self._has_tx[tail_none] = True
            if tailed:
                se = self.SE[tailed]
                pr = np.array([self._items[k].req.prefill_remaining
                               for k in tailed], np.float64)[:, None]
                tb = np.array([self._items[k].req.tail_bytes
                               for k in tailed], np.float64)[:, None]
                tail = np.minimum(np.maximum(tb, 0.0), se)
                t_stream = np.maximum(se / b_row, pr + tail / b_row)
                self._tx[tailed] = np.where(
                    se <= 0.0, lat_row, t_stream + lat_row)
                self._has_tx[tailed] = True
        if self._pallas and np_rows is not None:
            self._build_pallas(np_rows, n)

    def _build_pallas(self, rows: np.ndarray, n: int) -> None:
        """Run the cohort-axis kernel once on the snapshot for the serial
        rows; snapshot free/healthy + the kernel's f32 feasibility threshold
        so later rows can prove their precomputed argmin is still live."""
        from repro.kernels.netkv_score import netkv_score_cohort

        sched, cv, oracle = self._sched, self._cv, self._oracle
        infl = self._inflight if sched.uses_self_contention else None
        if sched._pallas_interpret is None:
            import jax

            sched._pallas_interpret = jax.default_backend() != "tpu"
        cong = sched._congestion_by_tier(oracle)
        items = [self._items[int(k)] for k in rows]
        tier_rows = np.stack([cv.tier_row(it.prefill_id) for it in items])
        infl_rows = [[sched._n_by_tier(infl, it.prefill_id)[t] for t in TIERS]
                     for it in items]
        costs, best = netkv_score_cohort(
            cv.column("free_memory"), cv.column("queued"), cv.column("batch"),
            self.H[rows], tier_rows,
            cv.column("healthy") & (cv.column("role") == ROLE_DECODE),
            cv.column("iter_scale"),
            [oracle.tier_bandwidth[t] for t in TIERS],
            [oracle.tier_latency[t] for t in TIERS],
            [cong[t] for t in TIERS], infl_rows,
            s_r=[it.req.kv_bytes for it in items],
            input_len=[it.req.input_len for it in items],
            iter_a=sched.iter_model.a, iter_b=sched.iter_model.b,
            m_min=sched.m_min, beta_max=sched.beta_max,
            interpret=sched._pallas_interpret,
        )
        self._pl_rows = {int(k): i for i, k in enumerate(rows)}
        self._pl_costs = np.asarray(costs)
        self._pl_best = np.asarray(best)
        self._free0 = cv.column("free_memory").copy()
        self._healthy0 = (cv.column("healthy")
                          & (cv.column("role") == ROLE_DECODE)).copy()
        # The kernel masks in f32: replicate its s_eff + m_min threshold so
        # feasibility flips from later reserves are detected in f32 terms.
        h32 = self.H[rows].astype(np.float32)
        l32 = np.array([it.req.input_len for it in items],
                       np.float32)[:, None]
        s32 = np.array([it.req.kv_bytes for it in items], np.float32)[:, None]
        hit = np.minimum(h32, l32)
        se32 = s32 * (np.float32(1.0) - hit / np.maximum(l32, np.float32(1.0)))
        self._pl_thr32 = se32 + np.float32(sched.m_min)

    # -------------------------------------------------------------- accounting
    def take_setup_time(self) -> float:
        """One-shot: the cohort precompute wall time (fold into row 0's
        decision latency so the per-decision metric stays comparable)."""
        s, self._setup_s = self._setup_s, 0.0
        return s

    def _watch_slot(self, iid: int) -> None:
        if self._evictions_fn is None:
            return
        self._watch[iid] = (self._cv.slot_of(iid), self._evictions_fn(iid))

    def _poll_evictions(self, k: int) -> None:
        """Reserve-time evictions on a winner shrink later rows' prefix hits
        on that slot only; refresh exactly those H/SE entries."""
        if not self._watch:
            return
        for iid, (slot, count) in list(self._watch.items()):
            cur = self._evictions_fn(iid)
            if cur == count:
                continue
            self._watch[iid] = (slot, cur)
            for r in range(k, len(self._items)):
                req = self._items[r].req
                new = float(self._hit_fn(r, iid))
                if new == self.H[r, slot]:
                    continue
                self.H[r, slot] = new
                if req.input_len > 0:
                    l = float(req.input_len)
                    self.SE[r, slot] = req.kv_bytes * (
                        1.0 - min(max(new, 0.0), l) / l)
                self._dirty[r] = True

    # ------------------------------------------------------------------ select
    def select_row(self, k: int) -> Optional[Decision]:
        """Row k's decision — bit-identical to ``sched.select`` at its turn."""
        self._poll_evictions(k)
        item = self._items[k]
        req, pid = item.req, item.prefill_id
        sched, cv, oracle = self._sched, self._cv, self._oracle
        se = self.SE[k]
        mask = cv.column("healthy") & (cv.column("role") == ROLE_DECODE) & (
            cv.column("free_memory") >= se + sched.m_min)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        kind = self._kind
        h = sched.trace_hook
        if kind == "rr":
            ord_ids = np.argsort(cv.ids[idx])
            pos = sched._next % idx.size
            j = int(idx[ord_ids[pos]])
            sched._next += 1
            iid = int(cv.ids[j])
            if h is not None and h.want_decision():
                j2 = int(idx[ord_ids[(pos + 1) % idx.size]]) \
                    if idx.size > 1 else -1
                sched._note_decision("rr", req, pid, cv, oracle,
                                     sched._oracle_tier_fn(cv, oracle, pid),
                                     j, j2, cache=self.H[k])
            self._watch_slot(iid)
            return Decision(iid, 0.0, 0.0, oracle.tier_of(pid, iid),
                            float(se[j]))
        if kind == "la":
            ties = sched._ties(idx.size)
            j = _pick_min(idx, self._load, ties)
            iid = int(cv.ids[j])
            if h is not None and h.want_decision():
                sched._note_decision(
                    "la", req, pid, cv, oracle,
                    sched._oracle_tier_fn(cv, oracle, pid),
                    j, _runner_up(idx, ties, (self._load[idx],)),
                    cost=self._load, cache=self.H[k], load=self._load)
            self._watch_slot(iid)
            return Decision(iid, float(self._load[j]), 0.0,
                            oracle.tier_of(pid, iid), float(se[j]))
        if kind == "ca":
            neg_hit = -self.H[k]
            ties = sched._ties(idx.size)
            j = _pick_min2(idx, neg_hit, self._load, ties)
            iid = int(cv.ids[j])
            if h is not None and h.want_decision():
                sched._note_decision(
                    "ca", req, pid, cv, oracle,
                    sched._oracle_tier_fn(cv, oracle, pid),
                    j, _runner_up(idx, ties,
                                  (self._load[idx], neg_hit[idx])),
                    cost=neg_hit, cache=self.H[k], load=self._load)
            self._watch_slot(iid)
            return Decision(iid, float(neg_hit[j]), 0.0,
                            oracle.tier_of(pid, iid), float(se[j]))
        if kind == "cla":
            miss = 1.0 - np.minimum(self.H[k], req.input_len) \
                / max(req.input_len, 1)
            score = sched.w_cache * miss + sched.w_load * self._loadn
            ties = sched._ties(idx.size)
            j = _pick_min(idx, score, ties)
            iid = int(cv.ids[j])
            if h is not None and h.want_decision():
                sched._note_decision(
                    "cla", req, pid, cv, oracle,
                    sched._oracle_tier_fn(cv, oracle, pid),
                    j, _runner_up(idx, ties, (score[idx],)),
                    cost=score, cache=self.H[k], load=self._loadn)
            self._watch_slot(iid)
            return Decision(iid, float(score[j]), 0.0,
                            oracle.tier_of(pid, iid), float(se[j]))
        # netkv rungs
        tier_row = cv.tier_row(pid)
        infl = self._inflight if sched.uses_self_contention else None
        if self._pallas and not self._streamed[k]:
            return self._pallas_row(k, req, pid, se, tier_row, infl)
        if self._has_tx[k] and not self._dirty[k] \
                and pid not in self._infl_dirty:
            t_x = self._tx[k]
        else:
            # Invalidated (eviction refresh / same-source n_inflight bump)
            # or never precomputed (pred): the sequential op sequence, with
            # the cohort-invariant Eq. (6)/(7) vectors reused.
            t_x = sched._xfer_vec(req, cv, pid, oracle, infl, se, tier_row)
        cost = (t_x + self._t_q) + self._t_d
        ties = sched._ties(idx.size)
        j = _pick_min(idx, cost, ties)
        best_tier = int(tier_row[j])
        if infl is not None:
            infl.incr(pid, best_tier)
            self._infl_dirty.add(pid)
        if h is not None and h.want_decision():
            sched._note_decision(sched.name, req, pid, cv, oracle,
                                 lambda jj: int(tier_row[jj]),
                                 j, _runner_up(idx, ties, (cost[idx],)),
                                 cost=cost, cache=self.H[k],
                                 load=self._t_q + self._t_d, xfer=t_x)
        iid = int(cv.ids[j])
        self._watch_slot(iid)
        return Decision(iid, float(cost[j]), float(t_x[j]), best_tier,
                        float(se[j]))

    # ------------------------------------------------------------ pallas rows
    def _pallas_feas_unchanged(self, i: int) -> bool:
        """True iff no slot's f32 feasibility bit for kernel row i flipped
        since the snapshot (cost entries don't read free_memory, so an
        unchanged mask means an unchanged row)."""
        cv = self._cv
        live = cv.column("healthy") & (cv.column("role") == ROLE_DECODE)
        if not np.array_equal(live, self._healthy0):
            return False
        free = cv.column("free_memory")
        changed = np.flatnonzero(free != self._free0)
        if changed.size == 0:
            return True
        thr = self._pl_thr32[i, changed]
        f_new = free[changed].astype(np.float32)
        f_old = self._free0[changed].astype(np.float32)
        return bool(np.all((f_new >= thr) == (f_old >= thr)))

    def _pallas_row(self, k, req, pid, se, tier_row, infl):
        sched, cv, oracle = self._sched, self._cv, self._oracle
        i = self._pl_rows.get(k) if self._pl_best is not None else None
        if i is None or self._dirty[k] or pid in self._infl_dirty \
                or not self._pallas_feas_unchanged(i):
            # The single-row kernel reads the live hit_tokens column, which
            # the cohort path never fills (that per-request fill is the cost
            # being amortised) — install row k's hits like _fill_hits would.
            cv.hit_tokens[: cv.n] = self.H[k]
            d = sched._select_pallas(req, pid, cv, oracle, infl, se, tier_row)
        else:
            from repro.kernels.netkv_score import BIG

            j = int(self._pl_best[i])
            best_cost = float(self._pl_costs[i, j])
            if not best_cost < BIG / 2:
                return None
            tier = int(tier_row[j])
            se_j = float(se[j])
            cong = sched._congestion_by_tier(oracle)
            nfl = sched._n_by_tier(infl, pid)
            t_x = transfer_time(se_j, oracle.tier_bandwidth[tier], cong[tier],
                                nfl[tier], oracle.tier_latency[tier])
            if infl is not None:
                infl.incr(pid, tier)
            h = sched.trace_hook
            if h is not None and h.want_decision():
                # Same row the single-row kernel path records (the cohort
                # kernel's f32 cost row is bit-identical across shapes).
                sched._note_pallas(req, pid, cv, oracle, tier_row, se,
                                   self.H[k], self._pl_costs[i], cong, nfl,
                                   j, t_x)
            d = Decision(int(cv.ids[j]), best_cost, t_x, tier, se_j)
        if d is not None:
            if infl is not None:
                self._infl_dirty.add(pid)
            self._watch_slot(d.instance_id)
        return d


class DeflectedCohortSelector:
    """Fused R x D twin of sequential ``Scheduler.select_deflected`` calls.

    The deflected objective (``core/cost.py::deflected_cost``) has no
    network term, so the whole cohort shares ONE Eq. (6)/(7) load vector
    (cohort-invariant: deflected requests enqueue on decode only at prefill
    completion, never between the rows of one cohort) and only two columns
    move between rows: the winner's deflect-queue ETA grows by its own
    ``c*l + d`` and its free memory shrinks by the pinned KV.  Each row
    applies exactly that O(1) delta — same values the live ChunkPlane ETA
    fold and ``reserve`` would produce — so ``select_row(0..R-1)`` is
    bit-identical (decisions AND RNG tie draws) to the sequential ladder
    walking the live view.  Proven by ``tests/test_roleplane.py``.
    """

    def __init__(self, sched: Scheduler, reqs: Sequence[RequestInfo],
                 cv: ClusterView, deflect_eta: np.ndarray,
                 prefill_model) -> None:
        self._sched = sched
        self._reqs = list(reqs)
        self._cv = cv
        self._model = prefill_model
        self._eta = np.array(deflect_eta, np.float64)
        self._free = cv.column("free_memory").copy()
        self._role_ok = cv.column("healthy") \
            & (cv.column("role") == ROLE_DECODE)
        self._load = sched._t_queue_vec(cv) + sched._t_decode_vec(cv)

    def select_row(self, k: int) -> Optional[Decision]:
        sched = self._sched
        req = self._reqs[k]
        mask = self._role_ok & (self._free >= req.kv_bytes + sched.m_min)
        idx = np.flatnonzero(mask)
        if idx.size == 0:
            return None
        cost = deflected_cost(self._eta, self._load)
        ties = sched._ties(idx.size)
        j = int(idx[np.lexsort((ties, cost[idx]))[0]])
        # O(1) winner delta: the ETA fold of submitting this request's
        # chunks (+ c*l + d) and the reserve-time pin, mirroring what the
        # live ChunkPlane/engine do between sequential selections.
        self._eta[j] += self._model.c * req.input_len + self._model.d
        self._free[j] = max(self._free[j] - req.kv_bytes, 0.0)
        return Decision(int(self._cv.ids[j]), float(cost[j]), 0.0, 0, 0.0)
