"""Decode-instance selection policies (Algorithm 1 + the baseline ladder).

Every policy is a *scorer plugin* with the same call signature, mirroring the
paper's deployment story (llm-d Endpoint Picker scorer chain / Dynamo KV
router scoring fn).  The ladder, in ablation order (§VI-H):

  RoundRobin        -> no signal
  LoadAware         -> T_queue + T_decode
  CacheAware        -> max prefix hit, load tiebreak
  CacheLoadAware    -> tuned w_cache/w_load composite (Mooncake Conductor /
                       llm-d composite scorer equivalent; "CLA*")
  NetKVTopoOnly     -> CLA* + static tier map (B_tau, L_tau)
  NetKVStatic       -> + self-contention counter n_inflight^tau(p)
  NetKVFull         -> + dynamic congestion c_tau (Algorithm 1 complete)
  NetKVPredictive   -> beyond paper: EWMA one-step congestion forecast
  NetKVBatch        -> beyond paper: batch-level joint assignment (§VII-C
                       'future work'), see batch_assign.py

All policies share the same feasibility filter (line 1 of Alg. 1) and return
``None`` to signal rejection (line 2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .cost import (
    IterTimeModel,
    effective_bandwidth,
    effective_transfer_bytes,
    first_decode_time,
    queue_time,
    transfer_time,
)
from .oracle import OracleView, SelfContentionTracker, EWMACongestionPredictor


@dataclasses.dataclass
class CandidateState:
    """Scheduler-visible state of one decode instance (§III-C)."""

    instance_id: int
    free_memory: float          # m_d, bytes
    queued: int                 # q_d
    batch_size: int             # beta_d
    hit_tokens: float           # lambda_r(d) for the *current* request
    healthy: bool = True
    iter_scale: float = 1.0     # straggler EWMA multiplier (1.0 = nominal)


@dataclasses.dataclass
class RequestInfo:
    """What the scheduler knows about a request at selection time."""

    request_id: int
    input_len: int
    kv_bytes: float             # s_r (Eq. 1), aggregate across TP shards


@dataclasses.dataclass
class Decision:
    instance_id: int
    cost: float                 # policy-internal score of the winner
    est_transfer_time: float    # seconds, 0 for network-oblivious policies
    tier: int
    s_eff: float                # effective bytes to move


class Scheduler:
    """Base: feasibility filter + shared component models."""

    name = "base"
    uses_tier = False            # static tier map
    uses_self_contention = False
    uses_congestion = False

    def __init__(self, iter_model: IterTimeModel, beta_max: int, m_min: float = 2 * 1024**3,
                 seed: int = 0):
        self.iter_model = iter_model
        self.beta_max = beta_max
        self.m_min = m_min
        # Unbiased deterministic tie-breaking: scoring ties must not collapse
        # onto low instance ids (that would topology-bias network-oblivious
        # policies, since ids order pods).
        self._rng = np.random.default_rng(seed + 0xC0FFEE)

    def _tie(self) -> float:
        return float(self._rng.random())

    # -- shared helpers -----------------------------------------------------
    def _s_eff(self, req: RequestInfo, cand: CandidateState) -> float:
        return effective_transfer_bytes(req.kv_bytes, cand.hit_tokens, req.input_len)

    def feasible(self, req: RequestInfo, cands: Sequence[CandidateState]):
        return [
            c for c in cands
            if c.healthy and c.free_memory >= self._s_eff(req, c) + self.m_min
        ]

    def _t_queue(self, cand: CandidateState) -> float:
        return cand.iter_scale * queue_time(
            cand.queued, cand.batch_size, self.beta_max, self.iter_model
        )

    def _t_decode(self, cand: CandidateState) -> float:
        return cand.iter_scale * first_decode_time(cand.batch_size, self.iter_model)

    def _xfer(
        self,
        req: RequestInfo,
        cand: CandidateState,
        prefill_id: int,
        oracle: OracleView,
        inflight: Optional[SelfContentionTracker],
    ) -> tuple[float, int, float]:
        """(T_xfer, tier, s_eff) under this policy's information set."""
        tier = oracle.tier_of(prefill_id, cand.instance_id)
        s_eff = self._s_eff(req, cand)
        c = self._congestion(oracle, tier)
        n = self._n_inflight(inflight, prefill_id, tier)
        t = transfer_time(
            s_eff, oracle.tier_bandwidth[tier], c, n, oracle.tier_latency[tier]
        )
        return t, tier, s_eff

    def _congestion(self, oracle: OracleView, tier: int) -> float:
        return oracle.congestion.get(tier, 0.0) if self.uses_congestion else 0.0

    def _n_inflight(
        self, inflight: Optional[SelfContentionTracker], prefill_id: int, tier: int
    ) -> int:
        if self.uses_self_contention and inflight is not None:
            return inflight.get(prefill_id, tier)
        return 0

    # -- interface ----------------------------------------------------------
    def select(
        self,
        req: RequestInfo,
        prefill_id: int,
        cands: Sequence[CandidateState],
        oracle: OracleView,
        inflight: Optional[SelfContentionTracker] = None,
    ) -> Optional[Decision]:
        raise NotImplementedError


class RoundRobin(Scheduler):
    name = "rr"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._next = 0

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        feas.sort(key=lambda c: c.instance_id)
        cand = feas[self._next % len(feas)]
        self._next += 1
        tier = oracle.tier_of(prefill_id, cand.instance_id)
        return Decision(cand.instance_id, 0.0, 0.0, tier, self._s_eff(req, cand))


class LoadAware(Scheduler):
    """min T_queue + T_decode."""

    name = "la"

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best = min(feas, key=lambda c: (self._t_queue(c) + self._t_decode(c), self._tie()))
        tier = oracle.tier_of(prefill_id, best.instance_id)
        return Decision(
            best.instance_id,
            self._t_queue(best) + self._t_decode(best),
            0.0,
            tier,
            self._s_eff(req, best),
        )


class CacheAware(Scheduler):
    """max prefix hit length, load as tiebreaker."""

    name = "ca"

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best = min(
            feas,
            key=lambda c: (-c.hit_tokens, self._t_queue(c) + self._t_decode(c), self._tie()),
        )
        tier = oracle.tier_of(prefill_id, best.instance_id)
        return Decision(best.instance_id, -best.hit_tokens, 0.0, tier, self._s_eff(req, best))


class CacheLoadAware(Scheduler):
    """CLA*: w_cache * miss_frac + w_load * normalised load (tuned weights).

    Matches the scoring component of Mooncake's Conductor and llm-d's
    composite scorer; weights per workload from a grid search (§VI-A).
    """

    name = "cla"

    def __init__(self, *args, w_cache: float = 1.0, w_load: float = 1.0, **kwargs):
        super().__init__(*args, **kwargs)
        self.w_cache = w_cache
        self.w_load = w_load

    def _score(self, req: RequestInfo, cand: CandidateState) -> float:
        miss = 1.0 - min(cand.hit_tokens, req.input_len) / max(req.input_len, 1)
        load = (self._t_queue(cand) + self._t_decode(cand)) / self.iter_model(self.beta_max)
        return self.w_cache * miss + self.w_load * load

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best = min(feas, key=lambda c: (self._score(req, c), self._tie()))
        tier = oracle.tier_of(prefill_id, best.instance_id)
        return Decision(
            best.instance_id, self._score(req, best), 0.0, tier, self._s_eff(req, best)
        )


class NetKVFull(Scheduler):
    """Algorithm 1: C[d] = T_xfer + T_queue + T_decode, full oracle."""

    name = "netkv-full"
    uses_tier = True
    uses_self_contention = True
    uses_congestion = True

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        feas = self.feasible(req, cands)
        if not feas:
            return None
        best, best_cost, best_x, best_tier, best_seff = None, float("inf"), 0.0, 0, 0.0
        best_tie = 2.0
        for c in feas:
            t_x, tier, s_eff = self._xfer(req, c, prefill_id, oracle, inflight)
            cost = t_x + self._t_queue(c) + self._t_decode(c)
            tie = self._tie()
            if cost < best_cost or (cost == best_cost and tie < best_tie):
                best, best_cost, best_x, best_tier, best_seff = c, cost, t_x, tier, s_eff
                best_tie = tie
        assert best is not None
        if inflight is not None:
            inflight.incr(prefill_id, best_tier)  # line 14; decremented on done
        return Decision(best.instance_id, best_cost, best_x, best_tier, best_seff)


class NetKVStatic(NetKVFull):
    """Static tier map + self-contention, congestion withheld ('+Self-cont.')."""

    name = "netkv-static"
    uses_congestion = False


class NetKVTopoOnly(NetKVFull):
    """Static tier map only ('+Static' ablation rung)."""

    name = "netkv-topo"
    uses_self_contention = False
    uses_congestion = False

    def select(self, req, prefill_id, cands, oracle, inflight=None):
        # No n_inflight bookkeeping at all on this rung.
        d = super().select(req, prefill_id, cands, oracle, inflight=None)
        return d


class NetKVPredictive(NetKVFull):
    """Beyond paper: consume an EWMA forecast instead of the raw snapshot."""

    name = "netkv-pred"

    def __init__(self, *args, predictor: EWMACongestionPredictor | None = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.predictor = predictor or EWMACongestionPredictor()

    def _congestion(self, oracle: OracleView, tier: int) -> float:
        self.predictor.update(oracle.congestion)
        return self.predictor.predict(tier)


LADDER = {
    "rr": RoundRobin,
    "la": LoadAware,
    "ca": CacheAware,
    "cla": CacheLoadAware,
    "netkv-topo": NetKVTopoOnly,
    "netkv-static": NetKVStatic,
    "netkv-full": NetKVFull,
    "netkv-pred": NetKVPredictive,
}


def make_scheduler(name: str, iter_model: IterTimeModel, beta_max: int, **kw) -> Scheduler:
    try:
        cls = LADDER[name]
    except KeyError:
        from .batch_assign import NetKVBatch  # cycle-free late import

        if name == "netkv-batch":
            return NetKVBatch(iter_model, beta_max, **kw)
        raise ValueError(f"unknown scheduler {name!r}; known: {sorted(LADDER) + ['netkv-batch']}")
    return cls(iter_model, beta_max, **kw)
